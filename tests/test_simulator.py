"""Discrete-event simulator + workload generator (§5)."""

import numpy as np

from repro.core import (ClusterState, QSCH, QSCHConfig, QueuePolicy,
                        QuotaManager, RSCH, SimConfig, Simulator,
                        inference_trace, trace_stats, training_trace)
from repro.core.topology import small_topology


def _sim(topo, state, policy=QueuePolicy.BACKFILL):
    qm = QuotaManager({"t0": {0: 10_000}})
    qsch = QSCH(qm, RSCH(topo), QSCHConfig(policy=policy))
    return Simulator(state, qsch, SimConfig(tick_interval=30.0,
                                            sample_interval=120.0,
                                            binding_latency=10.0))


def test_simulation_drains_all_jobs(topo, state):
    jobs = training_trace(30, seed=1, arrival_rate_per_hour=600,
                          mean_duration_s=600.0)
    jobs = [j for j in jobs if j.n_gpus <= 64]     # fits the small cluster
    sim = _sim(topo, state)
    result = sim.run(jobs)
    assert all(j.state.value == "completed" for j in result.jobs)
    assert state.total_allocated() == 0
    state.check_invariants()


def test_sor_positive_under_load(topo, state):
    jobs = training_trace(20, seed=2, arrival_rate_per_hour=1200,
                          mean_duration_s=1800.0)
    jobs = [j for j in jobs if j.n_gpus <= 64]
    result = _sim(topo, state).run(jobs)
    assert 0.0 < result.metrics.sor() <= 1.0
    assert result.cycles > 0


def test_binding_latency_separates_start_and_run(topo, state):
    jobs = training_trace(5, seed=3, arrival_rate_per_hour=60)
    jobs = [j for j in jobs if j.n_gpus <= 8][:2]
    result = _sim(topo, state).run(jobs)
    for j in result.jobs:
        assert j.run_time == j.start_time + 10.0


def test_training_trace_matches_paper_distribution():
    """§5.1.1 / Fig 2: >90% of jobs below 8 GPUs but <10% of GPU-time;
    >=256-GPU jobs >50% of GPU-time."""
    jobs = training_trace(4000, seed=0)
    stats = trace_stats(jobs)
    assert stats.job_fraction_below(8) > 0.75
    assert stats.job_fraction_below(16) > 0.9
    assert stats.gpu_time_fraction_at_least(256) > 0.5
    small_share = 1 - stats.gpu_time_fraction_at_least(8)
    assert small_share < 0.10


def test_inference_trace_properties():
    jobs = inference_trace(100, seed=0, gpu_types=(0, 1))
    assert all(not j.gang for j in jobs)
    assert all(j.kind.value == "infer" for j in jobs)
    assert {j.gpu_type for j in jobs} == {0, 1}
