"""Discrete-event simulator + workload generator (§5)."""

import numpy as np

from repro.core import (ClusterState, QSCH, QSCHConfig, QueuePolicy,
                        QuotaManager, RSCH, SimConfig, Simulator,
                        inference_trace, trace_stats, training_trace)
from repro.core.topology import small_topology


def _sim(topo, state, policy=QueuePolicy.BACKFILL):
    qm = QuotaManager({"t0": {0: 10_000}})
    qsch = QSCH(qm, RSCH(topo), QSCHConfig(policy=policy))
    return Simulator(state, qsch, SimConfig(tick_interval=30.0,
                                            sample_interval=120.0,
                                            binding_latency=10.0))


def test_simulation_drains_all_jobs(topo, state):
    jobs = training_trace(30, seed=1, arrival_rate_per_hour=600,
                          mean_duration_s=600.0)
    jobs = [j for j in jobs if j.n_gpus <= 64]     # fits the small cluster
    sim = _sim(topo, state)
    result = sim.run(jobs)
    assert all(j.state.value == "completed" for j in result.jobs)
    assert state.total_allocated() == 0
    state.check_invariants()


def test_sor_positive_under_load(topo, state):
    jobs = training_trace(20, seed=2, arrival_rate_per_hour=1200,
                          mean_duration_s=1800.0)
    jobs = [j for j in jobs if j.n_gpus <= 64]
    result = _sim(topo, state).run(jobs)
    assert 0.0 < result.metrics.sor() <= 1.0
    assert result.cycles > 0


def test_binding_latency_separates_start_and_run(topo, state):
    jobs = training_trace(5, seed=3, arrival_rate_per_hour=60)
    jobs = [j for j in jobs if j.n_gpus <= 8][:2]
    result = _sim(topo, state).run(jobs)
    for j in result.jobs:
        assert j.run_time == j.start_time + 10.0


def test_training_trace_matches_paper_distribution():
    """§5.1.1 / Fig 2: >90% of jobs below 8 GPUs but <10% of GPU-time;
    >=256-GPU jobs >50% of GPU-time."""
    jobs = training_trace(4000, seed=0)
    stats = trace_stats(jobs)
    assert stats.job_fraction_below(8) > 0.75
    assert stats.job_fraction_below(16) > 0.9
    assert stats.gpu_time_fraction_at_least(256) > 0.5
    small_share = 1 - stats.gpu_time_fraction_at_least(8)
    assert small_share < 0.10


def test_inference_trace_properties():
    jobs = inference_trace(100, seed=0, gpu_types=(0, 1))
    assert all(not j.gang for j in jobs)
    assert all(j.kind.value == "infer" for j in jobs)
    assert {j.gpu_type for j in jobs} == {0, 1}


# ----------------------------------------------------------------------
# Horizon edge cases
# ----------------------------------------------------------------------
def _one_job(duration, submit=0.0):
    from repro.core import Job
    return Job(uid=1, tenant="t0", gpu_type=0, n_pods=1, gpus_per_pod=8,
               submit_time=submit, duration=duration)


def test_job_still_running_at_horizon(topo, state):
    sim = _sim(topo, state)
    sim.config.horizon = 1000.0
    job = _one_job(duration=5000.0)
    result = sim.run([job])
    assert job.state.value == "running", \
        "the horizon truncates observation, it does not kill jobs"
    assert job.end_time is None
    assert state.total_allocated() == job.n_gpus
    assert result.end_time <= 1000.0
    assert all(s.t <= 1000.0 for s in result.metrics.samples)
    state.check_invariants()


def test_sample_exactly_on_horizon_boundary(topo, state):
    # sample_interval=120 from t0=0: a SAMPLE lands exactly at t=1200.
    # Events AT the horizon are processed; only strictly-later ones drop.
    sim = _sim(topo, state)
    sim.config.horizon = 1200.0
    result = sim.run([_one_job(duration=5000.0)])
    assert any(s.t == 1200.0 for s in result.metrics.samples)
    assert all(s.t <= 1200.0 for s in result.metrics.samples)


def test_end_exactly_on_horizon_boundary(topo, state):
    # binding_latency=10 -> END fires exactly at 10 + duration.
    sim = _sim(topo, state)
    sim.config.horizon = 1010.0
    job = _one_job(duration=1000.0)
    sim.run([job])
    assert job.state.value == "completed"
    assert job.end_time == 1010.0
    assert state.total_allocated() == 0


def test_drain_window_open_across_horizon(topo, state):
    # DRAIN_END past the horizon: the run exits mid-window, cleanly.
    from repro.core import DrainWindow, DynamicsConfig
    sim = _sim(topo, state)
    sim.config.horizon = 2000.0
    sim.config.dynamics = DynamicsConfig(plugins=[
        DrainWindow(nodes=range(8), start=500.0, duration=10_000.0)])
    job = _one_job(duration=300.0)
    result = sim.run([job])
    assert job.state.value == "completed"
    assert state.node_draining[:8].all(), \
        "window still open when observation stopped"
    assert result.drains == 1
    state.check_invariants()
