"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests must see the single
real CPU device; only launch/dryrun.py forces 512 placeholder devices."""

import numpy as np
import pytest

from repro.core import (ClusterState, QSCH, QSCHConfig, QueuePolicy,
                        QuotaManager, QuotaMode, RSCH, RSCHConfig,
                        small_topology)


@pytest.fixture
def topo():
    return small_topology(n_nodes=16, gpus_per_node=8, nodes_per_leaf=4)


@pytest.fixture
def state(topo):
    return ClusterState.create(topo)


def make_qsch(topo, state, *, policy=QueuePolicy.BACKFILL,
              quota=None, mode=QuotaMode.ISOLATED,
              incremental=True, rsch_config=None, **cfg_kw):
    qm = QuotaManager(quota or {"t0": {0: 1024}}, mode=mode)
    rsch = RSCH(topo, rsch_config or RSCHConfig())
    cfg = QSCHConfig(policy=policy, **cfg_kw)
    return QSCH(qm, rsch, cfg, incremental_snapshots=incremental)
