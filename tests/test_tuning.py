"""Self-tuning subsystem (repro.core.tuning): ParamSpace clamping,
controller parity (attached never-mutating controller byte-identical to
a detached run), hill-climb revert-on-regression, starvation
escalation, profile transfer/warm-start, obs integration of parameter
changes, and the semantic soft-affinity contrib plugin."""

import dataclasses
import json
import types

import numpy as np
import pytest

from repro.core import (ClusterState, Job, JobKind, PRIO_HIGH,
                        PRIO_NORMAL, Placement, PodPlacement,
                        QueuePolicy, SimConfig, Simulator, Strategy,
                        small_topology)
from repro.core.framework import (SemanticSoftAffinity, available_plugins,
                                  create_plugin, token_similarity)
from repro.core.metrics import Sample
from repro.core.rsch import RSCHConfig
from repro.core.tuning import (HillClimbController, NoOpController,
                               ObjectiveWeights, ParamSpace,
                               StarvationEscalator, TuningManager,
                               TuningProfile, TuningWindow,
                               bind_profile_weights, frontier_objective)
from repro.core.workload import training_trace
from repro.obs import Telemetry

from conftest import make_qsch


def trace(n, seed):
    """Placeable trace for the 16-node test topology: cap job size at
    64 GPUs (structurally unplaceable jobs would pin the queue) and
    keep durations short so runs drain quickly."""
    return [j for j in training_trace(n, seed=seed,
                                      arrival_rate_per_hour=400,
                                      mean_duration_s=1200.0)
            if j.n_gpus <= 64]


def make_sim(topo, *, policy=QueuePolicy.BACKFILL,
             strategy=Strategy.E_BINPACK, horizon=None):
    state = ClusterState.create(topo)
    qsch = make_qsch(topo, state, policy=policy,
                     rsch_config=RSCHConfig(train_strategy=strategy))
    return Simulator(state, qsch, SimConfig(horizon=horizon))


def placement_fingerprint(jobs):
    return [(j.uid, j.start_time, j.end_time,
             tuple((p.node, p.gpu_indices) for p in j.placement.pods)
             if j.placement else None)
            for j in jobs]


# ----------------------------------------------------------------------
# ParamSpace contract
# ----------------------------------------------------------------------
def make_space(lo=0.0, hi=10.0, step=1.0, integer=False, init=5.0):
    space = ParamSpace()
    box = {"v": init}
    space.register("p", lambda: box["v"],
                   lambda v: box.__setitem__("v", v),
                   lo=lo, hi=hi, max_step=step, integer=integer)
    return space, box


def test_set_clamps_to_bounds_and_rate_limit():
    space, box = make_space()
    # Rate limit: a jump to 10 moves at most max_step from 5.
    assert space.set("p", 10.0) == 6.0
    assert box["v"] == 6.0
    # Bounds: forcing past hi clamps to hi, bypassing only the rate.
    assert space.set("p", 99.0, force=True) == 10.0
    assert space.set("p", -99.0, force=True) == 0.0
    # Non-forced move at the lo edge walks one step up.
    assert space.set("p", 5.0) == 1.0


def test_integer_handles_round():
    space, box = make_space(integer=True, step=4.0)
    assert space.set("p", 7.4) == 7.0
    assert box["v"] == 7.0


def test_noop_write_records_nothing():
    space, _ = make_space()
    seen = []
    space.on_change = seen.append
    assert space.set("p", 5.0) == 5.0
    assert space.changes == [] and seen == []
    space.set("p", 5.5)
    assert len(space.changes) == 1 and len(seen) == 1
    ch = space.changes[0]
    assert (ch.param, ch.previous, ch.value) == ("p", 5.0, 5.5)


def test_apply_skips_unknown_and_forces():
    space, box = make_space()
    skipped = space.apply({"p": 9.0, "ghost": 1.0})
    assert skipped == ["ghost"]
    assert box["v"] == 9.0                  # force bypassed the rate limit
    assert space.changes[0].source == "warm-start"


def test_duplicate_registration_raises():
    space, _ = make_space()
    with pytest.raises(ValueError):
        space.register("p", lambda: 0.0, lambda v: None,
                       lo=0.0, hi=1.0, max_step=0.1)


def test_bind_profile_weights_discovers_fused_terms(topo):
    from repro.core.framework import default_profiles
    space = ParamSpace()
    names = bind_profile_weights(space, default_profiles(topo))
    assert "train-e-binpack.BinpackScore.used" in names
    assert "inference-e-spread.SpreadScore.used" in names
    # Sign-preserving bounds: positive terms stay >= 0, negative <= 0.
    pos = space.param("train-e-binpack.BinpackScore.used")
    assert pos.lo == 0.0 and pos.hi > 0
    neg = space.param("inference-e-spread.SpreadScore.used")
    assert neg.hi == 0.0 and neg.lo < 0
    # Handles are live: writing moves the plugin's fused weights.
    space.set("train-e-binpack.BinpackScore.used", 1.25, force=True)
    assert space.get("train-e-binpack.BinpackScore.used") == 1.25


# ----------------------------------------------------------------------
# Controller parity: attached-but-silent == detached
# ----------------------------------------------------------------------
@pytest.mark.parametrize("policy,strategy", [
    (QueuePolicy.BACKFILL, Strategy.E_BINPACK),
    (QueuePolicy.STRICT_FIFO, Strategy.BINPACK),
    (QueuePolicy.BEST_EFFORT_FIFO, Strategy.E_SPREAD),
])
def test_noop_controller_byte_identity(topo, policy, strategy):
    jobs = trace(40, seed=7)

    def run(attach):
        sim = make_sim(topo, policy=policy, strategy=strategy)
        mgr = None
        if attach:
            mgr = TuningManager([NoOpController()])
            mgr.attach(sim)
        trace = [dataclasses.replace(j) for j in jobs]
        res = sim.run(trace)
        return res, mgr

    res_a, _ = run(attach=False)
    res_b, mgr = run(attach=True)
    assert placement_fingerprint(res_a.jobs) == \
        placement_fingerprint(res_b.jobs)
    assert [dataclasses.asdict(s) for s in res_a.metrics.samples] == \
        [dataclasses.asdict(s) for s in res_b.metrics.samples]
    assert repr(res_a.metrics.report()) == repr(res_b.metrics.report())
    # The controller really observed the run, it just never wrote.
    assert mgr.controllers[0].ticks_seen == res_b.cycles
    assert mgr.space.changes == []


# ----------------------------------------------------------------------
# Hill climb: hysteresis + revert-on-regression
# ----------------------------------------------------------------------
def window_scoring(value):
    """A synthetic window whose frontier objective is exactly ``value``
    (single sample: gar=value, everything else zeroed/absent)."""
    w = TuningWindow(t0=0.0, t1=1800.0)
    w.samples.append(Sample(t=0.0, gar=value, gfr=0.0, allocated=0,
                            capacity=0, queue_depth=0))
    return w


def climb_fixture(seed=0):
    space, box = make_space(lo=0.0, hi=10.0, step=1.0, init=5.0)
    ctl = HillClimbController(objective=ObjectiveWeights(), seed=seed,
                              epsilon=0.0, hysteresis=0.05)
    mgr = TuningManager([ctl])
    ctl.bind(space, mgr)
    return ctl, space, box


def test_hill_climb_reverts_on_regression():
    ctl, space, box = climb_fixture()
    ctl.control(window_scoring(0.6), space)      # baseline + first probe
    assert ctl.baseline == pytest.approx(0.6)
    assert ctl.moves == 1
    probed = box["v"]
    assert probed != 5.0
    ctl.control(window_scoring(0.4), space)      # regression -> revert
    assert ctl.reverts == 1 and ctl.accepts == 0
    assert box["v"] == 5.0
    assert ctl.baseline == pytest.approx(0.6)    # baseline unchanged
    # The revert flowed through the space as a forced, sourced change.
    assert space.changes[-1].source.endswith(":revert")


def test_hill_climb_accepts_improvement_beyond_hysteresis():
    ctl, space, box = climb_fixture()
    ctl.control(window_scoring(0.6), space)
    probed = box["v"]
    ctl.control(window_scoring(0.9), space)      # clear improvement
    assert ctl.accepts == 1 and ctl.reverts == 0
    assert box["v"] == probed                    # move kept
    assert ctl.baseline == pytest.approx(0.9)


def test_hill_climb_hysteresis_blocks_noise():
    ctl, space, box = climb_fixture()
    ctl.control(window_scoring(0.6), space)
    ctl.control(window_scoring(0.62), space)     # within hysteresis
    assert ctl.reverts == 1
    assert box["v"] == 5.0


def test_warm_start_seeds_baseline():
    ctl, space, _ = climb_fixture()
    prof = TuningProfile(name="donor", params={"p": 8.0}, objective=0.7)
    mgr = TuningManager([ctl])
    mgr.space = space
    space.on_change = mgr._emit_change
    mgr.warm_start(prof)
    assert space.get("p") == 8.0
    assert ctl.baseline == pytest.approx(0.7)


# ----------------------------------------------------------------------
# Starvation escalator
# ----------------------------------------------------------------------
def test_escalator_boosts_and_caps(topo, state):
    qsch = make_qsch(topo, state)
    esc = StarvationEscalator(wait_threshold_s=3600.0, boost=30,
                              escalation_period_s=1800.0)
    space = ParamSpace()
    esc.bind(space, TuningManager())
    assert "escalator.wait_threshold_s" in space
    jobs = [Job(uid=1, tenant="t0", gpu_type=0, n_pods=1, gpus_per_pod=8,
                submit_time=0.0),
            Job(uid=2, tenant="t0", gpu_type=0, n_pods=1, gpus_per_pod=8,
                submit_time=5000.0)]
    for j in jobs:
        qsch.submit(j)
    esc.on_tick(3599.0, qsch, space)
    assert jobs[0].priority == PRIO_NORMAL       # not starving yet
    esc.on_tick(3600.0, qsch, space)
    assert jobs[0].priority == PRIO_NORMAL + 30
    assert jobs[1].priority == PRIO_NORMAL       # waited nothing
    esc.on_tick(4000.0, qsch, space)             # inside refractory period
    assert jobs[0].priority == PRIO_NORMAL + 30
    esc.on_tick(5400.0, qsch, space)             # second escalation: capped
    assert jobs[0].priority == PRIO_HIGH
    esc.on_tick(9000.0, qsch, space)             # at cap: left alone
    assert jobs[0].priority == PRIO_HIGH
    assert jobs[1].priority == PRIO_NORMAL + 30  # now starving too
    assert esc.escalations == 3


def test_escalator_threshold_is_tunable():
    esc = StarvationEscalator(wait_threshold_s=3600.0)
    space = ParamSpace()
    esc.bind(space, TuningManager())
    space.set("escalator.wait_threshold_s", 1200.0, force=True)
    assert esc.wait_threshold_s == 1200.0


# ----------------------------------------------------------------------
# Profile serialization + transfer
# ----------------------------------------------------------------------
def test_profile_json_round_trip(tmp_path):
    prof = TuningProfile(name="tuned-a", params={"x": 1.5, "y": -2.0},
                         objective=0.42, meta={"scope": "dc-a"})
    clone = TuningProfile.from_json(prof.to_json())
    assert clone == prof
    path = str(tmp_path / "prof.json")
    prof.save(path)
    assert TuningProfile.load(path) == prof
    # The payload is plain JSON (transferable between processes).
    assert json.loads(prof.to_json())["params"]["y"] == -2.0


def test_manager_export_and_warm_start_round_trip(topo):
    sim = make_sim(topo)
    mgr = TuningManager([HillClimbController(seed=3)])
    mgr.attach(sim)
    sim.run(trace(30, seed=2))
    prof = mgr.export_profile("donor")
    assert prof.params.keys() == set(mgr.space.names())

    sim2 = make_sim(topo)
    mgr2 = TuningManager([HillClimbController(seed=4)])
    mgr2.attach(sim2)
    skipped = mgr2.warm_start(prof)
    assert skipped == []
    assert mgr2.space.snapshot() == prof.params


# ----------------------------------------------------------------------
# Obs integration: ParamChange -> gauge + audit + trace
# ----------------------------------------------------------------------
def test_param_change_reaches_registry_audit_and_trace(topo):
    sim = make_sim(topo)
    tel = Telemetry()
    tel.attach(sim)
    mgr = TuningManager()
    mgr.attach(sim)
    mgr.space.set("qsch.max_preemptions_per_cycle", 32.0, now=123.0,
                  source="test", force=True)
    g = tel.registry.get("kant_tuned_param")
    assert g.value(param="qsch.max_preemptions_per_cycle") == 32.0
    assert tel.audit.summary()["param_changes"] == 1
    change = tel.audit.param_changes[0]
    assert change.value == 32.0 and change.source == "test"
    events = [e for e in tel.tracer.to_json()["traceEvents"]
              if e.get("name") == "param-change"]
    assert len(events) == 1
    assert events[0]["args"]["param"] == "qsch.max_preemptions_per_cycle"
    # Audit export carries the change log.
    assert tel.audit.to_json()["param_changes"][0]["value"] == 32.0


def test_scoped_param_change_labels_member(topo):
    sim = make_sim(topo)
    tel = Telemetry(tracing=False)
    tel.attach(sim, scope="dc-a")
    mgr = TuningManager()
    mgr.attach(sim, scope="dc-a")
    mgr.space.set("qsch.max_preemptions_per_cycle", 48.0, now=1.0,
                  source="test")
    g = tel.registry.get("kant_tuned_param")
    assert g.value(param="qsch.max_preemptions_per_cycle",
                   member="dc-a") == 48.0


# ----------------------------------------------------------------------
# Registry diagnostics + ControllerPlugin slot
# ----------------------------------------------------------------------
def test_create_plugin_unknown_name_suggests_and_lists():
    with pytest.raises(KeyError) as exc:
        create_plugin("BinPackScore")
    msg = str(exc.value)
    assert "BinpackScore" in msg            # close match surfaced
    assert "registered:" in msg
    with pytest.raises(KeyError) as exc:
        create_plugin("HillClimbControler")
    assert "HillClimbController" in str(exc.value)


def test_controllers_are_registered_plugins():
    for name in ("NoOpController", "HillClimbController",
                 "StarvationEscalator"):
        assert name in available_plugins()
        assert create_plugin(name).name == name


# ----------------------------------------------------------------------
# Semantic soft-affinity contrib plugin
# ----------------------------------------------------------------------
def running_job(uid, node, topo, tenant="t0", metadata=None):
    j = Job(uid=uid, tenant=tenant, gpu_type=0, n_pods=1, gpus_per_pod=8,
            kind=JobKind.TRAIN, metadata=metadata)
    j.placement = Placement(pods=[PodPlacement(node=node,
                                               gpu_indices=(0, 1))])
    return j


def test_token_similarity():
    a = frozenset({"llama70b", "sft", "ads"})
    b = frozenset({"llama70b", "dpo", "ads"})
    assert token_similarity(a, b) == pytest.approx(2 / 4)
    assert token_similarity(a, frozenset()) == 0.0


def test_semantic_affinity_pulls_toward_similar_groups(topo):
    # topo: 16 nodes, 4 per leaf -> node 0 in group 0, node 12 in group 3.
    plugin = SemanticSoftAffinity(topo, weight=2.0)
    running = {
        1: running_job(1, 0, topo, metadata="llama70b sft ads"),
        2: running_job(2, 12, topo, metadata="resnet vision batch"),
    }
    ctx = types.SimpleNamespace(running=running)
    job = Job(uid=9, tenant="t1", gpu_type=0, n_pods=1, gpus_per_pod=8,
              metadata="llama70b dpo ads")
    snap = None
    per_group = plugin.group_score(job, snap, np.ones(16, bool), ctx)
    assert per_group[0] == pytest.approx(2.0 * 0.5)   # 2/4 token overlap
    assert per_group[3] == 0.0                        # unrelated
    node_scores = plugin.score(job, snap, np.ones(16, bool), ctx)
    assert node_scores[0] > node_scores[12]


def test_semantic_affinity_tenant_fallback_and_anti(topo):
    plugin = SemanticSoftAffinity(topo, weight=1.0, anti_weight=0.5,
                                  anti_threshold=0.1)
    running = {1: running_job(1, 0, topo, tenant="ads", metadata=None),
               2: running_job(2, 12, topo, tenant="search",
                              metadata=None)}
    ctx = types.SimpleNamespace(running=running)
    job = Job(uid=9, tenant="ads", gpu_type=0, n_pods=1, gpus_per_pod=8)
    per_group = plugin.group_score(job, None, np.ones(16, bool), ctx)
    assert per_group[0] == pytest.approx(1.0)    # same tenant token
    assert per_group[3] == pytest.approx(-0.5)   # occupied, unrelated
    # Empty cluster: the term vanishes instead of crashing.
    assert plugin.group_score(job, None, np.ones(16, bool),
                              types.SimpleNamespace(running={})) is None


# ----------------------------------------------------------------------
# End-to-end: manager windows the run and the climb stays bounded
# ----------------------------------------------------------------------
def test_manager_windows_and_bounded_climb(topo):
    sim = make_sim(topo)
    mgr = TuningManager([HillClimbController(seed=1),
                         StarvationEscalator(wait_threshold_s=600.0)])
    mgr.attach(sim)
    sim.run(trace(60, seed=3))
    assert mgr.periods == len(mgr.history) > 0
    # Every applied change respected its handle's bounds.
    for ch in mgr.space.changes:
        p = mgr.space.param(ch.param)
        assert p.lo <= ch.value <= p.hi
    # The wait harvester saw every started (uid, start_time) pair.
    started = {(j.uid, j.start_time) for j in sim.qsch.running.values()}
    assert mgr._seen_starts >= started
    assert len(mgr._seen_starts) > 0


def test_frontier_objective_nan_safe():
    w = TuningWindow(t0=0.0, t1=10.0)      # no samples, no waits
    assert frontier_objective(w) == 0.0
