"""ClusterState: allocation ledger, pools, fragmentation (§3.4.1, §4.3)."""

import numpy as np
import pytest

from repro.core import ClusterState, Job, Placement, PodPlacement
from repro.core.topology import small_topology


def _job(uid=0, n_pods=1, gpus=4, gpu_type=0, tenant="t0"):
    return Job(uid=uid, tenant=tenant, gpu_type=gpu_type, n_pods=n_pods,
               gpus_per_pod=gpus)


def test_allocate_release_roundtrip(state):
    job = _job(gpus=4)
    p = Placement(pods=[PodPlacement(node=0, gpu_indices=(0, 1, 2, 3))])
    state.allocate(job, p)
    assert state.free_gpus()[0] == 4
    assert state.total_allocated() == 4
    state.check_invariants()
    state.release(job.uid)
    assert state.total_allocated() == 0
    state.check_invariants()


def test_double_allocation_rejected(state):
    job = _job(gpus=2)
    p = Placement(pods=[PodPlacement(node=1, gpu_indices=(0, 1))])
    state.allocate(job, p)
    job2 = _job(uid=1, gpus=2)
    with pytest.raises(ValueError):
        state.allocate(job2, Placement(
            pods=[PodPlacement(node=1, gpu_indices=(1, 2))]))
    state.check_invariants()


def test_gang_all_or_nothing(state):
    """A multi-pod placement with one invalid pod must not mutate."""
    job = _job(n_pods=2, gpus=8)
    bad = Placement(pods=[PodPlacement(node=0, gpu_indices=tuple(range(8))),
                          PodPlacement(node=99, gpu_indices=tuple(range(8)))])
    with pytest.raises(ValueError):
        state.allocate(job, bad)
    assert state.total_allocated() == 0


def test_unhealthy_gpu_excluded(state):
    state.set_gpu_health(2, 0, False)
    assert state.free_gpus()[2] == 7
    assert state.total_allocatable() == 16 * 8 - 1
    job = _job(gpus=8)
    with pytest.raises(ValueError):
        state.allocate(job, Placement(
            pods=[PodPlacement(node=2, gpu_indices=tuple(range(8)))]))


def test_node_health_gates_everything(state):
    state.set_node_health(3, False)
    assert state.free_gpus()[3] == 0
    assert not state.pool_mask(0)[3]


def test_fragmentation_definition(state):
    """§4.3: fragmented = neither fully idle nor fully occupied."""
    assert state.fragmented_nodes().sum() == 0
    state.allocate(_job(uid=1, gpus=3), Placement(
        pods=[PodPlacement(node=0, gpu_indices=(0, 1, 2))]))
    assert state.fragmented_nodes().sum() == 1
    state.allocate(_job(uid=2, gpus=5), Placement(
        pods=[PodPlacement(node=0, gpu_indices=(3, 4, 5, 6, 7))]))
    assert state.fragmented_nodes().sum() == 0     # now fully occupied


def test_node_pools(topo):
    gpu_type = np.array([0] * 8 + [1] * 8, dtype=np.int32)
    st = ClusterState.create(topo, gpu_type=gpu_type)
    assert st.pool_free(0) == 64
    assert st.pool_free(1) == 64
    assert st.pool_mask(0).sum() == 8


def test_dirty_node_tracking(state):
    state.dirty_nodes.clear()
    state.allocate(_job(uid=5, gpus=2), Placement(
        pods=[PodPlacement(node=7, gpu_indices=(0, 1))]))
    assert state.dirty_nodes == {7}
