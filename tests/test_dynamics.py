"""Cluster dynamics subsystem: event bus, failure injection,
checkpoint-restart recovery, drain windows, tidal autoscaling, and the
mid-cycle snapshot-invalidation fix."""

import numpy as np
import pytest

from repro.core import (CheckpointModel, ClusterState, DrainWindow,
                        DynamicsConfig, EventBus, EventKind, Job, JobKind,
                        JobState, NodeFailureInjector, GpuFailureInjector,
                        QSCH, QSCHConfig, QuotaManager, RSCH, SimConfig,
                        Simulator, TidalAutoscaler, TidalService,
                        diurnal_demand)
from repro.core.framework import (DynamicsPlugin, PostBindPlugin,
                                  make_profile, ProfileSet, ebinpack_pass,
                                  single_pass_plan)
from repro.core.job import PRIO_HIGH, PRIO_LOW
from repro.core.snapshot import IncrementalSnapshotter
from repro.core.topology import small_topology

from conftest import make_qsch


class Scripted(DynamicsPlugin):
    """Test helper: replay a fixed event trace."""

    name = "ScriptedEvents"

    def __init__(self, events):
        self.events = events

    def schedule(self, engine, rng):
        return self.events


def make_sim(topo, state, *, dynamics=None, horizon=None, binding=0.0,
             quota=None, tick=30.0):
    qsch = make_qsch(topo, state, quota=quota)
    return Simulator(state, qsch,
                     SimConfig(tick_interval=tick, sample_interval=300.0,
                               binding_latency=binding, horizon=horizon,
                               dynamics=dynamics))


def train_job(uid=1, n_pods=2, gpus_per_pod=8, duration=3600.0,
              submit=0.0, priority=50, preemptible=True, tenant="t0"):
    return Job(uid=uid, tenant=tenant, gpu_type=0, n_pods=n_pods,
               gpus_per_pod=gpus_per_pod, submit_time=submit,
               duration=duration, priority=priority,
               preemptible=preemptible)


# ----------------------------------------------------------------------
# Event bus
# ----------------------------------------------------------------------
def test_event_bus_same_timestamp_order():
    bus = EventBus()
    seen = []
    for kind in (EventKind.SAMPLE, EventKind.TICK, EventKind.NODE_FAIL,
                 EventKind.END, EventKind.SUBMIT):
        bus.subscribe(kind, lambda ev: seen.append(ev.kind))
        bus.push(10.0, kind)
    while len(bus):
        bus.dispatch(bus.pop())
    assert seen == [EventKind.SUBMIT, EventKind.END, EventKind.NODE_FAIL,
                    EventKind.TICK, EventKind.SAMPLE]


def test_event_bus_pending_counters():
    bus = EventBus()
    bus.push(1.0, EventKind.SUBMIT)
    bus.push(2.0, EventKind.SUBMIT)
    bus.push(1.5, EventKind.TICK)
    assert bus.pending(EventKind.SUBMIT) == 2
    bus.pop()
    assert bus.pending(EventKind.SUBMIT) == 1
    assert bus.pending(EventKind.TICK) == 1
    assert bus.pending(EventKind.NODE_FAIL) == 0


# ----------------------------------------------------------------------
# Failure injectors: seeded, reproducible traces
# ----------------------------------------------------------------------
class _FakeEngine:
    def __init__(self, state, horizon):
        self.state = state
        self.horizon = horizon


def test_node_injector_deterministic(topo, state):
    eng = _FakeEngine(state, horizon=86400.0)
    inj = NodeFailureInjector(mtbf_s=6 * 3600.0, repair_s=1800.0,
                              shape=1.2)
    a = inj.schedule(eng, np.random.default_rng(7))
    b = inj.schedule(eng, np.random.default_rng(7))
    c = inj.schedule(eng, np.random.default_rng(8))
    assert a == b
    assert a != c
    assert a, "trace should not be empty at this MTBF/horizon"
    fails = [e for e in a if e[1] is EventKind.NODE_FAIL]
    recovers = [e for e in a if e[1] is EventKind.NODE_RECOVER]
    assert len(fails) == len(recovers)
    assert all(t <= 86400.0 for t, _, _ in fails)


def test_gpu_injector_bounds(topo, state):
    eng = _FakeEngine(state, horizon=86400.0)
    inj = GpuFailureInjector(rate_per_gpu_hour=0.001)
    trace = inj.schedule(eng, np.random.default_rng(0))
    for _, kind, payload in trace:
        assert 0 <= payload["node"] < state.n_nodes
        assert 0 <= payload["gpu"] < state.gpus_per_node


# ----------------------------------------------------------------------
# Checkpoint model math
# ----------------------------------------------------------------------
def test_checkpoint_model_partial_progress():
    model = CheckpointModel(interval_s=600.0, restart_overhead_s=120.0)
    job = train_job(duration=3600.0)
    job.run_time = 0.0
    remaining, lost, overhead = model.on_interrupt(job, 1450.0)
    # 1450s of progress -> last checkpoint at 1200s, 250s recomputed.
    assert job.checkpointed_progress == 1200.0
    assert lost == 250.0 and overhead == 120.0
    assert remaining == 3600.0 - 1200.0 + 120.0


def test_checkpoint_model_second_failure_accounts_overhead():
    model = CheckpointModel(interval_s=600.0, restart_overhead_s=120.0)
    job = train_job(duration=3600.0)
    job.run_time = 0.0
    model.on_interrupt(job, 1450.0)
    job.attempt = 1
    job.run_time = 2000.0
    # Second attempt runs 2000..2850: 850 elapsed minus 120 restore =
    # 730 progress -> one more 600s checkpoint, 130 lost.
    remaining, lost, _ = model.on_interrupt(job, 2850.0)
    assert job.checkpointed_progress == 1800.0
    assert lost == 130.0
    assert remaining == 3600.0 - 1800.0 + 120.0
    assert job.lost_work == 250.0 + 130.0
    assert job.restart_overhead == 240.0


def test_checkpoint_model_scratch_loses_everything():
    model = CheckpointModel(interval_s=600.0, restart_overhead_s=120.0,
                            mode="scratch")
    job = train_job(duration=3600.0)
    job.run_time = 0.0
    remaining, lost, _ = model.on_interrupt(job, 1450.0)
    assert job.checkpointed_progress == 0.0
    assert lost == 1450.0
    assert remaining == 3600.0 + 120.0


def test_checkpoint_model_stateless_service():
    model = CheckpointModel(interval_s=600.0, restart_overhead_s=60.0)
    job = train_job(duration=7200.0)
    job.kind = JobKind.INFER
    job.run_time = 0.0
    remaining, lost, _ = model.on_interrupt(job, 1000.0)
    assert lost == 0.0                       # serving time is not redone
    assert remaining == 7200.0 - 1000.0 + 60.0


def test_checkpoint_model_killed_during_binding():
    model = CheckpointModel(interval_s=600.0, restart_overhead_s=120.0)
    job = train_job(duration=3600.0)
    job.run_time = 500.0                     # container not yet running
    remaining, lost, _ = model.on_interrupt(job, 400.0)
    assert lost == 0.0 and job.checkpointed_progress == 0.0
    assert remaining == 3600.0 + 120.0


# ----------------------------------------------------------------------
# Failure -> kill -> requeue -> recover, end to end
# ----------------------------------------------------------------------
def test_node_fail_kills_requeues_and_recovers(topo, state):
    # Kill the whole cluster at t=650, bring it back at t=1200.
    events = [(650.0, EventKind.NODE_FAIL, {"node": n})
              for n in range(state.n_nodes)]
    events += [(1200.0, EventKind.NODE_RECOVER, {"node": n})
               for n in range(state.n_nodes)]
    dyn = DynamicsConfig(plugins=[Scripted(events)],
                         recovery=CheckpointModel(interval_s=600.0,
                                                  restart_overhead_s=120.0))
    sim = make_sim(topo, state, dynamics=dyn)
    job = train_job(duration=3600.0)
    result = sim.run([job])
    assert job.state is JobState.COMPLETED
    assert job.interrupt_count == 1 and job.attempt == 1
    # 650s elapsed -> checkpoint at 600 survives; the second attempt is
    # 3600 - 600 + 120 = 3120s long.
    assert job.checkpointed_progress == 600.0
    assert job.lost_work == 50.0
    assert job.end_time == pytest.approx(job.run_time + 3120.0)
    assert result.failures == state.n_nodes
    assert result.interrupts == 1
    assert state.node_healthy.all()
    assert state.total_allocated() == 0
    state.check_invariants()
    # MTTR recorded: restart happened after recovery at t=1200.
    assert result.metrics.mttr() >= 1200.0 - 650.0
    assert result.metrics.lost_gpu_seconds == 50.0 * job.n_gpus


def test_gpu_fail_kills_only_resident_job(topo, state):
    sim = make_sim(topo, state, dynamics=DynamicsConfig(plugins=[
        Scripted([(500.0, EventKind.GPU_FAIL, {"node": 0, "gpu": 0}),
                  (2000.0, EventKind.GPU_RECOVER,
                   {"node": 0, "gpu": 0})])]))
    # Binpack fills node 0 first: job a lands there, job b elsewhere.
    a = train_job(uid=1, n_pods=1, gpus_per_pod=8, duration=3000.0)
    b = train_job(uid=2, n_pods=1, gpus_per_pod=8, duration=3000.0)
    result = sim.run([a, b])
    assert a.state is JobState.COMPLETED
    assert b.state is JobState.COMPLETED
    victims = [j for j in (a, b) if j.interrupt_count]
    assert len(victims) == 1, "exactly one job sat on the failed GPU"
    assert result.failures == 1
    state.check_invariants()


def test_stale_end_event_ignored_after_interrupt(topo, state):
    # The killed attempt's END must not complete the restarted job early.
    events = [(650.0, EventKind.NODE_FAIL, {"node": n})
              for n in range(state.n_nodes)]
    events += [(700.0, EventKind.NODE_RECOVER, {"node": n})
               for n in range(state.n_nodes)]
    dyn = DynamicsConfig(plugins=[Scripted(events)])
    sim = make_sim(topo, state, dynamics=dyn)
    job = train_job(duration=3600.0)
    sim.run([job])
    assert job.state is JobState.COMPLETED
    # Original END would have fired at ~3600; the restart pushed it out.
    assert job.end_time > 3600.0


# ----------------------------------------------------------------------
# Drain windows
# ----------------------------------------------------------------------
def test_drain_excludes_new_placements_keeps_running(topo, state):
    # Node 0..7 drain during [1000, 5000); a runs there already.
    dyn = DynamicsConfig(plugins=[
        DrainWindow(nodes=range(8), start=1000.0, duration=4000.0)])
    sim = make_sim(topo, state, dynamics=dyn)
    a = train_job(uid=1, n_pods=8, gpus_per_pod=8, duration=3000.0)
    b = train_job(uid=2, n_pods=4, gpus_per_pod=8, duration=1000.0,
                  submit=1500.0)
    sim.run([a, b])
    assert a.state is JobState.COMPLETED
    assert a.interrupt_count == 0, "no-evict drain keeps jobs running"
    assert b.state is JobState.COMPLETED
    assert all(p.node >= 8 for p in b.placement.pods), \
        "placement during the window must avoid draining nodes"
    assert not state.node_draining.any()
    state.check_invariants()


def test_drain_evict_checkpoint_restarts(topo, state):
    dyn = DynamicsConfig(
        plugins=[Scripted([
            (700.0, EventKind.DRAIN_START,
             {"nodes": list(range(16)), "evict": True}),
            (1300.0, EventKind.DRAIN_END,
             {"nodes": list(range(16)), "evict": True})])],
        recovery=CheckpointModel(interval_s=600.0,
                                 restart_overhead_s=120.0))
    sim = make_sim(topo, state, dynamics=dyn)
    job = train_job(duration=3600.0)
    result = sim.run([job])
    assert job.state is JobState.COMPLETED
    assert job.interrupt_count == 1
    assert result.dynamics.drain_evictions == 1
    assert job.checkpointed_progress == 600.0


def test_overlapping_drain_windows_refcount(topo, state):
    # A:[100,600) over {0,1}; B:[200,1000) over {1,2}.  Node 1 must stay
    # drained until BOTH windows close.
    dyn = DynamicsConfig(plugins=[
        DrainWindow(nodes=[0, 1], start=100.0, duration=500.0),
        DrainWindow(nodes=[1, 2], start=200.0, duration=800.0)])
    sim = make_sim(topo, state, dynamics=dyn, horizon=2000.0)
    sim.run([train_job(duration=50.0)])
    # Horizon past both ends: everything reopened.
    assert not state.node_draining.any()
    # Replay manually to inspect the t=700 point (between A-end, B-end).
    state2 = ClusterState.create(topo)
    sim2 = make_sim(topo, state2, dynamics=dyn, horizon=700.0)
    sim2.run([train_job(duration=50.0)])
    assert not state2.node_draining[0], "A closed at 600"
    assert state2.node_draining[1], "B still holds node 1"
    assert state2.node_draining[2]


def test_recovery_past_trace_horizon_not_dropped(topo, state):
    # A failure whose repair lands beyond trace_horizon must still be
    # repaired in a drain-to-empty (horizon=None) run, or the requeued
    # job pends forever and the simulation never terminates.
    dyn = DynamicsConfig(
        plugins=[Scripted(
            [(500.0, EventKind.NODE_FAIL, {"node": n})
             for n in range(state.n_nodes)]
            + [(3000.0, EventKind.NODE_RECOVER, {"node": n})
               for n in range(state.n_nodes)])],
        trace_horizon=1000.0)
    sim = make_sim(topo, state, dynamics=dyn)   # horizon=None: drain
    job = train_job(duration=2000.0)
    result = sim.run([job])
    assert job.state is JobState.COMPLETED
    assert state.node_healthy.all()
    assert result.end_time > 3000.0


# ----------------------------------------------------------------------
# Mid-cycle health changes must invalidate snapshot caches
# ----------------------------------------------------------------------
def test_apply_health_refreshes_rows_and_drops_caches(topo, state):
    snap = IncrementalSnapshotter().take(state)
    pool = snap.candidate_pool(0)           # populate the caches
    snap.derived["group_cap"] = np.ones(3)
    assert pool[3]
    state.set_node_health(3, False)
    snap.apply_health(state, [3])
    assert not snap.candidate_pool(0)[3]
    assert snap.free_gpus[3] == 0
    assert not snap.derived, "derived arrays must be dropped"


class _FailFirstNodeOnBind(PostBindPlugin):
    """Fails the first placement's anchor node mid-cycle, through the
    sanctioned sync path."""

    name = "FailFirstNodeOnBind"

    def __init__(self):
        self.failed_node = None

    def post_bind(self, job, placement, ctx):
        if self.failed_node is None:
            self.failed_node = placement.pods[0].node
            ctx.state.set_node_health(self.failed_node, False)
            ctx.sched.sync_health(ctx.state, [self.failed_node])


def test_mid_cycle_node_fail_not_placed_on(topo, state):
    hook = _FailFirstNodeOnBind()
    plan = single_pass_plan(ebinpack_pass(2.0))
    profiles = ProfileSet(
        train=make_profile("t", plan, post_bind=(hook,)),
        inference=make_profile("i", plan),
        best_effort=make_profile("b", plan))
    quota = QuotaManager({"t0": {0: 1024}})
    rsch = RSCH(topo, profiles=profiles)
    qsch = QSCH(quota, rsch, QSCHConfig())
    # Without the sync, E-Binpack would pile the second 4-GPU pod onto
    # the same (now dead) node and the bind would explode.
    a = train_job(uid=1, n_pods=1, gpus_per_pod=4)
    b = train_job(uid=2, n_pods=1, gpus_per_pod=4)
    qsch.submit(a)
    qsch.submit(b)
    result = qsch.cycle(state, 0.0)
    assert len(result.scheduled) == 2
    assert b.placement.pods[0].node != hook.failed_node
    state.check_invariants()


def test_structurally_unplaceable_job_does_not_thrash(topo, state):
    # A 16-GPU pod can never fit an 8-GPU node: the preemption engine
    # must not evict anything for it, ever.
    qsch = make_qsch(topo, state)
    sim = Simulator(state, qsch, SimConfig(tick_interval=30.0,
                                           horizon=600.0))
    victim = train_job(uid=1, n_pods=4, gpus_per_pod=8, duration=10_000.0,
                       priority=PRIO_LOW)
    giant = train_job(uid=2, n_pods=1, gpus_per_pod=16, duration=100.0,
                      priority=PRIO_HIGH, submit=100.0)
    result = sim.run([victim, giant])
    assert result.preemptions == 0
    assert victim.preempt_count == 0


# ----------------------------------------------------------------------
# Tidal autoscaling
# ----------------------------------------------------------------------
def test_diurnal_curve_shape():
    assert diurnal_demand(14 * 3600.0, 2, 16) == pytest.approx(16.0)
    assert diurnal_demand(2 * 3600.0, 2, 16) == pytest.approx(2.0)
    svc = TidalService(name="s", min_replicas=2, max_replicas=16)
    assert svc.target_replicas(14 * 3600.0) == 16
    assert svc.target_replicas(2 * 3600.0) == 2


def test_tidal_scales_fleet_and_preempts_backfill(topo, state):
    svc = TidalService(name="s", tenant="svc", gpus_per_replica=4,
                       min_replicas=1, max_replicas=8, peak_hour=14.0)
    scaler = TidalAutoscaler([svc], interval_s=900.0)
    quota = {"svc": {0: 1024}, "batch": {0: 1024}}
    dyn = DynamicsConfig(plugins=[scaler])
    sim = make_sim(topo, state, dynamics=dyn, horizon=86_400.0,
                   quota=quota)
    rng = np.random.default_rng(0)
    backlog = [Job(uid=i, tenant="batch", gpu_type=0, n_pods=2,
                   gpus_per_pod=8, priority=PRIO_LOW, preemptible=True,
                   submit_time=float(rng.uniform(0, 1800.0)),
                   duration=float(rng.uniform(3.0, 5.0)) * 3600.0)
               for i in range(40)]
    result = sim.run(backlog)
    assert scaler.replicas_started >= svc.max_replicas, \
        "fleet must ramp to the peak size across the day"
    assert scaler.replicas_retired > 0, "evening ebb must retire"
    assert result.preemptions > 0, \
        "morning ramp must reclaim GPUs from low-priority backfill"
    assert scaler.satisfaction() > 0.9
    # Fleet tracked the curve: peak-hour fleet near max, night near min.
    peak = [s for s in scaler.demand_log
            if 13.5 * 3600 <= s.t <= 14.5 * 3600]
    night = [s for s in scaler.demand_log if s.t <= 2 * 3600]
    assert max(s.fleet for s in peak) >= 7
    assert min(s.fleet for s in night) <= 2
    state.check_invariants()


def test_two_autoscalers_do_not_amplify_each_other(topo, state):
    # Each autoscaler owns its SCALE_DECISION chain: with two of them
    # the event count is the SUM of their cadences, not 2^generations.
    a = TidalAutoscaler([TidalService(name="a", tenant="svc",
                                      min_replicas=0, max_replicas=1)],
                        interval_s=900.0)
    b = TidalAutoscaler([TidalService(name="b", tenant="svc",
                                      min_replicas=0, max_replicas=1)],
                        interval_s=1800.0)
    quota = {"t0": {0: 1024}, "svc": {0: 1024}}
    sim = make_sim(topo, state, quota=quota, horizon=4 * 3600.0,
                   dynamics=DynamicsConfig(plugins=[a, b]))
    result = sim.run([train_job(duration=100.0)])
    expected = (4 * 3600.0 // 900.0 + 1) + (4 * 3600.0 // 1800.0 + 1)
    assert result.scale_events == expected
    assert len(a.demand_log) == 4 * 3600.0 // 900.0 + 1
    assert len(b.demand_log) == 4 * 3600.0 // 1800.0 + 1


def test_retired_replica_credits_pre_interruption_serving(topo, state):
    # Replica serves, a failure interrupts it, it serves again, then is
    # retired: goodput must credit BOTH serving stretches.
    svc = TidalService(name="s", tenant="svc", gpus_per_replica=4,
                       min_replicas=1, max_replicas=1)
    scaler = TidalAutoscaler([svc], interval_s=600.0)
    fail = [(1800.0, EventKind.NODE_FAIL, {"node": n})
            for n in range(state.n_nodes)]
    fail += [(1900.0, EventKind.NODE_RECOVER, {"node": n})
             for n in range(state.n_nodes)]
    dyn = DynamicsConfig(
        plugins=[scaler, Scripted(fail)],
        recovery=CheckpointModel(interval_s=600.0,
                                 restart_overhead_s=100.0))
    quota = {"t0": {0: 1024}, "svc": {0: 1024}}
    sim = make_sim(topo, state, quota=quota, horizon=7200.0, dynamics=dyn)
    result = sim.run([])
    replica = [j for j in sim.qsch.running.values()] or None
    # At the horizon the replica is still running (min_replicas=1), so
    # goodput so far comes only from interruptions/retires; force the
    # accounting check through the engine's own numbers instead:
    served = result.metrics.useful_gpu_seconds
    # The interrupted attempt's 1800s of serving was checkpointed
    # (stateless): nothing of it may be lost.
    assert result.metrics.lost_gpu_seconds == 0.0
    assert result.interrupts == 1
    assert served >= 0.0  # replica still running: credited at retire


def test_retire_after_interrupt_unit(topo, state):
    # Unit-level: retire_job must sum checkpointed serving + current
    # attempt (minus restore overhead).
    from repro.core.dynamics.engine import ClusterDynamics
    qsch = make_qsch(topo, state)
    sim = Simulator(state, qsch, SimConfig())
    eng = ClusterDynamics(DynamicsConfig(
        recovery=CheckpointModel(interval_s=600.0,
                                 restart_overhead_s=100.0)))
    eng.attach(sim)
    job = Job(uid=1, tenant="t0", gpu_type=0, n_pods=1, gpus_per_pod=4,
              kind=JobKind.INFER, gang=False, duration=100_000.0)
    job.checkpointed_progress = 7200.0      # served 2h before a failure
    job.attempt = 1
    qsch.submit(job)
    qsch.cycle(state, 0.0)
    job.run_time = 0.0
    eng.retire_job(job, 3700.0)             # 3700 elapsed - 100 restore
    assert job.state is JobState.COMPLETED
    assert job.original_duration == 7200.0 + 3600.0
    assert sim.metrics.useful_gpu_seconds == (7200.0 + 3600.0) * 4


def test_scale_decision_revives_dead_tick_chain(topo, state):
    # All training done long before the autoscaler wants new replicas:
    # the SCALE_DECISION must restart the tick chain or the replicas
    # would never be placed.
    svc = TidalService(name="s", tenant="svc", gpus_per_replica=4,
                       min_replicas=0, max_replicas=4, peak_hour=6.0)
    scaler = TidalAutoscaler([svc], interval_s=3600.0)
    quota = {"t0": {0: 1024}, "svc": {0: 1024}}
    sim = make_sim(topo, state, dynamics=DynamicsConfig(plugins=[scaler]),
                   horizon=8 * 3600.0, quota=quota)
    short = train_job(duration=120.0)
    sim.run([short])
    assert short.state is JobState.COMPLETED
    ran = [s for s in scaler.demand_log if s.running > 0]
    assert ran, "replicas submitted after idle must still get scheduled"


# ----------------------------------------------------------------------
# Parity: disabled dynamics changes nothing
# ----------------------------------------------------------------------
def test_empty_dynamics_is_byte_identical(topo):
    from repro.core import training_trace

    def run(dynamics):
        st = ClusterState.create(topo)
        sim = make_sim(topo, st, dynamics=dynamics, binding=10.0)
        jobs = [j for j in training_trace(40, seed=3,
                                          arrival_rate_per_hour=900,
                                          mean_duration_s=900.0)
                if j.n_gpus <= 64]
        res = sim.run(jobs)
        return ([(j.uid, j.start_time, j.end_time,
                  tuple((p.node, p.gpu_indices) for p in j.placement.pods))
                 for j in res.jobs if j.placement],
                res.metrics.report())

    assert run(None) == run(DynamicsConfig())
