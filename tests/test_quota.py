"""Quota manager: static admission, shared/isolated, reclamation (§3.2.1)."""

import pytest

from repro.core import Job, QuotaManager, QuotaMode


def _job(uid=0, tenant="a", gpus=8, gpu_type=0):
    return Job(uid=uid, tenant=tenant, gpu_type=gpu_type, n_pods=1,
               gpus_per_pod=gpus)


def test_isolated_mode_blocks_over_quota():
    qm = QuotaManager({"a": {0: 8}, "b": {0: 8}}, mode=QuotaMode.ISOLATED)
    assert qm.can_admit(_job(gpus=8))
    assert not qm.can_admit(_job(gpus=9))


def test_shared_mode_borrows():
    qm = QuotaManager({"a": {0: 8}, "b": {0: 8}}, mode=QuotaMode.SHARED)
    j = _job(gpus=12)
    assert qm.can_admit(j)
    qm.charge(j)
    assert j.borrowed_quota == 4
    assert qm.total_used(0) == 12
    # b stays statically admissible within its OWN quota (it reclaims
    # the loan via preemption later, §3.2.3) ...
    assert qm.can_admit(_job(uid=1, tenant="b", gpus=8))
    # ... but a cannot borrow beyond the pool
    assert not qm.can_admit(_job(uid=2, tenant="a", gpus=8))


def test_refund_restores(quota_pair=None):
    qm = QuotaManager({"a": {0: 8}, "b": {0: 8}}, mode=QuotaMode.SHARED)
    j = _job(gpus=12)
    qm.charge(j)
    qm.refund(j)
    assert qm.total_used(0) == 0
    assert j.borrowed_quota == 0
    assert not qm.borrows


def test_per_gpu_type_quota():
    qm = QuotaManager({"a": {0: 8, 1: 2}})
    assert qm.can_admit(_job(gpus=8, gpu_type=0))
    assert not qm.can_admit(_job(gpus=4, gpu_type=1))
    assert qm.can_admit(_job(gpus=2, gpu_type=1))


def test_reclaim_candidates_orders_borrowers():
    qm = QuotaManager({"a": {0: 8}, "b": {0: 8}}, mode=QuotaMode.SHARED)
    j1 = _job(uid=1, tenant="a", gpus=10)
    qm.charge(j1)
    j1.start_time = 100.0
    j1.state = j1.state
    # owner b below quota; pool exhausted -> j1 is a reclaim victim
    victims = qm.reclaim_candidates("b", 0, [j1])
    assert victims == [j1]
    # isolated mode: never reclaims
    qm2 = QuotaManager({"a": {0: 8}}, mode=QuotaMode.ISOLATED)
    assert qm2.reclaim_candidates("b", 0, [j1]) == []


def test_charge_over_quota_raises():
    qm = QuotaManager({"a": {0: 4}})
    with pytest.raises(ValueError):
        qm.charge(_job(gpus=8))


# ----------------------------------------------------------------------
# Shared-mode edge cases
# ----------------------------------------------------------------------
def test_refund_of_partially_borrowed_job():
    """A job satisfied partly from own quota, partly borrowed: its
    refund must return BOTH shares, and a sibling borrow by the same
    tenant must survive the other job's refund untouched."""
    qm = QuotaManager({"a": {0: 8}, "b": {0: 16}}, mode=QuotaMode.SHARED)
    j1 = _job(uid=1, gpus=6)         # within own quota, no borrow
    j2 = _job(uid=2, gpus=6)         # 2 own + 4 borrowed
    qm.charge(j1)
    qm.charge(j2)
    assert j1.borrowed_quota == 0
    assert j2.borrowed_quota == 4
    assert qm.borrows[("a", 0)] == 4
    # Refund the fully-owned job first: the borrow ledger is untouched.
    qm.refund(j1)
    assert qm.borrows[("a", 0)] == 4
    assert qm.tenant_used("a", 0) == 6
    # Refund the borrower: ledger entry fully cleared.
    qm.refund(j2)
    assert qm.tenant_used("a", 0) == 0
    assert not qm.borrows
    assert j2.borrowed_quota == 0


def test_sibling_borrows_partial_ledger_refund():
    """Two borrowing jobs of one tenant: refunding one leaves exactly
    the other's borrowed share in the ledger."""
    qm = QuotaManager({"a": {0: 8}, "b": {0: 16}}, mode=QuotaMode.SHARED)
    j1 = _job(uid=1, gpus=10)        # 8 own + 2 borrowed
    j2 = _job(uid=2, gpus=6)         # all 6 borrowed
    qm.charge(j1)
    qm.charge(j2)
    assert (j1.borrowed_quota, j2.borrowed_quota) == (2, 6)
    assert qm.borrows[("a", 0)] == 8
    qm.refund(j1)
    assert qm.borrows[("a", 0)] == 6
    assert qm.tenant_used("a", 0) == 6
    qm.refund(j2)
    assert not qm.borrows and qm.tenant_used("a", 0) == 0


def test_borrows_split_across_gpu_types():
    """Borrowing is per GPU-type pool: loans in one pool must not leak
    into another pool's ledger, admission, or reclamation."""
    qm = QuotaManager({"a": {0: 4, 1: 4}, "b": {0: 8, 1: 8}},
                      mode=QuotaMode.SHARED)
    j0 = _job(uid=1, gpus=8, gpu_type=0)    # borrows 4 of type 0
    j1 = _job(uid=2, gpus=10, gpu_type=1)   # borrows 6 of type 1
    qm.charge(j0)
    qm.charge(j1)
    assert qm.borrows == {("a", 0): 4, ("a", 1): 6}
    # Further borrows by `a` are bounded per pool: type 0 has 4 left
    # (12 total - 8 used), type 1 only 2 (12 - 10).
    assert qm.can_admit(_job(uid=3, gpus=4, gpu_type=0))
    assert not qm.can_admit(_job(uid=4, gpus=3, gpu_type=1))
    # Reclamation is pool-scoped: b reclaiming type 1 sees only j1.
    assert qm.reclaim_candidates("b", 1, [j0, j1]) == [j1]
    assert qm.reclaim_candidates("b", 0, [j0, j1]) == [j0]
    # Refunding the type-0 borrow leaves the type-1 ledger intact.
    qm.refund(j0)
    assert qm.borrows == {("a", 1): 6}


def test_reclaim_ordering_two_borrowers_same_owner():
    """Two tenants borrowing from the same exhausted pool: reclamation
    victims order by priority first, then most-recently-started, so the
    owner claws back the cheapest work first."""
    qm = QuotaManager({"a": {0: 4}, "b": {0: 4}, "owner": {0: 8}},
                      mode=QuotaMode.SHARED)
    ja = _job(uid=1, tenant="a", gpus=8)    # borrows 4
    jb = _job(uid=2, tenant="b", gpus=8)    # borrows 4
    qm.charge(ja)
    qm.charge(jb)
    ja.start_time, jb.start_time = 100.0, 200.0
    ja.priority = jb.priority = 50
    # Same priority: the most recently started borrower goes first.
    assert qm.reclaim_candidates("owner", 0, [ja, jb]) == [jb, ja]
    # Lower priority outranks recency.
    ja.priority = 10
    assert qm.reclaim_candidates("owner", 0, [ja, jb]) == [ja, jb]
    # A non-preemptible borrower is never a victim.
    ja.preemptible = False
    assert qm.reclaim_candidates("owner", 0, [ja, jb]) == [jb]
    # Once the owner's own quota is exhausted, nothing to reclaim.
    qm.charge(_job(uid=3, tenant="owner", gpus=8))
    assert qm.reclaim_candidates("owner", 0, [ja, jb]) == []
