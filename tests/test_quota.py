"""Quota manager: static admission, shared/isolated, reclamation (§3.2.1)."""

import pytest

from repro.core import Job, QuotaManager, QuotaMode


def _job(uid=0, tenant="a", gpus=8, gpu_type=0):
    return Job(uid=uid, tenant=tenant, gpu_type=gpu_type, n_pods=1,
               gpus_per_pod=gpus)


def test_isolated_mode_blocks_over_quota():
    qm = QuotaManager({"a": {0: 8}, "b": {0: 8}}, mode=QuotaMode.ISOLATED)
    assert qm.can_admit(_job(gpus=8))
    assert not qm.can_admit(_job(gpus=9))


def test_shared_mode_borrows():
    qm = QuotaManager({"a": {0: 8}, "b": {0: 8}}, mode=QuotaMode.SHARED)
    j = _job(gpus=12)
    assert qm.can_admit(j)
    qm.charge(j)
    assert j.borrowed_quota == 4
    assert qm.total_used(0) == 12
    # b stays statically admissible within its OWN quota (it reclaims
    # the loan via preemption later, §3.2.3) ...
    assert qm.can_admit(_job(uid=1, tenant="b", gpus=8))
    # ... but a cannot borrow beyond the pool
    assert not qm.can_admit(_job(uid=2, tenant="a", gpus=8))


def test_refund_restores(quota_pair=None):
    qm = QuotaManager({"a": {0: 8}, "b": {0: 8}}, mode=QuotaMode.SHARED)
    j = _job(gpus=12)
    qm.charge(j)
    qm.refund(j)
    assert qm.total_used(0) == 0
    assert j.borrowed_quota == 0
    assert not qm.borrows


def test_per_gpu_type_quota():
    qm = QuotaManager({"a": {0: 8, 1: 2}})
    assert qm.can_admit(_job(gpus=8, gpu_type=0))
    assert not qm.can_admit(_job(gpus=4, gpu_type=1))
    assert qm.can_admit(_job(gpus=2, gpu_type=1))


def test_reclaim_candidates_orders_borrowers():
    qm = QuotaManager({"a": {0: 8}, "b": {0: 8}}, mode=QuotaMode.SHARED)
    j1 = _job(uid=1, tenant="a", gpus=10)
    qm.charge(j1)
    j1.start_time = 100.0
    j1.state = j1.state
    # owner b below quota; pool exhausted -> j1 is a reclaim victim
    victims = qm.reclaim_candidates("b", 0, [j1])
    assert victims == [j1]
    # isolated mode: never reclaims
    qm2 = QuotaManager({"a": {0: 8}}, mode=QuotaMode.ISOLATED)
    assert qm2.reclaim_candidates("b", 0, [j1]) == []


def test_charge_over_quota_raises():
    qm = QuotaManager({"a": {0: 4}})
    with pytest.raises(ValueError):
        qm.charge(_job(gpus=8))
