"""Integration: Kant scheduling + the workloads it places (cosched)."""

import jax
import numpy as np

from repro.core import (ClusterState, Job, JobKind, QSCH, QSCHConfig,
                        QueuePolicy, QuotaManager, RSCH, RSCHConfig,
                        SimConfig, Simulator, Strategy, training_trace)
from repro.core.topology import small_topology
from repro.launch.cosched import (effective_collective_bw,
                                  estimated_step_time, job_mesh_shape,
                                  placement_quality)
from repro.launch.mesh import ICI_BW


def _run_sim(strategy, jobs, n_nodes=16):
    topo = small_topology(n_nodes=n_nodes, gpus_per_node=8,
                          nodes_per_leaf=4)
    state = ClusterState.create(topo)
    qm = QuotaManager({"t0": {0: 100000}})
    qsch = QSCH(qm, RSCH(topo, RSCHConfig(train_strategy=strategy)),
                QSCHConfig(policy=QueuePolicy.BACKFILL))
    sim = Simulator(state, qsch, SimConfig())
    return topo, sim.run([Job(**{**j.__dict__}) for j in _fresh(jobs)])


def _fresh(jobs):
    out = []
    for j in jobs:
        out.append(Job(uid=j.uid, tenant=j.tenant, gpu_type=j.gpu_type,
                       n_pods=j.n_pods, gpus_per_pod=j.gpus_per_pod,
                       kind=j.kind, gang=j.gang, priority=j.priority,
                       submit_time=j.submit_time, duration=j.duration))
    return out


def test_placement_quality_and_step_time():
    topo = small_topology(n_nodes=16, gpus_per_node=8, nodes_per_leaf=4)
    from repro.core import Placement, PodPlacement
    good = Placement(pods=[PodPlacement(node=n,
                                        gpu_indices=tuple(range(8)))
                           for n in (0, 1)])          # same leaf
    bad = Placement(pods=[PodPlacement(node=n,
                                       gpu_indices=tuple(range(8)))
                          for n in (0, 4)])           # two leaves
    qg = placement_quality(good, topo, 16)
    qb = placement_quality(bad, topo, 16)
    assert qg.group_dev == 1.0 and qb.group_dev == 2.0
    assert effective_collective_bw(qg) == ICI_BW
    assert effective_collective_bw(qb) < ICI_BW
    terms = {"compute": 0.1, "memory": 0.2, "collective": 0.3}
    assert estimated_step_time(terms, qb) > \
        estimated_step_time(terms, qg)


def test_ebinpack_placements_beat_spread_in_perf_model():
    """The beyond-paper loop: E-Binpack's placements give lower estimated
    step time than Spread for multi-node training jobs."""
    jobs = [j for j in training_trace(40, seed=7,
                                      arrival_rate_per_hour=240,
                                      mean_duration_s=1200.0)
            if j.n_gpus <= 64]
    est = {}
    for strat in (Strategy.E_BINPACK, Strategy.SPREAD):
        topo, result = _run_sim(strat, jobs)
        times = []
        for j in result.jobs:
            if j.placement is None or j.n_gpus < 16:
                continue
            q = placement_quality(j.placement, topo, j.n_gpus)
            terms = {"compute": 1.0, "memory": 1.0, "collective": 2.0}
            times.append(estimated_step_time(terms, q))
        est[strat] = float(np.mean(times)) if times else 0.0
    assert est[Strategy.E_BINPACK] <= est[Strategy.SPREAD] + 1e-9


def test_job_mesh_shape_factorization():
    assert job_mesh_shape(64) == (8, 8)
    assert job_mesh_shape(8) == (1, 8)
    assert job_mesh_shape(6) == (3, 2)
    assert job_mesh_shape(1) == (1, 1)


def test_scheduled_job_trains_on_cpu_mesh():
    """Close the loop end-to-end: schedule a job with Kant, build a mesh
    from its placement size, run one real train step under it."""
    from repro.core.snapshot import FullSnapshotter
    from repro.configs import get_arch, make_inputs
    from repro.models import Model
    from repro.sharding.auto import ShardingRules, param_shardings
    from repro.train import AdamWConfig, adamw_init, make_train_step

    topo = small_topology(n_nodes=4, gpus_per_node=1)
    state = ClusterState.create(topo)
    rsch = RSCH(topo)
    job = Job(uid=1, tenant="t0", gpu_type=0, n_pods=1, gpus_per_pod=1,
              kind=JobKind.TRAIN)
    res = rsch.schedule(job, FullSnapshotter().take(state))
    assert res.placement is not None
    data, model_par = job_mesh_shape(res.placement.n_gpus)
    # 1 GPU -> (1,1) mesh over the single real CPU device
    mesh = jax.make_mesh((data, model_par), ("data", "model"))
    cfg = get_arch("glm4-9b", smoke=True)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    shardings = param_shardings(params, ShardingRules(mesh))
    params = jax.device_put(params, shardings)
    step = jax.jit(make_train_step(cfg, AdamWConfig(), remat=False))
    batch = make_inputs(cfg, batch=2, seq=16, kind="train")
    _, _, metrics = step(params, adamw_init(params), batch)
    assert np.isfinite(float(metrics["loss"]))
