"""Metrics: GAR, SOR, GFR, JWTD, JTTED definitions (§4)."""

import numpy as np

from repro.core import (ClusterState, Job, JobKind, MetricsRecorder,
                        Placement, PodPlacement, size_bucket)
from repro.core.topology import small_topology


def _alloc(state, uid, node, gpus):
    job = Job(uid=uid, tenant="t", gpu_type=0, n_pods=1,
              gpus_per_pod=len(gpus), kind=JobKind.TRAIN)
    job.placement = Placement(pods=[PodPlacement(node=node,
                                                 gpu_indices=tuple(gpus))])
    state.allocate(job, job.placement)
    return job


def test_gar_gfr_sample():
    topo = small_topology(n_nodes=4, gpus_per_node=4)
    state = ClusterState.create(topo)
    rec = MetricsRecorder(topo)
    s = rec.sample(0.0, state)
    assert s.gar == 0.0 and s.gfr == 0.0
    _alloc(state, 1, 0, [0, 1, 2, 3])      # full node -> not fragmented
    _alloc(state, 2, 1, [0, 1])            # partial -> fragmented
    s = rec.sample(10.0, state)
    assert s.gar == 6 / 16
    assert s.gfr == 1 / 4


def test_sor_integrates_allocation_over_time():
    """§4.2: SOR = GPU-seconds allocated / GPU-seconds capacity."""
    topo = small_topology(n_nodes=2, gpus_per_node=4)
    state = ClusterState.create(topo)
    rec = MetricsRecorder(topo)
    rec.sample(0.0, state)                 # alloc 0 for [0, 100)
    _alloc(state, 1, 0, [0, 1, 2, 3])
    rec.sample(100.0, state)               # alloc 4 for [100, 200)
    rec.sample(200.0, state)
    assert abs(rec.sor() - (4 * 100) / (8 * 200)) < 1e-9


def test_jwtd_buckets():
    assert size_bucket(1) == "<=8"
    assert size_bucket(64) == "9-64"
    assert size_bucket(256) == "65-256"
    assert size_bucket(2048) == "1025-2048"
    jobs = []
    for uid, (gpus, wait) in enumerate([(4, 10.0), (4, 30.0), (128, 100.0)]):
        j = Job(uid=uid, tenant="t", gpu_type=0, n_pods=1,
                gpus_per_pod=gpus, submit_time=0.0)
        j.start_time = wait
        jobs.append(j)
    rec = MetricsRecorder(small_topology())
    jw = rec.jwtd(jobs)
    assert jw["<=8"] == 20.0
    assert jw["65-256"] == 100.0


def test_jtted_deviation_ratios():
    topo = small_topology(n_nodes=16, gpus_per_node=8, nodes_per_leaf=4)
    rec = MetricsRecorder(topo)
    # 16 GPUs optimally need 2 nodes / 1 group; place on 2 nodes in 2
    # different groups -> node_dev 1.0, group_dev 2.0
    job = Job(uid=1, tenant="t", gpu_type=0, n_pods=2, gpus_per_pod=8,
              kind=JobKind.TRAIN)
    job.placement = Placement(pods=[
        PodPlacement(node=0, gpu_indices=tuple(range(8))),
        PodPlacement(node=4, gpu_indices=tuple(range(8)))])
    rec.on_job_placed(job)
    entry = rec.jtted[0]
    assert entry.node_dev == 1.0
    assert entry.group_dev == 2.0
    by_bucket = rec.jtted_by_bucket()
    assert by_bucket["9-64"] == (1.0, 2.0)


def test_inference_jobs_excluded_from_jtted():
    topo = small_topology()
    rec = MetricsRecorder(topo)
    job = Job(uid=1, tenant="t", gpu_type=0, n_pods=1, gpus_per_pod=2,
              kind=JobKind.INFER, gang=False)
    job.placement = Placement(pods=[PodPlacement(node=0,
                                                 gpu_indices=(0, 1))])
    rec.on_job_placed(job)
    assert rec.jtted == []
