"""Federation subsystem: summary matrix, ClusterSelect routing, GSCH
spillover, federation quotas, lockstep simulation, single-member parity,
and the heterogeneous-trace workload support it rides on."""

import numpy as np
import pytest

from repro.core import (ClusterState, DynamicsConfig, FederatedCluster,
                        FederatedSimulator, GSCHConfig, Job, JobKind,
                        NodeFailureInjector, QSCH, QSCHConfig, QueuePolicy,
                        QuotaManager, QuotaMode, RSCH, RSCHConfig,
                        SimConfig, Simulator, Strategy, make_member,
                        training_trace)
from repro.core.federation import (CapabilityCostSelect, GfrAwareSelect,
                                   GSCH, LeastLoadedSelect,
                                   LocalityAffinitySelect, QuotaFitSelect,
                                   jain_index, summarize,
                                   waiting_percentile)
from repro.core.job import JobState, Placement, PodPlacement


def _job(uid=0, gpus=4, gpu_type=0, tenant="t0", region=None, pods=None,
         submit=0.0, duration=600.0, priority=50):
    n_pods = pods if pods is not None else 1
    per_pod = gpus // n_pods
    return Job(uid=uid, tenant=tenant, gpu_type=gpu_type, n_pods=n_pods,
               gpus_per_pod=per_pod, submit_time=submit,
               duration=duration, region=region, priority=priority)


def _two_members(**kw):
    return FederatedCluster([
        make_member("a", gpu_pools=((0, 4),), region="r0", **kw),
        make_member("b", gpu_pools=((0, 4), (1, 4)), region="r1", **kw),
    ])


# ----------------------------------------------------------------------
# Summary matrix
# ----------------------------------------------------------------------
class TestSummary:
    def test_matrix_shapes_and_pools(self):
        fed = _two_members()
        s = summarize(fed.members, 0.0)
        assert s.gpu_types == [0, 1]
        assert s.free.shape == (2, 2)
        # member a hosts no type-1 pool.
        assert s.capacity[0, 1] == 0
        assert s.capacity[0, 0] == 4 * 8
        assert s.capacity[1, 0] == 4 * 8 and s.capacity[1, 1] == 4 * 8
        assert s.max_node_cap[0, 0] == 8

    def test_structural_vs_immediate_fit(self):
        fed = _two_members()
        s = summarize(fed.members, 0.0)
        j = _job(gpus=16, pods=2)            # 2 pods x 8 GPUs
        assert s.structural_fit(j).tolist() == [True, True]
        assert s.structural_fit(_job(gpus=8, gpu_type=1)).tolist() == \
            [False, True]
        # Committing routing charges flips immediate fit without a walk.
        big = _job(gpus=32, pods=4)
        assert s.immediate_fit(big).tolist() == [True, True]
        s.commit(0, _job(uid=1, gpus=8))
        assert s.immediate_fit(big).tolist() == [False, True]
        assert s.structural_fit(big).tolist() == [True, True]

    def test_queue_depth_and_pending_gangs(self):
        fed = _two_members()
        fed[0].qsch.submit(_job(uid=1, gpus=8))
        fed[0].qsch.submit(_job(uid=2, gpus=16, pods=2))
        s = summarize(fed.members, 0.0)
        assert s.queue_depth.tolist() == [2, 0]
        assert s.pending_gang_gpus.tolist() == [24, 0]

    def test_unknown_gpu_type_never_fits(self):
        fed = _two_members()
        s = summarize(fed.members, 0.0)
        assert not s.structural_fit(_job(gpu_type=7)).any()


# ----------------------------------------------------------------------
# ClusterSelect plugins + GSCH selection
# ----------------------------------------------------------------------
class TestRouting:
    def test_least_loaded_prefers_emptier_member(self):
        fed = _two_members()
        # Load member a: allocate half its pool directly.
        st = fed[0].state
        st.allocate(_job(uid=9, gpus=8), Placement(pods=[
            PodPlacement(node=0, gpu_indices=tuple(range(8)))]))
        gsch = GSCH(fed, GSCHConfig(select=(LeastLoadedSelect(),),
                                    immediate_fit_bonus=0.0))
        assert gsch.route(_job(uid=1, gpus=4), 0.0) == 1

    def test_locality_prefers_home_region(self):
        fed = _two_members()
        gsch = GSCH(fed, GSCHConfig(
            select=(LocalityAffinitySelect(weight=5.0),),
            immediate_fit_bonus=0.0))
        assert gsch.route(_job(uid=1, region="r1"), 0.0) == 1
        assert gsch.route(_job(uid=2, region="r0"), 0.0) == 0
        # No region: indifferent -> lowest index wins ties.
        assert gsch.route(_job(uid=3), 0.0) == 0

    def test_capability_cost_routes_to_cheapest(self):
        fed = FederatedCluster([
            make_member("pricey", gpu_pools=((0, 4),),
                        cost_per_gpu_hour={0: 4.0}, capability={0: 1.0}),
            make_member("cheap", gpu_pools=((0, 4),),
                        cost_per_gpu_hour={0: 1.0}, capability={0: 1.0}),
        ])
        gsch = GSCH(fed, GSCHConfig(select=(CapabilityCostSelect(),),
                                    immediate_fit_bonus=0.0))
        assert gsch.route(_job(uid=1), 0.0) == 1
        # A capability floor vetoes the cheap member.
        fed[1].capability[0] = 0.2
        gsch2 = GSCH(fed, GSCHConfig(
            select=(CapabilityCostSelect(min_capability=0.5),),
            immediate_fit_bonus=0.0))
        assert gsch2.route(_job(uid=2), 0.0) == 0

    def test_quota_fit_vetoes_non_admitting_member(self):
        fed = FederatedCluster([
            make_member("a", gpu_pools=((0, 4),), tenants=("alice",)),
            make_member("b", gpu_pools=((0, 4),), tenants=("bob",)),
        ])
        gsch = GSCH(fed, GSCHConfig(select=(QuotaFitSelect(),),
                                    immediate_fit_bonus=0.0))
        assert gsch.route(_job(uid=1, tenant="bob"), 0.0) == 1
        assert gsch.route(_job(uid=2, tenant="alice"), 0.0) == 0

    def test_gfr_aware_sign_by_job_shape(self):
        fed = _two_members()
        s = summarize(fed.members, 0.0)
        s.frag = np.asarray([0.5, 0.1])
        plug = GfrAwareSelect(weight=1.0)
        small = plug.score(_job(gpus=2), s)
        gang = plug.score(_job(gpus=32, pods=4), s)
        assert small[0] > small[1]          # fill fragmented member
        assert gang[0] < gang[1]            # keep gangs away from frag

    def test_structural_misfit_parks_at_biggest_pool(self):
        fed = _two_members()
        gsch = GSCH(fed, GSCHConfig())
        # 96 GPUs of type 1 exist only at b (32 healthy) -> nothing fits
        # structurally; the job parks at the biggest type-1 pool (b).
        assert gsch.route(_job(uid=1, gpus=96, pods=12, gpu_type=1),
                          0.0) == 1

    def test_routing_is_o_members_per_job(self):
        fed = _two_members()
        gsch = GSCH(fed, GSCHConfig(summary_max_age_s=15.0))
        for i in range(50):
            gsch.route(_job(uid=i, gpus=1), float(i) * 0.1)
        # 5s of arrivals, 15s staleness window -> one walk, not 50.
        assert gsch.stats.summary_refreshes == 1


# ----------------------------------------------------------------------
# Federated simulation: lockstep, spillover, quotas, dynamics
# ----------------------------------------------------------------------
class TestFederatedSimulator:
    def test_routes_and_completes_across_members(self):
        fed = _two_members()
        jobs = [_job(uid=i, gpus=8, submit=float(i)) for i in range(8)]
        res = FederatedSimulator(fed).run(jobs)
        assert all(j.state is JobState.COMPLETED for j in res.jobs)
        assert sum(res.routing.routed) == 8
        # Least-loaded + immediate-fit spreads across both members.
        assert all(n > 0 for n in res.routing.routed)
        assert res.report()["balance_index"] > 0.8

    def test_spillover_rescues_starving_job(self):
        fed = _two_members()
        cfg = GSCHConfig(
            select=(LocalityAffinitySelect(weight=100.0),),
            immediate_fit_bonus=0.0,
            spill_deadline_s=120.0, forward_delay_s=30.0,
            locality_penalty_s=60.0)
        # Home member a (32 GPUs) is pinned by a long resident job; the
        # next r0 job must spill to b to run before the first finishes.
        blocker = _job(uid=1, gpus=32, pods=4, region="r0",
                       duration=20_000.0)
        starver = _job(uid=2, gpus=8, region="r0", submit=10.0,
                       duration=600.0)
        res = FederatedSimulator(fed, cfg).run([blocker, starver])
        assert res.spills == 1
        assert res.routing.cross_region_forwards == 1
        assert starver.state is JobState.COMPLETED
        # It ran on member b (type-0 pool nodes there), after deadline +
        # forward delay + cross-region penalty.
        assert res.members[1].jobs == [starver]
        assert starver.start_time >= 120.0 + 30.0 + 60.0
        assert starver.end_time < blocker.end_time

    def test_no_spillover_when_disabled(self):
        fed = _two_members()
        cfg = GSCHConfig(
            select=(LocalityAffinitySelect(weight=100.0),),
            immediate_fit_bonus=0.0, spillover=False)
        blocker = _job(uid=1, gpus=32, pods=4, region="r0",
                       duration=20_000.0)
        starver = _job(uid=2, gpus=8, region="r0", submit=10.0,
                       duration=600.0)
        res = FederatedSimulator(fed, cfg, horizon=30_000.0).run(
            [blocker, starver])
        assert res.spills == 0
        assert starver.start_time > blocker.end_time - 1.0

    def test_federation_quota_backlog_layered_over_members(self):
        fed = _two_members()
        fq = QuotaManager({"t0": {0: 8}})
        cfg = GSCHConfig(federation_quota=fq)
        first = _job(uid=1, gpus=8, duration=600.0)
        second = _job(uid=2, gpus=8, submit=1.0, duration=600.0)
        res = FederatedSimulator(fed, cfg).run([first, second])
        # Both complete, but the second was held by the global grant
        # until the first finished — member quotas alone allow 10^6.
        assert res.routing.backlogged == 1
        assert all(j.state is JobState.COMPLETED for j in res.jobs)
        assert second.start_time >= first.end_time
        assert fq.total_used(0) == 0     # refunds observed on END

    def test_lockstep_samples_align_while_loaded(self):
        fed = _two_members()
        jobs = [_job(uid=i, gpus=4, submit=1.0 + i, duration=2000.0)
                for i in range(6)]
        res = FederatedSimulator(fed).run(jobs)
        t0 = [s.t for s in res.members[0].metrics.samples]
        t1 = [s.t for s in res.members[1].metrics.samples]
        # Chains start together at the first arrival on both members.
        assert t0[0] == t1[0] == 1.0
        shared = min(len(t0), len(t1)) - 1   # final samples may differ
        assert t0[:shared] == t1[:shared]

    def test_member_dynamics_compose(self):
        members = [
            make_member("a", gpu_pools=((0, 4),)),
            make_member("b", gpu_pools=((0, 4),),
                        sim_config=SimConfig(dynamics=DynamicsConfig(
                            plugins=[NodeFailureInjector(
                                mtbf_s=1800.0, repair_s=600.0)],
                            seed=1))),
        ]
        fed = FederatedCluster(members)
        jobs = [_job(uid=i, gpus=8, submit=float(i), duration=4000.0)
                for i in range(8)]
        res = FederatedSimulator(fed, horizon=6 * 3600.0).run(jobs)
        # Failures hit member b only; member a's report has none.
        assert res.members[1].failures > 0
        assert res.members[0].failures == 0

    def test_jain_index(self):
        assert jain_index([1.0, 1.0, 1.0]) == pytest.approx(1.0)
        assert jain_index([1.0, 0.0, 0.0]) == pytest.approx(1 / 3)
        assert jain_index([]) == 1.0

    def test_waiting_percentile(self):
        jobs = [_job(uid=i) for i in range(10)]
        for i, j in enumerate(jobs):
            j.start_time = float(i)
        assert waiting_percentile(jobs, 90.0) == pytest.approx(8.1)


# ----------------------------------------------------------------------
# Single-member degenerate case == plain Simulator
# ----------------------------------------------------------------------
def _placement_fp(jobs):
    return [(j.uid, j.start_time, j.end_time,
             tuple((p.node, p.gpu_indices)
                   for p in (j.placement.pods if j.placement else ())))
            for j in jobs]


@pytest.mark.parametrize("policy", [QueuePolicy.BACKFILL,
                                    QueuePolicy.STRICT_FIFO])
def test_single_member_parity(policy):
    jobs = training_trace(60, seed=11, arrival_rate_per_hour=600,
                          mean_duration_s=1500.0)
    jobs = [j for j in jobs if j.n_gpus <= 64]

    member = make_member("solo", gpu_pools=((0, 16),), nodes_per_leaf=4,
                         policy=policy)
    topo = member.topology
    state = ClusterState.create(topo)
    qm = QuotaManager({"t0": {0: 10 ** 6}})
    qsch = QSCH(qm, RSCH(topo, RSCHConfig()), QSCHConfig(policy=policy))
    base = Simulator(state, qsch, SimConfig()).run(
        [Job(uid=j.uid, tenant=j.tenant, gpu_type=j.gpu_type,
             n_pods=j.n_pods, gpus_per_pod=j.gpus_per_pod,
             submit_time=j.submit_time, duration=j.duration)
         for j in jobs])
    fedres = FederatedSimulator(FederatedCluster([member])).run(
        [Job(uid=j.uid, tenant=j.tenant, gpu_type=j.gpu_type,
             n_pods=j.n_pods, gpus_per_pod=j.gpus_per_pod,
             submit_time=j.submit_time, duration=j.duration)
         for j in jobs])
    assert _placement_fp(base.jobs) == _placement_fp(fedres.jobs)
    assert base.metrics.report() == fedres.members[0].metrics.report()


# ----------------------------------------------------------------------
# Workload satellite: heterogeneous + multi-region traces
# ----------------------------------------------------------------------
class TestHeterogeneousTrace:
    def test_gpu_types_mix(self):
        jobs = training_trace(300, seed=2, gpu_types=(0, 1, 3),
                              type_probs=(0.5, 0.3, 0.2))
        seen = {j.gpu_type for j in jobs}
        assert seen == {0, 1, 3}
        frac0 = sum(j.gpu_type == 0 for j in jobs) / len(jobs)
        assert 0.35 < frac0 < 0.65

    def test_default_stream_unchanged_by_new_knobs(self):
        base = training_trace(50, seed=7)
        hetero = training_trace(50, seed=7, gpu_types=(0, 1))
        # Same sizes, arrivals, durations, tenants — types draw from a
        # derived rng so heterogeneity A/Bs compare the same jobs.
        for a, b in zip(base, hetero):
            assert (a.n_pods, a.gpus_per_pod, a.submit_time, a.duration,
                    a.tenant) == (b.n_pods, b.gpus_per_pod,
                                  b.submit_time, b.duration, b.tenant)
        assert all(j.gpu_type == 0 for j in base)

    def test_tenant_regions_stamped(self):
        jobs = training_trace(40, seed=3, tenants=("x", "y"),
                              tenant_regions={"x": "r0", "y": "r1"})
        assert all(j.region == {"x": "r0", "y": "r1"}[j.tenant]
                   for j in jobs)
        assert all(j.region is None for j in training_trace(5, seed=3))
