"""Hypothesis property tests on system-wide invariants."""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="install .[test] for the "
                    "property-based invariant sweep")
from hypothesis import given, settings, strategies as st

from repro.core import (ClusterState, QSCH, QSCHConfig, QueuePolicy,
                        Job, JobKind, QuotaManager, QuotaMode, RSCH,
                        RSCHConfig)
from repro.core.topology import small_topology


def _build(policy, n_nodes=12):
    topo = small_topology(n_nodes=n_nodes, gpus_per_node=8,
                          nodes_per_leaf=4)
    state = ClusterState.create(topo)
    qm = QuotaManager({"a": {0: 48}, "b": {0: 48}}, mode=QuotaMode.SHARED)
    qsch = QSCH(qm, RSCH(topo), QSCHConfig(policy=policy,
                                           backfill_head_timeout=60.0))
    return topo, state, qsch


@st.composite
def job_stream(draw):
    n = draw(st.integers(1, 25))
    jobs = []
    t = 0.0
    for i in range(n):
        t += draw(st.floats(0.0, 120.0))
        gpus = draw(st.sampled_from([1, 2, 4, 8, 16, 32, 64]))
        n_pods, per_pod = (1, gpus) if gpus <= 8 else (gpus // 8, 8)
        jobs.append(Job(
            uid=i, tenant=draw(st.sampled_from(["a", "b"])), gpu_type=0,
            n_pods=n_pods, gpus_per_pod=per_pod,
            priority=draw(st.sampled_from([10, 50, 100])),
            submit_time=t,
            duration=draw(st.floats(60.0, 4000.0))))
    return jobs


@given(jobs=job_stream(),
       policy=st.sampled_from(list(QueuePolicy)))
@settings(max_examples=20, deadline=None)
def test_invariants_hold_through_any_schedule(jobs, policy):
    """Whatever the trace and policy: no double allocation, quota ledger
    consistent, GAR bounded, released state clean."""
    topo, state, qsch = _build(policy)
    now = 0.0
    for step in range(12):
        now += 45.0
        for j in jobs:
            if j.submit_time <= now and j.state.value == "pending" \
                    and j.uid not in {x.uid for q in qsch.queues.values()
                                      for x in q} \
                    and j.uid not in qsch.running:
                qsch.submit(j)
        qsch.cycle(state, now)
        state.check_invariants()
        # quota ledger matches running jobs exactly
        used = {}
        for j in qsch.running.values():
            used[j.tenant] = used.get(j.tenant, 0) + j.n_gpus
        for tenant in ("a", "b"):
            assert qsch.quota.tenant_used(tenant, 0) == \
                used.get(tenant, 0)
        # allocation never exceeds capacity
        assert 0 <= state.total_allocated() <= state.total_allocatable()
        # complete some jobs
        for j in list(qsch.running.values()):
            if (j.start_time or 0) + j.duration <= now:
                qsch.on_complete(j, state, now)
    # drain everything still running
    for j in list(qsch.running.values()):
        qsch.on_complete(j, state, now + 1e6)
    assert state.total_allocated() == 0
    assert qsch.quota.total_used(0) == 0


@given(seed=st.integers(0, 1000))
@settings(max_examples=15, deadline=None)
def test_gang_placement_never_partial(seed):
    """RSCH either places every pod of a gang job or none."""
    from repro.core.snapshot import FullSnapshotter
    topo, state, qsch = _build(QueuePolicy.BACKFILL)
    rng = np.random.default_rng(seed)
    rsch = RSCH(topo)
    # randomly pre-occupy
    for n in range(topo.n_nodes):
        k = int(rng.integers(0, 9))
        if k:
            state.gpu_busy[n, :k] = True
    snap = FullSnapshotter().take(state)
    n_pods = int(rng.integers(1, 14))
    job = Job(uid=0, tenant="a", gpu_type=0, n_pods=n_pods,
              gpus_per_pod=8, kind=JobKind.TRAIN)
    res = rsch.schedule(job, snap)
    if res.placement is not None:
        assert len(res.placement.pods) == n_pods
        # no pod overlaps an occupied device
        for pod in res.placement.pods:
            assert not state.gpu_busy[pod.node,
                                      list(pod.gpu_indices)].any()
    # state untouched either way (schedule is pure)
    assert state.allocations == {}


@given(ops=st.lists(
    st.tuples(st.sampled_from(["alloc", "release", "gpu_health",
                               "node_health", "drain", "snap"]),
              st.integers(0, 10 ** 6)),
    min_size=5, max_size=50))
@settings(max_examples=25, deadline=None)
def test_soa_columns_match_naive_reference(ops):
    """Random allocate/release/health/drain interleavings: the SoA
    ground-truth AND maintained derived columns must stay exactly equal
    to a naive per-field reference model, and Full vs Incremental
    snapshots of identically-driven states must stay equal."""
    from repro.core.job import Placement, PodPlacement
    from repro.core.snapshot import (FullSnapshotter,
                                     IncrementalSnapshotter,
                                     snapshots_equal)
    topo = small_topology(n_nodes=16, gpus_per_node=8, nodes_per_leaf=4)
    n, g = topo.n_nodes, topo.gpus_per_node
    state_a = ClusterState.create(topo)       # Full snapshotter
    state_b = ClusterState.create(topo)       # Incremental snapshotter
    full, inc = FullSnapshotter(), IncrementalSnapshotter()
    # Naive per-field reference model: plain arrays, no derived caches.
    busy = np.zeros((n, g), dtype=bool)
    ghealthy = np.ones((n, g), dtype=bool)
    nhealthy = np.ones(n, dtype=bool)
    drain = np.zeros(n, dtype=bool)
    allocs = {}
    uid = 0
    for kind, r in ops:
        rng = np.random.default_rng(r)
        if kind == "alloc":
            k = int(rng.integers(1, g + 1))
            ok = nhealthy & ~drain & ((~busy & ghealthy).sum(1) >= k)
            cand = np.nonzero(ok)[0]
            if len(cand) == 0:
                continue
            node = int(cand[rng.integers(0, len(cand))])
            idxs = np.nonzero(~busy[node] & ghealthy[node])[0][:k]
            job = Job(uid=uid, tenant="a", gpu_type=0, n_pods=1,
                      gpus_per_pod=k)
            pl = Placement(pods=[PodPlacement(
                node=node, gpu_indices=tuple(int(i) for i in idxs))])
            state_a.allocate(job, pl)
            state_b.allocate(job, pl)
            busy[node, idxs] = True
            allocs[uid] = (node, idxs)
            uid += 1
        elif kind == "release":
            if not allocs:
                continue
            u = sorted(allocs)[int(rng.integers(0, len(allocs)))]
            node, idxs = allocs.pop(u)
            state_a.release(u)
            state_b.release(u)
            busy[node, idxs] = False
        elif kind == "gpu_health":
            node, gi = int(rng.integers(0, n)), int(rng.integers(0, g))
            h = bool(rng.integers(0, 2))
            state_a.set_gpu_health(node, gi, h)
            state_b.set_gpu_health(node, gi, h)
            ghealthy[node, gi] = h
        elif kind == "node_health":
            node = int(rng.integers(0, n))
            h = bool(rng.integers(0, 2))
            state_a.set_node_health(node, h)
            state_b.set_node_health(node, h)
            nhealthy[node] = h
        elif kind == "drain":
            nodes = np.unique(rng.integers(0, n, size=3))
            d = bool(rng.integers(0, 2))
            state_a.set_drain(nodes, d)
            state_b.set_drain(nodes, d)
            drain[nodes] = d
        else:                                   # "snap"
            assert snapshots_equal(full.take(state_a),
                                   inc.take(state_b))
    # Ground-truth columns == reference model, on both states.
    for state in (state_a, state_b):
        state.ensure_derived()
        cols = state.cols
        assert np.array_equal(cols.gpu_busy, busy)
        assert np.array_equal(cols.gpu_healthy, ghealthy)
        assert np.array_equal(cols.node_healthy, nhealthy)
        assert np.array_equal(cols.node_draining, drain)
        # Maintained derived columns == from-scratch naive formulas.
        hc = ghealthy.sum(1)
        used = (busy & ghealthy).sum(1)
        assert np.array_equal(cols.healthy_count, hc)
        assert np.array_equal(cols.used_gpus, used)
        assert np.array_equal(cols.free_gpus,
                              np.where(nhealthy, hc - used, 0))
        assert np.array_equal(cols.busy_count, busy.sum(1))
        assert np.array_equal(
            cols.fragmented,
            (used > 0) & (used < hc) & nhealthy)
        state.check_invariants()
    assert snapshots_equal(full.take(state_a), inc.take(state_b))


@given(st.data())
@settings(max_examples=20, deadline=None)
def test_quota_ledger_charge_refund_inverse(data):
    tenants = {"a": {0: 100}, "b": {0: 50}}
    qm = QuotaManager(tenants, mode=QuotaMode.SHARED)
    charged = []
    for i in range(data.draw(st.integers(1, 15))):
        gpus = data.draw(st.integers(1, 40))
        j = Job(uid=i, tenant=data.draw(st.sampled_from(["a", "b"])),
                gpu_type=0, n_pods=1, gpus_per_pod=gpus)
        if qm.can_admit(j):
            qm.charge(j)
            charged.append(j)
        elif charged and data.draw(st.booleans()):
            qm.refund(charged.pop())
    for j in charged:
        qm.refund(j)
    assert qm.total_used(0) == 0
    assert not qm.borrows
