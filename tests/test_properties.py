"""Hypothesis property tests on system-wide invariants."""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="install .[test] for the "
                    "property-based invariant sweep")
from hypothesis import given, settings, strategies as st

from repro.core import (ClusterState, QSCH, QSCHConfig, QueuePolicy,
                        Job, JobKind, QuotaManager, QuotaMode, RSCH,
                        RSCHConfig)
from repro.core.topology import small_topology


def _build(policy, n_nodes=12):
    topo = small_topology(n_nodes=n_nodes, gpus_per_node=8,
                          nodes_per_leaf=4)
    state = ClusterState.create(topo)
    qm = QuotaManager({"a": {0: 48}, "b": {0: 48}}, mode=QuotaMode.SHARED)
    qsch = QSCH(qm, RSCH(topo), QSCHConfig(policy=policy,
                                           backfill_head_timeout=60.0))
    return topo, state, qsch


@st.composite
def job_stream(draw):
    n = draw(st.integers(1, 25))
    jobs = []
    t = 0.0
    for i in range(n):
        t += draw(st.floats(0.0, 120.0))
        gpus = draw(st.sampled_from([1, 2, 4, 8, 16, 32, 64]))
        n_pods, per_pod = (1, gpus) if gpus <= 8 else (gpus // 8, 8)
        jobs.append(Job(
            uid=i, tenant=draw(st.sampled_from(["a", "b"])), gpu_type=0,
            n_pods=n_pods, gpus_per_pod=per_pod,
            priority=draw(st.sampled_from([10, 50, 100])),
            submit_time=t,
            duration=draw(st.floats(60.0, 4000.0))))
    return jobs


@given(jobs=job_stream(),
       policy=st.sampled_from(list(QueuePolicy)))
@settings(max_examples=20, deadline=None)
def test_invariants_hold_through_any_schedule(jobs, policy):
    """Whatever the trace and policy: no double allocation, quota ledger
    consistent, GAR bounded, released state clean."""
    topo, state, qsch = _build(policy)
    now = 0.0
    for step in range(12):
        now += 45.0
        for j in jobs:
            if j.submit_time <= now and j.state.value == "pending" \
                    and j.uid not in {x.uid for q in qsch.queues.values()
                                      for x in q} \
                    and j.uid not in qsch.running:
                qsch.submit(j)
        qsch.cycle(state, now)
        state.check_invariants()
        # quota ledger matches running jobs exactly
        used = {}
        for j in qsch.running.values():
            used[j.tenant] = used.get(j.tenant, 0) + j.n_gpus
        for tenant in ("a", "b"):
            assert qsch.quota.tenant_used(tenant, 0) == \
                used.get(tenant, 0)
        # allocation never exceeds capacity
        assert 0 <= state.total_allocated() <= state.total_allocatable()
        # complete some jobs
        for j in list(qsch.running.values()):
            if (j.start_time or 0) + j.duration <= now:
                qsch.on_complete(j, state, now)
    # drain everything still running
    for j in list(qsch.running.values()):
        qsch.on_complete(j, state, now + 1e6)
    assert state.total_allocated() == 0
    assert qsch.quota.total_used(0) == 0


@given(seed=st.integers(0, 1000))
@settings(max_examples=15, deadline=None)
def test_gang_placement_never_partial(seed):
    """RSCH either places every pod of a gang job or none."""
    from repro.core.snapshot import FullSnapshotter
    topo, state, qsch = _build(QueuePolicy.BACKFILL)
    rng = np.random.default_rng(seed)
    rsch = RSCH(topo)
    # randomly pre-occupy
    for n in range(topo.n_nodes):
        k = int(rng.integers(0, 9))
        if k:
            state.gpu_busy[n, :k] = True
    snap = FullSnapshotter().take(state)
    n_pods = int(rng.integers(1, 14))
    job = Job(uid=0, tenant="a", gpu_type=0, n_pods=n_pods,
              gpus_per_pod=8, kind=JobKind.TRAIN)
    res = rsch.schedule(job, snap)
    if res.placement is not None:
        assert len(res.placement.pods) == n_pods
        # no pod overlaps an occupied device
        for pod in res.placement.pods:
            assert not state.gpu_busy[pod.node,
                                      list(pod.gpu_indices)].any()
    # state untouched either way (schedule is pure)
    assert state.allocations == {}


@given(st.data())
@settings(max_examples=20, deadline=None)
def test_quota_ledger_charge_refund_inverse(data):
    tenants = {"a": {0: 100}, "b": {0: 50}}
    qm = QuotaManager(tenants, mode=QuotaMode.SHARED)
    charged = []
    for i in range(data.draw(st.integers(1, 15))):
        gpus = data.draw(st.integers(1, 40))
        j = Job(uid=i, tenant=data.draw(st.sampled_from(["a", "b"])),
                gpu_type=0, n_pods=1, gpus_per_pod=gpus)
        if qm.can_admit(j):
            qm.charge(j)
            charged.append(j)
        elif charged and data.draw(st.booleans()):
            qm.refund(charged.pop())
    for j in charged:
        qm.refund(j)
    assert qm.total_used(0) == 0
    assert not qm.borrows
