"""Train/serve/data/ckpt substrate: loss decreases, optimizer, engine,
checkpoint roundtrip."""

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import load_checkpoint, save_checkpoint
from repro.configs import get_arch
from repro.data import DataConfig, synthetic_batches
from repro.models import Model
from repro.serve import Request, ServeEngine
from repro.train import (AdamWConfig, TrainState, adamw_init,
                         adamw_update, cross_entropy_loss)


def test_cross_entropy_basics():
    logits = jnp.zeros((1, 2, 4))
    labels = jnp.array([[1, 2]])
    loss = cross_entropy_loss(logits, labels)
    np.testing.assert_allclose(float(loss), np.log(4.0), rtol=1e-6)
    # ignore_id masks positions
    labels = jnp.array([[1, -1]])
    loss = cross_entropy_loss(logits, labels)
    np.testing.assert_allclose(float(loss), np.log(4.0), rtol=1e-6)


def test_adamw_moves_toward_minimum():
    params = {"w": jnp.asarray(5.0)}
    opt = adamw_init(params)
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    for _ in range(200):
        grads = {"w": 2.0 * params["w"]}        # d/dw w^2
        params, opt, _ = adamw_update(cfg, grads, opt, params)
    assert abs(float(params["w"])) < 0.1
    assert int(opt["step"]) == 200


def test_train_loss_decreases():
    """End-to-end: a tiny model learns the sticky-bigram structure."""
    cfg = get_arch("glm4-9b", smoke=True)
    state = TrainState(cfg, jax.random.PRNGKey(0),
                       AdamWConfig(lr=3e-3, weight_decay=0.0))
    data = synthetic_batches(cfg, DataConfig(batch=8, seq=32, seed=0))
    losses = [state.step(next(data))["loss"] for _ in range(30)]
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.5, losses


def test_grad_clip_bounds_update():
    params = {"w": jnp.asarray(1.0)}
    opt = adamw_init(params)
    cfg = AdamWConfig(lr=0.1, grad_clip=1.0, weight_decay=0.0)
    _, _, gnorm = adamw_update(cfg, {"w": jnp.asarray(1e6)}, opt, params)
    assert float(gnorm) == 1e6          # reported raw


def test_serve_engine_drains_requests():
    cfg = get_arch("glm4-9b", smoke=True)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, batch_size=2, max_seq=64)
    rng = np.random.default_rng(0)
    for i in range(5):
        engine.submit(Request(uid=i,
                              prompt=rng.integers(0, cfg.vocab, size=6)
                              .astype(np.int32),
                              max_new_tokens=4))
    finished = engine.run_until_drained()
    assert len(finished) == 5
    assert all(len(r.generated) == 4 for r in finished)


def test_ckpt_roundtrip(tmp_path):
    cfg = get_arch("mixtral-8x7b", smoke=True)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    opt = adamw_init(params)
    save_checkpoint(str(tmp_path), {"params": params, "opt": opt}, step=7)
    loaded = load_checkpoint(str(tmp_path))
    assert loaded["step"] == 7
    flat_a = jax.tree.leaves(params)
    flat_b = jax.tree.leaves(loaded["params"])
    assert len(flat_a) == len(flat_b)
    for a, b in zip(flat_a, flat_b):
        np.testing.assert_array_equal(np.asarray(a), b)


def test_data_pipeline_is_learnable_structure():
    cfg = get_arch("glm4-9b", smoke=True)
    data = synthetic_batches(cfg, DataConfig(batch=4, seq=64, seed=0,
                                             stickiness=1.0))
    b = next(data)
    toks = np.asarray(b["tokens"])
    labs = np.asarray(b["labels"])
    # with stickiness 1.0 every label is the deterministic successor
    assert b["tokens"].shape == (4, 64)
    assert (labs[:, :-1] == toks[:, 1:]).all()


def test_microbatched_step_matches_single_shot():
    """Gradient-accumulation microbatching is numerically the full-batch
    step (same loss, same params after update)."""
    import jax
    import jax.numpy as jnp
    from repro.configs import get_arch, make_inputs
    from repro.models.model import Model
    from repro.train.optim import adamw_init
    from repro.train.step import make_train_step

    cfg = get_arch("glm4-9b", smoke=True)
    m = Model(cfg)
    p = m.init(jax.random.PRNGKey(0))
    opt = adamw_init(p)
    b = make_inputs(cfg, batch=8, seq=16, kind="train")
    s1 = jax.jit(make_train_step(cfg, remat=False, microbatches=1))
    s4 = jax.jit(make_train_step(cfg, remat=False, microbatches=4))
    p1, _, m1 = s1(p, opt, b)
    p4, _, m4 = s4(p, opt, b)
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 1e-5
    d = max(float(jnp.max(jnp.abs(a - c))) for a, c in
            zip(jax.tree.leaves(p1), jax.tree.leaves(p4)))
    assert d < 5e-5          # f32 accumulation-order noise only


def test_microbatches_must_divide_batch():
    import jax
    import pytest as _pytest
    from repro.configs import get_arch, make_inputs
    from repro.models.model import Model
    from repro.train.optim import adamw_init
    from repro.train.step import make_train_step

    cfg = get_arch("glm4-9b", smoke=True)
    m = Model(cfg)
    p = m.init(jax.random.PRNGKey(0))
    b = make_inputs(cfg, batch=6, seq=8, kind="train")
    step = make_train_step(cfg, remat=False, microbatches=4)
    with _pytest.raises(ValueError, match="not divisible"):
        step(p, adamw_init(p), b)


def test_bf16_moments_update_preserves_dtype_and_learns():
    import jax
    import jax.numpy as jnp
    from repro.train.optim import AdamWConfig, adamw_update

    p = {"w": jnp.ones((4, 4), jnp.float32)}
    opt = {"m": {"w": jnp.zeros((4, 4), jnp.bfloat16)},
           "v": {"w": jnp.zeros((4, 4), jnp.bfloat16)},
           "step": jnp.zeros((), jnp.int32)}
    g = {"w": jnp.full((4, 4), 0.5, jnp.float32)}
    cfg = AdamWConfig(lr=1e-2, weight_decay=0.0)
    new_p, new_opt, gn = adamw_update(cfg, g, opt, p)
    assert new_opt["m"]["w"].dtype == jnp.bfloat16
    assert new_opt["v"]["w"].dtype == jnp.bfloat16
    assert float(new_p["w"][0, 0]) < 1.0          # moved against the grad


def test_seq_shard_context_resolves_only_when_enabled():
    import jax
    from jax.sharding import Mesh
    import numpy as np
    from repro.sharding.context import ActivationSharding

    mesh = jax.make_mesh((1,), ("model",))
    off = ActivationSharding(mesh, seq_shard=False)
    on = ActivationSharding(mesh, seq_shard=True)
    assert off.resolve(4096, "seq") is None
    assert on.resolve(4096, "seq") == ("model",)
    assert on.resolve(4095, "seq") == ("model",)   # 1-way axis divides all
