"""Topology: scale-out tiers, HBD, link classes, JTTED optima (§3.3.5)."""

import numpy as np

from repro.core.topology import (ClusterTopology, DIST_CROSS,
                                 DIST_SAME_LEAF, DIST_SAME_NODE,
                                 DIST_SAME_SPINE, DIST_SAME_SUPERSPINE,
                                 small_topology)


def test_hierarchy_ids():
    t = ClusterTopology(n_nodes=32, gpus_per_node=8, nodes_per_leaf=4,
                        leaves_per_spine=2, spines_per_superspine=2,
                        nodes_per_hbd=8)
    assert t.n_leaf_groups == 8
    assert t.leaf_id[0] == t.leaf_id[3] != t.leaf_id[4]
    assert t.spine_id[0] == t.spine_id[7] != t.spine_id[8]
    assert t.n_hbds == 4


def test_node_distance_tiers():
    t = ClusterTopology(n_nodes=32, gpus_per_node=8, nodes_per_leaf=4,
                        leaves_per_spine=2, spines_per_superspine=2,
                        nodes_per_hbd=4)
    assert t.node_distance(0, 0) == DIST_SAME_NODE
    assert t.node_distance(0, 3) == DIST_SAME_LEAF
    assert t.node_distance(0, 7) == DIST_SAME_SPINE
    assert t.node_distance(0, 15) == DIST_SAME_SUPERSPINE
    assert t.node_distance(0, 31) == DIST_CROSS


def test_pairwise_matches_scalar():
    t = small_topology(n_nodes=16)
    nodes = np.array([0, 3, 5, 12, 15])
    mat = t.pairwise_node_distance(nodes)
    for i, a in enumerate(nodes):
        for j, b in enumerate(nodes):
            assert mat[i, j] == t.node_distance(int(a), int(b))


def test_link_classes():
    t = ClusterTopology(n_nodes=2, gpus_per_node=8, nodes_per_leaf=2,
                        leaves_per_spine=1, spines_per_superspine=1,
                        nodes_per_hbd=2, nvlink_island=4, numa_split=4)
    cls = t.gpu_link_class()
    assert cls[0, 1] == 0          # same island
    assert cls[0, 5] == 2          # cross island + cross NUMA
    assert (np.diag(cls) == 0).all()
    nic = t.nic_for_gpu()
    assert nic[0] == nic[3] != nic[4]


def test_jtted_optima():
    t = small_topology(n_nodes=16, gpus_per_node=8, nodes_per_leaf=4)
    assert t.optimal_node_num(8) == 1
    assert t.optimal_node_num(9) == 2
    assert t.optimal_group_num(32) == 1       # 4 nodes, one leaf group
    assert t.optimal_group_num(33) == 2
