"""Trip-count-aware HLO analyzer: validated against hand-computable
programs (the roofline numbers are only as good as this parser)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import (HloModule, analyse_hlo_text,
                                       top_contributors)


def _compile_text(fn, *specs):
    return jax.jit(fn).lower(*specs).compile().as_text()


def test_scan_flops_scale_with_trip_count():
    def f(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        c, _ = jax.lax.scan(body, x, w)
        return c

    d = 128
    x = jax.ShapeDtypeStruct((d, d), jnp.float32)
    flops = {}
    for L in (4, 16):
        w = jax.ShapeDtypeStruct((L, d, d), jnp.float32)
        r = analyse_hlo_text(_compile_text(f, x, w))
        flops[L] = r["flops_per_device"]
        # dominated by L matmuls of 2*d^3
        assert abs(flops[L] - L * 2 * d**3) / (L * 2 * d**3) < 0.05
    assert 3.5 < flops[16] / flops[4] < 4.5


def test_single_matmul_flops_exact():
    def f(a, b):
        return a @ b

    a = jax.ShapeDtypeStruct((64, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 32), jnp.float32)
    r = analyse_hlo_text(_compile_text(f, a, b))
    assert r["flops_per_device"] >= 2 * 64 * 256 * 32
    assert r["flops_per_device"] < 2.2 * 64 * 256 * 32


def test_scan_bytes_do_not_count_full_stack_per_step():
    """The layer scan reads one (d,d) slice per step, not the (L,d,d)
    stack — the slice-aware fusion accounting must see that."""
    def f(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        c, _ = jax.lax.scan(body, x, w)
        return c

    d, L = 256, 32
    x = jax.ShapeDtypeStruct((d, d), jnp.float32)
    w = jax.ShapeDtypeStruct((L, d, d), jnp.float32)
    r = analyse_hlo_text(_compile_text(f, x, w))
    stack_bytes = L * d * d * 4
    # roughly: per step read w slice + read/write c (+ tanh temp, dot
    # operands) ~ 8 slices; catastrophic would be L * stack_bytes (32x).
    assert r["bytes_per_device"] < 12 * stack_bytes
    assert r["bytes_per_device"] > stack_bytes          # every slice read


def test_nested_scan_multiplies():
    def f(x, w):
        def outer(c, wi):
            def inner(ci, _):
                return jnp.tanh(ci @ wi), None
            ci, _ = jax.lax.scan(inner, c, None, length=3)
            return ci, None
        c, _ = jax.lax.scan(outer, x, w)
        return c

    d, L = 64, 5
    x = jax.ShapeDtypeStruct((d, d), jnp.float32)
    w = jax.ShapeDtypeStruct((L, d, d), jnp.float32)
    r = analyse_hlo_text(_compile_text(f, x, w))
    want = L * 3 * 2 * d**3
    assert abs(r["flops_per_device"] - want) / want < 0.1


def test_top_contributors_orders_by_weight():
    def f(x, w, big):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        c, _ = jax.lax.scan(body, x, w)
        return c.sum() + (big @ big).sum()

    d = 64
    x = jax.ShapeDtypeStruct((d, d), jnp.float32)
    w = jax.ShapeDtypeStruct((100, d, d), jnp.float32)   # 100 small dots
    big = jax.ShapeDtypeStruct((256, 256), jnp.float32)  # 1 big dot
    txt = _compile_text(f, x, w, big)
    rows = top_contributors(HloModule(txt), "flops", 5)
    # the loop-weighted small dot (100 * 2*64^3 = 5.2e7) must outrank the
    # single big dot (2*256^3 = 3.4e7)
    assert rows[0][0] > rows[1][0]
    assert rows[0][0] == pytest.approx(100 * 2 * d**3, rel=0.05)


def test_collective_parse_on_sharded_program():
    mesh = jax.make_mesh((1,), ("x",))
    from jax.sharding import NamedSharding, PartitionSpec as P

    def f(a):
        return a.sum()

    a = jax.ShapeDtypeStruct((8, 8), jnp.float32)
    with mesh:
        txt = jax.jit(f, in_shardings=NamedSharding(mesh, P("x", None))
                      ).lower(a).compile().as_text()
    r = analyse_hlo_text(txt)      # 1-device mesh: no collectives emitted
    assert r["collective_bytes_per_device"] >= 0.0


def test_scan_stacking_is_billed_per_slice_not_per_buffer():
    """A scan that stacks its per-step output writes one slice per trip
    in place (DUS-rooted fusion).  Billing the full (T, ...) history per
    step over-counts by ~T (the rwkv6 train_4k 5414s->18s correction,
    EXPERIMENTS.md §Perf iteration 0)."""
    def f(x, w):
        def body(c, wi):
            c = jnp.tanh(c @ wi)
            return c, c            # stacked ys output: (T, d, d)
        _, ys = jax.lax.scan(body, x, w)
        return ys

    d, T = 128, 64
    x = jax.ShapeDtypeStruct((d, d), jnp.float32)
    w = jax.ShapeDtypeStruct((T, d, d), jnp.float32)
    r = analyse_hlo_text(_compile_text(f, x, w))
    slice_bytes = d * d * 4
    # per step: weight-slice read (3 incl. fusion boundary), dot (3),
    # tanh (2), stacked in-place write (3) ~= 11 slices; the buggy
    # accounting billed the full T-slice stack per step (~T^2 total).
    per_step = r["bytes_per_device"] / T
    assert per_step < 13 * slice_bytes, (
        f"per-step bytes {per_step:.3e} suggests the full stack is "
        f"billed per step ({T * slice_bytes:.3e})")
    # sanity: at least the in-place write + one operand read per step
    assert per_step >= 2 * slice_bytes
