"""RSCH: strategies, gang semantics, device-level selection (§3.3)."""

import numpy as np
import pytest

from repro.core import (ClusterState, Job, JobKind, RSCH, RSCHConfig,
                        Strategy)
from repro.core.snapshot import FullSnapshotter
from repro.core.topology import ClusterTopology, small_topology


def _rsch(topo, **kw):
    return RSCH(topo, RSCHConfig(**kw))


def _snap(state):
    return FullSnapshotter().take(state)


def _train_job(uid=0, n_pods=1, gpus=8, prio=50):
    return Job(uid=uid, tenant="t0", gpu_type=0, n_pods=n_pods,
               gpus_per_pod=gpus, kind=JobKind.TRAIN, priority=prio)


def _infer_job(uid=0, n_pods=2, gpus=2):
    return Job(uid=uid, tenant="t0", gpu_type=0, n_pods=n_pods,
               gpus_per_pod=gpus, kind=JobKind.INFER, gang=False)


def test_binpack_prefers_used_nodes(topo, state):
    rsch = _rsch(topo, train_strategy=Strategy.BINPACK)
    j1 = _train_job(uid=1, gpus=4)
    r1 = rsch.schedule(j1, _snap(state))
    state.allocate(j1, r1.placement)
    j2 = _train_job(uid=2, gpus=4)
    r2 = rsch.schedule(j2, _snap(state))
    # exact-fit + used bonus -> same node as j1
    assert r2.placement.pods[0].node == r1.placement.pods[0].node


def test_spread_prefers_idle_nodes(topo, state):
    rsch = _rsch(topo, infer_strategy=Strategy.SPREAD)
    j1 = _infer_job(uid=1, n_pods=1, gpus=2)
    r1 = rsch.schedule(j1, _snap(state))
    state.allocate(j1, r1.placement)
    j2 = _infer_job(uid=2, n_pods=1, gpus=2)
    r2 = rsch.schedule(j2, _snap(state))
    assert r2.placement.pods[0].node != r1.placement.pods[0].node


def test_gang_all_or_nothing(topo, state):
    rsch = _rsch(topo)
    # 17 whole-node pods > 16 nodes -> must fail with no mutation
    big = _train_job(uid=1, n_pods=17, gpus=8)
    res = rsch.schedule(big, _snap(state))
    assert res.placement is None
    assert state.total_allocated() == 0


def test_feasible_checks_pool(topo, state):
    rsch = _rsch(topo)
    snap = _snap(state)
    assert rsch.feasible(_train_job(n_pods=16, gpus=8), snap)
    assert not rsch.feasible(_train_job(n_pods=17, gpus=8), snap)


def test_ebinpack_consolidates_groups(topo, state):
    """LeafGroup-level E-Binpack: small jobs land in the busiest group."""
    rsch = _rsch(topo, train_strategy=Strategy.E_BINPACK)
    j1 = _train_job(uid=1, gpus=8)
    r1 = rsch.schedule(j1, _snap(state))
    state.allocate(j1, r1.placement)
    seed_group = int(topo.leaf_id[r1.placement.pods[0].node])
    for uid in range(2, 5):
        j = _train_job(uid=uid, gpus=8)
        r = rsch.schedule(j, _snap(state))
        state.allocate(j, r.placement)
        assert int(topo.leaf_id[r.placement.pods[0].node]) == seed_group


def test_multi_group_job_minimizes_groups(topo, state):
    rsch = _rsch(topo, train_strategy=Strategy.E_BINPACK)
    # 8 whole nodes = 2 full leaf groups (4 nodes each)
    j = _train_job(uid=1, n_pods=8, gpus=8)
    r = rsch.schedule(j, _snap(state))
    assert r.placement is not None
    groups = {int(topo.leaf_id[p.node]) for p in r.placement.pods}
    assert len(groups) == 2


def test_espread_uses_dedicated_zone(topo):
    state = ClusterState.create(topo, inference_zone_nodes=4)
    rsch = _rsch(topo, infer_strategy=Strategy.E_SPREAD)
    j = _infer_job(uid=1, n_pods=2, gpus=2)
    r = rsch.schedule(j, _snap(state))
    assert r.placement is not None
    for pod in r.placement.pods:
        assert pod.node < 4        # inside the zone


def test_espread_large_pods_fall_back_to_general_pool(topo):
    state = ClusterState.create(topo, inference_zone_nodes=4)
    rsch = _rsch(topo, infer_strategy=Strategy.E_SPREAD)
    j = Job(uid=2, tenant="t0", gpu_type=0, n_pods=1, gpus_per_pod=8,
            kind=JobKind.INFER, gang=False)
    r = rsch.schedule(j, _snap(state))
    assert r.placement is not None
    assert r.placement.pods[0].node >= 4   # E-Binpack outside the zone


def test_device_selection_prefers_one_island():
    topo = ClusterTopology(n_nodes=1, gpus_per_node=8, nodes_per_leaf=1,
                           leaves_per_spine=1, spines_per_superspine=1,
                           nodes_per_hbd=1, nvlink_island=4, numa_split=4)
    state = ClusterState.create(topo)
    rsch = _rsch(topo)
    # occupy gpu 0 and 1 -> island 0 has 2 free, island 1 has 4 free
    state.gpu_busy[0, 0] = state.gpu_busy[0, 1] = True
    gpus = rsch._pick_devices(state.gpu_busy[0], state.gpu_healthy[0], 4)
    assert set(gpus) == {4, 5, 6, 7}       # the intact island
    nic = topo.nic_for_gpu()
    assert len({int(nic[g]) for g in gpus}) == 1


def test_unhealthy_devices_skipped(topo, state):
    rsch = _rsch(topo)
    state.set_gpu_health(0, 3, False)
    j = _train_job(uid=1, gpus=8)
    r = rsch.schedule(j, _snap(state))
    assert r.placement is not None
    assert r.placement.pods[0].node != 0   # node 0 has only 7 healthy


# ----------------------------------------------------------------------
# Batched gang placement (§3.4): one fused pass must equal the per-pod
# sequential loop — same nodes, same order, same devices.
# ----------------------------------------------------------------------
def _fragment(state, rng):
    for node in range(state.n_nodes):
        k = int(rng.integers(0, state.gpus_per_node + 1))
        if k and rng.random() < 0.6:
            free = np.nonzero(~state.gpu_busy[node])[0][:k]
            state.gpu_busy[node, free] = True


@pytest.mark.parametrize("strategy", list(Strategy))
@pytest.mark.parametrize("n_pods,gpus", [(1, 8), (4, 8), (8, 4), (12, 2)])
def test_batched_matches_sequential(topo, strategy, n_pods, gpus):
    import zlib
    rng = np.random.default_rng(
        zlib.crc32(f"{strategy.value}-{n_pods}-{gpus}".encode()))
    state = ClusterState.create(topo)
    _fragment(state, rng)
    state.set_gpu_health(1, 0, False)
    snap = _snap(state)
    kind = JobKind.INFER if strategy in (Strategy.SPREAD,
                                         Strategy.E_SPREAD) else JobKind.TRAIN
    job = Job(uid=1, tenant="t0", gpu_type=0, n_pods=n_pods,
              gpus_per_pod=gpus, kind=kind, gang=(kind is JobKind.TRAIN))
    kw = dict(train_strategy=strategy, infer_strategy=strategy)
    rb = _rsch(topo, batched_gang=True, **kw).schedule(job, snap)
    rs = _rsch(topo, batched_gang=False, **kw).schedule(job, snap)
    assert (rb.placement is None) == (rs.placement is None)
    if rb.placement is not None:
        assert ([(p.node, p.gpu_indices) for p in rb.placement.pods]
                == [(p.node, p.gpu_indices) for p in rs.placement.pods])


def test_batched_slot_expansion_colocates(topo, state):
    """A node contributes floor(free/gpus_per_pod) slots; the co-location
    bonus folded into the slot chain keeps the gang on one node."""
    rsch = _rsch(topo, train_strategy=Strategy.E_BINPACK)
    j = Job(uid=1, tenant="t0", gpu_type=0, n_pods=4, gpus_per_pod=2,
            kind=JobKind.TRAIN)
    r = rsch.schedule(j, _snap(state))
    assert r.placement is not None
    assert len({p.node for p in r.placement.pods}) == 1


def test_batched_gang_all_or_nothing(topo, state):
    rsch = _rsch(topo, batched_gang=True)
    res = rsch.schedule(_train_job(uid=1, n_pods=17, gpus=8), _snap(state))
    assert res.placement is None
    assert state.total_allocated() == 0


def test_select_gang_slots_insufficient_capacity():
    from repro.core.scoring import NEG_INF, select_gang_slots
    scores = np.asarray([1.0, NEG_INF, 0.5], dtype=np.float32)
    free = np.asarray([8, 8, 4])
    assert select_gang_slots(scores, free, 4, 4) is None     # 3 slots < 4
    picks = select_gang_slots(scores, free, 4, 3)
    assert picks == [0, 0, 2]                                # 2+1 slots
