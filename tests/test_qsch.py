"""QSCH: queueing policies, admission, preemption, requeue (§3.2)."""

import pytest

from repro.core import (Job, JobKind, JobState, QueuePolicy, QuotaMode,
                        PRIO_HIGH, PRIO_LOW)
from conftest import make_qsch


def _job(uid, gpus=8, n_pods=1, prio=50, t=0.0, tenant="t0", dur=3600.0):
    return Job(uid=uid, tenant=tenant, gpu_type=0, n_pods=n_pods,
               gpus_per_pod=gpus, priority=prio, submit_time=t,
               duration=dur)


def fill_cluster(qsch, state, now=0.0, uid0=100):
    """Occupy every node with 16 single-node 8-GPU jobs."""
    for i in range(16):
        qsch.submit(_job(uid0 + i, gpus=8, t=now))
    res = qsch.cycle(state, now)
    assert len(res.scheduled) == 16
    return res


def test_strict_fifo_head_blocks_queue(topo, state):
    qsch = make_qsch(topo, state, policy=QueuePolicy.STRICT_FIFO)
    fill_cluster(qsch, state)
    qsch.submit(_job(1, n_pods=4, gpus=8, t=10.0))   # cannot fit
    qsch.submit(_job(2, gpus=1, t=11.0))             # could fit, but FIFO
    res = qsch.cycle(state, 30.0)
    assert res.scheduled == []
    assert res.blocked_head is not None and res.blocked_head.uid == 1


def test_best_effort_bypasses_head(topo, state):
    qsch = make_qsch(topo, state, policy=QueuePolicy.BEST_EFFORT_FIFO,
                     priority_preemption=False)
    for i in range(15):                      # leave one node free
        qsch.submit(_job(100 + i, gpus=8))
    qsch.cycle(state, 0.0)
    qsch.submit(_job(1, n_pods=4, gpus=8, t=10.0))   # blocked head
    qsch.submit(_job(2, gpus=8, t=11.0))             # fits the free node
    res = qsch.cycle(state, 30.0)
    assert [j.uid for j in res.scheduled] == [2]


def test_backfill_schedules_small_and_preempts_on_timeout(topo, state):
    qsch = make_qsch(topo, state, policy=QueuePolicy.BACKFILL,
                     backfill_head_timeout=100.0)
    for i in range(15):
        qsch.submit(_job(100 + i, gpus=8))
    qsch.cycle(state, 0.0)
    qsch.submit(_job(1, n_pods=2, gpus=8, t=10.0))   # head needs 2 nodes
    qsch.submit(_job(2, gpus=8, t=11.0))             # backfill fodder
    res = qsch.cycle(state, 20.0)
    assert [j.uid for j in res.scheduled] == [2]
    assert res.scheduled[0].backfilled
    # before timeout: no preemption
    res = qsch.cycle(state, 60.0)
    assert res.preempted == []
    # one long-running job ends -> a node frees
    done = next(j for j in qsch.running.values() if j.uid == 100)
    qsch.on_complete(done, state, 110.0)
    # after timeout: the head preempts the backfilled job to get node 2
    res = qsch.cycle(state, 130.0)
    assert any(j.uid == 2 for j in res.preempted)
    assert any(j.uid == 1 for j in res.scheduled)
    # preempted job was requeued (§3.2.4)
    j2 = next(j for j in qsch.pending_jobs() if j.uid == 2)
    assert j2.requeue_count == 1 and j2.state is JobState.PENDING


def test_priority_preemption(topo, state):
    qsch = make_qsch(topo, state, policy=QueuePolicy.BACKFILL)
    for i in range(16):
        qsch.submit(_job(100 + i, gpus=8, prio=PRIO_LOW))
    qsch.cycle(state, 0.0)
    qsch.submit(_job(1, gpus=8, prio=PRIO_HIGH, t=10.0))
    res = qsch.cycle(state, 30.0)
    assert any(j.uid == 1 for j in res.scheduled)
    assert len(res.preempted) >= 1


def test_conservative_preemption_no_thrash(topo, state):
    """Preemption must not fire when it provably cannot help."""
    qsch = make_qsch(topo, state, policy=QueuePolicy.BACKFILL)
    for i in range(16):
        qsch.submit(_job(100 + i, gpus=8, prio=PRIO_LOW))
    qsch.cycle(state, 0.0)
    # 17 whole nodes can never fit in a 16-node cluster
    qsch.submit(_job(1, n_pods=17, gpus=8, prio=PRIO_HIGH, t=10.0))
    res = qsch.cycle(state, 30.0)
    assert res.preempted == []


def test_static_quota_gates_global_queue(topo, state):
    qsch = make_qsch(topo, state, quota={"t0": {0: 8}})
    qsch.submit(_job(1, gpus=8))
    qsch.submit(_job(2, gpus=8))           # over quota, stays in queue
    res = qsch.cycle(state, 0.0)
    assert [j.uid for j in res.scheduled] == [1]
    assert qsch.queue_depth() == 1
    qsch.on_complete(qsch.running[1], state, 100.0)
    res = qsch.cycle(state, 130.0)
    assert [j.uid for j in res.scheduled] == [2]


def test_quota_reclamation_preemption(topo, state):
    qsch = make_qsch(topo, state, quota={"a": {0: 64}, "b": {0: 64}},
                     mode=QuotaMode.SHARED)
    # tenant a borrows the whole cluster
    for i in range(16):
        qsch.submit(_job(100 + i, gpus=8, tenant="a"))
    qsch.cycle(state, 0.0)
    # owner b wants its quota back
    qsch.submit(_job(1, gpus=8, tenant="b", t=10.0))
    res = qsch.cycle(state, 30.0)
    assert any(j.uid == 1 for j in res.scheduled)
    assert len(res.preempted) >= 1
    assert all(j.tenant == "a" for j in res.preempted)


def test_ordering_priority_time_size(topo, state):
    qsch = make_qsch(topo, state)
    qsch.submit(_job(1, gpus=8, prio=10, t=0.0))
    qsch.submit(_job(2, gpus=4, prio=50, t=5.0))
    qsch.submit(_job(3, gpus=2, prio=50, t=5.0))
    order = [j.uid for j in qsch.pending_jobs()]
    assert order == [3, 2, 1]      # prio desc, then size asc tiebreak


def test_one_snapshot_take_per_cycle(topo, state):
    """§3.4.3: mid-cycle placements are mirrored onto the working
    snapshot as deltas; the cluster is snapshotted exactly once."""
    qsch = make_qsch(topo, state)
    takes = []
    orig = qsch.snapshotter.take
    qsch.snapshotter.take = lambda s: takes.append(1) or orig(s)
    for i in range(10):
        qsch.submit(_job(100 + i, gpus=8))
    res = qsch.cycle(state, 0.0)
    assert len(res.scheduled) == 10
    assert len(takes) == 1
    # later placements saw the earlier ones: 10 distinct nodes
    assert len({j.placement.pods[0].node for j in res.scheduled}) == 10


def test_snapshot_placement_delta_equals_retake(topo, state):
    from repro.core import FullSnapshotter, snapshots_equal
    from repro.core.rsch import RSCH, RSCHConfig

    rsch = RSCH(topo, RSCHConfig())
    snap = FullSnapshotter().take(state)
    job = _job(1, gpus=4, n_pods=3)
    placement = rsch.schedule(job, snap).placement
    state.allocate(job, placement)
    snap.apply_placement(placement)
    assert snapshots_equal(snap, FullSnapshotter().take(state))
    released = state.release(job.uid)
    snap.apply_release(released)
    assert snapshots_equal(snap, FullSnapshotter().take(state))
