"""Node-score kernel: numpy == jnp oracle == Pallas(interpret) across a
hypothesis sweep of shapes/dtypes/weights."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="install .[test] for the "
                    "property-based kernel sweep")
from hypothesis import given, settings, strategies as st

from repro.core.scoring import (BINPACK, E_BINPACK, E_SPREAD, NEG_INF,
                                SPREAD, ScoreWeights, node_scores_np)
from repro.kernels.ops import best_node, node_scores


def _table(rng, n, g=8):
    free = rng.integers(0, g + 1, size=n).astype(np.int32)
    used = (g - free).astype(np.int32)
    mask = rng.random(n) < 0.8
    group_load = rng.random(n).astype(np.float32)
    topo_pref = rng.random(n).astype(np.float32)
    return free, used, mask, group_load, topo_pref


STRATEGIES = [BINPACK, E_BINPACK, SPREAD, E_SPREAD,
              ScoreWeights(used=0.3, fit=-0.2, group=1.1, topo=-0.7)]


@given(n=st.integers(1, 3000), seed=st.integers(0, 99),
       strat=st.sampled_from(STRATEGIES), request=st.integers(1, 8))
@settings(max_examples=40, deadline=None)
def test_ref_matches_numpy(n, seed, strat, request):
    rng = np.random.default_rng(seed)
    free, used, mask, gl, tp = _table(rng, n)
    want = node_scores_np(free, used, mask, gl, tp, request, 8, strat)
    got = node_scores(free, used, mask, gl, tp, request=request,
                      gpus_per_node=8, weights=strat, backend="ref")
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-6)


@pytest.mark.parametrize("n", [1, 7, 128, 1000, 8192, 8193])
@pytest.mark.parametrize("strat", [E_BINPACK, E_SPREAD])
def test_pallas_interpret_matches_ref(n, strat):
    rng = np.random.default_rng(n)
    free, used, mask, gl, tp = _table(rng, n)
    ref = node_scores(free, used, mask, gl, tp, request=4,
                      gpus_per_node=8, weights=strat, backend="ref")
    pal = node_scores(free, used, mask, gl, tp, request=4,
                      gpus_per_node=8, weights=strat, backend="interpret")
    np.testing.assert_allclose(np.asarray(pal), np.asarray(ref),
                               rtol=1e-6)


def test_padding_rows_never_win():
    """Padding must carry -inf so argmax cannot select a phantom node."""
    n = 130                                  # forces padding to 8192
    free = np.full(n, 8, np.int32)
    used = np.zeros(n, np.int32)
    mask = np.zeros(n, bool)
    mask[17] = True
    gl = np.zeros(n, np.float32)
    tp = np.zeros(n, np.float32)
    idx = best_node(free, used, mask, gl, tp, request=4, gpus_per_node=8,
                    weights=E_BINPACK, backend="interpret")
    assert idx == 17


@pytest.mark.parametrize("backend", ["ref", "interpret"])
@pytest.mark.parametrize("n", [1, 130, 1000, 8193])
def test_scores_and_slots_fused_pass(backend, n):
    """Batched gang placement front half: the fused (scores, slots) pass
    agrees with the scalar score kernel + floor(free/request) expansion."""
    from repro.kernels.ops import node_scores_and_slots
    rng = np.random.default_rng(n)
    free, used, mask, gl, tp = _table(rng, n)
    scores, slots = node_scores_and_slots(
        free, used, mask, gl, tp, request=4, gpus_per_node=8,
        weights=E_BINPACK, backend=backend)
    want_scores = node_scores_np(free, used, mask, gl, tp, 4, 8, E_BINPACK)
    want_slots = np.where(want_scores > NEG_INF, free // 4, 0)
    np.testing.assert_allclose(np.asarray(scores), want_scores, rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(slots), want_slots)


def test_no_valid_node_returns_minus_one():
    free = np.zeros(64, np.int32)
    used = np.full(64, 8, np.int32)
    mask = np.ones(64, bool)
    z = np.zeros(64, np.float32)
    idx = best_node(free, used, mask, z, z, request=1, gpus_per_node=8,
                    weights=BINPACK, backend="ref")
    assert idx == -1


def test_scheduler_scoring_agrees_with_kernel(topo, state):
    """RSCH's numpy scoring pass == the kernel on real cluster state."""
    from repro.core.snapshot import FullSnapshotter
    snap = FullSnapshotter().take(state)
    free = snap.free_gpus
    used = snap.used_gpus
    mask = snap.node_healthy
    gl = np.zeros(topo.n_nodes, np.float32)
    tp = np.zeros(topo.n_nodes, np.float32)
    want = node_scores_np(free, used, mask, gl, tp, 4, 8, E_BINPACK)
    got = node_scores(free, used, mask, gl, tp, request=4,
                      gpus_per_node=8, weights=E_BINPACK,
                      backend="interpret")
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-6)


# ---------------------------------------------------------------------------
# wkv6: RWKV-6 WKV recurrence kernel (kernels/wkv6.py)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,T,H,n,tb", [
    (1, 16, 1, 8, 8),
    (2, 32, 3, 8, 16),
    (2, 64, 2, 16, 64),     # tb == T: single time block
    (3, 48, 5, 4, 16),      # odd head count, tiny head dim
])
def test_wkv6_kernel_matches_ref(B, T, H, n, tb):
    from repro.kernels.ops import wkv6
    ks = jax.random.split(jax.random.PRNGKey(B * T + H), 6)
    r, k, v = (jax.random.normal(ki, (B, T, H, n)) * 0.5 for ki in ks[:3])
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, T, H, n)))
    u = jax.random.normal(ks[4], (H, n)) * 0.5
    s0 = jax.random.normal(ks[5], (B, H, n, n)) * 0.1
    o_ref, sT_ref = wkv6(r, k, v, w, u, s0, backend="ref")
    o_pl, sT_pl = wkv6(r, k, v, w, u, s0, backend="interpret", tb=tb)
    np.testing.assert_allclose(np.asarray(o_pl), np.asarray(o_ref),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(sT_pl), np.asarray(sT_ref),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_wkv6_kernel_dtypes(dtype):
    from repro.kernels.ops import wkv6
    B, T, H, n = 2, 16, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(7), 6)
    r, k, v = ((jax.random.normal(ki, (B, T, H, n)) * 0.5).astype(dtype)
               for ki in ks[:3])
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, T, H, n))).astype(dtype)
    u = (jax.random.normal(ks[4], (H, n)) * 0.5).astype(dtype)
    s0 = (jax.random.normal(ks[5], (B, H, n, n)) * 0.1).astype(jnp.float32)
    o_ref, sT_ref = wkv6(r, k, v, w, u, s0, backend="ref")
    o_pl, sT_pl = wkv6(r, k, v, w, u, s0, backend="interpret", tb=8)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(o_pl), np.asarray(o_ref),
                               atol=tol, rtol=tol)


def test_time_mix_kernel_backend_matches_scan():
    """rwkv6.time_mix(backend='interpret') == the step-scan layer path."""
    from repro.models import rwkv6 as rw
    d, hd, T, B = 32, 8, 24, 2
    p = rw.init_rwkv_block(jax.random.PRNGKey(0), d, 64, hd, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, T, d)) * 0.5
    st0 = jnp.zeros(rw.rwkv_state_shape(B, d, hd), jnp.float32)
    xl = jnp.zeros((B, d))
    o_scan, s_scan, _ = rw.time_mix(p, x, st0, xl, backend="scan")
    o_ker, s_ker, _ = rw.time_mix(p, x, st0, xl, backend="interpret")
    np.testing.assert_allclose(np.asarray(o_ker), np.asarray(o_scan),
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(s_ker), np.asarray(s_scan),
                               atol=2e-5, rtol=2e-5)
