"""Backfill head-timeout preemption + requeue accounting (§3.2.3/§3.2.4).

Direct coverage of the paths the policy benchmarks rely on: the
head-timeout eviction order and budget, and the ``requeue_count`` /
``backfilled`` bookkeeping that every requeue must reset.
"""

from repro.core import (JobKind, Job, JobState, QSCHConfig, QueuePolicy,
                        QuotaManager, RSCH, SimConfig, Simulator,
                        ClusterState)
from conftest import make_qsch


def _job(uid, gpus=8, n_pods=1, prio=50, t=0.0, dur=3600.0):
    return Job(uid=uid, tenant="t0", gpu_type=0, n_pods=n_pods,
               gpus_per_pod=gpus, priority=prio, submit_time=t,
               duration=dur)


def _fill(qsch, state, n=16, now=0.0, uid0=100):
    for i in range(n):
        qsch.submit(_job(uid0 + i, gpus=8, t=now))
    res = qsch.cycle(state, now)
    assert len(res.scheduled) == n


def test_backfill_timeout_evicts_newest_backfilled_first(topo, state):
    qsch = make_qsch(topo, state, policy=QueuePolicy.BACKFILL,
                     backfill_head_timeout=100.0)
    _fill(qsch, state, n=14)                      # two nodes stay free
    qsch.submit(_job(1, n_pods=4, gpus=8, t=10.0))   # head needs 4 nodes
    qsch.submit(_job(2, gpus=8, t=11.0))             # backfill, older
    res = qsch.cycle(state, 20.0)
    assert {j.uid for j in res.scheduled} == {2}
    qsch.submit(_job(3, gpus=8, t=21.0))             # backfill, newer
    res = qsch.cycle(state, 30.0)
    assert {j.uid for j in res.scheduled} == {3}
    assert all(j.backfilled for j in qsch.running.values()
               if j.uid in (2, 3))
    # Two running jobs end -> with both backfilled evicted, 4 nodes open.
    for uid in (100, 101):
        qsch.on_complete(qsch.running[uid], state, 110.0)
    res = qsch.cycle(state, 140.0)
    # Head became feasible only after evicting BOTH backfilled jobs,
    # newest (uid 3) first.
    assert [j.uid for j in res.preempted] == [3, 2]
    assert any(j.uid == 1 for j in res.scheduled)
    assert res.requeues == 2


def test_backfill_timeout_respects_preemption_budget(topo, state):
    qsch = make_qsch(topo, state, policy=QueuePolicy.BACKFILL,
                     backfill_head_timeout=100.0,
                     max_preemptions_per_cycle=1)
    _fill(qsch, state, n=14)
    qsch.submit(_job(1, n_pods=4, gpus=8, t=10.0))
    qsch.submit(_job(2, gpus=8, t=11.0))
    qsch.submit(_job(3, gpus=8, t=12.0))
    qsch.cycle(state, 20.0)                      # 2 and 3 backfill
    for uid in (100, 101):
        qsch.on_complete(qsch.running[uid], state, 110.0)
    res = qsch.cycle(state, 140.0)
    # Budget of 1: only one eviction per cycle, head still blocked.
    assert len(res.preempted) == 1
    assert res.blocked_head is not None and res.blocked_head.uid == 1


def test_requeue_resets_backfilled_and_counts(topo, state):
    qsch = make_qsch(topo, state, policy=QueuePolicy.BACKFILL,
                     backfill_head_timeout=100.0)
    _fill(qsch, state, n=15)
    qsch.submit(_job(1, n_pods=2, gpus=8, t=10.0))   # blocked head
    qsch.submit(_job(2, gpus=8, t=11.0))             # backfills
    qsch.cycle(state, 20.0)
    done = next(j for j in qsch.running.values() if j.uid == 100)
    qsch.on_complete(done, state, 110.0)
    res = qsch.cycle(state, 130.0)                   # head preempts 2
    assert any(j.uid == 2 for j in res.preempted)
    j2 = next(j for j in qsch.pending_jobs() if j.uid == 2)
    # §3.2.4 bookkeeping: requeue restores a clean pending job.
    assert j2.state is JobState.PENDING
    assert j2.requeue_count == 1
    assert j2.preempt_count == 1
    assert j2.backfilled is False
    assert j2.placement is None
    assert res.requeues == 1


def test_preempted_job_reschedules_and_completes(topo):
    """End-to-end through the simulator: a preempted backfilled job is
    requeued, rescheduled and finishes; counters line up."""
    state = ClusterState.create(topo)
    qsch = make_qsch(topo, state, policy=QueuePolicy.BACKFILL,
                     backfill_head_timeout=60.0)
    sim = Simulator(state, qsch, SimConfig(tick_interval=30.0,
                                           sample_interval=300.0,
                                           binding_latency=0.0))
    # 15 fillers occupy 15 of 16 nodes; one ends early so the blocked
    # head (2 nodes) becomes helpable by evicting the backfilled job.
    jobs = [_job(100 + i, gpus=8, t=0.0,
                 dur=(100.0 if i == 0 else 3600.0)) for i in range(15)]
    jobs.append(_job(1, n_pods=2, gpus=8, t=10.0, dur=100.0))  # head
    jobs.append(_job(2, gpus=8, t=11.0, dur=600.0))            # backfill
    result = sim.run(jobs)
    j2 = next(j for j in result.jobs if j.uid == 2)
    assert j2.state is JobState.COMPLETED
    assert j2.preempt_count >= 1
    assert j2.requeue_count >= 1
    assert result.preemptions >= 1
    assert result.requeues >= result.preemptions
    assert state.total_allocated() == 0


def test_placement_failure_requeues_with_count(topo, state):
    """Dynamic admission can pass while gang placement fails
    (fragmentation): the job must requeue, not deadlock."""
    # Fragment: every node keeps 4 free GPUs -> 64 free total, but no
    # node can host an 8-GPU pod.
    for node in range(state.n_nodes):
        state.gpu_busy[node, :4] = True
    qsch = make_qsch(topo, state)
    qsch.submit(_job(1, n_pods=1, gpus=6))
    res = qsch.cycle(state, 0.0)
    assert res.scheduled == []
    # feasible() said no (6 > 4 free per node) -> infeasible, no requeue
    assert res.infeasible == 1
    job = qsch.pending_jobs()[0]
    assert job.requeue_count == 0

    # A gang too wide for one LeafGroup set that passes feasibility but
    # fails device selection is hard to build here; exercise requeue()
    # directly for the bookkeeping contract instead.
    job.backfilled = True
    job.placement = object()
    qsch._remove_from_queue(job)
    qsch.requeue(job)
    assert job.requeue_count == 1
    assert job.backfilled is False and job.placement is None
    assert job.state is JobState.PENDING
