"""Elastic training subsystem: ElasticSpec contract, rate-scaled
checkpoint recovery (including restarts at a different GPU count),
shrink-into-fragments placement, checkpoint-boundary grow, byte-identity
of the rigid path, and the combo-cache memoization."""

import math

import pytest

from repro.core import (CheckpointModel, ClusterState, DynamicsConfig,
                        ElasticConfig, ElasticManager, ElasticSpec,
                        EventKind, GreedyElastic, Job, JobKind, JobState,
                        ParallelismPlan, QSCH, QSCHConfig, QuotaManager,
                        RSCH, RSCHConfig, SimConfig, Simulator,
                        scaling_artifacts, spec_from_artifacts,
                        training_trace, waiting_percentile)
from repro.core.elastic import plan_cache, step_time_from_terms
from repro.core.framework import DynamicsPlugin
from repro.launch.combo_cache import ComboCache, mesh_key

from conftest import make_qsch


def make_spec():
    """Ideal 8x8 (64 GPUs), shrinkable to 4x8 at 0.6 and 2x8 at 0.3."""
    return ElasticSpec(plans=(ParallelismPlan(8, 8, 1.0),
                              ParallelismPlan(4, 8, 0.6),
                              ParallelismPlan(2, 8, 0.3)))


def elastic_job(uid=1, duration=3600.0, submit=0.0, spec=None,
                tenant="t0"):
    spec = spec or make_spec()
    ideal = spec.ideal()
    return Job(uid=uid, tenant=tenant, gpu_type=0, n_pods=ideal.n_pods,
               gpus_per_pod=ideal.gpus_per_pod, submit_time=submit,
               duration=duration, preemptible=True, elastic=spec)


def rigid_job(uid, n_pods, duration, submit=0.0, priority=50):
    return Job(uid=uid, tenant="t0", gpu_type=0, n_pods=n_pods,
               gpus_per_pod=8, submit_time=submit, duration=duration,
               priority=priority, preemptible=True)


def make_elastic_sim(topo, state, *, dynamics=None, horizon=None,
                     manager=None):
    qm = QuotaManager({"t0": {0: 1024}})
    rsch = RSCH(topo, RSCHConfig())
    qsch = QSCH(qm, rsch, QSCHConfig(),
                elastic=manager or ElasticManager())
    return Simulator(state, qsch,
                     SimConfig(tick_interval=30.0, sample_interval=300.0,
                               binding_latency=0.0, horizon=horizon,
                               dynamics=dynamics))


# ----------------------------------------------------------------------
# Spec contract
# ----------------------------------------------------------------------
def test_spec_ordering_and_lookup():
    spec = make_spec()
    assert spec.ideal().shape == (8, 8)
    assert [p.n_gpus for p in spec.by_throughput()] == [64, 32, 16]
    assert spec.plan_for(4, 8).throughput == 0.6
    assert spec.plan_for(3, 8) is None
    assert spec.min_gpus() == 16


def test_spec_rejects_duplicates_and_bad_plans():
    with pytest.raises(ValueError):
        ElasticSpec(plans=(ParallelismPlan(2, 8, 1.0),
                           ParallelismPlan(2, 8, 0.5)))
    with pytest.raises(ValueError):
        ParallelismPlan(0, 8, 1.0)
    with pytest.raises(ValueError):
        ParallelismPlan(2, 8, 0.0)
    with pytest.raises(ValueError):
        ElasticSpec(plans=())


def test_spec_validates_job_at_construction():
    spec = make_spec()
    # Shape must equal the ideal plan's shape.
    with pytest.raises(ValueError):
        Job(uid=1, tenant="t0", gpu_type=0, n_pods=4, gpus_per_pod=8,
            duration=100.0, elastic=spec)
    # Gang-scheduled training only.
    with pytest.raises(ValueError):
        Job(uid=1, tenant="t0", gpu_type=0, n_pods=8, gpus_per_pod=8,
            duration=100.0, kind=JobKind.INFER, gang=False, elastic=spec)


def test_from_throughputs_packs_at_node_granularity():
    spec = ElasticSpec.from_throughputs([(64, 1.0), (32, 0.6), (4, 0.1)])
    assert spec.plan_for(8, 8).throughput == 1.0
    assert spec.plan_for(4, 8).throughput == 0.6
    assert spec.plan_for(1, 4).throughput == 0.1
    with pytest.raises(ValueError):
        ElasticSpec.from_throughputs([(12, 0.5)])   # not a node multiple


def test_job_work_rate_defaults():
    job = rigid_job(uid=1, n_pods=2, duration=100.0)
    assert job.work_rate == 1.0
    assert job.ideal_n_gpus == 16
    ej = elastic_job()
    assert ej.work_rate == 1.0                       # ideal until shrunk
    assert ej.ideal_n_gpus == 64
    ej.apply_plan(ej.elastic.plan_for(4, 8))
    assert ej.work_rate == 0.6
    assert ej.n_gpus == 32
    assert ej.ideal_n_gpus == 64                     # yardstick unchanged
    ej.state = JobState.RUNNING
    with pytest.raises(ValueError):
        ej.apply_plan(ej.elastic.ideal())


# ----------------------------------------------------------------------
# Rate-scaled checkpoint recovery (satellite: different-GPU-count
# restarts must account work at the active plan's throughput)
# ----------------------------------------------------------------------
def test_recovery_scales_progress_by_work_rate():
    model = CheckpointModel(interval_s=600.0, restart_overhead_s=120.0)
    job = elastic_job(duration=3600.0)
    job.apply_plan(job.elastic.plan_for(4, 8))       # rate 0.6
    job.run_time = 0.0
    remaining, lost, overhead = model.on_interrupt(job, 1450.0)
    # 1450 wall seconds at rate 0.6; checkpoints land on wall boundaries
    # (600, 1200), so 1200 wall = 720 work survive and 250 wall is lost.
    assert job.checkpointed_progress == pytest.approx(720.0)
    assert lost == pytest.approx(250.0)
    assert overhead == 120.0
    # Remaining wall time is quoted at the STILL-ACTIVE shrunk plan.
    assert remaining == pytest.approx((3600.0 - 720.0) / 0.6 + 120.0)
    # A restart at the ideal plan (different GPU count) would need
    # (3600 - 720) / 1.0 + 120 instead — select_shape's formula.
    assert (job.original_duration - job.checkpointed_progress) / 1.0 \
        + 120.0 == pytest.approx(3000.0)


def test_recovery_caps_progress_at_remaining_work():
    # A shrunk attempt cannot checkpoint more work than the job has.
    model = CheckpointModel(interval_s=600.0, restart_overhead_s=120.0)
    spec = ElasticSpec(plans=(ParallelismPlan(4, 8, 1.0),
                              ParallelismPlan(2, 8, 0.5)))
    job = Job(uid=1, tenant="t0", gpu_type=0, n_pods=4, gpus_per_pod=8,
              duration=600.0, elastic=spec)
    job.apply_plan(spec.plan_for(2, 8))              # rate 0.5
    job.run_time = 0.0
    # 1450 wall elapsed but the whole job is only 600/0.5 = 1200 wall:
    # everything checkpoints, nothing is lost.
    remaining, lost, _ = model.on_interrupt(job, 1450.0)
    assert job.checkpointed_progress == pytest.approx(600.0)
    assert lost == 0.0
    assert remaining == pytest.approx(120.0)


def test_recovery_rate_one_matches_rigid_math():
    # An elastic job running at its ideal plan must account exactly like
    # the rigid path (byte-identity of the arithmetic).
    model = CheckpointModel(interval_s=600.0, restart_overhead_s=120.0)
    job = elastic_job(duration=3600.0)
    job.apply_plan(job.elastic.ideal())
    job.run_time = 0.0
    remaining, lost, _ = model.on_interrupt(job, 1450.0)
    assert job.checkpointed_progress == 1200.0
    assert lost == 250.0
    assert remaining == 3600.0 - 1200.0 + 120.0


def test_failure_restart_at_smaller_gpu_count(topo, state):
    # End to end: a 64-GPU elastic job loses 10 of 16 nodes at t=650 and
    # must restart in the surviving 48 GPUs at the 32-GPU plan, with the
    # new attempt's wall duration quoted at that plan's throughput.
    events = [(650.0, EventKind.NODE_FAIL, {"node": n})
              for n in range(10)]
    events += [(100_000.0, EventKind.NODE_RECOVER, {"node": n})
               for n in range(10)]

    class Scripted(DynamicsPlugin):
        name = "ScriptedElastic"

        def schedule(self, engine, rng):
            return events

    dyn = DynamicsConfig(plugins=[Scripted()],
                         recovery=CheckpointModel(interval_s=600.0,
                                                  restart_overhead_s=120.0))
    sim = make_elastic_sim(topo, state, dynamics=dyn)
    job = elastic_job(duration=3600.0)
    result = sim.run([job])
    assert job.state is JobState.COMPLETED
    assert job.interrupt_count == 1 and job.attempt == 1
    # First attempt at the ideal plan: checkpoint at 600 work-seconds.
    assert job.checkpointed_progress == 600.0
    assert job.n_gpus == 32                          # finished shrunk
    assert job.active_plan.throughput == 0.6
    # Second attempt: 120 restore + (3600 - 600) work at rate 0.6.
    assert job.end_time - job.run_time == pytest.approx(
        120.0 + 3000.0 / 0.6)
    # Goodput credits the ideal shape regardless of the finishing plan.
    assert result.metrics.useful_gpu_seconds == 3600.0 * 64
    assert result.metrics.reshapes == 0              # forced, not chosen
    state.check_invariants()


# ----------------------------------------------------------------------
# Shrink: start now in fragmented capacity instead of queueing
# ----------------------------------------------------------------------
def test_shrinks_into_fragmented_capacity(topo, state):
    sim = make_elastic_sim(topo, state)
    blocker = rigid_job(uid=1, n_pods=12, duration=10_000.0,
                        priority=90)                 # leaves 4 nodes free
    job = elastic_job(uid=2, duration=3600.0)
    sim.run([blocker, job])
    assert job.state is JobState.COMPLETED
    assert job.start_time == blocker.start_time, "no queueing"
    assert job.n_gpus == 32 and job.active_plan.throughput == 0.6
    # Wall time stretched by the inverse rate.
    assert job.end_time - job.run_time == pytest.approx(3600.0 / 0.6)
    state.check_invariants()


def test_min_rate_floor_queues_instead_of_crawling(topo, state):
    # Only 2 nodes free: the 16-GPU plan fits but sits below the policy
    # floor (0.3 < min_rate=0.5), so the job queues for the ideal shape.
    manager = ElasticManager(ElasticConfig(
        policy=GreedyElastic(min_rate=0.5)))
    sim = make_elastic_sim(topo, state, manager=manager)
    blocker = rigid_job(uid=1, n_pods=14, duration=2000.0, priority=90)
    job = elastic_job(uid=2, duration=600.0)
    sim.run([blocker, job])
    assert job.state is JobState.COMPLETED
    assert job.n_gpus == 64, "waited for the ideal shape"
    assert job.run_time >= 2000.0
    state.check_invariants()


# ----------------------------------------------------------------------
# Grow: reshape back toward the ideal plan at a checkpoint boundary
# ----------------------------------------------------------------------
def test_grows_at_checkpoint_boundary_when_capacity_frees(topo, state):
    sim = make_elastic_sim(topo, state)
    blocker = rigid_job(uid=1, n_pods=12, duration=650.0, priority=90)
    job = elastic_job(uid=2, duration=7200.0)
    result = sim.run([blocker, job])
    assert job.state is JobState.COMPLETED
    assert job.n_gpus == 64, "grew back to the ideal plan"
    assert job.reshape_count == 1
    assert result.metrics.reshapes == 1
    # The voluntary reshape charged the OLD (32-GPU) shape and recorded
    # no MTTR sample (nothing failed).
    assert result.metrics.reshape_gpu_seconds > 0
    assert result.metrics.reshape_gpu_seconds == pytest.approx(
        (result.metrics.lost_gpu_seconds
         + result.metrics.overhead_gpu_seconds))
    assert result.metrics.mttr() == 0.0
    # Grow boundary slack bounds the lost work: < one checkpoint.
    assert job.lost_work < 600.0
    # Goodput = blocker + elastic job at its IDEAL shape.
    assert result.metrics.useful_gpu_seconds == 650.0 * 96 + 7200.0 * 64
    state.check_invariants()


def test_no_grow_without_payback(topo, state):
    # Near-finished job: the wall time saved cannot cover the reshape
    # cost, so the policy must leave it alone.
    manager = ElasticManager(ElasticConfig(
        policy=GreedyElastic(grow_payback=2.0)))
    sim = make_elastic_sim(topo, state, manager=manager)
    blocker = rigid_job(uid=1, n_pods=12, duration=650.0, priority=90)
    # 400 work-seconds at rate 0.6 ≈ 667 wall: growing saves ~267 wall,
    # less than 2 x 120 restart overhead.
    job = elastic_job(uid=2, duration=400.0)
    result = sim.run([blocker, job])
    assert job.state is JobState.COMPLETED
    assert job.reshape_count == 0
    assert result.metrics.reshapes == 0
    assert job.n_gpus == 32, "finished at the shrunk plan"


def test_scratch_recovery_never_grows(topo, state):
    manager = ElasticManager(ElasticConfig(
        recovery=CheckpointModel(interval_s=600.0,
                                 restart_overhead_s=120.0,
                                 mode="scratch")))
    sim = make_elastic_sim(topo, state, manager=manager)
    blocker = rigid_job(uid=1, n_pods=12, duration=650.0, priority=90)
    job = elastic_job(uid=2, duration=7200.0)
    result = sim.run([blocker, job])
    assert job.state is JobState.COMPLETED
    assert job.reshape_count == 0 and result.metrics.reshapes == 0


# ----------------------------------------------------------------------
# Byte-identity: no ElasticSpec -> the rigid path, exactly
# ----------------------------------------------------------------------
def test_manager_without_specs_is_byte_identical(topo):
    def run(with_manager):
        st = ClusterState.create(topo)
        if with_manager:
            sim = make_elastic_sim(topo, st)
        else:
            qsch = make_qsch(topo, st)
            sim = Simulator(st, qsch,
                            SimConfig(tick_interval=30.0,
                                      sample_interval=300.0,
                                      binding_latency=0.0))
        jobs = [j for j in training_trace(40, seed=3,
                                          arrival_rate_per_hour=900,
                                          mean_duration_s=900.0)
                if j.n_gpus <= 64]
        res = sim.run(jobs)
        return ([(j.uid, j.start_time, j.end_time,
                  tuple((p.node, p.gpu_indices) for p in j.placement.pods))
                 for j in res.jobs if j.placement],
                res.metrics.report())

    assert run(True) == run(False)


# ----------------------------------------------------------------------
# Promoted waiting_percentile
# ----------------------------------------------------------------------
def test_waiting_percentile_promoted_and_reexported():
    from repro.core.federation import waiting_percentile as fed_wp
    assert fed_wp is waiting_percentile
    jobs = [rigid_job(uid=i, n_pods=1, duration=10.0) for i in range(4)]
    for i, j in enumerate(jobs[:3]):
        j.start_time = j.submit_time + 100.0 * i    # waits 0/100/200
    assert waiting_percentile(jobs, 50.0) == pytest.approx(100.0)
    # No started jobs -> no percentile: NaN ("no data"), not a fake
    # perfect 0.0 tail latency.
    assert math.isnan(waiting_percentile([], 90.0))


# ----------------------------------------------------------------------
# Combo cache + plan estimation
# ----------------------------------------------------------------------
def test_combo_cache_counters():
    c = ComboCache("t")
    assert c.get("k") is None
    assert c.stats() == {"name": "t", "hits": 0, "misses": 1, "size": 0}
    c.put("k", 5)
    assert c.get("k") == 5 and c.hits == 1
    assert c.get_or("j", lambda: 7) == 7             # miss + compute
    assert c.get_or("j", lambda: 0) == 7             # hit, not recomputed
    assert len(c) == 2 and "j" in c
    c.clear()
    assert c.stats() == {"name": "t", "hits": 0, "misses": 0, "size": 0}


def test_mesh_key_duck_typed():
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 8}

    assert mesh_key(FakeMesh()) == (("data", 16), ("model", 8))


def test_step_time_and_scaling_artifacts():
    arts = scaling_artifacts("gpt", "small", [32, 64, 128],
                             base_step_s=1.0, alpha=0.85)
    by_chips = {a["chips"]: a for a in arts}
    assert step_time_from_terms(by_chips[128]) == pytest.approx(1.0)
    # Throughput grows sublinearly: 2x chips < 2x throughput.
    t64 = 1.0 / step_time_from_terms(by_chips[64])
    t128 = 1.0 / step_time_from_terms(by_chips[128])
    assert t64 < t128 < 2.0 * t64
    with pytest.raises(ValueError):
        step_time_from_terms({"compute_term_s": 0.0})


def test_spec_from_artifacts_memoized():
    cache = plan_cache()
    cache.clear()
    arts = scaling_artifacts("llama", "small", [32, 64, 128])
    a = spec_from_artifacts(arts)
    assert cache.stats()["misses"] == 1
    b = spec_from_artifacts(list(reversed(arts)))    # order-insensitive
    assert b is a
    assert cache.stats()["hits"] == 1
    assert a.ideal().n_gpus == 128
    # Validates single-combo input.
    with pytest.raises(ValueError):
        spec_from_artifacts(arts
                            + scaling_artifacts("gpt", "small", [32]))
    # Derived specs drive real jobs.
    job = Job(uid=9, tenant="t0", gpu_type=0, n_pods=16, gpus_per_pod=8,
              duration=100.0, elastic=a)
    assert job.work_rate == 1.0
