"""Million-node scheduling core: SoA columns, vectorized top-k slot
engine, tracked group aggregates, subset scoring, cycle pipelining."""

import numpy as np
import pytest

from repro.core import (ClusterState, Job, JobKind, QSCH, QSCHConfig,
                        QueuePolicy, QuotaManager, RSCH, RSCHConfig,
                        Strategy)
from repro.core.scoring import (NEG_INF, chains_nondecreasing,
                                select_gang_slots)
from repro.core.simulator import SimConfig, Simulator
from repro.core.snapshot import FullSnapshotter
from repro.core.topology import small_topology
from conftest import make_qsch


# ----------------------------------------------------------------------
# Column layout (satellite: int32 pinning)
# ----------------------------------------------------------------------
def test_columns_are_int32_pinned(topo):
    state = ClusterState.create(topo)
    state.ensure_derived()
    cols = state.cols
    assert cols.gpu_type.dtype == np.int32
    assert cols.free_gpus.dtype == np.int32
    assert cols.used_gpus.dtype == np.int32
    assert cols.busy_count.dtype == np.int32
    assert cols.healthy_count.dtype == np.int32
    for b in (cols.gpu_busy, cols.gpu_healthy, cols.node_healthy,
              cols.inference_zone, cols.node_draining, cols.fragmented):
        assert b.dtype == np.bool_
    # Snapshots share the exact same block layout.
    snap = FullSnapshotter().take(state)
    assert snap.free_gpus.dtype == np.int32
    assert snap.cols.healthy_count.dtype == np.int32


def test_derived_columns_survive_direct_setup_writes(topo):
    """Tests/benches write state.gpu_busy directly before first use;
    the lazy derived init plus FullSnapshotter's re-derive must fold
    those writes in."""
    state = ClusterState.create(topo)
    state.gpu_busy[3, :5] = True
    assert int(state.free_gpus()[3]) == 3
    state.gpu_busy[4, :2] = True            # after derived init
    snap = FullSnapshotter().take(state)
    assert int(snap.free_gpus[4]) == 6
    assert bool(snap.cols.fragmented[4])


# ----------------------------------------------------------------------
# Vectorized top-k slot engine == heap oracle
# ----------------------------------------------------------------------
def _random_case(rng, engineable=True):
    n = int(rng.integers(1, 200))
    free = rng.integers(0, 9, size=n).astype(np.int64)
    request = int(rng.choice([1, 2, 4, 8]))
    scores = np.where(
        (free >= request) & (rng.random(n) < 0.9),
        rng.choice([-2.0, -1.0, 0.0, 0.5, 1.0, 1.5],
                   size=n).astype(np.float32),
        np.float32(NEG_INF)).astype(np.float32)
    n_pods = int(rng.integers(1, 65))
    if engineable:
        colocate = float(rng.choice([0.0, 0.5, 2.0]))
        fit = float(rng.choice([0.0, 0.5, -0.25]))
        if not chains_nondecreasing(fit, colocate):
            fit = 0.5
    else:
        colocate, fit = -1.0, -0.5          # decreasing chains
    return scores, free, request, n_pods, fit, colocate


def test_topk_engine_matches_heap_fuzz():
    rng = np.random.default_rng(0)
    for _ in range(400):
        scores, free, request, n_pods, fit, colo = _random_case(rng)
        heap = select_gang_slots(scores, free, request, n_pods,
                                 fit_weight=fit, colocate_bonus=colo,
                                 engine="heap")
        topk = select_gang_slots(scores, free, request, n_pods,
                                 fit_weight=fit, colocate_bonus=colo,
                                 engine="topk")
        assert heap == topk


def test_topk_engine_edge_cases():
    # Exactly enough slots; all-tied scores; single node; infeasible.
    free = np.asarray([8, 8], dtype=np.int64)
    scores = np.asarray([1.0, 1.0], dtype=np.float32)
    for n_pods in (1, 2, 4):
        assert (select_gang_slots(scores, free, 4, n_pods, engine="topk")
                == select_gang_slots(scores, free, 4, n_pods,
                                     engine="heap"))
    assert select_gang_slots(scores, free, 8, 3, engine="topk") is None
    one = select_gang_slots(np.asarray([0.5], dtype=np.float32),
                            np.asarray([8], dtype=np.int64), 2, 4,
                            fit_weight=0.5, colocate_bonus=2.0,
                            engine="topk")
    assert one == [0, 0, 0, 0]


def test_decreasing_chains_fall_back_to_heap():
    """Negative colocate bonus violates the top-k precondition; the
    engine kwarg must silently use the exact heap path."""
    rng = np.random.default_rng(1)
    for _ in range(50):
        scores, free, request, n_pods, fit, colo = _random_case(
            rng, engineable=False)
        assert not chains_nondecreasing(fit, colo)
        a = select_gang_slots(scores, free, request, n_pods,
                              fit_weight=fit, colocate_bonus=colo,
                              engine="topk")
        b = select_gang_slots(scores, free, request, n_pods,
                              fit_weight=fit, colocate_bonus=colo,
                              engine="heap")
        assert a == b


def test_topk_kernel_engine_matches_heap():
    pytest.importorskip("jax")
    rng = np.random.default_rng(2)
    for _ in range(25):
        scores, free, request, n_pods, fit, colo = _random_case(rng)
        heap = select_gang_slots(scores, free, request, n_pods,
                                 fit_weight=fit, colocate_bonus=colo,
                                 engine="heap")
        kern = select_gang_slots(scores, free, request, n_pods,
                                 fit_weight=fit, colocate_bonus=colo,
                                 engine="topk_kernel")
        assert heap == kern


# ----------------------------------------------------------------------
# TrackedGroupSum: row patches == from-scratch bincount
# ----------------------------------------------------------------------
def test_tracked_group_sum_patch_equals_bincount(topo):
    state = ClusterState.create(topo)
    state.gpu_busy[1, :3] = True
    snap = FullSnapshotter().take(state)

    def contrib(s, idx):
        if idx is None:
            return s.free_gpus // 4
        return s.free_gpus[idx] // 4

    totals = snap.tracked_sum("t", topo.leaf_id, topo.n_leaf_groups,
                              contrib)
    rng = np.random.default_rng(3)
    for _ in range(30):
        node = int(rng.integers(0, topo.n_nodes))
        k = int(rng.integers(0, 9))
        snap.cols.gpu_busy[node] = False
        snap.cols.gpu_busy[node, :k] = True
        snap._refresh_rows([node])
        scratch = np.bincount(topo.leaf_id,
                              weights=snap.free_gpus // 4,
                              minlength=topo.n_leaf_groups).astype(int)
        assert np.array_equal(totals, scratch)


# ----------------------------------------------------------------------
# Subset level-2 scoring == full-width scoring
# ----------------------------------------------------------------------
@pytest.mark.parametrize("strategy", list(Strategy))
def test_subset_scoring_matches_full_width(strategy):
    topo = small_topology(n_nodes=64, gpus_per_node=8, nodes_per_leaf=8)
    rng = np.random.default_rng(4)
    for trial in range(10):
        state = ClusterState.create(topo)
        busy = rng.random(64) < 0.5
        count = rng.integers(1, 9, size=64)
        state.gpu_busy[:] = ((np.arange(8) < count[:, None])
                             & busy[:, None])
        snap = FullSnapshotter().take(state)
        job = Job(uid=trial, tenant="t", gpu_type=0,
                  n_pods=int(rng.integers(1, 9)),
                  gpus_per_pod=int(rng.choice([1, 2, 4, 8])),
                  kind=JobKind.TRAIN)
        fast = RSCH(topo, RSCHConfig(train_strategy=strategy))
        slow = RSCH(topo, RSCHConfig(train_strategy=strategy,
                                     subset_scoring=False,
                                     slot_engine="heap"))
        a = fast.schedule(job, snap)
        b = slow.schedule(job, snap)
        if a.placement is None:
            assert b.placement is None
        else:
            assert [(p.node, p.gpu_indices) for p in a.placement.pods] \
                == [(p.node, p.gpu_indices) for p in b.placement.pods]


# ----------------------------------------------------------------------
# Cycle pipelining: byte-identity + speculation accounting
# ----------------------------------------------------------------------
def _sim_jobs(rng, n):
    return [Job(uid=i, tenant=f"t{i % 3}", gpu_type=0,
                n_pods=int(rng.integers(1, 6)),
                gpus_per_pod=int(rng.choice([4, 8])),
                duration=float(rng.integers(600, 8000)),
                submit_time=float(rng.integers(0, 600)),
                priority=int(rng.integers(0, 3)),
                kind=JobKind.TRAIN) for i in range(n)]


def _placements(jobs):
    return [(j.uid, j.start_time,
             None if j.placement is None else
             tuple((p.node, tuple(p.gpu_indices))
                   for p in j.placement.pods))
            for j in sorted(jobs, key=lambda j: j.uid)]


def _run_sim(policy, pipelined, seed=5):
    rng = np.random.default_rng(seed)
    topo = small_topology(n_nodes=24, gpus_per_node=8, nodes_per_leaf=8)
    state = ClusterState.create(topo)
    quota = QuotaManager({f"t{i}": {0: 10 ** 6} for i in range(3)})
    qsch = QSCH(quota, RSCH(topo), QSCHConfig(policy=policy))
    sim = Simulator(state, qsch,
                    SimConfig(pipelined_cycles=pipelined))
    res = sim.run(_sim_jobs(rng, 40))
    return _placements(res.jobs), res


@pytest.mark.parametrize("policy", list(QueuePolicy))
def test_pipelined_cycles_byte_identical(policy):
    a, ra = _run_sim(policy, False)
    b, rb = _run_sim(policy, True)
    assert a == b
    assert ra.pipeline is None
    stats = rb.pipeline
    assert stats is not None
    # Every speculation is eventually accounted: conflicted at arm
    # time, hit/missed at consume time — except at most one still
    # in flight when the run drains.
    drained = stats["hits"] + stats["misses"] + stats["conflicts"]
    assert 0 <= stats["speculated"] - drained <= 1
    assert stats["errors"] == 0


def test_pipeline_hits_under_contention():
    """A fragmentation-blocked head is re-scored every cycle; the
    speculation must be consumed (hit), not recomputed."""
    a, ra = _run_sim(QueuePolicy.BACKFILL, False, seed=6)
    b, rb = _run_sim(QueuePolicy.BACKFILL, True, seed=6)
    assert a == b
    stats = rb.pipeline
    assert stats["speculated"] > 0
    assert stats["hits"] > 0


def test_pipeline_requires_incremental_snapshots(topo, state):
    qsch = make_qsch(topo, state, incremental=False)
    with pytest.raises(ValueError):
        qsch.enable_pipeline()


def test_pipeline_off_is_default(topo, state):
    qsch = make_qsch(topo, state)
    sim = Simulator(state, qsch)
    assert qsch.pipeline is None
    assert SimConfig().pipelined_cycles is False
