"""Observability subsystem (repro.obs): metric registry semantics,
Chrome-trace emission, decision audit attribution, telemetry facade
wiring, report tool, and the satellite publishers (serving pool,
combo caches)."""

import json
import math

import numpy as np
import pytest

from repro.core import (ClusterState, Job, JobKind, QueuePolicy,
                        QuotaManager, QSCH, QSCHConfig, RSCH, RSCHConfig,
                        SimConfig, Simulator, Strategy, small_topology,
                        training_trace)
from repro.core.workload import DEFAULT_QUERY_CLASSES, ServeRequest
from repro.launch.combo_cache import ComboCache, cache_stats
from repro.obs import (DEFAULT_BUCKETS, DecisionAudit, MetricRegistry,
                       ObserverPlugin, PID_JOBS, PID_SCHED,
                       PlacementDecision, Telemetry, Tracer,
                       build_report, render_markdown)
from repro.obs import report as report_mod
from repro.serve import LeastLoadedRouter, ReplicaPool, ReplicaSpec

from conftest import make_qsch


# ----------------------------------------------------------------------
# Metric registry
# ----------------------------------------------------------------------
def test_counter_gauge_labels_and_ring():
    reg = MetricRegistry(ring=4)
    c = reg.counter("reqs_total", "requests")
    c.inc()
    c.inc(2.0, zone="a")
    assert c.value() == 1.0
    assert c.value(zone="a") == 2.0
    with pytest.raises(ValueError):
        c.inc(-1.0)
    g = reg.gauge("depth")
    g.set(5.0)
    g.inc(1.5)
    assert g.value() == 6.5
    for i in range(10):
        g.set(float(i))
    assert len(g.series()) == 4          # ring-bounded
    assert g.series()[-1] == (0.0, 9.0)


def test_registry_clock_stamps_series():
    t = {"now": 0.0}
    reg = MetricRegistry(clock=lambda: t["now"])
    g = reg.gauge("x")
    g.set(1.0)
    t["now"] = 42.0
    g.set(2.0)
    assert g.series() == [(0.0, 1.0), (42.0, 2.0)]


def test_metric_type_conflict_raises():
    reg = MetricRegistry()
    reg.counter("m")
    with pytest.raises(TypeError):
        reg.gauge("m")


def test_histogram_matches_numpy_reference():
    rng = np.random.default_rng(3)
    values = rng.uniform(0.0, 20_000.0, size=500)
    # Pin the boundary semantics: values exactly on a bound must land
    # in that bound's bucket (Prometheus `le`, i.e. value <= bound).
    values = np.concatenate([values, np.asarray(DEFAULT_BUCKETS)])
    reg = MetricRegistry()
    h = reg.histogram("lat", "latency")
    for v in values:
        h.observe(float(v))
    bounds = np.asarray(DEFAULT_BUCKETS)
    ref = [int((values <= b).sum()) for b in bounds] + [len(values)]
    assert h.cumulative() == ref


def test_prometheus_text_exposition():
    reg = MetricRegistry()
    reg.counter("jobs_total", "jobs").inc(3, tenant="t0")
    h = reg.histogram("wait", "queue wait", buckets=(1.0, 10.0))
    h.observe(0.5)
    h.observe(5.0)
    h.observe(100.0)
    text = reg.expose_text()
    assert "# HELP jobs_total jobs" in text
    assert "# TYPE jobs_total counter" in text
    assert 'jobs_total{tenant="t0"} 3' in text
    assert 'wait_bucket{le="1"} 1' in text
    assert 'wait_bucket{le="10"} 2' in text       # cumulative
    assert 'wait_bucket{le="+Inf"} 3' in text
    assert "wait_sum 105.5" in text
    assert "wait_count 3" in text


def test_pull_collectors_run_on_exposition():
    reg = MetricRegistry()
    calls = []

    def pull(r):
        calls.append(1)
        r.gauge("pulled").set(7.0)

    reg.add_collector(pull)
    assert "pulled 7" in reg.expose_text()
    doc = reg.to_json()
    assert doc["pulled"]["series"][0]["value"] == 7.0
    assert calls
    json.dumps(doc)                       # strictly serializable


# ----------------------------------------------------------------------
# Tracer (Chrome trace-event format)
# ----------------------------------------------------------------------
def _lane_balance(events):
    lanes = {}
    for e in events:
        if e["ph"] == "B":
            lanes[(e["pid"], e["tid"])] = lanes.get(
                (e["pid"], e["tid"]), 0) + 1
        elif e["ph"] == "E":
            lanes[(e["pid"], e["tid"])] = lanes.get(
                (e["pid"], e["tid"]), 0) - 1
    return lanes


def test_trace_event_schema_and_balance():
    tr = Tracer()
    tr.metadata(PID_SCHED, "scheduler (wall clock)")
    tr.begin("cycle", 0.0, PID_SCHED, 0, args={"t_sim": 0.0})
    tr.span("filter", 1.0, 5.0, PID_SCHED, 0)
    tr.instant("NODE_FAIL", 3.0, PID_SCHED, 0, args={"node": 4})
    tr.end("cycle", 10.0, PID_SCHED, 0)
    doc = tr.to_json()
    events = doc["traceEvents"]
    for e in events:
        assert {"ph", "name", "ts", "pid", "tid"} <= set(e)
    instants = [e for e in events if e["ph"] == "i"]
    assert instants and all(e["s"] == "t" for e in instants)
    # args are included only when present and truthy
    b_filter = next(e for e in events
                    if e["name"] == "filter" and e["ph"] == "B")
    assert "args" not in b_filter
    assert all(v == 0 for v in _lane_balance(events).values())
    json.dumps(doc)


def test_trace_close_all_tags_injected_ends():
    tr = Tracer()
    tr.begin("job-1", 0.0, PID_JOBS, 1)
    tr.begin("job-2", 5.0, PID_JOBS, 2)
    assert len(tr.open_spans()) == 2
    assert tr.close_all(50.0) == 2
    assert tr.open_spans() == {}
    ends = [e for e in tr.to_json()["traceEvents"] if e["ph"] == "E"]
    assert len(ends) == 2
    assert all(e["ts"] == 50.0 for e in ends)
    assert all(e["args"]["closed_at_finalize"] for e in ends)


def test_trace_event_cap_counts_drops():
    tr = Tracer(max_events=3)
    tr.instant("a", 0.0, PID_SCHED, 0)
    tr.instant("b", 1.0, PID_SCHED, 0)
    tr.span("s", 2.0, 1.0, PID_SCHED, 0)   # needs 2 slots, only 1 left
    assert tr.dropped == 2
    assert len(tr.to_json()["traceEvents"]) == 2


# ----------------------------------------------------------------------
# Decision audit through a real QSCH cycle
# ----------------------------------------------------------------------
def _gang(uid=1, pods=2, gpg=8, **kw):
    return Job(uid=uid, tenant="t0", gpu_type=0, n_pods=pods,
               gpus_per_pod=gpg, kind=JobKind.TRAIN, **kw)


def test_audit_breakdown_sums_to_fused_score(topo, state):
    qsch = make_qsch(topo, state, policy=QueuePolicy.STRICT_FIFO)
    tel = Telemetry()
    tel.attach_qsch(qsch)
    qsch.submit(_gang())
    result = qsch.cycle(state, 0.0)
    assert len(result.scheduled) == 1
    (dec,) = tel.audit.bound()
    assert dec.outcome == "bound" and dec.reason == "ok"
    placement = result.scheduled[0].placement
    assert dec.nodes == sorted({p.node for p in placement.pods})
    pa = dec.passes[-1]
    assert pa.pool_size > 0
    for st in pa.filters:
        assert 0 <= st.nodes_after <= st.nodes_before
        assert st.eliminated == st.nodes_before - st.nodes_after
    assert pa.breakdown, "winning pass must carry a score breakdown"
    assert {b.node for b in pa.breakdown} == set(dec.nodes)
    for b in pa.breakdown:
        assert b.terms, "per-ScorePlugin terms present"
        assert math.isclose(sum(b.terms.values()), b.total,
                            rel_tol=1e-6, abs_tol=1e-9), \
            f"terms {b.terms} do not sum to fused total {b.total}"
    json.dumps(dec.as_dict())             # export path serializable


def test_audit_records_rejection_reason(topo, state):
    qsch = make_qsch(topo, state, policy=QueuePolicy.STRICT_FIFO)
    tel = Telemetry()
    tel.attach_qsch(qsch)
    # 64 pods x 8 GPUs on a 128-GPU cluster can never fit.
    qsch.submit(_gang(uid=9, pods=64))
    result = qsch.cycle(state, 0.0)
    assert not result.scheduled
    rej = tel.audit.rejected()
    assert rej and rej[0].uid == 9
    reason = rej[0].reason
    assert reason
    assert tel.audit.rejections_by_reason()[reason] >= 1


def test_preemption_record_names_plugin_and_beneficiary():
    class Ctx:
        now = 120.0

    tel = Telemetry()
    tel.emit_preempt(_gang(uid=7), Ctx(), ("TenantClawback", 11))
    (rec,) = tel.audit.preemptions
    assert rec.victim_uid == 7
    assert rec.beneficiary_uid == 11
    assert rec.plugin == "TenantClawback"
    assert rec.t == 120.0
    assert tel.registry.counter("kant_preemptions_total").value(
        plugin="TenantClawback") == 1.0


def test_audit_ring_cap_reports_drops():
    audit = DecisionAudit(max_records=2)
    for uid in range(5):
        audit.on_bind(None, PlacementDecision(
            uid=uid, tenant="t0", kind="TRAIN", outcome="bound",
            reason="ok", t=float(uid)), None)
    assert len(audit.decisions) == 2
    assert audit.dropped == 3
    assert audit.summary()["decisions"] == 5


def test_custom_observer_plugin_receives_taps(topo, state):
    class Recorder(ObserverPlugin):
        name = "RecorderTestOnly"

        def __init__(self):
            self.cycles = 0
            self.binds = []

        def on_cycle(self, span, ctx):
            self.cycles += 1

        def on_bind(self, job, decision, ctx):
            self.binds.append((job.uid, decision))

    rec = Recorder()
    qsch = make_qsch(topo, state)
    tel = Telemetry(observers=[rec])
    tel.attach_qsch(qsch)
    qsch.submit(_gang(uid=3))
    qsch.cycle(state, 0.0)
    assert rec.cycles == 1
    assert rec.binds and rec.binds[0][0] == 3
    # The built-in audit's decision object is shared with customs.
    assert rec.binds[0][1] is tel.audit.bound()[0]


# ----------------------------------------------------------------------
# Telemetry facade on a full simulator run
# ----------------------------------------------------------------------
def _trace_jobs(n=40, seed=11):
    jobs = training_trace(n, seed=seed, arrival_rate_per_hour=400,
                          mean_duration_s=1800.0)
    return [j for j in jobs if j.n_gpus <= 64]


def _run_sim(jobs, telemetry=None):
    topo = small_topology(n_nodes=32, gpus_per_node=8, nodes_per_leaf=4)
    state = ClusterState.create(topo)
    qm = QuotaManager({"t0": {0: 10**6}})
    rsch = RSCH(topo, RSCHConfig(train_strategy=Strategy.E_BINPACK))
    qsch = QSCH(qm, rsch, QSCHConfig(policy=QueuePolicy.BACKFILL))
    sim = Simulator(state, qsch,
                    SimConfig(tick_interval=30.0, sample_interval=300.0,
                              binding_latency=45.0))
    if telemetry is not None:
        telemetry.attach(sim)
    return sim, sim.run(jobs)


def _fingerprint(result):
    return [(j.uid, j.start_time, j.end_time,
             tuple((p.node, p.gpu_indices)
                   for p in (j.placement.pods if j.placement else ())))
            for j in result.jobs]


def test_detached_telemetry_is_byte_identical():
    base_sim, base = _run_sim(_trace_jobs())
    tel = Telemetry()
    inst_sim, inst = _run_sim(_trace_jobs(), telemetry=tel)
    assert _fingerprint(base) == _fingerprint(inst)
    assert base.metrics.report() == inst.metrics.report()
    assert tel.registry.counter("kant_cycles_total").value() > 0
    tel.detach(inst_sim)
    assert inst_sim.qsch.obs is None and inst_sim.qsch.rsch.obs is None


def test_job_spans_cover_run_and_lanes_balance():
    tel = Telemetry()
    _, result = _run_sim(_trace_jobs(), telemetry=tel)
    events = tel.tracer.to_json()["traceEvents"]
    begins = {e["name"] for e in events
              if e["ph"] == "B" and e["pid"] == PID_JOBS}
    assert begins == {f"job-{j.uid}" for j in result.jobs}
    assert all(v == 0 for v in _lane_balance(events).values())
    # Job lifecycle records accumulated waits consistent with the sim.
    recs = {r["uid"]: r for r in tel.job_records()}
    for j in result.jobs:
        if j.start_time is not None:
            assert recs[j.uid]["first_start"] == j.start_time
            assert recs[j.uid]["wait_s"] == j.start_time - j.submit_time


def test_pillar_toggles_disable_cleanly():
    tel = Telemetry(registry=False, tracing=False, audit=False)
    assert tel.registry is None and tel.tracer is None
    assert tel.audit is None and not tel.audit_on
    with pytest.raises(ValueError):
        tel.save_trace("unused.json")
    bundle = tel.bundle()
    assert "metrics" not in bundle and "trace" not in bundle
    assert "audit" not in bundle
    assert bundle["meta"]["pillars"] == {"registry": False,
                                         "tracing": False,
                                         "audit": False}


# ----------------------------------------------------------------------
# Bundle + report tool
# ----------------------------------------------------------------------
def test_bundle_report_and_cli_roundtrip(tmp_path):
    tel = Telemetry()
    _run_sim(_trace_jobs(), telemetry=tel)
    bundle = tel.bundle()
    assert bundle["meta"]["format"] == "repro.obs/1"
    assert bundle["jobs"] and bundle["metrics"] and bundle["audit"]

    path = tmp_path / "bundle.json"
    tel.save(str(path))
    loaded = json.loads(path.read_text())
    report = build_report(loaded)
    assert report["summary"]["jobs_seen"] == len(bundle["jobs"])
    assert report["summary"]["jobs_completed"] > 0
    assert report["audit"]["bound"] == bundle["audit"]["summary"]["bound"]
    md = render_markdown(report)
    assert md.startswith("# Run telemetry report")
    assert "## Summary" in md and "## Metrics" in md

    out_md = tmp_path / "report.md"
    assert report_mod.main([str(path), "--format", "md",
                            "-o", str(out_md)]) == 0
    assert "# Run telemetry report" in out_md.read_text()
    out_js = tmp_path / "report.json"
    assert report_mod.main([str(path), "--format", "json",
                            "-o", str(out_js)]) == 0
    assert json.loads(out_js.read_text())["summary"]["jobs_seen"] == \
        report["summary"]["jobs_seen"]


# ----------------------------------------------------------------------
# Satellite publishers: serving pool + combo caches
# ----------------------------------------------------------------------
def test_replica_pool_publishes_to_registry():
    reg = MetricRegistry()
    pool = ReplicaPool([ReplicaSpec("a", capability=1.0,
                                    cost_per_1k_tokens=2.0)],
                       LeastLoadedRouter())
    pool.route(ServeRequest(uid=0, qclass=DEFAULT_QUERY_CLASSES[0],
                            arrival_s=10.0, prompt_tokens=64,
                            output_tokens=16))
    pool.bind_registry(reg, name="edge")
    text = reg.expose_text()
    assert 'serving_replicas{pool="edge"} 1' in text
    assert "serving_observed_rps" in text
    assert "serving_replica_demand" in text


def test_combo_cache_stats_reach_registry():
    cache = ComboCache("obs-test-cache")
    assert cache.get("k") is None          # miss
    cache.put("k", 1)
    assert cache.get("k") == 1             # hit
    st = cache_stats()["obs-test-cache"]
    assert st == {"hits": 1, "misses": 1, "size": 1}
    tel = Telemetry()
    text = tel.registry.expose_text()
    assert 'combo_cache_hits{cache="obs-test-cache"} 1' in text
    assert 'combo_cache_misses{cache="obs-test-cache"} 1' in text
    assert 'combo_cache_entries{cache="obs-test-cache"} 1' in text
