"""Snapshot equivalence: incremental == full copy, always (§3.4.3)."""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="install .[test] for the "
                    "property-based equivalence sweep")
from hypothesis import given, settings, strategies as st

from repro.core import (ClusterState, FullSnapshotter,
                        IncrementalSnapshotter, Job, Placement,
                        PodPlacement, snapshots_equal)
from repro.core.topology import small_topology


def _random_ops(state, rng, uid_start, n_ops):
    """Apply random allocate/release/health ops; returns next uid."""
    uid = uid_start
    for _ in range(n_ops):
        op = rng.integers(0, 4)
        if op == 0:                                   # allocate
            free = state.free_gpus()
            nodes = np.nonzero(free > 0)[0]
            if len(nodes) == 0:
                continue
            node = int(rng.choice(nodes))
            k = int(rng.integers(1, free[node] + 1))
            avail = np.nonzero(~state.gpu_busy[node]
                               & state.gpu_healthy[node])[0][:k]
            job = Job(uid=uid, tenant="t", gpu_type=0, n_pods=1,
                      gpus_per_pod=len(avail))
            state.allocate(job, Placement(pods=[PodPlacement(
                node=node, gpu_indices=tuple(int(g) for g in avail))]))
            uid += 1
        elif op == 1 and state.allocations:           # release
            state.release(int(rng.choice(list(state.allocations))))
        elif op == 2:                                 # gpu health flip
            n = int(rng.integers(0, state.n_nodes))
            g = int(rng.integers(0, state.gpus_per_node))
            if not state.gpu_busy[n, g]:
                state.set_gpu_health(n, g, bool(rng.integers(0, 2)))
        else:                                         # node health flip
            n = int(rng.integers(0, state.n_nodes))
            if not state.gpu_busy[n].any():
                state.set_node_health(n, bool(rng.integers(0, 2)))
    return uid


@given(seed=st.integers(0, 10_000), rounds=st.integers(1, 8))
@settings(max_examples=25, deadline=None)
def test_incremental_equals_full(seed, rounds):
    """Property: after any op sequence, the incremental snapshot equals a
    fresh full copy."""
    topo = small_topology(n_nodes=12, gpus_per_node=4)
    state = ClusterState.create(topo)
    inc = IncrementalSnapshotter()
    rng = np.random.default_rng(seed)
    uid = 0
    for _ in range(rounds):
        uid = _random_ops(state, rng, uid, n_ops=int(rng.integers(1, 10)))
        snap_inc = inc.take(state)
        snap_full = FullSnapshotter().take(state)
        assert snapshots_equal(snap_inc, snap_full)
        state.check_invariants()


def test_incremental_copies_fewer_rows():
    topo = small_topology(n_nodes=64, gpus_per_node=8)
    state = ClusterState.create(topo)
    inc = IncrementalSnapshotter()
    inc.take(state)                      # first take = full copy
    assert inc.rows_copied == 64
    job = Job(uid=1, tenant="t", gpu_type=0, n_pods=1, gpus_per_pod=2)
    state.allocate(job, Placement(pods=[PodPlacement(
        node=5, gpu_indices=(0, 1))]))
    inc.take(state)
    assert inc.rows_copied == 65         # only the dirty row


@given(seed=st.integers(0, 10_000), n_jobs=st.integers(1, 12))
@settings(max_examples=25, deadline=None)
def test_placement_delta_equals_retake(seed, n_jobs):
    """Property (§3.4.3): applying allocate/release deltas to a live
    snapshot is indistinguishable from re-taking it."""
    topo = small_topology(n_nodes=12, gpus_per_node=4)
    state = ClusterState.create(topo)
    snap = FullSnapshotter().take(state)
    rng = np.random.default_rng(seed)
    live = []
    for uid in range(n_jobs):
        if live and rng.random() < 0.3:
            victim = live.pop(int(rng.integers(0, len(live))))
            snap.apply_release(state.release(victim))
            continue
        free = state.free_gpus()
        nodes = np.nonzero(free > 0)[0]
        if len(nodes) == 0:
            continue
        node = int(rng.choice(nodes))
        k = int(rng.integers(1, free[node] + 1))
        avail = np.nonzero(~state.gpu_busy[node]
                           & state.gpu_healthy[node])[0][:k]
        job = Job(uid=uid, tenant="t", gpu_type=0, n_pods=1,
                  gpus_per_pod=len(avail))
        placement = Placement(pods=[PodPlacement(
            node=node, gpu_indices=tuple(int(g) for g in avail))])
        state.allocate(job, placement)
        snap.apply_placement(placement)
        live.append(uid)
    assert snapshots_equal(snap, FullSnapshotter().take(state))


def test_snapshot_isolated_from_later_mutation():
    topo = small_topology(n_nodes=4, gpus_per_node=4)
    state = ClusterState.create(topo)
    inc = IncrementalSnapshotter()
    snap = inc.take(state)
    free_before = snap.free_gpus.copy()
    job = Job(uid=1, tenant="t", gpu_type=0, n_pods=1, gpus_per_pod=4)
    state.allocate(job, Placement(pods=[PodPlacement(
        node=0, gpu_indices=(0, 1, 2, 3))]))
    # The retained snapshot object is refreshed only on the next take().
    assert (snap.free_gpus == free_before).all()
    snap2 = inc.take(state)
    assert snap2.free_gpus[0] == 0
