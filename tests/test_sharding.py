"""Auto-sharder rule table: determinism + divisibility fallbacks."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_arch
from repro.models import Model
from repro.sharding.auto import (ShardingRules, batch_specs,
                                 cache_specs_sharding, param_shardings,
                                 partition_spec)


@pytest.fixture(scope="module")
def rules():
    # A (4, 2) CPU mesh stands in for (data, model); the rule table only
    # reads axis sizes, so divisibility semantics are identical.
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    return ShardingRules(mesh)


@pytest.fixture(scope="module")
def rules_16x16():
    from jax.sharding import AbstractMesh
    # jax 0.4.x takes one shape tuple of (name, size) pairs.
    return ShardingRules(AbstractMesh((("data", 16), ("model", 16))))


def test_mlp_rules(rules_16x16):
    r = rules_16x16
    assert partition_spec("layers/mlp/w_gate", (40, 4096, 13696), r) == \
        P(None, "data", "model")
    assert partition_spec("layers/mlp/w_down", (40, 13696, 4096), r) == \
        P(None, "model", "data")


def test_attention_rules_with_fallback(rules_16x16):
    r = rules_16x16
    # 32 q heads divide 16 -> TP on heads
    assert partition_spec("layers/attn/wq", (40, 4096, 32, 128), r) == \
        P(None, "data", "model", None)
    # 2 kv heads do NOT divide 16 -> replicate heads (no hd fallback)
    assert partition_spec("layers/attn/wk", (40, 4096, 2, 128), r) == \
        P(None, "data", None, None)
    assert partition_spec("layers/attn/wo", (40, 32, 128, 4096), r) == \
        P(None, "model", None, "data")


def test_moe_expert_parallel_and_fallback(rules_16x16):
    r = rules_16x16
    # llama4: 128 experts divide 16 -> EP
    assert partition_spec("layers/moe/w_gate", (48, 128, 5120, 8192),
                          r) == P(None, "model", "data", None)
    # mixtral: 8 experts don't -> TP on d_ff instead
    assert partition_spec("layers/moe/w_gate", (32, 8, 4096, 14336),
                          r) == P(None, None, "data", "model")


def test_embed_and_head(rules_16x16):
    r = rules_16x16
    assert partition_spec("embed", (151552, 4096), r) == \
        P("model", "data")
    assert partition_spec("lm_head", (4096, 151552), r) == \
        P("data", "model")
    # seamless vocab 256206 is not divisible by 16 -> only data on d
    assert partition_spec("embed", (256206, 1024), r) == P(None, "data")


def test_norms_replicated(rules_16x16):
    assert partition_spec("layers/norm1", (40, 4096), rules_16x16) == P()
    assert partition_spec("final_norm", (4096,), rules_16x16) == P()


def test_every_param_of_every_arch_gets_a_spec(rules_16x16):
    """Rule table is total + deterministic over the whole zoo."""
    for arch_id in ARCH_IDS:
        cfg = get_arch(arch_id)
        specs = Model(cfg).param_specs()
        flat, _ = jax.tree_util.tree_flatten_with_path(specs)
        for keypath, leaf in flat:
            path = "/".join(str(getattr(k, "key", k)) for k in keypath)
            spec1 = partition_spec(path, leaf.shape, rules_16x16)
            spec2 = partition_spec(path, leaf.shape, rules_16x16)
            assert spec1 == spec2
            # every sharded dim divides
            for dim, part in enumerate(spec1):
                if part is None:
                    continue
                size = 16
                assert leaf.shape[dim] % size == 0, (arch_id, path)


def test_batch_specs_divisibility(rules_16x16):
    specs = batch_specs(
        {"tokens": jax.ShapeDtypeStruct((256, 4096), jnp.int32),
         "odd": jax.ShapeDtypeStruct((1, 7), jnp.int32)}, rules_16x16)
    assert specs["tokens"].spec == P(("data",), None)
    assert specs["odd"].spec == P(None, None)


def test_cache_sharding_head_vs_window_fallback(rules_16x16):
    r = rules_16x16
    cache = {
        "layers": {
            # 8 kv heads don't divide 16 -> window dim gets model
            "k": jax.ShapeDtypeStruct((88, 128, 32768, 8, 128),
                                      jnp.bfloat16),
            # 16 kv heads divide -> heads get model
            "v": jax.ShapeDtypeStruct((24, 128, 32768, 16, 64),
                                      jnp.bfloat16),
        },
        "t": jax.ShapeDtypeStruct((), jnp.int32),
    }
    out = cache_specs_sharding(cache, r)
    assert out["layers"]["k"].spec == P(None, ("data",), "model", None,
                                        None)
    assert out["layers"]["v"].spec == P(None, ("data",), None, "model",
                                        None)
    assert out["t"].spec == P()
