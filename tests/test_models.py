"""Per-arch smoke tests (deliverable f) + decode/forward equivalence.

Each assigned architecture instantiates its REDUCED (smoke) variant —
2 layers, d_model<=512, <=4 experts — runs one forward and one train
step on CPU, and asserts output shapes + no NaNs.  The equivalence test
asserts prefill + token-by-token decode reproduces the teacher-forced
forward logits.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_arch, make_inputs
from repro.models import Model
from repro.train import AdamWConfig, adamw_init, make_train_step

SEQ = 32
BATCH = 2


@pytest.fixture(scope="module")
def zoo():
    """Init each smoke model once per test session."""
    cache = {}

    def get(arch_id):
        if arch_id not in cache:
            cfg = get_arch(arch_id, smoke=True)
            model = Model(cfg)
            params = model.init(jax.random.PRNGKey(0))
            cache[arch_id] = (cfg, model, params)
        return cache[arch_id]

    return get


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_forward_shapes_no_nans(zoo, arch_id):
    cfg, model, params = zoo(arch_id)
    batch = make_inputs(cfg, batch=BATCH, seq=SEQ, kind="train")
    logits, aux = jax.jit(model.forward)(params, batch)
    s_text = batch["tokens"].shape[1]
    assert logits.shape == (BATCH, s_text, cfg.vocab)
    assert not jnp.isnan(logits).any()
    assert not jnp.isnan(aux)


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_train_step_runs_and_is_finite(zoo, arch_id):
    cfg, model, params = zoo(arch_id)
    batch = make_inputs(cfg, batch=BATCH, seq=SEQ, kind="train")
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3), remat=False))
    opt = adamw_init(params)
    new_params, _, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    delta = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()),
                         params, new_params)
    assert max(jax.tree.leaves(delta)) > 0


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_prefill_decode_matches_forward(zoo, arch_id):
    cfg, model, params = zoo(arch_id)
    batch = make_inputs(cfg, batch=BATCH, seq=24, kind="prefill")
    logits_full, _ = jax.jit(model.forward)(params, batch)
    k = 16
    total = batch["tokens"].shape[1] + \
        (cfg.n_prefix if cfg.family == "vlm" else 0)
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :k]
    lg, cache = jax.jit(
        lambda p, b: model.prefill(p, b, seq_len=total))(params, pre)
    # Smoke MoE configs use capacity_factor=4 so no tokens can drop and
    # decode matches forward tightly for every family.
    tol = 1e-3
    errs = [float(jnp.abs(lg - logits_full[:, k - 1]).max())]
    step = jax.jit(model.decode_step)
    for i in range(k, batch["tokens"].shape[1]):
        lg, cache = step(params, cache, batch["tokens"][:, i])
        errs.append(float(jnp.abs(lg - logits_full[:, i]).max()))
    assert max(errs) < tol, f"{arch_id}: decode drift {max(errs)}"


def test_sliding_window_masks_old_tokens():
    """With window W, tokens outside the L×W receptive field must not
    change the final logits (SWA really masks)."""
    import dataclasses
    cfg = dataclasses.replace(get_arch("glm4-9b", smoke=True), window=16)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab, size=(1, 80)).astype(np.int32)
    toks2 = toks.copy()
    toks2[0, :8] = (toks2[0, :8] + 1) % cfg.vocab   # beyond 2 layers × 16
    outs = []
    for t in (toks, toks2):
        logits, _ = jax.jit(model.forward)(
            params, {"tokens": jnp.asarray(t)})
        outs.append(np.asarray(logits[:, -1]))
    np.testing.assert_allclose(outs[0], outs[1], atol=1e-4)


def test_full_configs_match_assignment():
    """The FULL configs carry the exact published hyperparameters."""
    expect = {
        "mistral-large-123b": (88, 12288, 96, 8, 28672, 32768),
        "glm4-9b": (40, 4096, 32, 2, 13696, 151552),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
        "codeqwen1.5-7b": (32, 4096, 32, 32, 13440, 92416),
        "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
        "granite-20b": (52, 6144, 48, 1, 24576, 49152),
        "rwkv6-3b": (32, 2560, 0, 0, 8960, 65536),
        "llava-next-34b": (60, 7168, 56, 8, 20480, 64000),
    }
    for arch_id, (L, d, h, kv, ff, v) in expect.items():
        cfg = get_arch(arch_id)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.d_ff, cfg.vocab) == (L, d, h, kv, ff, v), arch_id
    assert get_arch("mixtral-8x7b").n_experts == 8
    assert get_arch("mixtral-8x7b").top_k == 2
    assert get_arch("llama4-maverick-400b-a17b").n_experts == 128
    assert get_arch("llama4-maverick-400b-a17b").top_k == 1
    assert get_arch("seamless-m4t-large-v2").n_enc_layers == 24
    assert get_arch("hymba-1.5b").ssm_state == 16


def test_smoke_configs_are_reduced():
    for arch_id in ARCH_IDS:
        cfg = get_arch(arch_id, smoke=True)
        assert cfg.n_layers <= 2
        assert cfg.d_model <= 512
        assert cfg.n_experts <= 4
