"""Serving fabric: per-slot continuous batching, router policies,
replica pool, demand export to the tidal autoscaler."""

import numpy as np
import pytest

from repro.core import (DynamicsConfig, Simulator, SimConfig,
                        request_trace)
from repro.core.dynamics import TidalAutoscaler
from repro.core.framework import (RouterPolicyPlugin, available_plugins,
                                  create_plugin, register)
from repro.core.workload import DEFAULT_QUERY_CLASSES, QueryClass, \
    ServeRequest
from repro.serve import (CapabilityCostRouter, LeastLoadedRouter,
                         Replica, ReplicaPool, ReplicaSpec,
                         RoundRobinRouter, demand_service,
                         to_engine_request)

from conftest import make_qsch


# ----------------------------------------------------------------------
# Engine: per-slot prefill (jax-backed, smoke arch)
# ----------------------------------------------------------------------
@pytest.fixture(scope="module")
def engine_setup():
    import jax
    from repro.configs import get_arch
    from repro.models import Model
    cfg = get_arch("glm4-9b", smoke=True)
    params = Model(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


def _mk_engine(cfg, params, **kw):
    from repro.serve import ServeEngine
    return ServeEngine(cfg, params, batch_size=2, max_seq=64, **kw)


def test_per_slot_token_identical_to_legacy_on_waves(engine_setup):
    """Equal-length prompts admitted in full waves: neither path pads,
    so per-slot prefill must reproduce the legacy whole-batch re-prefill
    token for token on a fixed seed."""
    from repro.serve import Request
    cfg, params = engine_setup
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab, size=6).astype(np.int32)
               for _ in range(4)]

    def run(per_slot):
        eng = _mk_engine(cfg, params, per_slot_prefill=per_slot)
        for i, p in enumerate(prompts):
            eng.submit(Request(uid=i, prompt=p, max_new_tokens=4))
        return {r.uid: list(r.generated)
                for r in eng.run_until_drained()}

    assert run(True) == run(False)


def test_per_slot_outputs_independent_and_never_reprefilled(engine_setup):
    """Mixed-length prompts with staggered finishes: every request's
    output must equal its solo B=1 reference (admission splices into a
    live batch without disturbing residents), and prefill accounting
    must show exactly one prefill per request — while the legacy shim
    re-runs resident tokens."""
    from repro.serve import Request
    cfg, params = engine_setup
    rng = np.random.default_rng(2)
    lens = [6, 9, 4, 7]
    budgets = [3, 6, 4, 5]
    prompts = [rng.integers(0, cfg.vocab, size=n).astype(np.int32)
               for n in lens]

    solo = {}
    for i, p in enumerate(prompts):
        from repro.serve import ServeEngine
        eng = ServeEngine(cfg, params, batch_size=1, max_seq=64)
        eng.submit(Request(uid=i, prompt=p, max_new_tokens=budgets[i]))
        [r] = eng.run_until_drained()
        solo[i] = list(r.generated)

    eng = _mk_engine(cfg, params)
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=p, max_new_tokens=budgets[i]))
    fin = eng.run_until_drained()
    assert len(fin) == 4
    assert {r.uid: list(r.generated) for r in fin} == solo
    assert eng.prefill_calls == 4
    assert eng.prefill_tokens == sum(lens)

    legacy = _mk_engine(cfg, params, per_slot_prefill=False)
    for i, p in enumerate(prompts):
        legacy.submit(Request(uid=i, prompt=p, max_new_tokens=budgets[i]))
    legacy.run_until_drained()
    assert legacy.prefill_tokens > sum(lens)


def test_deadline_eviction_frees_slot(engine_setup):
    from repro.serve import Request
    cfg, params = engine_setup
    rng = np.random.default_rng(3)
    eng = _mk_engine(cfg, params)
    hog = Request(uid=0, prompt=rng.integers(0, cfg.vocab, size=5)
                  .astype(np.int32), max_new_tokens=50, deadline_steps=3)
    ok = Request(uid=1, prompt=rng.integers(0, cfg.vocab, size=5)
                 .astype(np.int32), max_new_tokens=4)
    eng.submit(hog)
    eng.submit(ok)
    fin = eng.run_until_drained(max_steps=100)
    by_uid = {r.uid: r for r in fin}
    assert by_uid[0].evicted and by_uid[0].done
    assert len(by_uid[0].generated) < 50
    assert not by_uid[1].evicted and len(by_uid[1].generated) == 4
    assert eng.evictions == 1
    # TTFT/TPOT accounting on the survivor.
    assert by_uid[1].ttft_steps is not None and by_uid[1].ttft_steps >= 0
    assert by_uid[1].tpot_steps == pytest.approx(1.0)


# ----------------------------------------------------------------------
# Router policies (pure python, sim-time replicas)
# ----------------------------------------------------------------------
def _req(qclass: QueryClass, uid=0, t=0.0, prompt=100, out=50):
    return ServeRequest(uid=uid, qclass=qclass, arrival_s=t,
                        prompt_tokens=prompt, output_tokens=out)


def _replica(cap=0.5, cost=1.0, prefill=5000.0, decode=50.0, slots=2,
             name="r"):
    return Replica(ReplicaSpec(name, capability=cap,
                               cost_per_1k_tokens=cost,
                               prefill_tokens_per_s=prefill,
                               decode_tokens_per_s=decode, slots=slots))


def test_round_robin_cycles():
    reps = [_replica(name=f"r{i}") for i in range(3)]
    pol = RoundRobinRouter()
    req = _req(DEFAULT_QUERY_CLASSES[0])
    assert [pol.select(req, reps, 0.0) for _ in range(6)] == \
        [0, 1, 2, 0, 1, 2]


def test_least_loaded_prefers_empty_replica():
    reps = [_replica(name="busy"), _replica(name="idle")]
    reps[0].admit(_req(DEFAULT_QUERY_CLASSES[0], out=500), 0.0, 0)
    pol = LeastLoadedRouter()
    assert pol.select(_req(DEFAULT_QUERY_CLASSES[0], uid=1), reps, 0.0) == 1


def test_capcost_rejects_slo_infeasible_request():
    """No replica can decode fast enough for the SLO: reject (None)
    rather than knowingly miss; with reject_infeasible=False the
    request degrades to the fastest capable replica instead."""
    tight = QueryClass("tight", quality_floor=0.0, latency_slo_s=1.0)
    slow = _replica(decode=10.0, name="slow")        # 500 tok -> 50 s
    slower = _replica(decode=5.0, name="slower")
    req = _req(tight, out=500)
    assert CapabilityCostRouter().select(req, [slow, slower], 0.0) is None
    pol = CapabilityCostRouter(reject_infeasible=False)
    assert pol.select(req, [slower, slow], 0.0) == 1   # fastest capable


def test_capcost_rejects_when_no_replica_meets_quality_floor():
    hard = QueryClass("hard", quality_floor=0.9, latency_slo_s=100.0)
    reps = [_replica(cap=0.4), _replica(cap=0.6)]
    assert CapabilityCostRouter().select(_req(hard), reps, 0.0) is None
    # reject_infeasible only relaxes the SLO stage, never quality.
    pol = CapabilityCostRouter(reject_infeasible=False)
    assert pol.select(_req(hard), reps, 0.0) is None


def test_capcost_picks_cheapest_feasible_and_breaks_ties_on_latency():
    easy = QueryClass("easy", quality_floor=0.5, latency_slo_s=100.0)
    reps = [_replica(cap=0.9, cost=8.0, name="pricey"),
            _replica(cap=0.6, cost=1.0, decode=25.0, name="cheap-slow"),
            _replica(cap=0.6, cost=1.0, decode=50.0, name="cheap-fast"),
            _replica(cap=0.3, cost=0.1, name="too-weak")]
    # cheapest feasible wins over capable-but-pricey; equal-cost tie
    # breaks toward lower predicted latency (index 2 beats index 1).
    assert CapabilityCostRouter().select(_req(easy), reps, 0.0) == 2


def test_capcost_online_learning_routes_around_misdeclared_replica():
    cls = QueryClass("c", quality_floor=0.5, latency_slo_s=100.0)
    pol = CapabilityCostRouter(learn=True, learn_rate=1.0)
    reps = [_replica(cap=0.9, cost=0.5, name="liar"),
            _replica(cap=0.9, cost=2.0, name="honest")]
    assert pol.select(_req(cls), reps, 0.0) == 0        # cheapest prior
    from repro.serve import RequestOutcome
    pol.observe(RequestOutcome(uid=0, qclass="c", replica=0,
                               rejected=False, quality_ok=False))
    assert pol.select(_req(cls, uid=1), reps, 0.0) == 1  # routed around


def test_router_policies_in_plugin_registry():
    names = available_plugins()
    for n in ("RoundRobinRouter", "LeastLoadedRouter",
              "CapabilityCostRouter"):
        assert n in names
    pol = create_plugin("CapabilityCostRouter", slo_margin=0.5)
    assert isinstance(pol, CapabilityCostRouter)
    assert pol.slo_margin == 0.5


def test_custom_router_policy_registers_and_routes():
    """The docs/serving.md worked example: an out-of-tree policy plugs
    into the pool through the shared framework registry."""
    @register
    class CheapestRouter(RouterPolicyPlugin):
        name = "CheapestRouterTestOnly"

        def select(self, request, replicas, now):
            return min(range(len(replicas)),
                       key=lambda i: replicas[i].spec.cost_per_1k_tokens)

    reps = [ReplicaSpec("a", capability=1.0, cost_per_1k_tokens=5.0),
            ReplicaSpec("b", capability=1.0, cost_per_1k_tokens=1.0)]
    pool = ReplicaPool(reps, create_plugin("CheapestRouterTestOnly"))
    out = pool.route(_req(DEFAULT_QUERY_CLASSES[0]))
    assert out.replica == 1


# ----------------------------------------------------------------------
# Request trace + pool metrics
# ----------------------------------------------------------------------
def test_request_trace_is_sorted_mixed_and_reproducible():
    t1 = request_trace(300, seed=7, period_s=1800.0)
    t2 = request_trace(300, seed=7, period_s=1800.0)
    assert [r.arrival_s for r in t1] == [r.arrival_s for r in t2]
    arr = [r.arrival_s for r in t1]
    assert arr == sorted(arr) and arr[0] > 0.0
    names = {r.qclass.name for r in t1}
    assert {"chat", "code"} <= names
    assert all(r.prompt_tokens >= 4 and r.output_tokens >= 1 for r in t1)


def test_pool_books_rejection_as_slo_miss():
    hard = QueryClass("hard", quality_floor=0.99, latency_slo_s=10.0)
    pool = ReplicaPool([ReplicaSpec("weak", capability=0.2,
                                    cost_per_1k_tokens=1.0)],
                       CapabilityCostRouter())
    out = pool.route(_req(hard))
    assert out.rejected and not out.slo_ok and out.cost == 0.0
    assert pool.metrics.slo_attainment() == 0.0
    assert pool.metrics.rejected() == 1


def test_to_engine_request_is_deterministic_and_clipped():
    req = ServeRequest(uid=5, qclass=DEFAULT_QUERY_CLASSES[0],
                       arrival_s=0.0, prompt_tokens=500,
                       output_tokens=999)
    a = to_engine_request(req, vocab=512, seed=3, max_prompt=32,
                          max_new=8)
    b = to_engine_request(req, vocab=512, seed=3, max_prompt=32,
                          max_new=8)
    assert np.array_equal(a.prompt, b.prompt)
    assert len(a.prompt) == 32 and a.max_new_tokens == 8
    assert a.qclass == "chat"


# ----------------------------------------------------------------------
# Demand export round-trip: pool -> TidalService -> autoscaler -> sim
# ----------------------------------------------------------------------
def test_demand_export_roundtrip_through_autoscaler(topo, state):
    # Low rates so the trace spans most of the compressed diurnal cycle
    # (the generator peaks at t=0), single-slot replicas so the demand
    # signal swings across several integer replica counts.
    trace = request_trace(3000, seed=0, period_s=1800.0, base_rps=0.3,
                          peak_rps=5.0, burst_rate_per_hour=1.0,
                          burst_multiplier=2.0)
    pool = ReplicaPool([ReplicaSpec("m", capability=0.9,
                                    cost_per_1k_tokens=1.0,
                                    prefill_tokens_per_s=6000.0,
                                    decode_tokens_per_s=60.0, slots=1)],
                       LeastLoadedRouter(), demand_bucket_s=300.0)
    pool.route_trace(trace)
    svc = demand_service(pool, min_replicas=1, max_replicas=8,
                         gpus_per_replica=4, tenant="svc")

    # The analytic curve is replaced by observed load, clipped to range.
    span = trace[-1].arrival_s
    targets = [svc.target_replicas(t) for t in np.arange(0, span, 60.0)]
    assert max(targets) > min(targets), "targets must track the load"
    assert all(1 <= x <= 8 for x in targets)

    # Round-trip: the autoscaler scales a real simulated fleet to the
    # pool's observed demand.
    scaler = TidalAutoscaler([svc], interval_s=60.0)
    qsch = make_qsch(topo, state, quota={"svc": {0: 1024}})
    sim = Simulator(state, qsch,
                    SimConfig(tick_interval=30.0, sample_interval=300.0,
                              horizon=span,
                              dynamics=DynamicsConfig(plugins=[scaler])))
    sim.run([])
    assert scaler.replicas_started >= max(targets), \
        "fleet must ramp to the observed peak"
    logged = {s.target for s in scaler.demand_log}
    assert logged == {svc.target_replicas(s.t)
                      for s in scaler.demand_log}
    # The observed signal is bursty; allow the fleet some ramp lag.
    assert scaler.satisfaction() > 0.7
    state.check_invariants()
