"""Plugin framework: registry, profiles, extension points, equivalence.

The two hard guarantees of the framework refactor:

1. the default profiles (and the legacy ``Strategy``/``QueuePolicy``
   shims that build them) are placement-identical to the pre-framework
   schedulers;
2. every extension point actually extends: custom plugins change
   behavior without touching scheduler internals.
"""

import numpy as np
import pytest

from repro.core import (ClusterState, Job, JobKind, JobState, QSCH,
                        QSCHConfig, QueuePolicy, QuotaManager, QuotaMode,
                        RSCH, RSCHConfig, SimConfig, Simulator, Strategy,
                        profiles_from_config)
from repro.core.framework import (AdmitPlugin, BackfillPolicy,
                                  FilterPlugin, GfrAwareScore,
                                  PlacementPass, PostBindPlugin, ProfileSet,
                                  QueueSortPlugin, ReservePlugin,
                                  PermitPlugin, ScorePlugin,
                                  SchedulingContext, TenantSoftAffinity,
                                  available_plugins, binpack_pass,
                                  create_plugin, default_profiles,
                                  ebinpack_pass, make_profile, register,
                                  single_pass_plan, spread_pass)
from repro.core.scoring import ScoreWeights
from repro.core.snapshot import FullSnapshotter
from repro.core.topology import ClusterTopology, small_topology
from conftest import make_qsch


def _snap(state):
    return FullSnapshotter().take(state)


def _job(uid=0, n_pods=1, gpus=8, kind=JobKind.TRAIN, tenant="t0",
         prio=50, t=0.0):
    return Job(uid=uid, tenant=tenant, gpu_type=0, n_pods=n_pods,
               gpus_per_pod=gpus, kind=kind,
               gang=(kind is JobKind.TRAIN), priority=prio, submit_time=t)


# ----------------------------------------------------------------------
# Registry
# ----------------------------------------------------------------------
def test_registry_has_builtins_and_contrib():
    names = available_plugins()
    for expected in ("QuotaAdmit", "DynamicFeasibility", "GpuTypeFilter",
                     "HealthFilter", "BinpackScore", "SpreadScore",
                     "GroupConsolidation", "TopoAnchor", "ColocateBonus",
                     "QuotaReserve", "PriorityPreempt",
                     "QuotaReclaimPreempt", "BackfillHeadTimeout",
                     "StrictFIFO", "BestEffortFIFO", "Backfill",
                     "DefaultQueueSort", "GfrAwareScore",
                     "TenantSoftAffinity"):
        assert expected in names


def test_registry_create_and_unknown():
    plugin = create_plugin("ColocateBonus", bonus=3.0)
    assert plugin.per_pod_bonus(_job()) == 3.0
    with pytest.raises(KeyError):
        create_plugin("NoSuchPlugin")


def test_registry_rejects_duplicate_name():
    @register
    class _Dup(ScorePlugin):
        name = "_DupTestPlugin"

    with pytest.raises(ValueError):
        @register
        class _Dup2(ScorePlugin):  # noqa: F811 — intentional clash
            name = "_DupTestPlugin"


# ----------------------------------------------------------------------
# Default-profile equivalence with the legacy shims
# ----------------------------------------------------------------------
def _mixed_trace(n=80, seed=3):
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(8.0, size=n))
    kinds = [JobKind.TRAIN, JobKind.INFER, JobKind.DEBUG]
    jobs = []
    for i in range(n):
        kind = kinds[int(rng.integers(0, 3))]
        gpus = int(rng.choice([1, 2, 4, 8]))
        pods = int(rng.choice([1, 2, 4])) if gpus == 8 else 1
        jobs.append(Job(uid=i, tenant=f"t{i % 2}", gpu_type=0,
                        n_pods=pods, gpus_per_pod=gpus, kind=kind,
                        gang=(kind is JobKind.TRAIN),
                        priority=int(rng.choice([10, 50, 100])),
                        submit_time=float(arrivals[i]),
                        duration=float(rng.exponential(600.0) + 60.0)))
    return jobs


def _run(topo, qsch_kw, rsch):
    state = ClusterState.create(topo, inference_zone_nodes=4)
    qm = QuotaManager({"t0": {0: 10**6}, "t1": {0: 10**6}},
                      mode=QuotaMode.SHARED)
    qsch = QSCH(qm, rsch, **qsch_kw)
    sim = Simulator(state, qsch, SimConfig(tick_interval=30.0,
                                           sample_interval=120.0))
    return sim.run(_mixed_trace())


def _placement_fingerprint(result):
    return [(j.uid, j.state.value, j.start_time, j.requeue_count,
             None if j.placement is None else
             [(p.node, p.gpu_indices, p.nic) for p in j.placement.pods])
            for j in sorted(result.jobs, key=lambda j: j.uid)]


def test_default_profiles_equal_legacy_shim(topo):
    """Explicit default profiles == QSCHConfig/RSCHConfig shims, down to
    every pod's device indices."""
    legacy = _run(topo, dict(config=QSCHConfig(
        policy=QueuePolicy.BACKFILL, backfill_head_timeout=120.0)),
        RSCH(topo, RSCHConfig()))
    explicit = _run(
        topo,
        dict(queue_policy=BackfillPolicy(head_timeout=120.0)),
        RSCH(topo, profiles=default_profiles()))
    assert _placement_fingerprint(legacy) == _placement_fingerprint(explicit)
    assert (legacy.preemptions, legacy.requeues, legacy.infeasible) == \
        (explicit.preemptions, explicit.requeues, explicit.infeasible)


@pytest.mark.parametrize("tstrat,istrat", [
    (Strategy.BINPACK, Strategy.SPREAD),
    (Strategy.E_BINPACK, Strategy.E_SPREAD),
    (Strategy.E_SPREAD, Strategy.E_BINPACK),
])
def test_profiles_from_config_covers_every_strategy(topo, tstrat, istrat):
    cfg = RSCHConfig(train_strategy=tstrat, infer_strategy=istrat)
    legacy = _run(topo, dict(config=QSCHConfig()), RSCH(topo, cfg))
    explicit = _run(topo, dict(config=QSCHConfig()),
                    RSCH(topo, cfg, profiles=profiles_from_config(cfg)))
    assert _placement_fingerprint(legacy) == _placement_fingerprint(explicit)


# ----------------------------------------------------------------------
# Extension points
# ----------------------------------------------------------------------
def test_custom_queue_sort_reorders(topo, state):
    class LargestFirst(QueueSortPlugin):
        name = "LargestFirst"

        def key(self, job):
            return (-job.n_gpus, job.uid)

    profiles = default_profiles()
    profiles.train.queue_sort = LargestFirst()
    qsch = QSCH(QuotaManager({"t0": {0: 1024}}), RSCH(topo,
                profiles=profiles))
    qsch.submit(_job(1, gpus=2))
    qsch.submit(_job(2, gpus=8))
    qsch.submit(_job(3, gpus=4))
    assert [j.uid for j in qsch.pending_jobs()] == [2, 3, 1]


def test_custom_filter_restricts_pool(topo, state):
    class EvenNodesOnly(FilterPlugin):
        name = "EvenNodesOnly"

        def mask(self, job, snap, zone):
            return np.arange(snap.free_gpus.shape[0]) % 2 == 0

    profiles = default_profiles()
    base = profiles.train
    base.filters = base.filters + (EvenNodesOnly(),)
    rsch = RSCH(topo, profiles=profiles)
    for uid in range(4):
        snap = _snap(state)
        r = rsch.schedule(_job(uid, gpus=8), snap)
        assert r.placement is not None
        assert all(p.node % 2 == 0 for p in r.placement.pods)
        state.allocate(_job(uid, gpus=8), r.placement)


def test_filter_subclass_of_builtin_is_not_swallowed(topo, state):
    """A subclass of a built-in filter overriding mask() must go
    through the generic path, not the cached-pool fast path."""
    from repro.core.framework import HealthFilter

    class EvenHealthy(HealthFilter):
        name = "_EvenHealthy"

        def mask(self, job, snap, zone):
            even = np.arange(snap.free_gpus.shape[0]) % 2 == 0
            return snap.node_healthy & even

    profiles = default_profiles()
    profiles.train.filters = (
        profiles.train.filters[0],    # GpuTypeFilter
        EvenHealthy(),
    )
    rsch = RSCH(topo, profiles=profiles)
    r = rsch.schedule(_job(1, n_pods=4, gpus=8), _snap(state))
    assert r.placement is not None
    assert all(p.node % 2 == 0 for p in r.placement.pods)


def test_feasible_honors_custom_filter_chain(topo, state):
    """Dynamic admission must see the same pool placement does; a
    restrictive Filter plugin must not create an admit-pass /
    place-fail requeue loop."""
    class NothingFits(FilterPlugin):
        name = "_NothingFits"

        def mask(self, job, snap, zone):
            return np.zeros(snap.free_gpus.shape[0], dtype=bool)

    profiles = default_profiles()
    profiles.train.filters = profiles.train.filters + (NothingFits(),)
    rsch = RSCH(topo, profiles=profiles)
    assert not rsch.feasible(_job(1, gpus=8), _snap(state))
    qsch = QSCH(QuotaManager({"t0": {0: 1024}}), rsch)
    qsch.submit(_job(1, gpus=8))
    res = qsch.cycle(state, 0.0)
    assert res.scheduled == []
    assert res.infeasible == 1
    assert res.requeues == 0          # rejected at admission, not requeued
    assert qsch.pending_jobs()[0].requeue_count == 0


@pytest.mark.parametrize("batched", [True, False])
def test_custom_score_plugin_changes_placement(topo, state, batched):
    """An additive Score term flips the winner; batched and sequential
    engines agree on plugin-augmented scores."""
    class PreferNode(ScorePlugin):
        name = "_PreferNode"

        def __init__(self, node, weight=100.0):
            self.node = node
            self.weight = weight

        def score(self, job, snap, pool, ctx):
            term = np.zeros(snap.free_gpus.shape[0], dtype=np.float32)
            term[self.node] = self.weight
            return term

    # node 3 sits inside the preselected NodeNetGroup (nodes 0-3); the
    # extra term must beat binpack's default lowest-index pick (node 0).
    profiles = ProfileSet(
        train=make_profile("t", single_pass_plan(PlacementPass(
            scorers=(create_plugin("BinpackScore"), PreferNode(3))))),
        inference=make_profile("i", single_pass_plan(binpack_pass())),
        best_effort=make_profile("b", single_pass_plan(binpack_pass())),
    )
    rsch = RSCH(topo, RSCHConfig(batched_gang=batched), profiles=profiles)
    r = rsch.schedule(_job(1, gpus=4), _snap(state))
    assert r.placement.pods[0].node == 3


@pytest.mark.parametrize("n_pods,gpus", [(4, 8), (8, 4), (12, 2)])
def test_batched_matches_sequential_with_extra_scorer(topo, n_pods, gpus):
    """Parity of the two engines must survive non-fused score terms."""
    rng = np.random.default_rng(42)
    state = ClusterState.create(topo)
    for node in range(state.n_nodes):
        k = int(rng.integers(0, 7))
        if k:
            state.gpu_busy[node, :k] = True
    snap = _snap(state)
    job = _job(1, n_pods=n_pods, gpus=gpus)

    def mk(batched):
        profiles = ProfileSet(
            train=make_profile("t", single_pass_plan(ebinpack_pass(
                colocate=2.0, extra_scorers=(GfrAwareScore(weight=3.0),)))),
            inference=make_profile("i", single_pass_plan(spread_pass())),
            best_effort=make_profile("b", single_pass_plan(binpack_pass())),
        )
        return RSCH(topo, RSCHConfig(batched_gang=batched),
                    profiles=profiles)

    rb = mk(True).schedule(job, snap)
    rs = mk(False).schedule(job, snap)
    assert (rb.placement is None) == (rs.placement is None)
    if rb.placement is not None:
        assert [(p.node, p.gpu_indices) for p in rb.placement.pods] == \
            [(p.node, p.gpu_indices) for p in rs.placement.pods]


def test_custom_admit_plugin_rejects_and_counts(topo, state):
    class MaxSizeAdmit(AdmitPlugin):
        name = "_MaxSizeAdmit"
        stage = "static"

        def admit(self, job, ctx):
            return job.n_gpus <= 8

    profiles = default_profiles()
    for prof in (profiles.train, profiles.inference, profiles.best_effort):
        prof.admit = prof.admit + (MaxSizeAdmit(),)
    qsch = QSCH(QuotaManager({"t0": {0: 1024}}),
                RSCH(topo, profiles=profiles))
    qsch.submit(_job(1, n_pods=4, gpus=8))      # 32 GPUs: rejected
    qsch.submit(_job(2, gpus=8))                # admitted
    res = qsch.cycle(state, 0.0)
    assert [j.uid for j in res.scheduled] == [2]
    assert res.admit_rejected == 1
    assert qsch.queue_depth() == 1


def test_permit_veto_rolls_back_reservations(topo, state):
    events = []

    class SpyReserve(ReservePlugin):
        name = "_SpyReserve"

        def reserve(self, job, placement, ctx):
            events.append(("reserve", job.uid))
            return True

        def unreserve(self, job, placement, ctx):
            events.append(("unreserve", job.uid))

    class VetoAll(PermitPlugin):
        name = "_VetoAll"

        def permit(self, job, placement, ctx):
            return False

    profiles = default_profiles()
    profiles.train.reserve = profiles.train.reserve + (SpyReserve(),)
    profiles.train.permit = (VetoAll(),)
    qm = QuotaManager({"t0": {0: 1024}})
    qsch = QSCH(qm, RSCH(topo, profiles=profiles))
    qsch.submit(_job(1, gpus=8))
    res = qsch.cycle(state, 0.0)
    assert res.scheduled == []
    assert res.requeues == 1
    # transactional: quota charged then refunded, spy rolled back
    assert qm.tenant_used("t0", 0) == 0
    assert events == [("reserve", 1), ("unreserve", 1)]
    assert state.total_allocated() == 0
    job = qsch.pending_jobs()[0]
    assert job.requeue_count == 1 and job.state is JobState.PENDING


def test_post_bind_plugin_invoked(topo, state):
    bound = []

    class RecordBind(PostBindPlugin):
        name = "_RecordBind"

        def post_bind(self, job, placement, ctx):
            bound.append((job.uid, len(placement.pods)))

    profiles = default_profiles()
    profiles.train.post_bind = (RecordBind(),)
    qsch = QSCH(QuotaManager({"t0": {0: 1024}}),
                RSCH(topo, profiles=profiles))
    qsch.submit(_job(1, n_pods=2, gpus=8))
    res = qsch.cycle(state, 0.0)
    assert [j.uid for j in res.scheduled] == [1]
    assert bound == [(1, 2)]


# ----------------------------------------------------------------------
# Contrib plugins
# ----------------------------------------------------------------------
def test_gfr_aware_score_heals_fragmented_node(topo, state):
    # node 3 fragmented with an exact 4-GPU hole; node 0..: idle.
    state.gpu_busy[3, :4] = True
    profiles = ProfileSet(
        train=make_profile("t", single_pass_plan(PlacementPass(
            scorers=(create_plugin("SpreadScore"),
                     GfrAwareScore(weight=10.0))))),
        inference=make_profile("i", single_pass_plan(spread_pass())),
        best_effort=make_profile("b", single_pass_plan(spread_pass())),
    )
    rsch = RSCH(topo, profiles=profiles)
    r = rsch.schedule(_job(1, gpus=4), _snap(state))
    # Spread alone would avoid node 3; the GFR term overrides it.
    assert r.placement.pods[0].node == 3
    baseline = RSCH(topo, RSCHConfig(train_strategy=Strategy.SPREAD))
    rb = baseline.schedule(_job(1, gpus=4), _snap(state))
    assert rb.placement.pods[0].node != 3


def test_tenant_soft_affinity_groups_tenant(topo, state):
    rsch_default = RSCH(topo)
    # Tenant A runs a job in some group; an unrelated tenant too.
    running = {}
    for uid, tenant, node_hint in ((10, "a", None), (11, "b", None)):
        j = Job(uid=uid, tenant=tenant, gpu_type=0, n_pods=1,
                gpus_per_pod=2, kind=JobKind.TRAIN)
        r = rsch_default.schedule(j, _snap(state))
        state.allocate(j, r.placement)
        j.placement = r.placement
        running[uid] = j
    group_of = {j.tenant: int(topo.leaf_id[j.placement.pods[0].node])
                for j in running.values()}

    affinity = TenantSoftAffinity(topo, weight=50.0, anti_weight=50.0)
    profiles = ProfileSet(
        train=make_profile("t", single_pass_plan(PlacementPass(
            scorers=(create_plugin("SpreadScore"), affinity)))),
        inference=make_profile("i", single_pass_plan(spread_pass())),
        best_effort=make_profile("b", single_pass_plan(spread_pass())),
    )
    rsch = RSCH(topo, profiles=profiles)
    ctx = SchedulingContext(running=running)
    ra = rsch.schedule(_job(1, gpus=2, tenant="a"), _snap(state), ctx)
    assert int(topo.leaf_id[ra.placement.pods[0].node]) == group_of["a"]
    # And without context the term vanishes (no crash, spread behavior).
    rn = rsch.schedule(_job(2, gpus=2, tenant="a"), _snap(state))
    assert rn.placement is not None


# ----------------------------------------------------------------------
# Counters (admission-rejection / requeue accounting)
# ----------------------------------------------------------------------
def test_cycle_counters_quota_and_infeasible(topo, state):
    qsch = make_qsch(topo, state, quota={"t0": {0: 8}})
    qsch.submit(_job(1, gpus=8))
    qsch.submit(_job(2, gpus=8))      # over quota -> admit_rejected
    res = qsch.cycle(state, 0.0)
    assert [j.uid for j in res.scheduled] == [1]
    assert res.admit_rejected == 1

    qsch2 = make_qsch(topo, state)
    qsch2.submit(_job(3, n_pods=32, gpus=8))   # 32 nodes > cluster
    res2 = qsch2.cycle(state, 0.0)
    assert res2.scheduled == []
    assert res2.infeasible >= 1


def test_sim_result_aggregates_counters(topo):
    state = ClusterState.create(topo)
    qm = QuotaManager({"t0": {0: 32}})       # tight quota forces waits
    qsch = QSCH(qm, RSCH(topo), QSCHConfig())
    sim = Simulator(state, qsch, SimConfig(tick_interval=30.0,
                                           sample_interval=300.0))
    jobs = [_job(uid, gpus=8, t=float(uid)) for uid in range(8)]
    for j in jobs:
        j.duration = 120.0
    result = sim.run(jobs)
    assert all(j.state is JobState.COMPLETED for j in result.jobs)
    assert result.admit_rejected > 0      # quota made some jobs wait
    assert result.requeues == 0
