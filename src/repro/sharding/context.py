"""Activation-sharding hints for the model code.

``jax.jit`` in/out shardings constrain only the step boundary; inside a
scanned layer body XLA's propagation is free to pick batch-replicated,
weight-stationary strategies (it does, catastrophically — see DESIGN.md
§4).  Real frameworks pin activations with ``with_sharding_constraint``;
this module provides that without coupling the model code to a mesh:

* launchers/dry-run install an :class:`ActivationSharding` via
  ``use_activation_sharding`` around tracing;
* model code calls :func:`constrain` with a *logical* spec such as
  ``("batch", None, "model", None)``;
* with no context installed (CPU unit tests), ``constrain`` is a no-op;
* axes that do not divide the corresponding dim fall back to ``None``
  (e.g. 25 hymba heads on a 16-way ``model`` axis).
"""

from __future__ import annotations

import contextlib
import threading
from typing import Any, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = threading.local()

Logical = Union[None, str, Tuple[str, ...]]


class ActivationSharding:
    def __init__(self, mesh: Mesh, seq_shard: bool = False) -> None:
        self.mesh = mesh
        self.sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
        batch = tuple(a for a in ("pod", "data") if a in self.sizes)
        # "seq" is the Megatron-style sequence-parallel hint: layer-boundary
        # activations (and their remat-saved residuals) shard S over
        # ``model`` when enabled, else the hint resolves to replicated.
        self.logical = {"batch": batch, "model": ("model",),
                        "seq": ("model",) if seq_shard else ()}

    def resolve(self, dim: int, logical: Logical) -> Optional[Tuple[str, ...]]:
        if logical is None:
            return None
        axes = self.logical.get(logical, (logical,)) \
            if isinstance(logical, str) else logical
        if not axes:
            return None
        # Longest prefix of the axis tuple that divides the dim.
        for k in range(len(axes), 0, -1):
            prod = int(np.prod([self.sizes[a] for a in axes[:k]]))
            if dim % prod == 0 and dim >= prod:
                return tuple(axes[:k])
        return None


@contextlib.contextmanager
def use_activation_sharding(mesh: Optional[Mesh], seq_shard: bool = False):
    prev = getattr(_STATE, "ctx", None)
    _STATE.ctx = (ActivationSharding(mesh, seq_shard=seq_shard)
                  if mesh is not None else None)
    try:
        yield
    finally:
        _STATE.ctx = prev


def current() -> Optional[ActivationSharding]:
    return getattr(_STATE, "ctx", None)


def axis_size(name: str) -> int:
    """Mesh size of a logical axis under the installed context (1 if no
    context) — lets model code pick between equivalent layouts, e.g.
    head-sharded vs q-sequence-sharded attention chunks."""
    ctx = current()
    if ctx is None:
        return 1
    axes = ctx.logical.get(name, (name,))
    size = 1
    for a in axes:
        size *= ctx.sizes.get(a, 1)
    return size


def constrain(x: jax.Array, spec: Sequence[Logical]) -> jax.Array:
    """Pin ``x`` to a logical sharding if a context is installed."""
    ctx = current()
    if ctx is None:
        return x
    if len(spec) != x.ndim:
        raise ValueError(f"spec rank {len(spec)} != array rank {x.ndim}")
    parts = [ctx.resolve(int(d), s) for d, s in zip(x.shape, spec)]
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, P(*parts)))
