"""Rule-based auto-sharder (FSDP × TP × EP) for the model zoo."""

from .auto import (batch_axes, batch_specs, cache_specs_sharding,
                   param_shardings, partition_spec, ShardingRules)

__all__ = ["batch_axes", "batch_specs", "cache_specs_sharding",
           "param_shardings", "partition_spec", "ShardingRules"]
