"""Deterministic rule-based auto-sharder.

Maps every parameter / input / cache leaf to a ``PartitionSpec`` over the
production mesh (``data``, ``model``[, ``pod``]):

* **FSDP** — a weight dim is sharded over ``data`` (gathered on use);
* **TP**   — heads / d_ff / vocab dims are sharded over ``model``;
* **EP**   — MoE expert dims go on ``model`` when divisible (expert
  parallelism: the scatter/gather dispatch lowers to an all-to-all);
* **batch** — activations shard batch over (``pod``, ``data``).

Rules are matched by path regex and tried in priority order; any dim that
fails the divisibility check falls back down the candidate list and
ultimately to replication.  The table is deterministic and unit-tested
(``tests/test_sharding.py``).

Rationale for the non-obvious fallbacks (see DESIGN.md §4):

* GQA caches: KV-head counts (8, 5, 2, 1) rarely divide the 16-way
  ``model`` axis, so the KV cache falls back to sharding the *window*
  dim — the ring-buffer ``dynamic_update_slice`` then crosses shards,
  which XLA SPMD handles (baseline; §Perf iterates on this);
* small KV projections (MQA kv=1): shard ``head_dim`` over ``model``
  instead, accepting an all-reduce on the scores.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any

# (path regex, [(dim, axis), ...]) — dims are negative (counted from the
# end) so the same rule covers stacked (leading-L) and unstacked leaves.
PARAM_RULES: List[Tuple[str, List[Tuple[int, str]]]] = [
    # embeddings / head
    (r"(^|/)embed$",            [(-2, "model"), (-1, "data")]),
    (r"(^|/)lm_head$",          [(-1, "model"), (-2, "data")]),
    # attention (decoder, cross, encoder)
    (r"(attn|xattn)/wq$",       [(-2, "model"), (-3, "data")]),
    # NOTE: no head_dim fallback for K/V — contracting a model-sharded
    # head_dim in the score einsum would force a (B,S,H,S)-sized
    # all-reduce per KV block.  Small-KV archs replicate K/V heads.
    (r"(attn|xattn)/w[kv]$",    [(-2, "model"), (-3, "data")]),
    (r"(attn|xattn)/wo$",       [(-3, "model"), (-1, "data")]),
    # dense / shared MLP
    (r"mlp/w_(gate|up)$",       [(-1, "model"), (-2, "data")]),
    (r"mlp/w_down$",            [(-2, "model"), (-1, "data")]),
    # MoE — expert dim first (EP), then d_ff (TP), then FSDP
    (r"moe/router$",            [(-1, "model"), (-2, "data")]),
    (r"moe/w_(gate|up)$",       [(-3, "model"), (-1, "model"),
                                 (-2, "data")]),
    (r"moe/w_down$",            [(-3, "model"), (-2, "model"),
                                 (-1, "data")]),
    # rwkv6 time-mix / channel-mix (flat block: layers/wr etc.)
    (r"layers/w[rkvg]$",        [(-1, "model"), (-2, "data")]),
    (r"layers/wo$",             [(-2, "model"), (-1, "data")]),
    (r"layers/ck$",             [(-1, "model"), (-2, "data")]),
    (r"layers/cv$",             [(-2, "model"), (-1, "data")]),
    (r"layers/cr$",             [(-1, "model"), (-2, "data")]),
    (r"decay_[ab]$",            []),
    # hymba SSM branch
    (r"ssm/w_in$",              [(-1, "model"), (-2, "data")]),
    (r"ssm/w_out$",             [(-2, "model"), (-1, "data")]),
    (r"ssm/w_bc$",              [(-2, "data")]),
    (r"ssm/w_dt2?$",            [(-2, "data")]),
    # everything else (norms, mu, decay_base, bonus_u, a_log, d_skip):
    # replicated — they are O(d_model) vectors.
]


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Resolved mesh-axis sizes + the rule table (swappable for §Perf)."""

    mesh: Mesh
    rules: Sequence[Tuple[str, List[Tuple[int, str]]]] = tuple(PARAM_RULES)

    @property
    def axis_sizes(self) -> Dict[str, int]:
        return dict(zip(self.mesh.axis_names, self.mesh.axis_sizes))


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Mesh axes the global batch is sharded over."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _apply_candidates(shape: Sequence[int], cands: List[Tuple[int, str]],
                      sizes: Dict[str, int]) -> P:
    spec: List[Optional[str]] = [None] * len(shape)
    used_axes = set()
    for dim, axis in cands:
        if axis not in sizes or axis in used_axes:
            continue
        if dim < -len(shape):
            continue
        if spec[dim] is not None:
            continue
        if shape[dim] % sizes[axis] != 0 or shape[dim] < sizes[axis]:
            continue
        spec[dim] = axis
        used_axes.add(axis)
    return P(*spec)


def partition_spec(path: str, shape: Sequence[int],
                   rules: ShardingRules) -> P:
    sizes = rules.axis_sizes
    for pattern, cands in rules.rules:
        if re.search(pattern, path):
            return _apply_candidates(shape, cands, sizes)
    return P()        # replicate by default (norm scales etc.)


def _tree_paths(tree: PyTree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    for keypath, leaf in flat:
        path = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in keypath)
        yield path, leaf
    return


def param_shardings(params: PyTree, rules: ShardingRules) -> PyTree:
    """NamedSharding tree matching a parameter (or spec) tree."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for keypath, leaf in flat:
        path = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in keypath)
        spec = partition_spec(path, leaf.shape, rules)
        out.append(NamedSharding(rules.mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)


def _batch_dim_spec(n: int, mesh: Mesh) -> Optional[Tuple[str, ...]]:
    axes = batch_axes(mesh)
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    # Use the largest prefix of (pod, data) that divides the batch.
    for k in range(len(axes), 0, -1):
        prod = int(np.prod([sizes[a] for a in axes[:k]]))
        if n % prod == 0 and n >= prod:
            return axes[:k]
    return None


def batch_specs(batch: PyTree, rules: ShardingRules) -> PyTree:
    """Shard every batch leaf over its leading (batch) dim."""
    mesh = rules.mesh

    def one(leaf):
        b = _batch_dim_spec(leaf.shape[0], mesh)
        spec = [b] + [None] * (len(leaf.shape) - 1)
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, batch)


def cache_specs_sharding(cache: PyTree, rules: ShardingRules) -> PyTree:
    """KV/SSM cache sharding.

    Layer caches are stacked: (L, B, W, Kh, hd) for k/v, (L, B, ...) for
    SSM states, plus scalars.  Batch goes over (pod, data); the KV head
    dim over ``model`` when divisible, else the window dim, else
    replicated.
    """
    mesh = rules.mesh
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    m = sizes.get("model", 1)
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    out = []
    for keypath, leaf in flat:
        path = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in keypath)
        shp = leaf.shape
        if len(shp) == 0:                      # the step counter
            out.append(NamedSharding(mesh, P()))
            continue
        spec: List[Any] = [None] * len(shp)
        # Leading dim is L (stacked layers) for layer caches / memory.
        bdim = 1 if len(shp) >= 2 else 0
        spec[bdim] = _batch_dim_spec(shp[bdim], mesh)
        if re.search(r"(^|/)(k|v|mk|mv)$", path) and len(shp) == 5:
            L, B, W, Kh, hd = shp
            if Kh % m == 0 and Kh >= m:
                spec[3] = "model"
            elif W % m == 0 and W >= m:
                spec[2] = "model"
        elif re.search(r"/(ssm|state)$", path) and len(shp) >= 4:
            # (L,B,d,N) or (L,B,H,n,n): shard the channel/head dim.
            if shp[2] % m == 0 and shp[2] >= m:
                spec[2] = "model"
        out.append(NamedSharding(mesh, P(*spec)))
    return jax.tree_util.tree_unflatten(treedef, out)
