"""Run reporter: telemetry bundle -> markdown (or JSON) summary.

Usage::

    python -m repro.obs.report run_telemetry.json            # md to stdout
    python -m repro.obs.report run_telemetry.json -o run.md
    python -m repro.obs.report run_telemetry.json --format json -o run.json

Input is the bundle written by
:meth:`repro.obs.telemetry.Telemetry.save`.  The report has five
sections: run summary, metric series (last/mean/min/max per labeled
series), cycle-phase wall-time breakdown, the top-N jobs by queue wait,
and the failure/interrupt/reshape timeline, plus the decision-audit
summary when the audit pillar was on.
"""

from __future__ import annotations

import argparse
import json
import math
import sys
from typing import Dict, List, Optional

__all__ = ["build_report", "render_markdown", "main"]

TOP_JOBS = 10


def _series_stats(samples: List[List[float]]) -> Dict[str, float]:
    values = [v for _, v in samples]
    if not values:
        return {"last": math.nan, "mean": math.nan, "min": math.nan,
                "max": math.nan, "n": 0}
    return {"last": values[-1], "mean": sum(values) / len(values),
            "min": min(values), "max": max(values), "n": len(values)}


def _fmt_labels(labels: Dict[str, str]) -> str:
    if not labels:
        return ""
    return "{" + ",".join(f'{k}="{v}"'
                          for k, v in sorted(labels.items())) + "}"


def _num(x: float) -> str:
    if x != x:                     # NaN
        return "-"
    if x == int(x) and abs(x) < 1e15:
        return str(int(x))
    return f"{x:.4g}"


def build_report(bundle: Dict) -> Dict[str, object]:
    """Structured report (the ``--format json`` output)."""
    meta = bundle.get("meta", {})
    jobs = bundle.get("jobs", [])
    phase_totals = bundle.get("phase_totals", {})

    metrics = []
    for name, fam in sorted(bundle.get("metrics", {}).items()):
        for s in fam.get("series", []):
            metrics.append({
                "metric": name,
                "type": fam.get("type", ""),
                "labels": s.get("labels", {}),
                **_series_stats(s.get("samples", [])),
            })

    waited = [j for j in jobs if j.get("wait_s") is not None]
    waited.sort(key=lambda j: (-j["wait_s"], j["uid"]))

    timeline = []
    trace = bundle.get("trace", {})
    for ev in trace.get("traceEvents", []):
        if ev.get("ph") == "i":
            timeline.append({"t_s": ev["ts"] / 1e6, "event": ev["name"],
                             "args": ev.get("args", {})})
    timeline.sort(key=lambda e: e["t_s"])

    completed = [j for j in jobs if j.get("end_t") is not None]
    report: Dict[str, object] = {
        "meta": meta,
        "summary": {
            "sim_end_t": meta.get("sim_end_t"),
            "jobs_seen": len(jobs),
            "jobs_completed": len(completed),
            "interrupts": sum(j.get("interrupts", 0) for j in jobs),
            "reshapes": sum(j.get("reshapes", 0) for j in jobs),
            "preemptions": sum(j.get("preemptions", 0) for j in jobs),
            "events": bundle.get("events", {}),
        },
        "metrics": metrics,
        "phases": dict(sorted(phase_totals.items(),
                              key=lambda kv: -kv[1])),
        "top_wait_jobs": waited[:TOP_JOBS],
        "timeline": timeline,
    }
    if "audit" in bundle:
        report["audit"] = bundle["audit"].get("summary", {})
    return report


def render_markdown(report: Dict) -> str:
    out: List[str] = ["# Run telemetry report", ""]
    s = report["summary"]
    out += ["## Summary", ""]
    out += [f"- simulated end time: **{_num(float(s['sim_end_t'] or 0))} s**",
            f"- jobs seen: **{s['jobs_seen']}** "
            f"(completed: {s['jobs_completed']})",
            f"- interrupts: {s['interrupts']}  ·  reshapes: "
            f"{s['reshapes']}  ·  preemptions: {s['preemptions']}"]
    if s.get("events"):
        ev = ", ".join(f"{k}={v}" for k, v in sorted(s["events"].items()))
        out.append(f"- bus events: {ev}")
    out.append("")

    if report.get("metrics"):
        out += ["## Metrics", "",
                "| metric | labels | last | mean | min | max | n |",
                "|---|---|---:|---:|---:|---:|---:|"]
        for m in report["metrics"]:
            out.append(
                f"| `{m['metric']}` | `{_fmt_labels(m['labels'])}` "
                f"| {_num(m['last'])} | {_num(m['mean'])} "
                f"| {_num(m['min'])} | {_num(m['max'])} | {m['n']} |")
        out.append("")

    if report.get("phases"):
        total = sum(report["phases"].values()) or 1.0
        out += ["## Cycle-phase wall time", "",
                "| phase | total s | share |", "|---|---:|---:|"]
        for name, sec in report["phases"].items():
            out.append(f"| {name} | {sec:.6f} | {100 * sec / total:.1f}% |")
        out.append("")

    if report.get("top_wait_jobs"):
        out += [f"## Top {TOP_JOBS} jobs by queue wait", "",
                "| uid | tenant | kind | gpus | wait s | binds "
                "| interrupts |", "|---:|---|---|---:|---:|---:|---:|"]
        for j in report["top_wait_jobs"]:
            out.append(
                f"| {j['uid']} | {j['tenant']} | {j['kind']} "
                f"| {j['n_gpus']} | {_num(j['wait_s'])} | {j['binds']} "
                f"| {j['interrupts']} |")
        out.append("")

    if report.get("timeline"):
        out += ["## Failure / preemption / reshape timeline", "",
                "| t (s) | event | details |", "|---:|---|---|"]
        for e in report["timeline"][:200]:
            args = ", ".join(f"{k}={v}" for k, v in e["args"].items())
            out.append(f"| {_num(e['t_s'])} | {e['event']} | {args} |")
        if len(report["timeline"]) > 200:
            out.append(f"| … | {len(report['timeline']) - 200} more | |")
        out.append("")

    if report.get("audit"):
        a = report["audit"]
        out += ["## Decision audit", "",
                f"- decisions: {a.get('decisions', 0)} "
                f"(bound {a.get('bound', 0)}, "
                f"rejected {a.get('rejected', 0)})",
                f"- preemptions: {a.get('preemptions', 0)}"]
        reasons = a.get("rejections_by_reason") or {}
        if reasons:
            body = ", ".join(f"{k}: {v}"
                             for k, v in sorted(reasons.items()))
            out.append(f"- rejections by reason: {body}")
        out.append("")

    return "\n".join(out)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Render a telemetry bundle as markdown or JSON.")
    ap.add_argument("bundle", help="bundle written by Telemetry.save()")
    ap.add_argument("--format", choices=("md", "json"), default="md")
    ap.add_argument("-o", "--output", default=None,
                    help="output path (default: stdout)")
    args = ap.parse_args(argv)

    with open(args.bundle) as f:
        bundle = json.load(f)
    report = build_report(bundle)
    text = (json.dumps(report, indent=2, default=float)
            if args.format == "json" else render_markdown(report))
    if args.output:
        with open(args.output, "w") as f:
            f.write(text)
    else:
        sys.stdout.write(text + ("\n" if not text.endswith("\n") else ""))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
