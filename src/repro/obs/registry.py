"""Prometheus-style metric registry with ring-buffered time series.

Three metric types — :class:`Counter` (monotone), :class:`Gauge`
(set-to-value) and :class:`Histogram` (bucketed observations) — each
addressable by name + label set, exactly like the Prometheus data
model.  Every write also appends ``(t, value)`` to a bounded ring
buffer per labeled series, so a run keeps a live *series* (what the
ROADMAP's self-tuning controller will consume) and not just a final
scalar.

Time comes from a settable **clock**: the attached
:class:`~repro.obs.telemetry.Telemetry` points it at the simulator's
event time, so series are in simulated seconds; standalone users can
leave the default 0-clock or set their own.

Exposition is dual: :meth:`MetricRegistry.expose_text` emits the
Prometheus text format (``# HELP`` / ``# TYPE`` / samples, histogram
``_bucket``/``_sum``/``_count`` with cumulative ``le`` buckets) and
:meth:`MetricRegistry.to_json` a JSON document including the ring
series — the part the text format has no room for.

Pull-model **collectors** (:meth:`MetricRegistry.add_collector`) let
subsystems that keep their own counters (serving pools, combo caches,
the dynamics engine) publish on demand: ``collect()`` runs every
registered callable right before exposition.
"""

from __future__ import annotations

import collections
import json
from typing import Callable, Dict, List, Optional, Sequence, Tuple

__all__ = ["Counter", "Gauge", "Histogram", "Metric", "MetricRegistry",
           "DEFAULT_BUCKETS"]

#: Default histogram bucket upper bounds (seconds-flavored, like
#: Prometheus' defaults but extended for queue-wait scales).
DEFAULT_BUCKETS: Tuple[float, ...] = (
    0.001, 0.005, 0.01, 0.05, 0.1, 0.5, 1.0, 5.0, 10.0, 30.0, 60.0,
    300.0, 900.0, 3600.0, 14400.0)

LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: Dict[str, object]) -> LabelKey:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _fmt_labels(key: LabelKey, extra: Tuple[Tuple[str, str], ...] = ()
                ) -> str:
    items = key + extra
    if not items:
        return ""
    body = ",".join(f'{k}="{v}"' for k, v in items)
    return "{" + body + "}"


class _Series:
    """One labeled series: current value + bounded (t, value) ring."""

    __slots__ = ("value", "ring")

    def __init__(self, ring: int) -> None:
        self.value = 0.0
        self.ring: collections.deque = collections.deque(maxlen=ring)

    def record(self, t: float, value: float) -> None:
        self.value = value
        self.ring.append((t, value))


class Metric:
    """Base: a named family of labeled series."""

    type_name = "untyped"

    def __init__(self, name: str, help: str, registry: "MetricRegistry"
                 ) -> None:
        self.name = name
        self.help = help
        self._registry = registry
        self._series: Dict[LabelKey, _Series] = {}

    def _get(self, labels: Dict[str, object]) -> _Series:
        key = _label_key(labels)
        s = self._series.get(key)
        if s is None:
            s = self._series[key] = _Series(self._registry.ring)
            # Stable exposition order: keep insertion order per family.
        return s

    def series(self, **labels) -> List[Tuple[float, float]]:
        """The ring-buffered (t, value) series for one label set."""
        return list(self._get(labels).ring)

    def value(self, **labels) -> float:
        return self._get(labels).value

    def label_sets(self) -> List[Dict[str, str]]:
        return [dict(k) for k in self._series]

    # -- exposition ----------------------------------------------------
    def expose(self) -> List[str]:
        lines = [f"# HELP {self.name} {self.help}",
                 f"# TYPE {self.name} {self.type_name}"]
        for key, s in self._series.items():
            lines.append(f"{self.name}{_fmt_labels(key)} {s.value:g}")
        return lines

    def to_json(self) -> Dict[str, object]:
        return {
            "type": self.type_name,
            "help": self.help,
            "series": [{"labels": dict(key), "value": s.value,
                        "samples": [[t, v] for t, v in s.ring]}
                       for key, s in self._series.items()],
        }


class Counter(Metric):
    """Monotone counter: ``inc`` only (negative increments rejected)."""

    type_name = "counter"

    def inc(self, amount: float = 1.0, **labels) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name} cannot decrease")
        s = self._get(labels)
        s.record(self._registry.now(), s.value + amount)


class Gauge(Metric):
    """Set-to-current-value metric."""

    type_name = "gauge"

    def set(self, value: float, **labels) -> None:
        self._get(labels).record(self._registry.now(), float(value))

    def inc(self, amount: float = 1.0, **labels) -> None:
        s = self._get(labels)
        s.record(self._registry.now(), s.value + amount)


class _HistSeries(_Series):
    __slots__ = ("counts", "sum", "count")

    def __init__(self, ring: int, n_buckets: int) -> None:
        super().__init__(ring)
        self.counts = [0] * (n_buckets + 1)   # +inf bucket last
        self.sum = 0.0
        self.count = 0


class Histogram(Metric):
    """Bucketed observations with Prometheus cumulative exposition.

    ``buckets`` are the **upper bounds** of the non-cumulative bins;
    an implicit ``+Inf`` bucket catches the tail.  Bucket assignment is
    ``value <= bound`` (Prometheus ``le`` semantics) — asserted against
    a ``np.histogram`` reference in ``tests/test_obs.py``."""

    type_name = "histogram"

    def __init__(self, name: str, help: str, registry: "MetricRegistry",
                 buckets: Sequence[float] = DEFAULT_BUCKETS) -> None:
        super().__init__(name, help, registry)
        self.buckets: Tuple[float, ...] = tuple(sorted(float(b)
                                                       for b in buckets))

    def _get(self, labels: Dict[str, object]) -> _HistSeries:
        key = _label_key(labels)
        s = self._series.get(key)
        if s is None:
            s = self._series[key] = _HistSeries(self._registry.ring,
                                                len(self.buckets))
        return s  # type: ignore[return-value]

    def observe(self, value: float, **labels) -> None:
        s = self._get(labels)
        value = float(value)
        i = 0
        for i, bound in enumerate(self.buckets):
            if value <= bound:
                break
        else:
            i = len(self.buckets)
        s.counts[i] += 1
        s.sum += value
        s.count += 1
        s.record(self._registry.now(), value)

    def cumulative(self, **labels) -> List[int]:
        """Cumulative counts per ``le`` bound (+Inf last)."""
        s = self._get(labels)
        out, acc = [], 0
        for c in s.counts:
            acc += c
            out.append(acc)
        return out

    def expose(self) -> List[str]:
        lines = [f"# HELP {self.name} {self.help}",
                 f"# TYPE {self.name} {self.type_name}"]
        for key, s in self._series.items():
            acc = 0
            for bound, c in zip(self.buckets, s.counts):
                acc += c
                lines.append(f"{self.name}_bucket"
                             f"{_fmt_labels(key, (('le', f'{bound:g}'),))}"
                             f" {acc}")
            acc += s.counts[-1]
            lines.append(f"{self.name}_bucket"
                         f"{_fmt_labels(key, (('le', '+Inf'),))} {acc}")
            lines.append(f"{self.name}_sum{_fmt_labels(key)} {s.sum:g}")
            lines.append(f"{self.name}_count{_fmt_labels(key)} {s.count}")
        return lines

    def to_json(self) -> Dict[str, object]:
        out = super().to_json()
        out["buckets"] = list(self.buckets)
        for entry, (key, s) in zip(out["series"], self._series.items()):
            entry["counts"] = list(s.counts)
            entry["sum"] = s.sum
            entry["count"] = s.count
        return out


class MetricRegistry:
    """Name -> metric family store with collectors and a settable clock."""

    def __init__(self, ring: int = 512,
                 clock: Optional[Callable[[], float]] = None) -> None:
        self.ring = int(ring)
        self._clock: Callable[[], float] = clock or (lambda: 0.0)
        self._metrics: Dict[str, Metric] = {}
        self._collectors: List[Callable[["MetricRegistry"], None]] = []

    # -- clock ---------------------------------------------------------
    def set_clock(self, clock: Callable[[], float]) -> None:
        self._clock = clock

    def now(self) -> float:
        return float(self._clock())

    # -- families ------------------------------------------------------
    def _family(self, cls, name: str, help: str, **kw) -> Metric:
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = cls(name, help, self, **kw)
        elif not isinstance(m, cls):
            raise TypeError(f"metric {name!r} already registered as "
                            f"{m.type_name}")
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._family(Counter, name, help)  # type: ignore

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._family(Gauge, name, help)  # type: ignore

    def histogram(self, name: str, help: str = "",
                  buckets: Sequence[float] = DEFAULT_BUCKETS
                  ) -> Histogram:
        return self._family(Histogram, name, help,  # type: ignore
                            buckets=buckets)

    def get(self, name: str) -> Optional[Metric]:
        return self._metrics.get(name)

    def names(self) -> List[str]:
        return list(self._metrics)

    # -- collectors ----------------------------------------------------
    def add_collector(self, fn: Callable[["MetricRegistry"], None]
                      ) -> None:
        self._collectors.append(fn)

    def collect(self) -> None:
        for fn in self._collectors:
            fn(self)

    # -- exposition ----------------------------------------------------
    def expose_text(self, collect: bool = True) -> str:
        if collect:
            self.collect()
        lines: List[str] = []
        for m in self._metrics.values():
            lines.extend(m.expose())
        return "\n".join(lines) + "\n"

    def to_json(self, collect: bool = True) -> Dict[str, object]:
        if collect:
            self.collect()
        return {name: m.to_json() for name, m in self._metrics.items()}

    def dumps(self) -> str:
        return json.dumps(self.to_json(), indent=2, sort_keys=True)
