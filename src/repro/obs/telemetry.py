"""The Telemetry facade: one attach point for all four pillars.

``Telemetry`` owns a :class:`~repro.obs.registry.MetricRegistry`, a
:class:`~repro.obs.trace.Tracer` and the observer chain (including the
built-in :class:`~repro.obs.audit.DecisionAudit`), and wires them into
a simulator with one call::

    tel = Telemetry()
    sim = Simulator(state, qsch, cfg)
    tel.attach(sim)
    result = sim.run(jobs)
    tel.save("run_telemetry.json")        # full bundle
    tel.save_trace("run_trace.json")      # Perfetto-loadable trace

``attach`` sets the duck-typed ``obs`` attribute on the QSCH, RSCH and
MetricsRecorder and installs the EventBus tap — the *only* coupling the
core has to this package.  With no telemetry attached every ``obs`` is
``None`` and the pipeline is byte-identical to an untelemetered build
(gated in ``benchmarks/obs_bench.py``); attached overhead is budgeted
at ≤5% per cycle at 10k nodes by the same benchmark.

A federation attaches one Telemetry to every member simulator with a
*scope*::

    tel = Telemetry()
    fed_sim.attach_telemetry(tel)   # scope = member name per member

Scoped streams label registry series with ``member=...``, run one
scheduler trace lane per member, and stamp decisions with the member
name.

Time domains: the registry clock and job/cluster trace events run on
**simulated** time; cycle spans are **wall-clock** (that is what "where
does scheduling CPU go" means).  See :mod:`repro.obs.trace`.
"""

from __future__ import annotations

import dataclasses
import json
import time
from typing import Dict, List, Optional, Sequence

from ..core.events import EventKind
from ..launch.combo_cache import cache_stats
from .audit import DecisionAudit, PreemptionRecord, build_decision
from .registry import MetricRegistry
from .trace import PID_CLUSTER, PID_JOBS, PID_SCHED, Tracer

__all__ = ["Telemetry", "CycleSpan", "JobRecord"]

#: Histogram buckets for per-cycle wall time (seconds).
_CYCLE_BUCKETS = (1e-5, 3e-5, 1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 3e-2, 0.1,
                  0.3, 1.0)


@dataclasses.dataclass
class CycleSpan:
    """One QSCH cycle as observers see it (the Tick tap payload)."""

    t: float                      # simulated cycle time
    wall_s: float                 # wall-clock duration
    phases: Dict[str, float]      # phase -> wall seconds
    scope: Optional[str]
    result: object                # framework.api.CycleResult


@dataclasses.dataclass
class JobRecord:
    """Per-job lifecycle summary accumulated from the hooks."""

    uid: int
    tenant: str
    kind: str
    n_gpus: int
    submit_t: Optional[float] = None
    first_start: Optional[float] = None
    end_t: Optional[float] = None
    binds: int = 0
    interrupts: int = 0
    reshapes: int = 0
    preemptions: int = 0
    scope: Optional[str] = None
    _span_open: bool = False

    @property
    def wait_s(self) -> Optional[float]:
        if self.submit_t is None or self.first_start is None:
            return None
        return self.first_start - self.submit_t

    def as_dict(self) -> Dict[str, object]:
        d = dataclasses.asdict(self)
        d.pop("_span_open", None)
        d["wait_s"] = self.wait_s
        return d


class _PhaseTimer:
    """Context manager accumulating one pipeline phase's wall time."""

    __slots__ = ("tel", "scope", "name", "_t0")

    def __init__(self, tel: "Telemetry", scope: Optional[str],
                 name: str) -> None:
        self.tel = tel
        self.scope = scope
        self.name = name

    def __enter__(self) -> "_PhaseTimer":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        self.tel._phase_done(self.scope, self.name,
                             time.perf_counter() - self._t0)


class _ScopedTelemetry:
    """Thin per-member adapter: the same obs interface, scope-bound."""

    def __init__(self, tel: "Telemetry", scope: str) -> None:
        self._tel = tel
        self._scope = scope

    @property
    def audit_on(self) -> bool:
        return self._tel.audit_on

    def phase(self, name: str) -> _PhaseTimer:
        return self._tel._timer(self._scope, name)

    def cycle_begin(self, now: float) -> None:
        self._tel.cycle_begin(now, scope=self._scope)

    def cycle_end(self, result, ctx) -> None:
        self._tel.cycle_end(result, ctx, scope=self._scope)

    def emit_bind(self, job, sched, ctx) -> None:
        self._tel.emit_bind(job, sched, ctx, scope=self._scope)

    def emit_reject(self, job, sched, ctx, reason: str) -> None:
        self._tel.emit_reject(job, sched, ctx, reason, scope=self._scope)

    def emit_preempt(self, victim, ctx, source) -> None:
        self._tel.emit_preempt(victim, ctx, source, scope=self._scope)

    def on_bus_event(self, event) -> None:
        self._tel.on_bus_event(event, scope=self._scope)

    def on_sample(self, sample) -> None:
        self._tel.on_sample(sample, scope=self._scope)

    def on_job_placed(self, job, now) -> None:
        self._tel.on_job_placed(job, now, scope=self._scope)

    def on_job_finished(self, job) -> None:
        self._tel.on_job_finished(job, scope=self._scope)

    def on_job_interrupted(self, job, t, lost, overhead, reshape) -> None:
        self._tel.on_job_interrupted(job, t, lost, overhead, reshape,
                                     scope=self._scope)

    def on_param_change(self, change) -> None:
        self._tel.on_param_change(change, scope=self._scope)

    def finalize_run(self, sim) -> None:
        self._tel.finalize_run(sim, scope=self._scope)


class Telemetry:
    """Unified telemetry: metric registry + tracing + decision audit.

    ``registry`` / ``tracing`` / ``audit`` toggle the pillars (each
    ``False`` drops that pillar's cost entirely); ``observers`` adds
    custom :class:`~repro.core.framework.api.ObserverPlugin` instances
    behind the built-in audit.
    """

    def __init__(self, registry: bool = True, tracing: bool = True,
                 audit: bool = True, observers: Sequence = (),
                 ring: int = 512, max_trace_events: int = 500_000,
                 audit_max_records: int = 20_000) -> None:
        self._simclock = 0.0
        self.registry: Optional[MetricRegistry] = (
            MetricRegistry(ring=ring, clock=lambda: self._simclock)
            if registry else None)
        self.tracer: Optional[Tracer] = (
            Tracer(max_events=max_trace_events) if tracing else None)
        self.audit: Optional[DecisionAudit] = (
            DecisionAudit(max_records=audit_max_records) if audit
            else None)
        self.observers: List = ([self.audit] if self.audit is not None
                                else []) + list(observers)
        self._t0 = time.perf_counter()
        self._timers: Dict[tuple, _PhaseTimer] = {}
        self._cycles: Dict[Optional[str], Dict] = {}
        self._scope_tids: Dict[Optional[str], int] = {}
        self.phase_totals: Dict[str, float] = {}
        self.jobs: Dict[tuple, JobRecord] = {}
        self.event_counts: Dict[str, int] = {}
        self._attached: List = []
        if self.registry is not None:
            self.registry.add_collector(self._collect_combo_caches)

    # -- wiring --------------------------------------------------------
    @property
    def audit_on(self) -> bool:
        return bool(self.observers)

    def attach(self, sim, scope: Optional[str] = None) -> None:
        """Wire this telemetry into a simulator (and its QSCH/RSCH/
        metrics + event bus).  ``scope`` labels a federation member."""
        obs = self if scope is None else _ScopedTelemetry(self, scope)
        sim.obs = obs
        sim.qsch.obs = obs
        sim.qsch.rsch.obs = obs
        sim.metrics.obs = obs
        sim.bus.tap = obs.on_bus_event
        self._attached.append(sim)
        if self.registry is not None:
            lbl = self._labels(scope)

            def collect(reg, sim=sim, lbl=lbl):
                eng = getattr(sim, "_engine", None)
                if eng is not None:
                    for k, v in eng.summary.as_dict().items():
                        reg.gauge("kant_dynamics_" + k,
                                  "dynamics engine counters").set(v, **lbl)
                elastic = getattr(sim.qsch, "elastic", None)
                if elastic is not None:
                    for k, v in elastic.stats().items():
                        reg.gauge("kant_elastic_" + k,
                                  "elastic manager counters").set(v, **lbl)
            self.registry.add_collector(collect)

    def detach(self, sim) -> None:
        """Undo :meth:`attach` (the byte-identity benchmark's A side)."""
        sim.obs = None
        sim.qsch.obs = None
        sim.qsch.rsch.obs = None
        sim.metrics.obs = None
        sim.bus.tap = None
        if sim in self._attached:
            self._attached.remove(sim)

    def attach_qsch(self, qsch, scope: Optional[str] = None) -> None:
        """Wire a bare QSCH/RSCH pair (no simulator) — unit-test and
        standalone-cycle use."""
        obs = self if scope is None else _ScopedTelemetry(self, scope)
        qsch.obs = obs
        qsch.rsch.obs = obs

    # -- labels / lanes ------------------------------------------------
    @staticmethod
    def _labels(scope: Optional[str]) -> Dict[str, str]:
        return {} if scope is None else {"member": scope}

    def _sched_tid(self, scope: Optional[str]) -> int:
        tid = self._scope_tids.get(scope)
        if tid is None:
            tid = self._scope_tids[scope] = len(self._scope_tids)
            if self.tracer is not None:
                self.tracer.metadata(PID_SCHED, "scheduler (wall clock)")
                self.tracer.metadata(PID_SCHED, scope or "qsch", tid=tid)
                self.tracer.metadata(PID_JOBS, "jobs (sim time)")
                self.tracer.metadata(PID_CLUSTER, "cluster (sim time)")
        return tid

    def _wall_us(self) -> float:
        return (time.perf_counter() - self._t0) * 1e6

    def _job_rec(self, job, scope: Optional[str]) -> JobRecord:
        key = (scope, job.uid)
        rec = self.jobs.get(key)
        if rec is None:
            rec = self.jobs[key] = JobRecord(
                uid=job.uid, tenant=job.tenant, kind=job.kind.name,
                n_gpus=job.n_gpus, submit_t=job.submit_time, scope=scope)
        return rec

    # -- phases / cycles -----------------------------------------------
    def phase(self, name: str) -> _PhaseTimer:
        return self._timer(None, name)

    def _timer(self, scope: Optional[str], name: str) -> _PhaseTimer:
        """Interned per (scope, name): phases are non-reentrant and the
        pipeline enters several per cycle — reusing the context manager
        keeps the attached hot path allocation-free."""
        tmr = self._timers.get((scope, name))
        if tmr is None:
            tmr = self._timers[(scope, name)] = _PhaseTimer(self, scope,
                                                            name)
        return tmr

    def _phase_done(self, scope: Optional[str], name: str,
                    dt: float) -> None:
        self.phase_totals[name] = self.phase_totals.get(name, 0.0) + dt
        cyc = self._cycles.get(scope)
        if cyc is not None:
            ph = cyc["phases"]
            ph[name] = ph.get(name, 0.0) + dt

    def cycle_begin(self, now: float, scope: Optional[str] = None) -> None:
        self._simclock = float(now)
        self._cycles[scope] = {"t": float(now),
                               "wall0": time.perf_counter(),
                               "phases": {}}

    def cycle_end(self, result, ctx, scope: Optional[str] = None) -> None:
        cyc = self._cycles.pop(scope, None)
        if cyc is None:
            return
        wall = time.perf_counter() - cyc["wall0"]
        span = CycleSpan(t=cyc["t"], wall_s=wall, phases=cyc["phases"],
                         scope=scope, result=result)
        reg = self.registry
        if reg is not None:
            lbl = self._labels(scope)
            reg.counter("kant_cycles_total",
                        "QSCH scheduling cycles").inc(**lbl)
            if result.scheduled:
                reg.counter("kant_scheduled_total",
                            "jobs bound").inc(len(result.scheduled), **lbl)
            if result.admit_rejected:
                reg.counter("kant_admit_rejected_total",
                            "static admission rejections").inc(
                    result.admit_rejected, **lbl)
            if result.infeasible:
                reg.counter("kant_infeasible_total",
                            "dynamic admission failures").inc(
                    result.infeasible, **lbl)
            if result.requeues:
                reg.counter("kant_requeues_total",
                            "requeue events").inc(result.requeues, **lbl)
            reg.histogram("kant_cycle_seconds",
                          "wall-clock cycle duration",
                          buckets=_CYCLE_BUCKETS).observe(wall, **lbl)
        tr = self.tracer
        if tr is not None:
            tid = self._sched_tid(scope)
            end_us = self._wall_us()
            start_us = end_us - wall * 1e6
            tr.begin("cycle", start_us, PID_SCHED, tid,
                     args={"t_sim": cyc["t"]})
            # The measured phases are re-laid sequentially inside the
            # cycle span (their true offsets are not recorded; only the
            # durations are) — documented in docs/observability.md.
            ts = start_us
            for name, dur in cyc["phases"].items():
                tr.span(name, ts, dur * 1e6, PID_SCHED, tid)
                ts += dur * 1e6
            tr.end("cycle", end_us, PID_SCHED, tid,
                   args={"scheduled": len(result.scheduled),
                         "preempted": len(result.preempted),
                         "requeues": result.requeues})
        for ob in self.observers:
            ob.on_cycle(span, ctx)

    # -- placement decisions (from QSCH) -------------------------------
    def emit_bind(self, job, sched, ctx,
                  scope: Optional[str] = None) -> None:
        if self.registry is not None:
            # per-cycle totals come from cycle_end; nothing extra here
            pass
        decision = None
        if self.audit_on:
            capture = getattr(sched, "audit", None)
            decision = build_decision(job, capture, "bound", "ok",
                                      ctx.now, member=scope)
            # Stash the placement; decision.nodes derives lazily.
            decision._placement = sched.placement
        for ob in self.observers:
            ob.on_bind(job, decision, ctx)

    def emit_reject(self, job, sched, ctx, reason: str,
                    scope: Optional[str] = None) -> None:
        if self.registry is not None:
            self.registry.counter(
                "kant_placement_rejects_total",
                "placement attempts rejected, by reason").inc(
                reason=reason, **self._labels(scope))
        decision = None
        if self.audit_on:
            capture = getattr(sched, "audit", None) if sched is not None \
                else None
            decision = build_decision(job, capture, "rejected", reason,
                                      ctx.now, member=scope)
        for ob in self.observers:
            ob.on_reject(job, decision, ctx)

    def emit_preempt(self, victim, ctx, source,
                     scope: Optional[str] = None) -> None:
        plugin, beneficiary = (source if source is not None
                               else ("unknown", None))
        record = PreemptionRecord(
            victim_uid=victim.uid, victim_tenant=victim.tenant,
            victim_n_gpus=victim.n_gpus, beneficiary_uid=beneficiary,
            plugin=plugin, t=ctx.now, member=scope)
        rec = self._job_rec(victim, scope)
        rec.preemptions += 1
        if self.registry is not None:
            self.registry.counter(
                "kant_preemptions_total",
                "evictions by the preemption engine").inc(
                plugin=plugin, **self._labels(scope))
        if self.tracer is not None:
            self.tracer.instant("preempt", ctx.now * 1e6, PID_CLUSTER,
                                self._sched_tid(scope),
                                args={"victim": victim.uid,
                                      "beneficiary": beneficiary,
                                      "plugin": plugin})
        for ob in self.observers:
            ob.on_preempt(record, ctx)

    # -- event bus tap -------------------------------------------------
    def on_bus_event(self, event, scope: Optional[str] = None) -> None:
        self._simclock = event.t
        kind = event.kind.name
        self.event_counts[kind] = self.event_counts.get(kind, 0) + 1
        tr = self.tracer
        if tr is not None:
            if event.kind is EventKind.SUBMIT:
                job = event.payload
                rec = self._job_rec(job, scope)
                if not rec._span_open:
                    rec._span_open = True
                    self._sched_tid(scope)     # lane metadata
                    tr.begin(f"job-{job.uid}", event.t * 1e6, PID_JOBS,
                             job.uid, args={"tenant": job.tenant,
                                            "n_gpus": job.n_gpus,
                                            "kind": job.kind.name})
            elif event.kind not in (EventKind.END, EventKind.TICK,
                                    EventKind.SAMPLE):
                tr.instant(kind, event.t * 1e6, PID_CLUSTER,
                           self._sched_tid(scope),
                           args={"payload": repr(event.payload)})
        for ob in self.observers:
            ob.on_event(event, scope)

    # -- MetricsRecorder hooks -----------------------------------------
    def on_sample(self, sample, scope: Optional[str] = None) -> None:
        self._simclock = sample.t
        reg = self.registry
        if reg is not None:
            lbl = self._labels(scope)
            reg.gauge("kant_gar", "allocated/total GPUs").set(
                sample.gar, **lbl)
            reg.gauge("kant_gfr", "fragmented-node ratio").set(
                sample.gfr, **lbl)
            reg.gauge("kant_queue_depth", "pending jobs").set(
                sample.queue_depth, **lbl)
            reg.gauge("kant_allocated_gpus", "GPUs allocated").set(
                sample.allocated, **lbl)
            reg.gauge("kant_capacity_gpus", "allocatable GPUs").set(
                sample.capacity, **lbl)
            reg.gauge("kant_train_allocated_gpus",
                      "GPUs held by training jobs").set(
                sample.train_allocated, **lbl)
            reg.gauge("kant_infer_allocated_gpus",
                      "GPUs held by inference jobs").set(
                sample.infer_allocated, **lbl)
        for ob in self.observers:
            ob.on_sample(sample, scope)

    def on_job_placed(self, job, now: Optional[float],
                      scope: Optional[str] = None) -> None:
        t = float(now) if now is not None else (job.start_time or 0.0)
        rec = self._job_rec(job, scope)
        rec.binds += 1
        first = rec.first_start is None
        if first:
            rec.first_start = t
            if self.registry is not None:
                w = job.waiting_time
                if w is not None:
                    self.registry.histogram(
                        "kant_job_wait_seconds",
                        "queue wait until first bind").observe(
                        w, **self._labels(scope))
        if self.tracer is not None and rec._span_open:
            self.tracer.instant("bind" if first else "rebind",
                                t * 1e6, PID_JOBS, job.uid,
                                args={"attempt": job.attempt})
        for ob in self.observers:
            ob.on_job(job, "placed", t, scope)

    def on_job_finished(self, job,
                        scope: Optional[str] = None) -> None:
        rec = self._job_rec(job, scope)
        t = job.end_time if job.end_time is not None else self._simclock
        rec.end_t = t
        if self.registry is not None:
            self.registry.counter(
                "kant_jobs_completed_total", "jobs finished").inc(
                **self._labels(scope))
        if self.tracer is not None and rec._span_open:
            rec._span_open = False
            self.tracer.end(f"job-{job.uid}", t * 1e6, PID_JOBS,
                            job.uid, args={"interrupts": rec.interrupts,
                                           "binds": rec.binds})
        for ob in self.observers:
            ob.on_job(job, "finished", t, scope)

    def on_job_interrupted(self, job, t: float, lost: float,
                           overhead: float, reshape: bool,
                           scope: Optional[str] = None) -> None:
        rec = self._job_rec(job, scope)
        lbl = self._labels(scope)
        if reshape:
            rec.reshapes += 1
        else:
            rec.interrupts += 1
        if self.registry is not None:
            name = ("kant_reshapes_total" if reshape
                    else "kant_interrupts_total")
            help = ("voluntary checkpoint-boundary reshapes" if reshape
                    else "failure/drain interrupts")
            self.registry.counter(name, help).inc(**lbl)
        if self.tracer is not None and rec._span_open:
            self.tracer.instant("reshape" if reshape else "interrupt",
                                t * 1e6, PID_JOBS, job.uid,
                                args={"lost_s": lost,
                                      "overhead_s": overhead})
        for ob in self.observers:
            ob.on_job(job, "reshape" if reshape else "interrupted", t,
                      scope)

    # -- tuning hooks (repro.core.tuning) ------------------------------
    def on_param_change(self, change,
                        scope: Optional[str] = None) -> None:
        """A tuning controller moved a registered handle: publish the
        new value as a Gauge, stamp a trace instant on the scheduler
        lane, and feed the observer chain (DecisionAudit keeps the
        ring-capped change log)."""
        self._simclock = max(self._simclock, change.t)
        if self.registry is not None:
            lbl = self._labels(scope)
            self.registry.gauge(
                "kant_tuned_param",
                "current value of a tuned scheduling parameter").set(
                change.value, param=change.param, **lbl)
            self.registry.counter(
                "kant_param_changes_total",
                "applied tuning parameter moves, by source").inc(
                source=change.source or "unknown", **lbl)
        if self.tracer is not None:
            self.tracer.instant("param-change", change.t * 1e6,
                                PID_CLUSTER, self._sched_tid(scope),
                                args={"param": change.param,
                                      "previous": change.previous,
                                      "value": change.value,
                                      "source": change.source})
        for ob in self.observers:
            ob.on_param_change(change, scope)

    # -- run lifecycle -------------------------------------------------
    def finalize_run(self, sim, scope: Optional[str] = None) -> None:
        self._simclock = max(self._simclock, sim.now)
        if self.tracer is not None:
            # Horizon cuts / still-pending jobs: close their spans so
            # the trace stays balanced and loadable.
            self.tracer.close_all(sim.now * 1e6)
            for rec in self.jobs.values():
                rec._span_open = False
        if self.registry is not None:
            self.registry.collect()
        for ob in self.observers:
            ob.on_run_end(sim, scope)

    # -- external collectors -------------------------------------------
    @staticmethod
    def _collect_combo_caches(reg) -> None:
        for name, st in cache_stats().items():
            reg.gauge("combo_cache_hits",
                      "dry-run combo cache hits").set(st["hits"],
                                                      cache=name)
            reg.gauge("combo_cache_misses",
                      "dry-run combo cache misses").set(st["misses"],
                                                        cache=name)
            reg.gauge("combo_cache_entries",
                      "dry-run combo cache size").set(st["size"],
                                                      cache=name)

    # -- export --------------------------------------------------------
    def job_records(self) -> List[Dict[str, object]]:
        return [r.as_dict() for r in self.jobs.values()]

    def bundle(self) -> Dict[str, object]:
        """The complete telemetry bundle (input of repro.obs.report)."""
        out: Dict[str, object] = {
            "meta": {
                "format": "repro.obs/1",
                "pillars": {"registry": self.registry is not None,
                            "tracing": self.tracer is not None,
                            "audit": self.audit is not None},
                "sim_end_t": self._simclock,
            },
            "events": dict(self.event_counts),
            "phase_totals": dict(self.phase_totals),
            "jobs": self.job_records(),
        }
        if self.registry is not None:
            out["metrics"] = self.registry.to_json()
        if self.tracer is not None:
            out["trace"] = self.tracer.to_json()
        if self.audit is not None:
            out["audit"] = self.audit.to_json()
        return out

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.bundle(), f, default=float)
        return path

    def save_trace(self, path: str) -> str:
        if self.tracer is None:
            raise ValueError("tracing pillar is disabled")
        return self.tracer.save(path)
