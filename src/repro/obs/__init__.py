"""Unified telemetry: metric registry, tracing, decision audit, reports.

The observability layer for the whole stack (core QSCH/RSCH cycles,
dynamics, federation members, serving pools, elastic reshapes).  Four
pillars, one attach point:

* :mod:`repro.obs.registry`  — Prometheus-style metrics with
  ring-buffered time series and text/JSON exposition;
* :mod:`repro.obs.trace`     — Chrome trace-event tracer (Perfetto):
  wall-clock cycle spans with pipeline-phase children, sim-time job
  lifecycle spans, cluster instants;
* :mod:`repro.obs.audit`     — kube-scheduler-style decision audit
  (filter eliminations, per-ScorePlugin breakdown of bound nodes,
  preemption rationale) behind the ObserverPlugin extension point;
* :mod:`repro.obs.report`    — ``python -m repro.obs.report`` bundle
  renderer (markdown / JSON).

Telemetry is strictly opt-in: with nothing attached, every core hook
is a ``None`` check and scheduling output is byte-identical to an
untelemetered build (``benchmarks/obs_bench.py`` gates this, plus the
≤5% attached per-cycle overhead budget).

See ``docs/observability.md``.
"""

from ..core.framework.api import ObserverPlugin
from .audit import (DecisionAudit, FilterStat, PassAudit,
                    PlacementDecision, PreemptionRecord, ScoreBreakdown,
                    build_decision)
from .registry import (DEFAULT_BUCKETS, Counter, Gauge, Histogram,
                       Metric, MetricRegistry)
from .report import build_report, render_markdown
from .telemetry import CycleSpan, JobRecord, Telemetry
from .trace import PID_CLUSTER, PID_JOBS, PID_SCHED, Tracer

__all__ = [
    "Telemetry", "CycleSpan", "JobRecord",
    "MetricRegistry", "Counter", "Gauge", "Histogram", "Metric",
    "DEFAULT_BUCKETS",
    "Tracer", "PID_SCHED", "PID_JOBS", "PID_CLUSTER",
    "ObserverPlugin", "DecisionAudit", "PlacementDecision", "PassAudit",
    "FilterStat", "ScoreBreakdown", "PreemptionRecord", "build_decision",
    "build_report", "render_markdown",
]
