"""Chrome trace-event JSON tracer (Perfetto-loadable).

Emits the `trace-event format`__ consumed by ``chrome://tracing`` and
https://ui.perfetto.dev: a flat list of events with ``ph`` (phase),
``ts`` (microseconds), ``pid``/``tid`` lanes and free-form ``args``.
Only four phases are used:

* ``B``/``E`` — begin/end of a duration span (always balanced per
  ``(pid, tid)`` lane; asserted in ``tests/test_obs.py``);
* ``i`` — an instant event (failures, preemptions, reshapes);
* ``M`` — metadata naming the process/thread lanes.

__ https://docs.google.com/document/d/1CvAClvFfyA5R-PhYUmn5OOQtYMH4h6I0nSsKchNAySU

The telemetry layer maps the two time domains onto separate pids:

* ``PID_SCHED`` — *wall-clock* scheduler spans: one span per QSCH
  cycle with synthesized sequential child spans for the measured
  pipeline phases (snapshot → queue-sort → filter → score →
  reserve-permit → bind → preempt);
* ``PID_JOBS`` — *simulated-time* job lifecycle spans: SUBMIT opens,
  END closes, with bind / interrupt / reshape instants inside;
* ``PID_CLUSTER`` — simulated-time cluster events (failures, drains,
  scale decisions, preemptions).

Mixing domains in one timeline would be meaningless; as separate
processes Perfetto renders them as independent tracks.
"""

from __future__ import annotations

import json
from typing import Dict, List, Optional

__all__ = ["Tracer", "PID_SCHED", "PID_JOBS", "PID_CLUSTER"]

PID_SCHED = 1     # wall-clock scheduler cycles
PID_JOBS = 2      # sim-time job lifecycle spans
PID_CLUSTER = 3   # sim-time cluster events


class Tracer:
    """Append-only trace-event buffer with balanced-span bookkeeping.

    Events are stored as compact ``(ph, name, ts, pid, tid, args)``
    tuples and materialized into trace-event dicts only at export —
    emission sits on the scheduler's per-cycle hot path (the ≤5%
    attached-overhead budget in ``benchmarks/obs_bench.py``)."""

    def __init__(self, max_events: int = 500_000) -> None:
        self.events: List[tuple] = []
        self.max_events = int(max_events)
        self.dropped = 0
        # Open B-span names per (pid, tid) lane, for balance/finalize.
        self._open: Dict[tuple, List[str]] = {}
        self._named: set = set()

    def __len__(self) -> int:
        return len(self.events)

    # -- low-level emit ------------------------------------------------
    def _emit(self, ev: tuple) -> None:
        if len(self.events) >= self.max_events:
            self.dropped += 1
            return
        self.events.append(ev)

    def metadata(self, pid: int, name: str,
                 tid: Optional[int] = None) -> None:
        """Name a process (``tid=None``) or thread lane (idempotent)."""
        key = (pid, tid)
        if key in self._named:
            return
        self._named.add(key)
        self._emit(("M",
                    "process_name" if tid is None else "thread_name",
                    0, pid, tid if tid is not None else 0,
                    {"name": name}))

    def begin(self, name: str, ts_us: float, pid: int, tid: int,
              args: Optional[Dict] = None) -> None:
        self._open.setdefault((pid, tid), []).append(name)
        self._emit(("B", name, ts_us, pid, tid, args))

    def end(self, name: str, ts_us: float, pid: int, tid: int,
            args: Optional[Dict] = None) -> None:
        stack = self._open.get((pid, tid))
        if stack and stack[-1] == name:
            stack.pop()
        self._emit(("E", name, ts_us, pid, tid, args))

    def instant(self, name: str, ts_us: float, pid: int, tid: int,
                args: Optional[Dict] = None) -> None:
        self._emit(("i", name, ts_us, pid, tid, args))

    def span(self, name: str, ts_us: float, dur_us: float, pid: int,
             tid: int, args: Optional[Dict] = None) -> None:
        """A closed span as a balanced B/E pair.

        Balanced by construction, so it skips the ``_open`` stack
        entirely — the per-cycle phase spans go through here."""
        ev = self.events
        if len(ev) + 2 > self.max_events:
            self.dropped += 2
            return
        ev.append(("B", name, ts_us, pid, tid, None))
        ev.append(("E", name, ts_us + max(0.0, dur_us), pid, tid, args))

    # -- lifecycle -----------------------------------------------------
    def open_spans(self) -> Dict[tuple, List[str]]:
        """Unclosed B-spans per (pid, tid) lane (empty when balanced)."""
        return {k: list(v) for k, v in self._open.items() if v}

    def close_all(self, ts_us: float) -> int:
        """Close every open span (used at run finalize so a horizon cut
        or an unfinished job still yields a loadable, balanced trace)."""
        n = 0
        for (pid, tid), stack in list(self._open.items()):
            while stack:
                self.end(stack[-1], ts_us, pid, tid,
                         args={"closed_at_finalize": True})
                n += 1
        return n

    # -- export --------------------------------------------------------
    def to_json(self) -> Dict[str, object]:
        out = []
        for ph, name, ts, pid, tid, args in self.events:
            ev = {"ph": ph, "name": name, "ts": ts, "pid": pid,
                  "tid": tid}
            if ph == "i":
                ev["s"] = "t"
            if args:
                ev["args"] = args
            out.append(ev)
        return {"traceEvents": out,
                "displayTimeUnit": "ms",
                "otherData": {"dropped": self.dropped}}

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_json(), f)
        return path
