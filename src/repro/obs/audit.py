"""Scheduling decision audit log (kube-scheduler style).

Answers "why did this job land *there*?" / "why was it rejected?" per
decision, the way kube-scheduler's scheduling framework reports filter
and score results:

* per placement attempt, each :class:`FilterStat` records how many
  nodes a Filter plugin (or a structural stage: drain windows, the
  inference-zone selector) eliminated, replaying the chain
  sequentially;
* for the pass that won, a :class:`ScoreBreakdown` per distinct bound
  node decomposes the fused score into per-ScorePlugin terms — their
  sum reproduces the fused kernel's score for that node (asserted in
  ``tests/test_obs.py``);
* every eviction is a :class:`PreemptionRecord` naming the victim, the
  beneficiary it was evicted for, and the Preempt plugin that chose it.

:class:`DecisionAudit` is the built-in
:class:`~repro.core.framework.api.ObserverPlugin` that retains these
records (ring-capped); any custom observer registered on the Telemetry
facade receives the same objects through ``on_bind`` / ``on_reject`` /
``on_preempt``.

The raw capture dicts are produced inside RSCH/QSCH (so the core never
imports this package); :func:`build_decision` lifts them into the
typed records.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Deque, Dict, List, Optional

import numpy as np

from ..core.framework.api import ObserverPlugin
from ..core.framework.registry import register

__all__ = ["FilterStat", "ScoreBreakdown", "PassAudit",
           "PlacementDecision", "PreemptionRecord", "DecisionAudit",
           "build_decision"]


@dataclasses.dataclass(frozen=True)
class FilterStat:
    """One Filter-chain stage: nodes remaining before/after its mask."""

    plugin: str
    nodes_before: int
    nodes_after: int

    @property
    def eliminated(self) -> int:
        return self.nodes_before - self.nodes_after


@dataclasses.dataclass(frozen=True)
class ScoreBreakdown:
    """Per-ScorePlugin decomposition of one bound node's fused score.

    ``sum(terms.values())`` reproduces ``total`` (the fused
    filter+score kernel's value at the node, including snapshot-static
    extra terms) up to float32-vs-float64 rounding."""

    node: int
    total: float
    terms: Dict[str, float]


@dataclasses.dataclass
class PassAudit:
    """One PlacementPass attempt inside a decision."""

    zone: Optional[str]
    reason: str
    filters: List[FilterStat]
    pool_size: int
    breakdown: List[ScoreBreakdown] = dataclasses.field(
        default_factory=list)
    colocate_per_pod: float = 0.0


class PlacementDecision:
    """One placement or rejection, with full attribution.

    Not a dataclass: ``passes`` lifts the raw RSCH capture into typed
    :class:`PassAudit` records lazily, on first read — the bind hot
    path only stashes a reference (the ≤5% attached-overhead budget in
    ``benchmarks/obs_bench.py`` counts on this)."""

    __slots__ = ("uid", "tenant", "kind", "outcome", "reason", "t",
                 "profile", "member", "_nodes", "_placement",
                 "_raw_passes", "_passes")

    def __init__(self, uid: int, tenant: str, kind: str, outcome: str,
                 reason: str, t: float, profile: str = "",
                 member: Optional[str] = None,
                 nodes: Optional[List[int]] = None,
                 raw_passes=()) -> None:
        self.uid = uid
        self.tenant = tenant
        self.kind = kind
        self.outcome = outcome                # "bound" | "rejected"
        self.reason = reason                  # "ok" | rejection reason
        self.t = t
        self.profile = profile
        self.member = member
        self._nodes: Optional[List[int]] = (list(nodes) if nodes
                                            else None)
        self._placement = None
        self._raw_passes = tuple(raw_passes)
        self._passes: Optional[List[PassAudit]] = None

    @property
    def nodes(self) -> List[int]:
        """Sorted distinct bound nodes (lazy off the stashed placement)."""
        if self._nodes is None:
            pl = self._placement
            self._nodes = (sorted({p.node for p in pl.pods})
                           if pl is not None else [])
        return self._nodes

    @nodes.setter
    def nodes(self, value) -> None:
        self._nodes = list(value)

    @property
    def passes(self) -> List[PassAudit]:
        if self._passes is None:
            self._passes = [_lift_pass(p) for p in self._raw_passes]
        return self._passes

    def as_dict(self) -> Dict[str, object]:
        return {"uid": self.uid, "tenant": self.tenant,
                "kind": self.kind, "outcome": self.outcome,
                "reason": self.reason, "t": self.t,
                "profile": self.profile, "member": self.member,
                "nodes": self.nodes,
                "passes": [dataclasses.asdict(p) for p in self.passes]}

    def __repr__(self) -> str:
        return (f"<PlacementDecision uid={self.uid} {self.outcome}"
                f" reason={self.reason!r}>")


@dataclasses.dataclass
class PreemptionRecord:
    """One eviction: who was killed, for whom, and which plugin said so."""

    victim_uid: int
    victim_tenant: str
    victim_n_gpus: int
    beneficiary_uid: Optional[int]
    plugin: str
    t: float
    member: Optional[str] = None

    def as_dict(self) -> Dict[str, object]:
        return dataclasses.asdict(self)


def _lift_pass(p: Dict) -> PassAudit:
    stats = [FilterStat(plugin=name, nodes_before=before,
                        nodes_after=after)
             for name, before, after in p.get("filters", ())]
    breakdown: List[ScoreBreakdown] = []
    bd = p.get("breakdown")
    if bd:
        # The capture is gathers-only (see RSCH._audit_breakdown); the
        # fused-term arithmetic mirroring node_scores_np and the
        # per-node pivot happen here, off the bind hot path.
        used_norm = bd["used"].astype(np.float64) / bd["g"]
        exact_fit = (bd["free"] == bd["request"]).astype(np.float64)
        gload = bd["gload"].astype(np.float64)
        tpref = bd["tpref"].astype(np.float64)
        cols: Dict[str, "np.ndarray"] = {}
        for name, w_used, w_fit, w_group, w_topo in bd["weights"]:
            val = (w_used * used_norm + w_fit * exact_fit
                   + w_group * gload + w_topo * tpref)
            cols[name] = cols[name] + val if name in cols else val
        for name, term in bd["extra"].items():
            term = np.asarray(term, dtype=np.float64)
            cols[name] = cols[name] + term if name in cols else term
        totals = bd["totals"].astype(np.float64)
        terms = {k: [float(v) for v in col] for k, col in cols.items()}
        for i, node in enumerate(bd["nodes"]):
            breakdown.append(ScoreBreakdown(
                node=int(node), total=float(totals[i]),
                terms={k: terms[k][i] for k in terms}))
    return PassAudit(
        zone=p.get("zone"), reason=p.get("reason", ""),
        filters=stats, pool_size=int(p.get("pool", 0)),
        breakdown=breakdown,
        colocate_per_pod=float(p.get("colocate_per_pod", 0.0)))


def build_decision(job, capture: Optional[Dict], outcome: str,
                   reason: str, t: float,
                   member: Optional[str] = None) -> PlacementDecision:
    """Wrap RSCH's raw capture dict in a decision record (typed pass
    audits materialize lazily through ``decision.passes``).

    ``capture`` is ``None`` for decisions made before RSCH ran (static
    admission / dynamic feasibility rejections) — the decision then
    carries no pass audits, only the outcome."""
    if capture is None:
        capture = {}
    return PlacementDecision(
        uid=job.uid, tenant=job.tenant, kind=job.kind.name,
        outcome=outcome, reason=reason, t=float(t),
        profile=capture.get("profile", ""), member=member,
        raw_passes=capture.get("passes", ()))


@register
class DecisionAudit(ObserverPlugin):
    """Built-in observer retaining the decision/preemption history.

    ``max_records`` bounds memory on long runs: the oldest records are
    dropped (FIFO) and counted in ``dropped`` — never silently."""

    name = "DecisionAudit"

    def __init__(self, max_records: int = 20_000) -> None:
        self.decisions: Deque[PlacementDecision] = collections.deque(
            maxlen=max_records)
        self.preemptions: Deque[PreemptionRecord] = collections.deque(
            maxlen=max_records)
        # Tuning parameter moves (repro.core.tuning ParamChange records).
        self.param_changes: Deque = collections.deque(maxlen=max_records)
        self._seen_decisions = 0
        self._seen_preemptions = 0
        self._seen_param_changes = 0

    # -- ObserverPlugin hooks ------------------------------------------
    def on_bind(self, job, decision, ctx) -> None:
        if decision is not None:
            self._seen_decisions += 1
            self.decisions.append(decision)

    def on_reject(self, job, decision, ctx) -> None:
        if decision is not None:
            self._seen_decisions += 1
            self.decisions.append(decision)

    def on_preempt(self, record, ctx) -> None:
        if record is not None:
            self._seen_preemptions += 1
            self.preemptions.append(record)

    def on_param_change(self, change, scope=None) -> None:
        self._seen_param_changes += 1
        self.param_changes.append(change)

    # -- accessors -----------------------------------------------------
    @property
    def dropped(self) -> int:
        return ((self._seen_decisions - len(self.decisions))
                + (self._seen_preemptions - len(self.preemptions))
                + (self._seen_param_changes - len(self.param_changes)))

    def bound(self) -> List[PlacementDecision]:
        return [d for d in self.decisions if d.outcome == "bound"]

    def rejected(self) -> List[PlacementDecision]:
        return [d for d in self.decisions if d.outcome == "rejected"]

    def rejections_by_reason(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for d in self.rejected():
            out[d.reason] = out.get(d.reason, 0) + 1
        return out

    def summary(self) -> Dict[str, object]:
        return {
            "decisions": self._seen_decisions,
            "bound": len(self.bound()),
            "rejected": len(self.rejected()),
            "rejections_by_reason": self.rejections_by_reason(),
            "preemptions": self._seen_preemptions,
            "param_changes": self._seen_param_changes,
            "dropped": self.dropped,
        }

    def to_json(self) -> Dict[str, object]:
        return {
            "summary": self.summary(),
            "decisions": [d.as_dict() for d in self.decisions],
            "preemptions": [p.as_dict() for p in self.preemptions],
            "param_changes": [c.as_dict() for c in self.param_changes],
        }
