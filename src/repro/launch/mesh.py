"""Production meshes (deliverable e).

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import, and everything else must keep seeing the single real CPU device.

Target hardware: TPU v5e — one pod = 16×16 = 256 chips
(``data`` × ``model``); two pods = 512 chips with a leading ``pod`` axis
(DCN between pods, ICI within).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_cpu_mesh(n_data: int = 1, n_model: int = 1):
    """Tiny mesh over the real devices for CPU-scale examples/tests."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


# TPU v5e hardware constants used by the roofline analysis (§Roofline).
PEAK_FLOPS_BF16 = 197e12       # per chip, FLOP/s
HBM_BW = 819e9                 # per chip, bytes/s
ICI_BW = 50e9                  # per link, bytes/s
