"""Keyed memoization for (arch × shape × mesh) combo work.

The dry-run pipeline lowers and analyses the same (architecture, input
shape, mesh) combo over and over when a job's candidate parallelism
plans are enumerated — re-lowering an identical combo is pure waste.
:class:`ComboCache` is the shared memo: :mod:`repro.launch.dryrun`
keys its ``lower_combo``/``analyse`` results on the combo tuple, and
:mod:`repro.core.elastic.estimate` keys derived plan tables the same
way.

This module is deliberately **jax-free**: ``dryrun.py`` must be the
process entry point (it sets ``XLA_FLAGS`` before importing jax), so
tests and the elastic benchmark exercise the cache through here without
ever importing the dry-run module.  Hit/miss counters are first-class:
``benchmarks/elastic_bench.py`` reports them as its cache-efficiency
figure.
"""

from __future__ import annotations

import weakref
from typing import Any, Callable, Dict, Hashable, Optional, Tuple

__all__ = ["ComboCache", "cache_stats", "mesh_key"]

# Every live ComboCache, for telemetry pull-collection (repro.obs wires
# cache_stats() into its metric registry).  Weak references: a cache's
# lifetime stays owned by its creator, not by the stats registry.
_LIVE: "weakref.WeakSet[ComboCache]" = weakref.WeakSet()


def cache_stats() -> Dict[str, Dict[str, int]]:
    """Hit/miss/size stats of every live cache, keyed by cache name.

    Same-named caches (e.g. a fresh one per benchmark phase) collapse
    onto one key with summed counters."""
    out: Dict[str, Dict[str, int]] = {}
    for cache in list(_LIVE):
        st = cache.stats()
        agg = out.setdefault(st["name"], {"hits": 0, "misses": 0,
                                          "size": 0})
        agg["hits"] += st["hits"]
        agg["misses"] += st["misses"]
        agg["size"] += st["size"]
    return out


def mesh_key(mesh) -> Tuple[Tuple[str, int], ...]:
    """Stable cache key for a mesh: its named axes and their sizes.
    Duck-typed over ``jax.sharding.Mesh`` (``axis_names`` + ``shape``)
    so key construction needs no jax import."""
    shape = mesh.shape   # Mapping[axis name, size] on jax meshes
    return tuple((str(name), int(shape[name])) for name in mesh.axis_names)


class ComboCache:
    """A dict-backed memo with hit/miss accounting.

    Not thread-safe (neither is the dry-run pipeline); ``clear()``
    resets both entries and counters so benchmarks can measure one
    phase in isolation.
    """

    def __init__(self, name: str = "combo") -> None:
        self.name = name
        self.hits = 0
        self.misses = 0
        self._data: Dict[Hashable, Any] = {}
        _LIVE.add(self)

    def __len__(self) -> int:
        return len(self._data)

    def __contains__(self, key: Hashable) -> bool:
        return key in self._data

    # ------------------------------------------------------------------
    def get(self, key: Hashable) -> Optional[Any]:
        """Counted lookup: a present key is a hit, a missing one a miss
        (the caller is expected to compute and :meth:`put`)."""
        if key in self._data:
            self.hits += 1
            return self._data[key]
        self.misses += 1
        return None

    def put(self, key: Hashable, value: Any) -> Any:
        self._data[key] = value
        return value

    def get_or(self, key: Hashable, compute: Callable[[], Any]) -> Any:
        """Memoized call: one hit or one miss per invocation."""
        if key in self._data:
            self.hits += 1
            return self._data[key]
        self.misses += 1
        return self.put(key, compute())

    # ------------------------------------------------------------------
    def stats(self) -> Dict[str, int]:
        return {"name": self.name, "hits": self.hits,
                "misses": self.misses, "size": len(self._data)}

    def clear(self) -> None:
        self._data.clear()
        self.hits = 0
        self.misses = 0
