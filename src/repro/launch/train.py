"""CPU-scale training driver (examples / integration tests).

``python -m repro.launch.train --arch glm4-9b --smoke --steps 20`` runs a
reduced-config model end-to-end: synthetic data pipeline -> train_step ->
checkpoint.  On real hardware the same code path runs under the
production mesh with the auto-sharder (see dryrun.py for the lowering).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..ckpt import save_checkpoint
from ..configs import get_arch
from ..data import DataConfig, synthetic_batches
from ..train import AdamWConfig, TrainState


def train_loop(arch: str, *, smoke: bool = True, steps: int = 20,
               batch: int = 8, seq: int = 64, lr: float = 1e-3,
               ckpt_dir: str = "", seed: int = 0, log_every: int = 5):
    cfg = get_arch(arch, smoke=smoke)
    state = TrainState(cfg, jax.random.PRNGKey(seed),
                       AdamWConfig(lr=lr, weight_decay=0.0))
    data = synthetic_batches(cfg, DataConfig(batch=batch, seq=seq,
                                             seed=seed))
    t0 = time.time()
    for i in range(steps):
        metrics = state.step(next(data))
        if i % log_every == 0 or i == steps - 1:
            print(f"step {i:4d}  loss {metrics['loss']:.4f}  "
                  f"gnorm {metrics['grad_norm']:.3f}  "
                  f"({time.time() - t0:.1f}s)", flush=True)
    if ckpt_dir:
        save_checkpoint(ckpt_dir, {"params": state.params,
                                   "opt": state.opt_state}, step=steps)
        print(f"checkpoint written to {ckpt_dir}")
    return state


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt", default="")
    args = ap.parse_args()
    train_loop(args.arch, smoke=args.smoke, steps=args.steps,
               batch=args.batch, seq=args.seq, lr=args.lr,
               ckpt_dir=args.ckpt)


if __name__ == "__main__":
    main()
