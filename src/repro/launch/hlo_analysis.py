"""Trip-count-aware analysis of optimized (post-SPMD) HLO text.

``compiled.cost_analysis()`` counts every ``while`` body ONCE, regardless
of trip count — our layer stacks, attention chunk loops and recurrent
scans are all ``lax.scan``s, so raw numbers undercount by 1–3 orders of
magnitude.  This module re-derives the three roofline inputs from
``compiled.as_text()`` with loop multipliers taken from the
``known_trip_count`` backend config XLA attaches to counted loops:

* **flops** — 2·|out|·K for every ``dot`` (K = product of the lhs
  contracting dims), |out| per elementwise/reduce op (fusion bodies are
  recursed into);
* **bytes** — per top-level op: operand + output sizes (fusions count
  their boundary only — internal traffic stays in registers), with
  ``dynamic-update-slice`` special-cased to 2×|update| (in-place);
* **collective_bytes** — operand sizes of all-gather / all-reduce /
  reduce-scatter / all-to-all / collective-permute, by kind.

Shapes in partitioned HLO are per-device, so every number here is
per-device; the roofline divides by per-chip peak rates directly.
"""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8,
                "s32": 4, "u64": 8, "u32": 4, "s16": 2, "u16": 2,
                "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
                "f8e4m3fn": 1, "f8e5m2": 1}

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\(?[^=]*?\)?)\s*([\w\-]+)\((.*)$")
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%([\w.\-]+)")
_BODY_RE = re.compile(r"body=%([\w.\-]+)")
_COND_RE = re.compile(r"condition=%([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_LHS_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

_NO_TRAFFIC = {"parameter", "get-tuple-element", "tuple", "bitcast",
               "constant", "iota", "after-all", "partition-id",
               "replica-id", "rng-bit-generator", "opt-barrier"}

_ELEMENTWISE_FLOP = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum",
    "exponential", "tanh", "log", "rsqrt", "sqrt", "power", "negate",
    "abs", "sign", "floor", "ceil", "cosine", "sine", "logistic",
    "select", "clamp", "compare", "and", "or", "not", "xor",
    "reduce", "convert", "expm1", "log1p", "atan2", "remainder",
}


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVE_KINDS})

    def __iadd__(self, other: "Cost") -> "Cost":
        self.flops += other.flops
        self.bytes += other.bytes
        for k in COLLECTIVE_KINDS:
            self.coll[k] += other.coll[k]
        return self

    def scaled(self, m: float) -> "Cost":
        return Cost(self.flops * m, self.bytes * m,
                    {k: v * m for k, v in self.coll.items()})

    @property
    def collective_bytes(self) -> float:
        return sum(self.coll.values())


@dataclasses.dataclass
class Instr:
    name: str
    types: List[Tuple[str, Tuple[int, ...]]]   # result shapes (tuple-flat)
    opcode: str
    operands: List[str]
    rest: str                                  # attribute tail of the line

    def out_bytes(self) -> int:
        return sum(_nbytes(d, s) for d, s in self.types)

    def out_elems(self) -> int:
        total = 0
        for _, s in self.types:
            n = 1
            for d in s:
                n *= d
            total += n
        return total


def _nbytes(dtype: str, shape: Tuple[int, ...]) -> int:
    n = _DTYPE_BYTES.get(dtype, 4)
    for d in shape:
        n *= d
    return n


def _parse_types(s: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for m in _SHAPE_RE.finditer(s):
        dims = tuple(int(d) for d in m.group(2).split(",")) \
            if m.group(2) else ()
        out.append((m.group(1), dims))
    return out


def _split_operands(argstr: str) -> List[str]:
    """Operand names from the text inside op(...) — balanced to depth 0."""
    out, depth, cur = [], 0, []
    for ch in argstr:
        if ch == "(":
            depth += 1
        elif ch == ")":
            if depth == 0:
                break
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        out.append("".join(cur))
    names = []
    for tok in out:
        m = re.search(r"%([\w.\-]+)", tok)
        if m:
            names.append(m.group(1))
    return names


class HloModule:
    def __init__(self, text: str) -> None:
        self.computations: Dict[str, List[Instr]] = {}
        self.entry: Optional[str] = None
        self._parse(text)
        # name -> Instr, per computation
        self.defs: Dict[str, Dict[str, Instr]] = {
            c: {i.name: i for i in instrs}
            for c, instrs in self.computations.items()}
        self._memo: Dict[str, Cost] = {}

    # -- parsing ---------------------------------------------------------
    def _parse(self, text: str) -> None:
        current: Optional[str] = None
        for raw in text.splitlines():
            line = re.sub(r"/\*.*?\*/", "", raw).rstrip()
            s = line.strip()
            header = re.match(r"^(ENTRY\s+)?%([\w.\-]+)\s*\(.*->.*\{", s)
            if header and not line.startswith(" "):
                current = header.group(2)
                self.computations[current] = []
                if header.group(1):
                    self.entry = current
                continue
            if s == "}":
                continue
            if current is None:
                continue
            m = _INSTR_RE.match(line)
            if not m:
                continue
            name, types, opcode, rest = m.groups()
            self.computations[current].append(Instr(
                name=name, types=_parse_types(types), opcode=opcode,
                operands=_split_operands(rest), rest=rest))

    # -- cost ------------------------------------------------------------
    def _operand_shape(self, comp: str, name: str
                       ) -> Optional[Tuple[str, Tuple[int, ...]]]:
        instr = self.defs.get(comp, {}).get(name)
        if instr and instr.types:
            return instr.types[0]
        return None

    def _dot_flops(self, comp: str, instr: Instr) -> float:
        m = _LHS_CDIMS_RE.search(instr.rest)
        k = 1
        if m and instr.operands:
            lhs = self._operand_shape(comp, instr.operands[0])
            if lhs:
                dims = [int(d) for d in m.group(1).split(",")
                        if d != ""]
                for d in dims:
                    if d < len(lhs[1]):
                        k *= lhs[1][d]
        return 2.0 * instr.out_elems() * k

    def _instr_bytes(self, comp: str, instr: Instr) -> float:
        if instr.opcode in _NO_TRAFFIC:
            return 0.0
        if instr.opcode == "dynamic-update-slice":
            # In-place: read + write the updated slice only.
            upd = (self._operand_shape(comp, instr.operands[1])
                   if len(instr.operands) > 1 else None)
            return 2.0 * (_nbytes(*upd) if upd else instr.out_bytes())
        if instr.opcode in ("dynamic-slice", "slice", "gather"):
            # Reads only the sliced window, not the whole operand.
            return 2.0 * instr.out_bytes()
        if instr.opcode in ("fusion", "call"):
            # XLA:CPU wraps parallelized fusions in a ``call`` to a
            # ``parallel_*`` computation (e.g. the scan body's
            # dynamic-slice over the stacked weights); billing the call
            # boundary like a fusion keeps the slice-aware accounting —
            # otherwise every scan step is charged the full stack.
            return self._fusion_bytes(comp, instr)
        total = float(instr.out_bytes())
        for op in instr.operands:
            shp = self._operand_shape(comp, op)
            if shp:
                total += _nbytes(*shp)
        return total

    def _param_names(self, comp: str) -> Dict[int, str]:
        """Parameter index -> instruction name inside a computation."""
        out: Dict[int, str] = {}
        for fi in self.computations.get(comp, []):
            if fi.opcode == "parameter":
                m = re.match(r"(\d+)\)", fi.rest)
                if m:
                    out[int(m.group(1))] = fi.name
        return out

    def _sliced_read_bytes(self, comp: str, value: str,
                           depth: int = 0) -> Optional[float]:
        """Bytes actually read from ``value`` if it is consumed ONLY by
        slicing ops — directly, through bitcast/copy/convert, or as a
        slice-only parameter of a nested fusion/call (XLA:CPU wraps
        parallelized fusions in ``call %parallel_*`` computations whose
        body is another fusion).  Returns ``None`` when any consumer
        reads the full operand."""
        if depth > 8:
            return None
        consumers = [fi for fi in self.computations.get(comp, [])
                     if value in fi.operands]
        if not consumers:
            return None
        total = 0.0
        for fi in consumers:
            if fi.opcode in ("dynamic-slice", "slice", "gather"):
                total += fi.out_bytes()
            elif fi.opcode in ("bitcast", "copy", "convert"):
                inner = self._sliced_read_bytes(comp, fi.name, depth + 1)
                if inner is None:
                    return None
                total += inner
            elif fi.opcode in ("fusion", "call"):
                called = _CALLS_RE.search(fi.rest)
                if not called:
                    return None
                inner_name = called.group(1)
                params = self._param_names(inner_name)
                for j, op in enumerate(fi.operands):
                    if op != value:
                        continue
                    pname = params.get(j)
                    if pname is None:
                        return None
                    inner = self._sliced_read_bytes(inner_name, pname,
                                                    depth + 1)
                    if inner is None:
                        return None
                    total += inner
            else:
                return None
        return total

    def _fusion_bytes(self, comp: str, instr: Instr) -> float:
        """Fusion boundary traffic, with slice-aware operand accounting.

        * If a fusion parameter is consumed exclusively by dynamic-slice /
          slice / gather ops inside the fused computation (the layer-scan
          reads one layer's weights from the stacked tensor this way), the
          fusion reads only the slices — not the full stacked operand.
        * If the fusion ROOT is a ``dynamic-update-slice`` (scan stacking
          its per-step output into a loop-carried buffer), XLA updates the
          buffer in place: traffic is read+write of the *updated slice*,
          and the aliased full-size buffer operand costs nothing.  Without
          this, a 4096-step scan writing a (4096, ...) history is billed
          the full history per step — a ~4096x over-count (found while
          profiling rwkv6 train_4k; see EXPERIMENTS.md §Perf iteration 0).
        """
        called = _CALLS_RE.search(instr.rest)
        inner_name = called.group(1) if called else ""
        inner = self.computations.get(inner_name, [])
        root = inner[-1] if inner else None     # HLO prints the root last
        inner_defs = self.defs.get(inner_name, {})
        # A root that is an elementwise chain (convert/bitcast/copy) over a
        # DUS is the same in-place stacking pattern with a dtype cast fused
        # in (jax stacks bf16 residuals via f32: convert-dus-convert); the
        # emitter still updates in place, so bill the slice, not the stack.
        while root is not None and \
                root.opcode in ("convert", "bitcast", "copy") \
                and root.operands:
            root = inner_defs.get(root.operands[0])
        # param index -> name inside the fused computation
        param_names = self._param_names(inner_name)
        aliased_param: Optional[str] = None
        if root is not None and root.opcode == "dynamic-update-slice":
            upd = (self._operand_shape(inner_name, root.operands[1])
                   if len(root.operands) > 1 else None)
            # read + write of the updated window only
            total = 2.0 * (_nbytes(*upd) if upd else instr.out_bytes())
            # trace the in-place buffer back through bitcast/copy/convert
            # to its fusion parameter — aliased, not re-read
            name = root.operands[0] if root.operands else ""
            while name in inner_defs and \
                    inner_defs[name].opcode in ("bitcast", "copy",
                                                "convert"):
                ops = inner_defs[name].operands
                if not ops:
                    break
                name = ops[0]
            if name in inner_defs and \
                    inner_defs[name].opcode == "parameter":
                aliased_param = name
        else:
            total = float(instr.out_bytes())
        for i, op in enumerate(instr.operands):
            shp = self._operand_shape(comp, op)
            if not shp:
                continue
            pname = param_names.get(i)
            if pname is not None and pname == aliased_param:
                continue                      # in-place DUS buffer
            if pname is not None and inner:
                sliced = self._sliced_read_bytes(inner_name, pname)
                if sliced is not None:
                    total += sliced
                    continue
            total += _nbytes(*shp)
        return total

    def computation_cost(self, comp: str) -> Cost:
        if comp in self._memo:
            return self._memo[comp]
        total = Cost()
        for instr in self.computations.get(comp, []):
            op = instr.opcode
            base = op.replace("-start", "")
            if base in COLLECTIVE_KINDS and not op.endswith("-done"):
                # XLA's CPU pipeline promotes bf16 all-reduces to f32
                # (to_apply=%..._promoted, operand via a convert fusion);
                # TPUs reduce native bf16, so bill the pre-promotion size.
                promoted = "promoted" in instr.rest
                for name in instr.operands:
                    shp = self._operand_shape(comp, name)
                    if shp:
                        n = _nbytes(*shp)
                        if promoted and shp[0] == "f32":
                            n //= 2
                        total.coll[base] += n
                total.bytes += self._instr_bytes(comp, instr)
                continue
            if op == "while":
                body = _BODY_RE.search(instr.rest)
                cond = _COND_RE.search(instr.rest)
                trip = _TRIP_RE.search(instr.rest)
                n = int(trip.group(1)) if trip else 1
                if body:
                    total += self.computation_cost(body.group(1)).scaled(n)
                if cond:
                    total += self.computation_cost(cond.group(1)).scaled(n)
                continue
            if op == "conditional":
                m = _BRANCH_RE.search(instr.rest)
                if m:
                    branches = [b.strip().lstrip("%")
                                for b in m.group(1).split(",")]
                    costs = [self.computation_cost(b) for b in branches]
                    if costs:
                        # worst case branch
                        best = max(costs, key=lambda c: c.flops + c.bytes)
                        total += best
                continue
            if op in ("fusion", "call", "custom-call", "map", "reduce",
                      "reduce-window", "sort", "scatter", "select-and-scatter"):
                called = _CALLS_RE.search(instr.rest)
                if called:
                    inner = self.computation_cost(called.group(1))
                    total.flops += inner.flops
                    for k in COLLECTIVE_KINDS:
                        total.coll[k] += inner.coll[k]
                total.bytes += self._instr_bytes(comp, instr)
                continue
            if op == "dot":
                total.flops += self._dot_flops(comp, instr)
                total.bytes += self._instr_bytes(comp, instr)
                continue
            if op in _ELEMENTWISE_FLOP:
                total.flops += instr.out_elems()
            total.bytes += self._instr_bytes(comp, instr)
        self._memo[comp] = total
        return total

    def entry_cost(self) -> Cost:
        if self.entry is None:
            return Cost()
        return self.computation_cost(self.entry)


def top_contributors(mod: "HloModule", metric: str = "flops",
                     n: int = 20) -> List[Tuple[float, str, str, str]]:
    """The dry-run 'profile': heaviest instructions by loop-weighted cost.

    Returns [(weighted_value, opcode, result_type, jax op_name), ...].
    ``metric`` is 'flops', 'bytes' or 'coll'.
    """
    # computation -> total loop multiplier (entry = 1)
    mult: Dict[str, float] = {mod.entry: 1.0}
    order = [mod.entry]
    while order:
        comp = order.pop()
        m = mult[comp]
        for instr in mod.computations.get(comp, []):
            if instr.opcode == "while":
                body = _BODY_RE.search(instr.rest)
                cond = _COND_RE.search(instr.rest)
                trip = _TRIP_RE.search(instr.rest)
                k = int(trip.group(1)) if trip else 1
                for g in (body, cond):
                    if g:
                        mult[g.group(1)] = mult.get(g.group(1), 0) + m * k
                        order.append(g.group(1))
            else:
                called = _CALLS_RE.search(instr.rest)
                if called and instr.opcode in ("call", "conditional"):
                    mult[called.group(1)] = mult.get(called.group(1),
                                                     0) + m
                    order.append(called.group(1))
    rows: List[Tuple[float, str, str, str]] = []
    for comp, m in mult.items():
        for instr in mod.computations.get(comp, []):
            if instr.opcode in ("while",):
                continue
            if metric == "flops":
                if instr.opcode == "dot":
                    val = mod._dot_flops(comp, instr)
                elif instr.opcode in ("fusion", "custom-call"):
                    called = _CALLS_RE.search(instr.rest)
                    val = (mod.computation_cost(called.group(1)).flops
                           if called else 0.0)
                elif instr.opcode in _ELEMENTWISE_FLOP:
                    val = float(instr.out_elems())
                else:
                    val = 0.0
            elif metric == "bytes":
                val = mod._instr_bytes(comp, instr)
            else:
                base = instr.opcode.replace("-start", "")
                if base in COLLECTIVE_KINDS and \
                        not instr.opcode.endswith("-done"):
                    promoted = "promoted" in instr.rest
                    val = 0.0
                    for o in instr.operands:
                        shp = mod._operand_shape(comp, o)
                        if shp:
                            n = _nbytes(*shp)
                            if promoted and shp[0] == "f32":
                                n //= 2
                            val += n
                else:
                    val = 0.0
            if val > 0:
                meta = re.search(r'op_name="([^"]*)"', instr.rest)
                rows.append((val * m, instr.opcode,
                             instr.types[0][0] + str(list(
                                 instr.types[0][1])) if instr.types else "",
                             meta.group(1) if meta else instr.name))
    rows.sort(key=lambda r: -r[0])
    return rows[:n]


def analyse_hlo_text(text: str) -> Dict[str, object]:
    mod = HloModule(text)
    cost = mod.entry_cost()
    return {
        "flops_per_device": cost.flops,
        "bytes_per_device": cost.bytes,
        "collective_bytes_per_device": cost.collective_bytes,
        "collectives": dict(cost.coll),
    }
