"""Kant placement -> training-performance bridge (beyond-paper feature).

The paper's JTTED metric (§4.5) uses *deviation ratios* as a proxy for
training time, arguing that placements spanning more NodeNetGroups pay
more communication.  Because our framework also owns the workloads, we
close the loop: a Kant :class:`Placement` is translated into

1. a device mesh shape for the job (data × model over its GPUs), and
2. a **placement-aware roofline**: the job's collective term is scaled by
   the effective bisection bandwidth of its placement — intra-group
   traffic runs at full ICI rate; the fraction of ring traffic that
   crosses NodeNetGroup boundaries runs at the (slower) inter-group rate.

``estimated_step_time(terms, placement, topo)`` is what the cosched
example and ``benchmarks/fig9_ebinpack_jtted.py`` use to show E-Binpack's
placements are measurably better *in the performance model*, not just in
the deviation-ratio proxy.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from ..core.job import Placement
from ..core.topology import ClusterTopology
from .mesh import ICI_BW

# Inter-group (leaf-crossing) links run at a fraction of intra-group ICI;
# 4x oversubscription at the leaf->spine uplink is typical for AI fabrics.
INTER_GROUP_BW_FRACTION = 0.25


@dataclasses.dataclass(frozen=True)
class PlacementQuality:
    n_nodes: int
    n_groups: int
    node_dev: float          # actual / optimal nodes
    group_dev: float         # actual / optimal groups
    cross_group_fraction: float


def placement_quality(placement: Placement, topo: ClusterTopology,
                      n_gpus: int) -> PlacementQuality:
    nodes = placement.distinct_nodes()
    groups = {int(topo.leaf_id[n]) for n in nodes}
    opt_nodes = topo.optimal_node_num(n_gpus)
    opt_groups = topo.optimal_group_num(n_gpus)
    # Fraction of ring-allreduce hops that cross a group boundary when
    # nodes are ordered topologically: (#groups - 1) boundaries over
    # (#nodes) hops, doubled for the bidirectional ring.
    cross = (len(groups) - 1) / max(1, len(nodes))
    return PlacementQuality(
        n_nodes=len(nodes), n_groups=len(groups),
        node_dev=len(nodes) / max(1, opt_nodes),
        group_dev=len(groups) / max(1, opt_groups),
        cross_group_fraction=cross,
    )


def effective_collective_bw(quality: PlacementQuality) -> float:
    """Bandwidth-weighted harmonic mix of intra/inter-group hops."""
    f = quality.cross_group_fraction
    return 1.0 / ((1.0 - f) / ICI_BW
                  + f / (ICI_BW * INTER_GROUP_BW_FRACTION))


def estimated_step_time(terms: Dict[str, float],
                        quality: PlacementQuality) -> float:
    """Roofline step-time estimate for a placed job.

    ``terms`` are the per-device roofline seconds from the dry-run
    (compute/memory/collective at full ICI).  The collective term is
    rescaled by the placement's effective bandwidth; the step time is the
    max of the three (perfect-overlap model).
    """
    coll_bytes = terms["collective"] * ICI_BW
    coll = coll_bytes / effective_collective_bw(quality)
    return max(terms["compute"], terms["memory"], coll)


def job_mesh_shape(n_gpus: int, model_parallel: int = 8
                   ) -> Tuple[int, int]:
    """(data, model) mesh factorization for a job's GPU count."""
    model = model_parallel
    while model > 1 and (n_gpus % model or model > n_gpus):
        model //= 2
    model = max(1, model)
    return (n_gpus // model, model)
