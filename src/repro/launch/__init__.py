"""Launchers: production mesh, multi-pod dry-run, CPU train/serve
drivers, and the Kant placement -> mesh co-scheduling bridge."""
