"""CPU-scale serving driver: batched requests through the ServeEngine.

``python -m repro.launch.serve --arch glm4-9b --requests 12`` serves a
reduced-config model with continuous batching; reports throughput and
per-request latency in engine steps.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs import get_arch
from ..models.model import Model
from ..serve import Request, ServeEngine


def serve_demo(arch: str, *, requests: int = 12, batch_size: int = 4,
               max_new: int = 8, seed: int = 0, per_slot: bool = True):
    cfg = get_arch(arch, smoke=True)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    engine = ServeEngine(cfg, params, batch_size=batch_size, max_seq=128,
                         per_slot_prefill=per_slot)
    rng = np.random.default_rng(seed)
    for i in range(requests):
        prompt = rng.integers(0, cfg.vocab, size=rng.integers(4, 17)
                              ).astype(np.int32)
        engine.submit(Request(uid=i, prompt=prompt, max_new_tokens=max_new))
    t0 = time.time()
    finished = engine.run_until_drained()
    dt = time.time() - t0
    tokens = sum(len(r.generated) for r in finished)
    print(f"served {len(finished)}/{requests} requests, {tokens} tokens "
          f"in {engine.steps} engine steps ({dt:.1f}s, "
          f"{tokens / max(dt, 1e-9):.1f} tok/s)")
    return finished


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--legacy", action="store_true",
                    help="use the legacy whole-batch re-prefill shim "
                         "instead of per-slot continuous batching")
    args = ap.parse_args()
    serve_demo(args.arch, requests=args.requests,
               batch_size=args.batch_size, max_new=args.max_new,
               per_slot=not args.legacy)


if __name__ == "__main__":
    main()
