"""Multi-pod dry-run (deliverable e) + roofline term extraction (g).

MUST be the process entry point (``python -m repro.launch.dryrun``):
the first two lines below force 512 placeholder host devices BEFORE any
jax import, because jax locks the device count on first init.  Never set
this globally — smoke tests and benchmarks see the single real CPU.

For every (architecture × input shape × mesh) the dry-run:

1. builds ``ShapeDtypeStruct`` stand-ins for params / optimizer / batch /
   cache (zero allocation),
2. ``jax.jit(step, in_shardings=..., out_shardings=...).lower(...)
   .compile()`` under the production mesh,
3. records ``compiled.memory_analysis()`` (proves the working set fits),
   ``compiled.cost_analysis()`` (FLOPs / bytes for the roofline), and the
   per-device collective bytes parsed from the partitioned HLO
   (all-gather / all-reduce / reduce-scatter / all-to-all /
   collective-permute operand sizes),
4. writes one JSON per combination under ``experiments/dryrun/``.

Roofline terms (TPU v5e: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s ICI):
``cost_analysis`` runs on the *partitioned per-device module*, so

    compute    = flops_per_device / peak_flops      (s)
    memory     = bytes_per_device / hbm_bw          (s)
    collective = coll_bytes_per_device / ici_bw     (s)

which equal the brief's ``global / (chips × per-chip)`` formulas.
"""

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ruff: noqa: E402
import argparse
import json
import re
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCH_IDS, SHAPES, get_arch, input_specs
from ..configs.base import ArchConfig, InputShape
from ..models.model import Model
from ..serve.step import make_decode_step, make_prefill_step
from ..sharding.auto import (ShardingRules, batch_specs,
                             cache_specs_sharding, param_shardings)
from ..train.optim import opt_specs
from ..train.step import make_train_step
from .combo_cache import ComboCache, mesh_key
from .mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16, make_production_mesh

# Memoization for sweeps that revisit (arch × shape × mesh) combos —
# e.g. elastic-plan estimation probing one architecture at several chip
# counts.  Custom ``rules`` objects bypass the cache (their sharding is
# not captured by the key).  ``cache_stats()`` feeds the benchmarks'
# hit counters.
_LOWER_CACHE = ComboCache("dryrun-lower")
_ANALYSE_CACHE = ComboCache("dryrun-analyse")
# id(lowered) -> combo key, so analyse() can reuse the lowering's key
# without re-deriving it from jax objects.
_LOWERED_KEY: Dict[int, tuple] = {}


def _combo_key(cfg: ArchConfig, shape: InputShape, mesh, *, remat: bool,
               microbatches: int, seq_shard: bool,
               bf16_moments: bool) -> tuple:
    return (cfg.name, shape.name, mesh_key(mesh), bool(remat),
            int(microbatches), bool(seq_shard), bool(bf16_moments))


def cache_stats() -> Dict[str, Dict[str, Any]]:
    """Hit/miss/size counters of the lowering + analysis memo caches."""
    return {c.name: c.stats() for c in (_LOWER_CACHE, _ANALYSE_CACHE)}


def clear_caches() -> None:
    _LOWER_CACHE.clear()
    _ANALYSE_CACHE.clear()
    _LOWERED_KEY.clear()

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8,
                "s32": 4, "u64": 8, "u32": 4, "s16": 2, "u16": 2,
                "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter",
                "all-to-all", "collective-permute")


# ---------------------------------------------------------------------------
# HLO parsing
# ---------------------------------------------------------------------------
def _shape_bytes(type_str: str) -> int:
    """'bf16[2,8]' -> 32.  Tuples handled by the caller."""
    m = re.match(r"(\w+)\[([\d,]*)\]", type_str)
    if not m:
        return 0
    dtype, dims = m.group(1), m.group(2)
    size = _DTYPE_BYTES.get(dtype, 4)
    if dims:
        for d in dims.split(","):
            size *= int(d)
    return size


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-device operand bytes of every collective, by collective kind."""
    # name -> output bytes for every instruction
    sizes: Dict[str, int] = {}
    for m in re.finditer(
            r"%?([\w.\-]+) = \(?((?:\w+\[[\d,]*\][^)=]*?)+)\)? ", hlo_text):
        name, types = m.group(1), m.group(2)
        total = sum(_shape_bytes(t) for t in
                    re.findall(r"\w+\[[\d,]*\]", types))
        sizes[name] = total
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(r"%?[\w.\-]+ = .*? (" + "|".join(_COLLECTIVES)
                     + r")(?:-start|-done)?\(([^)]*)\)", stripped)
        if not m:
            continue
        kind, args = m.group(1), m.group(2)
        if "-done(" in stripped:
            continue                   # counted at the -start op
        for arg in args.split(", "):
            arg = arg.strip().lstrip("%")
            if arg in sizes:
                out[kind] += sizes[arg]
            else:
                # operand annotated inline: 'bf16[4,8]{1,0} %x'
                mm = re.match(r"(\w+\[[\d,]*\])", arg)
                if mm:
                    out[kind] += _shape_bytes(mm.group(1))
    return out


# ---------------------------------------------------------------------------
# Lowering
# ---------------------------------------------------------------------------
def lower_combo(cfg: ArchConfig, shape: InputShape, mesh, *,
                rules: Optional[ShardingRules] = None,
                remat: bool = True, microbatches: int = 1,
                seq_shard: bool = False, bf16_moments: bool = False):
    """Build the jitted step for one (arch × shape) and lower it.

    Memoized on (arch, shape, mesh axes, remat, microbatches,
    seq_shard, bf16_moments) unless explicit ``rules`` are passed."""
    from ..sharding.context import use_activation_sharding
    key = None
    if rules is None:
        key = _combo_key(cfg, shape, mesh, remat=remat,
                         microbatches=microbatches, seq_shard=seq_shard,
                         bf16_moments=bf16_moments)
        cached = _LOWER_CACHE.get(key)
        if cached is not None:
            return cached
    rules = rules or ShardingRules(mesh)
    model = Model(cfg)
    p_specs = model.param_specs(jnp.bfloat16)
    p_shard = param_shardings(p_specs, rules)
    b_specs = input_specs(cfg, shape)
    b_shard = batch_specs(b_specs, rules)

    with mesh, use_activation_sharding(mesh, seq_shard=seq_shard):
        if shape.kind == "train":
            from jax.sharding import NamedSharding, PartitionSpec as P
            o_specs = opt_specs(p_specs,
                                moment_dtype=jnp.bfloat16 if bf16_moments
                                else jnp.float32)
            o_shard = {"m": p_shard, "v": p_shard,
                       "step": NamedSharding(mesh, P())}
            step = make_train_step(cfg, remat=remat,
                                   microbatches=microbatches,
                                   grad_shardings=p_shard)
            jitted = jax.jit(step,
                             in_shardings=(p_shard, o_shard, b_shard),
                             out_shardings=(p_shard, o_shard, None),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(p_specs, o_specs, b_specs)
        elif shape.kind == "prefill":
            step = make_prefill_step(cfg, shape.seq_len)
            c_specs = model.cache_specs(shape.global_batch, shape.seq_len,
                                        jnp.bfloat16)
            c_shard = cache_specs_sharding(c_specs, rules)
            jitted = jax.jit(step, in_shardings=(p_shard, b_shard),
                             out_shardings=(None, c_shard))
            lowered = jitted.lower(p_specs, b_specs)
        else:                                  # decode
            step = make_decode_step(cfg)
            c_specs = model.cache_specs(shape.global_batch, shape.seq_len,
                                        jnp.bfloat16)
            c_shard = cache_specs_sharding(c_specs, rules)
            t_shard = batch_specs(
                {"token": b_specs["token"]}, rules)["token"]
            jitted = jax.jit(step,
                             in_shardings=(p_shard, c_shard, t_shard),
                             out_shardings=(None, c_shard),
                             donate_argnums=(1,))
            lowered = jitted.lower(p_specs, c_specs, b_specs["token"])
    if key is not None:
        _LOWER_CACHE.put(key, lowered)
        _LOWERED_KEY[id(lowered)] = key
    return lowered


def model_flops(cfg: ArchConfig, shape: InputShape) -> float:
    """MODEL_FLOPS = 6·N·D (train) or 2·N·D (fwd-only), N active for MoE."""
    model = Model(cfg)
    n = model.n_active_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch          # one token per sequence


def analyse(lowered, cfg: ArchConfig, shape: InputShape, n_chips: int
            ) -> Dict[str, Any]:
    from .hlo_analysis import analyse_hlo_text
    # Memoized when the lowering came out of lower_combo's cache path:
    # compile + HLO reanalysis dominate a sweep's wall time.  Callers
    # get a fresh dict (run_one mutates its result).
    memo_key = None
    lkey = _LOWERED_KEY.get(id(lowered))
    if lkey is not None:
        memo_key = (lkey, int(n_chips))
        cached = _ANALYSE_CACHE.get(memo_key)
        if cached is not None:
            return dict(cached)
    t0 = time.time()
    compiled = lowered.compile()
    compile_s = time.time() - t0
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    raw_flops = float(cost.get("flops", 0.0))
    raw_bytes = float(cost.get("bytes accessed", 0.0))
    mem = compiled.memory_analysis()
    mem_info = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes"):
        if mem is not None and hasattr(mem, attr):
            mem_info[attr] = int(getattr(mem, attr))
    # Trip-count-aware reanalysis of the partitioned HLO (cost_analysis
    # counts while bodies once — see hlo_analysis module docstring).
    hlo = analyse_hlo_text(compiled.as_text())
    flops_dev = float(hlo["flops_per_device"])
    bytes_dev = float(hlo["bytes_per_device"])
    coll = {k: float(v) for k, v in hlo["collectives"].items()}
    coll_total = float(hlo["collective_bytes_per_device"])

    mf = model_flops(cfg, shape)
    flops_global = flops_dev * n_chips
    result = {
        "arch": cfg.name, "shape": shape.name, "chips": n_chips,
        "compile_s": round(compile_s, 2),
        "flops_per_device": flops_dev,
        "bytes_per_device": bytes_dev,
        "raw_cost_analysis": {"flops": raw_flops, "bytes": raw_bytes},
        "collective_bytes_per_device": coll_total,
        "collectives": coll,
        "memory_analysis": mem_info,
        "model_flops_global": mf,
        "useful_flops_ratio": (mf / flops_global) if flops_global else 0.0,
        "compute_term_s": flops_dev / PEAK_FLOPS_BF16,
        "memory_term_s": bytes_dev / HBM_BW,
        "collective_term_s": coll_total / ICI_BW,
    }
    terms = {"compute": result["compute_term_s"],
             "memory": result["memory_term_s"],
             "collective": result["collective_term_s"]}
    result["dominant_term"] = max(terms, key=terms.get)
    if memo_key is not None:
        _ANALYSE_CACHE.put(memo_key, dict(result))
    return result


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------
def run_one(arch_id: str, shape_name: str, multi_pod: bool,
            out_dir: str, *, remat: bool = True,
            rules_name: str = "baseline", microbatches: int = 1,
            seq_shard: bool = False,
            bf16_moments: bool = False) -> Dict[str, Any]:
    cfg = get_arch(arch_id)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(mesh.devices.shape))
    t0 = time.time()
    lowered = lower_combo(cfg, shape, mesh, remat=remat,
                          microbatches=microbatches, seq_shard=seq_shard,
                          bf16_moments=bf16_moments)
    lower_s = time.time() - t0
    result = analyse(lowered, cfg, shape, n_chips)
    result["lower_s"] = round(lower_s, 2)
    result["mesh"] = "2x16x16" if multi_pod else "16x16"
    result["rules"] = rules_name
    result["microbatches"] = microbatches
    result["seq_shard"] = seq_shard
    os.makedirs(out_dir, exist_ok=True)
    fname = f"{arch_id}__{shape_name}__{result['mesh']}__{rules_name}.json"
    with open(os.path.join(out_dir, fname), "w") as f:
        json.dump(result, f, indent=1)
    return result


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--arch", default="all",
                    help="architecture id or 'all'")
    ap.add_argument("--shape", default="all",
                    help="input shape name or 'all'")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--rules", default="baseline",
                    help="tag recorded in the artifact filename")
    ap.add_argument("--microbatches", type=int, default=1,
                    help="gradient-accumulation microbatches (train)")
    ap.add_argument("--seq-shard", action="store_true",
                    help="sequence-parallel layer-boundary activations")
    ap.add_argument("--bf16-moments", action="store_true",
                    help="store AdamW moments in bf16 (halves opt HBM)")
    args = ap.parse_args()

    archs = list(ARCH_IDS) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    failures = []
    for a in archs:
        for s in shapes:
            tag = f"{a} × {s} × {'2x16x16' if args.multi_pod else '16x16'}"
            try:
                r = run_one(a, s, args.multi_pod, args.out,
                            remat=not args.no_remat,
                            rules_name=args.rules,
                            microbatches=args.microbatches,
                            seq_shard=args.seq_shard,
                            bf16_moments=args.bf16_moments)
                print(f"[ok] {tag}: dominant={r['dominant_term']} "
                      f"compute={r['compute_term_s']:.3e}s "
                      f"memory={r['memory_term_s']:.3e}s "
                      f"collective={r['collective_term_s']:.3e}s "
                      f"(compile {r['compile_s']}s)", flush=True)
            except Exception as e:   # noqa: BLE001 — report, keep going
                failures.append(tag)
                print(f"[FAIL] {tag}: {e}", flush=True)
                traceback.print_exc()
    if failures:
        print(f"{len(failures)} failures: {failures}")
        return 1
    print("all dry-runs passed")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
