"""Dry-run profiler: heaviest HLO instructions for one (arch × shape).

The CPU container has no TPU timings, so the "profile" is the
loop-weighted per-instruction cost of the partitioned HLO
(`hlo_analysis.top_contributors`).  This is what the §Perf hillclimb
iterates against.

Usage::

    PYTHONPATH=src python -m repro.launch.profile \
        --arch rwkv6-3b --shape train_4k --metric bytes --top 25
"""

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ruff: noqa: E402
import argparse

from .dryrun import lower_combo, analyse
from .mesh import make_production_mesh
from ..configs import SHAPES, get_arch
from .hlo_analysis import HloModule, top_contributors


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--metric", default="bytes",
                    choices=["bytes", "flops", "coll"])
    ap.add_argument("--top", type=int, default=25)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--seq-shard", action="store_true")
    ap.add_argument("--bf16-moments", action="store_true")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    shape = SHAPES[args.shape]
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    n_chips = int(mesh.devices.size)
    lowered = lower_combo(cfg, shape, mesh, remat=not args.no_remat,
                          microbatches=args.microbatches,
                          seq_shard=args.seq_shard,
                          bf16_moments=args.bf16_moments)
    result = analyse(lowered, cfg, shape, n_chips)
    print(f"{args.arch} × {args.shape} × "
          f"{'2x16x16' if args.multi_pod else '16x16'}")
    print(f"  compute {result['compute_term_s']:.3e}s  "
          f"memory {result['memory_term_s']:.3e}s  "
          f"collective {result['collective_term_s']:.3e}s  "
          f"dominant={result['dominant_term']}  "
          f"useful={result['useful_flops_ratio']:.3f}")
    print(f"\ntop-{args.top} instructions by loop-weighted {args.metric}:")
    mod = HloModule(lowered.compile().as_text())
    total = {"bytes": result["memory_term_s"] * 819e9,
             "flops": result["compute_term_s"] * 197e12,
             "coll": result["collective_term_s"] * 50e9}[args.metric]
    for val, opcode, rtype, opname in top_contributors(
            mod, metric=args.metric, n=args.top):
        frac = val / total if total else 0.0
        print(f"  {val:12.4e} ({frac:6.1%})  {opcode:22s} {rtype:26s} "
              f"{opname[:90]}")


if __name__ == "__main__":
    main()
