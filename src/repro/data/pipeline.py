"""Synthetic token pipeline with a learnable structure.

Sequences follow a sticky-bigram Markov process (each token prefers a
fixed successor with probability ``stickiness``), so a language model can
actually reduce loss on it — which is what the train-loss-decreases
integration test and the 100M-model example rely on.  Batches come out
in the same dict format ``configs.make_inputs`` uses, including the
stubbed modality embeddings for vlm/encdec families.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    batch: int = 8
    seq: int = 128
    seed: int = 0
    stickiness: float = 0.9


def synthetic_batches(cfg: ArchConfig, data: DataConfig
                      ) -> Iterator[Dict[str, jnp.ndarray]]:
    rng = np.random.default_rng(data.seed)
    succ = rng.integers(0, cfg.vocab, size=cfg.vocab)   # bigram table
    key = jax.random.PRNGKey(data.seed)

    s_text = data.seq - (cfg.n_prefix if cfg.family == "vlm" else 0)
    s_text = max(2, s_text)
    while True:
        toks = np.empty((data.batch, s_text + 1), np.int64)
        toks[:, 0] = rng.integers(0, cfg.vocab, size=data.batch)
        for t in range(1, s_text + 1):
            follow = rng.random(data.batch) < data.stickiness
            rand = rng.integers(0, cfg.vocab, size=data.batch)
            toks[:, t] = np.where(follow, succ[toks[:, t - 1]], rand)
        batch: Dict[str, jnp.ndarray] = {
            "tokens": jnp.asarray(toks[:, :-1], jnp.int32),
            "labels": jnp.asarray(toks[:, 1:], jnp.int32),
        }
        if cfg.family == "vlm":
            key, sub = jax.random.split(key)
            batch["patch_embeds"] = jax.random.normal(
                sub, (data.batch, cfg.n_prefix, cfg.d_model)) * 0.02
        if cfg.family == "encdec":
            key, sub = jax.random.split(key)
            batch["enc_embeds"] = jax.random.normal(
                sub, (data.batch, max(1, s_text // cfg.enc_seq_divisor),
                      cfg.d_model)) * 0.02
        yield batch
