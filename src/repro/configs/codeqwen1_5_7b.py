"""codeqwen1.5-7b — qwen1.5 arch, GQA kv=32 (MHA) [hf:Qwen/CodeQwen1.5-7B]."""

from .base import ArchConfig

FULL = ArchConfig(
    name="codeqwen1.5-7b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32,
    d_ff=13440, vocab=92416,
    citation="hf:Qwen/CodeQwen1.5-7B",
)

SMOKE = ArchConfig(
    name="codeqwen1.5-7b-smoke", family="dense",
    n_layers=2, d_model=256, n_heads=8, n_kv_heads=8,
    d_ff=448, vocab=512,
    citation="reduced variant of hf:Qwen/CodeQwen1.5-7B",
)
