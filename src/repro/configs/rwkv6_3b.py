"""rwkv6-3b "Finch" — attention-free, data-dependent decay
[arXiv:2404.05892].  O(1) recurrent state: runs long_500k natively."""

from .base import ArchConfig

FULL = ArchConfig(
    name="rwkv6-3b", family="ssm",
    n_layers=32, d_model=2560, n_heads=0, n_kv_heads=0,
    d_ff=8960, vocab=65536, head_dim=64, ssm_state=64,
    citation="arXiv:2404.05892",
)

SMOKE = ArchConfig(
    name="rwkv6-3b-smoke", family="ssm",
    n_layers=2, d_model=256, n_heads=0, n_kv_heads=0,
    d_ff=512, vocab=512, head_dim=64, ssm_state=64,
    citation="reduced variant of arXiv:2404.05892",
)
