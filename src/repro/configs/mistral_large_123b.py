"""mistral-large-123b [hf:mistralai/Mistral-Large-Instruct-2407]."""

from .base import ArchConfig

FULL = ArchConfig(
    name="mistral-large-123b", family="dense",
    n_layers=88, d_model=12288, n_heads=96, n_kv_heads=8,
    d_ff=28672, vocab=32768,
    citation="hf:mistralai/Mistral-Large-Instruct-2407",
)

SMOKE = ArchConfig(
    name="mistral-large-123b-smoke", family="dense",
    n_layers=2, d_model=256, n_heads=8, n_kv_heads=2,
    d_ff=512, vocab=512,
    citation="reduced variant of hf:mistralai/Mistral-Large-Instruct-2407",
)
