"""llama4-maverick-400b-a17b — MoE 128 experts top-1, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E].

Llama-4 uses chunked attention for long context; our long_500k decode
uses the sliding-window KV-cache variant (DESIGN.md).
"""

from .base import ArchConfig

FULL = ArchConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=8192, vocab=202048,
    n_experts=128, top_k=1,
    citation="hf:meta-llama/Llama-4-Scout-17B-16E",
)

SMOKE = ArchConfig(
    name="llama4-maverick-400b-a17b-smoke", family="moe",
    n_layers=2, d_model=256, n_heads=8, n_kv_heads=2,
    d_ff=512, vocab=512,
    n_experts=4, top_k=1, capacity_factor=4.0,
    citation="reduced variant of hf:meta-llama/Llama-4-Scout-17B-16E",
)
