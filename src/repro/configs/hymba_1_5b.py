"""hymba-1.5b — parallel attention + Mamba heads [arXiv:2411.13676].

Every layer is windowed (the Hymba paper uses SWA on most layers; we
window all of them and note it in DESIGN.md), so long_500k decode is
O(window) on the attention branch and O(1) on the SSM branch.
"""

from .base import ArchConfig

FULL = ArchConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5,
    d_ff=5504, vocab=32001, ssm_state=16, window=2048,
    citation="arXiv:2411.13676",
)

SMOKE = ArchConfig(
    name="hymba-1.5b-smoke", family="hybrid",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=2,
    d_ff=512, vocab=512, ssm_state=16, window=64,
    citation="reduced variant of arXiv:2411.13676",
)
