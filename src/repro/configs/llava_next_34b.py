"""llava-next-34b — VLM, anyres tiling
[hf:llava-hf/llava-v1.6-mistral-7b-hf].

The ViT/SigLIP vision encoder + projector is a stub: ``input_specs``
supplies 576 precomputed patch embeddings (one 24×24 anyres base tile)
spliced in front of the text tokens; the 60-layer language backbone that
consumes them is fully implemented.
"""

from .base import ArchConfig

FULL = ArchConfig(
    name="llava-next-34b", family="vlm",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=20480, vocab=64000, n_prefix=576,
    citation="hf:llava-hf/llava-v1.6-mistral-7b-hf",
)

SMOKE = ArchConfig(
    name="llava-next-34b-smoke", family="vlm",
    n_layers=2, d_model=256, n_heads=8, n_kv_heads=2,
    d_ff=512, vocab=512, n_prefix=16,
    citation="reduced variant of hf:llava-hf/llava-v1.6-mistral-7b-hf",
)
