"""Architecture registry: ``--arch <id>`` -> ArchConfig (FULL or SMOKE)."""

from .base import (ArchConfig, InputShape, SHAPES, TRAIN_4K, PREFILL_32K,
                   DECODE_32K, LONG_500K)
from .registry import ARCH_IDS, get_arch, input_specs, make_inputs

__all__ = ["ArchConfig", "InputShape", "SHAPES", "TRAIN_4K", "PREFILL_32K",
           "DECODE_32K", "LONG_500K", "ARCH_IDS", "get_arch",
           "input_specs", "make_inputs"]
