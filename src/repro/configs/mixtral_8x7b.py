"""mixtral-8x7b — MoE 8 experts top-2, native SWA 4096 [arXiv:2401.04088]."""

from .base import ArchConfig

FULL = ArchConfig(
    name="mixtral-8x7b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=32000,
    n_experts=8, top_k=2, window=4096,
    citation="arXiv:2401.04088",
)

SMOKE = ArchConfig(
    name="mixtral-8x7b-smoke", family="moe",
    n_layers=2, d_model=256, n_heads=8, n_kv_heads=2,
    d_ff=512, vocab=512,
    n_experts=4, top_k=2, window=64, capacity_factor=4.0,
    citation="reduced variant of arXiv:2401.04088",
)
