"""seamless-m4t-large-v2 — enc-dec audio backbone [arXiv:2308.11596].

The assignment lists "24L"; the model card has 24 speech-encoder + 24
text-decoder layers, so we implement 24 enc + 24 dec (see DESIGN.md).
The mel-spectrogram/conformer frontend is a stub: ``input_specs`` provides
precomputed frame embeddings (B, seq/4, d_model).
"""

from .base import ArchConfig

FULL = ArchConfig(
    name="seamless-m4t-large-v2", family="encdec",
    n_layers=24, n_enc_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=8192, vocab=256206,
    citation="arXiv:2308.11596",
)

SMOKE = ArchConfig(
    name="seamless-m4t-large-v2-smoke", family="encdec",
    n_layers=2, n_enc_layers=2, d_model=256, n_heads=8, n_kv_heads=8,
    d_ff=512, vocab=512,
    citation="reduced variant of arXiv:2308.11596",
)
