"""Registry mapping ``--arch <id>`` to configs, plus input construction.

``input_specs`` builds the allocation-free ``ShapeDtypeStruct`` batch for
the dry-run; ``make_inputs`` builds small concrete batches for smoke
tests.  Both understand the per-family input contracts:

* decoder-only LM families — ``tokens`` (B, S) [+ ``labels`` for train];
* vlm — text ``tokens`` (B, S - n_prefix) plus stubbed ``patch_embeds``
  (B, n_prefix, d_model) so the total sequence length is exactly S;
* encdec — stubbed ``enc_embeds`` (B, S // enc_seq_divisor, d_model)
  plus decoder ``tokens`` (B, S).
"""

from __future__ import annotations

import importlib
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .base import ArchConfig, InputShape

_MODULES: Dict[str, str] = {
    "mistral-large-123b": "mistral_large_123b",
    "glm4-9b": "glm4_9b",
    "mixtral-8x7b": "mixtral_8x7b",
    "codeqwen1.5-7b": "codeqwen1_5_7b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "hymba-1.5b": "hymba_1_5b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "granite-20b": "granite_20b",
    "rwkv6-3b": "rwkv6_3b",
    "llava-next-34b": "llava_next_34b",
}

ARCH_IDS = tuple(_MODULES)


def get_arch(arch_id: str, smoke: bool = False) -> ArchConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f".{_MODULES[arch_id]}", __package__)
    return mod.SMOKE if smoke else mod.FULL


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(x) for x in shape), dtype)


def input_specs(cfg: ArchConfig, shape: InputShape,
                dtype=jnp.bfloat16) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input (dry-run)."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        return {"token": _sds((B,), jnp.int32)}
    batch: Dict[str, jax.ShapeDtypeStruct] = {}
    if cfg.family == "vlm":
        batch["tokens"] = _sds((B, S - cfg.n_prefix), jnp.int32)
        batch["patch_embeds"] = _sds((B, cfg.n_prefix, cfg.d_model), dtype)
    elif cfg.family == "encdec":
        batch["tokens"] = _sds((B, S), jnp.int32)
        batch["enc_embeds"] = _sds(
            (B, max(1, S // cfg.enc_seq_divisor), cfg.d_model), dtype)
    else:
        batch["tokens"] = _sds((B, S), jnp.int32)
    if shape.kind == "train":
        batch["labels"] = _sds(batch["tokens"].shape, jnp.int32)
    return batch


def make_inputs(cfg: ArchConfig, *, batch: int, seq: int,
                kind: str = "train", dtype=jnp.float32, seed: int = 0
                ) -> Dict[str, jnp.ndarray]:
    """Small concrete batches for smoke tests and examples."""
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed)
    if kind == "decode":
        return {"token": jnp.asarray(
            rng.integers(0, cfg.vocab, size=(batch,)), jnp.int32)}
    out: Dict[str, jnp.ndarray] = {}
    if cfg.family == "vlm":
        s_text = max(1, seq - cfg.n_prefix)
        out["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab, size=(batch, s_text)), jnp.int32)
        out["patch_embeds"] = (jax.random.normal(
            key, (batch, cfg.n_prefix, cfg.d_model)) * 0.02).astype(dtype)
    elif cfg.family == "encdec":
        out["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab, size=(batch, seq)), jnp.int32)
        out["enc_embeds"] = (jax.random.normal(
            key, (batch, max(1, seq // cfg.enc_seq_divisor), cfg.d_model))
            * 0.02).astype(dtype)
    else:
        out["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab, size=(batch, seq)), jnp.int32)
    if kind == "train":
        out["labels"] = jnp.asarray(
            rng.integers(0, cfg.vocab, size=out["tokens"].shape), jnp.int32)
    return out
