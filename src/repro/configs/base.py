"""Architecture configuration schema shared by the model zoo.

Every assigned architecture gets one ``src/repro/configs/<id>.py`` module
exporting ``FULL`` (the exact published config, cited) and ``SMOKE`` (a
reduced same-family variant for CPU tests: <=2 layers, d_model<=512,
<=4 experts).  ``repro.configs.registry`` resolves ``--arch <id>``.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int                # 0 for attention-free families
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0           # 0 -> d_model // n_heads
    # --- MoE -----------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # --- attention windows ----------------------------------------------
    window: int = 0             # 0 = full causal; >0 = sliding window (train)
    decode_window: int = 32768  # KV-cache window for long-context decode
    # --- SSM -------------------------------------------------------------
    ssm_state: int = 0          # Mamba/RWKV state size N
    # --- encoder-decoder --------------------------------------------------
    n_enc_layers: int = 0       # 0 = decoder-only
    enc_seq_divisor: int = 4    # encoder frames = seq_len // divisor
    # --- modality frontend stub ------------------------------------------
    n_prefix: int = 0           # patch/frame embedding prefix tokens (VLM)
    rope_theta: float = 1e6
    norm_eps: float = 1e-5
    citation: str = ""

    def __post_init__(self) -> None:
        if self.n_heads and self.head_dim == 0:
            object.__setattr__(self, "head_dim",
                               self.d_model // self.n_heads)
        if self.n_heads:
            if self.n_heads % max(1, self.n_kv_heads):
                raise ValueError("n_heads must be divisible by n_kv_heads")
            if self.head_dim * self.n_heads != self.d_model \
                    and self.family != "hybrid":
                # hybrid (hymba) uses head_dim*n_heads == d_model too; keep
                # the check strict everywhere.
                raise ValueError(
                    f"{self.name}: head_dim*n_heads != d_model")
        if self.family == "moe" and (self.n_experts <= 0 or self.top_k <= 0):
            raise ValueError("moe family needs n_experts and top_k")
        if self.family in ("ssm", "hybrid") and self.ssm_state <= 0:
            raise ValueError("ssm/hybrid family needs ssm_state")

    # ------------------------------------------------------------------
    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def q_groups(self) -> int:
        """Query heads per KV head (GQA group size)."""
        return self.n_heads // max(1, self.n_kv_heads)

    def n_params(self) -> int:
        """Parameter count (embedding + blocks + head), for 6·N·D."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        total = v * d * 2                       # embed + lm head
        total += d                              # final norm
        per_layer = self._block_params()
        total += self.n_layers * per_layer
        if self.n_enc_layers:
            total += self.n_enc_layers * self._enc_block_params()
        return total

    def n_active_params(self) -> int:
        """Active parameters per token (MoE routes top_k of n_experts)."""
        if self.family != "moe":
            return self.n_params()
        d, f = self.d_model, self.d_ff
        dense = self.n_params() - self.n_layers * self._ffn_params()
        active_ffn = self.n_layers * (
            3 * d * f * self.top_k + d * self.n_experts)  # + router
        return dense + active_ffn

    # -- helpers -----------------------------------------------------------
    def _attn_params(self) -> int:
        d, hd = self.d_model, self.head_dim
        return (d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd
                + self.n_heads * hd * d)

    def _ffn_params(self) -> int:
        d, f = self.d_model, self.d_ff
        if self.family == "moe":
            return self.n_experts * 3 * d * f + d * self.n_experts
        return 3 * d * f

    def _block_params(self) -> int:
        d = self.d_model
        if self.family == "ssm":
            # rwkv6: time-mix (r,k,v,w,g ~ 5 d², output d²) + channel-mix.
            return 6 * d * d + 3 * d * self.d_ff // 1 + 2 * d
        if self.family == "hybrid":
            ssm = 2 * d * d + 2 * d * self.ssm_state * 2 + d
            return self._attn_params() + ssm + self._ffn_params() + 2 * d
        base = self._attn_params() + self._ffn_params() + 2 * d
        if self.family == "encdec":
            base += self._attn_params() + d      # cross-attention + norm
        return base

    def _enc_block_params(self) -> int:
        return self._attn_params() + 3 * self.d_model * self.d_ff \
            + 2 * self.d_model


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # "train" | "prefill" | "decode"


TRAIN_4K = InputShape("train_4k", 4_096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32_768, 128, "decode")
LONG_500K = InputShape("long_500k", 524_288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}
