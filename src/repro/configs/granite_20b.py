"""granite-20b — llama-arch code model, MQA kv=1 [arXiv:2405.04324]."""

from .base import ArchConfig

FULL = ArchConfig(
    name="granite-20b", family="dense",
    n_layers=52, d_model=6144, n_heads=48, n_kv_heads=1,
    d_ff=24576, vocab=49152,
    citation="arXiv:2405.04324",
)

SMOKE = ArchConfig(
    name="granite-20b-smoke", family="dense",
    n_layers=2, d_model=256, n_heads=8, n_kv_heads=1,
    d_ff=512, vocab=512,
    citation="reduced variant of arXiv:2405.04324",
)
