"""glm4-9b — RoPE, GQA kv=2 [hf:THUDM/glm-4-9b]."""

from .base import ArchConfig

FULL = ArchConfig(
    name="glm4-9b", family="dense",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=2,
    d_ff=13696, vocab=151552,
    citation="hf:THUDM/glm-4-9b",
)

SMOKE = ArchConfig(
    name="glm4-9b-smoke", family="dense",
    n_layers=2, d_model=256, n_heads=8, n_kv_heads=2,
    d_ff=448, vocab=512,
    citation="reduced variant of hf:THUDM/glm-4-9b",
)
