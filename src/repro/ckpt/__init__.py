"""Checkpointing: npz shards + json manifest."""

from .store import load_checkpoint, save_checkpoint

__all__ = ["save_checkpoint", "load_checkpoint"]
