"""Pytree checkpointing: one ``.npz`` shard per top-level key plus a JSON
manifest holding the tree structure and dtypes.  Round-trip is exact
(tested in ``tests/test_ckpt.py``)."""

from __future__ import annotations

import json
import os
from typing import Any, Dict

import jax
import numpy as np

PyTree = Any

_MANIFEST = "manifest.json"


def _flatten(tree: PyTree) -> Dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for keypath, leaf in flat:
        path = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in keypath)
        out[path] = np.asarray(leaf)
    return out


def save_checkpoint(directory: str, state: Dict[str, PyTree],
                    step: int = 0) -> None:
    """``state`` maps shard name (e.g. "params", "opt") -> pytree."""
    os.makedirs(directory, exist_ok=True)
    manifest: Dict[str, Any] = {"step": step, "shards": {}}
    for name, tree in state.items():
        flat = _flatten(tree)
        np.savez(os.path.join(directory, f"{name}.npz"), **flat)
        manifest["shards"][name] = {
            "treedef": json.loads(_treedef_json(tree)),
            "keys": sorted(flat),
        }
    with open(os.path.join(directory, _MANIFEST), "w") as f:
        json.dump(manifest, f)


def load_checkpoint(directory: str) -> Dict[str, Any]:
    with open(os.path.join(directory, _MANIFEST)) as f:
        manifest = json.load(f)
    out: Dict[str, Any] = {"step": manifest["step"]}
    for name, meta in manifest["shards"].items():
        with np.load(os.path.join(directory, f"{name}.npz")) as z:
            flat = {k: z[k] for k in z.files}
        out[name] = _unflatten(meta["treedef"], flat)
    return out


def _treedef_json(tree: PyTree) -> str:
    """Nested-dict skeleton (we only checkpoint dict pytrees)."""
    def skel(t):
        if isinstance(t, dict):
            return {k: skel(v) for k, v in t.items()}
        return None
    return json.dumps(skel(tree))


def _unflatten(skel: Any, flat: Dict[str, np.ndarray],
               prefix: str = "") -> PyTree:
    if skel is None:
        return flat[prefix]
    return {k: _unflatten(v, flat, f"{prefix}/{k}" if prefix else k)
            for k, v in skel.items()}
