"""RWKV-6 "Finch" block: data-dependent decay linear attention
[arXiv:2404.05892].

Per head ``h`` with head_dim ``n`` the time-mix recurrence over state
``S_t ∈ R^{n×n}`` is::

    S_t = diag(w_t) · S_{t-1} + k_t^T v_t
    o_t = r_t · (S_{t-1} + diag(u) k_t^T v_t)

with the *data-dependent* decay ``w_t = exp(-exp(wb + W_w · x_t))`` (the
Finch novelty vs RWKV-5's static decay) and a LoRA-style low-rank path for
the decay projection.  Token-shift mixes each input with its predecessor.

Training runs the recurrence with ``lax.scan`` over time in chunks of
``CHUNK`` steps (keeps HLO small; the per-step math is pure VPU work).
Decode carries ``S`` explicitly — O(1) state, which is why rwkv6 runs the
long_500k shape natively (DESIGN.md §Arch-applicability).
"""

from __future__ import annotations

from typing import Dict, Tuple

import math

import jax
import jax.numpy as jnp

from ..sharding.context import constrain
from .layers import Params, dense_init, rmsnorm, spec

DECAY_LORA = 64


def init_rwkv_block(key, d_model: int, d_ff: int, head_dim: int,
                    dtype, out_scale: float = 1.0) -> Params:
    H = d_model // head_dim
    ks = jax.random.split(key, 10)
    return {
        "ln_t": jnp.ones((d_model,), dtype),
        "ln_c": jnp.ones((d_model,), dtype),
        # token-shift mixing coefficients per stream
        "mu": (jnp.ones((5, d_model)) * 0.5).astype(dtype),
        "wr": dense_init(ks[0], (d_model, d_model), dtype),
        "wk": dense_init(ks[1], (d_model, d_model), dtype),
        "wv": dense_init(ks[2], (d_model, d_model), dtype),
        "wg": dense_init(ks[3], (d_model, d_model), dtype),
        "wo": dense_init(ks[4], (d_model, d_model), dtype,
                         scale=out_scale / math.sqrt(d_model)),
        # data-dependent decay: base + LoRA path
        "decay_base": jnp.zeros((H, head_dim), dtype),
        "decay_a": dense_init(ks[5], (d_model, DECAY_LORA), dtype),
        "decay_b": dense_init(ks[6], (DECAY_LORA, d_model), dtype),
        "bonus_u": (jnp.ones((H, head_dim)) * 0.5).astype(dtype),
        # channel-mix (RWKV FFN): square ReLU
        "ck": dense_init(ks[7], (d_model, d_ff), dtype),
        "cv": dense_init(ks[8], (d_ff, d_model), dtype,
                         scale=out_scale / math.sqrt(d_ff)),
        "cr": dense_init(ks[9], (d_model, d_model), dtype),
    }


def spec_rwkv_block(d_model: int, d_ff: int, head_dim: int, dtype) -> Params:
    H = d_model // head_dim
    return {
        "ln_t": spec((d_model,), dtype),
        "ln_c": spec((d_model,), dtype),
        "mu": spec((5, d_model), dtype),
        "wr": spec((d_model, d_model), dtype),
        "wk": spec((d_model, d_model), dtype),
        "wv": spec((d_model, d_model), dtype),
        "wg": spec((d_model, d_model), dtype),
        "wo": spec((d_model, d_model), dtype),
        "decay_base": spec((H, head_dim), dtype),
        "decay_a": spec((d_model, DECAY_LORA), dtype),
        "decay_b": spec((DECAY_LORA, d_model), dtype),
        "bonus_u": spec((H, head_dim), dtype),
        "ck": spec((d_model, d_ff), dtype),
        "cv": spec((d_ff, d_model), dtype),
        "cr": spec((d_model, d_model), dtype),
    }


def rwkv_state_shape(batch: int, d_model: int, head_dim: int
                     ) -> Tuple[int, int, int, int]:
    H = d_model // head_dim
    return (batch, H, head_dim, head_dim)


def _streams(p: Params, x: jnp.ndarray, x_prev: jnp.ndarray):
    """Token-shift then project the five RWKV streams.

    x: (B, T, d); x_prev: (B, T, d) (x shifted right by one).
    """
    B, T, d = x.shape
    mu = p["mu"].astype(x.dtype)
    one = jnp.ones((), x.dtype)
    xs = [x * mu[i] + x_prev * (one - mu[i]) for i in range(5)]
    r = constrain(xs[0] @ p["wr"], ("batch", None, "model"))
    k = constrain(xs[1] @ p["wk"], ("batch", None, "model"))
    v = constrain(xs[2] @ p["wv"], ("batch", None, "model"))
    g = constrain(jax.nn.silu(xs[3] @ p["wg"]), ("batch", None, "model"))
    dd = jnp.tanh(xs[4] @ p["decay_a"]) @ p["decay_b"]
    H, hd = p["decay_base"].shape
    w = jnp.exp(-jnp.exp(p["decay_base"].astype(jnp.float32).reshape(-1)
                         + dd.astype(jnp.float32)))        # (B,T,d) in (0,1)
    shp = (B, T, H, hd)
    return (r.reshape(shp), k.reshape(shp), v.reshape(shp),
            g, w.reshape(shp))


def time_mix(p: Params, x: jnp.ndarray, state: jnp.ndarray,
             x_last: jnp.ndarray, backend: str = "scan"
             ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Full-sequence time-mix.

    x: (B, T, d) normalized input; state: (B, H, n, n); x_last: (B, d)
    the last pre-norm input of the previous segment (token shift seam).
    Returns (out (B,T,d), new state, new x_last).

    ``backend``: "scan" (pure-jnp step scan — the portable default and
    what the CPU dry-run lowers) or "pallas"/"interpret" — the
    VMEM-resident WKV kernel (kernels/wkv6.py), which removes the
    per-step HBM state round-trip on TPU (§Perf rwkv6 log).
    """
    B, T, d = x.shape
    x_prev = jnp.concatenate([x_last[:, None, :], x[:, :-1]], axis=1)
    r, k, v, g, w = _streams(p, x, x_prev)
    u = p["bonus_u"].astype(jnp.float32)

    if backend != "scan":
        from ..kernels.ops import wkv6
        o4, state = wkv6(r, k, v, w, u, state, backend=backend)
        o = o4.reshape(B, T, d)
        out = (o.astype(x.dtype) * g) @ p["wo"]
        return out, state, x[:, -1]

    def step(S, inp):
        r_t, k_t, v_t, w_t = inp                 # (B,H,hd) each
        kv = k_t[..., :, None] * v_t[..., None, :]          # (B,H,n,n)
        o = jnp.einsum("bhn,bhnm->bhm", r_t,
                       S + u[None, :, :, None] * kv)
        S = w_t[..., :, None] * S + kv
        return S, o

    rT = r.transpose(1, 0, 2, 3).astype(jnp.float32)
    kT = k.transpose(1, 0, 2, 3).astype(jnp.float32)
    vT = v.transpose(1, 0, 2, 3).astype(jnp.float32)
    wT = w.transpose(1, 0, 2, 3)
    state, oT = jax.lax.scan(step, state.astype(jnp.float32),
                             (rT, kT, vT, wT))
    o = oT.transpose(1, 0, 2, 3).reshape(B, T, d)
    out = (o.astype(x.dtype) * g) @ p["wo"]
    return out, state, x[:, -1]


def time_mix_decode(p: Params, x: jnp.ndarray, state: jnp.ndarray,
                    x_last: jnp.ndarray
                    ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One-token time-mix.  x: (B, 1, d)."""
    B, _, d = x.shape
    r, k, v, g, w = _streams(p, x, x_last[:, None, :])
    u = p["bonus_u"].astype(jnp.float32)
    r1, k1, v1, w1 = (t[:, 0].astype(jnp.float32) for t in (r, k, v, w))
    kv = k1[..., :, None] * v1[..., None, :]
    o = jnp.einsum("bhn,bhnm->bhm", r1,
                   state.astype(jnp.float32) + u[None, :, :, None] * kv)
    state = w1[..., :, None] * state.astype(jnp.float32) + kv
    out = (o.reshape(B, 1, d).astype(x.dtype) * g) @ p["wo"]
    return out, state, x[:, -1]


def channel_mix(p: Params, x: jnp.ndarray, x_last: jnp.ndarray
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """RWKV channel-mix (squared-ReLU FFN with receptance gate)."""
    x_prev = jnp.concatenate([x_last[:, None, :], x[:, :-1]], axis=1)
    mix = 0.5 * (x + x_prev)
    kx = jnp.square(jax.nn.relu(mix @ p["ck"]))
    rx = jax.nn.sigmoid(mix @ p["cr"])
    return rx * (kx @ p["cv"]), x[:, -1]
