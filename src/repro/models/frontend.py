"""Stub modality frontends (the one allowed carve-out, see DESIGN.md).

The audio (mel-spectrogram + conformer feature extractor) and vision
(ViT/SigLIP + projector) frontends are NOT implemented; these helpers
produce *shape-correct* precomputed embeddings — deterministic
pseudo-random for smoke tests, ``ShapeDtypeStruct`` for the dry-run —
that the fully-implemented transformer backbones consume.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig

# llava-next anyres tiling: base 24×24 patch grid = 576 tokens per tile.
VLM_PATCHES = 576


def patch_embeds(cfg: ArchConfig, batch: int, dtype=jnp.float32,
                 seed: int = 0) -> jnp.ndarray:
    """Vision stub: (B, n_prefix, d_model) patch embeddings."""
    key = jax.random.PRNGKey(seed)
    return (jax.random.normal(key, (batch, cfg.n_prefix, cfg.d_model))
            * 0.02).astype(dtype)


def patch_embed_spec(cfg: ArchConfig, batch: int, dtype=jnp.bfloat16
                     ) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct((batch, cfg.n_prefix, cfg.d_model), dtype)


def frame_embeds(cfg: ArchConfig, batch: int, seq_len: int,
                 dtype=jnp.float32, seed: int = 0) -> jnp.ndarray:
    """Audio stub: (B, seq_len // enc_seq_divisor, d_model) frames."""
    n = max(1, seq_len // cfg.enc_seq_divisor)
    key = jax.random.PRNGKey(seed + 1)
    return (jax.random.normal(key, (batch, n, cfg.d_model)) * 0.02
            ).astype(dtype)


def frame_embed_spec(cfg: ArchConfig, batch: int, seq_len: int,
                     dtype=jnp.bfloat16) -> jax.ShapeDtypeStruct:
    n = max(1, seq_len // cfg.enc_seq_divisor)
    return jax.ShapeDtypeStruct((batch, n, cfg.d_model), dtype)
