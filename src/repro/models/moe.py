"""Mixture-of-Experts FFN with GShard-style grouped capacity dispatch.

Dispatch is *flop-honest* and *sharding-preserving*:

* tokens are dispatched **per batch row** (the GShard "group" axis): each
  row computes its own router top-k, position-in-expert cumsum and
  capacity ``C = cf·S·k/E``.  The group axis is exactly the axis the
  auto-sharder puts on (``pod``, ``data``), so dispatch, expert compute
  and combine all stay batch-sharded — a single *global* capacity buffer
  would be replicated by SPMD and burn ``data``-axis-many times the
  FLOPs (measured: 16×; see EXPERIMENTS.md §Perf notes);
* scatter/gather into the ``(B, E, C, d)`` buffer costs no matmul FLOPs,
  so compiled FLOPs scale with ``top_k`` (active experts), not
  ``n_experts`` — what MODEL_FLOPS = 6·N_active·D expects;
* tokens overflowing a row's per-expert capacity are dropped (standard
  GShard/Switch semantics); the auxiliary load-balance loss keeps drops
  rare in training.

Sharding: expert tensors carry a leading ``E`` dim placed on ``model``
when divisible (expert parallelism — llama4's 128 experts over 16); the
buffer's ``E`` dim then lowers to an all-to-all, the communication
pattern Kant's HBD-granular placement (§3.3.5) exists to serve.  With
indivisible ``E`` (mixtral's 8) the expert weights are TP-sharded on
``d_ff`` instead and the buffer stays batch-sharded only.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..sharding.context import constrain
import math

from .layers import Params, dense_init, spec


def init_moe(key, d_model: int, d_ff: int, n_experts: int, dtype,
             out_scale: float = 1.0) -> Params:
    kr, k1, k2, k3 = jax.random.split(key, 4)
    return {
        "router": dense_init(kr, (d_model, n_experts), dtype),
        "w_gate": dense_init(k1, (n_experts, d_model, d_ff), dtype),
        "w_up": dense_init(k2, (n_experts, d_model, d_ff), dtype),
        "w_down": dense_init(k3, (n_experts, d_ff, d_model), dtype,
                             scale=out_scale / math.sqrt(d_ff)),
    }


def spec_moe(d_model: int, d_ff: int, n_experts: int, dtype) -> Params:
    return {
        "router": spec((d_model, n_experts), dtype),
        "w_gate": spec((n_experts, d_model, d_ff), dtype),
        "w_up": spec((n_experts, d_model, d_ff), dtype),
        "w_down": spec((n_experts, d_ff, d_model), dtype),
    }


def capacity(tokens_per_group: int, n_experts: int, top_k: int,
             capacity_factor: float) -> int:
    c = int(capacity_factor * tokens_per_group * top_k / n_experts)
    return max(4, -(-c // 4) * 4)               # multiple of 4, >= 4


def moe_ffn(p: Params, x: jnp.ndarray, *, top_k: int,
            capacity_factor: float = 1.25, dispatch: str = "sort"
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (out (B, S, d), aux load-balance loss scalar).

    ``dispatch="sort"`` (default) builds the (B, E, C, d) expert buffer
    with an argsort-by-expert + gathers and combines with a reshape-sum —
    entirely scatter-free.  SPMD partitions gathers on batch-sharded,
    d-replicated operands locally, where the ``"scatter"`` formulation
    (GShard-style ``.at[].add``) lowers to a mesh-transposing
    collective-permute plus a full-buffer all-reduce per layer
    (~6 s/step of the mixtral-8x7b collective term; §Perf mixtral log).
    ``"scatter"`` is kept as the reference/baseline formulation.
    """
    B, S, d = x.shape
    E = p["router"].shape[-1]
    C = capacity(S, E, top_k, capacity_factor)

    logits = (x.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                   # (B, S, E)
    gate_vals, expert_ids = jax.lax.top_k(probs, top_k)       # (B, S, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # Per-row position of each (token, k) assignment in its expert queue.
    flat_expert = expert_ids.reshape(B, S * top_k)            # (B, S*k)
    onehot = jax.nn.one_hot(flat_expert, E, dtype=jnp.int32)  # (B, S*k, E)
    pos_in_expert = jnp.cumsum(onehot, axis=1) - onehot       # exclusive
    pos = jnp.take_along_axis(pos_in_expert, flat_expert[..., None],
                              axis=2)[..., 0]                 # (B, S*k)
    keep = pos < C
    slot = jnp.where(keep, flat_expert * C + pos, E * C)      # E*C = trash

    tok_idx = jnp.repeat(jnp.arange(S), top_k)                # (S*k,)
    if dispatch == "sort":
        N = S * top_k
        counts = onehot.sum(axis=1)                           # (B, E)
        starts = jnp.cumsum(counts, axis=1) - counts          # exclusive
        order = jnp.argsort(flat_expert, axis=1, stable=True)  # (B, N)
        # sorted rank start[e] + c  ->  assignment id  ->  token id.
        grid = starts[:, :, None] + jnp.arange(C)[None, None, :]
        valid = jnp.arange(C)[None, None, :] <             jnp.minimum(counts, C)[:, :, None]                # (B, E, C)
        assign = jnp.take_along_axis(
            order, jnp.clip(grid, 0, N - 1).reshape(B, E * C), axis=1)
        token = assign // top_k                               # (B, E*C)
        gathered = jnp.take_along_axis(x, token[..., None], axis=1)
        expert_in = gathered.reshape(B, E, C, d)             * valid[..., None].astype(x.dtype)
    else:
        # Row-local scatter into (B, E*C+1, d); trash absorbs overflow.
        xa = x[:, tok_idx]                                    # (B, S*k, d)
        row = jnp.arange(B)[:, None]
        buf = jnp.zeros((B, E * C + 1, d), dtype=x.dtype)
        buf = buf.at[row, slot].add(xa)
        expert_in = buf[:, :E * C].reshape(B, E, C, d)
    expert_in = constrain(expert_in, ("batch", "model", None, None))

    # Batched expert SwiGLU — the only real FLOPs in this function.
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", expert_in, p["w_gate"])) \
        * jnp.einsum("becd,edf->becf", expert_in, p["w_up"])
    h = constrain(h, ("batch", "model", None, None))
    expert_out = constrain(
        jnp.einsum("becf,efd->becd", h, p["w_down"]),
        ("batch", "model", None, None))

    # Row-local gather back, weighted by the (renormalized) gates.
    flat_out = jnp.concatenate(
        [expert_out.reshape(B, E * C, d),
         jnp.zeros((B, 1, d), dtype=expert_out.dtype)], axis=1)
    per_assign = jnp.take_along_axis(flat_out, slot[..., None], axis=1)
    # Combine in x.dtype: gate_vals is f32 (softmax); multiplying the bf16
    # expert outputs by it promotes the whole combine — and the transpose
    # of that convert drags f32 cotangents through every dispatch
    # scatter/gather collective in the backward (2x bytes; §Perf mixtral).
    gates = (gate_vals.reshape(B, S * top_k)[..., None]
             * keep[..., None].astype(jnp.float32)).astype(x.dtype)
    per_assign = per_assign * gates
    # tok_idx repeats each token top_k times, so the .at[].add combine is
    # exactly a reshape-sum over k — scatter-free.
    out = per_assign.reshape(B, S, top_k, d).sum(axis=2).astype(x.dtype)
    out = constrain(out, ("batch", None, None))

    # Switch-style auxiliary load-balance loss.
    me = probs.mean(axis=(0, 1))                              # (E,)
    ce = onehot.sum(axis=(0, 1)).astype(jnp.float32) / (B * S * top_k)
    aux = E * jnp.sum(me * ce)
    return out, aux
