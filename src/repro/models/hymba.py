"""Hymba hybrid block: parallel attention + Mamba(SSM) heads
[arXiv:2411.13676].

Each layer projects the input once and feeds *both* a sliding-window GQA
attention branch and a Mamba-style selective-SSM branch; the two outputs
are independently normalized and averaged (the paper's "parallel hybrid
head" design).  Most Hymba layers use SWA — we window every layer (noted
in DESIGN.md) which is what makes the long_500k decode shape O(window).

SSM branch (diagonal selective scan, state size N = ``ssm_state``)::

    h_t = exp(Δ_t ⊙ A) ⊙ h_{t-1} + Δ_t ⊙ (x_t ⊗ B_t)
    y_t = h_t · C_t + D ⊙ x_t

with input-dependent Δ, B, C (the Mamba selectivity).  Decode carries
``h`` explicitly — O(1) state.
"""

from __future__ import annotations

from typing import Dict, Tuple

import math

import jax
import jax.numpy as jnp

from ..sharding.context import constrain
from .layers import (Params, dense_init, init_attn, rmsnorm, spec,
                     spec_attn)

DT_RANK = 32


def init_ssm(key, d_model: int, n_state: int, dtype,
             out_scale: float = 1.0) -> Params:
    ks = jax.random.split(key, 6)
    return {
        "w_in": dense_init(ks[0], (d_model, d_model), dtype),
        "w_bc": dense_init(ks[1], (d_model, 2 * n_state), dtype),
        "w_dt": dense_init(ks[2], (d_model, DT_RANK), dtype),
        "w_dt2": dense_init(ks[3], (DT_RANK, d_model), dtype),
        "a_log": jnp.zeros((d_model, n_state), dtype),   # A = -exp(a_log)
        "d_skip": jnp.ones((d_model,), dtype),
        "w_out": dense_init(ks[4], (d_model, d_model), dtype,
                            scale=out_scale / math.sqrt(d_model)),
    }


def spec_ssm(d_model: int, n_state: int, dtype) -> Params:
    return {
        "w_in": spec((d_model, d_model), dtype),
        "w_bc": spec((d_model, 2 * n_state), dtype),
        "w_dt": spec((d_model, DT_RANK), dtype),
        "w_dt2": spec((DT_RANK, d_model), dtype),
        "a_log": spec((d_model, n_state), dtype),
        "d_skip": spec((d_model,), dtype),
        "w_out": spec((d_model, d_model), dtype),
    }


def ssm_state_shape(batch: int, d_model: int, n_state: int
                    ) -> Tuple[int, int, int]:
    return (batch, d_model, n_state)


def _ssm_inputs(p: Params, x: jnp.ndarray):
    """x: (B, T, d) -> (u, dt, B_t, C_t) selective-scan inputs."""
    u = constrain(jax.nn.silu(x @ p["w_in"]),
                  ("batch", None, "model"))              # (B,T,d)
    bc = x @ p["w_bc"]
    n = p["a_log"].shape[-1]
    B_t, C_t = bc[..., :n], bc[..., n:]                     # (B,T,N)
    dt = jax.nn.softplus((x @ p["w_dt"]) @ p["w_dt2"])      # (B,T,d)
    return u, dt, B_t, C_t


def ssm_scan(p: Params, x: jnp.ndarray, h0: jnp.ndarray
             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence selective scan.  x: (B,T,d); h0: (B,d,N)."""
    B, T, d = x.shape
    u, dt, B_t, C_t = _ssm_inputs(p, x)
    A = -jnp.exp(p["a_log"].astype(jnp.float32))            # (d,N)

    def step(h, inp):
        u_t, dt_t, b_t, c_t = inp                           # (B,d),(B,d),(B,N),(B,N)
        decay = jnp.exp(dt_t[..., None] * A[None])          # (B,d,N)
        h = decay * h + (dt_t * u_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    xs = (u.transpose(1, 0, 2).astype(jnp.float32),
          dt.transpose(1, 0, 2).astype(jnp.float32),
          B_t.transpose(1, 0, 2).astype(jnp.float32),
          C_t.transpose(1, 0, 2).astype(jnp.float32))
    h, yT = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    y = yT.transpose(1, 0, 2).astype(x.dtype)
    y = y + u * p["d_skip"]
    return (y @ p["w_out"]), h


def ssm_step(p: Params, x: jnp.ndarray, h: jnp.ndarray
             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One-token selective scan.  x: (B,1,d); h: (B,d,N)."""
    u, dt, B_t, C_t = _ssm_inputs(p, x)
    A = -jnp.exp(p["a_log"].astype(jnp.float32))
    u1, dt1 = u[:, 0].astype(jnp.float32), dt[:, 0].astype(jnp.float32)
    b1, c1 = B_t[:, 0].astype(jnp.float32), C_t[:, 0].astype(jnp.float32)
    decay = jnp.exp(dt1[..., None] * A[None])
    h = decay * h.astype(jnp.float32) + (dt1 * u1)[..., None] * b1[:, None]
    y = jnp.einsum("bdn,bn->bd", h, c1)[:, None, :].astype(x.dtype)
    y = y + u * p["d_skip"]
    return (y @ p["w_out"]), h


def init_hymba_block(key, d_model: int, n_heads: int, n_kv: int,
                     head_dim: int, n_state: int, dtype,
                     out_scale: float = 1.0) -> Params:
    ka, ks, _ = jax.random.split(key, 3)
    return {
        "attn": init_attn(ka, d_model, n_heads, n_kv, head_dim, dtype,
                          out_scale=out_scale),
        "ssm": init_ssm(ks, d_model, n_state, dtype, out_scale=out_scale),
        "norm_attn_out": jnp.ones((d_model,), dtype),
        "norm_ssm_out": jnp.ones((d_model,), dtype),
    }


def spec_hymba_block(d_model: int, n_heads: int, n_kv: int, head_dim: int,
                     n_state: int, dtype) -> Params:
    return {
        "attn": spec_attn(d_model, n_heads, n_kv, head_dim, dtype),
        "ssm": spec_ssm(d_model, n_state, dtype),
        "norm_attn_out": spec((d_model,), dtype),
        "norm_ssm_out": spec((d_model,), dtype),
    }
