"""Unified model builder: ``ArchConfig`` -> init / forward / prefill /
decode for every family in the zoo (dense, moe, ssm, hybrid, encdec, vlm).

Design notes
------------
* **Stacked layers + ``lax.scan``** — per-layer parameters are stacked
  along a leading ``L`` axis and the forward pass scans over them.  This
  keeps the HLO one-layer-sized, which is what makes the 512-device CPU
  dry-run compile tractable for 88-layer models.
* **Three modes** — ``forward`` (training, teacher-forced logits),
  ``prefill`` (same pass but emits the ring-buffer KV/SSM cache),
  ``decode_step`` (one token against the cache).  Tests assert prefill +
  step-wise decode reproduces ``forward`` logits exactly.
* **Spec twins** — ``param_specs`` / ``cache_specs`` mirror ``init`` /
  ``init_cache`` with ``ShapeDtypeStruct`` so the multi-pod dry-run never
  allocates.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import math

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..sharding.context import constrain
from . import hymba as hy
from . import moe as moe_mod
from . import rwkv6 as rw
from .layers import (Params, chunked_attention, cross_attention,
                     decode_attention, embed, init_attn, init_embed,
                     init_mlp, memory_kv, pad_axis, prefill_attention,
                     rmsnorm, self_attention, spec, spec_attn, spec_mlp)

PyTree = Any


# ---------------------------------------------------------------------------
# Block init/spec per family
# ---------------------------------------------------------------------------
def _residual_out_scale(n_layers: int) -> float:
    """GPT-2/Megatron depth scaling for residual-output projections:
    keeps the backward pass ~O(1) per layer instead of compounding
    (16-layer stacks showed 1e7+ init grad norms without it)."""
    return 1.0 / math.sqrt(max(1, 2 * n_layers))


def _init_block(cfg: ArchConfig, key, dtype) -> Params:
    d, f = cfg.d_model, cfg.d_ff
    rs = _residual_out_scale(cfg.n_layers)
    if cfg.family == "ssm":
        return rw.init_rwkv_block(key, d, f, cfg.head_dim or 64, dtype,
                                  out_scale=rs)
    k1, k2, k3 = jax.random.split(key, 3)
    p: Params = {"norm1": jnp.ones((d,), dtype),
                 "norm2": jnp.ones((d,), dtype)}
    if cfg.family == "hybrid":
        p.update(hy.init_hymba_block(k1, d, cfg.n_heads, cfg.n_kv_heads,
                                     cfg.head_dim, cfg.ssm_state, dtype,
                                     out_scale=rs))
        p["mlp"] = init_mlp(k2, d, f, dtype, out_scale=rs)
        return p
    p["attn"] = init_attn(k1, d, cfg.n_heads, cfg.n_kv_heads,
                          cfg.head_dim, dtype, out_scale=rs)
    if cfg.family == "moe":
        p["moe"] = moe_mod.init_moe(k2, d, f, cfg.n_experts, dtype,
                                    out_scale=rs)
    else:
        p["mlp"] = init_mlp(k2, d, f, dtype, out_scale=rs)
    if cfg.family == "encdec":
        p["norm_x"] = jnp.ones((d,), dtype)
        p["xattn"] = init_attn(k3, d, cfg.n_heads, cfg.n_kv_heads,
                               cfg.head_dim, dtype, out_scale=rs)
    return p


def _spec_block(cfg: ArchConfig, dtype) -> Params:
    d, f = cfg.d_model, cfg.d_ff
    if cfg.family == "ssm":
        return rw.spec_rwkv_block(d, f, cfg.head_dim or 64, dtype)
    p: Params = {"norm1": spec((d,), dtype), "norm2": spec((d,), dtype)}
    if cfg.family == "hybrid":
        p.update(hy.spec_hymba_block(d, cfg.n_heads, cfg.n_kv_heads,
                                     cfg.head_dim, cfg.ssm_state, dtype))
        p["mlp"] = spec_mlp(d, f, dtype)
        return p
    p["attn"] = spec_attn(d, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
                          dtype)
    if cfg.family == "moe":
        p["moe"] = moe_mod.spec_moe(d, f, cfg.n_experts, dtype)
    else:
        p["mlp"] = spec_mlp(d, f, dtype)
    if cfg.family == "encdec":
        p["norm_x"] = spec((d,), dtype)
        p["xattn"] = spec_attn(d, cfg.n_heads, cfg.n_kv_heads,
                               cfg.head_dim, dtype)
    return p


def _enc_init_block(cfg: ArchConfig, key, dtype) -> Params:
    d, f = cfg.d_model, cfg.d_ff
    rs = _residual_out_scale(cfg.n_enc_layers)
    k1, k2 = jax.random.split(key)
    return {"norm1": jnp.ones((d,), dtype), "norm2": jnp.ones((d,), dtype),
            "attn": init_attn(k1, d, cfg.n_heads, cfg.n_kv_heads,
                              cfg.head_dim, dtype, out_scale=rs),
            "mlp": init_mlp(k2, d, f, dtype, out_scale=rs)}


def _enc_spec_block(cfg: ArchConfig, dtype) -> Params:
    d, f = cfg.d_model, cfg.d_ff
    return {"norm1": spec((d,), dtype), "norm2": spec((d,), dtype),
            "attn": spec_attn(d, cfg.n_heads, cfg.n_kv_heads,
                              cfg.head_dim, dtype),
            "mlp": spec_mlp(d, f, dtype)}


def _stack_specs(tree: PyTree, n: int) -> PyTree:
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype), tree)


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig

    # -- parameters -----------------------------------------------------
    def init(self, key, dtype=jnp.float32) -> Params:
        cfg = self.cfg
        ke, kl, kh, kenc = jax.random.split(key, 4)
        layer_keys = jax.random.split(kl, cfg.n_layers)
        params: Params = {
            "embed": init_embed(ke, cfg.vocab, cfg.d_model, dtype),
            "layers": jax.vmap(
                lambda k: _init_block(cfg, k, dtype))(layer_keys),
            "final_norm": jnp.ones((cfg.d_model,), dtype),
            "lm_head": init_embed(kh, cfg.vocab, cfg.d_model, dtype).T,
        }
        if cfg.n_enc_layers:
            enc_keys = jax.random.split(kenc, cfg.n_enc_layers)
            params["encoder"] = jax.vmap(
                lambda k: _enc_init_block(cfg, k, dtype))(enc_keys)
            params["enc_norm"] = jnp.ones((cfg.d_model,), dtype)
        return params

    def param_specs(self, dtype=jnp.bfloat16) -> Params:
        cfg = self.cfg
        params: Params = {
            "embed": spec((cfg.vocab, cfg.d_model), dtype),
            "layers": _stack_specs(_spec_block(cfg, dtype), cfg.n_layers),
            "final_norm": spec((cfg.d_model,), dtype),
            "lm_head": spec((cfg.d_model, cfg.vocab), dtype),
        }
        if cfg.n_enc_layers:
            params["encoder"] = _stack_specs(_enc_spec_block(cfg, dtype),
                                             cfg.n_enc_layers)
            params["enc_norm"] = spec((cfg.d_model,), dtype)
        return params

    def n_params(self) -> int:
        import numpy as _np
        specs = self.param_specs()
        return int(sum(int(_np.prod(s.shape))
                       for s in jax.tree.leaves(specs)))

    def n_active_params(self) -> int:
        """MoE: count top_k of n_experts expert params; else n_params."""
        cfg = self.cfg
        total = self.n_params()
        if cfg.family != "moe":
            return total
        expert = 3 * cfg.d_model * cfg.d_ff * cfg.n_layers
        inactive = expert * (cfg.n_experts - cfg.top_k)
        return total - inactive

    # -- input assembly ---------------------------------------------------
    def _input_seq(self, params: Params, batch: Dict[str, jnp.ndarray]
                   ) -> jnp.ndarray:
        """Token embeddings, with the VLM patch prefix spliced in front."""
        x = embed(params["embed"], batch["tokens"])
        if self.cfg.family == "vlm":
            x = jnp.concatenate(
                [batch["patch_embeds"].astype(x.dtype), x], axis=1)
        return constrain(x, ("batch", "seq", None))

    def _encode(self, params: Params, enc_embeds: jnp.ndarray
                ) -> jnp.ndarray:
        """Encoder stack over precomputed frame embeddings (audio stub)."""
        cfg = self.cfg

        def body(x, lp):
            h = x + self_attention(lp["attn"], rmsnorm(x, lp["norm1"],
                                                       cfg.norm_eps),
                                   theta=cfg.rope_theta, causal=False)
            from .layers import mlp as _mlp
            h = h + _mlp(lp["mlp"], rmsnorm(h, lp["norm2"], cfg.norm_eps))
            return h, None

        x, _ = jax.lax.scan(body, enc_embeds.astype(params["embed"].dtype),
                            params["encoder"])
        return rmsnorm(x, params["enc_norm"], cfg.norm_eps)

    # -- full-sequence pass (training / prefill) ---------------------------
    def _seq_block(self, lp: Params, x: jnp.ndarray, *,
                   memory: Optional[jnp.ndarray], cache_window: int,
                   emit_cache: bool
                   ) -> Tuple[jnp.ndarray, Optional[Params], jnp.ndarray]:
        """Apply one decoder block to the full sequence.

        Returns (x, cache_entry or None, aux_loss)."""
        cfg = self.cfg
        from .layers import mlp as _mlp
        aux = jnp.zeros((), jnp.float32)
        if cfg.family == "ssm":
            B = x.shape[0]
            st0 = jnp.zeros(rw.rwkv_state_shape(B, cfg.d_model,
                                                cfg.head_dim or 64),
                            jnp.float32)
            xt = rmsnorm(x, lp["ln_t"], cfg.norm_eps)
            t_out, st, xl_t = rw.time_mix(lp, xt, st0,
                                          jnp.zeros_like(xt[:, 0]))
            x = x + t_out
            xc = rmsnorm(x, lp["ln_c"], cfg.norm_eps)
            c_out, xl_c = rw.channel_mix(lp, xc, jnp.zeros_like(xc[:, 0]))
            x = x + c_out
            cache = ({"state": st, "x_last_t": xl_t, "x_last_c": xl_c}
                     if emit_cache else None)
            return x, cache, aux

        h_in = rmsnorm(x, lp["norm1"], cfg.norm_eps)
        cache: Optional[Params] = None
        if emit_cache:
            a_out, k_c, v_c = prefill_attention(
                lp["attn"], h_in, cache_window, theta=cfg.rope_theta,
                window=cfg.window)
        else:
            a_out = self_attention(lp["attn"], h_in, theta=cfg.rope_theta,
                                   window=cfg.window)
        if cfg.family == "hybrid":
            B = x.shape[0]
            s_out, h_ssm = hy.ssm_scan(
                lp["ssm"], h_in,
                jnp.zeros(hy.ssm_state_shape(B, cfg.d_model,
                                             cfg.ssm_state), jnp.float32))
            a_out = rmsnorm(a_out, lp["norm_attn_out"], cfg.norm_eps)
            s_out = rmsnorm(s_out, lp["norm_ssm_out"], cfg.norm_eps)
            x = x + 0.5 * (a_out + s_out)
            if emit_cache:
                cache = {"k": k_c, "v": v_c, "ssm": h_ssm}
        else:
            x = x + a_out
            if emit_cache:
                cache = {"k": k_c, "v": v_c}
        if cfg.family == "encdec":
            xm = rmsnorm(x, lp["norm_x"], cfg.norm_eps)
            mk, mv = memory_kv(lp["xattn"], memory)
            x = x + cross_attention(lp["xattn"], xm, mk, mv)
        h2 = rmsnorm(x, lp["norm2"], cfg.norm_eps)
        if cfg.family == "moe":
            m_out, aux = moe_mod.moe_ffn(lp["moe"], h2, top_k=cfg.top_k,
                                         capacity_factor=cfg.capacity_factor)
            x = x + m_out
        else:
            x = x + _mlp(lp["mlp"], h2)
        return x, cache, aux

    def _run_layers(self, params: Params, x: jnp.ndarray, *,
                    memory: Optional[jnp.ndarray], cache_window: int,
                    emit_cache: bool, remat: bool = False
                    ) -> Tuple[jnp.ndarray, Optional[PyTree], jnp.ndarray]:
        def body(carry, lp):
            h, aux_acc = carry
            h = constrain(h, ("batch", "seq", None))
            h, cache, aux = self._seq_block(
                lp, h, memory=memory, cache_window=cache_window,
                emit_cache=emit_cache)
            h = constrain(h, ("batch", "seq", None))
            return (h, aux_acc + aux), cache

        fn = jax.checkpoint(body) if remat else body
        (x, aux), caches = jax.lax.scan(
            fn, (x, jnp.zeros((), jnp.float32)), params["layers"])
        return x, caches, aux

    # -- public entry points ------------------------------------------------
    def forward(self, params: Params, batch: Dict[str, jnp.ndarray], *,
                remat: bool = False
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Teacher-forced logits over the full sequence.

        Returns (logits (B, S_out, vocab), aux loss scalar)."""
        cfg = self.cfg
        memory = (self._encode(params, batch["enc_embeds"])
                  if cfg.n_enc_layers else None)
        x = self._input_seq(params, batch)
        x, _, aux = self._run_layers(params, x, memory=memory,
                                     cache_window=1, emit_cache=False,
                                     remat=remat)
        x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        if cfg.family == "vlm":                  # only text positions score
            x = x[:, batch["patch_embeds"].shape[1]:]
        # V over "model": keeps dlogits / d(lm_head) transients sharded in
        # the backward — unconstrained, SPMD all-gathers a full f32 vocab
        # matrix per device (1.6 GB on mistral-large; §Perf iteration log).
        logits = constrain(x @ params["lm_head"], ("batch", None, "model"))
        return logits, aux

    # -- caches -----------------------------------------------------------
    def cache_window(self, seq_len: int) -> int:
        cfg = self.cfg
        if cfg.family == "ssm":
            return 1                            # O(1) recurrent state
        w = cfg.window if cfg.window > 0 else seq_len
        return min(seq_len, w, cfg.decode_window)

    def _layer_cache_spec(self, B: int, W: int, dtype) -> PyTree:
        cfg = self.cfg
        hd, Kh = cfg.head_dim, cfg.n_kv_heads
        if cfg.family == "ssm":
            H = cfg.d_model // (cfg.head_dim or 64)
            n = cfg.head_dim or 64
            return {"state": spec((B, H, n, n), jnp.float32),
                    "x_last_t": spec((B, cfg.d_model), dtype),
                    "x_last_c": spec((B, cfg.d_model), dtype)}
        entry = {"k": spec((B, W, Kh, hd), dtype),
                 "v": spec((B, W, Kh, hd), dtype)}
        if cfg.family == "hybrid":
            entry["ssm"] = spec((B, cfg.d_model, cfg.ssm_state),
                                jnp.float32)
        return entry

    def cache_specs(self, B: int, seq_len: int, dtype=jnp.bfloat16
                    ) -> PyTree:
        cfg = self.cfg
        W = self.cache_window(seq_len)
        cache: PyTree = {
            "layers": _stack_specs(self._layer_cache_spec(B, W, dtype),
                                   cfg.n_layers),
            "t": spec((), jnp.int32),
        }
        if cfg.n_enc_layers:
            S_enc = max(1, seq_len // cfg.enc_seq_divisor)
            mem = {"mk": spec((cfg.n_layers, B, S_enc, cfg.n_kv_heads,
                               cfg.head_dim), dtype),
                   "mv": spec((cfg.n_layers, B, S_enc, cfg.n_kv_heads,
                               cfg.head_dim), dtype)}
            cache["memory"] = mem
        return cache

    def init_cache(self, B: int, seq_len: int, dtype=jnp.float32) -> PyTree:
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                            self.cache_specs(B, seq_len, dtype))

    # -- prefill ------------------------------------------------------------
    def prefill(self, params: Params, batch: Dict[str, jnp.ndarray],
                seq_len: Optional[int] = None
                ) -> Tuple[jnp.ndarray, PyTree]:
        """Run the prompt; return (last-position logits (B, vocab), cache).

        ``seq_len`` sizes the cache window (defaults to the prompt length,
        i.e. full-history cache)."""
        cfg = self.cfg
        memory = (self._encode(params, batch["enc_embeds"])
                  if cfg.n_enc_layers else None)
        x = self._input_seq(params, batch)
        S_total = x.shape[1]
        W = self.cache_window(seq_len or S_total)
        x, caches, _ = self._run_layers(params, x, memory=memory,
                                        cache_window=W, emit_cache=True)
        x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        logits = x[:, -1] @ params["lm_head"]
        cache: PyTree = {"layers": caches,
                         "t": jnp.asarray(S_total, jnp.int32)}
        if cfg.n_enc_layers:
            mk, mv = jax.vmap(memory_kv, in_axes=(0, None))(
                params["layers"]["xattn"], memory)
            cache["memory"] = {"mk": mk, "mv": mv}
        return logits, cache

    # -- decode ---------------------------------------------------------------
    def _decode_block(self, lp: Params, x: jnp.ndarray, cache: PyTree,
                      t, memory_layer: Optional[PyTree]
                      ) -> Tuple[jnp.ndarray, PyTree]:
        cfg = self.cfg
        from .layers import mlp as _mlp
        if cfg.family == "ssm":
            xt = rmsnorm(x, lp["ln_t"], cfg.norm_eps)
            t_out, st, xl_t = rw.time_mix_decode(lp, xt, cache["state"],
                                                 cache["x_last_t"])
            x = x + t_out
            xc = rmsnorm(x, lp["ln_c"], cfg.norm_eps)
            c_out, xl_c = rw.channel_mix(lp, xc, cache["x_last_c"])
            x = x + c_out
            return x, {"state": st, "x_last_t": xl_t, "x_last_c": xl_c}

        h_in = rmsnorm(x, lp["norm1"], cfg.norm_eps)
        new_cache = dict(cache)
        if cfg.family == "hybrid":
            a_out, k_c, v_c = decode_attention(
                lp["attn"], h_in, cache["k"], cache["v"], t,
                theta=cfg.rope_theta, window=cfg.window)
            s_out, h_ssm = hy.ssm_step(lp["ssm"], h_in, cache["ssm"])
            a_out = rmsnorm(a_out, lp["norm_attn_out"], cfg.norm_eps)
            s_out = rmsnorm(s_out, lp["norm_ssm_out"], cfg.norm_eps)
            x = x + 0.5 * (a_out + s_out)
            new_cache.update(k=k_c, v=v_c, ssm=h_ssm)
        else:
            a_out, k_c, v_c = decode_attention(
                lp["attn"], h_in, cache["k"], cache["v"], t,
                theta=cfg.rope_theta, window=cfg.window)
            x = x + a_out
            new_cache.update(k=k_c, v=v_c)
        if cfg.family == "encdec":
            xm = rmsnorm(x, lp["norm_x"], cfg.norm_eps)
            x = x + cross_attention(lp["xattn"], xm, memory_layer["mk"],
                                    memory_layer["mv"])
        h2 = rmsnorm(x, lp["norm2"], cfg.norm_eps)
        if cfg.family == "moe":
            m_out, _ = moe_mod.moe_ffn(lp["moe"], h2, top_k=cfg.top_k,
                                       capacity_factor=cfg.capacity_factor)
            x = x + m_out
        else:
            x = x + _mlp(lp["mlp"], h2)
        return x, new_cache

    def decode_step(self, params: Params, cache: PyTree,
                    token: jnp.ndarray
                    ) -> Tuple[jnp.ndarray, PyTree]:
        """One decode step.  token: (B,) int32.  Returns (logits (B,vocab),
        updated cache)."""
        cfg = self.cfg
        x = embed(params["embed"], token[:, None])
        t = cache["t"]

        if cfg.n_enc_layers:
            xs = (params["layers"], cache["layers"],
                  {"mk": cache["memory"]["mk"],
                   "mv": cache["memory"]["mv"]})

            def body(h, inp):
                lp, lc, mem = inp
                h, nc = self._decode_block(lp, h, lc, t, mem)
                return h, nc
        else:
            xs = (params["layers"], cache["layers"])

            def body(h, inp):
                lp, lc = inp
                h, nc = self._decode_block(lp, h, lc, t, None)
                return h, nc

        x = constrain(x, ("batch", "seq", None))
        x, new_layer_caches = jax.lax.scan(body, x, xs)
        x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        logits = x[:, -1] @ params["lm_head"]
        new_cache = dict(cache)
        new_cache["layers"] = new_layer_caches
        new_cache["t"] = t + 1
        return logits, new_cache
