"""Model zoo: the workloads Kant schedules (see DESIGN.md §3)."""

from .model import Model

__all__ = ["Model"]
