"""Transformer building blocks shared by every family in the zoo.

Everything here is pure-functional JAX: parameters are plain dict pytrees
created by ``init_*`` helpers (or described by ``spec_*`` twins returning
``ShapeDtypeStruct`` for the allocation-free dry-run).

Attention is implemented *chunked* (flash-style streaming softmax over KV
blocks, and over Q blocks) so the 32k-prefill shape never materializes an
S×S score matrix — the TPU-native adaptation of memory-bound attention,
kept in pure JAX because Kant's contribution has no attention kernel
(see DESIGN.md).  Decode uses a ring-buffer KV cache so the windowed
long-context variant (long_500k) is O(window), not O(seq).
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..sharding.context import axis_size, constrain

Params = Dict[str, Any]

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Param helpers
# ---------------------------------------------------------------------------
def dense_init(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def spec(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(int(s) for s in shape), dtype)


def round_up(n: int, m: int) -> int:
    return -(-n // m) * m


def pad_axis(x: jnp.ndarray, axis: int, to: int) -> jnp.ndarray:
    pad = to - x.shape[axis]
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------
def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-5
            ) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
            ).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float
               ) -> jnp.ndarray:
    """x: (B, S, H, hd); positions: (S,) or (B, S)."""
    hd = x.shape[-1]
    exponents = jnp.arange(0, hd, 2, dtype=jnp.float32) / hd
    freqs = 1.0 / (theta ** exponents)                    # (hd/2,)
    if positions.ndim == 1:
        positions = positions[None, :]
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B,S,hd/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                          axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Chunked (flash-style) attention
# ---------------------------------------------------------------------------
def chunked_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                      causal: bool = True, window: int = 0,
                      q_offset: int = 0, q_chunk: int = 2048,
                      kv_chunk: int = 1024) -> jnp.ndarray:
    """GQA attention without materializing the full score matrix.

    q: (B, Sq, H, hd); k, v: (B, Sk, Kh, hd) with H = Kh * G.
    ``q_offset`` is the absolute position of q[0] relative to k[0].
    ``window > 0`` restricts each query to the last ``window`` keys.
    """
    B, Sq, H, hd = q.shape
    Sk, Kh = k.shape[1], k.shape[2]
    G = H // Kh
    scale = 1.0 / math.sqrt(hd)

    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Sk)
    Sq_p, Sk_p = round_up(Sq, q_chunk), round_up(Sk, kv_chunk)
    # Heads stay FLAT (B,S,H,hd): a (Kh,G) reshape of a model-sharded H
    # axis defeats XLA's SPMD propagation (involuntary full remat);
    # instead K/V blocks are broadcast to H heads inside the scan body —
    # flop-free, block-sized, and every einsum keeps H cleanly sharded.
    q = pad_axis(q, 1, Sq_p)
    k = pad_axis(k, 1, Sk_p)
    v = pad_axis(v, 1, Sk_p)
    n_q, n_k = Sq_p // q_chunk, Sk_p // kv_chunk
    # (n_k, B, kv_chunk, Kh, hd) so the scan streams one block at a time.
    ks = k.reshape(B, n_k, kv_chunk, Kh, hd).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, n_k, kv_chunk, Kh, hd).transpose(1, 0, 2, 3, 4)

    # Attention-chunk layout: shard heads over ``model`` when the head
    # count divides it; otherwise shard the q-chunk (sequence) dim — with
    # indivisible head counts (llava 56, hymba 25, llama4 40 on a 16-way
    # axis) the head fallback replicated every f32 chunk buffer AND the
    # score/PV compute on all 16 model shards (llava train_4k memory term
    # 363 s; §Perf notes).  q-sequence sharding keeps the whole q-block
    # pipeline local: kb/vb are broadcast, scores and PV shard over Sq.
    m_size = axis_size("model")
    head_sharded = H % m_size == 0 and H >= m_size
    hspec = ("batch", None, "model") if head_sharded \
        else ("batch", "model", None)
    hspec4 = hspec + (None,)

    def q_body(_, qi):
        qb = jax.lax.dynamic_slice_in_dim(q, qi * q_chunk, q_chunk, axis=1)
        qb = constrain(qb, hspec4)
        q_pos = q_offset + qi * q_chunk + jnp.arange(q_chunk)
        acc0 = constrain(jnp.zeros((B, q_chunk, H, hd), jnp.float32),
                         hspec4)
        m0 = constrain(jnp.full((B, q_chunk, H), NEG_INF, jnp.float32),
                       hspec)
        l0 = constrain(jnp.zeros((B, q_chunk, H), jnp.float32), hspec)

        def kv_body(carry, inputs):
            kb, vb, ki = inputs
            acc, m, l = carry
            # GQA: broadcast Kh -> H (head h uses kv head h // G).
            kb = constrain(jnp.repeat(kb, G, axis=2),
                           ("batch", None, "model", None))
            vb = constrain(jnp.repeat(vb, G, axis=2),
                           ("batch", None, "model", None))
            kv_idx = ki * kv_chunk + jnp.arange(kv_chunk)
            mask = kv_idx[None, :] < Sk                    # pad rows out
            if causal:
                mask &= kv_idx[None, :] <= q_pos[:, None]
            if window > 0:
                mask &= kv_idx[None, :] > q_pos[:, None] - window
            s = jnp.einsum("bthd,bshd->bths", qb.astype(jnp.float32),
                           kb.astype(jnp.float32)) * scale
            s = jnp.where(mask[None, :, None, :], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l = l * alpha + p.sum(axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bths,bshd->bthd", p, vb.astype(jnp.float32))
            return (acc, m_new, l), None

        (acc, m, l), _ = jax.lax.scan(kv_body, (acc0, m0, l0),
                                      (ks, vs, jnp.arange(n_k)))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_body, None, jnp.arange(n_q))
    # (n_q, B, q_chunk, H, hd) -> (B, Sq, H, hd)
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, Sq_p, H, hd)
    return out[:, :Sq]


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------
def init_mlp(key, d_model: int, d_ff: int, dtype,
             out_scale: float = 1.0) -> Params:
    """``out_scale`` rescales the residual-output projection (GPT-2 style
    1/sqrt(2L)): without it the backward pass amplifies ~2x per layer and
    deep stacks see 1e7+ grad norms at init (found by train_e2e.py)."""
    k1, k2, k3 = jax.random.split(key, 3)
    return {"w_gate": dense_init(k1, (d_model, d_ff), dtype),
            "w_up": dense_init(k2, (d_model, d_ff), dtype),
            "w_down": dense_init(k3, (d_ff, d_model), dtype,
                                 scale=out_scale / math.sqrt(d_ff))}


def spec_mlp(d_model: int, d_ff: int, dtype) -> Params:
    return {"w_gate": spec((d_model, d_ff), dtype),
            "w_up": spec((d_model, d_ff), dtype),
            "w_down": spec((d_ff, d_model), dtype)}


def mlp(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    h = constrain(h, ("batch",) + (None,) * (h.ndim - 2) + ("model",))
    return constrain(h @ p["w_down"],
                     ("batch",) + (None,) * (h.ndim - 1))


# ---------------------------------------------------------------------------
# GQA attention block (params + apply for all three modes)
# ---------------------------------------------------------------------------
def init_attn(key, d_model: int, n_heads: int, n_kv: int, head_dim: int,
              dtype, out_scale: float = 1.0) -> Params:
    # Explicit scales: wq/wk/wv are 3D (d_model, heads, head_dim) tensors
    # contracting over dim 0, so dense_init's shape[-2] fan-in guess would
    # be `heads` — 8x too hot for 512/8, saturating the softmax forward
    # and exploding the backward ~2x/layer (found by examples/train_e2e;
    # see EXPERIMENTS.md deep-stack init note).
    kq, kk, kv, ko = jax.random.split(key, 4)
    proj = 1.0 / math.sqrt(d_model)
    return {
        "wq": dense_init(kq, (d_model, n_heads, head_dim), dtype,
                         scale=proj),
        "wk": dense_init(kk, (d_model, n_kv, head_dim), dtype, scale=proj),
        "wv": dense_init(kv, (d_model, n_kv, head_dim), dtype, scale=proj),
        "wo": dense_init(ko, (n_heads, head_dim, d_model), dtype,
                         scale=out_scale / math.sqrt(n_heads * head_dim)),
    }


def spec_attn(d_model: int, n_heads: int, n_kv: int, head_dim: int,
              dtype) -> Params:
    return {
        "wq": spec((d_model, n_heads, head_dim), dtype),
        "wk": spec((d_model, n_kv, head_dim), dtype),
        "wv": spec((d_model, n_kv, head_dim), dtype),
        "wo": spec((n_heads, head_dim, d_model), dtype),
    }


def self_attention(p: Params, x: jnp.ndarray, *, theta: float,
                   causal: bool = True, window: int = 0,
                   positions: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Full-sequence attention (training / prefill)."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)
    q = constrain(jnp.einsum("bsd,dhk->bshk", x, p["wq"]),
                  ("batch", None, "model", None))
    k = constrain(jnp.einsum("bsd,dhk->bshk", x, p["wk"]),
                  ("batch", None, "model", None))
    v = constrain(jnp.einsum("bsd,dhk->bshk", x, p["wv"]),
                  ("batch", None, "model", None))
    q = apply_rope(q, positions, theta)
    k = apply_rope(k, positions, theta)
    o = chunked_attention(q, k, v, causal=causal, window=window)
    return constrain(jnp.einsum("bshk,hkd->bsd", o, p["wo"]),
                     ("batch", None, None))


def cross_attention(p: Params, x: jnp.ndarray, memory_k: jnp.ndarray,
                    memory_v: jnp.ndarray) -> jnp.ndarray:
    """Decoder cross-attention against precomputed encoder K/V."""
    q = constrain(jnp.einsum("bsd,dhk->bshk", x, p["wq"]),
                  ("batch", None, "model", None))
    o = chunked_attention(q, memory_k, memory_v, causal=False)
    return constrain(jnp.einsum("bshk,hkd->bsd", o, p["wo"]),
                     ("batch", None, None))


def memory_kv(p: Params, memory: jnp.ndarray
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    k = jnp.einsum("bsd,dhk->bshk", memory, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", memory, p["wv"])
    return k, v


def prefill_attention(p: Params, x: jnp.ndarray, cache_window: int, *,
                      theta: float, window: int = 0
                      ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Prefill: full causal attention AND return the ring-buffer KV cache
    covering the last ``cache_window`` positions."""
    B, S, _ = x.shape
    positions = jnp.arange(S)
    q = constrain(jnp.einsum("bsd,dhk->bshk", x, p["wq"]),
                  ("batch", None, "model", None))
    k = constrain(jnp.einsum("bsd,dhk->bshk", x, p["wk"]),
                  ("batch", None, "model", None))
    v = constrain(jnp.einsum("bsd,dhk->bshk", x, p["wv"]),
                  ("batch", None, "model", None))
    q = apply_rope(q, positions, theta)
    k = apply_rope(k, positions, theta)
    o = chunked_attention(q, k, v, causal=True, window=window)
    out = constrain(jnp.einsum("bshk,hkd->bsd", o, p["wo"]),
                    ("batch", None, None))
    k_cache, v_cache = ring_from_prefill(k, cache_window), \
        ring_from_prefill(v, cache_window)
    return out, k_cache, v_cache


def ring_from_prefill(kv: jnp.ndarray, W: int) -> jnp.ndarray:
    """Arrange the last ``W`` positions of a (B,S,Kh,hd) tensor into ring
    order: slot i holds position p with p ≡ i (mod W)."""
    B, S, Kh, hd = kv.shape
    if S <= W:
        return pad_axis(kv, 1, W)
    tail = kv[:, S - W:]                     # positions S-W .. S-1
    # position (S-W+j) goes to slot (S-W+j) mod W; roll accomplishes this.
    return jnp.roll(tail, shift=(S - W) % W, axis=1)


def decode_attention(p: Params, x: jnp.ndarray, k_cache: jnp.ndarray,
                     v_cache: jnp.ndarray, cache_len, *, theta: float,
                     window: int = 0) -> Tuple[jnp.ndarray, jnp.ndarray,
                                               jnp.ndarray]:
    """Single-token decode against a ring-buffer KV cache.

    x: (B, 1, d).  k_cache/v_cache: (B, W, Kh, hd).  ``cache_len`` is the
    number of tokens already in history (= absolute position of x).
    Slot i holds absolute position p = cache_len - ((cache_len - i) mod W).

    ``cache_len`` may be a scalar (every batch row shares one position
    clock — training-style decode, the dry-run shapes) or a ``(B,)``
    vector (per-row clocks — the serving engine's continuous batching,
    where slots were prefilled at different times and hold sequences of
    different lengths).  The scalar path is kept verbatim so existing
    decode lowerings are untouched.
    """
    B = x.shape[0]
    W = k_cache.shape[1]
    hd = p["wq"].shape[-1]
    per_row = jnp.asarray(cache_len).ndim > 0
    if per_row:
        cl = jnp.asarray(cache_len, jnp.int32)           # (B,)
        pos = cl[:, None]
    else:
        pos = jnp.full((B, 1), cache_len, dtype=jnp.int32)
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    q = apply_rope(q, pos, theta)
    k = apply_rope(k, pos, theta)
    idx = jnp.arange(W)
    if per_row:
        slot = jnp.mod(cl, W)                            # (B,)
        rows = jnp.arange(B)
        k_cache = k_cache.at[rows, slot].set(k[:, 0])
        v_cache = v_cache.at[rows, slot].set(v[:, 0])
        abs_pos = cl[:, None] - jnp.mod(cl[:, None] - idx[None, :], W)
        valid = abs_pos >= 0                             # (B, W)
        if window > 0:
            valid &= abs_pos > cl[:, None] - window
        vmask = valid[:, None, None, None, :]
    else:
        slot = jnp.mod(cache_len, W)
        k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k, slot,
                                                      axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v, slot,
                                                      axis=1)
        abs_pos = cache_len - jnp.mod(cache_len - idx, W)
        valid = abs_pos >= 0
        if window > 0:
            valid &= abs_pos > cache_len - window
        vmask = valid[None, None, None, None, :]
    Kh = k_cache.shape[2]
    G = q.shape[2] // Kh
    qf = q.reshape(B, 1, Kh, G, hd).astype(jnp.float32)
    s = jnp.einsum("btkgh,bskh->btkgs", qf,
                   k_cache.astype(jnp.float32)) / math.sqrt(hd)
    s = jnp.where(vmask, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("btkgs,bskh->btkgh", w,
                   v_cache.astype(jnp.float32)).astype(x.dtype)
    o = o.reshape(B, 1, q.shape[2], hd)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"]), k_cache, v_cache


# ---------------------------------------------------------------------------
# Embedding
# ---------------------------------------------------------------------------
def init_embed(key, vocab: int, d_model: int, dtype) -> jnp.ndarray:
    return (jax.random.normal(key, (vocab, d_model)) * 0.02).astype(dtype)


def embed(table: jnp.ndarray, tokens: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(table, tokens, axis=0)
