"""The paper's five scheduling metrics (§4): GAR, SOR, GFR, JWTD, JTTED —
plus the dynamics-subsystem family (goodput, MTTR, restart overhead).

* **GAR** (§4.1) — instantaneous allocated/total GPUs.
* **SOR** (§4.2) — time-integrated GPU-hours allocated / GPU-hours
  available; accumulation starts at *scheduling completion* (binding),
  before the container reaches Running, exactly as the paper specifies.
* **GFR** (§4.3) — fraction of nodes neither fully idle nor fully
  occupied.
* **JWTD** (§4.4) — mean waiting time by job-size bucket (queueing +
  scheduling-decision time).
* **JTTED** (§4.5) — per-size NodeNum and NodeNetGroupNum deviation
  ratios vs. the communication-optimal placement.

Dynamics metrics (see ``docs/dynamics.md`` for definitions):

* **goodput** — GPU-seconds of *useful* (completed, non-recomputed)
  work delivered; ``goodput_fraction`` divides by allocated
  GPU-seconds, so recompute debt and restart overhead show up as loss;
* **MTTR** — mean time from an interruption to the rescheduled
  attempt's scheduling completion;
* **restart overhead / lost work** — GPU-seconds burned restoring
  checkpoints and recomputing work since the last checkpoint;
* **interrupted JTTED** — topology deviation of restarted placements
  only (do rescheduled gangs land in worse topology?).

Elastic accounting (``repro.core.elastic``): completed work is credited
at the **ideal plan's** GPU count — a plan-independent yardstick, so
elastic and rigid runs of the same trace compare on identical units
(and non-elastic jobs are untouched: ``ideal_n_gpus == n_gpus``).
Voluntary checkpoint-boundary reshapes flow through
:meth:`MetricsRecorder.on_job_interrupted` with ``reshape=True``: their
cost lands in the shared lost/overhead totals *and* in the dedicated
reshape aggregates, but records no MTTR sample (nothing failed).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .cluster import ClusterState
from .job import Job, JobKind, SIZE_BUCKETS, size_bucket
from .topology import ClusterTopology


def waiting_percentile(jobs: Sequence[Job], q: float) -> float:
    """P<q> of job waiting times (s) over started jobs — the headline
    tail-latency metric (P90 JWTD) shared by the federation and elastic
    benchmarks.

    With no started jobs there *is* no percentile: the result is NaN,
    not 0.0 — a zero here read as "perfect tail latency" when it meant
    "no data" (callers treat NaN as missing)."""
    waits = [j.waiting_time for j in jobs if j.waiting_time is not None]
    return float(np.percentile(waits, q)) if waits else float("nan")


@dataclasses.dataclass
class Sample:
    t: float
    gar: float
    gfr: float
    allocated: int
    capacity: int
    queue_depth: int
    # Per-workload breakdown (0 when the caller passes no running set):
    # lets the tidal benchmarks separate training backfill from
    # inference fleet allocation in the same GAR series.
    train_allocated: int = 0
    infer_allocated: int = 0


@dataclasses.dataclass
class JTTEDEntry:
    uid: int
    n_gpus: int
    node_dev: float       # actual nodes / optimal nodes
    group_dev: float      # actual groups / optimal groups
    attempt: int = 0      # 0 = first placement, >0 = post-failure restart


class MetricsRecorder:
    def __init__(self, topology: ClusterTopology) -> None:
        self.topology = topology
        # Optional telemetry facade (repro.obs) — observes every sample
        # and job-lifecycle edge.  None keeps recording byte-identical
        # to an untelemetered run.
        self.obs = None
        self.samples: List[Sample] = []
        self.jtted: List[JTTEDEntry] = []
        self._finished: List[Job] = []
        # Riemann accumulation for SOR.
        self._last_t: Optional[float] = None
        self._last_alloc: int = 0
        self._last_cap: int = 0
        self._gpu_seconds_alloc: float = 0.0
        self._gpu_seconds_cap: float = 0.0
        # Dynamics accounting.
        self._interrupted_at: Dict[int, float] = {}   # uid -> kill time
        self.mttr_samples: List[float] = []
        self.useful_gpu_seconds: float = 0.0          # completed work
        self.lost_gpu_seconds: float = 0.0            # recompute debt
        self.overhead_gpu_seconds: float = 0.0        # restart overhead
        # Elastic reshaping (voluntary checkpoint-boundary interrupts).
        self.reshapes: int = 0
        self.reshape_gpu_seconds: float = 0.0

    # ------------------------------------------------------------------
    def sample(self, t: float, state: ClusterState, queue_depth: int = 0,
               running: Optional[Dict[int, Job]] = None) -> Sample:
        cap = state.total_allocatable()
        alloc = state.total_allocated()
        healthy_nodes = int(state.node_healthy.sum())
        frag = int(state.fragmented_nodes().sum())
        gfr = frag / healthy_nodes if healthy_nodes else 0.0
        gar = alloc / cap if cap else 0.0
        if self._last_t is not None:
            dt = t - self._last_t
            if dt > 0:
                # GPU-hours accrue from scheduling completion (§4.2) — the
                # allocation arrays flip at bind time, so integrating them
                # matches the paper's semantics.
                self._gpu_seconds_alloc += self._last_alloc * dt
                self._gpu_seconds_cap += self._last_cap * dt
        self._last_t, self._last_alloc, self._last_cap = t, alloc, cap
        train_alloc = infer_alloc = 0
        if running:
            for j in running.values():
                if j.kind is JobKind.INFER:
                    infer_alloc += j.n_gpus
                else:
                    train_alloc += j.n_gpus
        s = Sample(t=t, gar=gar, gfr=gfr, allocated=alloc, capacity=cap,
                   queue_depth=queue_depth, train_allocated=train_alloc,
                   infer_allocated=infer_alloc)
        self.samples.append(s)
        if self.obs is not None:
            self.obs.on_sample(s)
        return s

    def on_job_placed(self, job: Job, now: Optional[float] = None) -> None:
        """Record JTTED deviation ratios at placement time (§4.5) and,
        for post-interruption restarts, the MTTR sample."""
        t_int = self._interrupted_at.pop(job.uid, None)
        if t_int is not None:
            t = now if now is not None else job.start_time
            if t is not None:
                self.mttr_samples.append(float(t) - t_int)
        if self.obs is not None:
            self.obs.on_job_placed(job, now)
        if job.placement is None or job.kind is not JobKind.TRAIN:
            return
        topo = self.topology
        actual_nodes = len(job.placement.distinct_nodes())
        actual_groups = len({int(topo.leaf_id[n])
                             for n in job.placement.distinct_nodes()})
        opt_nodes = topo.optimal_node_num(job.n_gpus)
        opt_groups = topo.optimal_group_num(job.n_gpus)
        self.jtted.append(JTTEDEntry(
            uid=job.uid, n_gpus=job.n_gpus,
            node_dev=actual_nodes / max(1, opt_nodes),
            group_dev=actual_groups / max(1, opt_groups),
            attempt=job.attempt))

    def on_job_finished(self, job: Job) -> None:
        self._finished.append(job)
        # Completed jobs delivered their full useful work, whatever got
        # recomputed along the way — credited at the ideal plan's GPU
        # count (== n_gpus for rigid jobs) so elastic and rigid runs
        # measure goodput in the same units.
        self.useful_gpu_seconds += job.original_duration * job.ideal_n_gpus
        if self.obs is not None:
            self.obs.on_job_finished(job)

    def on_job_interrupted(self, job: Job, t: float, lost_work: float,
                           overhead: float, reshape: bool = False) -> None:
        """A failure/drain killed the job at ``t``: ``lost_work`` seconds
        since its last checkpoint must be recomputed and ``overhead``
        seconds of restore cost were added to the next attempt.

        ``reshape=True`` marks a *voluntary* checkpoint-boundary
        reshape (elastic grow): same cost accounting against the shape
        that burned it, but no MTTR sample — nothing failed — and the
        cost is additionally tracked in the reshape aggregates the
        elastic benchmark budgets."""
        if reshape:
            self.reshapes += 1
            self.reshape_gpu_seconds += (max(0.0, lost_work)
                                         + max(0.0, overhead)) * job.n_gpus
        else:
            self._interrupted_at[job.uid] = float(t)
        self.lost_gpu_seconds += max(0.0, lost_work) * job.n_gpus
        self.overhead_gpu_seconds += max(0.0, overhead) * job.n_gpus
        if self.obs is not None:
            self.obs.on_job_interrupted(job, t, lost_work, overhead,
                                        reshape)

    # ------------------------------------------------------------------
    # Aggregates
    # ------------------------------------------------------------------
    def gar_series(self) -> Tuple[np.ndarray, np.ndarray]:
        t = np.asarray([s.t for s in self.samples])
        v = np.asarray([s.gar for s in self.samples])
        return t, v

    def gfr_series(self) -> Tuple[np.ndarray, np.ndarray]:
        t = np.asarray([s.t for s in self.samples])
        v = np.asarray([s.gfr for s in self.samples])
        return t, v

    def median_gar(self) -> float:
        vals = [s.gar for s in self.samples]
        return float(np.median(vals)) if vals else 0.0

    def mean_gfr(self) -> float:
        vals = [s.gfr for s in self.samples]
        return float(np.mean(vals)) if vals else 0.0

    def sor(self) -> float:
        """Cumulative SOR over the observation window (§4.2)."""
        if self._gpu_seconds_cap <= 0:
            return 0.0
        return self._gpu_seconds_alloc / self._gpu_seconds_cap

    def gpu_seconds(self) -> Tuple[float, float]:
        """(allocated, capacity) GPU-seconds accumulated so far — the
        SOR numerator/denominator, exposed so a federation can compute
        the global SOR as Σalloc / Σcap across member recorders."""
        return self._gpu_seconds_alloc, self._gpu_seconds_cap

    def jwtd(self, jobs: Optional[Sequence[Job]] = None
             ) -> Dict[str, float]:
        """Mean waiting time per size bucket (§4.4)."""
        pool = list(jobs) if jobs is not None else self._finished
        acc: Dict[str, List[float]] = {}
        for j in pool:
            w = j.waiting_time
            if w is None:
                continue
            acc.setdefault(size_bucket(j.n_gpus), []).append(w)
        return {b: float(np.mean(acc[b])) for b in SIZE_BUCKETS if b in acc}

    def jwtd_max(self, jobs: Optional[Sequence[Job]] = None
                 ) -> Dict[str, float]:
        pool = list(jobs) if jobs is not None else self._finished
        acc: Dict[str, List[float]] = {}
        for j in pool:
            w = j.waiting_time
            if w is None:
                continue
            acc.setdefault(size_bucket(j.n_gpus), []).append(w)
        return {b: float(np.max(acc[b])) for b in SIZE_BUCKETS if b in acc}

    def jtted_by_bucket(self) -> Dict[str, Tuple[float, float]]:
        """Mean (node_dev, group_dev) per size bucket (§4.5)."""
        return self._jtted_acc(self.jtted)

    def interrupted_jtted_by_bucket(self) -> Dict[str, Tuple[float, float]]:
        """§4.5 deviation ratios restricted to restarted placements —
        the checkpoint-restart path's topology-quality check."""
        return self._jtted_acc([e for e in self.jtted if e.attempt > 0])

    @staticmethod
    def _jtted_acc(entries: Sequence[JTTEDEntry]
                   ) -> Dict[str, Tuple[float, float]]:
        acc: Dict[str, List[JTTEDEntry]] = {}
        for e in entries:
            acc.setdefault(size_bucket(e.n_gpus), []).append(e)
        return {b: (float(np.mean([e.node_dev for e in v])),
                    float(np.mean([e.group_dev for e in v])))
                for b, v in acc.items()}

    # -- dynamics aggregates -------------------------------------------
    def mttr(self) -> float:
        """Mean time from interruption to rescheduled placement (s)."""
        return float(np.mean(self.mttr_samples)) if self.mttr_samples \
            else 0.0

    def goodput_fraction(self) -> float:
        """Useful GPU-seconds / allocated GPU-seconds: 1.0 means no
        recompute debt, no restart overhead, no abandoned work."""
        if self._gpu_seconds_alloc <= 0:
            return 0.0
        return self.useful_gpu_seconds / self._gpu_seconds_alloc

    def reshape_overhead_fraction(self) -> float:
        """Reshape cost / useful work delivered — the elastic
        benchmark's ≤10% budget."""
        if self.useful_gpu_seconds <= 0:
            return 0.0
        return self.reshape_gpu_seconds / self.useful_gpu_seconds

    def report(self) -> Dict[str, object]:
        return {
            "median_gar": self.median_gar(),
            "sor": self.sor(),
            "mean_gfr": self.mean_gfr(),
            "jwtd_mean": self.jwtd(),
            "jwtd_max": self.jwtd_max(),
            "jtted": self.jtted_by_bucket(),
            "goodput_gpu_seconds": self.useful_gpu_seconds,
            "goodput_fraction": self.goodput_fraction(),
            "mttr": self.mttr(),
            "lost_gpu_seconds": self.lost_gpu_seconds,
            "overhead_gpu_seconds": self.overhead_gpu_seconds,
            "reshapes": self.reshapes,
            "reshape_gpu_seconds": self.reshape_gpu_seconds,
            "interrupted_jtted": self.interrupted_jtted_by_bucket(),
        }
