"""Discrete-event cluster simulator driving QSCH + RSCH.

The loop is an :class:`~repro.core.events.EventBus` (see that module for
the determinism contract).  Built-in event kinds:

* ``SUBMIT``  — a job arrives and enters its tenant queue;
* ``TICK``    — a scheduling cycle fires (QSCH admission -> RSCH placement
  -> binding);
* ``END``     — a running job completes and releases devices;
* ``SAMPLE``  — metrics sampling.

The dynamics subsystem (:mod:`repro.core.dynamics`) subscribes the
remaining kinds (NODE_FAIL, NODE_RECOVER, GPU_FAIL/RECOVER,
DRAIN_START/END, SCALE_DECISION) when ``SimConfig.dynamics`` is set;
with it unset the event stream — and therefore every placement and
metric — is identical to the pre-bus simulator (asserted by
``benchmarks/dynamics_bench.py``).

Binding latency (image pull, container start — §4.2) is modeled as a
constant delay between scheduling completion and Running, but GPU-hours
accrue from scheduling completion per the SOR definition.
"""

from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Callable, Dict, List, Optional, Sequence

from .cluster import ClusterState
from .events import Event, EventBus, EventKind
from .job import Job, JobState
from .metrics import MetricsRecorder
from .qsch import QSCH, CycleResult

if TYPE_CHECKING:  # dynamics imports stay lazy — see run()
    from .dynamics.engine import ClusterDynamics, DynamicsConfig


@dataclasses.dataclass
class SimConfig:
    tick_interval: float = 30.0        # scheduling cycle period (s)
    sample_interval: float = 300.0     # metric sampling period (s)
    binding_latency: float = 45.0      # schedule->running delay (s)
    horizon: Optional[float] = None    # stop time; default: drain
    # Cluster dynamics (failures, drains, autoscaling); None = static
    # cluster, byte-identical to the pre-dynamics simulator.
    dynamics: Optional["DynamicsConfig"] = None
    # Optimistic cycle pipelining (repro.core.pipeline): speculatively
    # snapshot+score the next cycle's head job so a pipelined deployment
    # can overlap it with binding I/O.  Off = byte-identical classic
    # sequential cycles.
    pipelined_cycles: bool = False


@dataclasses.dataclass
class SimResult:
    jobs: List[Job]
    metrics: MetricsRecorder
    end_time: float
    cycles: int
    preemptions: int
    # Why jobs waited (summed over cycles; see CycleResult counters):
    # static-admission rejections, dynamic-admission failures, and
    # requeue events (§3.2.4: placement failures + preemptions).
    admit_rejected: int = 0
    infeasible: int = 0
    requeues: int = 0
    # Dynamics accounting (zero on static runs); the engine's summary
    # object carries the detailed per-event breakdown.
    failures: int = 0
    interrupts: int = 0
    drains: int = 0
    scale_events: int = 0
    dynamics: Optional[object] = None
    # CyclePipeline.stats() when pipelined_cycles was on (hits,
    # conflicts, misses, spec_seconds); None otherwise.
    pipeline: Optional[dict] = None


class Simulator:
    def __init__(self, state: ClusterState, qsch: QSCH,
                 config: Optional[SimConfig] = None) -> None:
        self.state = state
        self.qsch = qsch
        self.config = config or SimConfig()
        self.metrics = MetricsRecorder(state.topology)
        elastic = getattr(qsch, "elastic", None)
        if elastic is not None:
            # Voluntary reshapes report through the same recorder as
            # failures (flagged, so MTTR stays failure-only).
            elastic.bind_metrics(self.metrics)
        if self.config.pipelined_cycles and qsch.pipeline is None:
            qsch.enable_pipeline()
        self.bus = EventBus()
        self.now = 0.0
        self.cycles = 0
        self.preemptions = 0
        self.admit_rejected = 0
        self.infeasible = 0
        self.requeues = 0
        # job uid -> authoritative END time; a preempted/interrupted
        # job's stale END event must be ignored (the rescheduled run
        # pushes a fresh one).
        self.pending_ends: Dict[int, float] = {}
        # Extra work-outstanding predicate for federated drivers: jobs
        # not yet routed to this member live outside the bus, so the
        # TICK/SAMPLE chains must not die while the federation still has
        # arrivals or in-flight forwards (None = standalone, unchanged).
        self.external_work: Optional[Callable[[], bool]] = None
        self._engine: Optional["ClusterDynamics"] = None
        # Optional telemetry facade (repro.obs.Telemetry.attach sets it,
        # together with qsch.obs / rsch.obs / metrics.obs / bus.tap).
        # None = untelemetered, byte-identical output.
        self.obs = None
        self._register_builtins()

    # ------------------------------------------------------------------
    # Built-in handlers
    # ------------------------------------------------------------------
    def _register_builtins(self) -> None:
        self.bus.subscribe(EventKind.SUBMIT, self._on_submit)
        self.bus.subscribe(EventKind.END, self._on_end)
        self.bus.subscribe(EventKind.TICK, self._on_tick)
        self.bus.subscribe(EventKind.SAMPLE, self._on_sample)

    def _on_submit(self, ev: Event) -> None:
        self.qsch.submit(ev.payload)

    def _on_end(self, ev: Event) -> None:
        job = ev.payload
        if (job.state is JobState.RUNNING
                and self.pending_ends.get(job.uid) == ev.t):
            self.pending_ends.pop(job.uid, None)
            self.qsch.on_complete(job, self.state, ev.t)
            self.metrics.on_job_finished(job)

    def _on_tick(self, ev: Event) -> None:
        cfg = self.config
        result = self.qsch.cycle(self.state, ev.t)
        self.cycles += 1
        self.preemptions += len(result.preempted)
        self.admit_rejected += result.admit_rejected
        self.infeasible += result.infeasible
        self.requeues += result.requeues
        for job in result.scheduled:
            self.metrics.on_job_placed(job, now=ev.t)
            job.run_time = ev.t + cfg.binding_latency
            end = job.run_time + job.duration
            self.pending_ends[job.uid] = end
            self.bus.push(end, EventKind.END, job)
        # Keep ticking while anything is queued or running.
        if self._work_outstanding():
            self.bus.push(ev.t + cfg.tick_interval, EventKind.TICK)

    def _on_sample(self, ev: Event) -> None:
        self.metrics.sample(ev.t, self.state, self.qsch.queue_depth(),
                            running=self.qsch.running)
        if self._work_outstanding():
            self.bus.push(ev.t + self.config.sample_interval,
                          EventKind.SAMPLE)

    def _work_outstanding(self) -> bool:
        return bool(self.qsch.queue_depth() or self.qsch.running
                    or self.bus.pending(EventKind.SUBMIT)
                    or (self.external_work is not None
                        and self.external_work()))

    # ------------------------------------------------------------------
    # Revival hooks (dynamics): a failure or scale decision can create
    # work after the TICK/SAMPLE chains died out — restart them without
    # ever double-scheduling (the per-kind pending counters are O(1)).
    # ------------------------------------------------------------------
    def ensure_tick(self, t: float) -> None:
        if self.bus.pending(EventKind.TICK) == 0:
            self.bus.push(t, EventKind.TICK)

    def ensure_sample(self, t: float) -> None:
        if self.bus.pending(EventKind.SAMPLE) == 0:
            self.bus.push(t, EventKind.SAMPLE)

    # ------------------------------------------------------------------
    # Run = prime + event loop + finalize.  The pieces are public so a
    # federated driver (repro.core.federation) can prime members, merge
    # their buses in ONE lockstep loop, and finalize each — a standalone
    # ``run`` stays byte-identical to the pre-split implementation.
    # ------------------------------------------------------------------
    def attach_dynamics(self) -> None:
        """Instantiate and attach the dynamics engine (idempotent)."""
        if self.config.dynamics is not None and self._engine is None:
            from .dynamics.engine import ClusterDynamics
            self._engine = ClusterDynamics(self.config.dynamics)
            self._engine.attach(self)
            elastic = getattr(self.qsch, "elastic", None)
            if elastic is not None:
                # One checkpoint model for failures AND reshapes unless
                # the elastic config pinned its own.
                elastic.adopt_recovery(self.config.dynamics.recovery)

    def prime(self, jobs: Sequence[Job]) -> List[Job]:
        """Attach dynamics, enqueue submissions, start the TICK/SAMPLE
        chains.  Returns the submit-time-sorted job list."""
        self.attach_dynamics()
        jobs = sorted(jobs, key=lambda j: j.submit_time)
        for j in jobs:
            self.bus.push(j.submit_time, EventKind.SUBMIT, j)
        if jobs:
            t0 = jobs[0].submit_time
            self.bus.push(t0, EventKind.TICK)
            self.bus.push(t0, EventKind.SAMPLE)
        elif self._engine is not None and len(self.bus):
            # Dynamics-only run (e.g. a pure autoscaler scenario): the
            # engine seeded events; give metrics a t=0 anchor.
            self.bus.push(0.0, EventKind.SAMPLE)
        return list(jobs)

    def finalize(self, jobs: Sequence[Job]) -> SimResult:
        """Closing metrics sample + result assembly."""
        self.metrics.sample(self.now, self.state, self.qsch.queue_depth(),
                            running=self.qsch.running)
        result = SimResult(jobs=list(jobs), metrics=self.metrics,
                           end_time=self.now, cycles=self.cycles,
                           preemptions=self.preemptions,
                           admit_rejected=self.admit_rejected,
                           infeasible=self.infeasible,
                           requeues=self.requeues)
        if self.qsch.pipeline is not None:
            result.pipeline = self.qsch.pipeline.stats()
        if self._engine is not None:
            self._engine.finalize(result)
        if self.obs is not None:
            self.obs.finalize_run(self)
        return result

    def run(self, jobs: Sequence[Job]) -> SimResult:
        cfg = self.config
        jobs = self.prime(jobs)
        while len(self.bus):
            ev = self.bus.pop()
            if cfg.horizon is not None and ev.t > cfg.horizon:
                break
            self.now = ev.t
            self.bus.dispatch(ev)
        return self.finalize(jobs)
