"""Discrete-event cluster simulator driving QSCH + RSCH.

Event kinds:

* ``SUBMIT``  — a job arrives and enters its tenant queue;
* ``TICK``    — a scheduling cycle fires (QSCH admission -> RSCH placement
  -> binding);
* ``END``     — a running job completes and releases devices.

Binding latency (image pull, container start — §4.2) is modeled as a
constant delay between scheduling completion and Running, but GPU-hours
accrue from scheduling completion per the SOR definition.
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Dict, List, Optional, Sequence

from .cluster import ClusterState
from .job import Job, JobState
from .metrics import MetricsRecorder
from .qsch import QSCH, CycleResult
from .quota import QuotaManager, QuotaMode


@dataclasses.dataclass
class SimConfig:
    tick_interval: float = 30.0        # scheduling cycle period (s)
    sample_interval: float = 300.0     # metric sampling period (s)
    binding_latency: float = 45.0      # schedule->running delay (s)
    horizon: Optional[float] = None    # stop time; default: drain


@dataclasses.dataclass
class SimResult:
    jobs: List[Job]
    metrics: MetricsRecorder
    end_time: float
    cycles: int
    preemptions: int
    # Why jobs waited (summed over cycles; see CycleResult counters):
    # static-admission rejections, dynamic-admission failures, and
    # requeue events (§3.2.4: placement failures + preemptions).
    admit_rejected: int = 0
    infeasible: int = 0
    requeues: int = 0


_SUBMIT, _END, _TICK, _SAMPLE = 0, 1, 2, 3


class Simulator:
    def __init__(self, state: ClusterState, qsch: QSCH,
                 config: Optional[SimConfig] = None) -> None:
        self.state = state
        self.qsch = qsch
        self.config = config or SimConfig()
        self.metrics = MetricsRecorder(state.topology)
        self._heap: List = []
        self._seq = itertools.count()
        # Count of SUBMIT events still in the heap — keeps the "anything
        # left to schedule?" check O(1) instead of an O(heap) scan per
        # tick/sample event.
        self._pending_submissions = 0

    def _push(self, t: float, kind: int, payload=None) -> None:
        if kind == _SUBMIT:
            self._pending_submissions += 1
        heapq.heappush(self._heap, (t, kind, next(self._seq), payload))

    def run(self, jobs: Sequence[Job]) -> SimResult:
        cfg = self.config
        jobs = sorted(jobs, key=lambda j: j.submit_time)
        for j in jobs:
            self._push(j.submit_time, _SUBMIT, j)
        if jobs:
            t0 = jobs[0].submit_time
            self._push(t0, _TICK)
            self._push(t0, _SAMPLE)
        now = 0.0
        cycles = 0
        preemptions = 0
        admit_rejected = 0
        infeasible = 0
        requeues = 0
        pending_ends: Dict[int, float] = {}

        while self._heap:
            now, kind, _, payload = heapq.heappop(self._heap)
            if kind == _SUBMIT:
                self._pending_submissions -= 1
            if cfg.horizon is not None and now > cfg.horizon:
                break
            if kind == _SUBMIT:
                self.qsch.submit(payload)
            elif kind == _END:
                job = payload
                # A preempted job's stale END event must be ignored; the
                # rescheduled run pushes a fresh one.
                if (job.state is JobState.RUNNING
                        and pending_ends.get(job.uid) == now):
                    self.qsch.on_complete(job, self.state, now)
                    self.metrics.on_job_finished(job)
            elif kind == _TICK:
                result = self.qsch.cycle(self.state, now)
                cycles += 1
                preemptions += len(result.preempted)
                admit_rejected += result.admit_rejected
                infeasible += result.infeasible
                requeues += result.requeues
                for job in result.scheduled:
                    self.metrics.on_job_placed(job)
                    job.run_time = now + cfg.binding_latency
                    end = job.run_time + job.duration
                    pending_ends[job.uid] = end
                    self._push(end, _END, job)
                # Keep ticking while anything is queued or running.
                if self.qsch.queue_depth() or self.qsch.running \
                        or self._has_future_submissions():
                    self._push(now + cfg.tick_interval, _TICK)
            elif kind == _SAMPLE:
                self.metrics.sample(now, self.state,
                                    self.qsch.queue_depth())
                if self.qsch.queue_depth() or self.qsch.running \
                        or self._has_future_submissions():
                    self._push(now + cfg.sample_interval, _SAMPLE)
        self.metrics.sample(now, self.state, self.qsch.queue_depth())
        return SimResult(jobs=list(jobs), metrics=self.metrics,
                         end_time=now, cycles=cycles,
                         preemptions=preemptions,
                         admit_rejected=admit_rejected,
                         infeasible=infeasible, requeues=requeues)

    def _has_future_submissions(self) -> bool:
        return self._pending_submissions > 0
