"""Tenant quota management (paper §3.2.1 "Static Quota Admission").

GPU quotas are kept per tenant *and per GPU model* (node pools, §3.4.1).
Two modes:

* **Isolated** — a tenant can never exceed its own quota (strong isolation);
* **Shared** — a tenant may borrow unused quota from other tenants; the
  owner can later *reclaim* the loan via preemption (§3.2.3 "Quota
  Reclamation Preemption").

The ledger tracks how many GPUs of each running job were satisfied from
borrowed quota so reclamation can pick concrete victims.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Dict, List, Optional, Tuple

from .job import Job


class QuotaMode(enum.Enum):
    ISOLATED = "isolated"
    SHARED = "shared"


@dataclasses.dataclass
class QuotaManager:
    # quota[tenant][gpu_type] -> GPUs granted.
    quota: Dict[str, Dict[int, int]]
    mode: QuotaMode = QuotaMode.ISOLATED
    # used[tenant][gpu_type] -> GPUs currently charged.
    used: Dict[str, Dict[int, int]] = dataclasses.field(default_factory=dict)
    # borrows[(borrower, gpu_type)] -> GPUs taken beyond own quota.
    borrows: Dict[Tuple[str, int], int] = dataclasses.field(
        default_factory=dict)

    # ------------------------------------------------------------------
    def _get(self, table: Dict[str, Dict[int, int]], tenant: str,
             gpu_type: int) -> int:
        return table.get(tenant, {}).get(gpu_type, 0)

    def _bump(self, tenant: str, gpu_type: int, delta: int) -> None:
        self.used.setdefault(tenant, {}).setdefault(gpu_type, 0)
        self.used[tenant][gpu_type] += delta
        if self.used[tenant][gpu_type] < 0:
            raise AssertionError("negative quota usage")

    def tenant_quota(self, tenant: str, gpu_type: int) -> int:
        return self._get(self.quota, tenant, gpu_type)

    def tenant_used(self, tenant: str, gpu_type: int) -> int:
        return self._get(self.used, tenant, gpu_type)

    def total_quota(self, gpu_type: int) -> int:
        return sum(q.get(gpu_type, 0) for q in self.quota.values())

    def total_used(self, gpu_type: int) -> int:
        return sum(u.get(gpu_type, 0) for u in self.used.values())

    # ------------------------------------------------------------------
    # Admission (§3.2.1)
    # ------------------------------------------------------------------
    def can_admit(self, job: Job) -> bool:
        """Static quota admission check (does not mutate)."""
        own_free = (self.tenant_quota(job.tenant, job.gpu_type)
                    - self.tenant_used(job.tenant, job.gpu_type))
        if own_free >= job.n_gpus:
            return True
        if self.mode is QuotaMode.ISOLATED:
            return False
        # Shared mode: borrow from the pool-wide unused quota.
        pool_free = (self.total_quota(job.gpu_type)
                     - self.total_used(job.gpu_type))
        return pool_free >= job.n_gpus

    def charge(self, job: Job) -> None:
        """Charge a job's GPUs against quota; records borrowing."""
        if not self.can_admit(job):
            raise ValueError(f"job {job.uid} fails static quota admission")
        own_free = (self.tenant_quota(job.tenant, job.gpu_type)
                    - self.tenant_used(job.tenant, job.gpu_type))
        borrowed = max(0, job.n_gpus - max(0, own_free))
        self._bump(job.tenant, job.gpu_type, job.n_gpus)
        if borrowed:
            key = (job.tenant, job.gpu_type)
            self.borrows[key] = self.borrows.get(key, 0) + borrowed
            job.borrowed_quota = borrowed

    def refund(self, job: Job) -> None:
        self._bump(job.tenant, job.gpu_type, -job.n_gpus)
        if job.borrowed_quota:
            key = (job.tenant, job.gpu_type)
            left = self.borrows.get(key, 0) - job.borrowed_quota
            if left > 0:
                self.borrows[key] = left
            else:
                self.borrows.pop(key, None)
            job.borrowed_quota = 0

    # ------------------------------------------------------------------
    # Quota reclamation (§3.2.3)
    # ------------------------------------------------------------------
    def reclaim_candidates(self, owner: str, gpu_type: int,
                           running_jobs: List[Job]) -> List[Job]:
        """Jobs whose borrowed quota blocks ``owner`` from using its own.

        Returns borrower jobs (most recently started first) whose
        preemption would return quota to the owner's pool.  Only relevant
        in shared mode when the owner is below its quota but the pool is
        exhausted.
        """
        if self.mode is not QuotaMode.SHARED:
            return []
        own_free = (self.tenant_quota(owner, gpu_type)
                    - self.tenant_used(owner, gpu_type))
        if own_free <= 0:
            return []
        victims = [j for j in running_jobs
                   if j.tenant != owner and j.gpu_type == gpu_type
                   and j.borrowed_quota > 0 and j.preemptible]
        victims.sort(key=lambda j: (j.priority, -(j.start_time or 0.0)))
        return victims

    def snapshot(self) -> Dict[str, Dict[int, int]]:
        return {t: dict(u) for t, u in self.used.items()}
