"""Scheduler snapshots: full deep copy vs incremental update (paper §3.4.3).

Before each scheduling cycle the scheduler works on a consistent copy of
the cluster state so in-flight mutations don't corrupt decisions.  The
naive approach deep-copies everything each cycle; Kant's RSCH instead
maintains a long-lived snapshot and copies only the rows dirtied since the
last cycle.  The paper reports >50 % scheduler CPU reduction on a
1 000-node cluster; ``benchmarks/snapshot_bench.py`` reproduces the
comparison and ``tests/test_snapshot.py`` property-checks equivalence.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .cluster import ClusterState


@dataclasses.dataclass
class Snapshot:
    """Immutable-by-convention array bundle RSCH scores against."""

    free_gpus: np.ndarray       # (n_nodes,) int32
    used_gpus: np.ndarray       # (n_nodes,) int32
    gpu_busy: np.ndarray        # (n_nodes, G) bool
    gpu_healthy: np.ndarray     # (n_nodes, G) bool
    node_healthy: np.ndarray    # (n_nodes,) bool
    gpu_type: np.ndarray        # (n_nodes,) int32
    inference_zone: np.ndarray  # (n_nodes,) bool
    version: int = 0


class FullSnapshotter:
    """Baseline: deep copy of every array, every cycle."""

    name = "full-copy"

    def __init__(self) -> None:
        self._version = 0

    def take(self, state: ClusterState) -> Snapshot:
        self._version += 1
        state.dirty_nodes.clear()  # parity with the incremental path
        return Snapshot(
            free_gpus=state.free_gpus().copy(),
            used_gpus=state.used_gpus().copy(),
            gpu_busy=state.gpu_busy.copy(),
            gpu_healthy=state.gpu_healthy.copy(),
            node_healthy=state.node_healthy.copy(),
            gpu_type=state.gpu_type.copy(),
            inference_zone=state.inference_zone.copy(),
            version=self._version,
        )


class IncrementalSnapshotter:
    """Kant's optimization: refresh only rows dirtied since last cycle.

    The first ``take`` is a full copy; afterwards only
    ``state.dirty_nodes`` rows are copied into the retained buffers.
    """

    name = "incremental"

    def __init__(self) -> None:
        self._snap: Optional[Snapshot] = None
        self._version = 0
        self.rows_copied = 0          # instrumentation for the benchmark

    def take(self, state: ClusterState) -> Snapshot:
        self._version += 1
        if self._snap is None:
            self._snap = FullSnapshotter().take(state)
            self._snap.version = self._version
            self.rows_copied += state.n_nodes
            state.dirty_nodes.clear()
            return self._snap

        snap = self._snap
        dirty = sorted(state.dirty_nodes)
        if dirty:
            idx = np.asarray(dirty, dtype=np.int64)
            # Row-level refresh of every mutable field.
            usable = state.gpu_healthy[idx] & ~state.gpu_busy[idx]
            free = usable.sum(axis=1).astype(np.int32)
            snap.free_gpus[idx] = np.where(state.node_healthy[idx], free, 0)
            snap.used_gpus[idx] = (
                state.gpu_busy[idx] & state.gpu_healthy[idx]
            ).sum(axis=1).astype(np.int32)
            snap.gpu_busy[idx] = state.gpu_busy[idx]
            snap.gpu_healthy[idx] = state.gpu_healthy[idx]
            snap.node_healthy[idx] = state.node_healthy[idx]
            snap.gpu_type[idx] = state.gpu_type[idx]
            snap.inference_zone[idx] = state.inference_zone[idx]
            self.rows_copied += len(dirty)
        state.dirty_nodes.clear()
        snap.version = self._version
        return snap


def snapshots_equal(a: Snapshot, b: Snapshot) -> bool:
    return (np.array_equal(a.free_gpus, b.free_gpus)
            and np.array_equal(a.used_gpus, b.used_gpus)
            and np.array_equal(a.gpu_busy, b.gpu_busy)
            and np.array_equal(a.gpu_healthy, b.gpu_healthy)
            and np.array_equal(a.node_healthy, b.node_healthy)
            and np.array_equal(a.gpu_type, b.gpu_type)
            and np.array_equal(a.inference_zone, b.inference_zone))
