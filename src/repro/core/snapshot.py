"""Scheduler snapshots: full deep copy vs incremental update (paper §3.4.3).

Before each scheduling cycle the scheduler works on a consistent copy of
the cluster state so in-flight mutations don't corrupt decisions.  The
naive approach deep-copies everything each cycle; Kant's RSCH instead
maintains a long-lived snapshot and copies only the rows dirtied since the
last cycle.  The paper reports >50 % scheduler CPU reduction on a
1 000-node cluster; ``benchmarks/snapshot_bench.py`` reproduces the
comparison and ``tests/test_snapshot.py`` property-checks equivalence.

Snapshots share the :class:`~repro.core.columns.StateColumns` layout with
the live :class:`~repro.core.cluster.ClusterState`, so a full take is one
column-block copy and an incremental take is a dirty-row copy of the same
block (``copy_rows_from``) — never a per-field rebuild.  On top of the
block the snapshot keeps three cache layers, all keyed to the §3.4
optimizations:

* ``_pool_cache`` — §3.4.1 GPU-Type node-pool masks (delta-invariant);
* ``derived`` — scratch for delta-invariant derived arrays (per-group
  healthy capacity, observability stats);
* ``tracked`` — **row-patchable** per-NodeNetGroup aggregates
  (:class:`TrackedGroupSum`).  Unlike ``derived``, these survive
  placement deltas: ``_refresh_rows`` patches them in O(dirty rows)
  instead of dropping them, which is what makes RSCH preselection
  O(groups) instead of O(nodes) at 100k+ nodes.
"""

from __future__ import annotations

from typing import Callable, Dict, Hashable, Iterable, Optional

import numpy as np

from .cluster import ClusterState
from .columns import StateColumns
from .job import Placement


class TrackedGroupSum:
    """A per-group integer aggregate patched row-wise on snapshot deltas.

    ``contrib_fn(snap, idx)`` returns each node's integer contribution to
    its leaf group's total (for ``idx=None``: all nodes).  The totals are
    maintained exactly: contributions are small non-negative integers
    (bounded by gpus_per_node × nodes_per_leaf), so the ``np.add.at``
    patch arithmetic is exact in int64 and a patched total always equals
    a from-scratch ``bincount`` (asserted in tests/test_scale.py).
    """

    def __init__(self, leaf_id: np.ndarray, n_groups: int,
                 contrib_fn: Callable[["Snapshot", Optional[np.ndarray]],
                                      np.ndarray],
                 snap: "Snapshot") -> None:
        self.leaf_id = leaf_id
        self.contrib_fn = contrib_fn
        self.contrib = np.asarray(contrib_fn(snap, None), dtype=np.int64)
        self.totals = np.zeros(n_groups, dtype=np.int64)
        np.add.at(self.totals, leaf_id, self.contrib)

    def refresh(self, snap: "Snapshot", idx: np.ndarray) -> None:
        new = np.asarray(self.contrib_fn(snap, idx), dtype=np.int64)
        np.add.at(self.totals, self.leaf_id[idx], new - self.contrib[idx])
        self.contrib[idx] = new


class Snapshot:
    """Immutable-by-convention column block RSCH scores against.

    The one sanctioned mutation is the *placement delta*
    (:meth:`apply_placement` / :meth:`apply_release`): after QSCH commits
    a placement to the live ``ClusterState`` mid-cycle, it applies the
    same change to the working snapshot instead of re-taking a full one,
    so one scheduling cycle costs exactly one ``snapshotter.take``
    (§3.4.3 snapshot memory optimization).
    """

    def __init__(self, cols: StateColumns, version: int = 0) -> None:
        self.cols = cols
        self.version = version
        # Bumped on every row mutation folded into this snapshot.  The
        # cycle pipeline uses (id(snap), mut_count) as its optimistic-
        # concurrency fingerprint: a speculative result is reusable only
        # if the snapshot it scored against has not folded further rows.
        self.mut_count = 0
        # Cached §3.4.1 node-pool masks, keyed by (gpu_type, zone
        # selector); inputs are delta-invariant, so the cache survives
        # mid-cycle placements and is cleared on health refreshes.
        self._pool_cache: dict = {}
        # Scratch for delta-invariant derived arrays (e.g. per-group
        # healthy capacity).  Never store anything here that depends on
        # free/used/busy.
        self.derived: dict = {}
        # Row-patchable per-group aggregates (free/used/slot counts) —
        # these DO depend on busy bits and are kept current by
        # ``_refresh_rows`` patching instead of invalidation.
        self.tracked: Dict[Hashable, TrackedGroupSum] = {}

    # -- column views ---------------------------------------------------
    @property
    def free_gpus(self) -> np.ndarray:
        return self.cols.free_gpus

    @property
    def used_gpus(self) -> np.ndarray:
        return self.cols.used_gpus

    @property
    def gpu_busy(self) -> np.ndarray:
        return self.cols.gpu_busy

    @property
    def gpu_healthy(self) -> np.ndarray:
        return self.cols.gpu_healthy

    @property
    def node_healthy(self) -> np.ndarray:
        return self.cols.node_healthy

    @property
    def gpu_type(self) -> np.ndarray:
        return self.cols.gpu_type

    @property
    def inference_zone(self) -> np.ndarray:
        return self.cols.inference_zone

    @property
    def node_draining(self) -> np.ndarray:
        return self.cols.node_draining

    def healthy_per_node(self) -> np.ndarray:
        """(n_nodes,) healthy device count — a maintained column now,
        so this is a plain view rather than an O(n·G) reduction."""
        return self.cols.healthy_count

    def candidate_pool(self, gpu_type: int,
                       zone: Optional[str] = None) -> np.ndarray:
        """GPU-Type-based Node Pool mask (§3.4.1), optionally restricted
        to the inference dedicated zone (``"zone"``) or its complement
        (``"general"``).  Cached — the search-space restriction is a dict
        hit instead of two O(n) boolean passes per schedule call."""
        key = (int(gpu_type), zone)
        mask = self._pool_cache.get(key)
        if mask is None:
            mask = ((self.cols.gpu_type == gpu_type) & self.cols.node_healthy
                    & ~self.cols.node_draining)
            if zone == "zone":
                mask = mask & self.cols.inference_zone
            elif zone == "general":
                mask = mask & ~self.cols.inference_zone
            self._pool_cache[key] = mask
        return mask

    def tracked_sum(self, key: Hashable, leaf_id: np.ndarray,
                    n_groups: int,
                    contrib_fn: Callable[["Snapshot", Optional[np.ndarray]],
                                         np.ndarray]) -> np.ndarray:
        """Get-or-create a :class:`TrackedGroupSum` and return its
        per-group totals (int64, live view — do not mutate)."""
        cache = self.tracked.get(key)
        if cache is None:
            cache = TrackedGroupSum(leaf_id, n_groups, contrib_fn, self)
            self.tracked[key] = cache
        return cache.totals

    def invalidate_caches(self) -> None:
        """Drop cached pool masks / derived arrays / tracked aggregates
        (called by the snapshotters after a health/drain refresh)."""
        self._pool_cache.clear()
        self.derived.clear()
        self.tracked.clear()

    # -- placement deltas (§3.4.3) -------------------------------------
    def apply_placement(self, placement: Placement) -> None:
        """Mark a just-committed placement's devices busy and refresh the
        touched rows — identical to what a fresh ``take`` would see,
        because ``ClusterState.allocate`` only flips busy bits."""
        for pod in placement.pods:
            self.cols.gpu_busy[pod.node, list(pod.gpu_indices)] = True
        self._refresh_rows(placement.nodes)

    def apply_release(self, placement: Placement) -> None:
        """Inverse delta for a mid-cycle preemption/release."""
        for pod in placement.pods:
            self.cols.gpu_busy[pod.node, list(pod.gpu_indices)] = False
        self._refresh_rows(placement.nodes)

    def apply_health(self, state: "ClusterState",
                     nodes: Iterable[int]) -> None:
        """Mirror a mid-cycle health/drain mutation of the live state.

        Unlike placement deltas, health changes are NOT delta-invariant:
        the cached §3.4.1 pool masks and every ``derived``/``tracked``
        array key on health, so they must be dropped — otherwise a
        NODE_FAIL landing between ``take`` and a later bind in the same
        cycle can place onto a dead node.
        """
        idx = np.unique(np.fromiter((int(n) for n in nodes),
                                    dtype=np.int64))
        if idx.size == 0:
            return
        self.cols.copy_rows_from(state.cols, idx, invariants=True)
        self.mut_count += 1
        self.invalidate_caches()

    def _refresh_rows(self, nodes: Iterable[int]) -> None:
        idx = np.unique(np.fromiter((int(n) for n in nodes),
                                    dtype=np.int64))
        if idx.size == 0:
            return
        self.cols.refresh_derived(idx)
        self.mut_count += 1
        for cache in self.tracked.values():
            cache.refresh(self, idx)


class FullSnapshotter:
    """Baseline: deep copy of every column, every cycle."""

    name = "full-copy"

    def __init__(self) -> None:
        self._version = 0

    def take(self, state: ClusterState) -> Snapshot:
        self._version += 1
        # Re-derive everything from the bitmaps so direct setup writes
        # (tests/benches pre-fragmenting ``state.gpu_busy``) are folded.
        state.refresh_all_derived()
        state.dirty_nodes.clear()  # parity with the incremental path
        state.invariants_dirty = False
        return Snapshot(state.cols.copy(), version=self._version)


class IncrementalSnapshotter:
    """Kant's optimization: refresh only rows dirtied since last cycle.

    The first ``take`` is a full copy; afterwards only
    ``state.dirty_nodes`` rows are copied into the retained column block.
    """

    name = "incremental"

    def __init__(self) -> None:
        self._snap: Optional[Snapshot] = None
        self._version = 0
        self.rows_copied = 0          # instrumentation for the benchmark

    def take(self, state: ClusterState) -> Snapshot:
        self._version += 1
        if self._snap is None:
            self._snap = FullSnapshotter().take(state)
            self._snap.version = self._version
            self.rows_copied += state.n_nodes
            state.dirty_nodes.clear()
            return self._snap
        snap = self._snap
        self._fold(state, snap)
        snap.version = self._version
        return snap

    def refresh(self, state: ClusterState) -> Snapshot:
        """Fold dirty rows into the retained snapshot WITHOUT bumping the
        version — the cycle pipeline's speculative refresh.  Doing this
        at the end of cycle N makes the begin-of-cycle-N+1 ``take`` a
        version bump over zero dirty rows (when nothing intervened), so
        the snapshot the pipelined path schedules against is bit-for-bit
        the one the unpipelined path would have taken."""
        if self._snap is None:
            raise RuntimeError("refresh() before first take()")
        self._fold(state, self._snap)
        return self._snap

    def _fold(self, state: ClusterState, snap: Snapshot) -> None:
        dirty = sorted(state.dirty_nodes)
        if dirty:
            idx = np.asarray(dirty, dtype=np.int64)
            # Busy rows always refresh; the delta-invariant columns
            # (health, type, zone, drain) only changed if a setter
            # raised ``state.invariants_dirty`` — placement churn flips
            # busy bits alone.  While the flag is down, the §3.4.1 pool
            # masks + ``derived`` arrays stay valid and the ``tracked``
            # aggregates are patched in O(dirty) instead of dropped.
            inv = bool(state.invariants_dirty)
            snap.cols.copy_rows_from(state.cols, idx, invariants=inv)
            snap.mut_count += 1
            if inv:
                snap.invalidate_caches()
            else:
                for cache in snap.tracked.values():
                    cache.refresh(snap, idx)
            self.rows_copied += len(dirty)
        state.dirty_nodes.clear()
        state.invariants_dirty = False


def snapshots_equal(a: Snapshot, b: Snapshot) -> bool:
    return (np.array_equal(a.free_gpus, b.free_gpus)
            and np.array_equal(a.used_gpus, b.used_gpus)
            and np.array_equal(a.gpu_busy, b.gpu_busy)
            and np.array_equal(a.gpu_healthy, b.gpu_healthy)
            and np.array_equal(a.node_healthy, b.node_healthy)
            and np.array_equal(a.gpu_type, b.gpu_type)
            and np.array_equal(a.inference_zone, b.inference_zone)
            and np.array_equal(a.node_draining, b.node_draining))
