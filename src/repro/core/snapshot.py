"""Scheduler snapshots: full deep copy vs incremental update (paper §3.4.3).

Before each scheduling cycle the scheduler works on a consistent copy of
the cluster state so in-flight mutations don't corrupt decisions.  The
naive approach deep-copies everything each cycle; Kant's RSCH instead
maintains a long-lived snapshot and copies only the rows dirtied since the
last cycle.  The paper reports >50 % scheduler CPU reduction on a
1 000-node cluster; ``benchmarks/snapshot_bench.py`` reproduces the
comparison and ``tests/test_snapshot.py`` property-checks equivalence.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Optional

import numpy as np

from .cluster import ClusterState
from .job import Placement


@dataclasses.dataclass
class Snapshot:
    """Immutable-by-convention array bundle RSCH scores against.

    The one sanctioned mutation is the *placement delta*
    (:meth:`apply_placement` / :meth:`apply_release`): after QSCH commits
    a placement to the live ``ClusterState`` mid-cycle, it applies the
    same change to the working snapshot instead of re-taking a full one,
    so one scheduling cycle costs exactly one ``snapshotter.take``
    (§3.4.3 snapshot memory optimization).
    """

    free_gpus: np.ndarray       # (n_nodes,) int32
    used_gpus: np.ndarray       # (n_nodes,) int32
    gpu_busy: np.ndarray        # (n_nodes, G) bool
    gpu_healthy: np.ndarray     # (n_nodes, G) bool
    node_healthy: np.ndarray    # (n_nodes,) bool
    gpu_type: np.ndarray        # (n_nodes,) int32
    inference_zone: np.ndarray  # (n_nodes,) bool
    node_draining: Optional[np.ndarray] = None  # (n_nodes,) bool
    version: int = 0
    # Lazy healthy-device count per node; placement deltas never change
    # health, so it survives a whole cycle's worth of schedule calls.
    _healthy_count: Optional[np.ndarray] = dataclasses.field(
        default=None, repr=False, compare=False)
    # Cached §3.4.1 node-pool masks, keyed by (gpu_type, zone selector);
    # inputs (gpu_type, node_healthy, inference_zone) are delta-invariant,
    # so the cache survives mid-cycle placements and is cleared on take().
    _pool_cache: dict = dataclasses.field(
        default_factory=dict, repr=False, compare=False)
    # Scratch for delta-invariant derived arrays (e.g. per-group healthy
    # capacity); same lifetime as _pool_cache.  Never store anything here
    # that depends on free/used/busy.
    derived: dict = dataclasses.field(
        default_factory=dict, repr=False, compare=False)

    def __post_init__(self) -> None:
        if self.node_draining is None:
            self.node_draining = np.zeros(self.node_healthy.shape,
                                          dtype=bool)

    def healthy_per_node(self) -> np.ndarray:
        """(n_nodes,) healthy device count, cached across schedule calls."""
        if self._healthy_count is None:
            self._healthy_count = self.gpu_healthy.sum(
                axis=1).astype(np.int32)
        return self._healthy_count

    def candidate_pool(self, gpu_type: int,
                       zone: Optional[str] = None) -> np.ndarray:
        """GPU-Type-based Node Pool mask (§3.4.1), optionally restricted
        to the inference dedicated zone (``"zone"``) or its complement
        (``"general"``).  Cached — the search-space restriction is a dict
        hit instead of two O(n) boolean passes per schedule call."""
        key = (int(gpu_type), zone)
        mask = self._pool_cache.get(key)
        if mask is None:
            mask = ((self.gpu_type == gpu_type) & self.node_healthy
                    & ~self.node_draining)
            if zone == "zone":
                mask = mask & self.inference_zone
            elif zone == "general":
                mask = mask & ~self.inference_zone
            self._pool_cache[key] = mask
        return mask

    def invalidate_caches(self) -> None:
        """Drop cached pool masks / derived arrays (called by the
        snapshotters after refreshing rows from the live state)."""
        self._healthy_count = None
        self._pool_cache.clear()
        self.derived.clear()

    # -- placement deltas (§3.4.3) -------------------------------------
    def apply_placement(self, placement: Placement) -> None:
        """Mark a just-committed placement's devices busy and refresh the
        touched rows — identical to what a fresh ``take`` would see,
        because ``ClusterState.allocate`` only flips busy bits."""
        for pod in placement.pods:
            self.gpu_busy[pod.node, list(pod.gpu_indices)] = True
        self._refresh_rows(placement.nodes)

    def apply_release(self, placement: Placement) -> None:
        """Inverse delta for a mid-cycle preemption/release."""
        for pod in placement.pods:
            self.gpu_busy[pod.node, list(pod.gpu_indices)] = False
        self._refresh_rows(placement.nodes)

    def apply_health(self, state: "ClusterState",
                     nodes: Iterable[int]) -> None:
        """Mirror a mid-cycle health/drain mutation of the live state.

        Unlike placement deltas, health changes are NOT delta-invariant:
        the cached §3.4.1 pool masks and every ``derived`` array (e.g.
        per-group healthy capacity) key on health, so they must be
        dropped — otherwise a NODE_FAIL landing between ``take`` and a
        later bind in the same cycle can place onto a dead node.
        """
        idx = np.unique(np.fromiter((int(n) for n in nodes),
                                    dtype=np.int64))
        if idx.size == 0:
            return
        self.gpu_busy[idx] = state.gpu_busy[idx]
        self.gpu_healthy[idx] = state.gpu_healthy[idx]
        self.node_healthy[idx] = state.node_healthy[idx]
        self.node_draining[idx] = state.node_draining[idx]
        self.gpu_type[idx] = state.gpu_type[idx]
        self._refresh_rows(idx)
        self.invalidate_caches()

    def _refresh_rows(self, nodes: Iterable[int]) -> None:
        idx = np.unique(np.fromiter((int(n) for n in nodes),
                                    dtype=np.int64))
        usable = self.gpu_healthy[idx] & ~self.gpu_busy[idx]
        free = usable.sum(axis=1).astype(np.int32)
        self.free_gpus[idx] = np.where(self.node_healthy[idx], free, 0)
        self.used_gpus[idx] = (
            self.gpu_busy[idx] & self.gpu_healthy[idx]
        ).sum(axis=1).astype(np.int32)


class FullSnapshotter:
    """Baseline: deep copy of every array, every cycle."""

    name = "full-copy"

    def __init__(self) -> None:
        self._version = 0

    def take(self, state: ClusterState) -> Snapshot:
        self._version += 1
        state.dirty_nodes.clear()  # parity with the incremental path
        state.invariants_dirty = False
        return Snapshot(
            free_gpus=state.free_gpus().copy(),
            used_gpus=state.used_gpus().copy(),
            gpu_busy=state.gpu_busy.copy(),
            gpu_healthy=state.gpu_healthy.copy(),
            node_healthy=state.node_healthy.copy(),
            gpu_type=state.gpu_type.copy(),
            inference_zone=state.inference_zone.copy(),
            node_draining=state.node_draining.copy(),
            version=self._version,
        )


class IncrementalSnapshotter:
    """Kant's optimization: refresh only rows dirtied since last cycle.

    The first ``take`` is a full copy; afterwards only
    ``state.dirty_nodes`` rows are copied into the retained buffers.
    """

    name = "incremental"

    def __init__(self) -> None:
        self._snap: Optional[Snapshot] = None
        self._version = 0
        self.rows_copied = 0          # instrumentation for the benchmark

    def take(self, state: ClusterState) -> Snapshot:
        self._version += 1
        if self._snap is None:
            self._snap = FullSnapshotter().take(state)
            self._snap.version = self._version
            self.rows_copied += state.n_nodes
            state.dirty_nodes.clear()
            return self._snap

        snap = self._snap
        dirty = sorted(state.dirty_nodes)
        if dirty:
            idx = np.asarray(dirty, dtype=np.int64)
            # Busy-derived fields always refresh.
            usable = state.gpu_healthy[idx] & ~state.gpu_busy[idx]
            free = usable.sum(axis=1).astype(np.int32)
            snap.free_gpus[idx] = np.where(state.node_healthy[idx], free, 0)
            snap.used_gpus[idx] = (
                state.gpu_busy[idx] & state.gpu_healthy[idx]
            ).sum(axis=1).astype(np.int32)
            snap.gpu_busy[idx] = state.gpu_busy[idx]
            # Delta-invariant fields (health, type, zone, drain) only
            # changed if a setter raised ``state.invariants_dirty``;
            # placement churn flips busy bits alone.  While the flag is
            # down, the §3.4.1 pool masks + ``derived`` arrays stay
            # valid and the invariant-row copies are skipped — saving
            # two O(n) boolean passes per cycle on a busy cluster.
            if state.invariants_dirty:
                snap.gpu_healthy[idx] = state.gpu_healthy[idx]
                snap.node_healthy[idx] = state.node_healthy[idx]
                snap.gpu_type[idx] = state.gpu_type[idx]
                snap.inference_zone[idx] = state.inference_zone[idx]
                snap.node_draining[idx] = state.node_draining[idx]
                snap.invalidate_caches()
            self.rows_copied += len(dirty)
        state.dirty_nodes.clear()
        state.invariants_dirty = False
        snap.version = self._version
        return snap


def snapshots_equal(a: Snapshot, b: Snapshot) -> bool:
    return (np.array_equal(a.free_gpus, b.free_gpus)
            and np.array_equal(a.used_gpus, b.used_gpus)
            and np.array_equal(a.gpu_busy, b.gpu_busy)
            and np.array_equal(a.gpu_healthy, b.gpu_healthy)
            and np.array_equal(a.node_healthy, b.node_healthy)
            and np.array_equal(a.gpu_type, b.gpu_type)
            and np.array_equal(a.inference_zone, b.inference_zone)
            and np.array_equal(a.node_draining, b.node_draining))
