"""Cluster interconnect topology model (paper §3.3.5, §3.4.2).

Kant reasons about two interconnect hierarchies:

* **Scale-Out** — the RDMA fabric: access (Leaf) -> aggregation (Spine) ->
  core (Superspine) switches.  Each LeafGroup is abstracted as a
  ``NodeNetGroup``, the basic unit of Kant's hierarchical two-level
  scheduling (§3.4.2).  Communication quality degrades with the lowest
  common switch tier: same-leaf < same-spine < same-superspine < cross.
* **Scale-Up** — hyper-node HBD (Hyper Bandwidth Domain) domains in which
  every GPU of every member node is directly interconnected; EP/TP jobs
  are scheduled at HBD granularity.

Intra-node, GPUs are connected by links of decreasing bandwidth
(NVLink > PCIe > NUMA-remote, §3.3.5); we model this with integer *link
classes* (0 is best).  On the TPU adaptation the same classes map to
"same high-bandwidth island" / "host PCIe" / "NUMA-remote" — the
scheduling logic only ever compares classes, so it is hardware agnostic
(see DESIGN.md "Changed assumptions").
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

# Inter-node distance tiers (lowest common ancestor in the scale-out tree).
DIST_SAME_NODE = 0
DIST_SAME_LEAF = 1
DIST_SAME_SPINE = 2
DIST_SAME_SUPERSPINE = 3
DIST_CROSS = 4


@dataclasses.dataclass(frozen=True)
class ClusterTopology:
    """Static interconnect description for a cluster of ``n_nodes`` hosts.

    All per-node ids are dense ``np.ndarray[int32]`` of shape ``(n_nodes,)``
    so that scheduler scoring stays fully vectorized.
    """

    n_nodes: int
    gpus_per_node: int
    nodes_per_leaf: int
    leaves_per_spine: int
    spines_per_superspine: int
    nodes_per_hbd: int
    # GPUs [0, island) and [island, G) form two NVLink-class islands; a
    # value >= gpus_per_node means one flat all-to-all island (e.g. NVSwitch
    # or a TPU host board).
    nvlink_island: int = 8
    numa_split: int = 4  # GPUs below this index sit on NUMA node 0.

    leaf_id: np.ndarray = dataclasses.field(init=False, repr=False)
    spine_id: np.ndarray = dataclasses.field(init=False, repr=False)
    superspine_id: np.ndarray = dataclasses.field(init=False, repr=False)
    hbd_id: np.ndarray = dataclasses.field(init=False, repr=False)

    def __post_init__(self) -> None:
        if self.n_nodes <= 0:
            raise ValueError("n_nodes must be positive")
        if self.gpus_per_node <= 0:
            raise ValueError("gpus_per_node must be positive")
        if min(self.nodes_per_leaf, self.leaves_per_spine,
               self.spines_per_superspine, self.nodes_per_hbd) <= 0:
            raise ValueError("hierarchy arities must be positive")
        idx = np.arange(self.n_nodes, dtype=np.int32)
        leaf = idx // self.nodes_per_leaf
        spine = leaf // self.leaves_per_spine
        sspine = spine // self.spines_per_superspine
        hbd = idx // self.nodes_per_hbd
        object.__setattr__(self, "leaf_id", leaf)
        object.__setattr__(self, "spine_id", spine)
        object.__setattr__(self, "superspine_id", sspine)
        object.__setattr__(self, "hbd_id", hbd)

    # ------------------------------------------------------------------
    # Derived sizes
    # ------------------------------------------------------------------
    @property
    def n_gpus(self) -> int:
        return self.n_nodes * self.gpus_per_node

    @property
    def n_leaf_groups(self) -> int:
        return int(self.leaf_id[-1]) + 1

    @property
    def n_hbds(self) -> int:
        return int(self.hbd_id[-1]) + 1

    def leaf_members(self, leaf: int) -> np.ndarray:
        """Node indices belonging to NodeNetGroup ``leaf``."""
        return np.nonzero(self.leaf_id == leaf)[0].astype(np.int32)

    def hbd_members(self, hbd: int) -> np.ndarray:
        return np.nonzero(self.hbd_id == hbd)[0].astype(np.int32)

    # ------------------------------------------------------------------
    # Distances
    # ------------------------------------------------------------------
    def node_distance(self, a: int, b: int) -> int:
        """Scale-out distance tier between two nodes (§3.3.5 preference)."""
        if a == b:
            return DIST_SAME_NODE
        if self.leaf_id[a] == self.leaf_id[b]:
            return DIST_SAME_LEAF
        if self.spine_id[a] == self.spine_id[b]:
            return DIST_SAME_SPINE
        if self.superspine_id[a] == self.superspine_id[b]:
            return DIST_SAME_SUPERSPINE
        return DIST_CROSS

    def pairwise_node_distance(self, nodes: np.ndarray) -> np.ndarray:
        """Vectorized pairwise distance matrix for a set of node indices."""
        nodes = np.asarray(nodes, dtype=np.int32)
        leaf = self.leaf_id[nodes]
        spine = self.spine_id[nodes]
        ss = self.superspine_id[nodes]
        same = nodes[:, None] == nodes[None, :]
        d = np.full((len(nodes), len(nodes)), DIST_CROSS, dtype=np.int32)
        d = np.where(ss[:, None] == ss[None, :], DIST_SAME_SUPERSPINE, d)
        d = np.where(spine[:, None] == spine[None, :], DIST_SAME_SPINE, d)
        d = np.where(leaf[:, None] == leaf[None, :], DIST_SAME_LEAF, d)
        d = np.where(same, DIST_SAME_NODE, d)
        return d

    # ------------------------------------------------------------------
    # Intra-node GPU topology (§3.3.5 "Intra-Node GPU Topology")
    # ------------------------------------------------------------------
    def gpu_link_class(self) -> np.ndarray:
        """(G, G) matrix of link classes between GPU slots on one node.

        0 = same NVLink island (best), 1 = cross-island same NUMA (PCIe),
        2 = NUMA-remote.  Diagonal is 0.
        """
        g = self.gpus_per_node
        idx = np.arange(g)
        island = idx // max(1, self.nvlink_island)
        numa = (idx >= self.numa_split).astype(np.int32)
        cls = np.where(island[:, None] == island[None, :], 0,
                       np.where(numa[:, None] == numa[None, :], 1, 2))
        np.fill_diagonal(cls, 0)
        return cls.astype(np.int32)

    def nic_for_gpu(self) -> np.ndarray:
        """Best RDMA-NIC index per GPU slot (one NIC per NVLink island)."""
        idx = np.arange(self.gpus_per_node)
        return (idx // max(1, self.nvlink_island)).astype(np.int32)

    # ------------------------------------------------------------------
    # Optimal placement reference for JTTED (§4.5)
    # ------------------------------------------------------------------
    def optimal_node_num(self, n_gpus: int) -> int:
        """Minimum node count able to host ``n_gpus`` (ceil division)."""
        return -(-n_gpus // self.gpus_per_node)

    def optimal_group_num(self, n_gpus: int) -> int:
        """Minimum NodeNetGroup count for ``n_gpus``.

        "Optimal node number" in §4.5 is the minimum node count keeping
        all-to-all traffic inside a single LeafGroup when possible; a job
        larger than one group necessarily spans ``ceil(nodes/group_size)``
        groups.
        """
        nodes = self.optimal_node_num(n_gpus)
        return -(-nodes // self.nodes_per_leaf)


def small_topology(n_nodes: int = 16, gpus_per_node: int = 8,
                   nodes_per_leaf: int = 4) -> ClusterTopology:
    """Convenience topology for tests and examples."""
    return ClusterTopology(
        n_nodes=n_nodes,
        gpus_per_node=gpus_per_node,
        nodes_per_leaf=nodes_per_leaf,
        leaves_per_spine=2,
        spines_per_superspine=2,
        nodes_per_hbd=nodes_per_leaf,
        nvlink_island=gpus_per_node,  # flat island by default
        numa_split=gpus_per_node // 2,
    )


def training_cluster_topology(n_gpus: int = 8000, gpus_per_node: int = 8,
                              nodes_per_leaf: int = 32) -> ClusterTopology:
    """Paper §5.1: homogeneous 8 000-GPU training cluster."""
    n_nodes = n_gpus // gpus_per_node
    return ClusterTopology(
        n_nodes=n_nodes,
        gpus_per_node=gpus_per_node,
        nodes_per_leaf=nodes_per_leaf,
        leaves_per_spine=4,
        spines_per_superspine=4,
        nodes_per_hbd=nodes_per_leaf,
        nvlink_island=gpus_per_node,
        numa_split=gpus_per_node // 2,
    )
