"""Array-native cluster resource state (paper §3.1, §3.4).

The scheduler's view of the cluster is a structure-of-arrays block
(:class:`~repro.core.columns.StateColumns`) — per-node free/used/busy/
healthy counts, fragmentation, per-device busy/health bitmaps, GPU-type
ids — plus the static :class:`~repro.core.topology.ClusterTopology`.
Keeping the state dense serves the paper's §3.4 optimizations directly:

* *GPU-Type-based Node Pools* (§3.4.1) are boolean masks over the node
  axis, so restricting the search space to one pool is a vectorized
  ``mask &``, not a data-structure walk;
* *incremental snapshots* (§3.4.3) reduce to copying dirty rows of the
  shared column block (see :mod:`repro.core.snapshot`);
* per-node **derived columns** (free/used/busy/healthy counts, the §4.3
  fragmentation mask) are *maintained* behind the same dirty tracking
  instead of recomputed as a full ``(n_nodes × gpus_per_node)``
  reduction on every read — a metrics SAMPLE or snapshot take touches
  O(dirty) rows, not O(n·G) cells.

Mutation goes through :meth:`ClusterState.allocate` / ``release`` /
``set_*_health`` / ``set_drain`` only, so dirty-row tracking and the
allocation ledger can never drift from the arrays (property-tested in
``tests/test_properties.py``).  The one tolerated exception is *setup
writes*: tests and benchmarks may pre-fragment a fresh state by writing
``state.gpu_busy`` directly **before** the first derived read or
snapshot take — the derived columns initialize lazily on first access
(and every ``FullSnapshotter.take`` re-derives from the bitmaps), so
such writes are folded in exactly once.
"""

from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Set, Tuple

import numpy as np

from .columns import StateColumns
from .job import Job, Placement, PodPlacement
from .topology import ClusterTopology


class ClusterState:
    """Live cluster state: shared column block + allocation ledger."""

    def __init__(self, topology: ClusterTopology, cols: StateColumns,
                 allocations: Optional[Dict[int, Placement]] = None) -> None:
        self.topology = topology
        self.cols = cols
        # Allocation ledger: job uid -> placement.
        self.allocations: Dict[int, Placement] = allocations or {}
        # Nodes whose rows changed since the dirty set was last drained
        # (consumed by the incremental snapshot, §3.4.3).
        self.dirty_nodes: Set[int] = set()
        # True when a *delta-invariant* column (health, drain, type,
        # zone) changed since the last snapshot take.  Placement churn
        # only flips busy bits, so while this stays False the
        # incremental snapshotter keeps its cached §3.4.1 pool masks /
        # derived arrays and skips the invariant-row copies entirely.
        self.invariants_dirty: bool = False
        # Derived columns are refreshed lazily on first read so setup
        # code may bulk-write the bitmaps on a fresh state (see module
        # docstring); after that the mutators maintain them per-row.
        self._derived_ready = False

    # ------------------------------------------------------------------
    # Constructors
    # ------------------------------------------------------------------
    @classmethod
    def create(cls, topology: ClusterTopology,
               gpu_type: Optional[np.ndarray] = None,
               inference_zone_nodes: int = 0) -> "ClusterState":
        return cls(topology, StateColumns.create(
            topology.n_nodes, topology.gpus_per_node, gpu_type,
            inference_zone_nodes))

    # ------------------------------------------------------------------
    # Column views (attribute API preserved over the shared block)
    # ------------------------------------------------------------------
    @property
    def gpu_type(self) -> np.ndarray:
        return self.cols.gpu_type

    @property
    def gpu_busy(self) -> np.ndarray:
        return self.cols.gpu_busy

    @property
    def gpu_healthy(self) -> np.ndarray:
        return self.cols.gpu_healthy

    @property
    def node_healthy(self) -> np.ndarray:
        return self.cols.node_healthy

    @property
    def inference_zone(self) -> np.ndarray:
        return self.cols.inference_zone

    @property
    def node_draining(self) -> np.ndarray:
        return self.cols.node_draining

    @property
    def n_nodes(self) -> int:
        return self.topology.n_nodes

    @property
    def gpus_per_node(self) -> int:
        return self.topology.gpus_per_node

    # ------------------------------------------------------------------
    # Derived views — maintained int32/bool columns, O(1) per read
    # ------------------------------------------------------------------
    def ensure_derived(self) -> None:
        """Fold any pre-snapshot setup writes into the derived columns
        (idempotent; called by every derived read and snapshot take)."""
        if not self._derived_ready:
            self.cols.refresh_derived()
            self._derived_ready = True

    def refresh_all_derived(self) -> None:
        """Unconditional full re-derivation from the bitmaps — used by
        ``FullSnapshotter.take`` so direct setup writes are folded even
        after the lazy init already ran."""
        self.cols.refresh_derived()
        self._derived_ready = True

    def _update_rows(self, idx) -> None:
        if self._derived_ready:
            self.cols.refresh_derived(np.asarray(idx, dtype=np.int64))

    def free_gpus(self) -> np.ndarray:
        """(n_nodes,) count of healthy, unallocated devices per node."""
        self.ensure_derived()
        return self.cols.free_gpus

    def used_gpus(self) -> np.ndarray:
        self.ensure_derived()
        return self.cols.used_gpus

    def healthy_counts(self) -> np.ndarray:
        """(n_nodes,) healthy device count per node (maintained)."""
        self.ensure_derived()
        return self.cols.healthy_count

    def total_allocatable(self, gpu_type: Optional[int] = None) -> int:
        """Total healthy GPU capacity (optionally within one node pool)."""
        self.ensure_derived()
        mask = self.cols.node_healthy
        if gpu_type is not None:
            mask = mask & (self.cols.gpu_type == gpu_type)
        return int(self.cols.healthy_count[mask].sum())

    def total_allocated(self, gpu_type: Optional[int] = None) -> int:
        self.ensure_derived()
        mask = self.cols.node_healthy
        if gpu_type is not None:
            mask = mask & (self.cols.gpu_type == gpu_type)
        return int(self.cols.busy_count[mask].sum())

    def pool_mask(self, gpu_type: int) -> np.ndarray:
        """Node-pool membership mask (§3.4.1 heterogeneous splitting).
        Draining nodes are unschedulable, so they leave the pool."""
        return ((self.cols.gpu_type == gpu_type) & self.cols.node_healthy
                & ~self.cols.node_draining)

    def pool_free(self, gpu_type: int) -> int:
        """Free GPUs inside one GPU-Type-based Node Pool."""
        return int(self.free_gpus()[self.pool_mask(gpu_type)].sum())

    def group_free(self, gpu_type: int) -> np.ndarray:
        """(n_leaf_groups,) free GPUs per NodeNetGroup within a pool."""
        free = np.where(self.pool_mask(gpu_type), self.free_gpus(), 0)
        return np.bincount(self.topology.leaf_id, weights=free,
                           minlength=self.topology.n_leaf_groups
                           ).astype(np.int32)

    def group_used(self, gpu_type: int) -> np.ndarray:
        used = np.where(self.pool_mask(gpu_type), self.used_gpus(), 0)
        return np.bincount(self.topology.leaf_id, weights=used,
                           minlength=self.topology.n_leaf_groups
                           ).astype(np.int32)

    def fragmented_nodes(self) -> np.ndarray:
        """Bool mask of fragmented nodes per §4.3: neither fully idle nor
        fully occupied (w.r.t. healthy devices).  Maintained column — no
        (n × G) reduction on the metrics SAMPLE path."""
        self.ensure_derived()
        return self.cols.fragmented

    # ------------------------------------------------------------------
    # Mutation (the only entry points — keeps dirty tracking sound)
    # ------------------------------------------------------------------
    def _touch(self, nodes: Iterable[int]) -> None:
        self.dirty_nodes.update(int(n) for n in nodes)

    def allocate(self, job: Job, placement: Placement) -> None:
        """Bind a job to concrete devices.  Raises on any conflict; the
        caller (RSCH) must have validated the placement — gang semantics
        mean we never partially apply (§3.3.2)."""
        if job.uid in self.allocations:
            raise ValueError(f"job {job.uid} already allocated")
        if placement.n_gpus != job.n_gpus:
            raise ValueError("placement does not cover the job request")
        # Validate first (all-or-nothing), then apply.
        for pod in placement.pods:
            self._validate_pod(job, pod)
        for pod in placement.pods:
            self.cols.gpu_busy[pod.node, list(pod.gpu_indices)] = True
        self.allocations[job.uid] = placement
        nodes = placement.nodes
        self._touch(nodes)
        self._update_rows(nodes)

    def _validate_pod(self, job: Job, pod: PodPlacement) -> None:
        n = pod.node
        if not (0 <= n < self.n_nodes):
            raise ValueError(f"node {n} out of range")
        if not self.cols.node_healthy[n]:
            raise ValueError(f"node {n} is unhealthy")
        if self.cols.node_draining[n]:
            raise ValueError(f"node {n} is draining")
        if self.cols.gpu_type[n] != job.gpu_type:
            raise ValueError(
                f"node {n} pool {int(self.cols.gpu_type[n])} != job pool "
                f"{job.gpu_type}")
        if len(pod.gpu_indices) != job.gpus_per_pod:
            raise ValueError("pod placement size mismatch")
        idx = list(pod.gpu_indices)
        if max(idx) >= self.gpus_per_node or min(idx) < 0:
            raise ValueError("GPU index out of range")
        if self.cols.gpu_busy[n, idx].any():
            raise ValueError(f"GPU already busy on node {n}")
        if not self.cols.gpu_healthy[n, idx].all():
            raise ValueError(f"unhealthy GPU selected on node {n}")

    def release(self, job_uid: int) -> Placement:
        """Free a job's devices (completion or preemption)."""
        placement = self.allocations.pop(job_uid)
        for pod in placement.pods:
            self.cols.gpu_busy[pod.node, list(pod.gpu_indices)] = False
        nodes = placement.nodes
        self._touch(nodes)
        self._update_rows(nodes)
        return placement

    def set_gpu_health(self, node: int, gpu: int, healthy: bool) -> None:
        self.cols.gpu_healthy[node, gpu] = healthy
        self.invariants_dirty = True
        self._touch([node])
        self._update_rows([node])

    def set_node_health(self, node: int, healthy: bool) -> None:
        self.cols.node_healthy[node] = healthy
        self.invariants_dirty = True
        self._touch([node])
        self._update_rows([node])

    def set_drain(self, nodes: Iterable[int], draining: bool) -> None:
        """Open/close a planned maintenance drain window (dynamics):
        draining nodes accept no new placements but keep running work."""
        nodes = [int(n) for n in nodes]
        self.cols.node_draining[nodes] = draining
        self.invariants_dirty = True
        self._touch(nodes)
        self._update_rows(nodes)

    # ------------------------------------------------------------------
    # Failure-domain queries (dynamics subsystem)
    # ------------------------------------------------------------------
    def jobs_on(self, node: int, gpu: Optional[int] = None) -> List[int]:
        """Job uids with at least one pod on ``node`` (optionally on one
        specific device) — the blast radius of a NODE_FAIL/GPU_FAIL.
        Plain ledger scan: failures are rare events, not hot-path."""
        out: List[int] = []
        for uid, placement in self.allocations.items():
            for pod in placement.pods:
                if pod.node == node and (gpu is None
                                         or gpu in pod.gpu_indices):
                    out.append(uid)
                    break
        return out

    # ------------------------------------------------------------------
    # Invariant check (used by property tests)
    # ------------------------------------------------------------------
    def check_invariants(self) -> None:
        busy_from_ledger = np.zeros_like(self.cols.gpu_busy)
        for placement in self.allocations.values():
            for pod in placement.pods:
                idx = list(pod.gpu_indices)
                if busy_from_ledger[pod.node, idx].any():
                    raise AssertionError("double allocation in ledger")
                busy_from_ledger[pod.node, idx] = True
        if not np.array_equal(busy_from_ledger, self.cols.gpu_busy):
            raise AssertionError("gpu_busy drifted from allocation ledger")
        free = self.free_gpus()
        if (free < 0).any() or (free > self.gpus_per_node).any():
            raise AssertionError("free GPU count out of range")
        # Maintained derived columns must equal a fresh re-derivation
        # from the bitmaps (the SoA maintenance contract).
        fresh = self.cols.copy()
        fresh.refresh_derived()
        if not self.cols.columns_equal(fresh):
            raise AssertionError("derived columns drifted from bitmaps")


__all__ = ["ClusterState", "StateColumns"]
