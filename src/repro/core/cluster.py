"""Array-native cluster resource state (paper §3.1, §3.4).

The scheduler's view of the cluster is a bundle of dense arrays —
per-node free-GPU counts, per-device busy/health bitmaps, GPU-type ids —
plus the static :class:`~repro.core.topology.ClusterTopology`.  Keeping the
state dense serves two of the paper's §3.4 optimizations directly:

* *GPU-Type-based Node Pools* (§3.4.1) are boolean masks over the node
  axis, so restricting the search space to one pool is a vectorized
  ``mask &``, not a data-structure walk;
* *incremental snapshots* (§3.4.3) reduce to copying dirty rows of these
  arrays (see :mod:`repro.core.snapshot`).

Mutation goes through :meth:`ClusterState.allocate` / ``release`` only, so
dirty-row tracking and the allocation ledger can never drift from the
arrays (property-tested in ``tests/test_properties.py``).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

import numpy as np

from .job import Job, Placement, PodPlacement
from .topology import ClusterTopology


@dataclasses.dataclass
class ClusterState:
    topology: ClusterTopology
    # (n_nodes,) int32 GPU model id per node -> node pools (§3.4.1).
    gpu_type: np.ndarray
    # (n_nodes, gpus_per_node) bool: device currently allocated.
    gpu_busy: np.ndarray
    # (n_nodes, gpus_per_node) bool: device healthy (§3.3.1 health aware).
    gpu_healthy: np.ndarray
    # (n_nodes,) bool: node schedulable at all.
    node_healthy: np.ndarray
    # (n_nodes,) bool: node belongs to the inference dedicated zone
    # (E-Spread, §3.3.4).
    inference_zone: np.ndarray
    # (n_nodes,) bool: node inside a planned maintenance drain window —
    # running jobs keep running, but no new placement may land there
    # (dynamics subsystem; distinct from node_healthy so capacity/GAR
    # accounting is unaffected by drains).
    node_draining: Optional[np.ndarray] = None
    # Allocation ledger: job uid -> placement.
    allocations: Dict[int, Placement] = dataclasses.field(default_factory=dict)
    # Nodes whose rows changed since the dirty set was last drained
    # (consumed by the incremental snapshot, §3.4.3).
    dirty_nodes: Set[int] = dataclasses.field(default_factory=set)
    # True when a *delta-invariant* field (health, drain, type, zone)
    # changed since the last snapshot take.  Placement churn only flips
    # busy bits, so while this stays False the incremental snapshotter
    # keeps its cached §3.4.1 pool masks / derived arrays and skips the
    # invariant-row copies entirely.
    invariants_dirty: bool = False

    def __post_init__(self) -> None:
        if self.node_draining is None:
            self.node_draining = np.zeros(self.topology.n_nodes, dtype=bool)

    # ------------------------------------------------------------------
    # Constructors
    # ------------------------------------------------------------------
    @classmethod
    def create(cls, topology: ClusterTopology,
               gpu_type: Optional[np.ndarray] = None,
               inference_zone_nodes: int = 0) -> "ClusterState":
        n, g = topology.n_nodes, topology.gpus_per_node
        if gpu_type is None:
            gpu_type = np.zeros(n, dtype=np.int32)
        gpu_type = np.asarray(gpu_type, dtype=np.int32)
        if gpu_type.shape != (n,):
            raise ValueError("gpu_type must have shape (n_nodes,)")
        zone = np.zeros(n, dtype=bool)
        if inference_zone_nodes:
            zone[:inference_zone_nodes] = True
        return cls(
            topology=topology,
            gpu_type=gpu_type,
            gpu_busy=np.zeros((n, g), dtype=bool),
            gpu_healthy=np.ones((n, g), dtype=bool),
            node_healthy=np.ones(n, dtype=bool),
            inference_zone=zone,
        )

    # ------------------------------------------------------------------
    # Derived views (all vectorized)
    # ------------------------------------------------------------------
    @property
    def n_nodes(self) -> int:
        return self.topology.n_nodes

    @property
    def gpus_per_node(self) -> int:
        return self.topology.gpus_per_node

    def free_gpus(self) -> np.ndarray:
        """(n_nodes,) count of healthy, unallocated devices per node."""
        usable = self.gpu_healthy & ~self.gpu_busy
        free = usable.sum(axis=1).astype(np.int32)
        return np.where(self.node_healthy, free, 0).astype(np.int32)

    def used_gpus(self) -> np.ndarray:
        return (self.gpu_busy & self.gpu_healthy).sum(axis=1).astype(np.int32)

    def total_allocatable(self, gpu_type: Optional[int] = None) -> int:
        """Total healthy GPU capacity (optionally within one node pool)."""
        mask = self.node_healthy
        if gpu_type is not None:
            mask = mask & (self.gpu_type == gpu_type)
        return int((self.gpu_healthy & mask[:, None]).sum())

    def total_allocated(self, gpu_type: Optional[int] = None) -> int:
        mask = self.node_healthy
        if gpu_type is not None:
            mask = mask & (self.gpu_type == gpu_type)
        return int((self.gpu_busy & mask[:, None]).sum())

    def pool_mask(self, gpu_type: int) -> np.ndarray:
        """Node-pool membership mask (§3.4.1 heterogeneous splitting).
        Draining nodes are unschedulable, so they leave the pool."""
        return ((self.gpu_type == gpu_type) & self.node_healthy
                & ~self.node_draining)

    def pool_free(self, gpu_type: int) -> int:
        """Free GPUs inside one GPU-Type-based Node Pool."""
        return int(self.free_gpus()[self.pool_mask(gpu_type)].sum())

    def group_free(self, gpu_type: int) -> np.ndarray:
        """(n_leaf_groups,) free GPUs per NodeNetGroup within a pool."""
        free = np.where(self.pool_mask(gpu_type), self.free_gpus(), 0)
        return np.bincount(self.topology.leaf_id, weights=free,
                           minlength=self.topology.n_leaf_groups
                           ).astype(np.int32)

    def group_used(self, gpu_type: int) -> np.ndarray:
        used = np.where(self.pool_mask(gpu_type), self.used_gpus(), 0)
        return np.bincount(self.topology.leaf_id, weights=used,
                           minlength=self.topology.n_leaf_groups
                           ).astype(np.int32)

    def fragmented_nodes(self) -> np.ndarray:
        """Bool mask of fragmented nodes per §4.3: neither fully idle nor
        fully occupied (w.r.t. healthy devices)."""
        healthy_cap = self.gpu_healthy.sum(axis=1)
        used = (self.gpu_busy & self.gpu_healthy).sum(axis=1)
        frag = (used > 0) & (used < healthy_cap)
        return frag & self.node_healthy & (healthy_cap > 0)

    # ------------------------------------------------------------------
    # Mutation (the only entry points — keeps dirty tracking sound)
    # ------------------------------------------------------------------
    def _touch(self, nodes: Iterable[int]) -> None:
        self.dirty_nodes.update(int(n) for n in nodes)

    def allocate(self, job: Job, placement: Placement) -> None:
        """Bind a job to concrete devices.  Raises on any conflict; the
        caller (RSCH) must have validated the placement — gang semantics
        mean we never partially apply (§3.3.2)."""
        if job.uid in self.allocations:
            raise ValueError(f"job {job.uid} already allocated")
        if placement.n_gpus != job.n_gpus:
            raise ValueError("placement does not cover the job request")
        # Validate first (all-or-nothing), then apply.
        for pod in placement.pods:
            self._validate_pod(job, pod)
        for pod in placement.pods:
            self.gpu_busy[pod.node, list(pod.gpu_indices)] = True
        self.allocations[job.uid] = placement
        self._touch(placement.nodes)

    def _validate_pod(self, job: Job, pod: PodPlacement) -> None:
        n = pod.node
        if not (0 <= n < self.n_nodes):
            raise ValueError(f"node {n} out of range")
        if not self.node_healthy[n]:
            raise ValueError(f"node {n} is unhealthy")
        if self.node_draining[n]:
            raise ValueError(f"node {n} is draining")
        if self.gpu_type[n] != job.gpu_type:
            raise ValueError(
                f"node {n} pool {int(self.gpu_type[n])} != job pool "
                f"{job.gpu_type}")
        if len(pod.gpu_indices) != job.gpus_per_pod:
            raise ValueError("pod placement size mismatch")
        idx = list(pod.gpu_indices)
        if max(idx) >= self.gpus_per_node or min(idx) < 0:
            raise ValueError("GPU index out of range")
        if self.gpu_busy[n, idx].any():
            raise ValueError(f"GPU already busy on node {n}")
        if not self.gpu_healthy[n, idx].all():
            raise ValueError(f"unhealthy GPU selected on node {n}")

    def release(self, job_uid: int) -> Placement:
        """Free a job's devices (completion or preemption)."""
        placement = self.allocations.pop(job_uid)
        for pod in placement.pods:
            self.gpu_busy[pod.node, list(pod.gpu_indices)] = False
        self._touch(placement.nodes)
        return placement

    def set_gpu_health(self, node: int, gpu: int, healthy: bool) -> None:
        self.gpu_healthy[node, gpu] = healthy
        self.invariants_dirty = True
        self._touch([node])

    def set_node_health(self, node: int, healthy: bool) -> None:
        self.node_healthy[node] = healthy
        self.invariants_dirty = True
        self._touch([node])

    def set_drain(self, nodes: Iterable[int], draining: bool) -> None:
        """Open/close a planned maintenance drain window (dynamics):
        draining nodes accept no new placements but keep running work."""
        nodes = [int(n) for n in nodes]
        self.node_draining[nodes] = draining
        self.invariants_dirty = True
        self._touch(nodes)

    # ------------------------------------------------------------------
    # Failure-domain queries (dynamics subsystem)
    # ------------------------------------------------------------------
    def jobs_on(self, node: int, gpu: Optional[int] = None) -> List[int]:
        """Job uids with at least one pod on ``node`` (optionally on one
        specific device) — the blast radius of a NODE_FAIL/GPU_FAIL.
        Plain ledger scan: failures are rare events, not hot-path."""
        out: List[int] = []
        for uid, placement in self.allocations.items():
            for pod in placement.pods:
                if pod.node == node and (gpu is None
                                         or gpu in pod.gpu_indices):
                    out.append(uid)
                    break
        return out

    # ------------------------------------------------------------------
    # Invariant check (used by property tests)
    # ------------------------------------------------------------------
    def check_invariants(self) -> None:
        busy_from_ledger = np.zeros_like(self.gpu_busy)
        for placement in self.allocations.values():
            for pod in placement.pods:
                idx = list(pod.gpu_indices)
                if busy_from_ledger[pod.node, idx].any():
                    raise AssertionError("double allocation in ledger")
                busy_from_ledger[pod.node, idx] = True
        if not np.array_equal(busy_from_ledger, self.gpu_busy):
            raise AssertionError("gpu_busy drifted from allocation ledger")
        free = self.free_gpus()
        if (free < 0).any() or (free > self.gpus_per_node).any():
            raise AssertionError("free GPU count out of range")
