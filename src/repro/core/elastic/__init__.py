"""Elastic training: scheduler × parallelism co-design.

A training job declares an :class:`ElasticSpec` — alternative DP×TP
parallelism plans at different GPU counts, each with a throughput
estimate (from the dry-run HLO roofline via
:mod:`~repro.core.elastic.estimate`, or supplied directly) — and an
**ElasticPolicy** plugin decides when to *shrink* the gang into
currently-free fragmented capacity instead of queueing for the ideal
shape, and when to *grow* it back at the next checkpoint boundary.
Reshapes reuse the checkpoint-restart machinery
(:mod:`repro.core.dynamics.recovery`): the cost is restart overhead
plus work since the last checkpoint, and the simulator scales the
remaining work by the active plan's relative throughput.

* :mod:`~repro.core.elastic.spec`     — ParallelismPlan / ElasticSpec;
* :mod:`~repro.core.elastic.estimate` — plan throughput from dry-run
  artifacts (memoized, no jax);
* :mod:`~repro.core.elastic.policy`   — the built-in GreedyElastic
  policy (largest fitting plan, payback-gated grow);
* :mod:`~repro.core.elastic.manager`  — the ElasticManager executing
  decisions through QSCH.

Enable with ``QSCH(..., elastic=ElasticManager())``; jobs without an
``ElasticSpec`` are scheduled byte-identically to the rigid path (gated
by ``benchmarks/elastic_bench.py``).  See ``docs/elastic.md``.
"""

from .estimate import (plan_cache, plan_cache_stats, scaling_artifacts,
                       spec_from_artifacts, step_time_from_terms)
from .manager import ElasticConfig, ElasticManager
from .policy import GreedyElastic
from .spec import ElasticSpec, ParallelismPlan

__all__ = [
    "ElasticSpec", "ParallelismPlan",
    "ElasticConfig", "ElasticManager", "GreedyElastic",
    "spec_from_artifacts", "scaling_artifacts", "step_time_from_terms",
    "plan_cache", "plan_cache_stats",
]
