"""Built-in ElasticPolicy: greedy shrink, payback-gated grow.

``GreedyElastic`` implements the two decisions of the
:class:`~repro.core.framework.api.ElasticPolicyPlugin` contract:

* **shrink** (``select_plan``): at every placement attempt, take the
  highest-throughput plan that *fits the working snapshot right now*.
  If the ideal plan fits, the job runs rigid; if only a smaller plan
  fits, the gang starts immediately in the fragmented capacity instead
  of queueing.  Plans below ``min_rate`` of the ideal throughput are
  never selected — running a 128-GPU job at 1/16th speed mostly wastes
  the checkpoint overhead of getting it there.
* **grow** (``want_grow``): for a running shrunk job at a checkpoint
  boundary, find the best plan that would fit the free capacity *plus
  the job's own devices*, and reshape only if the wall-time saved on
  the remaining work exceeds ``grow_payback`` times the reshape cost
  (restart overhead; work since the last checkpoint is bounded by the
  boundary slack).  Conservative by design: a reshape that cannot pay
  for itself is a pure goodput loss.
"""

from __future__ import annotations

from typing import Optional

import numpy as np

from ..framework.api import CycleContext, ElasticPolicyPlugin
from ..framework.registry import register
from ..job import Job
from ..snapshot import Snapshot
from .spec import ParallelismPlan

__all__ = ["GreedyElastic"]


@register
class GreedyElastic(ElasticPolicyPlugin):
    """Largest-fitting-plan shrink + payback-gated grow (see module
    docstring)."""

    name = "GreedyElastic"

    def __init__(self, min_rate: float = 0.25,
                 grow_payback: float = 2.0) -> None:
        if not 0.0 <= min_rate <= 1.0:
            raise ValueError("min_rate must be in [0, 1]")
        if grow_payback < 0.0:
            raise ValueError("grow_payback must be non-negative")
        self.min_rate = float(min_rate)
        self.grow_payback = float(grow_payback)

    # ------------------------------------------------------------------
    def _fits(self, job: Job, plan: ParallelismPlan, snap: Snapshot,
              ctx: Optional[CycleContext]) -> bool:
        rsch = ctx.rsch if ctx is not None else None
        if rsch is not None:
            # Honors the job profile's full Filter chain, same as
            # dynamic admission.
            return rsch.feasible_shape(job, snap, plan.n_pods,
                                       plan.gpus_per_pod)
        pool = snap.candidate_pool(int(job.gpu_type))
        slots = np.where(pool & (snap.free_gpus >= plan.gpus_per_pod),
                         snap.free_gpus // plan.gpus_per_pod, 0)
        return int(slots.sum()) >= plan.n_pods

    def select_plan(self, job: Job, snap: Snapshot,
                    ctx: Optional[CycleContext]
                    ) -> Optional[ParallelismPlan]:
        spec = job.elastic
        ideal = spec.ideal()
        floor = self.min_rate * ideal.throughput
        for plan in spec.by_throughput():     # best first
            if plan is not ideal and plan.throughput < floor:
                break                          # everything after is slower
            if self._fits(job, plan, snap, ctx):
                return plan
        return ideal                           # nothing fits: behave rigid

    # ------------------------------------------------------------------
    def want_grow(self, job: Job, snap: Snapshot,
                  ctx: Optional[CycleContext], reshape_cost_s: float
                  ) -> Optional[ParallelismPlan]:
        spec, cur = job.elastic, job.active_plan
        if spec is None or cur is None:
            return None
        ideal = spec.ideal()
        # Capacity view for the hypothetical reshape: free GPUs plus the
        # job's own devices, which the reshape returns to the pool.
        free = snap.free_gpus.astype(np.int64).copy()
        if job.placement is not None:
            for pod in job.placement.pods:
                free[pod.node] += len(pod.gpu_indices)
        pool = snap.candidate_pool(int(job.gpu_type))
        target = None
        for plan in spec.by_throughput():
            if plan.throughput <= cur.throughput:
                break                          # no improvement below here
            slots = np.where(pool & (free >= plan.gpus_per_pod),
                             free // plan.gpus_per_pod, 0)
            if int(slots.sum()) >= plan.n_pods:
                target = plan
                break
        if target is None:
            return None
        # Payback: wall time saved on the remaining work must beat the
        # reshape cost with margin.  Remaining work is estimated
        # conservatively — checkpoint state plus everything this
        # attempt has run (even the yet-uncheckpointed slice, which the
        # boundary slack bounds).
        remaining = job.original_duration - job.checkpointed_progress
        r_cur = cur.throughput / ideal.throughput
        if ctx is not None and job.run_time is not None:
            remaining -= max(0.0, ctx.now - job.run_time) * r_cur
        if remaining <= 0.0:
            return None
        r_new = target.throughput / ideal.throughput
        saved = remaining / r_cur - remaining / r_new
        if saved <= self.grow_payback * max(reshape_cost_s, 0.0):
            return None
        return target
