"""ElasticSpec: the alternative-parallelism contract of a training job.

Kant gang-schedules distributed training all-or-nothing (§3.2.1), so a
128-GPU job waits idle while 64 GPUs of fragmented capacity sit free.
The elastic subsystem closes that gap Arena-style: a job declares a
small menu of :class:`ParallelismPlan`s — concrete DP×TP shapes at
different GPU counts, each with a throughput estimate (derived from the
dry-run HLO analysis via :mod:`repro.core.elastic.estimate`, or
supplied directly) — and the scheduler may run the job at any plan in
the menu, shrinking into fragmented capacity now and growing back at a
checkpoint boundary later.

Unit convention: ``throughput`` is *any* consistent rate (steps/s,
tokens/s, 1/step-time) — only ratios between plans of one spec are ever
used.  The **ideal** plan is the highest-throughput one; a job's
``duration``/``original_duration`` are expressed in ideal-plan seconds
("work"), and an attempt at plan *p* burns wall time at relative rate
``p.throughput / ideal.throughput`` (see ``Job.work_rate``).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

from ..job import JobKind

__all__ = ["ParallelismPlan", "ElasticSpec"]


@dataclasses.dataclass(frozen=True)
class ParallelismPlan:
    """One concrete shape a job can run at: ``n_pods`` pods of
    ``gpus_per_pod`` GPUs, delivering ``throughput`` (relative units,
    see module docstring).  ``name`` is informational (e.g.
    ``"dp16xtp8"``)."""

    n_pods: int
    gpus_per_pod: int
    throughput: float
    name: str = ""

    def __post_init__(self) -> None:
        if self.n_pods <= 0 or self.gpus_per_pod <= 0:
            raise ValueError("plans must request at least one pod and GPU")
        if self.throughput <= 0:
            raise ValueError("plan throughput must be positive")

    @property
    def n_gpus(self) -> int:
        return self.n_pods * self.gpus_per_pod

    @property
    def shape(self) -> Tuple[int, int]:
        return (self.n_pods, self.gpus_per_pod)

    def label(self) -> str:
        return self.name or f"{self.n_pods}x{self.gpus_per_pod}"


@dataclasses.dataclass(frozen=True)
class ElasticSpec:
    """The menu of plans a job may run at.  Immutable and shareable
    across job clones (benchmark A/Bs clone the same spec object)."""

    plans: Tuple[ParallelismPlan, ...]

    def __post_init__(self) -> None:
        plans = tuple(self.plans)
        object.__setattr__(self, "plans", plans)
        if not plans:
            raise ValueError("ElasticSpec needs at least one plan")
        shapes = [p.shape for p in plans]
        if len(set(shapes)) != len(shapes):
            raise ValueError("duplicate (n_pods, gpus_per_pod) plan shapes")

    # ------------------------------------------------------------------
    def ideal(self) -> ParallelismPlan:
        """The highest-throughput plan — the shape a rigid scheduler
        would queue for, and the yardstick work is measured against.
        Ties break toward more GPUs, then fewer pods (determinism)."""
        return max(self.plans,
                   key=lambda p: (p.throughput, p.n_gpus, -p.n_pods))

    def by_throughput(self) -> Tuple[ParallelismPlan, ...]:
        """Plans best-first (same tie-breaking as :meth:`ideal`)."""
        return tuple(sorted(
            self.plans,
            key=lambda p: (-p.throughput, -p.n_gpus, p.n_pods)))

    def plan_for(self, n_pods: int, gpus_per_pod: int
                 ) -> Optional[ParallelismPlan]:
        for p in self.plans:
            if p.shape == (n_pods, gpus_per_pod):
                return p
        return None

    def min_gpus(self) -> int:
        return min(p.n_gpus for p in self.plans)

    # ------------------------------------------------------------------
    def validate_for(self, job) -> None:
        """A spec is only meaningful on a gang-scheduled training job
        whose declared shape IS the ideal plan — ``original_duration``
        is interpreted as ideal-plan seconds, so a mismatch would make
        every plan's wall-time accounting wrong."""
        if job.kind is not JobKind.TRAIN or not job.gang:
            raise ValueError(
                "ElasticSpec applies to gang-scheduled training jobs only")
        ideal = self.ideal()
        if (job.n_pods, job.gpus_per_pod) != ideal.shape:
            raise ValueError(
                f"job shape {job.n_pods}x{job.gpus_per_pod} must equal the "
                f"ideal plan {ideal.n_pods}x{ideal.gpus_per_pod}")

    # ------------------------------------------------------------------
    @classmethod
    def from_throughputs(cls, entries: Sequence[Tuple[int, float]], *,
                         gpus_per_node: int = 8) -> "ElasticSpec":
        """Build a spec from ``(n_gpus, throughput)`` pairs, packing
        pods at node granularity (``gpus_per_node`` per pod, like the
        workload generators' ``_pods_for``)."""
        plans = []
        for n_gpus, thr in entries:
            if n_gpus <= gpus_per_node:
                n_pods, per_pod = 1, int(n_gpus)
            else:
                if n_gpus % gpus_per_node:
                    raise ValueError(
                        f"multi-node plan size {n_gpus} must be a multiple "
                        f"of gpus_per_node={gpus_per_node}")
                n_pods, per_pod = n_gpus // gpus_per_node, gpus_per_node
            plans.append(ParallelismPlan(n_pods=n_pods, gpus_per_pod=per_pod,
                                         throughput=float(thr)))
        return cls(plans=tuple(plans))
