"""ElasticManager: executes ElasticPolicy decisions through QSCH.

The manager is the subsystem's only actor — the policy plugin advises,
the manager drives the standard scheduler paths so quota charges,
snapshot deltas, stale-END guards and metrics accounting stay where
they already live:

* **shrink / plan selection** — ``QSCH.try_place`` calls
  :meth:`ElasticManager.select_shape` before admission: the policy
  picks a plan against the working snapshot, the job's shape is
  rewritten to it, and the attempt's wall ``duration`` is recomputed
  from the checkpoint state at the plan's relative throughput.  Quota
  is then charged for the shape that actually binds.
* **grow** — once per cycle (after the queue policy and preempt chain)
  :meth:`grow_pass` scans running shrunk jobs.  At a checkpoint
  boundary, if the policy names a better-fitting plan, the job is
  **voluntarily checkpoint-interrupted**: the PR-3 recovery model
  (:class:`~repro.core.dynamics.recovery.CheckpointModel`) charges the
  reshape as restart overhead + (boundary-slack-bounded) lost work,
  ``QSCH.on_interrupted`` requeues it, and the next placement attempt
  re-selects — now with the freed devices visible in the snapshot.

With no elastic jobs in the trace (or no manager attached) every hook
is a no-op and the scheduler is byte-identical to the rigid path
(gated by ``benchmarks/elastic_bench.py``).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from ..dynamics.recovery import CheckpointModel
from ..framework.api import CycleContext, ElasticPolicyPlugin
from ..job import Job, JobKind, JobState
from .policy import GreedyElastic

__all__ = ["ElasticConfig", "ElasticManager"]


@dataclasses.dataclass
class ElasticConfig:
    """Knobs of the shrink/grow machinery.

    ``recovery`` is the checkpoint model reshapes are costed with; when
    left ``None`` the manager adopts the dynamics engine's model at
    attach time (one source of truth for interval/overhead), falling
    back to the default :class:`CheckpointModel` on static runs.
    """

    policy: ElasticPolicyPlugin = dataclasses.field(
        default_factory=GreedyElastic)
    recovery: Optional[CheckpointModel] = None
    # A grow may fire within this many wall seconds after a checkpoint
    # boundary — the lost-work bound of a voluntary reshape.
    grow_boundary_slack_s: float = 90.0
    # Reshape budget per cycle: growing is never urgent, and unbounded
    # simultaneous reshapes would stampede the freed capacity.
    max_grows_per_cycle: int = 4


class ElasticManager:
    def __init__(self, config: Optional[ElasticConfig] = None) -> None:
        self.config = config or ElasticConfig()
        self.metrics = None          # bound by the Simulator
        self.reshapes = 0            # grow reshapes executed

    # ------------------------------------------------------------------
    # Wiring (Simulator)
    # ------------------------------------------------------------------
    def bind_metrics(self, metrics) -> None:
        self.metrics = metrics

    def stats(self) -> Dict[str, int]:
        """Counters for the telemetry registry's pull collector."""
        return {"reshapes": self.reshapes}

    def adopt_recovery(self, model: CheckpointModel) -> None:
        """Share the dynamics engine's checkpoint model unless the
        config pinned its own."""
        if self.config.recovery is None:
            self.config.recovery = model

    @property
    def recovery(self) -> CheckpointModel:
        if self.config.recovery is None:
            self.config.recovery = CheckpointModel()
        return self.config.recovery

    # ------------------------------------------------------------------
    # Placement-time plan selection (QSCH.try_place)
    # ------------------------------------------------------------------
    def select_shape(self, job: Job, ctx: CycleContext) -> None:
        """Adopt the policy's plan for this placement attempt and
        recompute the attempt's wall duration from the job's checkpoint
        state at the plan's relative throughput."""
        if job.elastic is None or job.state is JobState.RUNNING:
            return
        plan = self.config.policy.select_plan(job, ctx.snap, ctx)
        if plan is None:
            plan = job.elastic.ideal()
        if (job.n_pods, job.gpus_per_pod) != plan.shape \
                or job.active_plan is not plan:
            job.apply_plan(plan)
        rate = job.work_rate
        remaining_work = max(
            0.0, job.original_duration - job.checkpointed_progress)
        wall = remaining_work / rate if rate > 0 else remaining_work
        job.duration = self.recovery.attempt_overhead(job) + wall

    # ------------------------------------------------------------------
    # Grow pass (end of QSCH.cycle)
    # ------------------------------------------------------------------
    def at_checkpoint_boundary(self, job: Job, now: float) -> bool:
        """Within ``grow_boundary_slack_s`` wall seconds past a
        checkpoint boundary of the current attempt (attempt start
        counts: nothing to lose yet)."""
        model = self.recovery
        if job.run_time is None or now < job.run_time:
            return True                       # still binding: no progress
        progress = max(0.0, (now - job.run_time)
                       - model.attempt_overhead(job))
        return (progress % model.interval_s) \
            <= self.config.grow_boundary_slack_s

    def grow_pass(self, ctx: CycleContext) -> int:
        """Reshape up to ``max_grows_per_cycle`` running shrunk jobs
        whose policy names a better plan.  Returns the reshape count."""
        if self.recovery.mode != "checkpoint":
            # Scratch recovery would redo the whole job on a voluntary
            # reshape — never worth it.
            return 0
        sched = ctx.sched
        candidates: List[Job] = [
            j for j in sched.running.values()
            if j.elastic is not None and j.active_plan is not None
            and j.kind is JobKind.TRAIN
            and j.active_plan.throughput < j.elastic.ideal().throughput]
        candidates.sort(key=lambda j: j.uid)   # determinism
        grown = 0
        for job in candidates:
            if grown >= self.config.max_grows_per_cycle:
                break
            if not self.at_checkpoint_boundary(job, ctx.now):
                continue
            target = self.config.policy.want_grow(
                job, ctx.snap, ctx, self.recovery.restart_overhead_s)
            if target is None \
                    or target.throughput <= job.active_plan.throughput:
                continue
            self.reshape(job, ctx, target)
            grown += 1
        return grown

    def reshape(self, job: Job, ctx: CycleContext, target) -> None:
        """Voluntary checkpoint-interrupt so the next placement attempt
        can run ``job`` at ``target``.  Cost accounting is exactly the
        failure path's — restart overhead plus work since the last
        checkpoint — but flagged as a reshape in metrics (no MTTR
        sample, tracked against the reshape-overhead budget)."""
        remaining, lost, overhead = self.recovery.on_interrupt(
            job, ctx.now)
        if self.metrics is not None:
            self.metrics.on_job_interrupted(job, ctx.now, lost, overhead,
                                            reshape=True)
        placement = job.placement
        ctx.sched.on_interrupted(job, ctx.state, ctx.now, remaining)
        if placement is not None:
            # Mirror the release onto the working snapshot, like
            # preempt_job: later decisions this cycle see the freed
            # devices.
            ctx.snap.apply_release(placement)
        job.reshape_count += 1
        self.reshapes += 1
        # Adopt the target shape now so quota admission sees it; the
        # next placement attempt's select_shape may still re-pick if
        # the capacity moved underneath us.
        job.apply_plan(target)
