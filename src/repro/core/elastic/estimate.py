"""Plan throughput estimation from dry-run HLO analysis artifacts.

:mod:`repro.launch.dryrun` writes one JSON per (arch × shape × mesh)
combo with roofline terms over the *partitioned per-device* module:

* ``compute_term_s``    — flops_per_device / peak_flops
* ``memory_term_s``     — bytes_per_device / HBM bandwidth
* ``collective_term_s`` — collective bytes_per_device / ICI bandwidth

This module turns those artifacts into :class:`ParallelismPlan`s: the
roofline step-time estimate overlaps compute with memory traffic
(``max``) and adds the exposed collective time, and a plan's throughput
is ``1 / step_time`` — the same global batch is processed every step,
so relative throughput across chip counts is exactly the inverse
step-time ratio.

Plan derivation is memoized through the same
:class:`~repro.launch.combo_cache.ComboCache` machinery the dry-run
lowering uses, keyed by (arch, shape, chip-count tuple): enumerating
the candidate plans of every elastic job in a trace hits the cache
after the first job of each model family
(``benchmarks/elastic_bench.py`` reports the counters).

No jax anywhere on this path — artifacts are plain dicts, either read
from ``experiments/dryrun/*.json`` or synthesized via
:func:`scaling_artifacts` when no dry-run sweep is available.
"""

from __future__ import annotations

from typing import Dict, List, Mapping, Sequence, Tuple

from ...launch.combo_cache import ComboCache
from .spec import ElasticSpec, ParallelismPlan

__all__ = ["step_time_from_terms", "plan_from_artifact",
           "spec_from_artifacts", "scaling_artifacts", "plan_cache",
           "plan_cache_stats"]

#: Shared memo for derived plan tuples (see module docstring).
_PLAN_CACHE = ComboCache("elastic-plans")


def plan_cache() -> ComboCache:
    return _PLAN_CACHE


def plan_cache_stats() -> Dict[str, int]:
    """Hit/miss counters of the plan-derivation memo (reported by the
    elastic benchmark)."""
    return _PLAN_CACHE.stats()


# ----------------------------------------------------------------------
def step_time_from_terms(artifact: Mapping[str, float]) -> float:
    """Roofline step-time estimate from one dry-run artifact: compute
    overlapped with HBM traffic, plus exposed collective time."""
    compute = float(artifact.get("compute_term_s", 0.0))
    memory = float(artifact.get("memory_term_s", 0.0))
    collective = float(artifact.get("collective_term_s", 0.0))
    step = max(compute, memory) + collective
    if step <= 0:
        raise ValueError("artifact has no positive roofline term")
    return step


def plan_from_artifact(artifact: Mapping[str, object], *,
                       gpus_per_node: int = 8) -> ParallelismPlan:
    """One artifact (``chips`` + roofline terms) -> one plan, packed at
    node granularity like the workload generators."""
    chips = int(artifact["chips"])
    step = step_time_from_terms(artifact)
    if chips <= gpus_per_node:
        n_pods, per_pod = 1, chips
    else:
        if chips % gpus_per_node:
            raise ValueError(f"chip count {chips} not a multiple of "
                             f"gpus_per_node={gpus_per_node}")
        n_pods, per_pod = chips // gpus_per_node, gpus_per_node
    return ParallelismPlan(
        n_pods=n_pods, gpus_per_pod=per_pod, throughput=1.0 / step,
        name=f"{artifact.get('arch', '?')}@{chips}")


def spec_from_artifacts(artifacts: Sequence[Mapping[str, object]], *,
                        gpus_per_node: int = 8) -> ElasticSpec:
    """Artifacts for the SAME (arch, shape) at different chip counts ->
    an :class:`ElasticSpec`, memoized on (arch, shape, chip counts)."""
    if not artifacts:
        raise ValueError("need at least one dry-run artifact")
    archs = {str(a.get("arch")) for a in artifacts}
    shapes = {str(a.get("shape")) for a in artifacts}
    if len(archs) > 1 or len(shapes) > 1:
        raise ValueError(f"artifacts span multiple combos: "
                         f"{sorted(archs)} x {sorted(shapes)}")
    key = (archs.pop(), shapes.pop(),
           tuple(sorted(int(a["chips"]) for a in artifacts)),
           int(gpus_per_node))
    return _PLAN_CACHE.get_or(key, lambda: ElasticSpec(plans=tuple(
        plan_from_artifact(a, gpus_per_node=gpus_per_node)
        for a in artifacts)))


# ----------------------------------------------------------------------
def scaling_artifacts(arch: str, shape: str, chip_counts: Sequence[int], *,
                      base_step_s: float = 1.0, alpha: float = 0.85,
                      collective_frac: float = 0.15
                      ) -> List[Dict[str, object]]:
    """Synthetic artifacts following a power-law scaling model — the
    stand-in when no dry-run sweep exists (benchmarks, tests).

    Aggregate throughput scales as ``n^alpha`` (``alpha < 1``: growing
    the gang pays increasing collective overhead), so the per-combo
    step time is ``base_step_s / (n / n_max)^alpha`` relative to the
    largest count.  ``collective_frac`` of each step is attributed to
    the collective term so ``dominant_term``-style consumers see a
    plausible split.
    """
    if not chip_counts:
        raise ValueError("need at least one chip count")
    n_max = max(int(n) for n in chip_counts)
    out: List[Dict[str, object]] = []
    for n in chip_counts:
        step = float(base_step_s) / (int(n) / n_max) ** float(alpha)
        coll = step * float(collective_frac)
        out.append({
            "arch": arch, "shape": shape, "chips": int(n),
            "compute_term_s": step - coll, "memory_term_s": 0.0,
            "collective_term_s": coll,
        })
    return out
