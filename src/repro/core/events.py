"""Cluster event bus: the simulator's loop, generalized.

The original simulator hardcoded three event kinds (SUBMIT/TICK/END)
inside one ``while heap`` loop.  The dynamics subsystem
(:mod:`repro.core.dynamics`) needs more — node/GPU failures, recoveries,
planned drain windows, autoscaling decisions — so the loop is now an
:class:`EventBus`: a time-ordered heap of :class:`Event` records plus a
kind -> handler dispatch table.  The simulator registers its built-in
handlers; dynamics components subscribe theirs.

Determinism contract: events are dispatched in ``(t, kind, seq)`` order.
``EventKind`` values are chosen so that, at equal timestamps, job
lifecycle events (SUBMIT, END) land first, then cluster mutations
(failures, drains, scale decisions), then the scheduling TICK — a
failure stamped at cycle time is visible to that cycle — and metric
SAMPLEs observe the post-tick state.  The relative order of the four
original kinds is unchanged, so runs without dynamics events are
byte-identical to the pre-bus simulator.
"""

from __future__ import annotations

import dataclasses
import enum
import heapq
import itertools
from typing import Any, Callable, Dict, List, Optional


class EventKind(enum.IntEnum):
    """Every kind the simulator/dynamics pipeline understands.

    The integer values ARE the same-timestamp dispatch order — see the
    module docstring before renumbering anything.
    """

    SUBMIT = 0          # a job arrives and enters its tenant queue
    END = 1             # a running job completes
    NODE_FAIL = 2       # unplanned node failure (kills resident gangs)
    NODE_RECOVER = 3    # failed node returns to service
    GPU_FAIL = 4        # single-device failure (kills the resident job)
    GPU_RECOVER = 5     # failed device returns to service
    DRAIN_START = 6     # planned maintenance: stop scheduling onto nodes
    DRAIN_END = 7       # drain window closes
    SCALE_DECISION = 8  # autoscaler evaluates its demand curve
    TICK = 9            # a scheduling cycle fires
    SAMPLE = 10         # metrics sampling


@dataclasses.dataclass(frozen=True, order=True)
class Event:
    t: float
    kind: EventKind
    seq: int                       # heap tie-breaker (push order)
    payload: Any = dataclasses.field(default=None, compare=False)


Handler = Callable[[Event], None]


class EventBus:
    """Time-ordered event heap with per-kind handler dispatch.

    ``push`` enqueues, ``pop`` dequeues in ``(t, kind, seq)`` order, and
    ``dispatch`` runs every subscribed handler in subscription order.
    ``pending(kind)`` is an O(1) per-kind counter so drivers can ask
    "anything left of this kind?" without scanning the heap (the
    simulator's pending-submission check, §3.4-style bookkeeping).
    """

    def __init__(self) -> None:
        self._heap: List[Event] = []
        self._seq = itertools.count()
        self._handlers: Dict[EventKind, List[Handler]] = {}
        self._pending: Dict[EventKind, int] = {}
        # Optional read-only telemetry tap (repro.obs): called with each
        # event BEFORE its handlers, so observers see the pre-handler
        # world.  Must not push events or mutate state.
        self.tap: Optional[Handler] = None

    def __len__(self) -> int:
        return len(self._heap)

    def subscribe(self, kind: EventKind, handler: Handler) -> None:
        self._handlers.setdefault(kind, []).append(handler)

    def push(self, t: float, kind: EventKind, payload: Any = None) -> Event:
        ev = Event(t=float(t), kind=kind, seq=next(self._seq),
                   payload=payload)
        heapq.heappush(self._heap, ev)
        self._pending[kind] = self._pending.get(kind, 0) + 1
        return ev

    def pop(self) -> Optional[Event]:
        if not self._heap:
            return None
        ev = heapq.heappop(self._heap)
        self._pending[ev.kind] -= 1
        return ev

    def peek(self) -> Optional[Event]:
        """Next event without removing it (the federated lockstep loop
        merges member buses by peeking every head)."""
        return self._heap[0] if self._heap else None

    def pending(self, kind: EventKind) -> int:
        return self._pending.get(kind, 0)

    def dispatch(self, event: Event) -> None:
        if self.tap is not None:
            self.tap(event)
        for handler in self._handlers.get(event.kind, ()):
            handler(event)
