"""Structure-of-arrays scheduler state block (million-node core).

:class:`StateColumns` is the contiguous column bundle shared by
:class:`~repro.core.cluster.ClusterState` and
:class:`~repro.core.snapshot.Snapshot`: node health, drain, pool type,
zone membership and the per-device busy/health bitmaps, plus the
*maintained derived* columns (free/used/busy/healthy counts and the §4.3
fragmentation mask) that every hot read used to recompute as a full
``(n_nodes × gpus_per_node)`` reduction.

Layout contract:

* every integer column is pinned to **int32** (half the copy bytes of
  the former ``np.sum`` int64 defaults at 100k+ nodes), every flag
  column to ``bool``;
* derived columns are a pure function of the bitmap columns —
  :meth:`refresh_derived` recomputes them for all rows or a dirty-row
  subset, and the sanctioned mutators of ``ClusterState`` /
  ``Snapshot._refresh_rows`` are the only writers, so dirty-row
  tracking stays sound (property-tested against a naive per-field
  reference model in ``tests/test_properties.py``);
* snapshots are column copies + dirty-row copies of this block, never
  per-field rebuilds (see :mod:`repro.core.snapshot`).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass
class StateColumns:
    """One block of contiguous numpy columns over the node axis."""

    # -- ground-truth columns (written by the sanctioned mutators) -----
    gpu_type: np.ndarray        # (n,) int32 — §3.4.1 node pools
    gpu_busy: np.ndarray        # (n, G) bool — device allocated
    gpu_healthy: np.ndarray     # (n, G) bool — device healthy (§3.3.1)
    node_healthy: np.ndarray    # (n,) bool — node schedulable at all
    inference_zone: np.ndarray  # (n,) bool — E-Spread zone (§3.3.4)
    node_draining: np.ndarray   # (n,) bool — maintenance drain window
    # -- maintained derived columns (refresh_derived is the only writer)
    free_gpus: np.ndarray       # (n,) int32: healthy & ~busy, 0 if node down
    used_gpus: np.ndarray       # (n,) int32: busy & healthy
    busy_count: np.ndarray      # (n,) int32: busy (regardless of health)
    healthy_count: np.ndarray   # (n,) int32: healthy devices per node
    fragmented: np.ndarray      # (n,) bool: §4.3 neither idle nor full

    @classmethod
    def create(cls, n_nodes: int, gpus_per_node: int,
               gpu_type: Optional[np.ndarray] = None,
               inference_zone_nodes: int = 0) -> "StateColumns":
        n, g = n_nodes, gpus_per_node
        if gpu_type is None:
            gpu_type = np.zeros(n, dtype=np.int32)
        gpu_type = np.asarray(gpu_type, dtype=np.int32)
        if gpu_type.shape != (n,):
            raise ValueError("gpu_type must have shape (n_nodes,)")
        zone = np.zeros(n, dtype=bool)
        if inference_zone_nodes:
            zone[:inference_zone_nodes] = True
        cols = cls(
            gpu_type=gpu_type,
            gpu_busy=np.zeros((n, g), dtype=bool),
            gpu_healthy=np.ones((n, g), dtype=bool),
            node_healthy=np.ones(n, dtype=bool),
            inference_zone=zone,
            node_draining=np.zeros(n, dtype=bool),
            free_gpus=np.zeros(n, dtype=np.int32),
            used_gpus=np.zeros(n, dtype=np.int32),
            busy_count=np.zeros(n, dtype=np.int32),
            healthy_count=np.zeros(n, dtype=np.int32),
            fragmented=np.zeros(n, dtype=bool),
        )
        cols.refresh_derived()
        return cols

    @property
    def n_nodes(self) -> int:
        return int(self.node_healthy.shape[0])

    # ------------------------------------------------------------------
    # Derived-column maintenance
    # ------------------------------------------------------------------
    def refresh_derived(self, idx: Optional[np.ndarray] = None) -> None:
        """Recompute the derived columns from the bitmap columns, for
        all rows (``idx=None``) or the given row subset.  The formulas
        are the single source of truth every consumer used to inline."""
        if idx is None:
            busy, healthy = self.gpu_busy, self.gpu_healthy
            nh = self.node_healthy
            view = slice(None)
        else:
            busy, healthy = self.gpu_busy[idx], self.gpu_healthy[idx]
            nh = self.node_healthy[idx]
            view = idx
        healthy_count = healthy.sum(axis=1, dtype=np.int32)
        used = (busy & healthy).sum(axis=1, dtype=np.int32)
        free = healthy_count - used
        self.healthy_count[view] = healthy_count
        self.used_gpus[view] = used
        self.busy_count[view] = busy.sum(axis=1, dtype=np.int32)
        self.free_gpus[view] = np.where(nh, free, np.int32(0))
        self.fragmented[view] = ((used > 0) & (used < healthy_count)
                                 & nh & (healthy_count > 0))

    # ------------------------------------------------------------------
    # Snapshot support: column copies + dirty-row copies
    # ------------------------------------------------------------------
    def copy(self) -> "StateColumns":
        return StateColumns(
            **{f.name: getattr(self, f.name).copy()
               for f in dataclasses.fields(StateColumns)})

    def copy_rows_from(self, src: "StateColumns", idx: np.ndarray,
                       invariants: bool) -> None:
        """Dirty-row copy (§3.4.3 incremental snapshot).

        Busy-derived columns always refresh; the *delta-invariant*
        columns (health, drain, type, zone and their derived
        ``healthy_count``) are copied only when ``invariants`` says a
        health/drain/type setter ran — placement churn flips busy bits
        alone.  Derived rows are recomputed from the just-copied bitmap
        rows (not copied), so a snapshot can never inherit drift."""
        self.gpu_busy[idx] = src.gpu_busy[idx]
        if invariants:
            self.gpu_healthy[idx] = src.gpu_healthy[idx]
            self.node_healthy[idx] = src.node_healthy[idx]
            self.gpu_type[idx] = src.gpu_type[idx]
            self.inference_zone[idx] = src.inference_zone[idx]
            self.node_draining[idx] = src.node_draining[idx]
        self.refresh_derived(idx)

    def columns_equal(self, other: "StateColumns") -> bool:
        return all(np.array_equal(getattr(self, f.name),
                                  getattr(other, f.name))
                   for f in dataclasses.fields(StateColumns))
