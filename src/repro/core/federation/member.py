"""Member clusters of a federation.

The paper deploys Kant across *multiple* AI data-center clusters; a
:class:`MemberCluster` is one of them — a full single-cluster scheduling
stack (topology, state, QSCH/RSCH with its own profile set and
:class:`~repro.core.quota.QuotaManager`, optionally its own cluster
dynamics) plus the federation-facing attributes the global scheduler
routes on: region, per-pool cost and capability tables.

Members are deliberately heterogeneous: different node counts,
``gpus_per_node``, GPU-type pools, scheduling profiles and failure
models can coexist in one :class:`FederatedCluster`.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..cluster import ClusterState
from ..qsch import QSCH, QSCHConfig, QueuePolicy
from ..quota import QuotaManager, QuotaMode
from ..rsch import RSCH, RSCHConfig, Strategy
from ..simulator import SimConfig
from ..topology import ClusterTopology


@dataclasses.dataclass
class MemberCluster:
    """One member: a self-contained scheduling stack + routing traits."""

    name: str
    topology: ClusterTopology
    state: ClusterState
    qsch: QSCH
    sim_config: SimConfig = dataclasses.field(default_factory=SimConfig)
    region: str = "default"
    # Routing traits (ECCOS-style capability/cost coordination): relative
    # $-cost and capability score per GPU type hosted by this member.
    cost_per_gpu_hour: Dict[int, float] = dataclasses.field(
        default_factory=dict)
    capability: Dict[int, float] = dataclasses.field(default_factory=dict)

    @property
    def quota(self) -> QuotaManager:
        return self.qsch.quota

    def gpu_types(self) -> List[int]:
        """GPU-type pools hosted by this member."""
        return [int(t) for t in np.unique(self.state.gpu_type)]


@dataclasses.dataclass
class FederatedCluster:
    """N heterogeneous members fronted by the GSCH (see ``gsch.py``)."""

    members: List[MemberCluster]

    def __post_init__(self) -> None:
        if not self.members:
            raise ValueError("a federation needs at least one member")
        names = [m.name for m in self.members]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate member names: {names}")

    def __len__(self) -> int:
        return len(self.members)

    def __getitem__(self, i: int) -> MemberCluster:
        return self.members[i]

    def gpu_types(self) -> List[int]:
        """Sorted union of GPU types across members (summary columns)."""
        out = set()
        for m in self.members:
            out.update(m.gpu_types())
        return sorted(out)

    def index_of(self, name: str) -> int:
        for i, m in enumerate(self.members):
            if m.name == name:
                return i
        raise KeyError(name)


def make_member(name: str, *,
                gpu_pools: Sequence[Tuple[int, int]] = ((0, 128),),
                gpus_per_node: int = 8,
                nodes_per_leaf: int = 8,
                region: str = "default",
                policy: QueuePolicy = QueuePolicy.BACKFILL,
                strategy: Strategy = Strategy.E_BINPACK,
                quota: Optional[Dict[str, Dict[int, int]]] = None,
                tenants: Sequence[str] = ("t0",),
                quota_mode: QuotaMode = QuotaMode.ISOLATED,
                inference_zone_nodes: int = 0,
                sim_config: Optional[SimConfig] = None,
                cost_per_gpu_hour: Optional[Dict[int, float]] = None,
                capability: Optional[Dict[int, float]] = None) -> \
        MemberCluster:
    """Assemble one member from scenario-level knobs.

    ``gpu_pools`` is an ordered ``(gpu_type, n_nodes)`` list: the member
    hosts contiguous node blocks per GPU-type pool (§3.4.1), so two
    members can expose entirely different pool mixes to the federation.
    ``quota`` defaults to an effectively unlimited grant for every
    hosted type × every name in ``tenants`` (the federation layer is
    then the only admission gate); pass an explicit ``quota`` for
    member-level isolation experiments.
    """
    n_nodes = sum(n for _, n in gpu_pools)
    topo = ClusterTopology(
        n_nodes=n_nodes, gpus_per_node=gpus_per_node,
        nodes_per_leaf=nodes_per_leaf, leaves_per_spine=4,
        spines_per_superspine=4, nodes_per_hbd=nodes_per_leaf,
        nvlink_island=gpus_per_node, numa_split=max(1, gpus_per_node // 2))
    gpu_type = np.concatenate([
        np.full(n, t, dtype=np.int32) for t, n in gpu_pools])
    state = ClusterState.create(topo, gpu_type=gpu_type,
                                inference_zone_nodes=inference_zone_nodes)
    if quota is None:
        quota = {str(tn): {int(t): 10 ** 6 for t, _ in gpu_pools}
                 for tn in tenants}
    qm = QuotaManager(quota, mode=quota_mode)
    rsch = RSCH(topo, RSCHConfig(train_strategy=strategy))
    qsch = QSCH(qm, rsch, QSCHConfig(policy=policy))
    return MemberCluster(
        name=name, topology=topo, state=state, qsch=qsch,
        sim_config=sim_config or SimConfig(), region=region,
        cost_per_gpu_hour=dict(cost_per_gpu_hour or {}),
        capability=dict(capability or {}))
