"""FederatedSimulator: N member event buses driven in ONE lockstep loop.

Each member gets its own :class:`~repro.core.simulator.Simulator`
(state, QSCH, metrics, optional dynamics — failures, drains, tidal
autoscaling compose per member on the existing
:mod:`repro.core.events` kinds).  This driver merges the member buses
into a single global ordering:

* the next event is the minimum over member bus heads by
  ``(t, kind, member, seq)`` — within one member that is exactly the
  bus's own ``(t, kind, seq)`` contract, so member-local dispatch order
  is untouched;
* job *arrivals* live outside any bus until the GSCH routes them: an
  arrival at time ``t`` is processed before any member event with
  ``(t', kind') > (t, SUBMIT)``, which reproduces the plain simulator's
  "SUBMITs sort first at equal timestamps" ordering;
* after a member TICK dispatches, the GSCH gets its spillover pass for
  that member, and after an authoritative END the federation quota is
  refunded and the quota backlog retried.

Determinism/parity contract: with ONE member, no federation quota and
the default config, every event dispatches in exactly the order the
plain ``Simulator.run`` would produce — placements and metric samples
are byte-identical (gated by ``benchmarks/federation_bench.py``).  The
member TICK/SAMPLE chains stay alive while federation-level work is
outstanding via the simulator's ``external_work`` hook (mirroring the
pre-pushed-SUBMIT behavior of the standalone loop), and all member
chains are started at the first arrival so samples align across
members while the federation is loaded.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from ..events import EventKind
from ..job import Job, JobState
from ..simulator import SimResult, Simulator
from .gsch import GSCH, GSCHConfig, RoutingStats
from .member import FederatedCluster, MemberCluster
from .metrics import FederatedMetrics


@dataclasses.dataclass
class FederatedResult:
    jobs: List[Job]
    members: List[SimResult]
    metrics: FederatedMetrics
    routing: RoutingStats
    end_time: float
    cycles: int
    preemptions: int
    spills: int
    # Jobs never handed to any member (held in the federation-quota
    # backlog at the horizon, or arriving past it).  Empty on
    # single-member runs, where unrouted jobs stay attributed to the
    # lone member exactly like the plain Simulator attributes them.
    unrouted: List[Job] = dataclasses.field(default_factory=list)

    def report(self) -> Dict[str, object]:
        rep = self.metrics.report(self.jobs)
        rep["routing"] = {
            "routed": list(self.routing.routed),
            "spills": self.routing.spills,
            "cross_region_forwards": self.routing.cross_region_forwards,
            "backlogged": self.routing.backlogged,
            "summary_refreshes": self.routing.summary_refreshes,
        }
        return rep


class FederatedSimulator:
    def __init__(self, fed: FederatedCluster,
                 config: Optional[GSCHConfig] = None,
                 horizon: Optional[float] = None) -> None:
        self.fed = fed
        self.gsch = GSCH(fed, config)
        self.horizon = horizon
        self.sims: List[Simulator] = []
        for m in fed.members:
            if horizon is not None and m.sim_config.horizon is None:
                # One global clock: member dynamics traces and drains
                # sample against the federation horizon.
                m.sim_config = dataclasses.replace(m.sim_config,
                                                   horizon=horizon)
            self.sims.append(Simulator(m.state, m.qsch, m.sim_config))
        self._arrivals_left = 0
        for sim in self.sims:
            sim.external_work = self._federation_work_outstanding

    # ------------------------------------------------------------------
    def attach_telemetry(self, tel) -> None:
        """Attach one :class:`repro.obs.Telemetry` across every member,
        scoped by member name: registry series get ``member=...``
        labels, each member runs its own scheduler trace lane, and
        decisions carry the member they were made on.  The lockstep
        loop dispatches one member event at a time, so the shared
        facade's per-scope cycle accumulators never interleave."""
        for m, sim in zip(self.fed.members, self.sims):
            tel.attach(sim, scope=m.name)
        if tel.registry is not None:
            metrics = FederatedMetrics(
                names=[m.name for m in self.fed.members],
                recorders=[sim.metrics for sim in self.sims])
            tel.registry.add_collector(lambda reg: metrics.publish(reg))

    # ------------------------------------------------------------------
    def _federation_work_outstanding(self) -> bool:
        """Unrouted arrivals or quota-held jobs keep member TICK/SAMPLE
        chains alive, exactly like pre-pushed SUBMITs do standalone."""
        return self._arrivals_left > 0 or bool(self.gsch.backlog)

    def _forward(self, job: Job, member: int, t: float) -> None:
        """Hand a routed job to a member bus and make sure that member
        will actually run cycles to look at it."""
        sim = self.sims[member]
        sim.bus.push(t, EventKind.SUBMIT, job)
        sim.ensure_tick(t)
        sim.ensure_sample(t)

    def _start_chains(self, t: float) -> None:
        """Lockstep start: every member begins ticking/sampling at the
        first arrival so per-member samples align while loaded."""
        for sim in self.sims:
            sim.ensure_tick(t)
            sim.ensure_sample(t)

    # ------------------------------------------------------------------
    def run(self, jobs: Sequence[Job]) -> FederatedResult:
        gsch = self.gsch
        for sim in self.sims:
            sim.attach_dynamics()
        arrivals = sorted(jobs, key=lambda j: j.submit_time)
        self._arrivals_left = len(arrivals)
        if not arrivals:
            # Dynamics-only federation: anchor metrics like the plain
            # simulator's no-jobs branch.
            for sim in self.sims:
                if sim.config.dynamics is not None and len(sim.bus):
                    sim.bus.push(0.0, EventKind.SAMPLE)
        next_arrival = 0
        started = False
        while True:
            # Next member event: min over bus heads by (t, kind, member).
            best = None
            best_key = None
            for i, sim in enumerate(self.sims):
                ev = sim.bus.peek()
                if ev is None:
                    continue
                key = (ev.t, int(ev.kind), i)
                if best_key is None or key < best_key:
                    best, best_key = i, key
            if next_arrival < len(arrivals):
                job = arrivals[next_arrival]
                akey = (job.submit_time, int(EventKind.SUBMIT))
                if best_key is None or akey < best_key[:2]:
                    if (self.horizon is not None
                            and job.submit_time > self.horizon):
                        break
                    next_arrival += 1
                    self._arrivals_left -= 1
                    if not started:
                        self._start_chains(job.submit_time)
                        started = True
                    target = gsch.route(job, job.submit_time)
                    if target is not None:
                        self._forward(job, target, job.submit_time)
                    continue
            if best is None:
                break
            if self.horizon is not None and best_key[0] > self.horizon:
                break
            sim = self.sims[best]
            ev = sim.bus.pop()
            sim.now = ev.t
            sim.bus.dispatch(ev)
            if ev.kind is EventKind.TICK:
                for job, target, arrive in gsch.maybe_spill(best, ev.t):
                    self._forward(job, target, arrive)
                for job, target in gsch.drain_backlog(ev.t):
                    self._forward(job, target, ev.t)
            elif (ev.kind is EventKind.END
                  and isinstance(ev.payload, Job)
                  and ev.payload.state is JobState.COMPLETED):
                gsch.on_job_finished(ev.payload)

        # Finalize members; attribute each job to where it last ran or
        # waited.  Jobs with no route record (quota backlog / past the
        # horizon) belong to no member — except in the single-member
        # degenerate case, where the plain Simulator's SimResult.jobs
        # carries the full trace.
        member_jobs: List[List[Job]] = [[] for _ in self.sims]
        unrouted: List[Job] = []
        for job in arrivals:
            rec = gsch.routes.get(job.uid)
            if rec is not None:
                member_jobs[rec.member].append(job)
            elif len(self.sims) == 1:
                member_jobs[0].append(job)
            else:
                unrouted.append(job)
        results = [sim.finalize(member_jobs[i])
                   for i, sim in enumerate(self.sims)]
        metrics = FederatedMetrics(
            names=[m.name for m in self.fed.members],
            recorders=[sim.metrics for sim in self.sims])
        return FederatedResult(
            jobs=list(arrivals), members=results, metrics=metrics,
            routing=gsch.stats,
            end_time=max((r.end_time for r in results), default=0.0),
            cycles=sum(r.cycles for r in results),
            preemptions=sum(r.preemptions for r in results),
            spills=gsch.stats.spills, unrouted=unrouted)
