"""Federation subsystem: a global scheduler over heterogeneous members.

The paper deploys Kant in *multiple* AI data-center clusters; this
package adds the layer that decides which cluster a job lands in:

* :mod:`member`    — :class:`MemberCluster` (a full per-cluster
  scheduling stack + routing traits) and :class:`FederatedCluster`;
* :mod:`summary`   — the per-cluster summary matrix routing is
  vectorized over (O(members) per decision, never a node-array walk);
* :mod:`plugins`   — built-in **ClusterSelect** routing policies:
  quota-fit, least-loaded, GFR-aware, data-locality, capability/cost;
* :mod:`gsch`      — the GSCH: routing, spillover re-routing with
  forwarding delay + locality penalty, federation-level tenant quotas;
* :mod:`simulator` — :class:`FederatedSimulator`, driving the member
  event buses in one lockstep loop (single-member degenerate case is
  byte-identical to a plain :class:`~repro.core.simulator.Simulator`);
* :mod:`metrics`   — federated GAR/SOR/GFR/JWTD aggregation, P90
  waits, and the cross-cluster balance index.

See ``docs/federation.md`` for the architecture and the ClusterSelect
contract.
"""

from .gsch import GSCH, GSCHConfig, RouteRecord, RoutingStats, \
    default_select
from .member import FederatedCluster, MemberCluster, make_member
from .metrics import (FederatedMetrics, allocated_gar, jain_index,
                      waiting_percentile)
from .plugins import (CapabilityCostSelect, GfrAwareSelect,
                      LeastLoadedSelect, LocalityAffinitySelect,
                      QuotaFitSelect)
from .simulator import FederatedResult, FederatedSimulator
from .summary import FederationSummary, summarize

__all__ = [
    "MemberCluster", "FederatedCluster", "make_member",
    "FederationSummary", "summarize",
    "GSCH", "GSCHConfig", "RouteRecord", "RoutingStats", "default_select",
    "QuotaFitSelect", "LeastLoadedSelect", "GfrAwareSelect",
    "LocalityAffinitySelect", "CapabilityCostSelect",
    "FederatedSimulator", "FederatedResult",
    "FederatedMetrics", "allocated_gar", "jain_index",
    "waiting_percentile",
]
