"""Federated metric aggregation: global GAR/SOR/GFR/JWTD + balance.

Members sample independently (their chains can drain at different
times), so the global GAR series is built on the UNION of sample times
with step-hold semantics per member — each member contributes its last
known (allocated, capacity) pair at every union timestamp.  SOR needs
no alignment at all: it is Σ allocated GPU-seconds / Σ capacity
GPU-seconds over the member recorders' accumulators.

The **cross-cluster balance index** is Jain's fairness index over the
members' time-averaged utilization (their SOR):

    J = (Σ uᵢ)² / (M · Σ uᵢ²)   ∈ (1/M, 1]

1.0 = perfectly even load; 1/M = all load on one member.  Spillover
routing should push J up against static partitioning.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..job import Job, summarize_waits
from ..metrics import MetricsRecorder, waiting_percentile

__all__ = ["jain_index", "waiting_percentile", "allocated_gar",
           "FederatedMetrics"]


def jain_index(values: Sequence[float]) -> float:
    v = np.asarray(list(values), dtype=float)
    if len(v) == 0 or not (v > 0).any():
        return 1.0
    return float(v.sum() ** 2 / (len(v) * (v ** 2).sum()))


def allocated_gar(jobs: Sequence[Job], capacity_gpus: int,
                  t_max: float, default_end: Optional[float] = None
                  ) -> float:
    """EXACT time-averaged global GAR over ``[0, t_max]`` from job
    placement intervals (GPU-seconds allocated / capacity x window).

    The sampled :meth:`FederatedMetrics.mean_gar` estimate step-holds
    between 300 s samples, which biases small-cluster A/Bs by more than
    the effect under test; for a static-capacity federation the
    interval sum is exact.  ``default_end`` stands in for jobs still
    running at the horizon."""
    total = 0.0
    for j in jobs:
        if j.start_time is None:
            continue
        end = j.end_time if j.end_time is not None else default_end
        if end is None:
            end = t_max
        total += j.n_gpus * max(0.0, min(end, t_max) - j.start_time)
    denom = float(capacity_gpus) * t_max
    return total / denom if denom > 0 else 0.0


@dataclasses.dataclass
class FederatedMetrics:
    names: List[str]
    recorders: List[MetricsRecorder]

    # ------------------------------------------------------------------
    def global_gar_series(self) -> Tuple[np.ndarray, np.ndarray]:
        """(t, GAR) on the union of member sample times (step-hold)."""
        times = sorted({s.t for r in self.recorders for s in r.samples})
        if not times:
            return np.asarray([]), np.asarray([])
        union = np.asarray(times)
        alloc = np.zeros_like(union)
        cap = np.zeros_like(union)
        for r in self.recorders:
            if not r.samples:
                continue
            ts = np.asarray([s.t for s in r.samples])
            al = np.asarray([float(s.allocated) for s in r.samples])
            cp = np.asarray([float(s.capacity) for s in r.samples])
            idx = np.searchsorted(ts, union, side="right") - 1
            have = idx >= 0
            alloc[have] += al[np.maximum(idx, 0)][have]
            cap[have] += cp[np.maximum(idx, 0)][have]
        gar = np.where(cap > 0, alloc / np.maximum(cap, 1.0), 0.0)
        return union, gar

    def median_gar(self, t_max: Optional[float] = None) -> float:
        """Median global GAR, optionally restricted to samples at
        ``t <= t_max`` (the loaded window: with a fixed workload, a
        scheduler that finishes earlier shows a low-GAR drain tail that
        says nothing about how well it used the loaded period)."""
        t, gar = self.global_gar_series()
        if t_max is not None and len(t):
            gar = gar[t <= t_max]
        return float(np.median(gar)) if len(gar) else 0.0

    def mean_gar(self, t_max: Optional[float] = None) -> float:
        """Time-weighted mean global GAR (step integral over the union
        series), optionally up to ``t_max`` — the right aggregate for
        A/Bs with fixed work: more GPU-seconds delivered inside the
        window means a higher value, regardless of sample spacing."""
        t, gar = self.global_gar_series()
        if t_max is not None and len(t):
            keep = t <= t_max
            t, gar = t[keep], gar[keep]
        if len(t) < 2:
            return float(gar[0]) if len(gar) else 0.0
        end = t_max if t_max is not None else t[-1]
        dt = np.diff(np.append(t, end))
        span = end - t[0]
        return float((gar * dt).sum() / span) if span > 0 else 0.0

    def member_mean_gar(self, t_max: Optional[float] = None
                        ) -> List[float]:
        """Per-member mean GAR (optionally loaded-window-restricted)."""
        out = []
        for r in self.recorders:
            vals = [s.gar for s in r.samples
                    if t_max is None or s.t <= t_max]
            out.append(float(np.mean(vals)) if vals else 0.0)
        return out

    def sor(self) -> float:
        alloc = cap = 0.0
        for r in self.recorders:
            a, c = r.gpu_seconds()
            alloc += a
            cap += c
        return alloc / cap if cap > 0 else 0.0

    def mean_gfr(self) -> float:
        """Capacity-weighted mean of the members' mean GFR."""
        num = den = 0.0
        for r in self.recorders:
            caps = [s.capacity for s in r.samples]
            if not caps:
                continue
            w = float(np.mean(caps))
            num += w * r.mean_gfr()
            den += w
        return num / den if den else 0.0

    def balance_index(self, t_max: Optional[float] = None) -> float:
        """Jain's fairness index (see module doc) over member SOR — or,
        with ``t_max``, over loaded-window per-member mean GAR."""
        if t_max is not None:
            return jain_index(self.member_mean_gar(t_max))
        return jain_index([r.sor() for r in self.recorders])

    # ------------------------------------------------------------------
    def report(self, jobs: Optional[Sequence[Job]] = None
               ) -> Dict[str, object]:
        """Global aggregate + per-member breakdown.  ``jobs`` (the
        federation-wide trace) feeds the global JWTD family; member
        recorders only ever saw the jobs that finished there."""
        per_member = {name: r.report()
                      for name, r in zip(self.names, self.recorders)}
        out: Dict[str, object] = {
            "median_gar": self.median_gar(),
            "sor": self.sor(),
            "mean_gfr": self.mean_gfr(),
            "balance_index": self.balance_index(),
            "members": per_member,
        }
        if jobs is not None:
            out["jwtd_mean"] = summarize_waits(jobs)
            out["jwtd_p90_s"] = waiting_percentile(jobs, 90.0)
        return out

    def publish(self, registry) -> None:
        """Push the federation aggregates into a telemetry registry
        (duck-typed — this module never imports :mod:`repro.obs`):
        global gauges plus per-member SOR labeled ``member=...``."""
        registry.gauge("federation_median_gar",
                       "global median GAR").set(self.median_gar())
        registry.gauge("federation_sor",
                       "global SOR").set(self.sor())
        registry.gauge("federation_mean_gfr",
                       "capacity-weighted mean GFR").set(self.mean_gfr())
        registry.gauge("federation_balance_index",
                       "Jain fairness over member SOR").set(
            self.balance_index())
        sor_gauge = registry.gauge("federation_member_sor",
                                   "per-member SOR")
        for name, r in zip(self.names, self.recorders):
            sor_gauge.set(r.sor(), member=name)
