"""Built-in ClusterSelect plugins (federation routing policies).

Each plugin contributes a feasibility mask and/or an additive score over
the member axis of the :class:`~repro.core.federation.summary.
FederationSummary` — never a walk of member node arrays (the O(members)
routing contract).  They register in the shared framework registry, so
config-driven assemblies can mix them with out-of-tree policies.
"""

from __future__ import annotations

from typing import Optional

import numpy as np

from ..framework.api import ClusterSelectPlugin
from ..framework.registry import register
from ..job import Job
from .summary import FederationSummary


@register
class QuotaFitSelect(ClusterSelectPlugin):
    """Member-quota-aware routing: a member whose own
    :class:`~repro.core.quota.QuotaManager` would reject the tenant
    statically is infeasible (the job would sit in that member's queue
    forever, §3.2.1); among admitting members, prefer the one with the
    most remaining tenant headroom."""

    name = "QuotaFitSelect"

    def __init__(self, weight: float = 1.0) -> None:
        self.weight = weight

    def feasible(self, job: Job, summary: FederationSummary
                 ) -> Optional[np.ndarray]:
        return np.asarray([m.quota.can_admit(job)
                           for m in summary.members], dtype=bool)

    def score(self, job: Job, summary: FederationSummary
              ) -> Optional[np.ndarray]:
        head = np.asarray([
            m.quota.tenant_quota(job.tenant, job.gpu_type)
            - m.quota.tenant_used(job.tenant, job.gpu_type)
            for m in summary.members], dtype=float)
        denom = max(1.0, float(job.n_gpus))
        return self.weight * np.clip(head / denom, 0.0, 4.0)


@register
class LeastLoadedSelect(ClusterSelectPlugin):
    """Utilization balancing: prefer the member with the highest free
    fraction in the job's GPU-type pool."""

    name = "LeastLoadedSelect"

    def __init__(self, weight: float = 1.0) -> None:
        self.weight = weight

    def score(self, job: Job, summary: FederationSummary
              ) -> Optional[np.ndarray]:
        return self.weight * summary.free_fraction(job.gpu_type)


@register
class GfrAwareSelect(ClusterSelectPlugin):
    """Fragmentation-aware routing (global GFR/starvation trade-off):
    sub-node jobs are steered TOWARD fragmented members — they fill the
    partial nodes — while multi-node gangs are steered AWAY, keeping
    defragmented members available for large-gang placements."""

    name = "GfrAwareSelect"

    def __init__(self, weight: float = 1.0) -> None:
        self.weight = weight

    def score(self, job: Job, summary: FederationSummary
              ) -> Optional[np.ndarray]:
        c = summary.col(job.gpu_type)
        small = (job.n_pods == 1 and c is not None
                 and bool((job.gpus_per_pod
                           < summary.max_node_cap[:, c]).any()))
        sign = 1.0 if small else -1.0
        return self.weight * sign * summary.frag


@register
class LocalityAffinitySelect(ClusterSelectPlugin):
    """Data-locality / region affinity: members in the job's home region
    earn a bonus; jobs without a region are indifferent.  Soft by design
    — spillover can still move a job cross-region, paying the GSCH's
    locality penalty on the forward."""

    name = "LocalityAffinitySelect"

    def __init__(self, weight: float = 1.0) -> None:
        self.weight = weight

    def score(self, job: Job, summary: FederationSummary
              ) -> Optional[np.ndarray]:
        if job.region is None:
            return None
        local = np.asarray([r == job.region for r in summary.regions],
                           dtype=float)
        return self.weight * local


@register
class CapabilityCostSelect(ClusterSelectPlugin):
    """ECCOS-style capability/cost coordination: route to the cheapest
    member whose pool meets the job's capability floor.  ``capability``
    defaults to 1.0 for pools without a declared score, so untagged
    members stay routable."""

    name = "CapabilityCostSelect"

    def __init__(self, cost_weight: float = 1.0,
                 capability_weight: float = 0.5,
                 min_capability: float = 0.0) -> None:
        self.cost_weight = cost_weight
        self.capability_weight = capability_weight
        self.min_capability = min_capability

    def feasible(self, job: Job, summary: FederationSummary
                 ) -> Optional[np.ndarray]:
        if self.min_capability <= 0.0:
            return None
        c = summary.col(job.gpu_type)
        if c is None:
            return None
        return summary.capability[:, c] >= self.min_capability

    def score(self, job: Job, summary: FederationSummary
              ) -> Optional[np.ndarray]:
        c = summary.col(job.gpu_type)
        if c is None:
            return None
        return (self.capability_weight * summary.capability[:, c]
                - self.cost_weight * summary.cost[:, c])
