"""GSCH — the federation's global scheduler.

Sits above the per-member QSCH/RSCH stacks and makes exactly two kinds
of decisions, both through the **ClusterSelect** extension point
(:class:`~repro.core.framework.api.ClusterSelectPlugin`):

* **routing** — on arrival, pick the member a job is forwarded to:
  structural-fit mask ∧ plugin feasibility masks, then argmax of the
  summed plugin scores (+ a configurable bonus for members that can
  place the job *immediately*).  Ties break toward the lower member
  index.  O(members) per job: everything reads the cached
  :class:`~repro.core.federation.summary.FederationSummary`.
* **spillover** — a job pending at a member past ``spill_deadline_s``
  is pulled back and re-routed to a member that can place it now,
  paying ``forward_delay_s`` (plus ``locality_penalty_s`` when leaving
  the job's home region) before it re-enters a tenant queue.  Instead
  of starving behind one member's backlog, capacity anywhere in the
  federation absorbs it.  With one member — or ``spillover=False`` —
  this is structurally a no-op, which is what keeps the degenerate
  single-member federation byte-identical to a plain Simulator run.

Federation-level tenant quotas (``federation_quota``) layer over the
members' own managers: a job that fails the global grant is held in the
GSCH backlog (never forwarded) until a completion frees quota; member
quotas still apply unchanged at admission inside each member.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..framework.api import ClusterSelectPlugin
from ..job import Job, JobState
from ..quota import QuotaManager
from .member import FederatedCluster
from .plugins import (LeastLoadedSelect, LocalityAffinitySelect,
                      QuotaFitSelect)
from .summary import FederationSummary, summarize


def default_select() -> Tuple[ClusterSelectPlugin, ...]:
    """The default routing chain: member-quota fit, load balance,
    region affinity."""
    return (QuotaFitSelect(), LeastLoadedSelect(),
            LocalityAffinitySelect())


@dataclasses.dataclass
class GSCHConfig:
    select: Sequence[ClusterSelectPlugin] = dataclasses.field(
        default_factory=default_select)
    # Spillover (anti-starvation re-routing).
    spillover: bool = True
    spill_deadline_s: float = 1800.0
    forward_delay_s: float = 60.0
    locality_penalty_s: float = 240.0
    max_spills_per_job: int = 4
    # Prefer members able to place the job this instant: added on top of
    # the plugin scores for immediate-fit members (0 disables).
    immediate_fit_bonus: float = 1000.0
    # Federation-level tenant quotas layered over member quotas.
    federation_quota: Optional[QuotaManager] = None
    # Summary staleness tolerance: the matrix is rebuilt (one O(nodes)
    # walk) at most once per window; decisions in between run on the
    # cached matrix plus the `committed` routing charges.  Keeps GSCH
    # cost per cycle O(members) even under dense arrival bursts.
    summary_max_age_s: float = 15.0


@dataclasses.dataclass
class RouteRecord:
    member: int
    since: float          # waiting at `member` since (arrival there)
    spills: int = 0


@dataclasses.dataclass
class RoutingStats:
    # Per-member count of jobs CURRENTLY routed there (a spill moves
    # the count with the job; `spills` keeps the forward history).
    routed: List[int]
    spills: int = 0
    cross_region_forwards: int = 0
    backlogged: int = 0               # federation-quota holds (events)
    summary_refreshes: int = 0


class GSCH:
    def __init__(self, fed: FederatedCluster,
                 config: Optional[GSCHConfig] = None) -> None:
        self.fed = fed
        self.config = config or GSCHConfig()
        self.stats = RoutingStats(routed=[0] * len(fed))
        self.routes: Dict[int, RouteRecord] = {}
        # Jobs held by the federation quota, FIFO.
        self.backlog: List[Job] = []
        self._charged: Dict[int, Job] = {}
        self._gpu_types = fed.gpu_types()
        self._summary: Optional[FederationSummary] = None
        # Per-member lower bound on the earliest `since` of a routed,
        # possibly-still-pending job: lets the per-TICK spill check
        # return in O(1) until a deadline can actually have expired.
        self._earliest_since: Dict[int, float] = {}

    # ------------------------------------------------------------------
    # Summary cache: at most one node-array walk per staleness window
    # ------------------------------------------------------------------
    def summary(self, t: float) -> FederationSummary:
        s = self._summary
        if (s is None or t < s.t
                or t - s.t > self.config.summary_max_age_s):
            self._summary = summarize(self.fed.members, t,
                                      gpu_types=self._gpu_types)
            self.stats.summary_refreshes += 1
        return self._summary

    def invalidate(self) -> None:
        """Drop the cached summary (tests / external state surgery)."""
        self._summary = None

    # ------------------------------------------------------------------
    # Member selection (the ClusterSelect chain)
    # ------------------------------------------------------------------
    def select_member(self, job: Job, summary: FederationSummary,
                      exclude: Optional[int] = None,
                      require_immediate: bool = False,
                      extra_mask: Optional[np.ndarray] = None
                      ) -> Optional[int]:
        mask = summary.structural_fit(job)
        if exclude is not None:
            mask = mask.copy()
            mask[exclude] = False
        if require_immediate:
            mask = mask & summary.immediate_fit(job)
        if extra_mask is not None:
            mask = mask & extra_mask
        if not mask.any():
            if require_immediate or exclude is not None:
                return None            # spillover: no viable target
            # Nothing fits structurally (pool absent / gang too wide
            # everywhere): park the job at the biggest pool so it waits
            # exactly like it would on a lone cluster.
            c = summary.col(job.gpu_type)
            if c is None:
                return 0
            return int(np.argmax(summary.capacity[:, c]))
        scores = np.zeros(summary.n_members)
        for plugin in self.config.select:
            fm = plugin.feasible(job, summary)
            if fm is not None:
                narrowed = mask & np.asarray(fm, dtype=bool)
                if narrowed.any():
                    # A veto that would empty the mask is ignored: a
                    # plugin may delay preference but not strand a job.
                    mask = narrowed
            term = plugin.score(job, summary)
            if term is not None:
                scores = scores + np.asarray(term, dtype=float)
        if self.config.immediate_fit_bonus:
            scores = scores + (self.config.immediate_fit_bonus
                               * summary.immediate_fit(job))
        scores = np.where(mask, scores, -np.inf)
        return int(np.argmax(scores))   # ties -> lowest member index

    # ------------------------------------------------------------------
    # Routing (arrival path)
    # ------------------------------------------------------------------
    def route(self, job: Job, t: float) -> Optional[int]:
        """Pick a member for an arriving job; ``None`` = held in the
        federation-quota backlog."""
        fq = self.config.federation_quota
        if fq is not None and not fq.can_admit(job):
            self.backlog.append(job)
            self.stats.backlogged += 1
            return None
        summary = self.summary(t)
        target = self.select_member(job, summary)
        if fq is not None:
            fq.charge(job)
            self._charged[job.uid] = job
        summary.commit(target, job)
        self.routes[job.uid] = RouteRecord(member=target, since=t)
        self._note_pending(target, t)
        self.stats.routed[target] += 1
        return target

    def _note_pending(self, member: int, since: float) -> None:
        cur = self._earliest_since.get(member)
        if cur is None or since < cur:
            self._earliest_since[member] = since

    def drain_backlog(self, t: float) -> List[Tuple[Job, int]]:
        """Re-try federation-quota holds (after completions freed
        quota).  Returns ``(job, member)`` routes to forward."""
        fq = self.config.federation_quota
        if fq is None or not self.backlog:
            return []
        out: List[Tuple[Job, int]] = []
        held: List[Job] = []
        for job in self.backlog:
            if fq.can_admit(job):
                summary = self.summary(t)
                target = self.select_member(job, summary)
                fq.charge(job)
                self._charged[job.uid] = job
                summary.commit(target, job)
                self.routes[job.uid] = RouteRecord(member=target, since=t)
                self._note_pending(target, t)
                self.stats.routed[target] += 1
                out.append((job, target))
            else:
                held.append(job)
        self.backlog = held
        return out

    def on_job_finished(self, job: Job) -> None:
        """Completion observed on a member bus: release the federation-
        level grant (member quota was already refunded by its QSCH)."""
        if self._charged.pop(job.uid, None) is not None:
            self.config.federation_quota.refund(job)

    # ------------------------------------------------------------------
    # Spillover (anti-starvation re-routing)
    # ------------------------------------------------------------------
    def forward_delay(self, job: Job, target: int) -> float:
        """Forwarding cost: base delay + locality penalty when the job
        leaves its home region (checkpoint/data transfer, ECCOS-style
        cross-cluster cost)."""
        delay = self.config.forward_delay_s
        if (job.region is not None
                and self.fed[target].region != job.region):
            delay += self.config.locality_penalty_s
        return delay

    def maybe_spill(self, member: int, t: float
                    ) -> List[Tuple[Job, int, float]]:
        """After member ``member`` ran a cycle at ``t``: pull jobs that
        waited past the deadline and re-route each to a member that can
        place it NOW.  Returns ``(job, target, arrival_t)`` forwards
        (the federated simulator pushes the SUBMITs).  O(pending) scan +
        O(members) per overdue job — and an empty list without touching
        the summary when nothing is overdue."""
        cfg = self.config
        if not cfg.spillover or len(self.fed) == 1:
            return []
        # Cheap early-out: nothing routed here long enough ago for any
        # deadline to have expired (the bound is refreshed below).  A
        # cleared bound re-arms at `t` while pending work exists, so a
        # job requeued by preemption/failure still gets rescued one
        # deadline later.
        qsch = self.fed[member].qsch
        earliest = self._earliest_since.get(member)
        if earliest is None:
            if qsch.queue_depth():
                self._earliest_since[member] = t
            return []
        if t - earliest < cfg.spill_deadline_s:
            return []
        overdue: List[Tuple[float, int, Job]] = []
        waiting_since: List[float] = []
        for q in qsch.queues.values():
            for job in q:
                if job.state is not JobState.PENDING:
                    continue
                rec = self.routes.get(job.uid)
                if rec is None or rec.member != member:
                    continue
                if rec.spills >= cfg.max_spills_per_job:
                    continue
                if t - rec.since >= cfg.spill_deadline_s:
                    overdue.append((rec.since, job.uid, job))
                else:
                    waiting_since.append(rec.since)
        if not overdue:
            # Tighten the bound to the true earliest still-pending job
            # so the scan does not repeat every tick.
            if waiting_since:
                self._earliest_since[member] = min(waiting_since)
            else:
                self._earliest_since.pop(member, None)
            return []
        overdue.sort(key=lambda e: (e[0], e[1]))
        out: List[Tuple[Job, int, float]] = []
        summary = self.summary(t)
        for since, _, job in overdue:
            c = summary.col(job.gpu_type)
            if c is None:
                continue                      # no such pool anywhere
            if summary.immediate_fit(job)[member]:
                # Home can place it right now (a completion just freed
                # capacity): the next local cycle is cheaper than any
                # forward.
                waiting_since.append(since)
                continue
            # A spill target must have free capacity beyond what its
            # OWN pending backlog in THIS pool already claims: by
            # submit order an old forwarded job jumps the target queue,
            # so landing it on a backlogged member just moves the
            # starvation.
            headroom = (summary.free[:, c] - summary.committed[:, c]
                        - summary.pending_gang_by_type[:, c])
            uncongested = headroom >= job.n_gpus
            target = self.select_member(job, summary, exclude=member,
                                        require_immediate=True,
                                        extra_mask=uncongested)
            if target is None:
                waiting_since.append(since)   # still stuck here
                continue
            qsch._remove_from_queue(job)
            delay = self.forward_delay(job, target)
            arrival = t + delay
            summary.commit(target, job)
            rec = self.routes[job.uid]
            rec.member = target
            rec.since = arrival
            rec.spills += 1
            self.stats.spills += 1
            if self.fed[target].region != self.fed[member].region:
                self.stats.cross_region_forwards += 1
            self.stats.routed[member] -= 1
            self.stats.routed[target] += 1
            out.append((job, target, arrival))
        if waiting_since:
            self._earliest_since[member] = min(waiting_since)
        else:
            self._earliest_since.pop(member, None)
        return out
