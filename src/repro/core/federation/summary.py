"""The per-cluster summary matrix routing decisions are vectorized over.

GSCH must stay O(members) per job: a routing decision reads this
summary, never a member's node arrays.  :func:`summarize` is the one
place that walks member state — O(total nodes), vectorized, and run at
most once per ``GSCHConfig.summary_max_age_s`` window — so the per-job
cost is a handful of (M,)- and (M, T)-shaped array ops.

Matrix semantics (M members × T GPU types, T = the federation-wide type
union; a member without some pool has zero capacity in that column):

* ``free`` / ``capacity``     — free and healthy-total GPUs per pool;
* ``max_node_free`` / ``max_node_cap`` — best single node per pool
  (a pod needs ``gpus_per_pod`` on ONE node, and members differ in
  ``gpus_per_node``: an 8-GPU pod structurally cannot land on a
  4-GPU-per-node member);
* ``group_headroom``          — largest per-LeafGroup free-GPU count
  (gang locality headroom, §3.4.2);
* ``queue_depth`` / ``pending_gang_gpus`` — member backlog pressure;
* ``frag``                    — fragmented-node fraction (§4.3 GFR);
* ``cost`` / ``capability``   — the member's routing traits per pool;
* ``committed``               — GPUs routed since this refresh; charged
  by :meth:`commit` so that batch-routing between refreshes does not
  dog-pile one member.

The core fit/load matrices are computed eagerly; the pressure signals
(``frag``, ``queue_depth``, ``pending_gang_gpus``, ``group_headroom``)
are computed lazily on first access and cached — a routing chain that
never reads them (e.g. quota-fit + least-loaded) pays nothing for them.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np

from ..job import Job, JobState
from .member import MemberCluster


class FederationSummary:
    def __init__(self, t: float, gpu_types: List[int],
                 regions: List[str], free: np.ndarray,
                 capacity: np.ndarray, max_node_free: np.ndarray,
                 max_node_cap: np.ndarray, cost: np.ndarray,
                 capability: np.ndarray,
                 members: Sequence[MemberCluster]) -> None:
        self.t = float(t)
        self.gpu_types = gpu_types
        self.regions = regions
        self.free = free                      # (M, T) int64
        self.capacity = capacity              # (M, T) int64
        self.max_node_free = max_node_free    # (M, T) int64
        self.max_node_cap = max_node_cap      # (M, T) int64
        self.cost = cost                      # (M, T) float64
        self.capability = capability          # (M, T) float64
        self.committed = np.zeros_like(free)  # (M, T) int64, mutable
        self.members = members
        self._col: Dict[int, int] = {tp: i
                                     for i, tp in enumerate(gpu_types)}
        self._frag: Optional[np.ndarray] = None
        self._queue_depth: Optional[np.ndarray] = None
        self._pending_gang: Optional[np.ndarray] = None
        self._group_headroom: Optional[np.ndarray] = None

    @property
    def n_members(self) -> int:
        return self.free.shape[0]

    def col(self, gpu_type: int) -> Optional[int]:
        return self._col.get(int(gpu_type))

    # ------------------------------------------------------------------
    # Lazy pressure signals (cached; see module docstring)
    # ------------------------------------------------------------------
    @property
    def frag(self) -> np.ndarray:
        """(M,) fragmented-node fraction per member."""
        if self._frag is None:
            out = np.zeros(self.n_members)
            for i, m in enumerate(self.members):
                healthy = int(m.state.node_healthy.sum())
                out[i] = (int(m.state.fragmented_nodes().sum()) / healthy
                          if healthy else 0.0)
            self._frag = out
        return self._frag

    @frag.setter
    def frag(self, value: np.ndarray) -> None:
        self._frag = np.asarray(value, dtype=float)

    @property
    def queue_depth(self) -> np.ndarray:
        """(M,) pending-job count per member."""
        if self._queue_depth is None:
            self._queue_depth = np.asarray(
                [m.qsch.queue_depth() for m in self.members],
                dtype=np.int64)
        return self._queue_depth

    @property
    def pending_gang_by_type(self) -> np.ndarray:
        """(M, T) GPUs requested by pending jobs per member per pool —
        the backlog that competes with a spilled job for one pool's
        free capacity (a type-1 backlog says nothing about type-0
        headroom)."""
        if self._pending_gang is None:
            out = np.zeros_like(self.free)
            for i, m in enumerate(self.members):
                for q in m.qsch.queues.values():
                    for j in q:
                        if j.state is not JobState.PENDING:
                            continue
                        c = self.col(j.gpu_type)
                        if c is not None:
                            out[i, c] += j.n_gpus
            self._pending_gang = out
        return self._pending_gang

    @property
    def pending_gang_gpus(self) -> np.ndarray:
        """(M,) total GPUs requested by pending jobs per member."""
        return self.pending_gang_by_type.sum(axis=1)

    @property
    def group_headroom(self) -> np.ndarray:
        """(M, T) largest per-LeafGroup free-GPU count per pool."""
        if self._group_headroom is None:
            out = np.zeros_like(self.free)
            for i, m in enumerate(self.members):
                state = m.state
                node_free = state.free_gpus()
                leaf_id = state.topology.leaf_id
                for tp in np.unique(state.gpu_type):
                    c = self.col(int(tp))
                    if c is None:
                        continue
                    pool_free = np.where(state.pool_mask(int(tp)),
                                         node_free, 0)
                    out[i, c] = int(np.bincount(
                        leaf_id, weights=pool_free,
                        minlength=state.topology.n_leaf_groups).max())
            self._group_headroom = out
        return self._group_headroom

    # ------------------------------------------------------------------
    # Vectorized per-job views (each O(members))
    # ------------------------------------------------------------------
    def structural_fit(self, job: Job) -> np.ndarray:
        """Members that could EVER host the job: enough healthy pool
        capacity and a node model large enough for one pod."""
        c = self.col(job.gpu_type)
        if c is None:
            return np.zeros(self.n_members, dtype=bool)
        return ((self.capacity[:, c] >= job.n_gpus)
                & (self.max_node_cap[:, c] >= job.gpus_per_pod))

    def immediate_fit(self, job: Job) -> np.ndarray:
        """Members with enough free capacity to place the job *now*
        (modulo fragmentation), net of routing commitments."""
        c = self.col(job.gpu_type)
        if c is None:
            return np.zeros(self.n_members, dtype=bool)
        free_now = self.free[:, c] - self.committed[:, c]
        return ((free_now >= job.n_gpus)
                & (self.max_node_free[:, c] >= job.gpus_per_pod))

    def free_fraction(self, gpu_type: int) -> np.ndarray:
        """(M,) free/capacity in one pool (0 where the pool is absent),
        net of commitments — the least-loaded routing signal."""
        c = self.col(gpu_type)
        if c is None:
            return np.zeros(self.n_members)
        cap = np.maximum(self.capacity[:, c], 1)
        free_now = np.maximum(self.free[:, c] - self.committed[:, c], 0)
        return free_now / cap

    def commit(self, member: int, job: Job) -> None:
        """Charge a routing decision against the cached free view."""
        c = self.col(job.gpu_type)
        if c is not None:
            self.committed[member, c] += job.n_gpus


def summarize(members: Sequence[MemberCluster], t: float = 0.0,
              gpu_types: Optional[Sequence[int]] = None
              ) -> FederationSummary:
    """Build the summary matrix — the only node-array walk in GSCH."""
    if gpu_types is None:
        types = sorted({int(tp) for m in members
                        for tp in np.unique(m.state.gpu_type)})
    else:
        types = [int(tp) for tp in gpu_types]
    col = {tp: i for i, tp in enumerate(types)}
    m_n, t_n = len(members), len(types)
    free = np.zeros((m_n, t_n), dtype=np.int64)
    capacity = np.zeros((m_n, t_n), dtype=np.int64)
    max_node_free = np.zeros((m_n, t_n), dtype=np.int64)
    max_node_cap = np.zeros((m_n, t_n), dtype=np.int64)
    cost = np.zeros((m_n, t_n))
    capability = np.zeros((m_n, t_n))
    for i, m in enumerate(members):
        state = m.state
        node_free = state.free_gpus()
        node_cap = np.where(state.node_healthy,
                            state.healthy_counts(), 0)
        for tp in np.unique(state.gpu_type):
            c = col.get(int(tp))
            if c is None:
                continue
            pool = state.pool_mask(int(tp))
            pool_free = np.where(pool, node_free, 0)
            pool_cap = np.where(pool, node_cap, 0)
            free[i, c] = int(pool_free.sum())
            capacity[i, c] = int(pool_cap.sum())
            max_node_free[i, c] = int(pool_free.max())
            max_node_cap[i, c] = int(pool_cap.max())
            cost[i, c] = m.cost_per_gpu_hour.get(int(tp), 0.0)
            capability[i, c] = m.capability.get(int(tp), 1.0)
    return FederationSummary(
        t=t, gpu_types=types, regions=[m.region for m in members],
        free=free, capacity=capacity,
        max_node_free=max_node_free, max_node_cap=max_node_cap,
        cost=cost, capability=capability, members=members)
