"""TuningProfile: a serializable tuned operating point.

The transfer unit of the Sliwko direction: a named parameter dict (the
``ParamSpace.snapshot()`` of a tuned stack) plus the objective it
reached and free-form provenance metadata.  Export one from a tuned
trace or federation member, ship it as JSON, and warm-start another
member's :class:`~repro.core.tuning.manager.TuningManager` from it —
the receiver force-applies the parameter *intersection*, so profiles
transfer between differently-shaped clusters (unknown handles are
reported, not fatal).
"""

from __future__ import annotations

import dataclasses
import json
from typing import Dict, Optional


@dataclasses.dataclass
class TuningProfile:
    name: str
    #: ParamSpace handle name -> tuned value.
    params: Dict[str, float]
    #: Frontier objective at export time (None = never measured).
    objective: Optional[float] = None
    meta: Dict[str, object] = dataclasses.field(default_factory=dict)

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), indent=2,
                          sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "TuningProfile":
        d = json.loads(text)
        return cls(name=d["name"],
                   params={str(k): float(v)
                           for k, v in d["params"].items()},
                   objective=(None if d.get("objective") is None
                              else float(d["objective"])),
                   meta=dict(d.get("meta", {})))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json())

    @classmethod
    def load(cls, path: str) -> "TuningProfile":
        with open(path) as f:
            return cls.from_json(f.read())
