"""Built-in controllers: guarded hill-climb + starvation escalator.

Three :class:`~repro.core.framework.api.ControllerPlugin` implementations
registered in the plugin registry like any Score/Policy plugin:

* :class:`NoOpController` — attaches, observes, never writes.  The
  parity baseline: an attached NoOpController must leave the run
  byte-identical to a detached one (tests + tuning_bench gate (a)).
* :class:`HillClimbController` — Mamirov-style dynamic multi-objective
  adaptation as a guarded epsilon-greedy hill climb: each control
  period it either *measures* (judging the previous probe against the
  pre-probe baseline with absolute hysteresis, reverting on
  regression) or *probes* (one bounded, rate-limited move on one
  parameter).  One-move-at-a-time keeps credit assignment unambiguous;
  revert-on-regression bounds the damage of any probe to one window.
* :class:`StarvationEscalator` — Mamirov's starvation counter-measure:
  long-waiting queued gangs get their effective priority raised (up to
  ``PRIO_HIGH``) so size/FIFO ordering cannot starve them forever.
  Its wait threshold is itself a registered tunable handle, so the
  hill climb can tune the escalator.
"""

from __future__ import annotations

import math
import random
from typing import Dict, List, Optional, Sequence, Tuple

from ..framework.api import ControllerPlugin
from ..framework.registry import register
from ..job import PRIO_HIGH
from .manager import ObjectiveWeights, TuningWindow, frontier_objective
from .params import ParamSpace
from .profile import TuningProfile


@register
class NoOpController(ControllerPlugin):
    """Observes every window, never touches a handle — the attached-run
    byte-identity baseline."""

    name = "NoOpController"

    def __init__(self) -> None:
        self.windows_seen = 0
        self.ticks_seen = 0

    def on_tick(self, now, sched, space) -> None:
        self.ticks_seen += 1

    def control(self, window, space) -> None:
        self.windows_seen += 1


@register
class HillClimbController(ControllerPlugin):
    """Guarded epsilon-greedy hill climb over the registered handles.

    Lifecycle per control period (``control_period_s`` simulated
    seconds):

    1. **First window** measures the static baseline — no write.
    2. If a probe is outstanding, judge it: keep the move when the
       window's frontier objective beats the baseline by at least
       ``hysteresis`` (absolute), else force-revert to the pre-probe
       value.  Arm statistics record the outcome either way.
    3. Pick the next arm — one ``(parameter, direction)`` pair —
       epsilon-greedy on observed win rate (optimistic for untried
       arms), and apply a single rate-limited step.

    ``params`` restricts tuning to a name subset (prefix match), e.g.
    ``["train-e-binpack."]`` tunes only the training profile's weights.
    ``warm_start`` adopts a donor profile's objective as the initial
    baseline, so the climb continues *from* the transferred operating
    point instead of re-measuring and re-walking to it."""

    name = "HillClimbController"
    control_period_s = 1800.0

    def __init__(self, objective: Optional[ObjectiveWeights] = None,
                 seed: int = 0, epsilon: float = 0.25,
                 hysteresis: float = 0.01,
                 params: Optional[Sequence[str]] = None) -> None:
        self.objective = objective
        self.epsilon = float(epsilon)
        self.hysteresis = float(hysteresis)
        self.param_prefixes = list(params) if params is not None else None
        self.rng = random.Random(seed)
        self.baseline: Optional[float] = None
        self.moves = 0
        self.accepts = 0
        self.reverts = 0
        self._pending: Optional[Tuple[Tuple[str, int], float]] = None
        self._arms: List[Tuple[str, int]] = []
        # arm -> [tries, wins]
        self._stats: Dict[Tuple[str, int], List[int]] = {}

    # -- lifecycle -----------------------------------------------------
    def bind(self, space: ParamSpace, manager) -> None:
        if self.objective is None:
            self.objective = manager.objective
        self._arms = [(name, direction)
                      for name in space.names()
                      if self._tunes(name)
                      for direction in (+1, -1)]

    def _tunes(self, name: str) -> bool:
        if self.param_prefixes is None:
            return True
        return any(name.startswith(p) for p in self.param_prefixes)

    def warm_start(self, profile: TuningProfile, space: ParamSpace
                   ) -> None:
        # Parameters were already force-applied by the manager; adopting
        # the donor's objective as baseline makes the next window judge
        # against the transferred operating point.
        if profile.objective is not None:
            self.baseline = float(profile.objective)

    # -- control -------------------------------------------------------
    def control(self, window: TuningWindow, space: ParamSpace) -> None:
        score = frontier_objective(window, self.objective)
        if math.isnan(score):
            return
        if self.baseline is None:
            self.baseline = score        # first window: establish baseline
        elif self._pending is not None:
            arm, prev = self._pending
            self._pending = None
            stats = self._stats.setdefault(arm, [0, 0])
            stats[0] += 1
            if score >= self.baseline + self.hysteresis:
                stats[1] += 1
                self.accepts += 1
                self.baseline = score
            else:
                space.set(arm[0], prev, now=window.t1,
                          source=f"{self.name}:revert", force=True)
                self.reverts += 1
            return                        # next window measures clean
        self._probe(window.t1, space)

    def _probe(self, now: float, space: ParamSpace) -> None:
        if not self._arms:
            return
        arm = self._pick_arm()
        name, direction = arm
        p = space.param(name)
        prev = space.get(name)
        applied = space.set(name, prev + direction * p.max_step,
                            now=now, source=self.name)
        if applied != prev:
            self.moves += 1
            self._pending = (arm, prev)
        else:
            # Pinned at a bound: record a loss so the greedy choice
            # stops re-picking a dead arm.
            stats = self._stats.setdefault(arm, [0, 0])
            stats[0] += 1

    def _pick_arm(self) -> Tuple[str, int]:
        if self.rng.random() < self.epsilon:
            return self.rng.choice(self._arms)

        def win_rate(arm: Tuple[str, int]) -> float:
            tries, wins = self._stats.get(arm, (0, 0))
            return 1.0 if tries == 0 else wins / tries   # optimistic

        best = max(win_rate(a) for a in self._arms)
        candidates = [a for a in self._arms if win_rate(a) == best]
        return self.rng.choice(candidates)


@register
class StarvationEscalator(ControllerPlugin):
    """Raise the effective queue priority of long-waiting jobs.

    Every tick it scans the tenant queues; a job that has waited longer
    than ``wait_threshold_s`` gets ``boost`` added to its priority
    (capped at ``PRIO_HIGH``), at most once per
    ``escalation_period_s`` per job — repeated escalation walks a
    starving gang up the admission order one bounded step at a time.
    The threshold registers as a tunable handle
    (``escalator.wait_threshold_s``), so an outer controller can tune
    how aggressive starvation relief is."""

    name = "StarvationEscalator"
    control_period_s = 1800.0

    def __init__(self, wait_threshold_s: float = 3600.0,
                 boost: int = 10,
                 escalation_period_s: float = 1800.0) -> None:
        self.wait_threshold_s = float(wait_threshold_s)
        self.boost = int(boost)
        self.escalation_period_s = float(escalation_period_s)
        self.escalations = 0
        self._last_boost: Dict[int, float] = {}

    def bind(self, space: ParamSpace, manager) -> None:
        t0 = self.wait_threshold_s

        def get_threshold() -> float:
            return self.wait_threshold_s

        def set_threshold(v: float) -> None:
            self.wait_threshold_s = float(v)

        space.register("escalator.wait_threshold_s", get_threshold,
                       set_threshold, lo=max(60.0, 0.125 * t0),
                       hi=4.0 * t0, max_step=0.25 * t0)

    def on_tick(self, now: float, sched, space: ParamSpace) -> None:
        for queue in sched.queues.values():
            for job in queue:
                if job.priority >= PRIO_HIGH:
                    continue
                if now - job.submit_time < self.wait_threshold_s:
                    continue
                last = self._last_boost.get(job.uid)
                if last is not None \
                        and now - last < self.escalation_period_s:
                    continue
                job.priority = min(PRIO_HIGH, job.priority + self.boost)
                self._last_boost[job.uid] = now
                self.escalations += 1
