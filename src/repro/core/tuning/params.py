"""Tunable parameter handles: the ParamSpace contract.

Every hand-set scheduling constant Kant's Table-1 profiles carry —
fused score weights, the preemption budget, the backfill head timeout,
the federation spillover deadline, the starvation-escalation threshold
— becomes a *registered handle* in a :class:`ParamSpace`: a named
getter/setter pair with declared bounds, a per-move change-rate limit
and an integer flag.  Controllers (:mod:`repro.core.tuning.controllers`)
only ever write through :meth:`ParamSpace.set`, which

* clamps the requested value into ``[lo, hi]``,
* rate-limits the move to ``max_step`` per call (``force=True``
  bypasses the rate limit for warm-starts and reverts, never the
  bounds),
* rounds integer handles,
* drops no-op writes (same effective value -> nothing recorded), and
* on a real change appends a :class:`ParamChange` record and notifies
  the attached observability sink (Gauge + trace instant +
  DecisionAudit entry via ``Telemetry.on_param_change``).

This is what makes profiles *live-reconfigurable* instead of
constructor-frozen: :class:`~repro.core.framework.builtin.WeightSetScore`
re-reads ``self.weights`` on every ``fused_weights`` call, QSCH re-reads
its config every preemption chain, the Backfill policy re-reads
``head_timeout`` every cycle, and the GSCH re-reads
``spill_deadline_s`` every spillover scan — so a handle write takes
effect at the next cycle with zero hot-path cost.

The binding helpers (:func:`bind_qsch`, :func:`bind_profile_weights`,
:func:`bind_simulator`, :func:`bind_gsch`) are **read-only probes**:
they enumerate a profile's placement passes with representative jobs,
register handles for every discovered :class:`WeightSetScore` term, and
never mutate anything — an attached-but-silent controller stays
byte-identical to a detached run (gated in
``benchmarks/tuning_bench.py``).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import numpy as np

from ..framework.builtin import BackfillPolicy, WeightSetScore
from ..job import Job, JobKind
from ..scoring import ScoreWeights


@dataclasses.dataclass(frozen=True)
class ParamChange:
    """One applied parameter move (the audit record)."""

    param: str
    t: float
    previous: float
    value: float
    source: str = ""

    def as_dict(self) -> Dict[str, object]:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class TunableParam:
    """One registered handle: getter/setter + envelope."""

    name: str
    get: Callable[[], float]
    set: Callable[[float], None]
    lo: float
    hi: float
    #: Largest move one (non-forced) ``ParamSpace.set`` may apply.
    max_step: float
    integer: bool = False

    def clamp(self, value: float, *, force: bool = False) -> float:
        """The effective value a write of ``value`` would land at."""
        v = min(self.hi, max(self.lo, float(value)))
        if not force:
            cur = float(self.get())
            lo = cur - self.max_step
            hi = cur + self.max_step
            v = min(hi, max(lo, v))
            # The rate-limit window may poke outside the bounds when the
            # current value sits at an edge; bounds always win.
            v = min(self.hi, max(self.lo, v))
        if self.integer:
            v = float(int(round(v)))
        return v


class ParamSpace:
    """The registered tunable surface of one scheduler stack.

    ``on_change`` (set by the :class:`~repro.core.tuning.manager.
    TuningManager` at attach time) receives every applied
    :class:`ParamChange` — that is the hook through which changes reach
    the obs registry, the tracer and the decision audit."""

    def __init__(self) -> None:
        self._params: Dict[str, TunableParam] = {}
        self.changes: List[ParamChange] = []
        self.on_change: Optional[Callable[[ParamChange], None]] = None

    # -- registration --------------------------------------------------
    def register(self, name: str, get: Callable[[], float],
                 set: Callable[[float], None], lo: float, hi: float,
                 max_step: float, integer: bool = False) -> TunableParam:
        if name in self._params:
            raise ValueError(f"tunable {name!r} already registered")
        if not (lo <= hi):
            raise ValueError(f"tunable {name!r}: lo {lo} > hi {hi}")
        p = TunableParam(name=name, get=get, set=set, lo=lo, hi=hi,
                         max_step=float(max_step), integer=integer)
        self._params[name] = p
        return p

    def names(self) -> List[str]:
        return sorted(self._params)

    def __contains__(self, name: str) -> bool:
        return name in self._params

    def __len__(self) -> int:
        return len(self._params)

    def param(self, name: str) -> TunableParam:
        return self._params[name]

    # -- reads ---------------------------------------------------------
    def get(self, name: str) -> float:
        return float(self._params[name].get())

    def snapshot(self) -> Dict[str, float]:
        """Current value of every handle (TuningProfile payload)."""
        return {name: self.get(name) for name in self.names()}

    # -- writes --------------------------------------------------------
    def set(self, name: str, value: float, now: float = 0.0,
            source: str = "", force: bool = False) -> float:
        """Apply a bounded, rate-limited write; returns the effective
        value.  A write that lands on the current value is a no-op:
        nothing is stored, nothing is notified."""
        p = self._params[name]
        prev = float(p.get())
        v = p.clamp(value, force=force)
        if v == prev:
            return prev
        p.set(v)
        change = ParamChange(param=name, t=float(now), previous=prev,
                             value=v, source=source)
        self.changes.append(change)
        if self.on_change is not None:
            self.on_change(change)
        return v

    def apply(self, values: Dict[str, float], now: float = 0.0,
              source: str = "warm-start") -> List[str]:
        """Force-apply a parameter dict (warm-start / transfer path).
        Unknown names are skipped and returned — a donor profile from a
        differently-shaped cluster warm-starts the intersection."""
        skipped = []
        for name, value in sorted(values.items()):
            if name in self._params:
                self.set(name, value, now=now, source=source, force=True)
            else:
                skipped.append(name)
        return skipped


# ----------------------------------------------------------------------
# Binding helpers: enumerate a stack's tunable surface
# ----------------------------------------------------------------------
class _FakeZoneSnap:
    """Minimal snapshot stand-in for plan probing.

    Profile ``plan(job, snap)`` closures only consult
    ``snap.inference_zone.any()`` (the §3.3.4 zone dance); probing with
    both zone states enumerates every branch without touching cluster
    state."""

    def __init__(self, has_zone: bool) -> None:
        self.inference_zone = np.asarray([has_zone])


def _probe_jobs() -> List[Job]:
    """Representative jobs covering every plan branch: training gang,
    small inference pod (dedicated zone), large inference pod, debug."""
    return [
        Job(uid=-1, tenant="_probe", gpu_type=0, n_pods=2, gpus_per_pod=8,
            kind=JobKind.TRAIN),
        Job(uid=-2, tenant="_probe", gpu_type=0, n_pods=1, gpus_per_pod=1,
            kind=JobKind.INFER, gang=False),
        Job(uid=-3, tenant="_probe", gpu_type=0, n_pods=1, gpus_per_pod=8,
            kind=JobKind.INFER, gang=False),
        Job(uid=-4, tenant="_probe", gpu_type=0, n_pods=1, gpus_per_pod=1,
            kind=JobKind.DEBUG, gang=False),
    ]


def iter_profile_weight_plugins(profiles):
    """Yield ``(profile_name, plugin)`` for every distinct
    :class:`WeightSetScore` instance reachable through the profile
    set's plan closures (deduplicated by identity — espread plans share
    scorer instances across passes)."""
    seen = set()
    snaps = (_FakeZoneSnap(False), _FakeZoneSnap(True))
    jobs = _probe_jobs()
    for profile in (profiles.train, profiles.inference,
                    profiles.best_effort):
        for job in jobs:
            for snap in snaps:
                try:
                    passes = profile.plan(job, snap)
                except Exception:
                    # A custom plan inspecting more of the snapshot than
                    # the zone mask: skip the branch, keep the rest.
                    continue
                for p in passes:
                    for scorer in p.scorers:
                        if not isinstance(scorer, WeightSetScore):
                            continue
                        if id(scorer) in seen:
                            continue
                        seen.add(id(scorer))
                        yield profile.name, scorer


def _weight_setter(plugin: WeightSetScore, field: str
                   ) -> Callable[[float], None]:
    def setter(v: float) -> None:
        plugin.weights = dataclasses.replace(plugin.weights,
                                             **{field: float(v)})
    return setter


def _weight_getter(plugin: WeightSetScore, field: str
                   ) -> Callable[[], float]:
    def getter() -> float:
        return float(getattr(plugin.weights, field))
    return getter


def bind_profile_weights(space: ParamSpace, profiles,
                         prefix: str = "") -> List[str]:
    """Register a handle per nonzero fused-weight term of every
    :class:`WeightSetScore` in the profile set.

    Bounds are sign-preserving — ``[0, 4w]`` for positive terms,
    ``[4w, 0]`` for negative ones — so tuning can rescale a term's
    strength but never flip its semantics (a binpack term cannot become
    a spread term under the controller's feet); ``max_step`` is 25% of
    the initial magnitude per move."""
    registered: List[str] = []
    counts: Dict[str, int] = {}
    for profile_name, plugin in iter_profile_weight_plugins(profiles):
        base = f"{prefix}{profile_name}.{plugin.name}"
        counts[base] = counts.get(base, 0) + 1
        if counts[base] > 1:
            # Two same-named plugin instances in one profile (e.g. the
            # espread general/general-zone pass pair): disambiguate.
            base = f"{base}#{counts[base]}"
        for field in ("used", "fit", "group", "topo"):
            w = float(getattr(plugin.weights, field))
            if w == 0.0:
                continue
            lo, hi = (0.0, 4.0 * w) if w > 0 else (4.0 * w, 0.0)
            name = f"{base}.{field}"
            space.register(name, _weight_getter(plugin, field),
                           _weight_setter(plugin, field), lo=lo, hi=hi,
                           max_step=0.25 * abs(w))
            registered.append(name)
    return registered


def bind_qsch(space: ParamSpace, qsch, prefix: str = "") -> List[str]:
    """Register the QSCH-level handles: the per-cycle preemption budget
    and (when the queue policy is Backfill) the head timeout."""
    registered: List[str] = []
    cfg = qsch.config

    name = f"{prefix}qsch.max_preemptions_per_cycle"
    budget0 = int(cfg.max_preemptions_per_cycle)

    def get_budget() -> float:
        return float(cfg.max_preemptions_per_cycle)

    def set_budget(v: float) -> None:
        cfg.max_preemptions_per_cycle = int(v)

    space.register(name, get_budget, set_budget, lo=0.0,
                   hi=float(max(4 * budget0, 16)),
                   max_step=float(max(budget0 // 4, 4)), integer=True)
    registered.append(name)

    policy = qsch.queue_policy
    if isinstance(policy, BackfillPolicy):
        name = f"{prefix}qsch.backfill_head_timeout"
        t0 = float(policy.head_timeout)

        def get_timeout() -> float:
            return float(policy.head_timeout)

        def set_timeout(v: float) -> None:
            # The config mirror keeps introspection (and any re-built
            # policy) consistent with the live plugin.
            policy.head_timeout = float(v)
            cfg.backfill_head_timeout = float(v)

        space.register(name, get_timeout, set_timeout,
                       lo=max(60.0, 0.125 * t0), hi=4.0 * t0,
                       max_step=0.25 * t0)
        registered.append(name)
    return registered


def bind_gsch(space: ParamSpace, gsch, prefix: str = "gsch."
              ) -> List[str]:
    """Register the federation-level spillover deadline."""
    cfg = gsch.config
    d0 = float(cfg.spill_deadline_s)
    name = f"{prefix}spill_deadline_s"

    def get_deadline() -> float:
        return float(cfg.spill_deadline_s)

    def set_deadline(v: float) -> None:
        cfg.spill_deadline_s = float(v)

    space.register(name, get_deadline, set_deadline,
                   lo=max(60.0, 0.125 * d0), hi=4.0 * d0,
                   max_step=0.25 * d0)
    return [name]


def bind_simulator(space: ParamSpace, sim, prefix: str = "",
                   gsch=None) -> List[str]:
    """The standard binding for one simulator stack: QSCH knobs + every
    profile fused-weight term (+ the GSCH deadline when routing through
    a federation)."""
    registered = bind_qsch(space, sim.qsch, prefix=prefix)
    registered += bind_profile_weights(space, sim.qsch.rsch.profiles,
                                       prefix=prefix)
    if gsch is not None:
        registered += bind_gsch(space, gsch, prefix=f"{prefix}gsch.")
    return registered
