"""Self-tuning subsystem: the metrics->parameters loop.

Kant's Table-1 profiles hard-code fused score weights, preemption
budgets, backfill timeouts and spillover deadlines; since the obs
subsystem (PR 7) the stack also *observes* its own GFR/JWTD/GAR/SOR
series live.  This package closes that loop:

* :mod:`~repro.core.tuning.params` — :class:`ParamSpace`: bounded,
  rate-limited tunable handles over live scheduler state;
* :mod:`~repro.core.tuning.manager` — :class:`TuningManager`: binds a
  space over a simulator, windows the Sample/Tick stream, drives
  :class:`~repro.core.framework.api.ControllerPlugin` instances on a
  control-period cadence;
* :mod:`~repro.core.tuning.controllers` — built-ins:
  :class:`HillClimbController` (guarded hill climb with hysteresis and
  revert-on-regression), :class:`StarvationEscalator` (Mamirov-style
  priority escalation), :class:`NoOpController` (parity baseline);
* :mod:`~repro.core.tuning.profile` — :class:`TuningProfile`:
  serializable tuned operating points for cross-cluster warm-starts
  (Sliwko transfer direction).

See ``docs/tuning.md`` for the contract and worked examples, and
``benchmarks/tuning_bench.py`` for the acceptance gates.
"""

from .controllers import (HillClimbController, NoOpController,
                          StarvationEscalator)
from .manager import (ObjectiveWeights, TuningManager, TuningWindow,
                      frontier_objective)
from .params import (ParamChange, ParamSpace, TunableParam, bind_gsch,
                     bind_profile_weights, bind_qsch, bind_simulator)
from .profile import TuningProfile

__all__ = [
    "HillClimbController",
    "NoOpController",
    "StarvationEscalator",
    "ObjectiveWeights",
    "TuningManager",
    "TuningWindow",
    "frontier_objective",
    "ParamChange",
    "ParamSpace",
    "TunableParam",
    "bind_gsch",
    "bind_profile_weights",
    "bind_qsch",
    "bind_simulator",
    "TuningProfile",
]
