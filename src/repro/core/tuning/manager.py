"""TuningManager: wires controllers into a simulator's event stream.

The manager is the tuning analogue of ``repro.obs.Telemetry.attach``:
it binds a :class:`~repro.core.tuning.params.ParamSpace` over one
simulator stack (QSCH knobs + profile fused weights + optional GSCH
deadline), subscribes TICK/SAMPLE handlers on the simulator's event
bus *after* the built-ins (so a cycle's placements and the cycle's
metric sample are already recorded when the manager observes them),
and invokes each attached :class:`~repro.core.framework.api.
ControllerPlugin` on its control-period cadence with a
:class:`TuningWindow` — the windowed GFR/JWTD/GAR/SOR aggregate the
frontier objective is computed from.

Every applied parameter move flows back out through the obs facade
(``Telemetry.on_param_change``): a Gauge per tuned parameter, a trace
instant on the scheduler track, and a DecisionAudit record — the
tuning loop is itself observable.

Transfer (Sliwko direction): :meth:`TuningManager.export_profile`
snapshots the tuned operating point as a
:class:`~repro.core.tuning.profile.TuningProfile`;
:meth:`TuningManager.warm_start` force-applies a donor profile and
lets each controller seed its search state from it, so a new
federation member starts *at* the tuned point instead of re-learning
it (gated in ``benchmarks/tuning_bench.py``).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..events import Event, EventKind
from ..metrics import Sample
from .params import ParamChange, ParamSpace, bind_simulator
from .profile import TuningProfile


@dataclasses.dataclass(frozen=True)
class ObjectiveWeights:
    """Weights of the frontier objective (higher objective = better).

    ``wait_scale`` normalizes the P90 waiting-time term to the same
    order of magnitude as the rate metrics (seconds; default 1 hour)."""

    gar: float = 1.0
    sor: float = 1.0
    gfr: float = 1.0          # subtracted: fragmentation is a cost
    wait: float = 1.0         # subtracted: waiting is a cost
    wait_scale: float = 3600.0


@dataclasses.dataclass
class TuningWindow:
    """One control period's observations: the raw samples that landed in
    ``[t0, t1)`` plus the waiting times of jobs that *started* in it."""

    t0: float
    t1: float
    samples: List[Sample] = dataclasses.field(default_factory=list)
    waits: List[float] = dataclasses.field(default_factory=list)

    def mean_gar(self) -> float:
        return float(np.mean([s.gar for s in self.samples])) \
            if self.samples else float("nan")

    def mean_gfr(self) -> float:
        return float(np.mean([s.gfr for s in self.samples])) \
            if self.samples else float("nan")

    def sor(self) -> float:
        """Window SOR approximation: Σallocated / Σcapacity over the
        window's equally-spaced samples."""
        cap = sum(s.capacity for s in self.samples)
        if cap <= 0:
            return float("nan")
        return sum(s.allocated for s in self.samples) / cap

    def p90_wait(self) -> float:
        return float(np.percentile(self.waits, 90.0)) if self.waits \
            else float("nan")

    def mean_queue_depth(self) -> float:
        return float(np.mean([s.queue_depth for s in self.samples])) \
            if self.samples else float("nan")


def frontier_objective(window: TuningWindow,
                       weights: Optional[ObjectiveWeights] = None
                       ) -> float:
    """Scalarized multi-objective score of one window (higher = better).

    NaN terms (no samples / no starts in the window) contribute zero
    rather than poisoning the sum — an idle window scores 0, not NaN."""
    w = weights or ObjectiveWeights()
    total = 0.0
    for value, weight in ((window.mean_gar(), w.gar),
                          (window.sor(), w.sor),
                          (window.mean_gfr(), -w.gfr),
                          (window.p90_wait() / w.wait_scale, -w.wait)):
        if not math.isnan(value):
            total += weight * value
    return total


class TuningManager:
    """Owns the ParamSpace and drives controllers over one simulator.

    ``attach`` may be called once per manager; use one manager per
    federation member (each gets its own space and window state)."""

    def __init__(self, controllers: Sequence = (),
                 objective: Optional[ObjectiveWeights] = None,
                 control_period_s: Optional[float] = None) -> None:
        self.controllers = list(controllers)
        self.objective = objective or ObjectiveWeights()
        if control_period_s is None and self.controllers:
            control_period_s = min(c.control_period_s
                                   for c in self.controllers)
        self.control_period_s = control_period_s or 1800.0
        self.space = ParamSpace()
        self.space.on_change = self._emit_change
        #: (window_end_time, objective) per completed control period.
        self.history: List[Tuple[float, float]] = []
        #: ParamSpace snapshot at the END of each control period — the
        #: parameter trajectory (warm-start convergence is measured on
        #: the distance of these to a donor profile).
        self.period_snapshots: List[Dict[str, float]] = []
        self.periods = 0
        self._sim = None
        self._scope: Optional[str] = None
        self._window: Optional[TuningWindow] = None
        self._next_control: Optional[float] = None
        self._seen_starts: set = set()
        self.now = 0.0

    # ------------------------------------------------------------------
    def attach(self, sim, scope: Optional[str] = None,
               gsch=None) -> "TuningManager":
        """Bind the tunable surface of ``sim`` and start consuming its
        Tick/Sample stream.  ``scope`` labels emitted telemetry (the
        federation member name); ``gsch`` additionally registers the
        spillover-deadline handle."""
        if self._sim is not None:
            raise RuntimeError("TuningManager is already attached")
        self._sim = sim
        self._scope = scope
        bind_simulator(self.space, sim, gsch=gsch)
        # Subscribed after Simulator._register_builtins: the manager's
        # handlers observe post-cycle, post-sample state.
        sim.bus.subscribe(EventKind.TICK, self._on_tick)
        sim.bus.subscribe(EventKind.SAMPLE, self._on_sample)
        for c in self.controllers:
            c.bind(self.space, self)
        return self

    def _emit_change(self, change: ParamChange) -> None:
        obs = getattr(self._sim, "obs", None) if self._sim is not None \
            else None
        if obs is not None:
            obs.on_param_change(change)

    # ------------------------------------------------------------------
    # Event handlers (run after the simulator built-ins)
    # ------------------------------------------------------------------
    def _on_tick(self, ev: Event) -> None:
        self.now = ev.t
        sim = self._sim
        if self._window is None:
            self._window = TuningWindow(t0=ev.t, t1=ev.t)
            self._next_control = ev.t + self.control_period_s
        # Harvest waiting times of jobs that started since the last
        # tick.  Keyed by (uid, start_time) so a preempted-and-restarted
        # job's new wait is counted again.
        for job in sim.qsch.running.values():
            if job.start_time is None:
                continue
            key = (job.uid, job.start_time)
            if key in self._seen_starts:
                continue
            self._seen_starts.add(key)
            w = job.waiting_time
            if w is not None:
                self._window.waits.append(float(w))
        for c in self.controllers:
            c.on_tick(ev.t, sim.qsch, self.space)
        if ev.t >= self._next_control:
            self._fire_control(ev.t)

    def _on_sample(self, ev: Event) -> None:
        if self._window is None:
            self._window = TuningWindow(t0=ev.t, t1=ev.t)
            self._next_control = ev.t + self.control_period_s
        metrics = self._sim.metrics
        if metrics.samples:
            # The built-in SAMPLE handler appended this event's sample
            # before this handler ran (subscription order).
            self._window.samples.append(metrics.samples[-1])

    def _fire_control(self, t: float) -> None:
        window = self._window
        window.t1 = t
        score = frontier_objective(window, self.objective)
        self.history.append((t, score))
        self.periods += 1
        for c in self.controllers:
            c.control(window, self.space)
        self.period_snapshots.append(self.space.snapshot())
        self._window = TuningWindow(t0=t, t1=t)
        self._next_control = t + self.control_period_s

    # ------------------------------------------------------------------
    # Transfer (Sliwko direction)
    # ------------------------------------------------------------------
    def export_profile(self, name: str) -> TuningProfile:
        """Snapshot the current operating point as a transferable
        profile (parameter dict + last objective)."""
        objective = self.history[-1][1] if self.history else None
        return TuningProfile(
            name=name, params=self.space.snapshot(), objective=objective,
            meta={"scope": self._scope or "",
                  "periods": self.periods,
                  "n_params": len(self.space)})

    def warm_start(self, profile: TuningProfile) -> List[str]:
        """Seed this stack from a donor profile: force-apply the
        parameter intersection, then let each controller adopt the
        donor's search state.  Returns the donor parameter names that
        had no local handle (differently-shaped donor cluster)."""
        skipped = self.space.apply(profile.params, now=self.now,
                                   source=f"warm-start:{profile.name}")
        for c in self.controllers:
            c.warm_start(profile, self.space)
        return skipped
