"""Node filter+score pass shared by RSCH, the jnp oracle and the Pallas
kernel, plus the batched gang-placement slot selection built on top of it.

For every candidate node the scheduler computes one fused score

    score[i] = valid[i] ? ( w_used  * used[i]/G
                          + w_fit   * exact_fit[i]
                          + w_group * group_load[i]
                          + w_topo  * topo_pref[i] )
             : -inf

where ``valid[i] = mask[i] & (free[i] >= request)``.  Sign conventions on
the weight vector select the strategy:

* **Binpack / E-Binpack** (§3.3.3): ``w_used > 0`` packs busy nodes first,
  ``w_fit`` rewards exact fits (leaves no fragment behind), ``w_group > 0``
  consolidates into already-busy NodeNetGroups (LeafGroup-level E-Binpack),
  ``w_topo > 0`` pulls pods of one job toward its anchor group.
* **Spread / E-Spread** (§3.3.4): ``w_used < 0`` prefers idle nodes.

This module is the *numpy* implementation used by the discrete-event
simulator (cheap per call); ``repro.kernels.ref`` is the jnp oracle and
``repro.kernels.node_score`` the Pallas TPU kernel.  All three are
asserted identical in ``tests/test_kernels.py``.
:func:`compute_node_scores` is the single entry point that dispatches
between them, so RSCH can switch backends via config.

**Batched gang placement** (§3.4 search-space reduction): instead of
re-running the full score pass once per pod, a gang job is placed with
ONE fused pass.  Each valid node is expanded into
``floor(free / gpus_per_pod)`` pod *slots*; the value of node ``i``'s
``p``-th slot reproduces what the sequential per-pod rescoring loop
would have seen at the step that consumed it:

    slot(i, p) = base[i] + colocate_bonus * p
               + w_fit * [free[i] - p*request == request]

(the co-location bonus and the moving exact-fit term are the only parts
of the score that depend on earlier pods of the same job — ``used``,
``group_load`` and ``topo_pref`` are snapshot-static).  A lazy-greedy
heap pop over these per-node slot chains is an *exact* emulation of the
sequential argmax loop, including its lowest-index tie-breaking, at
O(n + pods·log n) instead of O(pods·n).
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Iterable, List, Optional

import numpy as np

NEG_INF = float(np.finfo(np.float32).min)


@dataclasses.dataclass(frozen=True)
class ScoreWeights:
    used: float = 0.0
    fit: float = 0.0
    group: float = 0.0
    topo: float = 0.0

    def as_array(self) -> np.ndarray:
        return np.asarray([self.used, self.fit, self.group, self.topo],
                          dtype=np.float32)


def combine_weights(weights: "Iterable[ScoreWeights]") -> ScoreWeights:
    """Sum per-term weights contributed by a Score plugin chain into the
    single weight vector of the fused filter+score pass."""
    used = fit = group = topo = 0.0
    for w in weights:
        used += w.used
        fit += w.fit
        group += w.group
        topo += w.topo
    return ScoreWeights(used=used, fit=fit, group=group, topo=topo)


BINPACK = ScoreWeights(used=1.0, fit=0.5, group=0.0, topo=0.0)
E_BINPACK = ScoreWeights(used=1.0, fit=0.5, group=0.75, topo=1.5)
SPREAD = ScoreWeights(used=-1.0, fit=0.0, group=0.0, topo=0.0)
E_SPREAD = ScoreWeights(used=-1.0, fit=0.0, group=-0.25, topo=0.0)


def node_scores_np(free: np.ndarray, used: np.ndarray, mask: np.ndarray,
                   group_load: np.ndarray, topo_pref: np.ndarray,
                   request: int, gpus_per_node: int,
                   weights: ScoreWeights) -> np.ndarray:
    """Reference numpy implementation (semantics match the Pallas kernel)."""
    free = free.astype(np.float32)
    used = used.astype(np.float32)
    valid = mask & (free >= float(request))
    used_norm = used / float(gpus_per_node)
    exact_fit = (free == float(request)).astype(np.float32)
    score = (weights.used * used_norm
             + weights.fit * exact_fit
             + weights.group * group_load.astype(np.float32)
             + weights.topo * topo_pref.astype(np.float32))
    return np.where(valid, score, NEG_INF).astype(np.float32)


def compute_node_scores(free: np.ndarray, used: np.ndarray,
                        mask: np.ndarray, group_load: np.ndarray,
                        topo_pref: np.ndarray, request: int,
                        gpus_per_node: int, weights: ScoreWeights,
                        backend: str = "np") -> np.ndarray:
    """One API over the numpy reference and the jnp/Pallas kernels.

    ``backend`` is ``"np"`` (default — no jax import, what the simulator
    uses), ``"ref"`` (jnp oracle), ``"interpret"`` (Pallas interpreter,
    CPU) or ``"pallas"`` (compiled TPU kernel).  All return the same
    (n,) f32 score vector with ``-inf`` at invalid nodes.
    """
    if backend == "np":
        return node_scores_np(free, used, mask, group_load, topo_pref,
                              request, gpus_per_node, weights)
    from ..kernels.ops import node_scores  # deferred: keep np path jax-free
    return np.asarray(node_scores(
        free, used, mask.astype(np.int32), group_load, topo_pref,
        request=request, gpus_per_node=gpus_per_node, weights=weights,
        backend=backend))


def pod_slots_np(free: np.ndarray, scores: np.ndarray,
                 request: int) -> np.ndarray:
    """Capacity expansion: pod slots contributed by each scored node."""
    valid = scores > NEG_INF
    return np.where(valid, free // request, 0).astype(np.int64)


def select_gang_slots(scores: np.ndarray, free: np.ndarray, request: int,
                      n_pods: int, *, fit_weight: float = 0.0,
                      colocate_bonus: float = 0.0,
                      slots: Optional[np.ndarray] = None
                      ) -> Optional[List[int]]:
    """Capacity-aware top-k slot selection for a whole gang at once.

    ``scores`` is the fused filter+score output for the *snapshot* free
    counts (slot 0 of every node).  Returns the node index for each pod
    in placement order, or ``None`` when fewer than ``n_pods`` slots
    exist.  The heap holds exactly one entry per node — its current slot
    value — so each pop is the argmax the sequential loop would have
    taken (ties break toward the lower node index, matching
    ``np.argmax``).
    """
    free = np.asarray(free)
    if slots is None:
        slots = pod_slots_np(free, scores, request)
    if int(slots.sum()) < n_pods:
        return None
    cand = np.nonzero(slots > 0)[0]
    # At most n_pods distinct nodes are ever popped, and a node's FIRST
    # pop happens at its slot-0 value — which must then be >= the static
    # slot-0 value of every never-popped node.  So the selection can be
    # restricted to the top-n_pods candidates by (slot-0 value desc,
    # index asc) before building the heap; everything below that line is
    # unreachable.  argpartition keeps this O(n).
    if len(cand) > n_pods:
        vals = scores[cand]
        part = np.argpartition(-vals, n_pods - 1)[:n_pods]
        thresh = vals[part].min()
        above = np.nonzero(vals > thresh)[0]
        ties = np.nonzero(vals == thresh)[0][:n_pods - len(above)]
        cand = cand[np.sort(np.concatenate([above, ties]))]
    # Per-node slot chains.  base strips the slot-0 exact-fit term so it
    # can be re-added at whichever slot the fit actually moves to.
    sfree = free[cand].astype(np.int64)
    base = scores[cand].astype(np.float64)
    base = np.where(sfree == request, base - fit_weight, base)
    exact_slot = np.where(sfree % request == 0, sfree // request - 1, -1)
    cslots = slots[cand]

    def slot_value(c: int, p: int) -> float:
        v = base[c] + colocate_bonus * p
        if p == exact_slot[c]:
            v += fit_weight
        return v

    heap = list(zip((-np.where(sfree == request, base + fit_weight, base)
                     ).tolist(), cand.tolist(), range(len(cand))))
    heapq.heapify(heap)
    placed = [0] * len(cand)
    order: List[int] = []
    while len(order) < n_pods:
        _, i, c = heapq.heappop(heap)
        order.append(i)
        placed[c] += 1
        if placed[c] < cslots[c]:
            heapq.heappush(heap, (-slot_value(c, placed[c]), i, c))
    return order
