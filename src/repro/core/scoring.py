"""Node filter+score pass shared by RSCH, the jnp oracle and the Pallas
kernel.

For every candidate node the scheduler computes one fused score

    score[i] = valid[i] ? ( w_used  * used[i]/G
                          + w_fit   * exact_fit[i]
                          + w_group * group_load[i]
                          + w_topo  * topo_pref[i] )
             : -inf

where ``valid[i] = mask[i] & (free[i] >= request)``.  Sign conventions on
the weight vector select the strategy:

* **Binpack / E-Binpack** (§3.3.3): ``w_used > 0`` packs busy nodes first,
  ``w_fit`` rewards exact fits (leaves no fragment behind), ``w_group > 0``
  consolidates into already-busy NodeNetGroups (LeafGroup-level E-Binpack),
  ``w_topo > 0`` pulls pods of one job toward its anchor group.
* **Spread / E-Spread** (§3.3.4): ``w_used < 0`` prefers idle nodes.

This module is the *numpy* implementation used by the discrete-event
simulator (cheap per call); ``repro.kernels.ref`` is the jnp oracle and
``repro.kernels.node_score`` the Pallas TPU kernel.  All three are
asserted identical in ``tests/test_kernels.py``.
"""

from __future__ import annotations

import dataclasses

import numpy as np

NEG_INF = float(np.finfo(np.float32).min)


@dataclasses.dataclass(frozen=True)
class ScoreWeights:
    used: float = 0.0
    fit: float = 0.0
    group: float = 0.0
    topo: float = 0.0

    def as_array(self) -> np.ndarray:
        return np.asarray([self.used, self.fit, self.group, self.topo],
                          dtype=np.float32)


BINPACK = ScoreWeights(used=1.0, fit=0.5, group=0.0, topo=0.0)
E_BINPACK = ScoreWeights(used=1.0, fit=0.5, group=0.75, topo=1.5)
SPREAD = ScoreWeights(used=-1.0, fit=0.0, group=0.0, topo=0.0)
E_SPREAD = ScoreWeights(used=-1.0, fit=0.0, group=-0.25, topo=0.0)


def node_scores_np(free: np.ndarray, used: np.ndarray, mask: np.ndarray,
                   group_load: np.ndarray, topo_pref: np.ndarray,
                   request: int, gpus_per_node: int,
                   weights: ScoreWeights) -> np.ndarray:
    """Reference numpy implementation (semantics match the Pallas kernel)."""
    free = free.astype(np.float32)
    used = used.astype(np.float32)
    valid = mask & (free >= float(request))
    used_norm = used / float(gpus_per_node)
    exact_fit = (free == float(request)).astype(np.float32)
    score = (weights.used * used_norm
             + weights.fit * exact_fit
             + weights.group * group_load.astype(np.float32)
             + weights.topo * topo_pref.astype(np.float32))
    return np.where(valid, score, NEG_INF).astype(np.float32)
