"""Node filter+score pass shared by RSCH, the jnp oracle and the Pallas
kernel, plus the batched gang-placement slot selection built on top of it.

For every candidate node the scheduler computes one fused score

    score[i] = valid[i] ? ( w_used  * used[i]/G
                          + w_fit   * exact_fit[i]
                          + w_group * group_load[i]
                          + w_topo  * topo_pref[i] )
             : -inf

where ``valid[i] = mask[i] & (free[i] >= request)``.  Sign conventions on
the weight vector select the strategy:

* **Binpack / E-Binpack** (§3.3.3): ``w_used > 0`` packs busy nodes first,
  ``w_fit`` rewards exact fits (leaves no fragment behind), ``w_group > 0``
  consolidates into already-busy NodeNetGroups (LeafGroup-level E-Binpack),
  ``w_topo > 0`` pulls pods of one job toward its anchor group.
* **Spread / E-Spread** (§3.3.4): ``w_used < 0`` prefers idle nodes.

This module is the *numpy* implementation used by the discrete-event
simulator (cheap per call); ``repro.kernels.ref`` is the jnp oracle and
``repro.kernels.node_score`` the Pallas TPU kernel.  All three are
asserted identical in ``tests/test_kernels.py``.
:func:`compute_node_scores` is the single entry point that dispatches
between them, so RSCH can switch backends via config.

**Batched gang placement** (§3.4 search-space reduction): instead of
re-running the full score pass once per pod, a gang job is placed with
ONE fused pass.  Each valid node is expanded into
``floor(free / gpus_per_pod)`` pod *slots*; the value of node ``i``'s
``p``-th slot reproduces what the sequential per-pod rescoring loop
would have seen at the step that consumed it:

    slot(i, p) = base[i] + colocate_bonus * p
               + w_fit * [free[i] - p*request == request]

(the co-location bonus and the moving exact-fit term are the only parts
of the score that depend on earlier pods of the same job — ``used``,
``group_load`` and ``topo_pref`` are snapshot-static).  A lazy-greedy
heap pop over these per-node slot chains is an *exact* emulation of the
sequential argmax loop, including its lowest-index tie-breaking, at
O(n + pods·log n) instead of O(pods·n).
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Iterable, List, Optional

import numpy as np

NEG_INF = float(np.finfo(np.float32).min)


@dataclasses.dataclass(frozen=True)
class ScoreWeights:
    used: float = 0.0
    fit: float = 0.0
    group: float = 0.0
    topo: float = 0.0

    def as_array(self) -> np.ndarray:
        return np.asarray([self.used, self.fit, self.group, self.topo],
                          dtype=np.float32)


def combine_weights(weights: "Iterable[ScoreWeights]") -> ScoreWeights:
    """Sum per-term weights contributed by a Score plugin chain into the
    single weight vector of the fused filter+score pass."""
    used = fit = group = topo = 0.0
    for w in weights:
        used += w.used
        fit += w.fit
        group += w.group
        topo += w.topo
    return ScoreWeights(used=used, fit=fit, group=group, topo=topo)


BINPACK = ScoreWeights(used=1.0, fit=0.5, group=0.0, topo=0.0)
E_BINPACK = ScoreWeights(used=1.0, fit=0.5, group=0.75, topo=1.5)
SPREAD = ScoreWeights(used=-1.0, fit=0.0, group=0.0, topo=0.0)
E_SPREAD = ScoreWeights(used=-1.0, fit=0.0, group=-0.25, topo=0.0)


def node_scores_np(free: np.ndarray, used: np.ndarray, mask: np.ndarray,
                   group_load: np.ndarray, topo_pref: np.ndarray,
                   request: int, gpus_per_node: int,
                   weights: ScoreWeights) -> np.ndarray:
    """Reference numpy implementation (semantics match the Pallas kernel)."""
    free = free.astype(np.float32)
    used = used.astype(np.float32)
    valid = mask & (free >= float(request))
    used_norm = used / float(gpus_per_node)
    exact_fit = (free == float(request)).astype(np.float32)
    score = (weights.used * used_norm
             + weights.fit * exact_fit
             + weights.group * group_load.astype(np.float32)
             + weights.topo * topo_pref.astype(np.float32))
    return np.where(valid, score, NEG_INF).astype(np.float32)


def compute_node_scores(free: np.ndarray, used: np.ndarray,
                        mask: np.ndarray, group_load: np.ndarray,
                        topo_pref: np.ndarray, request: int,
                        gpus_per_node: int, weights: ScoreWeights,
                        backend: str = "np") -> np.ndarray:
    """One API over the numpy reference and the jnp/Pallas kernels.

    ``backend`` is ``"np"`` (default — no jax import, what the simulator
    uses), ``"ref"`` (jnp oracle), ``"interpret"`` (Pallas interpreter,
    CPU) or ``"pallas"`` (compiled TPU kernel).  All return the same
    (n,) f32 score vector with ``-inf`` at invalid nodes.
    """
    if backend == "np":
        return node_scores_np(free, used, mask, group_load, topo_pref,
                              request, gpus_per_node, weights)
    from ..kernels.ops import node_scores  # deferred: keep np path jax-free
    return np.asarray(node_scores(
        free, used, mask.astype(np.int32), group_load, topo_pref,
        request=request, gpus_per_node=gpus_per_node, weights=weights,
        backend=backend))


def pod_slots_np(free: np.ndarray, scores: np.ndarray,
                 request: int) -> np.ndarray:
    """Capacity expansion: pod slots contributed by each scored node."""
    valid = scores > NEG_INF
    return np.where(valid, free // request, 0).astype(np.int64)


def _prefilter_np(scores: np.ndarray, slots: np.ndarray,
                  n_pods: int) -> np.ndarray:
    """Restrict slot selection to the top-``n_pods`` candidate nodes.

    At most ``n_pods`` distinct nodes are ever popped, and a node's
    FIRST pop happens at its slot-0 value — which must then be ≥ the
    static slot-0 value of every never-popped node.  So the selection
    can be restricted to the top-``n_pods`` candidates by (slot-0 value
    desc, index asc); everything below that line is unreachable.
    ``argpartition`` keeps this O(n).  Returns candidate node indices in
    ascending order.
    """
    cand = np.nonzero(slots > 0)[0]
    if len(cand) > n_pods:
        vals = scores[cand]
        part = np.argpartition(-vals, n_pods - 1)[:n_pods]
        thresh = vals[part].min()
        above = np.nonzero(vals > thresh)[0]
        ties = np.nonzero(vals == thresh)[0][:n_pods - len(above)]
        cand = cand[np.sort(np.concatenate([above, ties]))]
    return cand


def chains_nondecreasing(fit_weight: float, colocate_bonus: float) -> bool:
    """True when every node's slot-value chain is nondecreasing in the
    slot index — the precondition for the vectorized top-k engine.

    ``slot(i, p) = base[i] + colocate_bonus·p (+ fit_weight at the last
    slot when free is an exact multiple of request)``, so consecutive
    deltas are ``colocate_bonus`` everywhere except into the final
    exact-fit slot, where the delta is ``colocate_bonus + fit_weight``.
    Builtin profiles satisfy both (bonus 2.0, fit ≥ 0); plugins may
    contribute negative weights, in which case the heap engine is used.
    """
    return colocate_bonus >= 0.0 and colocate_bonus + fit_weight >= 0.0


def emit_slot_chains(cand: np.ndarray, scores: np.ndarray,
                     free: np.ndarray, slots: np.ndarray, request: int,
                     n_pods: int, fit_weight: float,
                     colocate_bonus: float) -> List[int]:
    """Exact f64 epilogue shared by the numpy and kernel top-k paths.

    With nondecreasing chains (:func:`chains_nondecreasing`) the lazy
    heap provably emits each popped node's ENTIRE chain consecutively:
    once node ``c`` wins a pop, its next slot value is ≥ its slot-0
    value, which in turn beats (strictly, or by the lower-index tie
    rule) every never-popped node's slot-0 value.  Heap order therefore
    collapses to: sort candidates by (slot-0 value desc, index asc),
    concatenate full chains, truncate at ``n_pods``.

    Float exactness: slot-0 values replicate the heap's arithmetic
    bit-for-bit — f64 base with the exact-fit weight subtracted and
    re-added (NOT algebraically simplified, since ``(x − w) + w ≠ x``
    in floats).  ``np.argsort(kind="stable")`` over an ascending
    candidate array preserves the heap's lowest-index tie-breaking.
    """
    cand = np.sort(np.asarray(cand, dtype=np.int64))
    sfree = free[cand].astype(np.int64)
    base = scores[cand].astype(np.float64)
    exact0 = sfree == request
    base = np.where(exact0, base - fit_weight, base)
    s0 = np.where(exact0, base + fit_weight, base)
    order = np.argsort(-s0, kind="stable")
    counts = np.asarray(slots, dtype=np.int64)[cand][order]
    return np.repeat(cand[order], counts)[:n_pods].tolist()


def select_gang_slots(scores: np.ndarray, free: np.ndarray, request: int,
                      n_pods: int, *, fit_weight: float = 0.0,
                      colocate_bonus: float = 0.0,
                      slots: Optional[np.ndarray] = None,
                      engine: str = "heap"
                      ) -> Optional[List[int]]:
    """Capacity-aware top-k slot selection for a whole gang at once.

    ``scores`` is the fused filter+score output for the *snapshot* free
    counts (slot 0 of every node).  Returns the node index for each pod
    in placement order, or ``None`` when fewer than ``n_pods`` slots
    exist.

    ``engine`` selects the implementation — all exact-identical:

    * ``"heap"`` — the lazy-greedy heap pop (the A/B oracle).  One
      entry per node, so each pop is the argmax the sequential loop
      would have taken (ties break toward the lower node index,
      matching ``np.argmax``).
    * ``"topk"`` — vectorized sort + chain emission
      (:func:`emit_slot_chains`), O(k log k) after an O(n) prefilter
      with no Python loop.
    * ``"topk_kernel"`` — same epilogue behind a ``jax.lax.top_k``
      prefilter (``repro.kernels.ops.gang_slot_prefilter``).

    The vectorized engines require nondecreasing slot chains; when
    plugin weights violate that (:func:`chains_nondecreasing`), they
    fall back to the heap automatically.
    """
    free = np.asarray(free)
    if slots is None:
        slots = pod_slots_np(free, scores, request)
    if int(slots.sum()) < n_pods:
        return None
    if engine != "heap" and chains_nondecreasing(fit_weight,
                                                 colocate_bonus):
        if engine == "topk_kernel":
            from ..kernels.ops import gang_slot_prefilter  # deferred
            cand = gang_slot_prefilter(scores, slots, n_pods)
        else:
            cand = _prefilter_np(scores, slots, n_pods)
        return emit_slot_chains(cand, scores, free, slots, request,
                                n_pods, fit_weight, colocate_bonus)
    cand = _prefilter_np(scores, slots, n_pods)
    # Per-node slot chains.  base strips the slot-0 exact-fit term so it
    # can be re-added at whichever slot the fit actually moves to.
    sfree = free[cand].astype(np.int64)
    base = scores[cand].astype(np.float64)
    base = np.where(sfree == request, base - fit_weight, base)
    exact_slot = np.where(sfree % request == 0, sfree // request - 1, -1)
    cslots = slots[cand]

    def slot_value(c: int, p: int) -> float:
        v = base[c] + colocate_bonus * p
        if p == exact_slot[c]:
            v += fit_weight
        return v

    heap = list(zip((-np.where(sfree == request, base + fit_weight, base)
                     ).tolist(), cand.tolist(), range(len(cand))))
    heapq.heapify(heap)
    placed = [0] * len(cand)
    order: List[int] = []
    while len(order) < n_pods:
        _, i, c = heapq.heappop(heap)
        order.append(i)
        placed[c] += 1
        if placed[c] < cslots[c]:
            heapq.heappush(heap, (-slot_value(c, placed[c]), i, c))
    return order
