"""Pluggable scheduling framework: extension points + per-workload profiles.

Kube-scheduler-style plugin API for QSCH/RSCH (paper §3.2-§3.4): queue
policies, admission, vectorized node filtering/scoring, transactional
gang commit and preemption are all named extension points; a
:class:`SchedulingProfile` bundles one plugin chain per point and a
:class:`ProfileSet` selects a profile per workload kind
(train / inference / best-effort).

* :mod:`repro.core.framework.api`      — plugin base classes + profiles;
* :mod:`repro.core.framework.registry` — name -> plugin factory registry;
* :mod:`repro.core.framework.builtin`  — the paper's behaviors as plugins
  plus the default train/inference/best-effort profiles;
* :mod:`repro.core.framework.contrib`  — beyond-paper example plugins
  (GFR-aware fragmentation score, tenant and semantic soft-affinity).

See ``docs/plugins.md`` for the extension-point contract and a worked
"write your own Score plugin" example.
"""

from .api import (AdmitPlugin, ClusterSelectPlugin, ControllerPlugin,
                  CycleContext, CycleResult, DynamicsPlugin,
                  ElasticPolicyPlugin, FilterPlugin, ObserverPlugin,
                  PermitPlugin, PlacementPass, Plugin, PostBindPlugin,
                  PreemptPlugin, ProfileSet, QueuePolicyPlugin,
                  QueueSortPlugin, ReservePlugin, RouterPolicyPlugin,
                  SchedulingContext, SchedulingProfile, ScorePlugin,
                  obs_phase, single_pass_plan)
from .builtin import (BackfillHeadTimeout, BackfillPolicy,
                      BestEffortFIFOPolicy, BinpackScore, ColocateBonus,
                      DefaultQueueSort, DynamicFeasibility, GpuTypeFilter,
                      GroupConsolidation, HealthFilter, PriorityPreempt,
                      QuotaAdmit, QuotaReclaimPreempt, QuotaReserve,
                      SpreadScore, StrictFIFOPolicy, TopoAnchor,
                      WeightSetScore, binpack_pass, default_profiles,
                      ebinpack_pass, espread_plan, espread_zone_pass,
                      make_profile, spread_pass)
from .contrib import (GfrAwareScore, SemanticSoftAffinity,
                      TenantSoftAffinity, token_similarity)
from .registry import available_plugins, create_plugin, register

__all__ = [
    # api
    "Plugin", "QueueSortPlugin", "AdmitPlugin", "FilterPlugin",
    "ScorePlugin", "ReservePlugin", "PermitPlugin", "PostBindPlugin",
    "PreemptPlugin", "QueuePolicyPlugin", "DynamicsPlugin",
    "ClusterSelectPlugin", "RouterPolicyPlugin", "ElasticPolicyPlugin",
    "ObserverPlugin", "ControllerPlugin", "PlacementPass",
    "SchedulingProfile", "ProfileSet", "SchedulingContext", "CycleContext",
    "CycleResult", "single_pass_plan", "obs_phase",
    # registry
    "register", "create_plugin", "available_plugins",
    # builtin
    "DefaultQueueSort", "QuotaAdmit", "DynamicFeasibility", "GpuTypeFilter",
    "HealthFilter", "WeightSetScore", "BinpackScore", "SpreadScore",
    "GroupConsolidation", "TopoAnchor", "ColocateBonus", "QuotaReserve",
    "PriorityPreempt", "QuotaReclaimPreempt", "BackfillHeadTimeout",
    "StrictFIFOPolicy", "BestEffortFIFOPolicy", "BackfillPolicy",
    "binpack_pass", "spread_pass", "ebinpack_pass", "espread_zone_pass",
    "espread_plan", "make_profile", "default_profiles",
    # contrib
    "GfrAwareScore", "TenantSoftAffinity", "SemanticSoftAffinity",
    "token_similarity",
]
