"""Plugin registry: name -> factory.

Built-in and contrib plugins self-register at import; out-of-tree code
registers with the same decorator, then profiles can be assembled from
names (useful for config-driven profile construction)::

    from repro.core.framework import register, create_plugin

    @register
    class MyScore(ScorePlugin):
        name = "MyScore"
        ...

    plugin = create_plugin("MyScore", weight=2.0)
"""

from __future__ import annotations

import difflib
from typing import Callable, Dict, List, Type

from .api import Plugin

_REGISTRY: Dict[str, Callable[..., Plugin]] = {}


def register(cls: Type[Plugin]) -> Type[Plugin]:
    """Class decorator: register a plugin type under its ``name``."""
    name = getattr(cls, "name", None) or cls.__name__
    existing = _REGISTRY.get(name)
    if existing is not None and existing is not cls:
        raise ValueError(
            f"plugin name {name!r} already registered by {existing!r}")
    _REGISTRY[name] = cls
    return cls


def create_plugin(name: str, **params) -> Plugin:
    """Instantiate a registered plugin by name.

    Unknown names raise :class:`KeyError` (kept for backward
    compatibility) whose message lists the sorted registered names plus
    the closest matches to the requested one — a typo like
    ``"BinPackScore"`` points straight at ``"BinpackScore"``.
    """
    try:
        factory = _REGISTRY[name]
    except KeyError:
        names = available_plugins()
        close = difflib.get_close_matches(name, names, n=3, cutoff=0.6)
        hint = f" (did you mean {close}?)" if close else ""
        raise KeyError(f"unknown plugin {name!r}{hint}; "
                       f"registered: {names}") from None
    return factory(**params)


def available_plugins() -> List[str]:
    return sorted(_REGISTRY)
