"""The paper's scheduler behaviors, shipped as built-in plugins.

Everything QSCH/RSCH did before the framework refactor is expressed
here: queue ordering, two-tier admission, node-pool filtering, the four
strategy weight-sets (Binpack / E-Binpack / Spread / E-Spread decomposed
into BinpackScore/SpreadScore + GroupConsolidation + TopoAnchor),
same-node co-location, quota reservation, the three preemption policies
and the three Table-1 queue policies.  ``default_profiles()`` assembles
them into the train / inference / best-effort profiles that are
placement-identical to the legacy ``Strategy``/``QueuePolicy`` enums.
"""

from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import numpy as np

from ..job import Job, JobKind, JobState, Placement
from ..scoring import ScoreWeights
from ..snapshot import Snapshot
from .api import (AdmitPlugin, CycleContext, FilterPlugin, PermitPlugin,
                  PlacementPass, PlanFn, PostBindPlugin, PreemptPlugin,
                  ProfileSet, QueuePolicyPlugin, QueueSortPlugin,
                  ReservePlugin, SchedulingProfile, ScorePlugin,
                  single_pass_plan)
from .registry import register


# ----------------------------------------------------------------------
# QueueSort
# ----------------------------------------------------------------------
@register
class DefaultQueueSort(QueueSortPlugin):
    """§3.2.2 ordering: priority desc, submit time, size, uid."""

    name = "DefaultQueueSort"

    def key(self, job: Job) -> Tuple:
        return job.order_key()


# ----------------------------------------------------------------------
# Admit
# ----------------------------------------------------------------------
@register
class QuotaAdmit(AdmitPlugin):
    """Static quota admission (§3.2.1): tenant quota, borrow-aware."""

    name = "QuotaAdmit"
    stage = "static"

    def admit(self, job: Job, ctx: CycleContext) -> bool:
        return ctx.quota.can_admit(job)


@register
class DynamicFeasibility(AdmitPlugin):
    """Dynamic resource admission (§3.2.1): enough free healthy GPUs in
    the job's node pool on the working snapshot."""

    name = "DynamicFeasibility"
    stage = "dynamic"

    def admit(self, job: Job, ctx: CycleContext) -> bool:
        return ctx.rsch.feasible(job, ctx.snap)


# ----------------------------------------------------------------------
# Filter
# ----------------------------------------------------------------------
@register
class GpuTypeFilter(FilterPlugin):
    """GPU-Type-based node pool membership (§3.4.1)."""

    name = "GpuTypeFilter"

    def mask(self, job: Job, snap: Snapshot,
             zone: Optional[str]) -> np.ndarray:
        return snap.gpu_type == job.gpu_type


@register
class HealthFilter(FilterPlugin):
    """Only schedulable (healthy) nodes."""

    name = "HealthFilter"

    def mask(self, job: Job, snap: Snapshot,
             zone: Optional[str]) -> np.ndarray:
        return snap.node_healthy

#: When a pass's filter chain is exactly this pair, the engine resolves
#: it through the snapshot's cached ``candidate_pool`` mask instead of
#: two O(n) boolean passes per schedule call (§3.4.1 fast path).
DEFAULT_FILTERS: Tuple[FilterPlugin, ...] = (GpuTypeFilter(),
                                             HealthFilter())


# ----------------------------------------------------------------------
# Score
# ----------------------------------------------------------------------
class WeightSetScore(ScorePlugin):
    """Snapshot-static weights folded into the fused filter+score pass."""

    def __init__(self, weights: ScoreWeights) -> None:
        self.weights = weights

    def fused_weights(self, job: Job) -> ScoreWeights:
        return self.weights


@register
class BinpackScore(WeightSetScore):
    """Node-level binpack (§3.3.3): pack busy nodes, reward exact fits."""

    name = "BinpackScore"

    def __init__(self, used: float = 1.0, fit: float = 0.5) -> None:
        super().__init__(ScoreWeights(used=used, fit=fit))


@register
class SpreadScore(WeightSetScore):
    """Spread (§3.3.4): prefer idle nodes."""

    name = "SpreadScore"

    def __init__(self, used: float = -1.0) -> None:
        super().__init__(ScoreWeights(used=used))


@register
class GroupConsolidation(WeightSetScore):
    """LeafGroup-level load term (§3.3.3): positive weight consolidates
    into busy NodeNetGroups (E-Binpack), negative spreads (E-Spread)."""

    name = "GroupConsolidation"

    def __init__(self, weight: float = 0.75) -> None:
        super().__init__(ScoreWeights(group=weight))


@register
class TopoAnchor(WeightSetScore):
    """Anchor-group preference (§3.3.5): pulls pods of one job toward
    its best-ranked NodeNetGroups (fewest groups, same spine)."""

    name = "TopoAnchor"

    def __init__(self, weight: float = 1.5) -> None:
        super().__init__(ScoreWeights(topo=weight))


@register
class ColocateBonus(ScorePlugin):
    """Pod-dependent same-node co-location bonus (node-level E-Binpack,
    §3.3.3): each pod of the job already on a node makes that node more
    attractive for the next pod.  Folded into the batched slot chains."""

    name = "ColocateBonus"
    pod_dependent = True

    def __init__(self, bonus: float = 2.0) -> None:
        self.bonus = bonus

    def per_pod_bonus(self, job: Job) -> float:
        return self.bonus


# ----------------------------------------------------------------------
# Reserve
# ----------------------------------------------------------------------
@register
class QuotaReserve(ReservePlugin):
    """Transactional quota charge for the gang commit (§3.2.1/§3.3.2)."""

    name = "QuotaReserve"

    def reserve(self, job: Job, placement: Placement,
                ctx: CycleContext) -> bool:
        ctx.quota.charge(job)
        return True

    def unreserve(self, job: Job, placement: Placement,
                  ctx: CycleContext) -> None:
        ctx.quota.refund(job)


# ----------------------------------------------------------------------
# Preempt (§3.2.3) — three policies, one conservative engine
# ----------------------------------------------------------------------
@register
class PriorityPreempt(PreemptPlugin):
    """Priority preemption: strictly-lower-priority preemptible work in
    the blocked job's node pool."""

    name = "PriorityPreempt"

    def victims(self, job: Job, ctx: CycleContext) -> List[Job]:
        return [j for j in ctx.running.values()
                if j.priority < job.priority and j.preemptible
                and j.gpu_type == job.gpu_type]


@register
class QuotaReclaimPreempt(PreemptPlugin):
    """Quota-reclamation preemption: shared-mode borrowers whose loan
    blocks the owner's own quota."""

    name = "QuotaReclaimPreempt"

    def victims(self, job: Job, ctx: CycleContext) -> List[Job]:
        return ctx.quota.reclaim_candidates(
            job.tenant, job.gpu_type, list(ctx.running.values()))


@register
class BackfillHeadTimeout(PreemptPlugin):
    """Backfill preemption: a head blocked past its timeout evicts
    backfilled jobs (newest first) — but only when the dry-run shows the
    head can actually become schedulable (conservative policy)."""

    name = "BackfillHeadTimeout"

    def victims(self, head: Job, ctx: CycleContext) -> List[Job]:
        v = [j for j in ctx.running.values()
             if j.backfilled and j.preemptible
             and j.gpu_type == head.gpu_type]
        v.sort(key=lambda j: -(j.start_time or 0.0))
        return v

    def execute(self, head: Job, ctx: CycleContext) -> None:
        if not ctx.sched.structurally_placeable(head, ctx):
            return  # no eviction set can ever make the head fit
        victims = self.victims(head, ctx)
        pool_free = ctx.state.pool_free(head.gpu_type)
        reclaimable = sum(v.n_gpus for v in victims)
        if pool_free + reclaimable < head.n_gpus:
            return  # preemption cannot help; don't thrash
        budget = ctx.sched.config.max_preemptions_per_cycle
        for victim in victims:
            if budget <= 0:
                break
            if ctx.sched.dynamic_admit(head, ctx) and \
                    ctx.rsch.schedule(head, ctx.snap,
                                      ctx).placement is not None:
                return
            ctx.sched.preempt_job(victim, ctx)
            budget -= 1


# ----------------------------------------------------------------------
# QueuePolicy (Table 1)
# ----------------------------------------------------------------------
@register
class StrictFIFOPolicy(QueuePolicyPlugin):
    """Strict FIFO: one blocked head blocks everyone."""

    name = "StrictFIFO"
    strict_head = True

    def run_cycle(self, queue: List[Job], ctx: CycleContext) -> None:
        for job in queue:
            if not ctx.sched.try_place(job, ctx):
                ctx.result.blocked_head = job
                return


@register
class BestEffortFIFOPolicy(QueuePolicyPlugin):
    """Best-Effort FIFO: skip unschedulable jobs.  Deliberately leaves
    ``blocked_head`` unset -> no preemption assist, which is what
    starves large jobs in the paper's Fig 4."""

    name = "BestEffortFIFO"

    def run_cycle(self, queue: List[Job], ctx: CycleContext) -> None:
        for job in queue:
            ctx.sched.try_place(job, ctx)


@register
class BackfillPolicy(QueuePolicyPlugin):
    """Backfill: smaller jobs run behind a blocked head; after
    ``head_timeout`` seconds the head preempts them (via the
    BackfillHeadTimeout Preempt plugin)."""

    name = "Backfill"

    def __init__(self, head_timeout: float = 1800.0,
                 preempt: Optional[PreemptPlugin] = None) -> None:
        self.head_timeout = head_timeout
        self.preempt = preempt or BackfillHeadTimeout()

    def run_cycle(self, queue: List[Job], ctx: CycleContext) -> None:
        sched = ctx.sched
        head = queue[0]
        if sched.try_place(head, ctx):
            sched.head_blocked_since.pop(head.uid, None)
        else:
            blocked_since = sched.head_blocked_since.setdefault(
                head.uid, ctx.now)
            if ctx.now - blocked_since >= self.head_timeout:
                # Stamp the eviction source so preempt_job's audit
                # record names this plugin and its beneficiary.
                sched._preempt_source = (self.preempt.name, head.uid)
                try:
                    self.preempt.execute(head, ctx)
                finally:
                    sched._preempt_source = None
                if sched.try_place(head, ctx):
                    sched.head_blocked_since.pop(head.uid, None)
                else:
                    ctx.result.blocked_head = head
            else:
                ctx.result.blocked_head = head
        # Backfill pass: later jobs may use idle resources now.
        for job in queue[1:]:
            if job.state is not JobState.PENDING:
                continue
            sched.try_place(job, ctx,
                            backfilled=ctx.result.blocked_head is not None)


# ----------------------------------------------------------------------
# Pass/plan/profile builders
# ----------------------------------------------------------------------
def binpack_pass(zone: Optional[str] = None) -> PlacementPass:
    """Plain node-level Binpack (§3.3.3)."""
    return PlacementPass(scorers=(BinpackScore(),), zone=zone)


def spread_pass(zone: Optional[str] = None) -> PlacementPass:
    """Plain Spread (§3.3.4)."""
    return PlacementPass(scorers=(SpreadScore(),), spread=True, zone=zone)


def ebinpack_pass(colocate: float = 0.0, zone: Optional[str] = None,
                  extra_scorers: Sequence[ScorePlugin] = ()
                  ) -> PlacementPass:
    """E-Binpack (§3.3.3): node binpack + group consolidation + anchor
    preference, optionally with the same-node co-location bonus."""
    scorers: Tuple[ScorePlugin, ...] = (
        BinpackScore(), GroupConsolidation(0.75), TopoAnchor(1.5))
    if colocate:
        scorers += (ColocateBonus(colocate),)
    return PlacementPass(scorers=scorers + tuple(extra_scorers),
                         enhanced=True, zone=zone)


def espread_zone_pass(extra_scorers: Sequence[ScorePlugin] = ()
                      ) -> PlacementPass:
    """E-Spread inside the inference dedicated zone (§3.3.4)."""
    scorers: Tuple[ScorePlugin, ...] = (SpreadScore(),
                                        GroupConsolidation(-0.25))
    return PlacementPass(scorers=scorers + tuple(extra_scorers),
                         spread=True, enhanced=True, zone="zone")


def espread_plan(small_pod_gpus: int = 8, colocate: float = 0.0,
                 extra_scorers: Sequence[ScorePlugin] = ()) -> PlanFn:
    """The §3.3.4 E-Spread dance as an ordered pass plan:

    * small inference pods go to the dedicated zone, remaining replicas
      E-Binpack in the general pool;
    * everything else E-Binpacks in the general pool first (keeping the
      zone for small replicas), falling back to the whole pool;
    * with no zone configured, E-Binpack over the whole pool.
    """
    zone_p = espread_zone_pass(extra_scorers)
    general_zone = ebinpack_pass(colocate, zone="general",
                                 extra_scorers=extra_scorers)
    general = ebinpack_pass(colocate, zone=None,
                            extra_scorers=extra_scorers)

    def plan(job: Job, snap: Snapshot) -> Sequence[PlacementPass]:
        has_zone = bool(snap.inference_zone.any())
        if (job.kind is JobKind.INFER
                and job.gpus_per_pod < small_pod_gpus and has_zone):
            return (zone_p, general_zone)
        if has_zone:
            return (general_zone, general)
        return (general,)

    return plan


def make_profile(name: str, plan: PlanFn, *,
                 queue_sort: Optional[QueueSortPlugin] = None,
                 admit: Optional[Sequence[AdmitPlugin]] = None,
                 filters: Optional[Sequence[FilterPlugin]] = None,
                 reserve: Optional[Sequence[ReservePlugin]] = None,
                 permit: Sequence[PermitPlugin] = (),
                 post_bind: Sequence[PostBindPlugin] = (),
                 preempt: Optional[Sequence[PreemptPlugin]] = None
                 ) -> SchedulingProfile:
    """A profile with the paper's default chains wherever not given."""
    return SchedulingProfile(
        name=name,
        plan=plan,
        queue_sort=queue_sort or DefaultQueueSort(),
        admit=tuple(admit) if admit is not None
        else (QuotaAdmit(), DynamicFeasibility()),
        filters=tuple(filters) if filters is not None else DEFAULT_FILTERS,
        reserve=tuple(reserve) if reserve is not None else (QuotaReserve(),),
        permit=tuple(permit),
        post_bind=tuple(post_bind),
        preempt=tuple(preempt) if preempt is not None
        else (PriorityPreempt(), QuotaReclaimPreempt()),
    )


def default_profiles(colocate: float = 2.0, small_pod_gpus: int = 8
                     ) -> ProfileSet:
    """Kant's defaults: E-Binpack training, E-Spread inference, and a
    best-effort (debug) profile that places like training."""
    return ProfileSet(
        train=make_profile(
            "train-e-binpack", single_pass_plan(ebinpack_pass(colocate))),
        inference=make_profile(
            "inference-e-spread", espread_plan(small_pod_gpus)),
        best_effort=make_profile(
            "best-effort-e-binpack",
            single_pass_plan(ebinpack_pass(colocate))),
    )
