"""Beyond-paper example plugins proving the framework's extensibility.

Each of these lands as a ~50-line Score plugin instead of a scheduler
fork; both are exercised end-to-end in ``examples/custom_plugins.py``
and compared in ``benchmarks/plugin_bench.py``.

* :class:`GfrAwareScore` — multi-objective fragmentation-aware scoring
  in the spirit of "Reducing Fragmentation and Starvation in GPU
  Clusters through Dynamic Multi-Objective Scheduling": score nodes by
  the GFR delta (§4.3) their selection would cause.
* :class:`TenantSoftAffinity` — tenant-semantic soft affinity /
  anti-affinity in the spirit of "Cluster Workload Allocation: Semantic
  Soft Affinity": pull a tenant's pods toward NodeNetGroups it already
  occupies, optionally away from groups occupied by other tenants.
* :class:`SemanticSoftAffinity` — the NLP-affinity generalization of
  the same idea: group affinity graded by token-overlap similarity of
  job *descriptions* (``Job.metadata``), so "llama70b sft ads" pulls
  toward "llama70b dpo ads" even across tenants.
"""

from __future__ import annotations

from typing import Optional

import numpy as np

from ..job import Job
from ..snapshot import Snapshot
from ..topology import ClusterTopology  # noqa: F401 — constructor params
from .api import SchedulingContext, ScorePlugin
from .registry import register


@register
class GfrAwareScore(ScorePlugin):
    """Snapshot-static GFR-delta term (§4.3 fragmentation rate).

    A node is *fragmented* when it is neither fully idle nor fully
    occupied.  Placing one pod of ``gpus_per_pod`` GPUs:

    * on a fragmented node it exactly fills -> heals it (GFR -1): bonus;
    * on an idle node it does not fill   -> fragments it (GFR +1): malus;
    * anywhere else the fragmented-node count is unchanged: neutral.

    With a ``topology`` the same delta also steers Level-1 NodeNetGroup
    preselection (``group_score``): groups holding heal-able nodes
    outrank groups of untouched idle nodes — without this, a spread
    pass preselects the emptiest group and never sees the fragmented
    ones (the multi-objective spread-vs-fragmentation trade-off).
    """

    name = "GfrAwareScore"

    def __init__(self, weight: float = 1.0,
                 topology: Optional[ClusterTopology] = None) -> None:
        self.weight = weight
        self.topology = topology

    def _node_delta(self, job: Job, snap: Snapshot) -> np.ndarray:
        free = snap.free_gpus
        used = snap.used_gpus
        fills = free == job.gpus_per_pod
        heals = fills & (used > 0)                 # fragmented -> full
        fragments = (used == 0) & ~fills           # idle -> fragmented
        return (heals.astype(np.float32)
                - fragments.astype(np.float32))

    def score(self, job: Job, snap: Snapshot, pool: np.ndarray,
              ctx: Optional[SchedulingContext]) -> np.ndarray:
        return self.weight * self._node_delta(job, snap)

    def group_score(self, job: Job, snap: Snapshot, pool: np.ndarray,
                    ctx: Optional[SchedulingContext]
                    ) -> Optional[np.ndarray]:
        if self.topology is None:
            return None
        topo = self.topology
        # Pool-masked: an out-of-pool (unhealthy / wrong-type / other
        # zone) healable node must not earn its group the top rank —
        # preselection would pin the job to a group it cannot use.
        delta = np.where(pool, self._node_delta(job, snap), 0.0)
        return self.weight * np.bincount(topo.leaf_id, weights=delta,
                                         minlength=topo.n_leaf_groups)


@register
class TenantSoftAffinity(ScorePlugin):
    """Tenant-semantic soft (anti-)affinity over NodeNetGroups.

    ``weight`` rewards LeafGroups already running pods of the job's
    tenant (keeps a tenant's traffic inside few groups);
    ``anti_weight`` penalizes groups running *other* tenants (soft
    isolation).  Soft: the terms bias group preselection
    (``group_score``) and node ranking (``score``), they never filter —
    a full cluster still schedules.

    Tenant occupancy is read from ``ctx.running`` (the QSCH running
    set); with no context the term vanishes.
    """

    name = "TenantSoftAffinity"

    def __init__(self, topology: ClusterTopology, weight: float = 1.0,
                 anti_weight: float = 0.0) -> None:
        self.topology = topology
        self.weight = weight
        self.anti_weight = anti_weight

    def _per_group(self, job: Job,
                   ctx: Optional[SchedulingContext]
                   ) -> Optional[np.ndarray]:
        running = getattr(ctx, "running", None)
        if not running:
            return None
        # One schedule call invokes this from group_score and score, per
        # pass; the occupancy scan is O(running pods) python, so reuse
        # the last result.  Occupancy is fully determined by the running
        # membership (placements of running jobs never mutate) and the
        # requesting tenant, so the key is exact — no id()-reuse or
        # same-length-different-members staleness.
        key = (job.tenant, tuple(running.keys()))
        cached = getattr(self, "_cache", None)
        if cached is not None and cached[0] == key:
            return cached[1]
        topo = self.topology
        own = np.zeros(topo.n_leaf_groups, dtype=np.float32)
        other = np.zeros(topo.n_leaf_groups, dtype=np.float32)
        for j in running.values():
            if j.placement is None:
                continue
            target = own if j.tenant == job.tenant else other
            for node in j.placement.nodes:
                target[int(topo.leaf_id[node])] = 1.0
        per_group = self.weight * own - self.anti_weight * other
        self._cache = (key, per_group)
        return per_group

    def group_score(self, job: Job, snap: Snapshot, pool: np.ndarray,
                    ctx: Optional[SchedulingContext]
                    ) -> Optional[np.ndarray]:
        return self._per_group(job, ctx)

    def score(self, job: Job, snap: Snapshot, pool: np.ndarray,
              ctx: Optional[SchedulingContext]) -> Optional[np.ndarray]:
        per_group = self._per_group(job, ctx)
        if per_group is None:
            return None
        return per_group[self.topology.leaf_id]


def _tokens(job: Job) -> frozenset:
    """Lower-cased token set of a job's description.  Jobs without
    ``metadata`` fall back to the tenant name, so the plugin degrades
    to tenant affinity on undescribed workloads."""
    text = job.metadata if job.metadata else job.tenant
    return frozenset(text.lower().split())


def token_similarity(a: frozenset, b: frozenset) -> float:
    """Jaccard similarity of two token sets (0.0 when either is empty)."""
    if not a or not b:
        return 0.0
    return len(a & b) / len(a | b)


@register
class SemanticSoftAffinity(ScorePlugin):
    """Semantic (NLP) soft affinity over NodeNetGroups.

    Generalizes :class:`TenantSoftAffinity` from the binary
    own-tenant/other-tenant split to a *graded* similarity: each
    LeafGroup is scored by the maximum Jaccard token overlap between
    the requesting job's description (:attr:`~repro.core.job.Job.
    metadata`, falling back to the tenant name) and the descriptions of
    the jobs already running there.  Workloads that talk about the same
    model/dataset/framework consolidate into the same network groups —
    across tenant boundaries — while unrelated work feels no pull.

    ``anti_weight`` optionally pushes away from groups whose resident
    similarity is *below* ``anti_threshold`` (soft isolation of
    unrelated workloads).  Like its parent it is purely a Score plugin:
    it biases preselection and ranking, never filters.
    """

    name = "SemanticSoftAffinity"

    def __init__(self, topology: ClusterTopology, weight: float = 1.0,
                 anti_weight: float = 0.0,
                 anti_threshold: float = 0.1) -> None:
        self.topology = topology
        self.weight = weight
        self.anti_weight = anti_weight
        self.anti_threshold = anti_threshold

    def _per_group(self, job: Job,
                   ctx: Optional[SchedulingContext]
                   ) -> Optional[np.ndarray]:
        running = getattr(ctx, "running", None)
        if not running:
            return None
        # Same exact-key memoization as TenantSoftAffinity: occupancy
        # and similarities are fully determined by the running
        # membership and the requesting job's token set.
        tokens = _tokens(job)
        key = (tokens, tuple(running.keys()))
        cached = getattr(self, "_cache", None)
        if cached is not None and cached[0] == key:
            return cached[1]
        topo = self.topology
        best = np.zeros(topo.n_leaf_groups, dtype=np.float32)
        occupied = np.zeros(topo.n_leaf_groups, dtype=bool)
        for j in running.values():
            if j.placement is None:
                continue
            sim = token_similarity(tokens, _tokens(j))
            for node in j.placement.nodes:
                g = int(topo.leaf_id[node])
                occupied[g] = True
                if sim > best[g]:
                    best[g] = sim
        per_group = self.weight * best
        if self.anti_weight:
            unrelated = occupied & (best < self.anti_threshold)
            per_group = per_group - self.anti_weight * \
                unrelated.astype(np.float32)
        self._cache = (key, per_group)
        return per_group

    def group_score(self, job: Job, snap: Snapshot, pool: np.ndarray,
                    ctx: Optional[SchedulingContext]
                    ) -> Optional[np.ndarray]:
        return self._per_group(job, ctx)

    def score(self, job: Job, snap: Snapshot, pool: np.ndarray,
              ctx: Optional[SchedulingContext]) -> Optional[np.ndarray]:
        per_group = self._per_group(job, ctx)
        if per_group is None:
            return None
        return per_group[self.topology.leaf_id]
