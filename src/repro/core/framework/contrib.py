"""Beyond-paper example plugins proving the framework's extensibility.

Each of these lands as a ~50-line Score plugin instead of a scheduler
fork; both are exercised end-to-end in ``examples/custom_plugins.py``
and compared in ``benchmarks/plugin_bench.py``.

* :class:`GfrAwareScore` — multi-objective fragmentation-aware scoring
  in the spirit of "Reducing Fragmentation and Starvation in GPU
  Clusters through Dynamic Multi-Objective Scheduling": score nodes by
  the GFR delta (§4.3) their selection would cause.
* :class:`TenantSoftAffinity` — tenant-semantic soft affinity /
  anti-affinity in the spirit of "Cluster Workload Allocation: Semantic
  Soft Affinity": pull a tenant's pods toward NodeNetGroups it already
  occupies, optionally away from groups occupied by other tenants.
"""

from __future__ import annotations

from typing import Optional

import numpy as np

from ..job import Job
from ..snapshot import Snapshot
from ..topology import ClusterTopology  # noqa: F401 — constructor params
from .api import SchedulingContext, ScorePlugin
from .registry import register


@register
class GfrAwareScore(ScorePlugin):
    """Snapshot-static GFR-delta term (§4.3 fragmentation rate).

    A node is *fragmented* when it is neither fully idle nor fully
    occupied.  Placing one pod of ``gpus_per_pod`` GPUs:

    * on a fragmented node it exactly fills -> heals it (GFR -1): bonus;
    * on an idle node it does not fill   -> fragments it (GFR +1): malus;
    * anywhere else the fragmented-node count is unchanged: neutral.

    With a ``topology`` the same delta also steers Level-1 NodeNetGroup
    preselection (``group_score``): groups holding heal-able nodes
    outrank groups of untouched idle nodes — without this, a spread
    pass preselects the emptiest group and never sees the fragmented
    ones (the multi-objective spread-vs-fragmentation trade-off).
    """

    name = "GfrAwareScore"

    def __init__(self, weight: float = 1.0,
                 topology: Optional[ClusterTopology] = None) -> None:
        self.weight = weight
        self.topology = topology

    def _node_delta(self, job: Job, snap: Snapshot) -> np.ndarray:
        free = snap.free_gpus
        used = snap.used_gpus
        fills = free == job.gpus_per_pod
        heals = fills & (used > 0)                 # fragmented -> full
        fragments = (used == 0) & ~fills           # idle -> fragmented
        return (heals.astype(np.float32)
                - fragments.astype(np.float32))

    def score(self, job: Job, snap: Snapshot, pool: np.ndarray,
              ctx: Optional[SchedulingContext]) -> np.ndarray:
        return self.weight * self._node_delta(job, snap)

    def group_score(self, job: Job, snap: Snapshot, pool: np.ndarray,
                    ctx: Optional[SchedulingContext]
                    ) -> Optional[np.ndarray]:
        if self.topology is None:
            return None
        topo = self.topology
        # Pool-masked: an out-of-pool (unhealthy / wrong-type / other
        # zone) healable node must not earn its group the top rank —
        # preselection would pin the job to a group it cannot use.
        delta = np.where(pool, self._node_delta(job, snap), 0.0)
        return self.weight * np.bincount(topo.leaf_id, weights=delta,
                                         minlength=topo.n_leaf_groups)


@register
class TenantSoftAffinity(ScorePlugin):
    """Tenant-semantic soft (anti-)affinity over NodeNetGroups.

    ``weight`` rewards LeafGroups already running pods of the job's
    tenant (keeps a tenant's traffic inside few groups);
    ``anti_weight`` penalizes groups running *other* tenants (soft
    isolation).  Soft: the terms bias group preselection
    (``group_score``) and node ranking (``score``), they never filter —
    a full cluster still schedules.

    Tenant occupancy is read from ``ctx.running`` (the QSCH running
    set); with no context the term vanishes.
    """

    name = "TenantSoftAffinity"

    def __init__(self, topology: ClusterTopology, weight: float = 1.0,
                 anti_weight: float = 0.0) -> None:
        self.topology = topology
        self.weight = weight
        self.anti_weight = anti_weight

    def _per_group(self, job: Job,
                   ctx: Optional[SchedulingContext]
                   ) -> Optional[np.ndarray]:
        running = getattr(ctx, "running", None)
        if not running:
            return None
        # One schedule call invokes this from group_score and score, per
        # pass; the occupancy scan is O(running pods) python, so reuse
        # the last result.  Occupancy is fully determined by the running
        # membership (placements of running jobs never mutate) and the
        # requesting tenant, so the key is exact — no id()-reuse or
        # same-length-different-members staleness.
        key = (job.tenant, tuple(running.keys()))
        cached = getattr(self, "_cache", None)
        if cached is not None and cached[0] == key:
            return cached[1]
        topo = self.topology
        own = np.zeros(topo.n_leaf_groups, dtype=np.float32)
        other = np.zeros(topo.n_leaf_groups, dtype=np.float32)
        for j in running.values():
            if j.placement is None:
                continue
            target = own if j.tenant == job.tenant else other
            for node in j.placement.nodes:
                target[int(topo.leaf_id[node])] = 1.0
        per_group = self.weight * own - self.anti_weight * other
        self._cache = (key, per_group)
        return per_group

    def group_score(self, job: Job, snap: Snapshot, pool: np.ndarray,
                    ctx: Optional[SchedulingContext]
                    ) -> Optional[np.ndarray]:
        return self._per_group(job, ctx)

    def score(self, job: Job, snap: Snapshot, pool: np.ndarray,
              ctx: Optional[SchedulingContext]) -> Optional[np.ndarray]:
        per_group = self._per_group(job, ctx)
        if per_group is None:
            return None
        return per_group[self.topology.leaf_id]
