"""Extension-point plugin API (the framework's contract).

The scheduling pipeline exposes one extension point per decision the
paper's QSCH/RSCH make; a plugin implements exactly one point:

==============  ======================================================
QueueSort       ordering of the global pending queue (§3.2.2)
Admit           static (quota, §3.2.1) and dynamic (feasibility)
                admission; ``stage`` selects when the plugin runs
Filter          vectorized node filtering: a boolean mask over the
                snapshot's node table (§3.4.1 node pools)
Score           vectorized node scoring: either *fused weights* into
                the shared filter+score kernel pass, an additive float
                term over the node table, or a pod-dependent
                per-extra-pod bonus (§3.3.3/§3.3.4)
Reserve/Permit  transactional gang commit: Reserve claims bookkeeping
                (quota), Permit may veto; any failure rolls back
                every successful Reserve (§3.3.2 all-or-nothing)
PostBind        fire-and-forget hook after a placement is bound
Preempt         victim selection for the conservative preemption
                engine (§3.2.3)
QueuePolicy     the cycle body: Strict FIFO / Best-Effort / Backfill
                (Table 1)
Dynamics        cluster dynamics (failure injection, drain windows,
                autoscaling) driven through the simulator's event bus
ClusterSelect   federation-level routing (repro.core.federation): which
                member cluster a job lands in, vectorized over the
                per-cluster summary matrix
RouterPolicy    query-level routing (repro.serve): which model replica
                serves an individual request, one level below
                ClusterSelect
ElasticPolicy   scheduler × parallelism co-design (repro.core.elastic):
                which declared parallelism plan an elastic training job
                runs at — shrink into fragmented capacity at placement,
                grow back at a checkpoint boundary
Observer        telemetry taps (repro.obs): cycle spans, placement /
                rejection decisions with filter+score attribution,
                preemption rationale, and every simulator bus event —
                strictly read-only, fed by the Telemetry facade
Controller      online parameter control (repro.core.tuning): consumes
                the Sample/Tick stream on a control-period cadence and
                adjusts registered tunable handles (score weights,
                preemption budgets, timeouts) through a bounded,
                rate-limited ParamSpace — the metrics→parameters loop
==============  ======================================================

**Score plugin contract** — every Score plugin declares whether its term
is *snapshot-static* (depends only on the snapshot, not on pods of the
job placed earlier in the same gang) or *pod-dependent*:

* snapshot-static terms either return :class:`ScoreWeights` from
  :meth:`ScorePlugin.fused_weights` (combined into ONE fused
  filter+score pass so the numpy/jnp/Pallas backends and the batched
  slot-chain gang selection are preserved) or a float array from
  :meth:`ScorePlugin.score` that is added onto the fused result;
* pod-dependent terms (``pod_dependent = True``) contribute a scalar
  per-extra-pod bonus via :meth:`ScorePlugin.per_pod_bonus`, folded
  into the per-node slot chains of
  :func:`repro.core.scoring.select_gang_slots` — the only
  pod-dependence the exact batched emulation supports is this linear
  same-node bonus (what ColocateBonus needs).
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import (TYPE_CHECKING, Callable, ClassVar, List, Mapping,
                    Optional, Sequence, Tuple)

import numpy as np

from ..events import EventKind
from ..job import Job, JobKind, Placement
from ..scoring import ScoreWeights
from ..snapshot import Snapshot

if TYPE_CHECKING:  # avoid import cycles: qsch/rsch import this module
    from ..cluster import ClusterState
    from ..qsch import QSCH
    from ..quota import QuotaManager
    from ..rsch import RSCH


class Plugin:
    """Base for every extension-point plugin.

    ``name`` is the registry key (see
    :mod:`repro.core.framework.registry`); instances may carry
    constructor parameters (weights, timeouts, ...).
    """

    name: ClassVar[str] = "plugin"

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"<{type(self).__name__} {self.name!r}>"


# ----------------------------------------------------------------------
# Contexts
# ----------------------------------------------------------------------
@dataclasses.dataclass
class SchedulingContext:
    """What a placement computation may consult beyond the snapshot.

    RSCH stays pure — plugins read this context, they never mutate
    cluster state through it.  ``running`` maps job uid -> running Job
    (used e.g. by tenant-affinity scoring); standalone callers of
    ``RSCH.schedule`` can pass their own.
    """

    running: Mapping[int, Job] = dataclasses.field(default_factory=dict)
    quota: Optional["QuotaManager"] = None


@dataclasses.dataclass
class CycleResult:
    """Outcome of one QSCH scheduling cycle (returned by ``cycle``)."""

    scheduled: List[Job] = dataclasses.field(default_factory=list)
    preempted: List[Job] = dataclasses.field(default_factory=list)
    blocked_head: Optional[Job] = None
    snapshot_version: int = 0
    # Why jobs waited (policy-experiment accounting): jobs excluded from
    # the global pass by static admission this cycle, dynamic-admission
    # failures during placement attempts, and requeue events (placement
    # failures + preemptions, §3.2.4).
    admit_rejected: int = 0
    infeasible: int = 0
    requeues: int = 0


@dataclasses.dataclass
class CycleContext(SchedulingContext):
    """Per-cycle context handed to queue-policy/admit/preempt plugins.

    ``sched`` is the QSCH orchestrator; plugins drive placements through
    its public helpers (``try_place``, ``preempt_job``,
    ``dynamic_admit``) so gang commit, snapshot deltas and accounting
    stay in one place.
    """

    sched: Optional["QSCH"] = None
    rsch: Optional["RSCH"] = None
    state: Optional["ClusterState"] = None
    snap: Optional[Snapshot] = None
    now: float = 0.0
    result: CycleResult = dataclasses.field(default_factory=CycleResult)


# ----------------------------------------------------------------------
# Extension points
# ----------------------------------------------------------------------
class QueueSortPlugin(Plugin):
    """Orders the pending queue; lower keys schedule first (§3.2.2)."""

    def key(self, job: Job) -> Tuple:
        raise NotImplementedError


class AdmitPlugin(Plugin):
    """Admission control.  ``stage`` is ``"static"`` (runs when the
    global queue is built and re-checked before every placement,
    §3.2.1) or ``"dynamic"`` (runs against the working snapshot)."""

    stage: ClassVar[str] = "static"

    def admit(self, job: Job, ctx: CycleContext) -> bool:
        raise NotImplementedError


class FilterPlugin(Plugin):
    """Vectorized node filter: returns a boolean mask over the node
    table.  ``zone`` is the pass's zone selector (``None`` / ``"zone"``
    / ``"general"``); most filters ignore it."""

    def mask(self, job: Job, snap: Snapshot,
             zone: Optional[str]) -> np.ndarray:
        raise NotImplementedError


class ScorePlugin(Plugin):
    """Vectorized node scoring term (see module docstring contract)."""

    #: snapshot-static (False) vs pod-dependent (True) declaration.
    pod_dependent: ClassVar[bool] = False

    def fused_weights(self, job: Job) -> Optional[ScoreWeights]:
        """Weights folded into the single fused filter+score pass
        (numpy / jnp / Pallas).  Return ``None`` if this plugin scores
        via :meth:`score` instead."""
        return None

    def score(self, job: Job, snap: Snapshot, pool: np.ndarray,
              ctx: Optional[SchedulingContext]) -> Optional[np.ndarray]:
        """Additive snapshot-static term over the node table (float
        array, shape ``(n_nodes,)``); added where the fused pass kept
        the node valid.  Return ``None`` to contribute nothing."""
        return None

    def group_score(self, job: Job, snap: Snapshot, pool: np.ndarray,
                    ctx: Optional[SchedulingContext]
                    ) -> Optional[np.ndarray]:
        """Additive term over the NodeNetGroup table (shape
        ``(n_leaf_groups,)``): biases Level-1 group preselection
        (§3.4.2), the group-granular twin of :meth:`score` — without it
        a group-constant node term can never steer single-group jobs,
        whose group is fixed before node scoring runs.  Aggregate only
        over ``pool`` nodes: the preselection never places outside the
        pass's Filter mask, so out-of-pool nodes must not earn a group
        its rank.  Higher wins; ties fall back to the pass's default
        group ranking.  Return ``None`` (the default) to leave
        preselection untouched."""
        return None

    def per_pod_bonus(self, job: Job) -> float:
        """Pod-dependent plugins only: bonus a node earns per pod of
        this job already placed on it (folded into the slot chains)."""
        return 0.0


class ReservePlugin(Plugin):
    """Claims bookkeeping for a computed placement before binding.
    Must be undoable: ``unreserve`` is called on every successfully
    reserved plugin if a later Reserve/Permit fails (§3.3.2)."""

    def reserve(self, job: Job, placement: Placement,
                ctx: CycleContext) -> bool:
        return True

    def unreserve(self, job: Job, placement: Placement,
                  ctx: CycleContext) -> None:
        pass


class PermitPlugin(Plugin):
    """Last gate before binding; a veto rolls back all reservations."""

    def permit(self, job: Job, placement: Placement,
               ctx: CycleContext) -> bool:
        return True


class PostBindPlugin(Plugin):
    """Runs after a placement is committed (informational)."""

    def post_bind(self, job: Job, placement: Placement,
                  ctx: CycleContext) -> None:
        pass


class PreemptPlugin(Plugin):
    """Victim selection for the conservative preemption engine
    (§3.2.3).  The orchestrator consults the profile's chain in order
    and runs its shared dry-run-checked eviction loop on the first
    non-empty victim list.  A plugin may instead override
    :meth:`execute` to own its whole preemption flow — eviction AND
    placement, via ``ctx.sched.preempt_job``/``try_place`` (Backfill
    head-timeout does this): the chain calls ``execute`` on every
    plugin whose ``victims`` came back empty and stops once the job is
    running."""

    def victims(self, job: Job, ctx: CycleContext) -> List[Job]:
        return []

    def execute(self, job: Job, ctx: CycleContext) -> None:
        """Full preemption flow for policies that are not driven by the
        shared chain loop (default: no-op)."""


class QueuePolicyPlugin(Plugin):
    """The cycle body (Table 1): walks the admitted global queue and
    drives placements via ``ctx.sched.try_place``."""

    # True when a blocked head ends the cycle with no further placement
    # attempts (Strict FIFO).  The cycle pipeline consults this to
    # predict which job — if any — opens the next cycle's RSCH call.
    strict_head = False

    def run_cycle(self, queue: List[Job], ctx: CycleContext) -> None:
        raise NotImplementedError


class DynamicsPlugin(Plugin):
    """Cluster-dynamics extension point (the ``DynamicsPolicy`` family).

    Where every other extension point decides *where work goes*, a
    dynamics plugin decides *what happens to the cluster*: failures,
    maintenance drains, autoscaling.  Two hooks:

    * :meth:`schedule` — called once at attach time with the
      :class:`~repro.core.dynamics.engine.ClusterDynamics` engine and a
      seeded RNG; yields ``(t, EventKind, payload)`` tuples that are
      pre-seeded onto the simulator's event bus (a reproducible failure
      trace, drain windows, the autoscaler's first SCALE_DECISION).
    * :meth:`on_event` — called for every bus event whose kind is in
      :attr:`handles`; the plugin drives cluster mutations and job
      submissions through the engine's action helpers (``fail_node``,
      ``submit_job``, ``retire_job``, ``push`` ...), never by touching
      ``ClusterState`` directly — that keeps snapshot sync, quota
      refunds and requeue accounting in one place.

    The built-in NODE_FAIL/NODE_RECOVER/GPU_FAIL/GPU_RECOVER/
    DRAIN_START/DRAIN_END semantics live in the engine itself, so
    injector plugins stay declarative trace generators.
    """

    #: Event kinds routed to :meth:`on_event`.
    handles: ClassVar[Tuple[EventKind, ...]] = ()

    def schedule(self, engine, rng) -> Sequence[Tuple[float, EventKind,
                                                      object]]:
        return ()

    def on_event(self, event, engine) -> None:  # pragma: no cover - hook
        pass


class ClusterSelectPlugin(Plugin):
    """Federation routing extension point (GSCH,
    :mod:`repro.core.federation`): decides which *member cluster* a job
    is forwarded to, the level above the per-cluster QSCH/RSCH pipeline.

    Both hooks are vectorized over the federation's per-cluster summary
    matrix (:class:`~repro.core.federation.summary.FederationSummary`):
    free GPUs per (member, pool), leaf-group headroom, queue depth,
    pending gang backlog, cost/capability tables.  A routing decision
    must stay O(members) — plugins read the summary, they never walk a
    member's node arrays.

    * :meth:`feasible` — boolean mask over members; ``None`` abstains.
      The GSCH ANDs all plugin masks onto the structural-fit mask (pool
      exists, a pod fits on one node).  If the chain vetoes every
      member, the GSCH falls back to structural fit so a veto can delay
      but never strand a job.
    * :meth:`score` — additive float term over members; higher wins.
      Ties break toward the lower member index (determinism).
    """

    def feasible(self, job: Job, summary) -> Optional[np.ndarray]:
        return None

    def score(self, job: Job, summary) -> Optional[np.ndarray]:
        return None


class ElasticPolicyPlugin(Plugin):
    """Elastic-training extension point (:mod:`repro.core.elastic`):
    decides which of a job's declared
    :class:`~repro.core.elastic.spec.ParallelismPlan`s it runs at.

    Both hooks are *advisory* — the
    :class:`~repro.core.elastic.manager.ElasticManager` executes the
    decision through the standard QSCH paths (placement via
    ``try_place``, reshape via the checkpoint-interrupt machinery), so
    plugins never mutate cluster state.  Jobs without an
    :attr:`~repro.core.job.Job.elastic` spec never reach these hooks:
    the non-elastic pipeline stays byte-identical.

    * :meth:`select_plan` — called on every placement attempt of an
      elastic job, against the cycle's working snapshot.  Return the
      plan the attempt should use, or ``None`` to keep the ideal plan
      (rigid behavior: queue/preempt for the full shape).  Returning a
      smaller fitting plan is the **shrink** path — the gang starts in
      currently-free fragmented capacity instead of waiting.
    * :meth:`want_grow` — called once per cycle for each *running*
      elastic job below its ideal plan, only at a checkpoint boundary
      (reshaping restarts from the last checkpoint, see
      ``docs/elastic.md``).  ``reshape_cost_s`` is the restart overhead
      the recovery model will charge.  Return a strictly better target
      plan to trigger the reshape, or ``None`` to keep running as-is.
    """

    def select_plan(self, job: Job, snap: Snapshot,
                    ctx: Optional[CycleContext]):
        return None

    def want_grow(self, job: Job, snap: Snapshot,
                  ctx: Optional[CycleContext], reshape_cost_s: float):
        return None


class RouterPolicyPlugin(Plugin):
    """Query-routing extension point (:mod:`repro.serve`): decides which
    model *replica* serves an individual request — the request-level
    sibling of :class:`ClusterSelectPlugin` (jobs → clusters there,
    queries → replicas here, per ECCOS-style constrained routing).

    * :meth:`select` — pick a replica index from ``replicas`` (a
      sequence of :class:`repro.serve.replica.Replica`, each exposing
      its :class:`~repro.serve.replica.ReplicaSpec` and live load) for
      ``request`` (a :class:`repro.core.workload.ServeRequest`) at
      simulated time ``now``.  Return ``None`` to REJECT the request
      (no replica can meet its constraints); the pool records the
      rejection as an SLO miss rather than queueing it forever.
    * :meth:`observe` — optional feedback hook called with each
      completed :class:`repro.serve.metrics.RequestOutcome`, so
      learning policies can update capability estimates online.
    """

    def select(self, request, replicas: Sequence, now: float
               ) -> Optional[int]:
        raise NotImplementedError

    def observe(self, outcome) -> None:  # pragma: no cover - hook
        pass


class ObserverPlugin(Plugin):
    """Telemetry extension point (:mod:`repro.obs`): read-only taps on
    the scheduling pipeline, fed by an attached
    :class:`~repro.obs.telemetry.Telemetry` facade.

    Where every other extension point *decides* something, an observer
    only *watches*: hooks must never mutate jobs, snapshots or cluster
    state — the detached-telemetry byte-identity gate
    (``benchmarks/obs_bench.py``) also runs with telemetry attached and
    asserts placements and metrics are unchanged.

    Hooks (all optional; default implementations are no-ops):

    * :meth:`on_cycle` — after every QSCH cycle (the Tick tap), with a
      :class:`~repro.obs.telemetry.CycleSpan` carrying wall-clock phase
      timings and the :class:`CycleResult`;
    * :meth:`on_bind` / :meth:`on_reject` — after a placement binds
      (the PostBind tap) or an attempt fails, with a
      :class:`~repro.obs.audit.PlacementDecision` carrying per-Filter
      node-elimination counts and the per-ScorePlugin score breakdown
      of the winning nodes (``None`` when the audit pillar is off);
    * :meth:`on_preempt` — one eviction fired (the Preempt tap), with a
      :class:`~repro.obs.audit.PreemptionRecord` naming victim,
      beneficiary and the Preempt plugin that selected it;
    * :meth:`on_event` — every simulator :class:`~repro.core.events.Event`
      (the EventBus subscriber: SUBMIT/END plus all dynamics kinds);
    * :meth:`on_sample` — every metrics :class:`~repro.core.metrics.Sample`;
    * :meth:`on_job` — job lifecycle edges (``"placed"`` /
      ``"finished"`` / ``"interrupted"`` / ``"reshape"``);
    * :meth:`on_param_change` — a tuning controller moved a registered
      handle (:class:`~repro.core.tuning.params.ParamChange`);
    * :meth:`on_run_end` — the simulator finalized.

    ``scope`` is ``None`` standalone and the member name under a
    federation (one Telemetry can watch every member simulator).
    """

    def on_cycle(self, span, ctx: "CycleContext") -> None:
        pass

    def on_bind(self, job: Job, decision, ctx: "CycleContext") -> None:
        pass

    def on_reject(self, job: Job, decision, ctx: "CycleContext") -> None:
        pass

    def on_preempt(self, record, ctx: "CycleContext") -> None:
        pass

    def on_event(self, event, scope: Optional[str] = None) -> None:
        pass

    def on_sample(self, sample, scope: Optional[str] = None) -> None:
        pass

    def on_job(self, job: Job, edge: str, t: float,
               scope: Optional[str] = None) -> None:
        pass

    def on_param_change(self, change,
                        scope: Optional[str] = None) -> None:
        pass

    def on_run_end(self, sim, scope: Optional[str] = None) -> None:
        pass


class ControllerPlugin(Plugin):
    """Online parameter-control extension point
    (:mod:`repro.core.tuning`): closes the metrics→parameters loop.

    Where an :class:`ObserverPlugin` only *watches*, a controller
    *steers* — but only through the registered tunable handles of a
    :class:`~repro.core.tuning.params.ParamSpace`, never by touching
    scheduler state directly.  Every write goes through
    ``ParamSpace.set``, which clamps to the handle's bounds, enforces
    its per-step change-rate limit, publishes the new value as a Gauge
    into the attached obs registry and emits a DecisionAudit/trace
    instant — so a controller cannot push the system outside its
    declared envelope and every change is attributable.

    Controllers are registered like any plugin and attached via
    :class:`~repro.core.tuning.manager.TuningManager`, which feeds them
    the simulator's Tick/Sample stream:

    * :meth:`bind` — once at attach time, after the ParamSpace is
      populated; stash references, seed internal state.
    * :meth:`on_tick` — every scheduler tick (between QSCH cycles, on
      the simulator's TICK cadence).  Cheap bookkeeping only — this is
      on the per-cycle path and is covered by the ≤5% attached-overhead
      gate (``benchmarks/tuning_bench.py``).
    * :meth:`control` — once per **control period**
      (:attr:`control_period_s` of simulated time), with a
      :class:`~repro.core.tuning.manager.TuningWindow` summarizing the
      period's GFR/JWTD/GAR/SOR observations.  This is where parameter
      moves happen.
    * :meth:`warm_start` — seed from a
      :class:`~repro.core.tuning.profile.TuningProfile` exported by a
      previously tuned run/member (Sliwko-style transfer) instead of
      starting cold.

    A controller that never calls ``space.set`` must be byte-identical
    to a detached run (placements, metric report, raw samples) — the
    tuning twin of the obs parity gate, enforced by
    ``benchmarks/tuning_bench.py`` and ``tests/test_tuning.py``.
    """

    #: Simulated seconds between :meth:`control` invocations.
    control_period_s: ClassVar[float] = 1800.0

    def bind(self, space, manager) -> None:
        pass

    def on_tick(self, now: float, sched: "QSCH", space) -> None:
        pass

    def control(self, window, space) -> None:
        pass

    def warm_start(self, profile, space) -> None:
        pass


#: Shared no-op context for detached-telemetry phase sites (one object,
#: never re-allocated: the detached hot path pays a single ``is None``
#: branch plus a constant-cost ``with``).
_NULL_PHASE = contextlib.nullcontext()


def obs_phase(obs, name: str):
    """Timed-phase context for an attached telemetry observer.

    QSCH/RSCH wrap each pipeline stage (snapshot → queue-sort → filter
    → score → reserve-permit → bind → preempt) in
    ``with obs_phase(self.obs, "..."):``; with ``obs is None`` (no
    telemetry attached) this returns a shared null context and the
    stage runs untimed and unchanged."""
    return _NULL_PHASE if obs is None else obs.phase(name)


# ----------------------------------------------------------------------
# Profiles
# ----------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class PlacementPass:
    """One Filter+Score placement attempt over a node-pool restriction.

    ``spread``/``enhanced`` steer the Level-1 NodeNetGroup preselection
    (§3.4.2): spread prefers the emptiest group, enhanced reserves
    empty groups for large jobs (LeafGroup E-Binpack, §3.3.3); ``zone``
    restricts to the inference dedicated zone or its complement
    (§3.3.4).
    """

    scorers: Tuple[ScorePlugin, ...]
    spread: bool = False
    enhanced: bool = False
    zone: Optional[str] = None


#: Plan: ordered placement passes for a job against a snapshot; the
#: first pass that yields a placement wins.
PlanFn = Callable[[Job, Snapshot], Sequence[PlacementPass]]


def single_pass_plan(p: PlacementPass) -> PlanFn:
    """Plan that always runs exactly one pass (the common case)."""
    def plan(job: Job, snap: Snapshot) -> Sequence[PlacementPass]:
        return (p,)
    return plan


@dataclasses.dataclass
class SchedulingProfile:
    """One plugin chain per extension point, for one workload class."""

    name: str
    plan: PlanFn
    queue_sort: QueueSortPlugin
    admit: Tuple[AdmitPlugin, ...] = ()
    filters: Tuple[FilterPlugin, ...] = ()
    reserve: Tuple[ReservePlugin, ...] = ()
    permit: Tuple[PermitPlugin, ...] = ()
    post_bind: Tuple[PostBindPlugin, ...] = ()
    preempt: Tuple[PreemptPlugin, ...] = ()

    def admit_chain(self, stage: str) -> Tuple[AdmitPlugin, ...]:
        return tuple(p for p in self.admit if p.stage == stage)


@dataclasses.dataclass
class ProfileSet:
    """Per-workload profiles (§2 diverse task types) + the shared queue
    policy.  Like kube-scheduler profiles, the queue is global: the
    ``train`` profile's QueueSort orders it for every workload."""

    train: SchedulingProfile
    inference: SchedulingProfile
    best_effort: SchedulingProfile

    def for_job(self, job: Job) -> SchedulingProfile:
        if job.kind is JobKind.INFER:
            return self.inference
        if job.kind is JobKind.DEBUG:
            return self.best_effort
        return self.train

    @property
    def queue_sort(self) -> QueueSortPlugin:
        return self.train.queue_sort
