"""Kant's core: cluster model, QSCH, RSCH, plugin framework, simulator,
cluster dynamics, federation, elastic training, self-tuning."""

from .cluster import ClusterState
from .dynamics import (CheckpointModel, ClusterDynamics, DrainWindow,
                       DynamicsConfig, DynamicsSummary, GpuFailureInjector,
                       NodeFailureInjector, TidalAutoscaler, TidalService)
from .elastic import (ElasticConfig, ElasticManager, ElasticSpec,
                      GreedyElastic, ParallelismPlan, scaling_artifacts,
                      spec_from_artifacts)
from .events import Event, EventBus, EventKind
from .federation import (FederatedCluster, FederatedResult,
                         FederatedSimulator, FederationSummary, GSCH,
                         GSCHConfig, MemberCluster, make_member)
from .framework import (CycleResult, PlacementPass, ProfileSet,
                        SchedulingProfile, default_profiles)
from .job import (Job, JobKind, JobState, Placement, PodPlacement,
                  PRIO_HIGH, PRIO_LOW, PRIO_NORMAL, size_bucket)
from .metrics import MetricsRecorder, waiting_percentile
from .qsch import QSCH, QSCHConfig, QueuePolicy
from .quota import QuotaManager, QuotaMode
from .rsch import RSCH, RSCHConfig, Strategy, profiles_from_config
from .scoring import (BINPACK, E_BINPACK, E_SPREAD, SPREAD, ScoreWeights,
                      combine_weights, compute_node_scores, node_scores_np,
                      select_gang_slots)
from .simulator import SimConfig, Simulator, SimResult
from .snapshot import (FullSnapshotter, IncrementalSnapshotter, Snapshot,
                       snapshots_equal)
from .topology import ClusterTopology, small_topology, \
    training_cluster_topology
from .tuning import (HillClimbController, NoOpController,
                     ObjectiveWeights, ParamChange, ParamSpace,
                     StarvationEscalator, TuningManager, TuningProfile,
                     TuningWindow)
from .workload import (DEFAULT_QUERY_CLASSES, QueryClass, ServeRequest,
                       backfill_training_trace, diurnal_demand,
                       inference_trace, request_trace, trace_stats,
                       training_trace)

__all__ = [
    "ClusterState", "Job", "JobKind", "JobState", "Placement",
    "PodPlacement", "PRIO_HIGH", "PRIO_LOW", "PRIO_NORMAL", "size_bucket",
    "MetricsRecorder", "waiting_percentile",
    "QSCH", "QSCHConfig", "QueuePolicy", "QuotaManager",
    "QuotaMode", "RSCH", "RSCHConfig", "Strategy", "BINPACK", "E_BINPACK",
    "E_SPREAD", "SPREAD", "ScoreWeights", "combine_weights",
    "compute_node_scores", "node_scores_np", "select_gang_slots",
    "SimConfig", "Simulator", "SimResult", "FullSnapshotter",
    "IncrementalSnapshotter", "Snapshot", "snapshots_equal",
    "ClusterTopology", "small_topology", "training_cluster_topology",
    "backfill_training_trace", "diurnal_demand", "inference_trace",
    "trace_stats", "training_trace", "QueryClass", "ServeRequest",
    "DEFAULT_QUERY_CLASSES", "request_trace",
    # events + dynamics (full surface in repro.core.dynamics)
    "Event", "EventBus", "EventKind", "ClusterDynamics", "DynamicsConfig",
    "DynamicsSummary", "NodeFailureInjector", "GpuFailureInjector",
    "DrainWindow", "CheckpointModel", "TidalAutoscaler", "TidalService",
    # framework (full surface in repro.core.framework)
    "CycleResult", "PlacementPass", "ProfileSet", "SchedulingProfile",
    "default_profiles", "profiles_from_config",
    # federation (full surface in repro.core.federation)
    "FederatedCluster", "FederatedResult", "FederatedSimulator",
    "FederationSummary", "GSCH", "GSCHConfig", "MemberCluster",
    "make_member",
    # elastic training (full surface in repro.core.elastic)
    "ElasticSpec", "ParallelismPlan", "ElasticConfig", "ElasticManager",
    "GreedyElastic", "spec_from_artifacts", "scaling_artifacts",
    # self-tuning (full surface in repro.core.tuning)
    "TuningManager", "ParamSpace", "ParamChange", "TuningProfile",
    "TuningWindow", "ObjectiveWeights", "HillClimbController",
    "StarvationEscalator", "NoOpController",
]
