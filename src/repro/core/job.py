"""Job and placement model (paper §2, §3.2).

Kant schedules three kinds of AI jobs (§2 "Diverse Task Types"):

* LLM distributed training  — gang-scheduled, throughput-oriented;
* inference services        — pod-level scheduling, latency/HA-oriented;
* development / debugging   — small, flexibility-oriented.

A job consists of ``n_pods`` pods, each requesting ``gpus_per_pod`` GPUs of
one GPU type.  Gang jobs (§3.3.2) are admitted, scheduled and preempted at
job granularity (all-or-nothing); non-gang jobs at pod granularity.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import TYPE_CHECKING, Dict, List, Optional, Sequence, Tuple

if TYPE_CHECKING:   # elastic imports JobKind; keep the cycle static-only
    from .elastic.spec import ElasticSpec, ParallelismPlan


class JobKind(enum.Enum):
    TRAIN = "train"
    INFER = "infer"
    DEBUG = "debug"


class JobState(enum.Enum):
    PENDING = "pending"        # submitted, waiting in the tenant queue
    ADMITTED = "admitted"      # passed static + dynamic admission
    RUNNING = "running"        # bound to devices
    COMPLETED = "completed"
    PREEMPTED = "preempted"    # evicted; will be requeued
    INTERRUPTED = "interrupted"  # killed by a failure/drain; requeued
    FAILED = "failed"


# Priority values: larger is more important.  These match the paper's
# qualitative tiers (inference/HA > training > debug/backfill fodder).
PRIO_HIGH = 100
PRIO_NORMAL = 50
PRIO_LOW = 10


@dataclasses.dataclass
class PodPlacement:
    """Concrete device assignment for one pod (fine-grained, §3.3.1)."""

    node: int
    gpu_indices: Tuple[int, ...]      # device slots on that node
    nic: int = 0                      # paired RDMA NIC (§3.3.1)

    def __post_init__(self) -> None:
        if len(set(self.gpu_indices)) != len(self.gpu_indices):
            raise ValueError("duplicate GPU indices in a pod placement")


@dataclasses.dataclass
class Placement:
    """Full placement of a job: one ``PodPlacement`` per pod."""

    pods: List[PodPlacement]

    @property
    def nodes(self) -> List[int]:
        return [p.node for p in self.pods]

    @property
    def n_gpus(self) -> int:
        return sum(len(p.gpu_indices) for p in self.pods)

    def distinct_nodes(self) -> List[int]:
        return sorted(set(self.nodes))


@dataclasses.dataclass
class Job:
    uid: int
    tenant: str
    gpu_type: int
    n_pods: int
    gpus_per_pod: int
    kind: JobKind = JobKind.TRAIN
    gang: bool = True
    priority: int = PRIO_NORMAL
    submit_time: float = 0.0
    duration: float = 3600.0
    preemptible: bool = True
    # Home region of the job's tenant/data (federation subsystem): the
    # GSCH locality plugin prefers member clusters in this region, and
    # cross-region forwarding pays the locality penalty.  None = no
    # affinity (single-cluster runs never look at it).
    region: Optional[str] = None
    # Elastic-training contract (repro.core.elastic): the menu of
    # alternative parallelism plans this job may run at.  None (the
    # default) keeps the job rigid — the scheduler never looks at it
    # and every placement stays byte-identical to the classic path.
    # The job's declared (n_pods, gpus_per_pod) must be the spec's
    # ideal plan; ``duration``/``original_duration`` are ideal-plan
    # seconds.
    elastic: Optional["ElasticSpec"] = None
    # Free-form descriptive text (model/framework/dataset tags): the
    # semantic soft-affinity contrib plugin scores token overlap over
    # it.  None = no description; affinity falls back to the tenant
    # name.  The scheduler core never reads it.
    metadata: Optional[str] = None

    # Mutable scheduling bookkeeping -----------------------------------
    state: JobState = JobState.PENDING
    admit_time: Optional[float] = None
    start_time: Optional[float] = None      # scheduling completion (binding)
    run_time: Optional[float] = None        # container actually running
    end_time: Optional[float] = None
    placement: Optional[Placement] = None
    backfilled: bool = False                # scheduled by bypassing the head
    preempt_count: int = 0
    requeue_count: int = 0
    borrowed_quota: int = 0                 # GPUs borrowed via shared quota
    # Checkpoint-restart bookkeeping (dynamics subsystem).  ``duration``
    # is the remaining wall time of the CURRENT attempt (the simulator
    # schedules END from it); ``original_duration`` is the total useful
    # work the job represents, fixed at construction.
    original_duration: float = 0.0
    attempt: int = 0                        # restart attempts so far
    interrupt_count: int = 0                # failure/drain kills
    checkpointed_progress: float = 0.0      # work safely persisted (s)
    lost_work: float = 0.0                  # recompute debt accrued (s)
    restart_overhead: float = 0.0           # restore overhead accrued (s)
    # Elastic bookkeeping: the plan the current/most recent attempt runs
    # at (None until the ElasticManager picks one) and how many
    # voluntary checkpoint-boundary reshapes the job has gone through.
    active_plan: Optional["ParallelismPlan"] = None
    reshape_count: int = 0

    def __post_init__(self) -> None:
        if self.n_pods <= 0 or self.gpus_per_pod <= 0:
            raise ValueError("jobs must request at least one pod and GPU")
        if not self.gang and self.kind == JobKind.TRAIN and self.n_pods > 1:
            # The paper gang-schedules all distributed training (§3.2.1).
            raise ValueError("multi-pod training jobs must be gang jobs")
        if not self.original_duration:
            self.original_duration = self.duration
        if self.elastic is not None:
            self.elastic.validate_for(self)

    @property
    def n_gpus(self) -> int:
        return self.n_pods * self.gpus_per_pod

    # -- elastic accounting (identity values for rigid jobs) -----------
    @property
    def work_rate(self) -> float:
        """Relative progress rate of the active plan: 1.0 for rigid
        jobs and for elastic jobs at their ideal plan; below 1.0 while
        shrunk.  One wall second on the active shape advances
        ``work_rate`` seconds of (ideal-plan) work."""
        if self.elastic is None or self.active_plan is None:
            return 1.0
        return self.active_plan.throughput / self.elastic.ideal().throughput

    @property
    def ideal_n_gpus(self) -> int:
        """GPU count of the ideal plan — the plan-independent yardstick
        goodput accounting multiplies completed work by."""
        if self.elastic is None:
            return self.n_gpus
        return self.elastic.ideal().n_gpus

    def apply_plan(self, plan: "ParallelismPlan") -> None:
        """Adopt ``plan`` as the next attempt's shape.  Only legal
        while the job is not bound to devices (quota charges and the
        allocator validate against the current shape)."""
        if self.state is JobState.RUNNING:
            raise ValueError("cannot reshape a bound job in place")
        self.n_pods = plan.n_pods
        self.gpus_per_pod = plan.gpus_per_pod
        self.active_plan = plan

    @property
    def waiting_time(self) -> Optional[float]:
        if self.start_time is None:
            return None
        return self.start_time - self.submit_time

    def order_key(self) -> Tuple[int, float, int, int]:
        """Global queue ordering (§3.2.2): priority desc, submit time asc,
        then size asc as the tie-breaker, uid for determinism."""
        return (-self.priority, self.submit_time, self.n_gpus, self.uid)


def size_bucket(n_gpus: int) -> str:
    """JWTD size buckets (§4.4 uses 'fewer than 8' / 'more than 64' bands;
    we refine to the sizes of Fig 4/8)."""
    for bound, name in ((8, "<=8"), (64, "9-64"), (256, "65-256"),
                        (1024, "257-1024"), (2048, "1025-2048")):
        if n_gpus <= bound:
            return name
    return ">2048"


SIZE_BUCKETS: Sequence[str] = ("<=8", "9-64", "65-256", "257-1024",
                               "1025-2048", ">2048")


def summarize_waits(jobs: Sequence[Job]) -> Dict[str, float]:
    """Mean waiting time per size bucket over started jobs."""
    acc: Dict[str, List[float]] = {}
    for j in jobs:
        w = j.waiting_time
        if w is None:
            continue
        acc.setdefault(size_bucket(j.n_gpus), []).append(w)
    return {k: sum(v) / len(v) for k, v in acc.items() if v}
