"""Job and placement model (paper §2, §3.2).

Kant schedules three kinds of AI jobs (§2 "Diverse Task Types"):

* LLM distributed training  — gang-scheduled, throughput-oriented;
* inference services        — pod-level scheduling, latency/HA-oriented;
* development / debugging   — small, flexibility-oriented.

A job consists of ``n_pods`` pods, each requesting ``gpus_per_pod`` GPUs of
one GPU type.  Gang jobs (§3.3.2) are admitted, scheduled and preempted at
job granularity (all-or-nothing); non-gang jobs at pod granularity.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Dict, List, Optional, Sequence, Tuple


class JobKind(enum.Enum):
    TRAIN = "train"
    INFER = "infer"
    DEBUG = "debug"


class JobState(enum.Enum):
    PENDING = "pending"        # submitted, waiting in the tenant queue
    ADMITTED = "admitted"      # passed static + dynamic admission
    RUNNING = "running"        # bound to devices
    COMPLETED = "completed"
    PREEMPTED = "preempted"    # evicted; will be requeued
    INTERRUPTED = "interrupted"  # killed by a failure/drain; requeued
    FAILED = "failed"


# Priority values: larger is more important.  These match the paper's
# qualitative tiers (inference/HA > training > debug/backfill fodder).
PRIO_HIGH = 100
PRIO_NORMAL = 50
PRIO_LOW = 10


@dataclasses.dataclass
class PodPlacement:
    """Concrete device assignment for one pod (fine-grained, §3.3.1)."""

    node: int
    gpu_indices: Tuple[int, ...]      # device slots on that node
    nic: int = 0                      # paired RDMA NIC (§3.3.1)

    def __post_init__(self) -> None:
        if len(set(self.gpu_indices)) != len(self.gpu_indices):
            raise ValueError("duplicate GPU indices in a pod placement")


@dataclasses.dataclass
class Placement:
    """Full placement of a job: one ``PodPlacement`` per pod."""

    pods: List[PodPlacement]

    @property
    def nodes(self) -> List[int]:
        return [p.node for p in self.pods]

    @property
    def n_gpus(self) -> int:
        return sum(len(p.gpu_indices) for p in self.pods)

    def distinct_nodes(self) -> List[int]:
        return sorted(set(self.nodes))


@dataclasses.dataclass
class Job:
    uid: int
    tenant: str
    gpu_type: int
    n_pods: int
    gpus_per_pod: int
    kind: JobKind = JobKind.TRAIN
    gang: bool = True
    priority: int = PRIO_NORMAL
    submit_time: float = 0.0
    duration: float = 3600.0
    preemptible: bool = True
    # Home region of the job's tenant/data (federation subsystem): the
    # GSCH locality plugin prefers member clusters in this region, and
    # cross-region forwarding pays the locality penalty.  None = no
    # affinity (single-cluster runs never look at it).
    region: Optional[str] = None

    # Mutable scheduling bookkeeping -----------------------------------
    state: JobState = JobState.PENDING
    admit_time: Optional[float] = None
    start_time: Optional[float] = None      # scheduling completion (binding)
    run_time: Optional[float] = None        # container actually running
    end_time: Optional[float] = None
    placement: Optional[Placement] = None
    backfilled: bool = False                # scheduled by bypassing the head
    preempt_count: int = 0
    requeue_count: int = 0
    borrowed_quota: int = 0                 # GPUs borrowed via shared quota
    # Checkpoint-restart bookkeeping (dynamics subsystem).  ``duration``
    # is the remaining wall time of the CURRENT attempt (the simulator
    # schedules END from it); ``original_duration`` is the total useful
    # work the job represents, fixed at construction.
    original_duration: float = 0.0
    attempt: int = 0                        # restart attempts so far
    interrupt_count: int = 0                # failure/drain kills
    checkpointed_progress: float = 0.0      # work safely persisted (s)
    lost_work: float = 0.0                  # recompute debt accrued (s)
    restart_overhead: float = 0.0           # restore overhead accrued (s)

    def __post_init__(self) -> None:
        if self.n_pods <= 0 or self.gpus_per_pod <= 0:
            raise ValueError("jobs must request at least one pod and GPU")
        if not self.gang and self.kind == JobKind.TRAIN and self.n_pods > 1:
            # The paper gang-schedules all distributed training (§3.2.1).
            raise ValueError("multi-pod training jobs must be gang jobs")
        if not self.original_duration:
            self.original_duration = self.duration

    @property
    def n_gpus(self) -> int:
        return self.n_pods * self.gpus_per_pod

    @property
    def waiting_time(self) -> Optional[float]:
        if self.start_time is None:
            return None
        return self.start_time - self.submit_time

    def order_key(self) -> Tuple[int, float, int, int]:
        """Global queue ordering (§3.2.2): priority desc, submit time asc,
        then size asc as the tie-breaker, uid for determinism."""
        return (-self.priority, self.submit_time, self.n_gpus, self.uid)


def size_bucket(n_gpus: int) -> str:
    """JWTD size buckets (§4.4 uses 'fewer than 8' / 'more than 64' bands;
    we refine to the sizes of Fig 4/8)."""
    for bound, name in ((8, "<=8"), (64, "9-64"), (256, "65-256"),
                        (1024, "257-1024"), (2048, "1025-2048")):
        if n_gpus <= bound:
            return name
    return ">2048"


SIZE_BUCKETS: Sequence[str] = ("<=8", "9-64", "65-256", "257-1024",
                               "1025-2048", ">2048")


def summarize_waits(jobs: Sequence[Job]) -> Dict[str, float]:
    """Mean waiting time per size bucket over started jobs."""
    acc: Dict[str, List[float]] = {}
    for j in jobs:
        w = j.waiting_time
        if w is None:
            continue
        acc.setdefault(size_bucket(j.n_gpus), []).append(w)
    return {k: sum(v) / len(v) for k, v in acc.items() if v}
