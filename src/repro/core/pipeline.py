"""Optimistic cycle pipelining: overlap snapshot+score of cycle N+1
with the bind commit of cycle N.

Production schedulers hide scheduling latency behind binding I/O (image
pulls, container starts — §4.2 measures ~45 s of it): while cycle N's
placements commit, the scheduler can already snapshot and score cycle
N+1's head job.  The simulator is single-threaded, so the pipeline
models the *decision dependency structure* rather than real threads:

* at the end of cycle N (:meth:`CyclePipeline.end_cycle`), the retained
  incremental snapshot is **speculatively refreshed** — dirty rows fold
  in WITHOUT a version bump (``IncrementalSnapshotter.refresh``) — and
  RSCH pre-computes a :class:`~repro.core.rsch.ScheduleResult` for the
  *predicted* head job of cycle N+1 (the first pending job passing
  static admission, which every built-in QueuePolicy attempts first);
* at the start of cycle N+1 (:meth:`CyclePipeline.begin_cycle`), a
  **conflict re-check** decides whether the speculation is still valid:
  any dirty rows or invariant changes on the live state since the
  speculative refresh (job ENDs, failures, drains, autoscaling), or
  further mutations folded into the snapshot (``mut_count`` drift),
  abandon it — the cycle recomputes from scratch, which is always
  correct;
* RSCH consumes an armed speculation in :meth:`~repro.core.rsch.RSCH.
  schedule` only after re-verifying the job's identity and shape, the
  snapshot identity and mutation count, and the score-weight
  fingerprint (a self-tuning controller may have nudged plugin weights
  between cycles).

Coverage argument: every observable input of ``RSCH.schedule`` is either
(a) the snapshot — guarded by ``mut_count`` + the live state's dirty
tracking, since *all* placement/health mutations go through the
sanctioned ``ClusterState`` writers; (b) the job — guarded by
uid/shape/fingerprint; or (c) plugin-visible cluster context.  Running-
set and quota changes always accompany a state mutation (allocate/
release), so (c) is covered by (a) for the built-in plugins.  The one
documented unsupported case is a custom Score plugin reading
``CycleContext.now`` (speculation passes a plain ``SchedulingContext``,
which has no clock) — such profiles should keep ``pipelined_cycles``
off.

A correct-but-stale prediction (head job changed, admission flipped) is
never an error: the speculation simply goes unconsumed and is counted
as a miss.  With the pipeline off, none of this code runs and the
simulator is byte-identical to the unpipelined implementation; with it
on, placements are identical whenever speculations are only consumed
under the guards above (asserted by ``benchmarks/sched_scale_bench.py``
over multi-day traces).
"""

from __future__ import annotations

import dataclasses
import time
from typing import TYPE_CHECKING, Optional

from .framework.api import CycleContext, CycleResult, SchedulingContext

if TYPE_CHECKING:
    from .cluster import ClusterState
    from .qsch import QSCH


@dataclasses.dataclass
class _Speculation:
    """One precomputed head-job schedule plus its validity guards."""

    job_uid: int
    shape: tuple                  # (n_pods, gpus_per_pod, gpu_type, kind)
    snap: object                  # Snapshot identity (is-check)
    mut: int                      # snap.mut_count at speculation time
    fingerprint: tuple            # score-weight fingerprint
    result: object                # ScheduleResult
    consumed: bool = False


class CyclePipeline:
    """Per-QSCH pipeline state + hit/conflict/miss accounting.

    ``spec_seconds`` accumulates the wall time spent inside speculative
    work — the portion of per-cycle cost that overlaps binding in a
    pipelined deployment.  The scale benchmark reports critical-path
    cycle time as (total cycle time − spec_seconds).
    """

    def __init__(self, qsch: "QSCH") -> None:
        self.qsch = qsch
        self.speculated = 0   # speculations computed
        self.hits = 0         # consumed by RSCH under all guards
        self.conflicts = 0    # invalidated by the begin-of-cycle re-check
        self.misses = 0       # armed but never consumed (prediction miss)
        self.errors = 0       # speculation aborted by an exception
        self.spec_seconds = 0.0

    # ------------------------------------------------------------------
    def begin_cycle(self, state: "ClusterState") -> None:
        """Conflict re-check: arm the speculation for this cycle, or
        abandon it if anything mutated since the speculative refresh."""
        spec = self._spec
        self._spec = None
        self._armed = None
        if spec is None:
            return
        if (not state.dirty_nodes and not state.invariants_dirty
                and spec.snap.mut_count == spec.mut):
            self.qsch.rsch.speculation = spec
            self._armed = spec
        else:
            self.conflicts += 1

    def end_cycle(self, state: "ClusterState", now: float) -> None:
        """Account this cycle's speculation outcome, then speculate for
        the next cycle against the freshly-folded snapshot."""
        rsch = self.qsch.rsch
        armed, self._armed = self._armed, None
        rsch.speculation = None
        if armed is not None:
            if armed.consumed:
                self.hits += 1
            else:
                self.misses += 1
        self._speculate(state, now)

    # ------------------------------------------------------------------
    _spec: Optional[_Speculation] = None
    _armed: Optional[_Speculation] = None

    def _predict_head(self, ctx: CycleContext):
        """The job whose ``RSCH.schedule`` call opens the next cycle:
        head of the QueueSort-merged pending queue that passes BOTH
        admission tiers — ``try_place`` only reaches ``schedule`` past
        static quota and dynamic feasibility, so a blocked head must be
        skipped here exactly as the cycle will skip it.  Both Admit
        chains are pure reads (quota/feasibility), so probing them
        speculatively has no side effects.  A wrong prediction is
        harmless (counted as a miss, never consumed)."""
        qsch = self.qsch
        strict = getattr(qsch.queue_policy, "strict_head", False)
        for job in qsch.pending_jobs():
            if not qsch.static_admit(job, ctx):
                continue          # never enters the global pass
            if qsch.dynamic_admit(job, ctx):
                return job
            # Dynamically blocked: try_place bounces off before the
            # schedule call.  Best-Effort/Backfill move on to the next
            # job; Strict FIFO ends the cycle at its blocked head.
            if strict:
                return None
        return None

    def _speculate(self, state: "ClusterState", now: float) -> None:
        qsch = self.qsch
        rsch = qsch.rsch
        # Elastic shape selection happens inside try_place (before
        # schedule) and telemetry records speculative phases it should
        # not — both regimes schedule unspeculated.
        if (qsch.elastic is not None or qsch.obs is not None
                or rsch.obs is not None):
            return
        t0 = time.perf_counter()
        try:
            snap = qsch.snapshotter.refresh(state)
            ctx = CycleContext(running=qsch.running, quota=qsch.quota,
                               sched=qsch, rsch=rsch, state=state,
                               snap=snap, now=now, result=CycleResult())
            head = self._predict_head(ctx)
            if head is None:
                return
            fingerprint = rsch._weights_fingerprint(head, snap)
            mut = snap.mut_count
            result = rsch.schedule(
                head, snap,
                SchedulingContext(running=qsch.running, quota=qsch.quota))
            self._spec = _Speculation(
                job_uid=head.uid,
                shape=(head.n_pods, head.gpus_per_pod,
                       int(head.gpu_type), head.kind),
                snap=snap, mut=mut, fingerprint=fingerprint,
                result=result)
            self.speculated += 1
        except Exception:
            # Speculation is an optimization, never a correctness
            # dependency: a plugin that cannot run outside a live cycle
            # (e.g. reads CycleContext.now) disables it for that cycle.
            self._spec = None
            self.errors += 1
        finally:
            self.spec_seconds += time.perf_counter() - t0

    # ------------------------------------------------------------------
    def stats(self) -> dict:
        return {"speculated": self.speculated, "hits": self.hits,
                "conflicts": self.conflicts, "misses": self.misses,
                "errors": self.errors,
                "spec_seconds": self.spec_seconds}
