"""QSCH — the Queue-based Scheduler (paper §3.2), as a thin cycle
orchestrator over the framework's plugin chains.

QSCH owns everything that happens to a job *before* RSCH places it, but
every policy decision is a plugin (see :mod:`repro.core.framework`):

* per-tenant queues ordered by the **QueueSort** plugin (§3.2.2);
* two-tier admission via **Admit** plugins: static quota admission then
  dynamic resource admission (§3.2.1);
* the cycle body is a **QueuePolicy** plugin (Table 1): Strict FIFO,
  Best-Effort FIFO, Backfill (with head-timeout preemption via the
  BackfillHeadTimeout Preempt plugin);
* preemption control (§3.2.3) runs the profile's **Preempt** chain
  (priority, quota-reclamation) through one conservative engine: a
  preemption fires only when the dry-run accounting shows it actually
  unblocks the beneficiary;
* the gang commit is transactional via **Reserve/Permit** plugins
  (quota charge with rollback), followed by the **PostBind** chain;
* requeueing (§3.2.4): placement failures and preemptions return the
  job to its tenant queue instead of deadlocking the pipeline.

Snapshot discipline (§3.4.3): one ``snapshotter.take`` per cycle.  Every
mid-cycle mutation (placement commit, preemption release) is mirrored
onto the working snapshot via :meth:`Snapshot.apply_placement` /
:meth:`Snapshot.apply_release` deltas instead of re-copying the cluster,
which is what made large-gang cycles O(placements × nodes).

``QSCHConfig(policy=...)`` remains as a deprecation shim mapping the
legacy :class:`QueuePolicy` enum onto the built-in QueuePolicy plugins;
pass ``queue_policy=`` for direct plugin control.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Dict, List, Optional

from .cluster import ClusterState
from .framework.api import CycleContext, CycleResult, obs_phase
from .framework.builtin import (BackfillHeadTimeout, BackfillPolicy,
                                BestEffortFIFOPolicy, StrictFIFOPolicy)
from .job import Job, JobState
from .quota import QuotaManager
from .rsch import RSCH
from .snapshot import FullSnapshotter, IncrementalSnapshotter

__all__ = ["QSCH", "QSCHConfig", "QueuePolicy", "CycleResult"]


class QueuePolicy(enum.Enum):
    """Legacy queue-policy names (shim over the QueuePolicy plugins)."""

    STRICT_FIFO = "strict-fifo"
    BEST_EFFORT_FIFO = "best-effort-fifo"
    BACKFILL = "backfill"


@dataclasses.dataclass
class QSCHConfig:
    policy: QueuePolicy = QueuePolicy.BACKFILL
    # Backfill: head job older than this (seconds of queue wait while
    # blocked) may preempt backfilled jobs (Table 1).
    backfill_head_timeout: float = 1800.0
    # Priority/quota-reclamation preemption (§3.2.3): enabled but
    # conservative.  Gates the profile's Preempt chain.
    priority_preemption: bool = True
    # Upper bound on preemptions per cycle — keeps cascades in check
    # ("conservative preemption policy", §3.2.3).
    max_preemptions_per_cycle: int = 64


def _policy_from_config(config: QSCHConfig):
    if config.policy is QueuePolicy.STRICT_FIFO:
        return StrictFIFOPolicy()
    if config.policy is QueuePolicy.BEST_EFFORT_FIFO:
        return BestEffortFIFOPolicy()
    return BackfillPolicy(head_timeout=config.backfill_head_timeout,
                          preempt=BackfillHeadTimeout())


class QSCH:
    def __init__(self, quota: QuotaManager, rsch: RSCH,
                 config: Optional[QSCHConfig] = None,
                 incremental_snapshots: bool = True,
                 queue_policy=None, elastic=None) -> None:
        self.quota = quota
        self.rsch = rsch
        self.config = config or QSCHConfig()
        self.queue_policy = queue_policy or _policy_from_config(self.config)
        # Elastic-training manager (repro.core.elastic), or None for the
        # classic rigid-gang scheduler.  Jobs without an ElasticSpec are
        # never touched either way (byte-identity gate in
        # benchmarks/elastic_bench.py).
        self.elastic = elastic
        self.snapshotter = (IncrementalSnapshotter()
                            if incremental_snapshots else FullSnapshotter())
        # Optional cycle pipeline (repro.core.pipeline): speculative
        # snapshot+score of the next cycle's head job.  None = classic
        # strictly-sequential cycles (byte-identical default).
        self.pipeline = None
        # Tenant queues (§3.2.2): submission order is kept per tenant; the
        # global pass merges by the QueueSort plugin's key.
        self.queues: Dict[str, List[Job]] = {}
        self.running: Dict[int, Job] = {}
        # Head-of-line blocking bookkeeping for Backfill.
        self.head_blocked_since: Dict[int, float] = {}
        # The cycle's working snapshot, held only while ``cycle`` runs —
        # the target of mid-cycle health syncs (see ``sync_health``).
        self._working_snap = None
        # Optional telemetry facade (repro.obs): cycle spans, placement
        # decisions, preemption rationale.  None = zero-cost detached.
        self.obs = None
        # (plugin name, beneficiary uid) while a Preempt plugin's
        # evictions run — preempt_job stamps it into the audit record.
        self._preempt_source: Optional[tuple] = None

    # ------------------------------------------------------------------
    # Profiles
    # ------------------------------------------------------------------
    def profile_for(self, job: Job):
        return self.rsch.profiles.for_job(job)

    def enable_pipeline(self):
        """Turn on optimistic cycle pipelining (§3.4 latency hiding —
        see :mod:`repro.core.pipeline`).  Requires the incremental
        snapshotter: speculation refreshes the retained buffer in place,
        which a full snapshotter does not keep."""
        from .pipeline import CyclePipeline
        if not isinstance(self.snapshotter, IncrementalSnapshotter):
            raise ValueError(
                "pipelined cycles require incremental snapshots")
        self.pipeline = CyclePipeline(self)
        return self.pipeline

    # ------------------------------------------------------------------
    # Queue management
    # ------------------------------------------------------------------
    def submit(self, job: Job) -> None:
        job.state = JobState.PENDING
        self.queues.setdefault(job.tenant, []).append(job)

    def requeue(self, job: Job) -> None:
        """§3.2.4: failed/preempted workloads restart the pipeline."""
        job.requeue_count += 1
        job.state = JobState.PENDING
        job.placement = None
        job.backfilled = False
        self.queues.setdefault(job.tenant, []).append(job)

    def pending_jobs(self) -> List[Job]:
        out: List[Job] = []
        for q in self.queues.values():
            out.extend(j for j in q if j.state is JobState.PENDING)
        out.sort(key=self.rsch.profiles.queue_sort.key)
        return out

    def queue_depth(self) -> int:
        """Pending-job count.  Plain sum over the tenant queues — this
        runs every simulator tick via metrics sampling, so it must not
        pay the full ``pending_jobs()`` merge-and-sort."""
        return sum(1 for q in self.queues.values()
                   for j in q if j.state is JobState.PENDING)

    def _remove_from_queue(self, job: Job) -> None:
        q = self.queues.get(job.tenant, [])
        if job in q:
            q.remove(job)

    # ------------------------------------------------------------------
    # Admission (§3.2.1): the profile's Admit chains
    # ------------------------------------------------------------------
    def static_admit(self, job: Job, ctx: CycleContext) -> bool:
        return all(p.admit(job, ctx)
                   for p in self.profile_for(job).admit_chain("static"))

    def dynamic_admit(self, job: Job, ctx: CycleContext) -> bool:
        return all(p.admit(job, ctx)
                   for p in self.profile_for(job).admit_chain("dynamic"))

    # ------------------------------------------------------------------
    # One scheduling cycle
    # ------------------------------------------------------------------
    def cycle(self, state: ClusterState, now: float) -> CycleResult:
        obs = self.obs
        if obs is not None:
            obs.cycle_begin(now)
        result = CycleResult()
        if self.pipeline is not None:
            self.pipeline.begin_cycle(state)
        with obs_phase(obs, "snapshot"):
            snap = self.snapshotter.take(state)
        self._working_snap = snap
        result.snapshot_version = snap.version
        ctx = CycleContext(running=self.running, quota=self.quota,
                           sched=self, rsch=self.rsch, state=state,
                           snap=snap, now=now, result=result)
        try:
            with obs_phase(obs, "queue-sort"):
                candidates = self.pending_jobs()
                # Jobs failing static quota stay in the tenant queue and
                # never enter the global pass (§3.2.2).
                global_queue = []
                for job in candidates:
                    if self.static_admit(job, ctx):
                        global_queue.append(job)
                    else:
                        result.admit_rejected += 1
            if global_queue:
                self.queue_policy.run_cycle(global_queue, ctx)

                # Preempt chain (§3.2.3): if the highest-priority pending
                # job is still blocked, conservatively evict work that
                # provably unblocks it (priority, then quota reclamation).
                if (self.config.priority_preemption and result.blocked_head
                        is not None):
                    with obs_phase(obs, "preempt"):
                        self._run_preempt_chain(result.blocked_head, ctx)
            # Elastic grow pass: running shrunk gangs may reshape toward
            # their ideal plan at a checkpoint boundary — runs even with
            # an empty queue (freed capacity is what triggers growth).
            if self.elastic is not None:
                with obs_phase(obs, "elastic"):
                    self.elastic.grow_pass(ctx)
            return result
        finally:
            if self.pipeline is not None:
                self.pipeline.end_cycle(state, now)
            self._working_snap = None
            if obs is not None:
                obs.cycle_end(result, ctx)

    def sync_health(self, state: ClusterState, nodes) -> None:
        """Mirror an external health/drain mutation onto the scheduler's
        snapshot view.  Two staleness windows exist:

        * *mid-cycle*: the working snapshot took its copy before the
          mutation — refresh its rows and drop the delta-invariant
          caches (pool masks, healthy-capacity counts), or this cycle's
          later binds can land on a dead/draining node;
        * *between cycles* with incremental snapshots: the retained
          buffer is refreshed from ``state.dirty_nodes`` at the next
          ``take`` — nothing to do here.
        """
        if self._working_snap is not None:
            self._working_snap.apply_health(state, nodes)

    # ------------------------------------------------------------------
    # Placement attempt: admission -> RSCH -> Reserve/Permit -> bind
    # ------------------------------------------------------------------
    def try_place(self, job: Job, ctx: CycleContext,
                  backfilled: bool = False) -> bool:
        result = ctx.result
        obs = self.obs
        # Elastic plan selection runs FIRST: admission, quota and
        # placement below all see the shape this attempt actually binds.
        if self.elastic is not None and job.elastic is not None:
            self.elastic.select_shape(job, ctx)
        # Re-check static quota: earlier placements in this cycle may have
        # consumed it since the global-queue filter ran (§3.2.1).
        if not self.static_admit(job, ctx):
            result.admit_rejected += 1
            if obs is not None:
                obs.emit_reject(job, None, ctx, "static-admit")
            return False
        if not self.dynamic_admit(job, ctx):
            result.infeasible += 1
            if obs is not None:
                obs.emit_reject(job, None, ctx, "dynamic-admit")
            return False
        job.state = JobState.ADMITTED
        job.admit_time = ctx.now
        sched = self.rsch.schedule(job, ctx.snap, ctx)
        if sched.placement is None:
            # Dynamic admission passed but placement failed (fragmentation
            # or topology): requeue mechanism (§3.2.4).
            self._remove_from_queue(job)
            self.requeue(job)
            result.requeues += 1
            if obs is not None:
                obs.emit_reject(job, sched, ctx,
                                sched.reason or "no-placement")
            return False
        profile = self.profile_for(job)
        # Reserve/Permit (§3.3.2 transactional gang commit): every
        # successful Reserve is rolled back if a later plugin fails.
        with obs_phase(obs, "reserve-permit"):
            reserved = []
            ok = True
            for plugin in profile.reserve:
                if plugin.reserve(job, sched.placement, ctx):
                    reserved.append(plugin)
                else:
                    ok = False
                    break
            if ok:
                for plugin in profile.permit:
                    if not plugin.permit(job, sched.placement, ctx):
                        ok = False
                        break
            if not ok:
                for plugin in reversed(reserved):
                    plugin.unreserve(job, sched.placement, ctx)
                self._remove_from_queue(job)
                self.requeue(job)
                result.requeues += 1
        if not ok:
            if obs is not None:
                obs.emit_reject(job, sched, ctx, "reserve-permit")
            return False
        with obs_phase(obs, "bind"):
            ctx.state.allocate(job, sched.placement)
            # Mirror the commit onto the working snapshot (§3.4.3): later
            # placements this cycle see it without re-taking the cluster.
            ctx.snap.apply_placement(sched.placement)
            job.placement = sched.placement
            job.state = JobState.RUNNING
            job.start_time = ctx.now
            job.backfilled = backfilled
            self._remove_from_queue(job)
            self.running[job.uid] = job
            result.scheduled.append(job)
            for plugin in profile.post_bind:
                plugin.post_bind(job, sched.placement, ctx)
        if obs is not None:
            obs.emit_bind(job, sched, ctx)
        return True

    # -- lifecycle callbacks from the simulator --------------------------
    def on_complete(self, job: Job, state: ClusterState, now: float) -> None:
        if job.uid in self.running:
            state.release(job.uid)
            self.quota.refund(job)
            del self.running[job.uid]
        job.state = JobState.COMPLETED
        job.end_time = now

    def on_interrupted(self, job: Job, state: ClusterState, now: float,
                       remaining: float) -> None:
        """Requeue-on-failure (§3.2.4 applied to the dynamics
        subsystem): a job killed by a node/GPU failure or drain eviction
        releases its devices, refunds quota, and re-enters its tenant
        queue with ``remaining`` seconds of work (computed by the
        recovery model from its checkpoint state)."""
        if job.uid in self.running:
            state.release(job.uid)
            self.quota.refund(job)
            del self.running[job.uid]
        job.state = JobState.INTERRUPTED
        job.interrupt_count += 1
        job.attempt += 1
        job.duration = max(0.0, float(remaining))
        job.end_time = None
        self.requeue(job)

    def preempt_job(self, job: Job, ctx: CycleContext) -> None:
        """Evict one running job and requeue it (used by the preemption
        engine and the Preempt plugins)."""
        released = ctx.state.release(job.uid)
        ctx.snap.apply_release(released)
        self.quota.refund(job)
        del self.running[job.uid]
        job.state = JobState.PREEMPTED
        job.preempt_count += 1
        job.end_time = None
        ctx.result.preempted.append(job)
        self.requeue(job)
        ctx.result.requeues += 1
        if self.obs is not None:
            self.obs.emit_preempt(job, ctx, self._preempt_source)

    # -- conservative preemption engine (§3.2.3) --------------------------
    def structurally_placeable(self, job: Job, ctx: CycleContext) -> bool:
        """Could the job fit even on an EMPTY pool?  Guards the
        preemption engine: the free+reclaimable dry-run is blind to
        per-node granularity, so a pod larger than any node's healthy
        capacity (or a gang wider than the pool's total slots) would
        trigger a futile eviction storm every cycle — victims die, the
        beneficiary stays blocked, repeat."""
        pool = ctx.snap.candidate_pool(int(job.gpu_type))
        slots = ctx.snap.healthy_per_node() // job.gpus_per_pod
        return int(slots[pool].sum()) >= job.n_pods

    def _run_preempt_chain(self, job: Job, ctx: CycleContext) -> None:
        """First Preempt plugin with victims wins; evictions only happen
        when the dry-run shows they can make ``job`` feasible.  A plugin
        without victims gets its ``execute`` hook instead (execute-only
        plugins own their whole flow, including placement)."""
        if not self.structurally_placeable(job, ctx):
            return
        victims: List[Job] = []
        for plugin in self.profile_for(job).preempt:
            victims = plugin.victims(job, ctx)
            if victims:
                break
            self._preempt_source = (plugin.name, job.uid)
            try:
                plugin.execute(job, ctx)
            finally:
                self._preempt_source = None
            if job.state is JobState.RUNNING:
                return
        if not victims:
            return
        pool_free = ctx.state.pool_free(job.gpu_type)
        reclaimable = sum(v.n_gpus for v in victims)
        if pool_free + reclaimable < job.n_gpus:
            return
        victims.sort(key=lambda j: (j.priority, -(j.start_time or 0.0)))
        budget = self.config.max_preemptions_per_cycle
        self._preempt_source = (plugin.name, job.uid)
        try:
            for victim in victims:
                if budget <= 0:
                    break
                if self.dynamic_admit(job, ctx):
                    break
                self.preempt_job(victim, ctx)
                budget -= 1
        finally:
            self._preempt_source = None
        if self.dynamic_admit(job, ctx):
            self.try_place(job, ctx)
