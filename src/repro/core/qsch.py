"""QSCH — the Queue-based Scheduler (paper §3.2).

QSCH owns everything that happens to a job *before* RSCH places it:

* per-tenant queues with the paper's ordering (priority desc, submit time,
  job size as tiebreaker) (§3.2.2);
* two-tier admission: static quota admission then dynamic resource
  admission (§3.2.1), at job level for gang jobs, pod level otherwise;
* queueing policies (Table 1): Strict FIFO, Best-Effort FIFO, Backfill
  (with head-timeout preemption of backfilled jobs);
* preemption control (§3.2.3): priority preemption, quota-reclamation
  preemption, backfill preemption — all deliberately conservative: a
  preemption fires only when the dry-run accounting shows it actually
  unblocks the beneficiary;
* requeueing (§3.2.4): placement failures and preemptions return the job
  to its tenant queue instead of deadlocking the pipeline.

Snapshot discipline (§3.4.3): one ``snapshotter.take`` per cycle.  Every
mid-cycle mutation (placement commit, preemption release) is mirrored
onto the working snapshot via :meth:`Snapshot.apply_placement` /
:meth:`Snapshot.apply_release` deltas instead of re-copying the cluster,
which is what made large-gang cycles O(placements × nodes).
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from .cluster import ClusterState
from .job import Job, JobKind, JobState
from .quota import QuotaManager, QuotaMode
from .rsch import RSCH, ScheduleResult
from .snapshot import FullSnapshotter, IncrementalSnapshotter, Snapshot


class QueuePolicy(enum.Enum):
    STRICT_FIFO = "strict-fifo"
    BEST_EFFORT_FIFO = "best-effort-fifo"
    BACKFILL = "backfill"


@dataclasses.dataclass
class QSCHConfig:
    policy: QueuePolicy = QueuePolicy.BACKFILL
    # Backfill: head job older than this (seconds of queue wait while
    # blocked) may preempt backfilled jobs (Table 1).
    backfill_head_timeout: float = 1800.0
    # Priority preemption (§3.2.3): enabled but conservative.
    priority_preemption: bool = True
    # Upper bound on preemptions per cycle — keeps cascades in check
    # ("conservative preemption policy", §3.2.3).
    max_preemptions_per_cycle: int = 64


@dataclasses.dataclass
class CycleResult:
    scheduled: List[Job] = dataclasses.field(default_factory=list)
    preempted: List[Job] = dataclasses.field(default_factory=list)
    blocked_head: Optional[Job] = None
    snapshot_version: int = 0


class QSCH:
    def __init__(self, quota: QuotaManager, rsch: RSCH,
                 config: Optional[QSCHConfig] = None,
                 incremental_snapshots: bool = True) -> None:
        self.quota = quota
        self.rsch = rsch
        self.config = config or QSCHConfig()
        self.snapshotter = (IncrementalSnapshotter()
                            if incremental_snapshots else FullSnapshotter())
        # Tenant queues (§3.2.2): submission order is kept per tenant; the
        # global pass merges by order_key.
        self.queues: Dict[str, List[Job]] = {}
        self.running: Dict[int, Job] = {}
        # Head-of-line blocking bookkeeping for Backfill.
        self._head_blocked_since: Dict[int, float] = {}

    # ------------------------------------------------------------------
    # Queue management
    # ------------------------------------------------------------------
    def submit(self, job: Job) -> None:
        job.state = JobState.PENDING
        self.queues.setdefault(job.tenant, []).append(job)

    def requeue(self, job: Job) -> None:
        """§3.2.4: failed/preempted workloads restart the pipeline."""
        job.requeue_count += 1
        job.state = JobState.PENDING
        job.placement = None
        job.backfilled = False
        self.queues.setdefault(job.tenant, []).append(job)

    def pending_jobs(self) -> List[Job]:
        out: List[Job] = []
        for q in self.queues.values():
            out.extend(j for j in q if j.state is JobState.PENDING)
        out.sort(key=Job.order_key)
        return out

    def queue_depth(self) -> int:
        return len(self.pending_jobs())

    def _remove_from_queue(self, job: Job) -> None:
        q = self.queues.get(job.tenant, [])
        if job in q:
            q.remove(job)

    # ------------------------------------------------------------------
    # Admission (§3.2.1)
    # ------------------------------------------------------------------
    def _static_admit(self, job: Job) -> bool:
        return self.quota.can_admit(job)

    def _dynamic_admit(self, job: Job, snap: Snapshot) -> bool:
        return self.rsch.feasible(job, snap)

    # ------------------------------------------------------------------
    # One scheduling cycle
    # ------------------------------------------------------------------
    def cycle(self, state: ClusterState, now: float) -> CycleResult:
        result = CycleResult()
        snap = self.snapshotter.take(state)
        result.snapshot_version = snap.version
        candidates = self.pending_jobs()
        # Jobs failing static quota stay in the tenant queue and never
        # enter the global pass (§3.2.2).
        global_queue = [j for j in candidates if self._static_admit(j)]
        if not global_queue:
            return result

        policy = self.config.policy
        if policy is QueuePolicy.STRICT_FIFO:
            self._cycle_strict(global_queue, state, snap, now, result)
        elif policy is QueuePolicy.BEST_EFFORT_FIFO:
            self._cycle_best_effort(global_queue, state, snap, now, result)
        else:
            self._cycle_backfill(global_queue, state, snap, now, result)

        # Priority preemption (§3.2.3): if the highest-priority pending job
        # is still blocked, conservatively evict strictly-lower-priority
        # preemptible work that provably unblocks it.
        if (self.config.priority_preemption and result.blocked_head
                is not None):
            self._try_priority_preemption(result.blocked_head, state, snap,
                                          now, result)
        return result

    # -- policy bodies --------------------------------------------------
    def _cycle_strict(self, queue: List[Job], state: ClusterState,
                      snap: Snapshot, now: float, result: CycleResult
                      ) -> None:
        """Table 1 Strict FIFO: one blocked head blocks everyone."""
        for job in queue:
            if not self._try_place(job, state, snap, now, result):
                result.blocked_head = job
                return

    def _cycle_best_effort(self, queue: List[Job], state: ClusterState,
                           snap: Snapshot, now: float, result: CycleResult
                           ) -> None:
        """Table 1 Best-Effort FIFO: skip unschedulable jobs.  No
        preemption -> large jobs can starve (reproduced in Fig 4)."""
        blocked: Optional[Job] = None
        for job in queue:
            if not self._try_place(job, state, snap, now, result) \
                    and blocked is None:
                blocked = job
        # Note: deliberately do NOT set result.blocked_head -> no
        # priority preemption assist; that is what distinguishes the
        # policy in the paper's Fig 4 starvation result.

    def _cycle_backfill(self, queue: List[Job], state: ClusterState,
                        snap: Snapshot, now: float, result: CycleResult
                        ) -> None:
        """Table 1 Backfill: smaller jobs may run behind a blocked head;
        after ``backfill_head_timeout`` the head preempts them."""
        head = queue[0]
        if self._try_place(head, state, snap, now, result):
            self._head_blocked_since.pop(head.uid, None)
            remaining = queue[1:]
        else:
            blocked_since = self._head_blocked_since.setdefault(
                head.uid, now)
            if now - blocked_since >= self.config.backfill_head_timeout:
                self._backfill_preempt_for(head, state, snap, now, result)
                if self._try_place(head, state, snap, now, result):
                    self._head_blocked_since.pop(head.uid, None)
                else:
                    result.blocked_head = head
            else:
                result.blocked_head = head
            remaining = queue[1:]
        # Backfill pass: later jobs may use idle resources now.
        for job in remaining:
            if job.state is not JobState.PENDING:
                continue
            self._try_place(job, state, snap, now, result,
                            backfilled=result.blocked_head is not None)

    # -- placement ------------------------------------------------------
    def _try_place(self, job: Job, state: ClusterState, snap: Snapshot,
                   now: float, result: CycleResult,
                   backfilled: bool = False) -> bool:
        # Re-check static quota: earlier placements in this cycle may have
        # consumed it since the global-queue filter ran (§3.2.1).
        if not self._static_admit(job):
            return False
        if not self._dynamic_admit(job, snap):
            return False
        job.state = JobState.ADMITTED
        job.admit_time = now
        sched = self.rsch.schedule(job, snap)
        if sched.placement is None:
            # Dynamic admission passed but placement failed (fragmentation
            # or topology): requeue mechanism (§3.2.4).
            self._remove_from_queue(job)
            self.requeue(job)
            return False
        self.quota.charge(job)
        state.allocate(job, sched.placement)
        # Mirror the commit onto the working snapshot (§3.4.3): later
        # placements this cycle see it without re-taking the cluster.
        snap.apply_placement(sched.placement)
        job.placement = sched.placement
        job.state = JobState.RUNNING
        job.start_time = now
        job.backfilled = backfilled
        self._remove_from_queue(job)
        self.running[job.uid] = job
        result.scheduled.append(job)
        return True

    # -- lifecycle callbacks from the simulator --------------------------
    def on_complete(self, job: Job, state: ClusterState, now: float) -> None:
        if job.uid in self.running:
            state.release(job.uid)
            self.quota.refund(job)
            del self.running[job.uid]
        job.state = JobState.COMPLETED
        job.end_time = now

    def _preempt(self, job: Job, state: ClusterState, snap: Snapshot,
                 now: float, result: CycleResult) -> None:
        released = state.release(job.uid)
        snap.apply_release(released)
        self.quota.refund(job)
        del self.running[job.uid]
        job.state = JobState.PREEMPTED
        job.preempt_count += 1
        job.end_time = None
        result.preempted.append(job)
        self.requeue(job)

    # -- preemption helpers (§3.2.3) --------------------------------------
    def _backfill_preempt_for(self, head: Job, state: ClusterState,
                              snap: Snapshot, now: float,
                              result: CycleResult) -> None:
        """Backfill preemption: evict backfilled jobs (newest first) until
        the head becomes feasible — but only if it provably can become
        feasible (conservative policy)."""
        victims = [j for j in self.running.values()
                   if j.backfilled and j.preemptible
                   and j.gpu_type == head.gpu_type]
        victims.sort(key=lambda j: -(j.start_time or 0.0))
        pool_free = state.pool_free(head.gpu_type)
        reclaimable = sum(v.n_gpus for v in victims)
        if pool_free + reclaimable < head.n_gpus:
            return  # preemption cannot help; don't thrash
        budget = self.config.max_preemptions_per_cycle
        for victim in victims:
            if budget <= 0:
                break
            if self._dynamic_admit(head, snap) and \
                    self.rsch.schedule(head, snap).placement is not None:
                return
            self._preempt(victim, state, snap, now, result)
            budget -= 1

    def _try_priority_preemption(self, job: Job, state: ClusterState,
                                 snap: Snapshot, now: float,
                                 result: CycleResult) -> None:
        victims = [j for j in self.running.values()
                   if j.priority < job.priority and j.preemptible
                   and j.gpu_type == job.gpu_type]
        if not victims:
            # Quota reclamation preemption: shared-mode borrowers block the
            # owner's quota (§3.2.3).
            victims = self.quota.reclaim_candidates(
                job.tenant, job.gpu_type, list(self.running.values()))
        if not victims:
            return
        pool_free = state.pool_free(job.gpu_type)
        reclaimable = sum(v.n_gpus for v in victims)
        if pool_free + reclaimable < job.n_gpus:
            return
        victims.sort(key=lambda j: (j.priority, -(j.start_time or 0.0)))
        budget = self.config.max_preemptions_per_cycle
        for victim in victims:
            if budget <= 0:
                break
            if self._dynamic_admit(job, snap):
                break
            self._preempt(victim, state, snap, now, result)
            budget -= 1
        if self._dynamic_admit(job, snap):
            self._try_place(job, state, snap, now, result)
