"""Synthetic workload generation matching the paper's job population.

§2 / §5.1.1 describe the shape of real AI-cluster workloads:

* >90 % of jobs use fewer than 8 GPUs, yet contribute <10 % of GPU-time;
* jobs of >=256 GPUs, though rare, consume over half of total GPU-time;
* the §5.1 test cluster sees sizes from 1 to 2048 GPUs.

``training_trace`` reproduces that distribution (validated in
``benchmarks/fig2_job_distribution.py``); ``inference_trace`` produces the
§5.2 multi-tenant replica fleets.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .job import Job, JobKind, PRIO_HIGH, PRIO_LOW, PRIO_NORMAL

# (n_gpus, probability, mean duration scale) — probabilities follow the
# paper's ">90% below 8 GPUs" long tail; duration scales are tuned so the
# GPU-time shares land on the paper's ">50% from >=256-GPU jobs" /
# "<10% from <8-GPU jobs" split (checked by the Fig 2 benchmark).
TRAIN_SIZE_TABLE: Sequence[Tuple[int, float, float]] = (
    (1, 0.40, 0.6),
    (2, 0.22, 0.6),
    (4, 0.18, 0.8),
    (8, 0.11, 1.0),
    (16, 0.03, 1.2),
    (32, 0.02, 1.5),
    (64, 0.013, 2.0),
    (128, 0.009, 3.0),
    (256, 0.008, 5.0),
    (512, 0.005, 6.0),
    (1024, 0.003, 8.0),
    (2048, 0.002, 10.0),
)


@dataclasses.dataclass
class TraceStats:
    jobs_by_size: Dict[int, int]
    gpu_time_by_size: Dict[int, float]

    def job_fraction_below(self, n: int) -> float:
        total = sum(self.jobs_by_size.values())
        small = sum(c for s, c in self.jobs_by_size.items() if s < n)
        return small / total if total else 0.0

    def gpu_time_fraction_at_least(self, n: int) -> float:
        total = sum(self.gpu_time_by_size.values())
        big = sum(c for s, c in self.gpu_time_by_size.items() if s >= n)
        return big / total if total else 0.0


def _pods_for(n_gpus: int, gpus_per_node: int) -> Tuple[int, int]:
    """Split a request into (n_pods, gpus_per_pod): multi-node jobs use
    whole-node pods; small jobs are single-pod."""
    if n_gpus <= gpus_per_node:
        return 1, n_gpus
    if n_gpus % gpus_per_node:
        raise ValueError("multi-node sizes must be node multiples")
    return n_gpus // gpus_per_node, gpus_per_node


def training_trace(n_jobs: int, *, seed: int = 0,
                   arrival_rate_per_hour: float = 120.0,
                   mean_duration_s: float = 7200.0,
                   gpus_per_node: int = 8,
                   gpu_type: int = 0,
                   gpu_types: Optional[Sequence[int]] = None,
                   type_probs: Optional[Sequence[float]] = None,
                   tenants: Sequence[str] = ("t0",),
                   tenant_regions: Optional[Dict[str, str]] = None,
                   start_uid: int = 0) -> List[Job]:
    """Poisson arrivals with the §5.1.1 size/duration population.

    ``gpu_types`` (mirroring ``inference_trace``) samples each job's GPU
    model from a mix — optionally weighted by ``type_probs`` — instead of
    pinning the whole trace to one ``gpu_type``; heterogeneous-pool and
    federation scenarios need mixed traces without hand-building them.
    Types draw from a rng derived from ``seed`` so the base population
    (sizes, arrivals, durations, tenants) is identical to the
    homogeneous trace with the same seed — heterogeneity A/Bs compare
    the SAME jobs.  ``tenant_regions`` stamps each job's home region
    from its tenant (multi-region tenancy for the federation GSCH).
    Both default to off and leave existing seeded traces untouched.
    """
    rng = np.random.default_rng(seed)
    type_rng = np.random.default_rng([seed, 0x67747970])  # "ggtyp"
    sizes = np.asarray([s for s, _, _ in TRAIN_SIZE_TABLE])
    probs = np.asarray([p for _, p, _ in TRAIN_SIZE_TABLE])
    probs = probs / probs.sum()
    dur_scale = {s: d for s, _, d in TRAIN_SIZE_TABLE}
    tprobs = None
    if gpu_types is not None and type_probs is not None:
        tprobs = np.asarray(list(type_probs), dtype=float)
        tprobs = tprobs / tprobs.sum()
    inter = rng.exponential(3600.0 / arrival_rate_per_hour, size=n_jobs)
    arrivals = np.cumsum(inter)
    jobs: List[Job] = []
    for i in range(n_jobs):
        n_gpus = int(rng.choice(sizes, p=probs))
        n_pods, per_pod = _pods_for(n_gpus, gpus_per_node)
        duration = float(rng.exponential(
            mean_duration_s * dur_scale[n_gpus]))
        duration = max(60.0, duration)
        tenant = str(rng.choice(list(tenants)))
        if gpu_types is not None:
            jtype = int(type_rng.choice(list(gpu_types), p=tprobs))
        else:
            jtype = gpu_type
        jobs.append(Job(
            uid=start_uid + i,
            tenant=tenant,
            gpu_type=jtype,
            n_pods=n_pods,
            gpus_per_pod=per_pod,
            kind=JobKind.TRAIN,
            gang=True,
            priority=PRIO_NORMAL,
            submit_time=float(arrivals[i]),
            duration=duration,
            region=(tenant_regions or {}).get(tenant),
        ))
    return jobs


def inference_trace(n_jobs: int, *, seed: int = 0,
                    arrival_rate_per_hour: float = 30.0,
                    mean_duration_s: float = 4 * 3600.0,
                    gpu_types: Sequence[int] = (0,),
                    tenants: Sequence[str] = ("t0", "t1", "t2"),
                    tenant_regions: Optional[Dict[str, str]] = None,
                    max_replicas: int = 4,
                    start_uid: int = 100_000) -> List[Job]:
    """§5.2 inference fleets: small per-replica pods, several replicas,
    high priority, non-gang (pod-level admission)."""
    rng = np.random.default_rng(seed)
    inter = rng.exponential(3600.0 / arrival_rate_per_hour, size=n_jobs)
    arrivals = np.cumsum(inter)
    jobs: List[Job] = []
    for i in range(n_jobs):
        per_pod = int(rng.choice([1, 1, 2, 2, 4, 8]))
        replicas = int(rng.integers(1, max_replicas + 1))
        tenant = str(rng.choice(list(tenants)))
        jobs.append(Job(
            uid=start_uid + i,
            tenant=tenant,
            region=(tenant_regions or {}).get(tenant),
            gpu_type=int(rng.choice(list(gpu_types))),
            n_pods=replicas,
            gpus_per_pod=per_pod,
            kind=JobKind.INFER,
            gang=False,
            priority=PRIO_HIGH,
            submit_time=float(arrivals[i]),
            duration=max(600.0, float(rng.exponential(mean_duration_s))),
        ))
    return jobs


DAY_S = 86_400.0


def diurnal_demand(t: float, base: float, peak: float,
                   period: float = DAY_S,
                   peak_hour: float = 14.0) -> float:
    """Smooth diurnal (tidal) demand curve.

    Raised cosine over one ``period``: ``peak`` at ``peak_hour`` (in
    hours from the period start), falling to ``base`` half a period
    away.  This is the demand signal the tidal autoscaler tracks —
    inference traffic that crests mid-afternoon and bottoms out
    overnight (§2 "inference services" diurnal load; the reclaimed
    night capacity backfills training).
    """
    frac = (t % period) / period
    x = np.cos(2.0 * np.pi * (frac - peak_hour * 3600.0 / period))
    return float(base + (peak - base) * (x + 1.0) / 2.0)


def backfill_training_trace(n_jobs: int, *, seed: int = 0,
                            sizes: Sequence[int] = (8, 16, 32, 64),
                            size_probs: Sequence[float] = (.3, .3, .25,
                                                           .15),
                            duration_range_h: Tuple[float, float] = (3.0,
                                                                     5.0),
                            submit_window_s: float = 3600.0,
                            gpus_per_node: int = 8,
                            gpu_type: int = 0,
                            tenant: str = "batch",
                            start_uid: int = 500_000) -> List[Job]:
    """Low-priority, preemptible training backlog for tidal scenarios:
    chunky jobs submitted inside one window, deep enough to soak up
    whatever the tide hands back overnight and be preempted away at the
    morning ramp (exercising PriorityPreempt)."""
    rng = np.random.default_rng(seed)
    lo_h, hi_h = duration_range_h
    jobs: List[Job] = []
    for i in range(n_jobs):
        n_gpus = int(rng.choice(list(sizes), p=list(size_probs)))
        n_pods, per_pod = _pods_for(max(n_gpus, 1), gpus_per_node)
        jobs.append(Job(
            uid=start_uid + i, tenant=tenant, gpu_type=gpu_type,
            n_pods=n_pods, gpus_per_pod=per_pod,
            priority=PRIO_LOW, preemptible=True,
            submit_time=float(rng.uniform(0.0, submit_window_s)),
            duration=float(rng.uniform(lo_h, hi_h)) * 3600.0))
    return jobs


# ---------------------------------------------------------------------------
# Request-level serving workload (the serving fabric's input)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class QueryClass:
    """One class of queries in the serving mix.

    ``quality_floor`` is the minimum model capability (0..1, same scale
    as ``ReplicaSpec.capability``) that produces an acceptable answer;
    ``latency_slo_s`` bounds end-to-end latency (queue wait + prefill +
    decode).  ``weight`` is the class's share of the arrival mix."""
    name: str
    prompt_mean: int = 128          # mean prompt tokens (geometric-ish)
    output_mean: int = 64           # mean output tokens
    quality_floor: float = 0.0
    latency_slo_s: float = 30.0
    weight: float = 1.0


#: A mixed production-style query population: short chat turns dominate,
#: long-document summarisation is rare but heavy, code queries demand a
#: capable model, background embedding-style traffic tolerates anything.
DEFAULT_QUERY_CLASSES: Tuple[QueryClass, ...] = (
    QueryClass("chat", prompt_mean=96, output_mean=48,
               quality_floor=0.35, latency_slo_s=15.0, weight=0.55),
    QueryClass("code", prompt_mean=256, output_mean=128,
               quality_floor=0.70, latency_slo_s=45.0, weight=0.20),
    QueryClass("summarize", prompt_mean=1024, output_mean=96,
               quality_floor=0.50, latency_slo_s=90.0, weight=0.10),
    QueryClass("batch", prompt_mean=192, output_mean=32,
               quality_floor=0.0, latency_slo_s=300.0, weight=0.15),
)


@dataclasses.dataclass
class ServeRequest:
    """One query arriving at the serving fabric router."""
    uid: int
    qclass: QueryClass
    arrival_s: float
    prompt_tokens: int
    output_tokens: int

    @property
    def total_tokens(self) -> int:
        return self.prompt_tokens + self.output_tokens

    @property
    def deadline_s(self) -> float:
        return self.arrival_s + self.qclass.latency_slo_s


def request_trace(n_requests: int, *, seed: int = 0,
                  classes: Sequence[QueryClass] = DEFAULT_QUERY_CLASSES,
                  base_rps: float = 2.0, peak_rps: float = 10.0,
                  period_s: float = DAY_S, peak_hour: float = 14.0,
                  burst_rate_per_hour: float = 2.0,
                  burst_duration_s: float = 120.0,
                  burst_multiplier: float = 4.0) -> List[ServeRequest]:
    """Diurnal + bursty request arrivals over a mixed query-class population.

    A nonhomogeneous Poisson process sampled by thinning: the base rate
    rides :func:`diurnal_demand` between ``base_rps`` and ``peak_rps``
    (``period_s`` compresses a whole diurnal cycle for fast benches),
    with Poisson-arriving burst windows that multiply the instantaneous
    rate by ``burst_multiplier`` for ``burst_duration_s`` — the "sudden
    hot query" spikes that separate load-aware from load-blind routing.
    Per-request prompt/output lengths are geometric around the class
    means (min 4 / min 1 tokens)."""
    rng = np.random.default_rng(seed)
    cls = list(classes)
    weights = np.asarray([c.weight for c in cls], float)
    weights = weights / weights.sum()
    # Burst window starts: Poisson over a generous horizon.
    horizon = period_s * max(4.0, 8.0 * n_requests / (base_rps * period_s))
    n_bursts = rng.poisson(burst_rate_per_hour * horizon / 3600.0)
    burst_starts = np.sort(rng.uniform(0.0, horizon, size=n_bursts))

    def rate(t: float) -> float:
        r = diurnal_demand(t, base_rps, peak_rps, period=period_s,
                           peak_hour=peak_hour)
        j = np.searchsorted(burst_starts, t, side="right") - 1
        if j >= 0 and t - burst_starts[j] < burst_duration_s:
            r *= burst_multiplier
        return r

    rate_max = peak_rps * burst_multiplier
    out: List[ServeRequest] = []
    t = 0.0
    while len(out) < n_requests:
        t += float(rng.exponential(1.0 / rate_max))
        if rng.uniform() > rate(t) / rate_max:
            continue                      # thinned away
        ci = int(rng.choice(len(cls), p=weights))
        c = cls[ci]
        out.append(ServeRequest(
            uid=len(out),
            qclass=c,
            arrival_s=t,
            prompt_tokens=max(4, int(rng.geometric(1.0 / c.prompt_mean))),
            output_tokens=max(1, int(rng.geometric(1.0 / c.output_mean))),
        ))
    return out


def trace_stats(jobs: Sequence[Job]) -> TraceStats:
    by_size: Dict[int, int] = {}
    gpu_time: Dict[int, float] = {}
    for j in jobs:
        by_size[j.n_gpus] = by_size.get(j.n_gpus, 0) + 1
        gpu_time[j.n_gpus] = gpu_time.get(j.n_gpus, 0.0) \
            + j.n_gpus * j.duration
    return TraceStats(jobs_by_size=by_size, gpu_time_by_size=gpu_time)
