"""Checkpoint-restart recovery model.

When a failure (or drain eviction) kills a job, the engine asks this
model what the *next attempt* costs.  The model mirrors the model-level
checkpointing story (:mod:`repro.ckpt.store`, wired into
``examples/train_e2e.py``) at scheduler granularity:

* training writes a checkpoint every ``interval_s`` seconds of
  progress; work since the last checkpoint is recomputed ("lost work");
* every restart pays ``restart_overhead_s`` up front (restore the
  checkpoint, rebuild the gang, warm caches);
* the ``scratch`` baseline never checkpoints: every failure restarts
  the job from zero — the paper-motivating ablation for
  ``benchmarks/dynamics_bench.py``;
* inference/debug pods are stateless services: interrupted serving time
  is not recomputed, only the restart overhead is paid.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

from ..job import Job, JobKind


@dataclasses.dataclass
class CheckpointModel:
    """``mode`` is ``"checkpoint"`` (periodic checkpoints) or
    ``"scratch"`` (restart from zero)."""

    interval_s: float = 600.0
    restart_overhead_s: float = 120.0
    mode: str = "checkpoint"

    def __post_init__(self) -> None:
        if self.mode not in ("checkpoint", "scratch"):
            raise ValueError(f"unknown recovery mode {self.mode!r}")
        if self.interval_s <= 0:
            raise ValueError("checkpoint interval must be positive")

    # ------------------------------------------------------------------
    def attempt_overhead(self, job: Job) -> float:
        """Restore overhead baked into the front of the current attempt
        (zero for the first run — nothing to restore)."""
        return self.restart_overhead_s if job.attempt > 0 else 0.0

    def on_interrupt(self, job: Job, t: float
                     ) -> Tuple[float, float, float]:
        """Account a kill at time ``t``; returns ``(remaining, lost,
        overhead)``:

        * ``remaining`` — wall seconds the next attempt needs (work left
          after the surviving checkpoint, plus restart overhead);
        * ``lost`` — recompute debt: progress this attempt that no
          checkpoint captured;
        * ``overhead`` — the restore cost added to the next attempt.

        Mutates the job's checkpoint bookkeeping
        (``checkpointed_progress`` / ``lost_work`` /
        ``restart_overhead``); the caller requeues with ``remaining``.
        """
        elapsed = 0.0
        if job.run_time is not None:
            # Killed before the container came up -> no progress at all.
            elapsed = max(0.0, float(t) - job.run_time)
        progress = max(0.0, elapsed - self.attempt_overhead(job))
        progress = min(progress,
                       job.original_duration - job.checkpointed_progress)

        if job.kind is JobKind.TRAIN and self.mode == "checkpoint":
            saved = (progress // self.interval_s) * self.interval_s
        elif job.kind is JobKind.TRAIN:   # scratch: all progress redone
            saved = 0.0
        else:
            # Stateless service: serving time is never recomputed.
            saved = progress
        lost = progress - saved
        job.checkpointed_progress = min(
            job.original_duration, job.checkpointed_progress + saved)

        overhead = self.restart_overhead_s
        remaining = (job.original_duration - job.checkpointed_progress
                     + overhead)
        job.lost_work += lost
        job.restart_overhead += overhead
        return remaining, lost, overhead
