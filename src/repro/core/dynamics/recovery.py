"""Checkpoint-restart recovery model.

When a failure (or drain eviction) kills a job, the engine asks this
model what the *next attempt* costs.  The model mirrors the model-level
checkpointing story (:mod:`repro.ckpt.store`, wired into
``examples/train_e2e.py``) at scheduler granularity:

* training writes a checkpoint every ``interval_s`` seconds of
  progress; work since the last checkpoint is recomputed ("lost work");
* every restart pays ``restart_overhead_s`` up front (restore the
  checkpoint, rebuild the gang, warm caches);
* the ``scratch`` baseline never checkpoints: every failure restarts
  the job from zero — the paper-motivating ablation for
  ``benchmarks/dynamics_bench.py``;
* inference/debug pods are stateless services: interrupted serving time
  is not recomputed, only the restart overhead is paid.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

from ..job import Job, JobKind


@dataclasses.dataclass
class CheckpointModel:
    """``mode`` is ``"checkpoint"`` (periodic checkpoints) or
    ``"scratch"`` (restart from zero)."""

    interval_s: float = 600.0
    restart_overhead_s: float = 120.0
    mode: str = "checkpoint"

    def __post_init__(self) -> None:
        if self.mode not in ("checkpoint", "scratch"):
            raise ValueError(f"unknown recovery mode {self.mode!r}")
        if self.interval_s <= 0:
            raise ValueError("checkpoint interval must be positive")

    # ------------------------------------------------------------------
    def attempt_overhead(self, job: Job) -> float:
        """Restore overhead baked into the front of the current attempt
        (zero for the first run — nothing to restore)."""
        return self.restart_overhead_s if job.attempt > 0 else 0.0

    def on_interrupt(self, job: Job, t: float
                     ) -> Tuple[float, float, float]:
        """Account a kill at time ``t``; returns ``(remaining, lost,
        overhead)``:

        * ``remaining`` — wall seconds the next attempt needs at the
          job's CURRENT shape (work left after the surviving
          checkpoint, plus restart overhead).  If an elastic job is
          restarted at a different plan, the
          :class:`~repro.core.elastic.manager.ElasticManager`
          recomputes the attempt duration at placement time from the
          same checkpoint state;
        * ``lost`` — recompute debt: *wall* seconds this attempt spent
          past its last checkpoint (metrics multiply by the shape that
          burned them);
        * ``overhead`` — the restore cost added to the next attempt.

        Elastic jobs progress at ``job.work_rate`` work-seconds per
        wall second (1.0 for rigid jobs, making every expression below
        bit-identical to the pre-elastic model): checkpoints still
        happen every ``interval_s`` *wall* seconds, but the work they
        persist is scaled by the rate.

        Mutates the job's checkpoint bookkeeping
        (``checkpointed_progress`` / ``lost_work`` /
        ``restart_overhead``); the caller requeues with ``remaining``.
        """
        elapsed = 0.0
        if job.run_time is not None:
            # Killed before the container came up -> no progress at all.
            elapsed = max(0.0, float(t) - job.run_time)
        rate = job.work_rate
        # Wall seconds of actual progress this attempt, capped at the
        # wall time the remaining work takes at the active rate.
        progress = max(0.0, elapsed - self.attempt_overhead(job))
        work_left = job.original_duration - job.checkpointed_progress
        if rate > 0:
            progress = min(progress, work_left / rate)

        if job.kind is JobKind.TRAIN and self.mode == "checkpoint":
            saved = (progress // self.interval_s) * self.interval_s
        elif job.kind is JobKind.TRAIN:   # scratch: all progress redone
            saved = 0.0
        else:
            # Stateless service: serving time is never recomputed.
            saved = progress
        lost = progress - saved
        job.checkpointed_progress = min(
            job.original_duration, job.checkpointed_progress + saved * rate)

        overhead = self.restart_overhead_s
        remaining_work = job.original_duration - job.checkpointed_progress
        if rate > 0:
            remaining_work = remaining_work / rate
        remaining = remaining_work + overhead
        job.lost_work += lost
        job.restart_overhead += overhead
        return remaining, lost, overhead
