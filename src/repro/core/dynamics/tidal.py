"""Tidal train/inference autoscaling (the co-scheduling story).

Inference demand is diurnal (:func:`repro.core.workload.diurnal_demand`):
it crests mid-afternoon and bottoms out overnight.  The
:class:`TidalAutoscaler` tracks each service's demand curve at a fixed
cadence (SCALE_DECISION events) and resizes its replica fleet:

* **night ebb** — surplus replicas are retired; the freed GPUs flow to
  the scheduler's pending queue, where low-priority training backfill
  soaks them up;
* **morning ramp** — new high-priority replicas are submitted; when the
  pool is full they block at the queue head and the framework's
  **Preempt** chain (PriorityPreempt) evicts the low-priority backfill
  to hand the GPUs back — the fleet is never starved by its own
  generosity.

Replica pods go through the same Admit/Reserve/Permit pipeline as any
job, so quota and feasibility checks apply unchanged.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from ..events import EventKind
from ..framework.api import DynamicsPlugin
from ..framework.registry import register
from ..job import Job, JobKind, JobState, PRIO_HIGH
from ..workload import diurnal_demand


@dataclasses.dataclass
class TidalService:
    """One autoscaled inference service and its demand curve."""

    name: str
    tenant: str = "svc"
    gpu_type: int = 0
    gpus_per_replica: int = 1
    min_replicas: int = 1
    max_replicas: int = 8
    peak_hour: float = 14.0
    priority: int = PRIO_HIGH
    #: Measured demand hook: replicas wanted at time ``t`` (fractional
    #: ok; clipped to [min, max]).  When set it replaces the analytic
    #: diurnal curve — this is how the serving fabric's ReplicaPool
    #: exports its observed request load to the autoscaler
    #: (see :func:`repro.serve.replica.demand_service`).
    demand: Optional[Callable[[float], float]] = None

    def target_replicas(self, t: float) -> int:
        """Demanded replica count at time ``t`` (rounded to a pod)."""
        if self.demand is not None:
            raw = float(self.demand(t))
            return int(round(min(float(self.max_replicas),
                                 max(float(self.min_replicas), raw))))
        return int(round(diurnal_demand(t, self.min_replicas,
                                        self.max_replicas,
                                        peak_hour=self.peak_hour)))


@dataclasses.dataclass
class DemandSample:
    t: float
    service: str
    target: int
    running: int
    fleet: int           # running + pending replicas


@register
class TidalAutoscaler(DynamicsPlugin):
    """Scales replica fleets along their diurnal demand curves."""

    name = "TidalAutoscaler"
    handles = (EventKind.SCALE_DECISION,)

    def __init__(self, services: Sequence[TidalService],
                 interval_s: float = 900.0, start: float = 0.0) -> None:
        if interval_s <= 0:
            raise ValueError("scale interval must be positive")
        self.services = list(services)
        self.interval_s = float(interval_s)
        self.start = float(start)
        self._fleet: Dict[str, List[Job]] = {s.name: []
                                             for s in self.services}
        #: (t, service, target, running, fleet) log — the benchmark's
        #: demand-satisfaction series.
        self.demand_log: List[DemandSample] = []
        self.replicas_started = 0
        self.replicas_retired = 0

    # ------------------------------------------------------------------
    def schedule(self, engine, rng) -> Sequence[Tuple[float, EventKind,
                                                      object]]:
        # {"owner": self} routes the chain to this autoscaler only —
        # several autoscalers can coexist without consuming (and
        # re-continuing) each other's SCALE_DECISION events.
        return [(self.start, EventKind.SCALE_DECISION, {"owner": self})]

    def on_event(self, event, engine) -> None:
        t = event.t
        for svc in self.services:
            self._scale_service(svc, t, engine)
        if t + self.interval_s <= engine.horizon:
            engine.push(t + self.interval_s, EventKind.SCALE_DECISION,
                        {"owner": self})

    # ------------------------------------------------------------------
    def _scale_service(self, svc: TidalService, t: float,
                       engine) -> None:
        fleet = self._fleet[svc.name]
        # Drop replicas that left the system (completed / failed).
        fleet[:] = [j for j in fleet
                    if j.state not in (JobState.COMPLETED, JobState.FAILED)]
        target = svc.target_replicas(t)
        running = sum(1 for j in fleet if j.state is JobState.RUNNING)
        if target > len(fleet):
            for _ in range(target - len(fleet)):
                fleet.append(self._submit_replica(svc, t, engine))
                self.replicas_started += 1
        elif target < len(fleet):
            # Retire pending replicas first (cheapest), then the
            # youngest running ones (oldest replicas keep the caches).
            doomed = sorted(
                fleet, key=lambda j: (
                    j.state is JobState.RUNNING,
                    -(j.start_time if j.start_time is not None else t)))
            for job in doomed[:len(fleet) - target]:
                engine.retire_job(job, t)
                fleet.remove(job)
                self.replicas_retired += 1
        self.demand_log.append(DemandSample(
            t=t, service=svc.name, target=target, running=running,
            fleet=len(fleet)))

    def _submit_replica(self, svc: TidalService, t: float, engine) -> Job:
        job = Job(
            uid=engine.next_uid(),
            tenant=svc.tenant,
            gpu_type=svc.gpu_type,
            n_pods=1,
            gpus_per_pod=svc.gpus_per_replica,
            kind=JobKind.INFER,
            gang=False,
            priority=svc.priority,
            submit_time=t,
            # Replicas live until retired: size the nominal duration to
            # the remaining horizon so no natural END fires first.
            duration=max(1.0, engine.horizon - t + 3600.0),
            preemptible=False,
        )
        engine.submit_job(job, t)
        return job

    # ------------------------------------------------------------------
    def satisfaction(self) -> float:
        """Mean demand satisfaction: running/target, clipped at 1."""
        vals = [min(1.0, s.running / s.target) for s in self.demand_log
                if s.target > 0]
        return sum(vals) / len(vals) if vals else 1.0
