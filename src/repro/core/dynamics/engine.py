"""The cluster-dynamics engine: event semantics + action helpers.

:class:`ClusterDynamics` attaches to a :class:`~repro.core.simulator.
Simulator` and owns the *semantics* of the dynamic event kinds:

* NODE_FAIL / GPU_FAIL — flip health bitmaps on the live state, mirror
  the change onto the scheduler's working snapshot
  (:meth:`~repro.core.qsch.QSCH.sync_health` — the mid-cycle
  cache-invalidation fix), and kill every resident gang: each victim
  goes through the checkpoint-restart recovery model and re-enters its
  tenant queue with the recomputed remaining duration (§3.2.4 requeue
  applied to failures);
* NODE_RECOVER / GPU_RECOVER — restore health and revive the scheduling
  tick chain so waiting work can use the returned capacity;
* DRAIN_START / DRAIN_END — planned maintenance windows: draining nodes
  accept no new placements (drain-aware filtering in RSCH); ``evict``
  windows also checkpoint-kill resident jobs;
* SCALE_DECISION — routed to the owning
  :class:`~repro.core.framework.api.DynamicsPlugin` (tidal autoscaler).

Plugins never mutate ``ClusterState`` directly — they drive the
engine's action helpers so snapshot sync, quota refunds, stale-END
bookkeeping and metrics accounting stay in one place.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence

import numpy as np

from ..events import Event, EventKind
from ..framework.api import DynamicsPlugin
from ..job import Job, JobState
from .recovery import CheckpointModel

_DYNAMIC_KINDS = (EventKind.NODE_FAIL, EventKind.NODE_RECOVER,
                  EventKind.GPU_FAIL, EventKind.GPU_RECOVER,
                  EventKind.DRAIN_START, EventKind.DRAIN_END)


@dataclasses.dataclass
class DynamicsConfig:
    """Everything the engine needs; an empty config (no plugins) is the
    documented no-op — simulation results are byte-identical to a run
    with ``SimConfig.dynamics=None`` (asserted by
    ``benchmarks/dynamics_bench.py``)."""

    plugins: Sequence[DynamicsPlugin] = ()
    recovery: CheckpointModel = dataclasses.field(
        default_factory=CheckpointModel)
    seed: int = 0
    # Horizon for pre-sampled traces when SimConfig.horizon is None
    # (drain-to-empty runs still need a bound for failure sampling).
    trace_horizon: float = 7 * 86_400.0


@dataclasses.dataclass
class DynamicsSummary:
    node_failures: int = 0
    gpu_failures: int = 0
    recoveries: int = 0
    interrupts: int = 0
    drain_windows: int = 0
    drain_evictions: int = 0
    scale_events: int = 0
    replicas_started: int = 0
    replicas_retired: int = 0

    def as_dict(self) -> Dict[str, int]:
        return dataclasses.asdict(self)


class ClusterDynamics:
    def __init__(self, config: DynamicsConfig) -> None:
        self.config = config
        self.summary = DynamicsSummary()
        self.sim = None
        self.rng = np.random.default_rng(config.seed)
        self._uids = itertools.count(10_000_000)
        # Reference counts of open failures/drains per node (device):
        # overlapping injector traces or drain windows must not let the
        # first recovery/window-end revive a node another open outage
        # still claims.
        self._down: Dict[int, int] = {}
        self._draining: Dict[int, int] = {}
        self._gpu_down: Dict[tuple, int] = {}

    # ------------------------------------------------------------------
    # Wiring
    # ------------------------------------------------------------------
    @property
    def state(self):
        return self.sim.state

    @property
    def qsch(self):
        return self.sim.qsch

    @property
    def horizon(self) -> float:
        h = self.sim.config.horizon
        return float(h) if h is not None else self.config.trace_horizon

    def attach(self, sim) -> None:
        self.sim = sim
        bus = sim.bus
        bus.subscribe(EventKind.NODE_FAIL, self._on_node_fail)
        bus.subscribe(EventKind.NODE_RECOVER, self._on_node_recover)
        bus.subscribe(EventKind.GPU_FAIL, self._on_gpu_fail)
        bus.subscribe(EventKind.GPU_RECOVER, self._on_gpu_recover)
        bus.subscribe(EventKind.DRAIN_START, self._on_drain_start)
        bus.subscribe(EventKind.DRAIN_END, self._on_drain_end)
        # Recovery-side events are pushed even past the horizon: with a
        # SimConfig horizon the loop stops before reaching them anyway,
        # but a drain-to-empty run (horizon=None) must not inherit a
        # permanently dead node from a dropped repair — that would keep
        # the requeued work pending and the TICK chain alive forever.
        closing = (EventKind.NODE_RECOVER, EventKind.GPU_RECOVER,
                   EventKind.DRAIN_END)
        for plugin in self.config.plugins:
            for kind in plugin.handles:
                bus.subscribe(kind, self._plugin_handler(plugin))
            for t, kind, payload in plugin.schedule(self, self.rng):
                if t <= self.horizon or kind in closing:
                    bus.push(t, kind, payload)

    def _plugin_handler(self, plugin: DynamicsPlugin):
        def handler(event: Event) -> None:
            # Owner routing: a plugin-owned event (payload carries
            # {"owner": plugin}) is delivered only to its owner.  Two
            # autoscalers subscribed to SCALE_DECISION must not see —
            # and re-continue — each other's chains, or the event count
            # doubles per generation.
            owner = (event.payload.get("owner")
                     if isinstance(event.payload, dict) else None)
            if owner is not None and owner is not plugin:
                return
            if event.kind is EventKind.SCALE_DECISION:
                self.summary.scale_events += 1
            plugin.on_event(event, self)
        return handler

    # ------------------------------------------------------------------
    # Action helpers (the only sanctioned mutation paths for plugins)
    # ------------------------------------------------------------------
    def push(self, t: float, kind: EventKind, payload=None) -> None:
        self.sim.bus.push(t, kind, payload)

    def next_uid(self) -> int:
        return next(self._uids)

    def submit_job(self, job: Job, t: float) -> None:
        """Enqueue a plugin-created job through the normal SUBMIT path
        and make sure a scheduling cycle will actually look at it."""
        self.sim.bus.push(max(t, job.submit_time), EventKind.SUBMIT, job)
        self._revive(t)

    def retire_job(self, job: Job, t: float) -> None:
        """Gracefully terminate a job now (autoscaler scale-down): it
        counts as completed with the work it actually delivered."""
        if job.state is JobState.RUNNING:
            # Useful work = total serving time, not the nominal
            # until-the-horizon duration replicas are created with.
            # Pre-interruption serving survives in checkpointed_progress
            # (stateless services checkpoint continuously); the current
            # attempt contributes its elapsed time minus the restore
            # overhead it started with.
            elapsed = max(0.0, t - (job.run_time if job.run_time
                                    is not None else t))
            # Wall time converts to work at the active plan's relative
            # throughput (1.0 for rigid jobs).
            attempt_work = max(
                0.0, elapsed - self.config.recovery.attempt_overhead(job)
            ) * job.work_rate
            job.original_duration = job.checkpointed_progress \
                + attempt_work
            self.sim.pending_ends.pop(job.uid, None)
            self.qsch.on_complete(job, self.state, t)
            self.sim.metrics.on_job_finished(job)
        else:
            # Still queued: cancel before it ever places.  Work served
            # before an interruption still counts.
            self.qsch._remove_from_queue(job)
            job.original_duration = job.checkpointed_progress
            job.state = JobState.COMPLETED
            job.end_time = t
            if job.original_duration > 0:
                self.sim.metrics.on_job_finished(job)

    def interrupt_job(self, job: Job, t: float) -> None:
        """Checkpoint-kill one running job (failure/drain-evict path)."""
        remaining, lost, overhead = self.config.recovery.on_interrupt(
            job, t)
        self.sim.metrics.on_job_interrupted(job, t, lost, overhead)
        self.qsch.on_interrupted(job, self.state, t, remaining)
        self.summary.interrupts += 1

    # ------------------------------------------------------------------
    # Built-in event semantics
    # ------------------------------------------------------------------
    def _kill_resident(self, node: int, t: float,
                       gpu: Optional[int] = None) -> List[Job]:
        victims = []
        for uid in self.state.jobs_on(node, gpu):
            job = self.qsch.running.get(uid)
            if job is not None:
                victims.append(job)
        for job in victims:
            self.interrupt_job(job, t)
        return victims

    def _sync(self, nodes: Sequence[int], t: float) -> None:
        self.qsch.sync_health(self.state, nodes)
        self._revive(t)

    def _revive(self, t: float) -> None:
        """Failures/recoveries/scale actions can create schedulable work
        after the tick/sample chains drained — restart them."""
        self.sim.ensure_tick(t)
        self.sim.ensure_sample(t)

    def _on_node_fail(self, ev: Event) -> None:
        node = int(ev.payload["node"])
        self._down[node] = self._down.get(node, 0) + 1
        if self._down[node] > 1:      # already down: stack the outage
            return
        self._kill_resident(node, ev.t)
        self.state.set_node_health(node, False)
        self.summary.node_failures += 1
        self._sync([node], ev.t)

    def _on_node_recover(self, ev: Event) -> None:
        node = int(ev.payload["node"])
        if node not in self._down:
            return
        self._down[node] -= 1
        if self._down[node] > 0:      # another overlapping outage open
            return
        del self._down[node]
        self.state.set_node_health(node, True)
        self.summary.recoveries += 1
        self._sync([node], ev.t)

    def _on_gpu_fail(self, ev: Event) -> None:
        node, gpu = int(ev.payload["node"]), int(ev.payload["gpu"])
        key = (node, gpu)
        self._gpu_down[key] = self._gpu_down.get(key, 0) + 1
        if self._gpu_down[key] > 1:
            return
        if node not in self._down:    # node-down already killed it all
            self._kill_resident(node, ev.t, gpu=gpu)
        self.state.set_gpu_health(node, gpu, False)
        self.summary.gpu_failures += 1
        self._sync([node], ev.t)

    def _on_gpu_recover(self, ev: Event) -> None:
        node, gpu = int(ev.payload["node"]), int(ev.payload["gpu"])
        key = (node, gpu)
        if key not in self._gpu_down:
            return
        self._gpu_down[key] -= 1
        if self._gpu_down[key] > 0:
            return
        del self._gpu_down[key]
        self.state.set_gpu_health(node, gpu, True)
        self.summary.recoveries += 1
        self._sync([node], ev.t)

    def _on_drain_start(self, ev: Event) -> None:
        nodes = [int(n) for n in ev.payload["nodes"]]
        fresh = []
        for n in nodes:
            self._draining[n] = self._draining.get(n, 0) + 1
            if self._draining[n] == 1:
                fresh.append(n)
        self.summary.drain_windows += 1
        if not fresh:
            return
        self.state.set_drain(fresh, True)
        if ev.payload.get("evict"):
            for node in fresh:
                self.summary.drain_evictions += len(
                    self._kill_resident(node, ev.t))
        self._sync(fresh, ev.t)

    def _on_drain_end(self, ev: Event) -> None:
        done = []
        for n in (int(n) for n in ev.payload["nodes"]):
            if n not in self._draining:
                continue
            self._draining[n] -= 1
            if self._draining[n] == 0:   # last open window on this node
                del self._draining[n]
                done.append(n)
        if not done:
            return
        self.state.set_drain(done, False)
        self._sync(done, ev.t)

    # ------------------------------------------------------------------
    def finalize(self, result) -> None:
        s = self.summary
        for plugin in self.config.plugins:
            s.replicas_started += getattr(plugin, "replicas_started", 0)
            s.replicas_retired += getattr(plugin, "replicas_retired", 0)
        result.failures = s.node_failures + s.gpu_failures
        result.interrupts = s.interrupts
        result.drains = s.drain_windows
        result.scale_events = s.scale_events
        result.dynamics = s
