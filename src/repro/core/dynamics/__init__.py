"""Cluster dynamics subsystem: failures, drains, tidal autoscaling.

Opens the scenario axis of the reproduction: the simulator's event bus
(:mod:`repro.core.events`) carries NODE_FAIL / NODE_RECOVER /
GPU_FAIL / GPU_RECOVER / DRAIN_START / DRAIN_END / SCALE_DECISION
events alongside the classic SUBMIT/TICK/END, and this package supplies

* :mod:`~repro.core.dynamics.failures` — seeded Weibull/exponential
  node and GPU failure injectors plus planned drain windows;
* :mod:`~repro.core.dynamics.recovery` — the checkpoint-restart
  recovery model (and its restart-from-scratch ablation);
* :mod:`~repro.core.dynamics.tidal`    — the tidal train/inference
  autoscaler riding the diurnal demand curve;
* :mod:`~repro.core.dynamics.engine`   — the engine binding it all to a
  :class:`~repro.core.simulator.Simulator`.

Enable with ``SimConfig(dynamics=DynamicsConfig(plugins=[...]))``; with
no config the simulator is byte-identical to the static-cluster one.
See ``docs/dynamics.md``.
"""

from .engine import ClusterDynamics, DynamicsConfig, DynamicsSummary
from .failures import DrainWindow, GpuFailureInjector, NodeFailureInjector
from .recovery import CheckpointModel
from .tidal import DemandSample, TidalAutoscaler, TidalService

__all__ = [
    "ClusterDynamics", "DynamicsConfig", "DynamicsSummary",
    "NodeFailureInjector", "GpuFailureInjector", "DrainWindow",
    "CheckpointModel", "TidalAutoscaler", "TidalService", "DemandSample",
]
