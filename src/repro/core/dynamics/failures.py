"""Failure and maintenance models: seeded trace generators.

Each injector is a :class:`~repro.core.framework.api.DynamicsPlugin`
whose :meth:`schedule` pre-samples a reproducible event trace from the
engine's seeded RNG — the whole failure history of a run is determined
by ``DynamicsConfig.seed``, which is what makes the dynamics benchmarks
comparable run-to-run (``benchmarks/run.py --seed``).

* :class:`NodeFailureInjector` — per-node Weibull (shape ``k``) failure
  process; ``k = 1`` degenerates to exponential (memoryless), ``k < 1``
  models infant mortality, ``k > 1`` wear-out.  Each failure is paired
  with an exponential repair time (NODE_RECOVER).
* :class:`GpuFailureInjector` — cluster-level Poisson process of
  single-device (ECC/thermal) failures, uniform over devices.
* :class:`DrainWindow` — one planned maintenance window over a fixed
  node set (DRAIN_START/DRAIN_END); ``evict=True`` additionally kills
  resident jobs at window start (they recover via checkpoint-restart),
  otherwise they run to completion while new placements are kept out.
"""

from __future__ import annotations

import math
from typing import Iterable, List, Optional, Sequence, Tuple

from ..events import EventKind
from ..framework.api import DynamicsPlugin
from ..framework.registry import register

Trace = List[Tuple[float, EventKind, object]]


@register
class NodeFailureInjector(DynamicsPlugin):
    """Seeded per-node Weibull failure + exponential repair process."""

    name = "NodeFailureInjector"

    def __init__(self, mtbf_s: float, repair_s: float = 1800.0,
                 shape: float = 1.0,
                 nodes: Optional[Sequence[int]] = None,
                 max_failures: Optional[int] = None) -> None:
        if mtbf_s <= 0 or repair_s < 0 or shape <= 0:
            raise ValueError("mtbf/repair/shape must be positive")
        self.mtbf_s = float(mtbf_s)
        self.repair_s = float(repair_s)
        self.shape = float(shape)
        self.nodes = None if nodes is None else [int(n) for n in nodes]
        self.max_failures = max_failures
        # Weibull scale chosen so the mean inter-failure time is the
        # configured MTBF: E[X] = scale * Gamma(1 + 1/k).
        self._scale = self.mtbf_s / math.gamma(1.0 + 1.0 / self.shape)

    def schedule(self, engine, rng) -> Trace:
        nodes = (self.nodes if self.nodes is not None
                 else range(engine.state.n_nodes))
        horizon = engine.horizon
        failures = []                  # (t, node, repair)
        for node in nodes:
            t = 0.0
            while True:
                t += float(rng.weibull(self.shape)) * self._scale
                if t > horizon:
                    break
                repair = float(rng.exponential(self.repair_s))
                failures.append((t, int(node), repair))
                t += repair
        if self.max_failures is not None:
            # Cap the TRACE, not a per-node budget walked in node-index
            # order — the earliest failures cluster-wide survive, so a
            # capped run still exercises the whole fleet.
            failures.sort()
            failures = failures[:self.max_failures]
        trace: Trace = []
        for t, node, repair in failures:
            trace.append((t, EventKind.NODE_FAIL, {"node": node}))
            trace.append((t + repair, EventKind.NODE_RECOVER,
                          {"node": node}))
        return trace


@register
class GpuFailureInjector(DynamicsPlugin):
    """Cluster-level Poisson process of single-device failures."""

    name = "GpuFailureInjector"

    def __init__(self, rate_per_gpu_hour: float,
                 repair_s: float = 3600.0) -> None:
        if rate_per_gpu_hour <= 0 or repair_s < 0:
            raise ValueError("rate/repair must be positive")
        self.rate_per_gpu_hour = float(rate_per_gpu_hour)
        self.repair_s = float(repair_s)

    def schedule(self, engine, rng) -> Trace:
        state = engine.state
        n_devices = state.n_nodes * state.gpus_per_node
        rate_per_s = self.rate_per_gpu_hour * n_devices / 3600.0
        trace: Trace = []
        t = 0.0
        while True:
            t += float(rng.exponential(1.0 / rate_per_s))
            if t > engine.horizon:
                break
            node = int(rng.integers(state.n_nodes))
            gpu = int(rng.integers(state.gpus_per_node))
            trace.append((t, EventKind.GPU_FAIL,
                          {"node": node, "gpu": gpu}))
            trace.append((t + float(rng.exponential(self.repair_s)),
                          EventKind.GPU_RECOVER,
                          {"node": node, "gpu": gpu}))
        return trace


@register
class DrainWindow(DynamicsPlugin):
    """One planned maintenance window over a fixed node set."""

    name = "DrainWindow"

    def __init__(self, nodes: Iterable[int], start: float,
                 duration: float, evict: bool = False) -> None:
        self.nodes = [int(n) for n in nodes]
        self.start = float(start)
        self.duration = float(duration)
        self.evict = evict

    def schedule(self, engine, rng) -> Trace:
        payload = {"nodes": self.nodes, "evict": self.evict}
        return [(self.start, EventKind.DRAIN_START, payload),
                (self.start + self.duration, EventKind.DRAIN_END, payload)]
