"""RSCH — the Resource-aware Scheduler (paper §3.3).

RSCH turns an admitted job into a concrete :class:`Placement`:

1. **Node-pool restriction** (§3.4.1): only nodes of the requested GPU
   type are considered.
2. **Two-level scheduling** (§3.4.2): first preselect NodeNetGroups
   (LeafGroups) with enough free capacity, then select nodes inside the
   chosen groups.
3. **Strategy scoring** (§3.3.3/§3.3.4): Binpack, E-Binpack, Spread or
   E-Spread via the shared fused filter+score pass
   (:mod:`repro.core.scoring`, Pallas kernel in
   :mod:`repro.kernels.node_score`).
4. **Gang semantics** (§3.3.2): the whole job is placed transactionally —
   if any pod cannot be placed the job stays pending and no state is
   mutated.
5. **Fine-grained device selection** (§3.3.1): within a node, pick the
   healthy GPU combination with the best interconnect (NVLink island >
   same-NUMA > cross-NUMA) and pair it with the island's RDMA NIC.
6. **Topology awareness** (§3.3.5): groups are chosen to minimize the
   number of NodeNetGroups (JTTED) preferring same-spine neighbours;
   EP-style jobs can be pinned to a single HBD.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .cluster import ClusterState
from .job import Job, JobKind, Placement, PodPlacement
from .scoring import (BINPACK, E_BINPACK, E_SPREAD, NEG_INF, SPREAD,
                      ScoreWeights, node_scores_np)
from .snapshot import Snapshot
from .topology import ClusterTopology


class Strategy(enum.Enum):
    BINPACK = "binpack"
    E_BINPACK = "e-binpack"
    SPREAD = "spread"
    E_SPREAD = "e-spread"


_WEIGHTS: Dict[Strategy, ScoreWeights] = {
    Strategy.BINPACK: BINPACK,
    Strategy.E_BINPACK: E_BINPACK,
    Strategy.SPREAD: SPREAD,
    Strategy.E_SPREAD: E_SPREAD,
}


@dataclasses.dataclass
class RSCHConfig:
    train_strategy: Strategy = Strategy.E_BINPACK
    infer_strategy: Strategy = Strategy.E_SPREAD
    # E-Spread (§3.3.4): inference pods smaller than this use the dedicated
    # zone; everything else falls back to E-Binpack in the general pool.
    espread_small_pod_gpus: int = 8
    # Schedule EP-style jobs at HBD granularity (§3.3.5 Scale-Up).
    hbd_granular_ep: bool = True


@dataclasses.dataclass
class ScheduleResult:
    placement: Optional[Placement]
    reason: str = ""
    groups_used: int = 0


class RSCH:
    def __init__(self, topology: ClusterTopology,
                 config: Optional[RSCHConfig] = None) -> None:
        self.topology = topology
        self.config = config or RSCHConfig()
        self._link_class = topology.gpu_link_class()
        self._nic = topology.nic_for_gpu()

    # ------------------------------------------------------------------
    # Public API
    # ------------------------------------------------------------------
    def strategy_for(self, job: Job) -> Strategy:
        if job.kind is JobKind.INFER:
            return self.config.infer_strategy
        return self.config.train_strategy

    def feasible(self, job: Job, snap: Snapshot) -> bool:
        """Dynamic-resource-admission check (§3.2.1): are there enough
        free, healthy GPUs in the job's node pool right now?"""
        pool = (snap.gpu_type == job.gpu_type) & snap.node_healthy
        per_node_ok = snap.free_gpus >= job.gpus_per_pod
        capacity = int((snap.free_gpus // job.gpus_per_pod)[
            pool & per_node_ok].sum())
        return capacity >= job.n_pods

    def schedule(self, job: Job, snap: Snapshot) -> ScheduleResult:
        """Compute a placement against a snapshot.  Pure — commits happen
        via ``ClusterState.allocate`` by the caller."""
        strategy = self.strategy_for(job)
        if (strategy is Strategy.E_SPREAD and job.kind is JobKind.INFER
                and job.gpus_per_pod < self.config.espread_small_pod_gpus
                and bool(snap.inference_zone.any())):
            result = self._schedule_with_mask(
                job, snap, Strategy.E_SPREAD,
                node_filter=snap.inference_zone)
            if result.placement is not None:
                return result
            # Remaining replicas: E-Binpack in the general pool (§3.3.4).
            return self._schedule_with_mask(
                job, snap, Strategy.E_BINPACK,
                node_filter=~snap.inference_zone)
        if strategy is Strategy.E_SPREAD:
            # Large inference pods get consolidated full nodes in the
            # general pool, keeping the dedicated zone for small
            # replicas (§3.3.4); fall back to anywhere if it's full.
            strategy = Strategy.E_BINPACK
            if bool(snap.inference_zone.any()):
                result = self._schedule_with_mask(
                    job, snap, strategy,
                    node_filter=~snap.inference_zone)
                if result.placement is not None:
                    return result
        return self._schedule_with_mask(job, snap, strategy, None)

    # ------------------------------------------------------------------
    # Core two-level placement
    # ------------------------------------------------------------------
    def _schedule_with_mask(self, job: Job, snap: Snapshot,
                            strategy: Strategy,
                            node_filter: Optional[np.ndarray]
                            ) -> ScheduleResult:
        topo = self.topology
        pool = (snap.gpu_type == job.gpu_type) & snap.node_healthy
        if node_filter is not None:
            pool = pool & node_filter
        free = snap.free_gpus.copy()        # mutated as pods are placed
        if not pool.any():
            return ScheduleResult(None, "empty node pool")

        # --- Level 1: NodeNetGroup preselection (§3.4.2) ---------------
        enhanced = strategy in (Strategy.E_BINPACK, Strategy.E_SPREAD)
        selected_groups = self._preselect_groups(job, snap, pool, free,
                                                 enhanced, strategy)
        if selected_groups is None:
            return ScheduleResult(None, "no NodeNetGroup set satisfies job")
        group_rank = {g: i for i, g in enumerate(selected_groups)}
        in_groups = np.isin(topo.leaf_id, np.asarray(selected_groups))

        # --- Level 2: node selection within selected groups ------------
        weights = _WEIGHTS[strategy]
        group_used = np.bincount(
            topo.leaf_id, weights=np.where(pool, snap.used_gpus, 0),
            minlength=topo.n_leaf_groups).astype(np.float32)
        group_cap = np.bincount(
            topo.leaf_id,
            weights=np.where(pool, snap.gpu_healthy.sum(axis=1), 0),
            minlength=topo.n_leaf_groups).astype(np.float32)
        group_load = group_used / np.maximum(group_cap, 1.0)
        # Preference for earlier-ranked (anchor) groups keeps a multi-pod
        # job inside as few groups as possible (§3.3.3 LeafGroup E-Binpack).
        topo_pref = np.zeros(topo.n_nodes, dtype=np.float32)
        for g, rank in group_rank.items():
            members = topo.leaf_id == g
            topo_pref[members] = 1.0 / (1.0 + rank)

        pods: List[PodPlacement] = []
        busy = snap.gpu_busy.copy()
        for _ in range(job.n_pods):
            mask = pool & in_groups
            scores = node_scores_np(
                free, snap.used_gpus + 0, mask, group_load[topo.leaf_id],
                topo_pref, job.gpus_per_pod, topo.gpus_per_node, weights)
            # Same-node co-location bonus (node-level E-Binpack §3.3.3):
            # pods of this job already on a node make it maximally
            # attractive for the next pod.
            if enhanced and pods and job.kind is not JobKind.INFER:
                for p in pods:
                    if scores[p.node] > NEG_INF:
                        scores[p.node] += 2.0
            node = int(np.argmax(scores))
            if scores[node] <= NEG_INF:
                return ScheduleResult(None, "gang placement failed")
            gpus = self._pick_devices(busy[node], snap.gpu_healthy[node],
                                      job.gpus_per_pod)
            if gpus is None:
                return ScheduleResult(None, "device-level selection failed")
            busy[node, list(gpus)] = True
            free[node] -= job.gpus_per_pod
            pods.append(PodPlacement(node=node, gpu_indices=gpus,
                                     nic=int(self._nic[gpus[0]])))
        placement = Placement(pods=pods)
        n_groups = len({int(topo.leaf_id[p.node]) for p in pods})
        return ScheduleResult(placement, "ok", groups_used=n_groups)

    # ------------------------------------------------------------------
    def _preselect_groups(self, job: Job, snap: Snapshot, pool: np.ndarray,
                          free: np.ndarray, enhanced: bool,
                          strategy: Strategy) -> Optional[List[int]]:
        """Pick an ordered list of candidate NodeNetGroups.

        * small job + E-Binpack: busiest group that still fits (consolidate,
          keep empty groups reserved for large jobs);
        * spread strategies: all groups, emptiest first;
        * large jobs: greedy minimal set of groups, preferring same-spine
          neighbours (JTTED: fewest groups, closest topology).
        """
        topo = self.topology
        # A node contributes floor(free/pod) pod slots.
        pod_slots = np.where(pool, free // job.gpus_per_pod, 0)
        group_slots = np.bincount(topo.leaf_id, weights=pod_slots,
                                  minlength=topo.n_leaf_groups).astype(int)
        group_free = np.bincount(topo.leaf_id, weights=np.where(pool, free, 0),
                                 minlength=topo.n_leaf_groups).astype(int)
        group_used = np.bincount(topo.leaf_id,
                                 weights=np.where(pool, snap.used_gpus, 0),
                                 minlength=topo.n_leaf_groups).astype(int)
        candidates = np.nonzero(group_slots > 0)[0]
        if len(candidates) == 0:
            return None

        if group_slots.sum() < job.n_pods:
            return None

        fits_one = candidates[group_slots[candidates] >= job.n_pods]
        if len(fits_one) > 0:
            if strategy in (Strategy.SPREAD, Strategy.E_SPREAD):
                # Spread wants room: emptiest group first.
                order = sorted(fits_one,
                               key=lambda g: (-group_free[g], g))
            elif enhanced:
                # LeafGroup-level E-Binpack: busiest group that fits.
                order = sorted(fits_one,
                               key=lambda g: (-group_used[g],
                                              group_free[g], g))
            else:
                # Plain binpack is node-level only: first fitting group by
                # best node score; approximate with most-used group too but
                # without reserving empties (same order, documented).
                order = sorted(fits_one,
                               key=lambda g: (-group_used[g], g))
            return [int(order[0])]

        # Multi-group job: greedy cover minimizing group count, preferring
        # same-spine neighbours of the seed group (topology-aware §3.3.5).
        seed_order = sorted(candidates, key=lambda g: (-group_slots[g], g))
        seed = int(seed_order[0])
        group_spine = topo.spine_id[np.searchsorted(
            topo.leaf_id, np.arange(topo.n_leaf_groups))]
        chosen: List[int] = [seed]
        covered = int(group_slots[seed])
        rest = [int(g) for g in candidates if g != seed]
        rest.sort(key=lambda g: (
            0 if group_spine[g] == group_spine[seed] else 1,
            -group_slots[g], g))
        for g in rest:
            if covered >= job.n_pods:
                break
            chosen.append(g)
            covered += int(group_slots[g])
        if covered < job.n_pods:
            return None
        return chosen

    # ------------------------------------------------------------------
    # Fine-grained device selection (§3.3.1)
    # ------------------------------------------------------------------
    def _pick_devices(self, busy_row: np.ndarray, healthy_row: np.ndarray,
                      k: int) -> Optional[Tuple[int, ...]]:
        """Choose ``k`` healthy free GPU slots minimizing link-class cost.

        Preference order: a single NVLink island, then a single NUMA
        domain, then best-effort lowest link classes.
        """
        avail = np.nonzero(~busy_row & healthy_row)[0]
        if len(avail) < k:
            return None
        cls = self._link_class
        best: Optional[Tuple[int, ...]] = None
        best_cost = None
        # Candidate seedings: group available GPUs by NVLink island / NUMA.
        islands: Dict[int, List[int]] = {}
        for g in avail:
            islands.setdefault(int(self._nic[g]), []).append(int(g))
        for members in islands.values():
            if len(members) >= k:
                cand = tuple(members[:k])
                cost = self._combo_cost(cand, cls)
                if best_cost is None or cost < best_cost:
                    best, best_cost = cand, cost
        if best is not None:
            return best
        # No single island fits: greedy fill ordered by island density.
        ordered = sorted(avail, key=lambda g: (int(self._nic[g]), int(g)))
        cand = tuple(int(g) for g in ordered[:k])
        return cand

    @staticmethod
    def _combo_cost(combo: Sequence[int], cls: np.ndarray) -> int:
        idx = np.asarray(combo)
        return int(cls[np.ix_(idx, idx)].sum())
