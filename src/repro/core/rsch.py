"""RSCH — the Resource-aware Scheduler (paper §3.3), as a placement
engine running its profile's plugin chains.

RSCH turns an admitted job into a concrete :class:`Placement` by running
the :class:`~repro.core.framework.api.SchedulingProfile` selected for
the job's workload kind (train / inference / best-effort):

1. **Plan** — the profile yields an ordered list of
   :class:`~repro.core.framework.api.PlacementPass` attempts (e.g. the
   E-Spread zone dance, §3.3.4); the first pass that places wins.
2. **Filter** (§3.4.1): the pass's Filter plugins produce the node-pool
   mask.  The default GpuTypeFilter+HealthFilter pair resolves through
   the snapshot's cached ``candidate_pool`` fast path.
3. **Level-1 group preselection** (§3.4.2): NodeNetGroups chosen by the
   pass's ``spread``/``enhanced`` flags (§3.3.3/§3.3.5).
4. **Score** (§3.3.3/§3.3.4): Score plugins contribute to ONE fused
   filter+score pass (numpy/jnp/Pallas, :mod:`repro.core.scoring`);
   snapshot-static extra terms are added onto it, pod-dependent bonuses
   are folded into the batched slot chains.
5. **Gang semantics** (§3.3.2): the whole job is placed transactionally
   — if any pod cannot be placed the job stays pending and no state is
   mutated.
6. **Fine-grained device selection** (§3.3.1): within a node, pick the
   healthy GPU combination with the best interconnect and pair it with
   the island's RDMA NIC.

The legacy ``Strategy`` enum and ``RSCHConfig(train_strategy=...)`` are
kept as a deprecation shim: :func:`profiles_from_config` maps them onto
default profiles built from the built-in plugins, placement-identical
to the pre-framework scheduler (asserted by
``benchmarks/sched_scale_bench.py`` and ``tests/test_framework.py``).
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Dict, List, Optional, Tuple

import numpy as np

from .framework.api import (PlacementPass, ProfileSet, SchedulingContext,
                            SchedulingProfile, obs_phase, single_pass_plan)
from .framework.builtin import (GpuTypeFilter, HealthFilter, binpack_pass,
                                ebinpack_pass, espread_plan, make_profile,
                                spread_pass)
from .job import Job, JobKind, Placement, PodPlacement
from .scoring import (NEG_INF, ScoreWeights, combine_weights,
                      compute_node_scores, node_scores_np,
                      select_gang_slots)
from .snapshot import Snapshot
from .topology import ClusterTopology


class Strategy(enum.Enum):
    """Legacy strategy names (shim over the plugin profiles; the weight
    compositions live in :mod:`repro.core.framework.builtin`)."""

    BINPACK = "binpack"
    E_BINPACK = "e-binpack"
    SPREAD = "spread"
    E_SPREAD = "e-spread"


@dataclasses.dataclass
class RSCHConfig:
    """Engine knobs + the legacy strategy shim.

    ``train_strategy``/``infer_strategy`` only matter when no explicit
    ``profiles`` are passed to :class:`RSCH`; they are then mapped onto
    default profiles via :func:`profiles_from_config`.
    """

    train_strategy: Strategy = Strategy.E_BINPACK
    infer_strategy: Strategy = Strategy.E_SPREAD
    # E-Spread (§3.3.4): inference pods smaller than this use the dedicated
    # zone; everything else falls back to E-Binpack in the general pool.
    espread_small_pod_gpus: int = 8
    # Schedule EP-style jobs at HBD granularity (§3.3.5 Scale-Up).
    hbd_granular_ep: bool = True
    # Batched gang placement (§3.4): one fused filter+score pass +
    # capacity-aware top-k slot selection for the whole gang, instead of
    # re-scoring every node once per pod.  The sequential path is kept
    # for A/B benchmarking (benchmarks/sched_scale_bench.py).
    batched_gang: bool = True
    # Score-pass backend: "np" (numpy, simulator default), "ref" (jnp
    # oracle), "interpret" (Pallas on CPU), "pallas" (compiled TPU).
    score_backend: str = "np"
    # Same-node co-location bonus per already-placed pod of the job
    # (node-level E-Binpack, §3.3.3).
    colocate_bonus: float = 2.0
    # Subset scoring (million-node core): for default Filter chains,
    # Level-1 preselection runs on snapshot-maintained per-group
    # aggregates (O(groups), patched row-wise on placement deltas) and
    # the Level-2 score pass touches only the selected groups' member
    # nodes — exact-identical to the full-width pass.  Falls back to
    # full width for custom Filter chains, non-"np" backends, and
    # decision-audit capture.
    subset_scoring: bool = True
    # Gang slot-selection engine: "topk" (vectorized sort + chain
    # emission), "heap" (the lazy-greedy loop, kept as the A/B oracle),
    # or "topk_kernel" (jax.lax.top_k prefilter).  The vectorized
    # engines auto-fall-back to the heap when plugin weights make slot
    # chains decreasing (see scoring.chains_nondecreasing).
    slot_engine: str = "topk"


def profiles_from_config(config: RSCHConfig) -> ProfileSet:
    """Deprecation shim: legacy ``Strategy`` pair -> default profiles.

    The resulting profiles are placement-identical to the pre-framework
    RSCH for every (strategy, workload) combination, including the
    train-with-E-Spread fallback to E-Binpack and the inference zone
    dance.
    """
    def plan_for(strategy: Strategy, for_infer: bool):
        # Co-location only ever applied to enhanced strategies on
        # non-inference jobs (the old `enhanced and kind != INFER` gate).
        colocate = 0.0 if for_infer else config.colocate_bonus
        if strategy is Strategy.BINPACK:
            return single_pass_plan(binpack_pass())
        if strategy is Strategy.SPREAD:
            return single_pass_plan(spread_pass())
        if strategy is Strategy.E_BINPACK:
            return single_pass_plan(ebinpack_pass(colocate))
        return espread_plan(config.espread_small_pod_gpus, colocate)

    return ProfileSet(
        train=make_profile(
            f"train-{config.train_strategy.value}",
            plan_for(config.train_strategy, for_infer=False)),
        inference=make_profile(
            f"inference-{config.infer_strategy.value}",
            plan_for(config.infer_strategy, for_infer=True)),
        best_effort=make_profile(
            f"best-effort-{config.train_strategy.value}",
            plan_for(config.train_strategy, for_infer=False)),
    )


@dataclasses.dataclass
class ScheduleResult:
    placement: Optional[Placement]
    reason: str = ""
    groups_used: int = 0
    # Raw decision-audit capture (repro.obs lifts it into typed records
    # via build_decision); None when no telemetry observer is attached.
    audit: Optional[Dict] = None


class RSCH:
    def __init__(self, topology: ClusterTopology,
                 config: Optional[RSCHConfig] = None,
                 profiles: Optional[ProfileSet] = None) -> None:
        self.topology = topology
        self.config = config or RSCHConfig()
        self.profiles = profiles or profiles_from_config(self.config)
        self._link_class = topology.gpu_link_class()
        self._nic = topology.nic_for_gpu()
        # Device selection runs once per placed pod; python lists over the
        # G-sized slot axis beat numpy dispatch overhead at G=8.
        self._nic_list = [int(n) for n in self._nic]
        self._n_islands = int(self._nic.max()) + 1
        # Static per-NodeNetGroup spine membership (topology never changes).
        self._group_spine = topology.spine_id[np.searchsorted(
            topology.leaf_id, np.arange(topology.n_leaf_groups))]
        # Member-node range of each NodeNetGroup: leaf_id is contiguous
        # ascending (idx // nodes_per_leaf), so group g's members are
        # exactly arange(_leaf_start[g], _leaf_start[g+1]).  This is what
        # lets subset scoring materialize selected-group node lists
        # without an O(n) membership scan.
        self._leaf_start = np.searchsorted(
            topology.leaf_id, np.arange(topology.n_leaf_groups + 1))
        # Optional telemetry facade (repro.obs): filter/score phase
        # timing + decision-audit capture.  None = zero-cost detached.
        self.obs = None
        # Armed by the cycle pipeline (repro.core.pipeline): a
        # precomputed ScheduleResult for the predicted head job, consumed
        # by :meth:`schedule` when every optimistic-concurrency guard
        # holds.  None in unpipelined operation.
        self.speculation = None

    # ------------------------------------------------------------------
    # Public API
    # ------------------------------------------------------------------
    def profile_for(self, job: Job) -> SchedulingProfile:
        return self.profiles.for_job(job)

    def strategy_for(self, job: Job) -> Strategy:
        """Legacy shim: the strategy the config would have used."""
        if job.kind is JobKind.INFER:
            return self.config.infer_strategy
        return self.config.train_strategy

    def feasible(self, job: Job, snap: Snapshot) -> bool:
        """Dynamic-resource-admission check (§3.2.1): are there enough
        free, healthy GPUs in the job's node pool right now?

        The pool honors the profile's full Filter chain (zone-agnostic,
        like the legacy check) — otherwise a restrictive custom filter
        would let admission pass forever while placement always fails.
        """
        return self.feasible_shape(job, snap, job.n_pods,
                                   job.gpus_per_pod)

    def feasible_shape(self, job: Job, snap: Snapshot, n_pods: int,
                       gpus_per_pod: int) -> bool:
        """Would ``job`` pass dynamic resource admission at a
        *hypothetical* ``(n_pods, gpus_per_pod)`` shape?  The elastic
        subsystem enumerates a job's candidate parallelism plans
        through this check without ever mutating the job; with the
        job's own shape it IS :meth:`feasible`."""
        pool, default = self._resolve_pool(job, snap, self.profile_for(job),
                                           None)
        if default:
            # Snapshot-maintained per-group slot totals: O(groups) to
            # sum, patched in O(dirty rows) on placement deltas.  A
            # node's ``floor(free/gpus_per_pod)`` is 0 exactly when
            # ``free < gpus_per_pod``, so the masked-division sum equals
            # the legacy ``pool & per_node_ok`` capacity count.
            totals = self._group_slots_cached(snap, int(job.gpu_type),
                                              None, gpus_per_pod)
            return int(totals.sum()) >= n_pods
        per_node_ok = snap.free_gpus >= gpus_per_pod
        capacity = int((snap.free_gpus // gpus_per_pod)[
            pool & per_node_ok].sum())
        return capacity >= n_pods

    def schedule(self, job: Job, snap: Snapshot,
                 ctx: Optional[SchedulingContext] = None) -> ScheduleResult:
        """Compute a placement against a snapshot.  Pure — commits happen
        via ``ClusterState.allocate`` by the caller.  ``ctx`` gives
        Score plugins optional cluster context (e.g. running jobs)."""
        spec = self.speculation
        if spec is not None and spec.job_uid == job.uid:
            # A pipelined speculative result exists for this job.  The
            # pipeline already verified no state mutation intervened;
            # here we verify the job itself (shape unchanged — elastic
            # reshapes recompute), the snapshot identity/mutation count,
            # and the score-weight fingerprint (a tuning controller may
            # have nudged plugin weights between cycles).
            self.speculation = None
            if (spec.snap is snap and spec.mut == snap.mut_count
                    and spec.shape == (job.n_pods, job.gpus_per_pod,
                                       int(job.gpu_type), job.kind)
                    and spec.fingerprint
                    == self._weights_fingerprint(job, snap)):
                spec.consumed = True
                return spec.result
        profile = self.profile_for(job)
        obs = self.obs
        capture: Optional[Dict] = None
        if obs is not None and obs.audit_on:
            capture = {"profile": profile.name, "passes": []}
        result = ScheduleResult(None, "empty placement plan")
        for pass_ in profile.plan(job, snap):
            result = self._run_pass(job, snap, pass_, profile, ctx,
                                    capture)
            if result.placement is not None:
                break
        result.audit = capture
        return result

    # ------------------------------------------------------------------
    # Snapshot-maintained per-group aggregates (subset scoring)
    # ------------------------------------------------------------------
    # Each helper registers a row-patchable TrackedGroupSum on the
    # snapshot (see repro.core.snapshot): built once per (pool, cycle
    # epoch) in O(n), then patched in O(dirty rows) as placements fold
    # in, and dropped wholesale on health/drain refreshes.  Only valid
    # for DEFAULT Filter chains, whose pool mask is the snapshot's own
    # cached candidate_pool — custom chains shape the pool per job.

    def _group_slots_cached(self, snap: Snapshot, gpu_type: int,
                            zone: Optional[str],
                            request: int) -> np.ndarray:
        topo = self.topology

        def contrib(s: Snapshot, idx: Optional[np.ndarray]) -> np.ndarray:
            p = s.candidate_pool(gpu_type, zone)
            if idx is None:
                return np.where(p, s.free_gpus // request, 0)
            return np.where(p[idx], s.free_gpus[idx] // request, 0)

        return snap.tracked_sum(("gslots", gpu_type, zone, int(request)),
                                topo.leaf_id, topo.n_leaf_groups, contrib)

    def _group_free_cached(self, snap: Snapshot, gpu_type: int,
                           zone: Optional[str]) -> np.ndarray:
        topo = self.topology

        def contrib(s: Snapshot, idx: Optional[np.ndarray]) -> np.ndarray:
            p = s.candidate_pool(gpu_type, zone)
            if idx is None:
                return np.where(p, s.free_gpus, 0)
            return np.where(p[idx], s.free_gpus[idx], 0)

        return snap.tracked_sum(("gfree", gpu_type, zone),
                                topo.leaf_id, topo.n_leaf_groups, contrib)

    def _group_used_cached(self, snap: Snapshot, gpu_type: int,
                           zone: Optional[str]) -> np.ndarray:
        topo = self.topology

        def contrib(s: Snapshot, idx: Optional[np.ndarray]) -> np.ndarray:
            p = s.candidate_pool(gpu_type, zone)
            if idx is None:
                return np.where(p, s.used_gpus, 0)
            return np.where(p[idx], s.used_gpus[idx], 0)

        return snap.tracked_sum(("gused", gpu_type, zone),
                                topo.leaf_id, topo.n_leaf_groups, contrib)

    def _members_of_groups(self, groups) -> np.ndarray:
        """Ascending node indices of the given NodeNetGroups.  Ascending
        order matters: the slot-selection tie rule is lowest-node-index,
        so subset positions must increase with node index."""
        off = self._leaf_start
        return np.concatenate([np.arange(off[g], off[g + 1])
                               for g in sorted(int(g) for g in groups)])

    def _weights_fingerprint(self, job: Job, snap: Snapshot) -> tuple:
        """Per-pass (scorer, fused weights, per-pod bonus) tuple — the
        speculation guard against score-parameter drift between the
        speculative and the real schedule call (e.g. a self-tuning
        controller adjusting plugin weights)."""
        fp = []
        for pass_ in self.profile_for(job).plan(job, snap):
            for s in pass_.scorers:
                w = s.fused_weights(job)
                fp.append((s.name,
                           None if w is None
                           else (w.used, w.fit, w.group, w.topo),
                           s.per_pod_bonus(job) if s.pod_dependent
                           else 0.0))
        return tuple(fp)

    # ------------------------------------------------------------------
    # Core two-level placement (one PlacementPass)
    # ------------------------------------------------------------------
    def _resolve_pool(self, job: Job, snap: Snapshot,
                      profile: SchedulingProfile,
                      zone: Optional[str]) -> Tuple[np.ndarray, bool]:
        """Run the Filter chain.  The default GpuTypeFilter+HealthFilter
        pair hits the snapshot's cached pool mask (§3.4.1); extra
        plugins AND their masks on top.  Returns ``(pool, default)``
        where ``default`` says the pool equals the cached default mask
        (safe to key derived caches on ``(gpu_type, zone)``).

        Exact-type check, not isinstance: a subclass overriding
        ``mask()`` must go through the generic path, never be silently
        swallowed by the fast path."""
        filters = profile.filters
        extras = [f for f in filters
                  if type(f) not in (GpuTypeFilter, HealthFilter)]
        defaults = sorted(type(f).__name__ for f in filters
                          if type(f) in (GpuTypeFilter, HealthFilter))
        if defaults == ["GpuTypeFilter", "HealthFilter"]:
            pool = snap.candidate_pool(int(job.gpu_type), zone)
            default = not extras
            for f in extras:
                pool = pool & np.asarray(f.mask(job, snap, zone),
                                         dtype=bool)
        else:
            # Drain windows are structural, like the zone selector: a
            # draining node must never be placed on, even by a custom
            # Filter chain that dropped the default HealthFilter.
            pool = ~snap.node_draining
            for f in filters:
                pool = pool & np.asarray(f.mask(job, snap, zone),
                                         dtype=bool)
            if zone == "zone":
                pool = pool & snap.inference_zone
            elif zone == "general":
                pool = pool & ~snap.inference_zone
            default = False
        return pool, default

    def _run_pass(self, job: Job, snap: Snapshot, pass_: PlacementPass,
                  profile: SchedulingProfile,
                  ctx: Optional[SchedulingContext],
                  capture: Optional[Dict] = None) -> ScheduleResult:
        topo = self.topology
        obs = self.obs
        with obs_phase(obs, "filter"):
            pool, default_pool = self._resolve_pool(job, snap, profile,
                                                    pass_.zone)
        pa: Optional[Dict] = None
        if capture is not None:
            pa = {"zone": pass_.zone, "reason": "",
                  "filters": self._audit_filters(job, snap, profile,
                                                 pass_.zone),
                  "pool": int(np.count_nonzero(pool)), "breakdown": None,
                  "colocate_per_pod": 0.0}
            capture["passes"].append(pa)

        def fail(reason: str) -> ScheduleResult:
            if pa is not None:
                pa["reason"] = reason
            return ScheduleResult(None, reason)

        if not pool.any():
            return fail("empty node pool")

        # Subset scoring (million-node core): with a default Filter
        # chain, the numpy backend and no audit capture, Level 1 runs on
        # snapshot-maintained per-group aggregates and Level 2 touches
        # only the selected groups' member nodes — exact-identical to
        # the full-width pass (tests/test_scale.py), but per-attempt
        # cost scales with the job's group footprint, not cluster size.
        use_subset = (default_pool and self.config.subset_scoring
                      and self.config.batched_gang
                      and self.config.score_backend == "np"
                      and capture is None)

        # --- Level 1: NodeNetGroup preselection (§3.4.2) ---------------
        gt = int(job.gpu_type)
        if use_subset:
            pod_slots = None
            group_slots = self._group_slots_cached(snap, gt, pass_.zone,
                                                   job.gpus_per_pod)
            group_free = self._group_free_cached(snap, gt, pass_.zone)
            group_used_i = self._group_used_cached(snap, gt, pass_.zone)
        else:
            pod_slots = np.where(pool, snap.free_gpus // job.gpus_per_pod,
                                 0)
            group_slots = group_free = group_used_i = None
        group_term = self._group_score_terms(job, snap, pool, pass_, ctx)
        selected_groups = self._preselect_groups(
            job, snap, pool, pod_slots, pass_.enhanced, pass_.spread,
            group_term, group_slots=group_slots, group_free=group_free,
            group_used=group_used_i)
        if selected_groups is None:
            return fail("no NodeNetGroup set satisfies job")
        # One gather resolves both group membership and the per-node
        # anchor-group preference (rank table over groups -> node axis).
        group_pref = np.zeros(topo.n_leaf_groups, dtype=np.float32)
        for rank, g in enumerate(selected_groups):
            group_pref[g] = 1.0 / (1.0 + rank)

        # --- Level 2: node selection within selected groups ------------
        # Score chain: fused weights go through the shared kernel pass;
        # snapshot-static extra terms are added on top; pod-dependent
        # bonuses fold into the slot chains (see framework.api contract).
        weights = combine_weights(
            w for w in (s.fused_weights(job) for s in pass_.scorers)
            if w is not None)
        colocate = sum(s.per_pod_bonus(job) for s in pass_.scorers
                       if s.pod_dependent)
        cap_key = ("group_cap", gt, pass_.zone)
        group_cap = snap.derived.get(cap_key) if default_pool else None
        if group_cap is None:
            # Healthy capacity per group is delta-invariant -> cacheable
            # for the rest of the cycle (default pools only: custom
            # Filter chains may shape the pool per job).
            group_cap = np.bincount(
                topo.leaf_id,
                weights=np.where(pool, snap.healthy_per_node(), 0),
                minlength=topo.n_leaf_groups).astype(np.float32)
            if default_pool:
                snap.derived[cap_key] = group_cap
        if use_subset:
            group_used = group_used_i.astype(np.float32)
        else:
            group_used = np.bincount(
                topo.leaf_id, weights=np.where(pool, snap.used_gpus, 0),
                minlength=topo.n_leaf_groups).astype(np.float32)
        group_load = group_used / np.maximum(group_cap, 1.0)
        extra = self._extra_score_terms(job, snap, pool, pass_, ctx)
        score_out = {} if pa is not None else None
        with obs_phase(obs, "score"):
            if use_subset:
                gload_nodes = topo_pref = None
                nodes = self._select_nodes_subset(
                    job, snap, pool, selected_groups, group_pref,
                    group_load, weights, colocate, extra)
            else:
                # topo_pref prefers earlier-ranked (anchor) groups,
                # keeping a multi-pod job inside as few groups as
                # possible (§3.3.3 LeafGroup E-Binpack).
                topo_pref = group_pref[topo.leaf_id]
                in_groups = topo_pref > 0.0
                gload_nodes = group_load[topo.leaf_id]
                if self.config.batched_gang:
                    nodes = self._select_nodes_batched(
                        job, snap, pool & in_groups, gload_nodes,
                        topo_pref, weights, colocate,
                        np.where(in_groups, pod_slots, 0), extra,
                        score_out)
                else:
                    nodes = self._select_nodes_sequential(
                        job, snap, pool, in_groups, gload_nodes,
                        topo_pref, weights, colocate, extra)
        if nodes is None:
            return fail("gang placement failed")
        if pa is not None:
            pa["reason"] = "ok"
            pa["colocate_per_pod"] = float(colocate)
            if score_out and "scores" in score_out:
                pa["breakdown"] = self._audit_breakdown(
                    job, snap, pass_, pool, gload_nodes, topo_pref,
                    score_out["scores"], nodes, ctx)

        # --- Fine-grained device selection per chosen slot (§3.3.1) ----
        # One vectorized gather extracts the availability rows of the
        # selected nodes; the per-pod work is then pure python over
        # G-sized lists (no per-pod numpy dispatch, no full-bitmap copy).
        uniq = list(dict.fromkeys(nodes))
        avail_rows = (~snap.gpu_busy[uniq]
                      & snap.gpu_healthy[uniq]).tolist()
        avail_map = dict(zip(uniq, avail_rows))
        pods: List[PodPlacement] = []
        for node in nodes:
            avail = avail_map[node]
            gpus = self._pick_from_avail(avail, job.gpus_per_pod)
            if gpus is None:
                return fail("device-level selection failed")
            for g in gpus:
                avail[g] = False
            pods.append(PodPlacement(node=node, gpu_indices=gpus,
                                     nic=self._nic_list[gpus[0]]))
        placement = Placement(pods=pods)
        n_groups = len({int(topo.leaf_id[p.node]) for p in pods})
        return ScheduleResult(placement, "ok", groups_used=n_groups)

    def _group_score_terms(self, job: Job, snap: Snapshot,
                           pool: np.ndarray, pass_: PlacementPass,
                           ctx: Optional[SchedulingContext]
                           ) -> Optional[np.ndarray]:
        """Sum of Score-plugin group-level terms biasing Level-1
        preselection (None in the default profiles -> zero overhead)."""
        total: Optional[np.ndarray] = None
        for s in pass_.scorers:
            term = s.group_score(job, snap, pool, ctx)
            if term is None:
                continue
            term = np.asarray(term, dtype=np.float64)
            total = term if total is None else total + term
        return total

    def _extra_score_terms(self, job: Job, snap: Snapshot,
                           pool: np.ndarray, pass_: PlacementPass,
                           ctx: Optional[SchedulingContext]
                           ) -> Optional[np.ndarray]:
        """Sum of snapshot-static Score-plugin terms outside the fused
        weight vector (None in the default profiles -> zero overhead)."""
        total: Optional[np.ndarray] = None
        for s in pass_.scorers:
            if s.pod_dependent:
                continue
            term = s.score(job, snap, pool, ctx)
            if term is None:
                continue
            term = np.asarray(term, dtype=np.float32)
            total = term if total is None else total + term
        return total

    # ------------------------------------------------------------------
    # Decision-audit capture (repro.obs; only runs with an observer on)
    # ------------------------------------------------------------------
    def _audit_filters(self, job: Job, snap: Snapshot,
                       profile: SchedulingProfile, zone: Optional[str]
                       ) -> List[tuple]:
        """Replay the Filter chain sequentially, counting the nodes each
        stage eliminates — `(plugin, before, after)` tuples, including
        the structural stages (drain windows, the zone selector).

        The default GpuTypeFilter+HealthFilter chain is job-independent
        given ``(gpu_type, zone)``, so its replay is cached per cycle in
        ``snap.derived`` (cleared on health mutations) — the audit then
        costs one dict hit per placement attempt, not an O(n) rescan."""
        filters = profile.filters
        key = None
        if all(type(f) in (GpuTypeFilter, HealthFilter) for f in filters):
            key = ("obs_fstats", int(job.gpu_type), zone)
            cached = snap.derived.get(key)
            if cached is not None:
                return cached
        pool = ~snap.node_draining
        after = int(np.count_nonzero(pool))
        stats = [("drain", int(pool.size), after)]
        for f in filters:
            before = after
            pool = pool & np.asarray(f.mask(job, snap, zone), dtype=bool)
            after = int(np.count_nonzero(pool))
            stats.append((f.name, before, after))
        if zone == "zone":
            pool = pool & snap.inference_zone
            stats.append(("inference-zone", after,
                          int(np.count_nonzero(pool))))
        elif zone == "general":
            pool = pool & ~snap.inference_zone
            stats.append(("general-zone", after,
                          int(np.count_nonzero(pool))))
        if key is not None:
            snap.derived[key] = stats
        return stats

    def _audit_breakdown(self, job: Job, snap: Snapshot,
                         pass_: PlacementPass, pool: np.ndarray,
                         gload_nodes: np.ndarray, topo_pref: np.ndarray,
                         scores: np.ndarray, nodes: List[int],
                         ctx: Optional[SchedulingContext]) -> Dict:
        """Raw capture for the per-ScorePlugin decomposition of the
        fused score at each distinct bound node.  Mirrors
        :func:`node_scores_np`'s inputs term by term, so per node the
        lifted terms sum to the captured fused score (float32 rounding
        aside).  The audit layer does the term arithmetic and the
        per-node pivot lazily, on first ``decision.passes`` read —
        this function is on the bind hot path (≤5% attached-overhead
        budget in ``benchmarks/obs_bench.py``)."""
        idx = np.fromiter(dict.fromkeys(nodes), dtype=np.intp)
        # Capture = gathers only.  Small per-node copies of the fused
        # kernel's inputs (snapshot rows mutate after the bind; the
        # full gload/topo/score arrays must not be pinned by the audit
        # ring) plus the scorers' weight rows; the per-plugin term
        # arithmetic happens lazily in the audit layer's lift.  Arrays
        # stay ndarrays: one GC-tracked object per field instead of
        # O(nodes) boxed floats, so a long attached run does not
        # inflate collector scans.
        weights: List[tuple] = []
        extra: Dict[str, np.ndarray] = {}
        for s in pass_.scorers:
            w = s.fused_weights(job)
            if w is not None:
                weights.append((s.name, w.used, w.fit, w.group, w.topo))
            if s.pod_dependent:
                continue
            term = s.score(job, snap, pool, ctx)
            if term is not None:
                prev = extra.get(s.name)
                term = np.asarray(term)[idx]
                extra[s.name] = term if prev is None else prev + term
        return {"nodes": idx,
                "used": snap.used_gpus[idx],
                "free": snap.free_gpus[idx],
                "gload": np.asarray(gload_nodes)[idx],
                "tpref": np.asarray(topo_pref)[idx],
                "totals": scores[idx],
                "g": float(self.topology.gpus_per_node),
                "request": float(job.gpus_per_pod),
                "weights": weights,
                "extra": extra}

    # ------------------------------------------------------------------
    # Node selection: batched (one fused pass) vs sequential (per pod)
    # ------------------------------------------------------------------
    def _select_nodes_batched(self, job: Job, snap: Snapshot,
                              mask: np.ndarray, gload_nodes: np.ndarray,
                              topo_pref: np.ndarray, weights: ScoreWeights,
                              colocate: float,
                              slots: Optional[np.ndarray] = None,
                              extra: Optional[np.ndarray] = None,
                              score_out: Optional[Dict] = None
                              ) -> Optional[List[int]]:
        """Whole-gang placement from ONE filter+score pass (§3.4).

        The fused pass scores every node once; capacity expansion turns
        each node into ``floor(free/gpus_per_pod)`` pod slots and the
        heap-based top-k selection emulates the sequential argmax loop
        exactly (same nodes, same order, same tie-breaking).
        """
        backend = self.config.score_backend
        if backend == "np":
            scores = node_scores_np(
                snap.free_gpus, snap.used_gpus, mask, gload_nodes,
                topo_pref, job.gpus_per_pod, self.topology.gpus_per_node,
                weights)
        else:
            from ..kernels.ops import node_scores_and_slots
            s, sl = node_scores_and_slots(
                snap.free_gpus, snap.used_gpus, mask.astype(np.int32),
                gload_nodes, topo_pref, request=job.gpus_per_pod,
                gpus_per_node=self.topology.gpus_per_node, weights=weights,
                backend=backend)
            scores = np.asarray(s)
            slots = np.asarray(sl).astype(np.int64)
        if extra is not None:
            scores = np.where(scores > NEG_INF, scores + extra, scores)
        if score_out is not None:
            # By reference — the audit breakdown reads a handful of
            # entries; no copy on the scheduling path.
            score_out["scores"] = scores
        return select_gang_slots(
            scores, snap.free_gpus, job.gpus_per_pod, job.n_pods,
            fit_weight=weights.fit, colocate_bonus=colocate, slots=slots,
            engine=self.config.slot_engine)

    def _select_nodes_subset(self, job: Job, snap: Snapshot,
                             pool: np.ndarray, selected_groups: List[int],
                             group_pref: np.ndarray,
                             group_load: np.ndarray,
                             weights: ScoreWeights, colocate: float,
                             extra: Optional[np.ndarray] = None
                             ) -> Optional[List[int]]:
        """Batched gang placement over ONLY the selected groups' member
        nodes (subset scoring).  Exact-identical to the full-width
        batched pass: every score term is elementwise, nodes outside the
        selected groups contribute zero slots there, and the ascending
        subset preserves the lowest-node-index tie rule — so the fused
        scores, candidate set and emission order all coincide.
        """
        sub = self._members_of_groups(selected_groups)
        leaf_sub = self.topology.leaf_id[sub]
        mask = pool[sub]
        free_sub = snap.free_gpus[sub]
        scores = node_scores_np(
            free_sub, snap.used_gpus[sub], mask, group_load[leaf_sub],
            group_pref[leaf_sub], job.gpus_per_pod,
            self.topology.gpus_per_node, weights)
        if extra is not None:
            ex = np.asarray(extra, dtype=np.float32)[sub]
            scores = np.where(scores > NEG_INF, scores + ex, scores)
        slots = np.where(mask, free_sub // job.gpus_per_pod,
                         0).astype(np.int64)
        order = select_gang_slots(
            scores, free_sub, job.gpus_per_pod, job.n_pods,
            fit_weight=weights.fit, colocate_bonus=colocate, slots=slots,
            engine=self.config.slot_engine)
        if order is None:
            return None
        return [int(sub[p]) for p in order]

    def _select_nodes_sequential(self, job: Job, snap: Snapshot,
                                 pool: np.ndarray, in_groups: np.ndarray,
                                 gload_nodes: np.ndarray,
                                 topo_pref: np.ndarray,
                                 weights: ScoreWeights,
                                 colocate: float,
                                 extra: Optional[np.ndarray] = None
                                 ) -> Optional[List[int]]:
        """The replaced O(n_pods × n_nodes) loop: full filter+score pass
        and argmax once per pod, with the per-pod co-location sweep.
        Kept verbatim as the A/B baseline the batched engine is measured
        against in ``benchmarks/sched_scale_bench.py``."""
        free = snap.free_gpus.copy()        # mutated as pods are placed
        backend = self.config.score_backend
        nodes: List[int] = []
        for _ in range(job.n_pods):
            mask = pool & in_groups
            scores = compute_node_scores(
                free, snap.used_gpus + 0, mask, gload_nodes, topo_pref,
                job.gpus_per_pod, self.topology.gpus_per_node, weights,
                backend=backend)
            if extra is not None:
                scores = np.where(scores > NEG_INF, scores + extra, scores)
            if colocate and nodes:
                for n in nodes:
                    if scores[n] > NEG_INF:
                        scores[n] += colocate
            node = int(np.argmax(scores))
            if scores[node] <= NEG_INF:
                return None
            free[node] -= job.gpus_per_pod
            nodes.append(node)
        return nodes

    # ------------------------------------------------------------------
    def _preselect_groups(self, job: Job, snap: Snapshot, pool: np.ndarray,
                          pod_slots: Optional[np.ndarray], enhanced: bool,
                          spread: bool,
                          group_term: Optional[np.ndarray] = None,
                          group_slots: Optional[np.ndarray] = None,
                          group_free: Optional[np.ndarray] = None,
                          group_used: Optional[np.ndarray] = None
                          ) -> Optional[List[int]]:
        """Pick an ordered list of candidate NodeNetGroups.

        * small job + enhanced binpack: busiest group that still fits
          (consolidate, keep empty groups reserved for large jobs);
        * spread passes: all groups, emptiest first;
        * large jobs: greedy minimal set of groups, preferring same-spine
          neighbours (JTTED: fewest groups, closest topology).

        ``pod_slots`` is the per-node capacity expansion
        ``floor(free / gpus_per_pod)`` restricted to the pool; subset
        scoring passes ``None`` and supplies precomputed per-group
        ``group_slots``/``group_free``/``group_used`` aggregates (the
        snapshot-maintained TrackedGroupSum totals — identical values to
        the legacy bincounts) instead.  ``group_term`` (Score plugins'
        group-level contribution) ranks above the pass's default keys;
        ties fall through to them.
        """
        topo = self.topology
        if group_slots is None:
            group_slots = np.bincount(
                topo.leaf_id, weights=pod_slots,
                minlength=topo.n_leaf_groups).astype(int)
        candidates = np.nonzero(group_slots > 0)[0]
        if len(candidates) == 0:
            return None

        if group_slots.sum() < job.n_pods:
            return None

        fits_one = candidates[group_slots[candidates] >= job.n_pods]
        if len(fits_one) > 0:
            # Only the best-ranked group is used; lexsort the (reversed)
            # key tuples instead of a python sort with lambda keys.
            if spread:
                if group_free is None:
                    group_free = np.bincount(
                        topo.leaf_id,
                        weights=np.where(pool, snap.free_gpus, 0),
                        minlength=topo.n_leaf_groups).astype(int)
                # Spread wants room: emptiest group first.
                keys = (fits_one, -group_free[fits_one])
            else:
                if group_used is None:
                    group_used = np.bincount(
                        topo.leaf_id,
                        weights=np.where(pool, snap.used_gpus, 0),
                        minlength=topo.n_leaf_groups).astype(int)
                if enhanced:
                    if group_free is None:
                        group_free = np.bincount(
                            topo.leaf_id,
                            weights=np.where(pool, snap.free_gpus, 0),
                            minlength=topo.n_leaf_groups).astype(int)
                    # LeafGroup-level E-Binpack: busiest group that fits.
                    keys = (fits_one, group_free[fits_one],
                            -group_used[fits_one])
                else:
                    # Plain binpack is node-level only: first fitting group
                    # by best node score; approximate with most-used group
                    # too but without reserving empties (same order,
                    # documented).
                    keys = (fits_one, -group_used[fits_one])
            if group_term is not None:
                # lexsort: last key is primary -> plugin term outranks
                # the default ranking, defaults break ties.
                keys = keys + (-group_term[fits_one],)
            return [int(fits_one[np.lexsort(keys)[0]])]

        # Multi-group job: greedy cover minimizing group count, preferring
        # same-spine neighbours of the seed group (topology-aware §3.3.5).
        seed_keys = (candidates, -group_slots[candidates])
        if group_term is not None:
            seed_keys = seed_keys + (-group_term[candidates],)
        seed = int(candidates[np.lexsort(seed_keys)[0]])
        group_spine = self._group_spine
        rest = candidates[candidates != seed]
        rest_keys = (rest, -group_slots[rest],
                     group_spine[rest] != group_spine[seed])
        if group_term is not None:
            rest_keys = rest_keys + (-group_term[rest],)
        rest = rest[np.lexsort(rest_keys)]
        # Greedy prefix: smallest set of groups whose slot total covers the
        # job (fits_one was empty, so the seed alone never suffices).
        covered = int(group_slots[seed]) + np.cumsum(group_slots[rest])
        cut = int(np.searchsorted(covered, job.n_pods)) + 1
        if cut > len(rest):
            return None
        return [seed] + [int(g) for g in rest[:cut]]

    # ------------------------------------------------------------------
    # Fine-grained device selection (§3.3.1)
    # ------------------------------------------------------------------
    def _pick_devices(self, busy_row: np.ndarray, healthy_row: np.ndarray,
                      k: int) -> Optional[Tuple[int, ...]]:
        """Choose ``k`` healthy free GPU slots minimizing link-class cost
        on one node row (see :meth:`_pick_from_avail`)."""
        return self._pick_from_avail(
            (~busy_row & healthy_row).tolist(), k)

    def _pick_from_avail(self, avail: List[bool], k: int
                         ) -> Optional[Tuple[int, ...]]:
        """Choose ``k`` available GPU slots minimizing link-class cost.

        Preference order: a single NVLink island (intra-island link class
        is 0, so the first island that fits is already cost-minimal),
        then best-effort fill in (island, slot) order.  Pure python over
        the G-sized row: this runs once per placed pod, and numpy call
        dispatch dominated the old implementation at G=8.
        """
        nic = self._nic_list
        members: List[List[int]] = [[] for _ in range(self._n_islands)]
        n_avail = 0
        for g, a in enumerate(avail):
            if a:
                members[nic[g]].append(g)
                n_avail += 1
        if n_avail < k:
            return None
        for m in members:
            if len(m) >= k:
                return tuple(m[:k])
        # No single island fits: greedy fill in (island, slot) order.
        flat = [g for m in members for g in m]
        return tuple(flat[:k])
