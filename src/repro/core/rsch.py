"""RSCH — the Resource-aware Scheduler (paper §3.3).

RSCH turns an admitted job into a concrete :class:`Placement`:

1. **Node-pool restriction** (§3.4.1): only nodes of the requested GPU
   type are considered.
2. **Two-level scheduling** (§3.4.2): first preselect NodeNetGroups
   (LeafGroups) with enough free capacity, then select nodes inside the
   chosen groups.
3. **Strategy scoring** (§3.3.3/§3.3.4): Binpack, E-Binpack, Spread or
   E-Spread via the shared fused filter+score pass
   (:mod:`repro.core.scoring`, Pallas kernel in
   :mod:`repro.kernels.node_score`).
4. **Gang semantics** (§3.3.2): the whole job is placed transactionally —
   if any pod cannot be placed the job stays pending and no state is
   mutated.
5. **Fine-grained device selection** (§3.3.1): within a node, pick the
   healthy GPU combination with the best interconnect (NVLink island >
   same-NUMA > cross-NUMA) and pair it with the island's RDMA NIC.
6. **Topology awareness** (§3.3.5): groups are chosen to minimize the
   number of NodeNetGroups (JTTED) preferring same-spine neighbours;
   EP-style jobs can be pinned to a single HBD.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Dict, List, Optional, Tuple

import numpy as np

from .cluster import ClusterState
from .job import Job, JobKind, Placement, PodPlacement
from .scoring import (BINPACK, E_BINPACK, E_SPREAD, NEG_INF, SPREAD,
                      ScoreWeights, compute_node_scores, node_scores_np,
                      select_gang_slots)
from .snapshot import Snapshot
from .topology import ClusterTopology


class Strategy(enum.Enum):
    BINPACK = "binpack"
    E_BINPACK = "e-binpack"
    SPREAD = "spread"
    E_SPREAD = "e-spread"


_WEIGHTS: Dict[Strategy, ScoreWeights] = {
    Strategy.BINPACK: BINPACK,
    Strategy.E_BINPACK: E_BINPACK,
    Strategy.SPREAD: SPREAD,
    Strategy.E_SPREAD: E_SPREAD,
}


@dataclasses.dataclass
class RSCHConfig:
    train_strategy: Strategy = Strategy.E_BINPACK
    infer_strategy: Strategy = Strategy.E_SPREAD
    # E-Spread (§3.3.4): inference pods smaller than this use the dedicated
    # zone; everything else falls back to E-Binpack in the general pool.
    espread_small_pod_gpus: int = 8
    # Schedule EP-style jobs at HBD granularity (§3.3.5 Scale-Up).
    hbd_granular_ep: bool = True
    # Batched gang placement (§3.4): one fused filter+score pass +
    # capacity-aware top-k slot selection for the whole gang, instead of
    # re-scoring every node once per pod.  The sequential path is kept
    # for A/B benchmarking (benchmarks/sched_scale_bench.py).
    batched_gang: bool = True
    # Score-pass backend: "np" (numpy, simulator default), "ref" (jnp
    # oracle), "interpret" (Pallas on CPU), "pallas" (compiled TPU).
    score_backend: str = "np"
    # Same-node co-location bonus per already-placed pod of the job
    # (node-level E-Binpack, §3.3.3).
    colocate_bonus: float = 2.0


@dataclasses.dataclass
class ScheduleResult:
    placement: Optional[Placement]
    reason: str = ""
    groups_used: int = 0


class RSCH:
    def __init__(self, topology: ClusterTopology,
                 config: Optional[RSCHConfig] = None) -> None:
        self.topology = topology
        self.config = config or RSCHConfig()
        self._link_class = topology.gpu_link_class()
        self._nic = topology.nic_for_gpu()
        # Device selection runs once per placed pod; python lists over the
        # G-sized slot axis beat numpy dispatch overhead at G=8.
        self._nic_list = [int(n) for n in self._nic]
        self._n_islands = int(self._nic.max()) + 1
        # Static per-NodeNetGroup spine membership (topology never changes).
        self._group_spine = topology.spine_id[np.searchsorted(
            topology.leaf_id, np.arange(topology.n_leaf_groups))]

    # ------------------------------------------------------------------
    # Public API
    # ------------------------------------------------------------------
    def strategy_for(self, job: Job) -> Strategy:
        if job.kind is JobKind.INFER:
            return self.config.infer_strategy
        return self.config.train_strategy

    def feasible(self, job: Job, snap: Snapshot) -> bool:
        """Dynamic-resource-admission check (§3.2.1): are there enough
        free, healthy GPUs in the job's node pool right now?"""
        pool = snap.candidate_pool(job.gpu_type)
        per_node_ok = snap.free_gpus >= job.gpus_per_pod
        capacity = int((snap.free_gpus // job.gpus_per_pod)[
            pool & per_node_ok].sum())
        return capacity >= job.n_pods

    def schedule(self, job: Job, snap: Snapshot) -> ScheduleResult:
        """Compute a placement against a snapshot.  Pure — commits happen
        via ``ClusterState.allocate`` by the caller."""
        strategy = self.strategy_for(job)
        if (strategy is Strategy.E_SPREAD and job.kind is JobKind.INFER
                and job.gpus_per_pod < self.config.espread_small_pod_gpus
                and bool(snap.inference_zone.any())):
            result = self._schedule_with_mask(
                job, snap, Strategy.E_SPREAD, zone="zone")
            if result.placement is not None:
                return result
            # Remaining replicas: E-Binpack in the general pool (§3.3.4).
            return self._schedule_with_mask(
                job, snap, Strategy.E_BINPACK, zone="general")
        if strategy is Strategy.E_SPREAD:
            # Large inference pods get consolidated full nodes in the
            # general pool, keeping the dedicated zone for small
            # replicas (§3.3.4); fall back to anywhere if it's full.
            strategy = Strategy.E_BINPACK
            if bool(snap.inference_zone.any()):
                result = self._schedule_with_mask(
                    job, snap, strategy, zone="general")
                if result.placement is not None:
                    return result
        return self._schedule_with_mask(job, snap, strategy, None)

    # ------------------------------------------------------------------
    # Core two-level placement
    # ------------------------------------------------------------------
    def _schedule_with_mask(self, job: Job, snap: Snapshot,
                            strategy: Strategy, zone: Optional[str]
                            ) -> ScheduleResult:
        topo = self.topology
        pool = snap.candidate_pool(job.gpu_type, zone)
        if not pool.any():
            return ScheduleResult(None, "empty node pool")

        # --- Level 1: NodeNetGroup preselection (§3.4.2) ---------------
        enhanced = strategy in (Strategy.E_BINPACK, Strategy.E_SPREAD)
        pod_slots = np.where(pool, snap.free_gpus // job.gpus_per_pod, 0)
        selected_groups = self._preselect_groups(job, snap, pool, pod_slots,
                                                 enhanced, strategy)
        if selected_groups is None:
            return ScheduleResult(None, "no NodeNetGroup set satisfies job")
        # One gather resolves both group membership and the per-node
        # anchor-group preference (rank table over groups -> node axis).
        group_pref = np.zeros(topo.n_leaf_groups, dtype=np.float32)
        for rank, g in enumerate(selected_groups):
            group_pref[g] = 1.0 / (1.0 + rank)
        topo_pref = group_pref[topo.leaf_id]
        in_groups = topo_pref > 0.0

        # --- Level 2: node selection within selected groups ------------
        weights = _WEIGHTS[strategy]
        group_used = np.bincount(
            topo.leaf_id, weights=np.where(pool, snap.used_gpus, 0),
            minlength=topo.n_leaf_groups).astype(np.float32)
        cap_key = ("group_cap", int(job.gpu_type), zone)
        group_cap = snap.derived.get(cap_key)
        if group_cap is None:
            # Healthy capacity per group is delta-invariant -> cacheable
            # for the rest of the cycle.
            group_cap = np.bincount(
                topo.leaf_id,
                weights=np.where(pool, snap.healthy_per_node(), 0),
                minlength=topo.n_leaf_groups).astype(np.float32)
            snap.derived[cap_key] = group_cap
        group_load = group_used / np.maximum(group_cap, 1.0)
        # topo_pref (computed above) prefers earlier-ranked (anchor)
        # groups, keeping a multi-pod job inside as few groups as
        # possible (§3.3.3 LeafGroup E-Binpack).
        mask = pool & in_groups
        gload_nodes = group_load[topo.leaf_id]
        # Same-node co-location bonus (node-level E-Binpack §3.3.3): pods
        # of this job already on a node make it more attractive for the
        # next pod; in the batched path it is folded into the per-node
        # slot chains.
        colocate = (self.config.colocate_bonus
                    if enhanced and job.kind is not JobKind.INFER else 0.0)
        if self.config.batched_gang:
            nodes = self._select_nodes_batched(
                job, snap, mask, gload_nodes, topo_pref, weights, colocate,
                np.where(in_groups, pod_slots, 0))
        else:
            nodes = self._select_nodes_sequential(
                job, snap, pool, in_groups, gload_nodes, topo_pref,
                weights, colocate)
        if nodes is None:
            return ScheduleResult(None, "gang placement failed")

        # --- Fine-grained device selection per chosen slot (§3.3.1) ----
        # One vectorized gather extracts the availability rows of the
        # selected nodes; the per-pod work is then pure python over
        # G-sized lists (no per-pod numpy dispatch, no full-bitmap copy).
        uniq = list(dict.fromkeys(nodes))
        avail_rows = (~snap.gpu_busy[uniq]
                      & snap.gpu_healthy[uniq]).tolist()
        avail_map = dict(zip(uniq, avail_rows))
        pods: List[PodPlacement] = []
        for node in nodes:
            avail = avail_map[node]
            gpus = self._pick_from_avail(avail, job.gpus_per_pod)
            if gpus is None:
                return ScheduleResult(None, "device-level selection failed")
            for g in gpus:
                avail[g] = False
            pods.append(PodPlacement(node=node, gpu_indices=gpus,
                                     nic=self._nic_list[gpus[0]]))
        placement = Placement(pods=pods)
        n_groups = len({int(topo.leaf_id[p.node]) for p in pods})
        return ScheduleResult(placement, "ok", groups_used=n_groups)

    # ------------------------------------------------------------------
    # Node selection: batched (one fused pass) vs sequential (per pod)
    # ------------------------------------------------------------------
    def _select_nodes_batched(self, job: Job, snap: Snapshot,
                              mask: np.ndarray, gload_nodes: np.ndarray,
                              topo_pref: np.ndarray, weights: ScoreWeights,
                              colocate: float,
                              slots: Optional[np.ndarray] = None
                              ) -> Optional[List[int]]:
        """Whole-gang placement from ONE filter+score pass (§3.4).

        The fused pass scores every node once; capacity expansion turns
        each node into ``floor(free/gpus_per_pod)`` pod slots and the
        heap-based top-k selection emulates the sequential argmax loop
        exactly (same nodes, same order, same tie-breaking).
        """
        backend = self.config.score_backend
        if backend == "np":
            scores = node_scores_np(
                snap.free_gpus, snap.used_gpus, mask, gload_nodes,
                topo_pref, job.gpus_per_pod, self.topology.gpus_per_node,
                weights)
        else:
            from ..kernels.ops import node_scores_and_slots
            s, sl = node_scores_and_slots(
                snap.free_gpus, snap.used_gpus, mask.astype(np.int32),
                gload_nodes, topo_pref, request=job.gpus_per_pod,
                gpus_per_node=self.topology.gpus_per_node, weights=weights,
                backend=backend)
            scores = np.asarray(s)
            slots = np.asarray(sl).astype(np.int64)
        return select_gang_slots(
            scores, snap.free_gpus, job.gpus_per_pod, job.n_pods,
            fit_weight=weights.fit, colocate_bonus=colocate, slots=slots)

    def _select_nodes_sequential(self, job: Job, snap: Snapshot,
                                 pool: np.ndarray, in_groups: np.ndarray,
                                 gload_nodes: np.ndarray,
                                 topo_pref: np.ndarray,
                                 weights: ScoreWeights,
                                 colocate: float) -> Optional[List[int]]:
        """The replaced O(n_pods × n_nodes) loop: full filter+score pass
        and argmax once per pod, with the per-pod co-location sweep.
        Kept verbatim as the A/B baseline the batched engine is measured
        against in ``benchmarks/sched_scale_bench.py``."""
        free = snap.free_gpus.copy()        # mutated as pods are placed
        backend = self.config.score_backend
        nodes: List[int] = []
        for _ in range(job.n_pods):
            mask = pool & in_groups
            scores = compute_node_scores(
                free, snap.used_gpus + 0, mask, gload_nodes, topo_pref,
                job.gpus_per_pod, self.topology.gpus_per_node, weights,
                backend=backend)
            if colocate and nodes:
                for n in nodes:
                    if scores[n] > NEG_INF:
                        scores[n] += colocate
            node = int(np.argmax(scores))
            if scores[node] <= NEG_INF:
                return None
            free[node] -= job.gpus_per_pod
            nodes.append(node)
        return nodes

    # ------------------------------------------------------------------
    def _preselect_groups(self, job: Job, snap: Snapshot, pool: np.ndarray,
                          pod_slots: np.ndarray, enhanced: bool,
                          strategy: Strategy) -> Optional[List[int]]:
        """Pick an ordered list of candidate NodeNetGroups.

        * small job + E-Binpack: busiest group that still fits (consolidate,
          keep empty groups reserved for large jobs);
        * spread strategies: all groups, emptiest first;
        * large jobs: greedy minimal set of groups, preferring same-spine
          neighbours (JTTED: fewest groups, closest topology).

        ``pod_slots`` is the per-node capacity expansion
        ``floor(free / gpus_per_pod)`` restricted to the pool.
        """
        topo = self.topology
        group_slots = np.bincount(topo.leaf_id, weights=pod_slots,
                                  minlength=topo.n_leaf_groups).astype(int)
        candidates = np.nonzero(group_slots > 0)[0]
        if len(candidates) == 0:
            return None

        if group_slots.sum() < job.n_pods:
            return None

        fits_one = candidates[group_slots[candidates] >= job.n_pods]
        if len(fits_one) > 0:
            # Only the best-ranked group is used; lexsort the (reversed)
            # key tuples instead of a python sort with lambda keys.
            group_free = np.bincount(
                topo.leaf_id, weights=np.where(pool, snap.free_gpus, 0),
                minlength=topo.n_leaf_groups).astype(int)
            if strategy in (Strategy.SPREAD, Strategy.E_SPREAD):
                # Spread wants room: emptiest group first.
                keys = (fits_one, -group_free[fits_one])
            else:
                group_used = np.bincount(
                    topo.leaf_id,
                    weights=np.where(pool, snap.used_gpus, 0),
                    minlength=topo.n_leaf_groups).astype(int)
                if enhanced:
                    # LeafGroup-level E-Binpack: busiest group that fits.
                    keys = (fits_one, group_free[fits_one],
                            -group_used[fits_one])
                else:
                    # Plain binpack is node-level only: first fitting group
                    # by best node score; approximate with most-used group
                    # too but without reserving empties (same order,
                    # documented).
                    keys = (fits_one, -group_used[fits_one])
            return [int(fits_one[np.lexsort(keys)[0]])]

        # Multi-group job: greedy cover minimizing group count, preferring
        # same-spine neighbours of the seed group (topology-aware §3.3.5).
        seed = int(candidates[np.lexsort(
            (candidates, -group_slots[candidates]))[0]])
        group_spine = self._group_spine
        rest = candidates[candidates != seed]
        rest = rest[np.lexsort((rest, -group_slots[rest],
                                group_spine[rest] != group_spine[seed]))]
        # Greedy prefix: smallest set of groups whose slot total covers the
        # job (fits_one was empty, so the seed alone never suffices).
        covered = int(group_slots[seed]) + np.cumsum(group_slots[rest])
        cut = int(np.searchsorted(covered, job.n_pods)) + 1
        if cut > len(rest):
            return None
        return [seed] + [int(g) for g in rest[:cut]]

    # ------------------------------------------------------------------
    # Fine-grained device selection (§3.3.1)
    # ------------------------------------------------------------------
    def _pick_devices(self, busy_row: np.ndarray, healthy_row: np.ndarray,
                      k: int) -> Optional[Tuple[int, ...]]:
        """Choose ``k`` healthy free GPU slots minimizing link-class cost
        on one node row (see :meth:`_pick_from_avail`)."""
        return self._pick_from_avail(
            (~busy_row & healthy_row).tolist(), k)

    def _pick_from_avail(self, avail: List[bool], k: int
                         ) -> Optional[Tuple[int, ...]]:
        """Choose ``k`` available GPU slots minimizing link-class cost.

        Preference order: a single NVLink island (intra-island link class
        is 0, so the first island that fits is already cost-minimal),
        then best-effort fill in (island, slot) order.  Pure python over
        the G-sized row: this runs once per placed pod, and numpy call
        dispatch dominated the old implementation at G=8.
        """
        nic = self._nic_list
        members: List[List[int]] = [[] for _ in range(self._n_islands)]
        n_avail = 0
        for g, a in enumerate(avail):
            if a:
                members[nic[g]].append(g)
                n_avail += 1
        if n_avail < k:
            return None
        for m in members:
            if len(m) >= k:
                return tuple(m[:k])
        # No single island fits: greedy fill in (island, slot) order.
        flat = [g for m in members for g in m]
        return tuple(flat[:k])
