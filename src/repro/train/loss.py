"""Token-level cross-entropy with numerically-stable log-softmax."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def cross_entropy_loss(logits: jnp.ndarray, labels: jnp.ndarray,
                       ignore_id: int = -1) -> jnp.ndarray:
    """Mean CE over non-ignored positions.

    logits: (B, S, V) (any float dtype); labels: (B, S) int32.
    """
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = lse - gold
    mask = (labels != ignore_id).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(mask.sum(), 1.0)
