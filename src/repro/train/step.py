"""Train-step factory: loss + grad + AdamW update, pjit-ready.

``make_train_step`` returns a pure function
``(params, opt_state, batch) -> (params, opt_state, metrics)`` suitable
for ``jax.jit`` with explicit in/out shardings (see launch/dryrun.py and
launch/train.py).  Activation checkpointing (remat) over the layer scan
is the default for training — the paper-faithful baseline for the
roofline's memory term.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..models.model import Model
from .loss import cross_entropy_loss
from .optim import AdamWConfig, adamw_update

PyTree = Any
AUX_WEIGHT = 0.01     # MoE load-balance loss weight


def make_train_step(cfg: ArchConfig, opt_cfg: AdamWConfig = AdamWConfig(),
                    *, remat: bool = True, microbatches: int = 1,
                    grad_shardings: PyTree = None
                    ) -> Callable[[PyTree, PyTree, Dict[str, jnp.ndarray]],
                                  Tuple[PyTree, PyTree, Dict[str, Any]]]:
    """``microbatches > 1`` splits the per-device batch and accumulates
    gradients with a ``lax.scan`` (gradient accumulation).  Activation
    live range — in particular the (L, B_ubatch, S, d) saved-residual
    stack under remat — shrinks by the microbatch factor, which is what
    lets the train_4k shapes fit v5e HBM (EXPERIMENTS.md §Perf).

    ``grad_shardings`` (a NamedSharding tree matching params) pins the
    f32 accumulator inside the scan: without it SPMD keeps the embed /
    lm_head gradient carries fully replicated — 2 x 1.6 GB f32 per device
    on mistral-large plus same-sized transients (§Perf iteration log)."""
    model = Model(cfg)

    def loss_fn(params, batch):
        logits, aux = model.forward(params, batch, remat=remat)
        loss = cross_entropy_loss(logits, batch["labels"])
        return loss + AUX_WEIGHT * aux, (loss, aux)

    def grads_of(params, batch):
        return jax.value_and_grad(loss_fn, has_aux=True)(params, batch)

    def split_ubatches(batch):
        def split(x):
            b = x.shape[0]
            if b % microbatches:
                raise ValueError(
                    f"batch {b} not divisible by {microbatches} ubatches")
            return x.reshape((microbatches, b // microbatches) + x.shape[1:])
        return jax.tree.map(split, batch)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            (total, (loss, aux)), grads = grads_of(params, batch)
        else:
            ubatches = split_ubatches(batch)

            def pin(tree):
                if grad_shardings is None:
                    return tree
                return jax.tree.map(jax.lax.with_sharding_constraint,
                                    tree, grad_shardings)

            def body(acc, ubatch):
                (t, (l, a)), g = grads_of(params, ubatch)
                acc_g, acc_m = acc
                acc_g = pin(jax.tree.map(jnp.add, acc_g, pin(g)))
                return (acc_g, acc_m + jnp.stack([t, l, a])), None

            zero_g = pin(jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params))
            (grads, sums), _ = jax.lax.scan(
                body, (zero_g, jnp.zeros((3,), jnp.float32)), ubatches)
            inv = 1.0 / microbatches
            grads = jax.tree.map(lambda g: g * inv, grads)
            total, loss, aux = sums[0] * inv, sums[1] * inv, sums[2] * inv
        params, opt_state, gnorm = adamw_update(opt_cfg, grads, opt_state,
                                                params)
        metrics = {"loss": loss, "aux_loss": aux, "total_loss": total,
                   "grad_norm": gnorm}
        return params, opt_state, metrics

    return train_step


class TrainState:
    """Thin mutable wrapper used by the CPU example driver."""

    def __init__(self, cfg: ArchConfig, key,
                 opt_cfg: AdamWConfig = AdamWConfig(),
                 dtype=jnp.float32, remat: bool = False) -> None:
        from .optim import adamw_init
        self.cfg = cfg
        self.model = Model(cfg)
        self.params = self.model.init(key, dtype=dtype)
        self.opt_state = adamw_init(self.params)
        self.step_fn = jax.jit(make_train_step(cfg, opt_cfg, remat=remat))
        self.history = []

    def step(self, batch) -> Dict[str, float]:
        self.params, self.opt_state, metrics = self.step_fn(
            self.params, self.opt_state, batch)
        out = {k: float(v) for k, v in metrics.items()}
        self.history.append(out)
        return out
