"""Training substrate: loss, AdamW, train-step factory."""

from .loss import cross_entropy_loss
from .optim import AdamWConfig, adamw_init, adamw_update, opt_specs
from .step import TrainState, make_train_step

__all__ = ["cross_entropy_loss", "AdamWConfig", "adamw_init",
           "adamw_update", "opt_specs", "TrainState", "make_train_step"]
