"""AdamW, built from scratch (no optax dependency).

Optimizer state mirrors the parameter tree (``m``, ``v`` per leaf, kept
in f32 regardless of parameter dtype) plus a replicated step counter, so
``sharding.param_shardings`` applies verbatim to the moments.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def adamw_init(params: PyTree) -> PyTree:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def opt_specs(param_specs: PyTree, moment_dtype=jnp.float32) -> PyTree:
    """ShapeDtypeStruct twin of ``adamw_init`` (dry-run).

    ``moment_dtype=bfloat16`` halves optimizer-state HBM (the memory-
    tight v5e fit for the 100B+ archs; update math stays f32 — see
    ``adamw_update``)."""
    md = lambda s: jax.ShapeDtypeStruct(s.shape, moment_dtype)
    return {"m": jax.tree.map(md, param_specs),
            "v": jax.tree.map(md, param_specs),
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def _global_norm(tree: PyTree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(cfg: AdamWConfig, grads: PyTree, opt_state: PyTree,
                 params: PyTree) -> Tuple[PyTree, PyTree, jnp.ndarray]:
    """One AdamW step with global-norm clipping.

    Returns (new_params, new_opt_state, grad_norm)."""
    step = opt_state["step"] + 1
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    b1t = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2t = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        mdt = m.dtype                      # moments may be stored bf16
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mh = m / b1t
        vh = v / b2t
        delta = mh / (jnp.sqrt(vh) + cfg.eps) \
            + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - cfg.lr * delta).astype(p.dtype), \
            m.astype(mdt), v.astype(mdt)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    new = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([t[0] for t in new])
    new_m = treedef.unflatten([t[1] for t in new])
    new_v = treedef.unflatten([t[2] for t in new])
    return new_p, {"m": new_m, "v": new_v, "step": step}, gnorm
