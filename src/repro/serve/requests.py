"""Bridging helpers: workload-level requests → engine-level requests.

The serving fabric has two request representations with different jobs:

* :class:`repro.core.workload.ServeRequest` — what the *router* sees:
  arrival time, query class (quality floor, latency SLO), token budget.
  Produced by :func:`repro.core.workload.request_trace`.
* :class:`repro.serve.engine.Request` — what the *engine* executes:
  concrete prompt token ids and a decode budget.

:func:`to_engine_request` converts the former into the latter with a
deterministic per-uid synthetic prompt (same seed ⇒ same tokens), so a
routed trace can be replayed at token-level fidelity on a real
:class:`~repro.serve.engine.ServeEngine` when needed.
"""

from __future__ import annotations

import numpy as np

from ..core.workload import (DEFAULT_QUERY_CLASSES, QueryClass,
                             ServeRequest, request_trace)
from .engine import Request

__all__ = ["QueryClass", "ServeRequest", "DEFAULT_QUERY_CLASSES",
           "request_trace", "to_engine_request"]


def to_engine_request(req: ServeRequest, *, vocab: int,
                      seed: int = 0,
                      max_prompt: int = 64,
                      max_new: int = 32,
                      deadline_steps: int | None = None) -> Request:
    """Materialise prompt tokens for a routed request.

    Token counts are clipped to ``max_prompt`` / ``max_new`` so smoke
    engines stay CPU-sized; the prompt is a deterministic function of
    ``(seed, req.uid)``."""
    rng = np.random.default_rng([seed, req.uid])
    n_prompt = max(1, min(req.prompt_tokens, max_prompt))
    return Request(
        uid=req.uid,
        prompt=rng.integers(0, vocab, size=n_prompt).astype(np.int32),
        max_new_tokens=max(1, min(req.output_tokens, max_new)),
        qclass=req.qclass.name,
        deadline_steps=deadline_steps,
    )
