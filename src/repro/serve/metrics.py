"""Serving-side metrics: TTFT / TPOT / SLO attainment / cost-per-token.

Request-level counterparts of the cluster metrics in
:mod:`repro.core.metrics` (GAR, SOR, GFR, JWTD, JTTED) — see
``docs/metrics.md`` for the full glossary.  A routed request produces
one :class:`RequestOutcome`; :class:`ServingMetrics` aggregates them
into the numbers the serving bench gates on.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class RequestOutcome:
    """What happened to one routed request.

    ``rejected`` means the router returned no feasible replica (counts
    as an SLO miss); ``quality_ok`` means the serving replica's
    capability met the query class's quality floor.  Times are
    simulated seconds."""
    uid: int
    qclass: str
    replica: Optional[int]          # replica index, None if rejected
    rejected: bool
    ttft_s: float = 0.0             # arrival -> first output token
    tpot_s: float = 0.0             # per-token decode time
    latency_s: float = 0.0          # arrival -> last token
    slo_s: float = 0.0              # the class's latency SLO
    quality_ok: bool = False
    cost: float = 0.0               # $-like units for the whole request
    tokens: int = 0                 # prompt + output tokens served

    @property
    def slo_ok(self) -> bool:
        """SLO attainment: served, within latency SLO, quality met."""
        return (not self.rejected and self.quality_ok
                and self.latency_s <= self.slo_s)


@dataclasses.dataclass
class ServingMetrics:
    """Aggregate serving metrics over a routed trace."""

    outcomes: List[RequestOutcome] = dataclasses.field(default_factory=list)

    def record(self, o: RequestOutcome) -> None:
        self.outcomes.append(o)

    # -- headline numbers ----------------------------------------------
    def slo_attainment(self) -> float:
        """Fraction of ALL requests (rejections included) that met
        their latency SLO on a quality-feasible replica."""
        if not self.outcomes:
            return 1.0
        return sum(o.slo_ok for o in self.outcomes) / len(self.outcomes)

    def total_cost(self) -> float:
        return sum(o.cost for o in self.outcomes)

    def served_tokens(self) -> int:
        return sum(o.tokens for o in self.outcomes if not o.rejected)

    def cost_per_1k_tokens(self) -> float:
        tok = self.served_tokens()
        return 1000.0 * self.total_cost() / tok if tok else 0.0

    def rejected(self) -> int:
        return sum(o.rejected for o in self.outcomes)

    def _served(self) -> List[RequestOutcome]:
        return [o for o in self.outcomes if not o.rejected]

    def mean_ttft_s(self) -> float:
        s = self._served()
        return float(np.mean([o.ttft_s for o in s])) if s else 0.0

    def p90_ttft_s(self) -> float:
        s = self._served()
        return float(np.percentile([o.ttft_s for o in s], 90)) if s else 0.0

    def mean_tpot_s(self) -> float:
        s = self._served()
        return float(np.mean([o.tpot_s for o in s])) if s else 0.0

    # -- breakdowns -----------------------------------------------------
    def by_class(self) -> Dict[str, Dict[str, float]]:
        out: Dict[str, Dict[str, float]] = {}
        classes = sorted({o.qclass for o in self.outcomes})
        for c in classes:
            sub = [o for o in self.outcomes if o.qclass == c]
            out[c] = {
                "n": float(len(sub)),
                "slo_attainment": sum(o.slo_ok for o in sub) / len(sub),
                "rejected": float(sum(o.rejected for o in sub)),
                "cost": float(sum(o.cost for o in sub)),
            }
        return out

    def replica_share(self) -> Dict[int, int]:
        """Requests served per replica index."""
        share: Dict[int, int] = {}
        for o in self._served():
            share[o.replica] = share.get(o.replica, 0) + 1
        return share

    def report(self) -> Dict[str, float]:
        return {
            "requests": float(len(self.outcomes)),
            "rejected": float(self.rejected()),
            "slo_attainment": self.slo_attainment(),
            "total_cost": self.total_cost(),
            "cost_per_1k_tokens": self.cost_per_1k_tokens(),
            "mean_ttft_s": self.mean_ttft_s(),
            "p90_ttft_s": self.p90_ttft_s(),
            "mean_tpot_s": self.mean_tpot_s(),
        }

    def publish(self, registry, pool: str = "pool") -> None:
        """Push the headline numbers into a telemetry
        :class:`~repro.obs.registry.MetricRegistry` (gauges labeled by
        pool name).  Duck-typed on the registry — this module never
        imports :mod:`repro.obs`."""
        for key, value in self.report().items():
            registry.gauge("serving_" + key,
                           "serving fabric headline metric").set(
                value, pool=pool)
