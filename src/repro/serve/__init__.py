"""Serving fabric: continuous-batching engine, replica pool, router.

* :mod:`repro.serve.engine`   — per-slot continuous-batching engine:
  admit prefills the new request alone and splices its cache rows into
  the live batch; residents are never re-prefilled (the legacy
  whole-batch re-prefill shim survives as ``per_slot_prefill=False``).
* :mod:`repro.serve.replica`  — heterogeneous :class:`ReplicaPool` in
  simulated time, with demand export to the TidalAutoscaler.
* :mod:`repro.serve.router`   — pluggable RouterPolicy plugins:
  round-robin, least-loaded, ECCOS-style capability/cost.
* :mod:`repro.serve.requests` — workload-level ↔ engine-level request
  bridging.
* :mod:`repro.serve.metrics`  — TTFT / TPOT / SLO attainment / cost.
* :mod:`repro.serve.step`     — prefill/decode step factories.

See ``docs/serving.md`` for the architecture and the router policy
contract, ``docs/metrics.md`` for the metric definitions.
"""

from .engine import Request, ServeEngine
from .metrics import RequestOutcome, ServingMetrics
from .replica import Replica, ReplicaPool, ReplicaSpec, demand_service
from .requests import to_engine_request
from .router import (CapabilityCostRouter, LeastLoadedRouter,
                     RoundRobinRouter)
from .step import make_decode_step, make_prefill_step

__all__ = ["Request", "ServeEngine", "make_decode_step",
           "make_prefill_step", "RequestOutcome", "ServingMetrics",
           "Replica", "ReplicaPool", "ReplicaSpec", "demand_service",
           "to_engine_request", "CapabilityCostRouter",
           "LeastLoadedRouter", "RoundRobinRouter"]
