"""Serving substrate: prefill/decode step factories + batched engine."""

from .engine import Request, ServeEngine
from .step import make_decode_step, make_prefill_step

__all__ = ["Request", "ServeEngine", "make_decode_step",
           "make_prefill_step"]
