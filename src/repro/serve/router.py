"""Built-in RouterPolicy plugins (query → replica routing).

The request-level siblings of the federation's ClusterSelect policies:
round-robin and least-loaded are the load-only baselines; ECCOS-style
:class:`CapabilityCostRouter` is the two-stage capability/cost policy
the serving bench gates on.  All register in the shared framework
registry, so config-driven assemblies can mix them with out-of-tree
policies (see docs/serving.md for a worked custom-policy example).
"""

from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

from ..core.framework.api import RouterPolicyPlugin
from ..core.framework.registry import register
from ..core.workload import ServeRequest


@register
class RoundRobinRouter(RouterPolicyPlugin):
    """Cycle through replicas regardless of load, cost or capability."""

    name = "RoundRobinRouter"

    def __init__(self) -> None:
        self._next = 0

    def select(self, request: ServeRequest, replicas: Sequence,
               now: float) -> Optional[int]:
        idx = self._next % len(replicas)
        self._next += 1
        return idx


@register
class LeastLoadedRouter(RouterPolicyPlugin):
    """Pick the replica with the smallest queued backlog (seconds of
    work ahead of the request).  Load-aware, capability/cost-blind.
    Ties rotate round-robin — a fixed tie-break would herd every
    request onto replica 0 whenever the fleet is idle."""

    name = "LeastLoadedRouter"

    def __init__(self) -> None:
        self._tick = 0

    def select(self, request: ServeRequest, replicas: Sequence,
               now: float) -> Optional[int]:
        n = len(replicas)
        self._tick += 1
        return min(range(n),
                   key=lambda i: (replicas[i].backlog_s(now),
                                  (i - self._tick) % n))


@register
class CapabilityCostRouter(RouterPolicyPlugin):
    """ECCOS-style two-stage routing: capability predictor, then
    constrained cost minimisation.

    **Stage 1 (capability predictor).**  A cheap per-(class, replica)
    capability estimate decides which replicas can answer the query
    acceptably.  The prior is the replica's declared
    :attr:`~repro.serve.replica.ReplicaSpec.capability`; with
    ``learn=True`` the estimate is refined online from
    :meth:`observe` feedback (quality outcomes of completed requests),
    so a mis-declared replica is routed around after a few misses.

    **Stage 2 (constrained cost minimiser).**  Among capability-feasible
    replicas whose *predicted* latency (queue wait + prefill + decode)
    meets the request's SLO, pick the cheapest per token; ties break
    toward lower predicted latency, then lower index.  If no replica
    passes stage 1, or ``reject_infeasible`` and none meets the SLO,
    the request is REJECTED (returns ``None``) rather than knowingly
    burning tokens on an answer that misses its floor — the pool books
    the rejection as an SLO miss, so rejection is never a free lunch
    for the attainment number.  With ``reject_infeasible=False`` an
    SLO-tight request degrades to the fastest capability-feasible
    replica instead.
    """

    name = "CapabilityCostRouter"

    def __init__(self, *, slo_margin: float = 1.0,
                 reject_infeasible: bool = True,
                 learn: bool = False, learn_rate: float = 0.2) -> None:
        self.slo_margin = slo_margin
        self.reject_infeasible = reject_infeasible
        self.learn = learn
        self.learn_rate = learn_rate
        # (qclass, replica) -> learned quality estimate (EWMA of
        # observed quality_ok); consulted only when learn=True.
        self._quality: Dict[Tuple[str, int], float] = {}

    # -- stage 1: capability prediction --------------------------------
    def predicted_capability(self, request: ServeRequest,
                             replicas: Sequence, i: int) -> float:
        prior = replicas[i].spec.capability
        if not self.learn:
            return prior
        return self._quality.get((request.qclass.name, i), prior)

    def observe(self, outcome) -> None:
        if not self.learn or outcome.rejected:
            return
        key = (outcome.qclass, outcome.replica)
        prev = self._quality.get(key)
        q = 1.0 if outcome.quality_ok else 0.0
        self._quality[key] = (q if prev is None
                              else prev + self.learn_rate * (q - prev))

    # -- stage 2: constrained cost minimisation ------------------------
    def select(self, request: ServeRequest, replicas: Sequence,
               now: float) -> Optional[int]:
        floor = request.qclass.quality_floor
        capable = [i for i in range(len(replicas))
                   if self.predicted_capability(request, replicas, i)
                   >= floor]
        if not capable:
            return None
        slo = request.qclass.latency_slo_s * self.slo_margin
        lat = {i: replicas[i].estimate_latency(request, now)
               for i in capable}
        feasible = [i for i in capable if lat[i] <= slo]
        if not feasible:
            if self.reject_infeasible:
                return None
            return min(capable, key=lambda i: (lat[i], i))
        return min(feasible,
                   key=lambda i: (replicas[i].spec.cost_per_1k_tokens,
                                  lat[i], i))
