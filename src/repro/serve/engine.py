"""Continuous-batching serving engine (per-slot prefill, CPU-scale).

The engine keeps one fixed-size decode batch of **slots**.  Admission is
per-slot: a newly admitted request is prefilled *alone* (a ``B=1``
prefill of just its own prompt) and its KV/SSM cache rows are spliced
into the live batch cache at the slot index — resident requests keep
decoding undisturbed and are **never re-prefilled**.  Each slot carries
its own position clock (the ``(B,)`` cache-length vector understood by
:func:`repro.models.layers.decode_attention`), so sequences of different
lengths coexist in one batch without left-padding — request outputs are
independent of what else happens to share the batch.

Per-request accounting (TTFT / TPOT in engine steps, deadline eviction,
prefill-call counting) makes the engine the measurement substrate for
the serving fabric (:mod:`repro.serve.replica` scales the same slot
semantics to replica pools in simulated time).

The pre-fabric behaviour — re-prefill the *whole* batch on every admit,
one shared position clock, left-padded to the batch max — is preserved
as ``ServeEngine(..., per_slot_prefill=False)`` for A/B comparison and
backward compatibility (``examples/inference_cluster.py`` pins it).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..models.model import Model

PyTree = Any


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int = 16
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # -- serving-fabric accounting ------------------------------------
    qclass: str = "default"       # query class (workload.QueryClass name)
    #: evict the request this many engine steps after admission (None =
    #: never); evicted requests come back ``done`` with ``evicted`` set.
    deadline_steps: Optional[int] = None
    evicted: bool = False
    submitted_step: Optional[int] = None
    admitted_step: Optional[int] = None
    first_token_step: Optional[int] = None
    finished_step: Optional[int] = None

    @property
    def ttft_steps(self) -> Optional[int]:
        """Engine steps from submission to the first generated token."""
        if self.first_token_step is None or self.submitted_step is None:
            return None
        return self.first_token_step - self.submitted_step

    @property
    def tpot_steps(self) -> Optional[float]:
        """Mean engine steps per generated token after the first."""
        if (self.finished_step is None or self.first_token_step is None
                or len(self.generated) <= 1):
            return None
        return ((self.finished_step - self.first_token_step)
                / (len(self.generated) - 1))


class ServeEngine:
    """Fixed-slot continuous-batching engine over one model replica.

    ``per_slot_prefill=True`` (default): per-slot admission as described
    in the module docstring.  ``False``: the legacy full-batch re-prefill
    shim (every admit replays prompt+generated of *all* resident slots,
    left-padded to one shared length).
    """

    def __init__(self, cfg: ArchConfig, params: PyTree, *,
                 batch_size: int = 4, max_seq: int = 256,
                 eos_id: Optional[int] = None,
                 per_slot_prefill: bool = True) -> None:
        self.cfg = cfg
        self.model = Model(cfg)
        self.params = params
        self.B = batch_size
        self.max_seq = max_seq
        self.eos_id = eos_id
        self.per_slot = per_slot_prefill
        self._prefill = jax.jit(
            lambda p, b: self.model.prefill(p, b, seq_len=max_seq))
        self._decode = jax.jit(self.model.decode_step)
        self._splice = jax.jit(self._splice_impl)
        self.queue: List[Request] = []
        self.slots: List[Optional[Request]] = [None] * batch_size
        self.cache: Optional[PyTree] = None
        self.last_token = np.zeros(batch_size, np.int32)
        self.steps = 0
        # Prefill accounting: ``prefill_tokens`` counts every token that
        # ran through a prefill pass.  Per-slot admission keeps this at
        # exactly sum(len(prompt)) over admitted requests; the legacy
        # shim re-runs resident sequences so it grows superlinearly
        # (asserted by benchmarks/serving_bench.py).
        self.prefill_calls = 0
        self.prefill_tokens = 0
        self.evictions = 0

    # ------------------------------------------------------------------
    def stats(self) -> dict:
        """Engine counters for telemetry pull-collection."""
        return {"steps": self.steps,
                "prefill_calls": self.prefill_calls,
                "prefill_tokens": self.prefill_tokens,
                "evictions": self.evictions,
                "queued": len(self.queue),
                "resident": sum(1 for s in self.slots
                                if s is not None and not s.done)}

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        if req.submitted_step is None:
            req.submitted_step = self.steps
        self.queue.append(req)

    def evict(self, uid: int) -> bool:
        """Evict a resident request by uid (frees its slot next admit)."""
        for s in self.slots:
            if s is not None and s.uid == uid and not s.done:
                self._mark_evicted(s)
                return True
        return False

    def _mark_evicted(self, req: Request) -> None:
        req.evicted = True
        req.done = True
        req.finished_step = self.steps
        self.evictions += 1

    def _evict_expired(self) -> None:
        for s in self.slots:
            if (s is not None and not s.done
                    and s.deadline_steps is not None
                    and s.admitted_step is not None
                    and self.steps - s.admitted_step >= s.deadline_steps):
                self._mark_evicted(s)

    # ------------------------------------------------------------------
    # Per-slot admission (continuous batching)
    # ------------------------------------------------------------------
    def _solo_batch(self, seq: np.ndarray) -> Dict[str, jnp.ndarray]:
        batch = {"tokens": jnp.asarray(seq[None, :])}
        if self.cfg.family == "vlm":
            from ..models.frontend import patch_embeds
            batch["patch_embeds"] = patch_embeds(self.cfg, 1)
        if self.cfg.family == "encdec":
            from ..models.frontend import frame_embeds
            # Fixed encoder length: the spliced memory rows must share
            # one shape across slots regardless of prompt length.
            batch["enc_embeds"] = frame_embeds(self.cfg, 1,
                                               self.max_seq * 4)
        return batch

    def _batch_template(self, solo: PyTree) -> PyTree:
        """Empty B-slot cache shaped like a solo (B=1) prefill cache."""
        def z(x):
            return jnp.zeros((x.shape[0], self.B) + x.shape[2:], x.dtype)
        tpl: PyTree = {"layers": jax.tree.map(z, solo["layers"]),
                       "t": jnp.zeros((self.B,), jnp.int32)}
        if "memory" in solo:
            tpl["memory"] = jax.tree.map(z, solo["memory"])
        return tpl

    def _splice_impl(self, cache: PyTree, solo: PyTree, i) -> PyTree:
        """Copy the solo cache's single batch row into slot ``i``."""
        def put(c, s):
            return c.at[:, i].set(s[:, 0])
        out: PyTree = {"layers": jax.tree.map(put, cache["layers"],
                                              solo["layers"]),
                       "t": cache["t"].at[i].set(
                           solo["t"].astype(cache["t"].dtype))}
        if "memory" in cache:
            out["memory"] = jax.tree.map(put, cache["memory"],
                                         solo["memory"])
        return out

    def _admit_per_slot(self) -> None:
        """Fill empty slots one request at a time: prefill the incoming
        request ALONE and splice its cache rows into the live batch —
        resident slots keep their cache and their position clocks."""
        for i in range(self.B):
            s = self.slots[i]
            if not ((s is None or s.done) and self.queue):
                continue
            req = self.queue.pop(0)
            seq = np.concatenate([req.prompt,
                                  np.asarray(req.generated, np.int32)])
            logits, solo = self._prefill(self.params,
                                         self._solo_batch(seq))
            self.prefill_calls += 1
            self.prefill_tokens += len(seq)
            if self.cache is None:
                self.cache = self._batch_template(solo)
            self.cache = self._splice(self.cache, solo,
                                      jnp.asarray(i, jnp.int32))
            if not self.last_token.flags.writeable:
                self.last_token = self.last_token.copy()
            self.last_token[i] = int(jnp.argmax(logits[0]))
            req.admitted_step = self.steps
            self.slots[i] = req

    # ------------------------------------------------------------------
    # Legacy full-batch re-prefill (the pre-fabric shim)
    # ------------------------------------------------------------------
    def _admit_rebatch(self) -> None:
        """Fill empty slots; (re)prefill the whole batch when admitting.

        Legacy shim: admission re-prefills every active prompt + its
        generated tokens so all slots share one cache and one position
        clock (left-padded to the batch max).  Kept for A/B comparison;
        resident outputs depend on co-resident lengths through the
        left-pad, which is why the per-slot path replaced it."""
        changed = False
        for i in range(self.B):
            if (self.slots[i] is None or self.slots[i].done) and self.queue:
                req = self.queue.pop(0)
                req.admitted_step = self.steps
                self.slots[i] = req
                changed = True
        if not changed or all(s is None for s in self.slots):
            return
        S = max((len(s.prompt) + len(s.generated))
                for s in self.slots if s is not None)
        toks = np.zeros((self.B, S), np.int32)
        for i, s in enumerate(self.slots):
            if s is None:
                continue
            seq = np.concatenate([s.prompt, np.asarray(s.generated,
                                                       np.int32)])
            toks[i, -len(seq):] = seq          # left-pad
            self.prefill_tokens += len(seq)
        self.prefill_calls += 1
        batch = {"tokens": jnp.asarray(toks)}
        if self.cfg.family == "vlm":
            from ..models.frontend import patch_embeds
            batch["patch_embeds"] = patch_embeds(self.cfg, self.B)
        if self.cfg.family == "encdec":
            from ..models.frontend import frame_embeds
            batch["enc_embeds"] = frame_embeds(self.cfg, self.B, S * 4)
        logits, self.cache = self._prefill(self.params, batch)
        self.last_token = np.asarray(jnp.argmax(logits, -1), np.int32)

    def _admit(self) -> None:
        self._evict_expired()
        if self.per_slot:
            self._admit_per_slot()
        else:
            self._admit_rebatch()

    # ------------------------------------------------------------------
    def step(self) -> int:
        """One engine tick: admit + one decode step.  Returns number of
        active requests."""
        self._admit()
        active = [i for i, s in enumerate(self.slots)
                  if s is not None and not s.done]
        if not active or self.cache is None:
            return 0
        for i in active:
            s = self.slots[i]
            if not s.generated:
                s.first_token_step = self.steps
            s.generated.append(int(self.last_token[i]))
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(self.last_token))
        self.last_token = np.asarray(jnp.argmax(logits, -1), np.int32)
        for i in active:
            s = self.slots[i]
            if len(s.generated) >= s.max_new_tokens or \
                    (self.eos_id is not None
                     and s.generated[-1] == self.eos_id):
                s.done = True
                s.finished_step = self.steps
        self.steps += 1
        return len(active)

    def run_until_drained(self, max_steps: int = 10_000) -> List[Request]:
        finished: List[Request] = []
        for _ in range(max_steps):
            if not self.queue and all(
                    s is None or s.done for s in self.slots):
                break
            self.step()
            for i, s in enumerate(self.slots):
                if s is not None and s.done:
                    finished.append(s)
                    self.slots[i] = None
        # Collect anything already done before the loop broke out.
        for i, s in enumerate(self.slots):
            if s is not None and s.done:
                finished.append(s)
                self.slots[i] = None
        return finished
