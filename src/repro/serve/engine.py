"""Batched serving engine (continuous-batching flavoured, CPU-scale).

The engine keeps one fixed-size decode batch; requests occupy slots,
finished slots are refilled from the queue.  This is the "inference
service" workload kind Kant schedules with Spread/E-Spread — the
``examples/inference_cluster.py`` demo runs several replica engines whose
pods were placed by RSCH.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..models.model import Model

PyTree = Any


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int = 16
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params: PyTree, *,
                 batch_size: int = 4, max_seq: int = 256,
                 eos_id: Optional[int] = None) -> None:
        self.cfg = cfg
        self.model = Model(cfg)
        self.params = params
        self.B = batch_size
        self.max_seq = max_seq
        self.eos_id = eos_id
        self._prefill = jax.jit(
            lambda p, b: self.model.prefill(p, b, seq_len=max_seq))
        self._decode = jax.jit(self.model.decode_step)
        self.queue: List[Request] = []
        self.slots: List[Optional[Request]] = [None] * batch_size
        self.cache: Optional[PyTree] = None
        self.last_token = np.zeros(batch_size, np.int32)
        self.steps = 0

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        """Fill empty slots; (re)prefill the whole batch when admitting.

        CPU-scale simplification: admission re-prefills every active
        prompt + its generated tokens so all slots share one cache.  A
        production engine would insert per-slot; the Kant integration
        only needs request-level throughput semantics.
        """
        changed = False
        for i in range(self.B):
            if (self.slots[i] is None or self.slots[i].done) and self.queue:
                self.slots[i] = self.queue.pop(0)
                changed = True
        if not changed or all(s is None for s in self.slots):
            return
        S = max((len(s.prompt) + len(s.generated))
                for s in self.slots if s is not None)
        toks = np.zeros((self.B, S), np.int32)
        for i, s in enumerate(self.slots):
            if s is None:
                continue
            seq = np.concatenate([s.prompt, np.asarray(s.generated,
                                                       np.int32)])
            toks[i, -len(seq):] = seq          # left-pad
        batch = {"tokens": jnp.asarray(toks)}
        if self.cfg.family == "vlm":
            from ..models.frontend import patch_embeds
            batch["patch_embeds"] = patch_embeds(self.cfg, self.B)
        if self.cfg.family == "encdec":
            from ..models.frontend import frame_embeds
            batch["enc_embeds"] = frame_embeds(self.cfg, self.B, S * 4)
        logits, self.cache = self._prefill(self.params, batch)
        self.last_token = np.asarray(jnp.argmax(logits, -1), np.int32)

    # ------------------------------------------------------------------
    def step(self) -> int:
        """One engine tick: admit + one decode step.  Returns number of
        active requests."""
        self._admit()
        active = [i for i, s in enumerate(self.slots)
                  if s is not None and not s.done]
        if not active or self.cache is None:
            return 0
        for i in active:
            self.slots[i].generated.append(int(self.last_token[i]))
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(self.last_token))
        self.last_token = np.asarray(jnp.argmax(logits, -1), np.int32)
        self.steps += 1
        for i in active:
            s = self.slots[i]
            if len(s.generated) >= s.max_new_tokens or \
                    (self.eos_id is not None
                     and s.generated[-1] == self.eos_id):
                s.done = True
        return len(active)

    def run_until_drained(self, max_steps: int = 10_000) -> List[Request]:
        finished: List[Request] = []
        for _ in range(max_steps):
            if not self.queue and all(
                    s is None or s.done for s in self.slots):
                break
            self.step()
            for i, s in enumerate(self.slots):
                if s is not None and s.done:
                    finished.append(s)
                    self.slots[i] = None
        return finished
