"""Replica pool: heterogeneous model replicas behind one router.

A :class:`Replica` is a timing model of one serving instance — ``slots``
parallel decode slots (the engine's batch size), a prefill/decode token
rate and a cost per token — driven in *simulated* time so a routing
bench can push thousands of requests through policy A/B runs in
milliseconds.  The slot semantics mirror :class:`repro.serve.engine.
ServeEngine` (per-slot admission, no re-prefill of residents); a replica
built from an :class:`~repro.configs.base.ArchConfig` via
:meth:`ReplicaSpec.from_arch` can materialise the real engine with
:meth:`Replica.build_engine` when token-level fidelity matters (tests,
the bench's prefill-count gate).

:class:`ReplicaPool` routes a request trace through a
:class:`~repro.core.framework.api.RouterPolicyPlugin`, aggregates
:class:`~repro.serve.metrics.ServingMetrics`, and exports the observed
replica demand to the cluster simulator's
:class:`~repro.core.dynamics.tidal.TidalAutoscaler` via
:func:`demand_service` — the hand-off that makes the serving tier and
the cluster simulator talk.
"""

from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Dict, List, Optional, Sequence

from ..configs.base import ArchConfig
from ..core.framework.api import RouterPolicyPlugin
from ..core.workload import ServeRequest
from .metrics import RequestOutcome, ServingMetrics


@dataclasses.dataclass(frozen=True)
class ReplicaSpec:
    """Static description of one replica tier.

    ``capability`` is on the same 0..1 scale as
    :attr:`repro.core.workload.QueryClass.quality_floor`; cost and token
    rates are per-replica constants (the timing model's parameters)."""
    name: str
    capability: float               # 0..1 answer-quality proxy
    cost_per_1k_tokens: float       # $-like units
    prefill_tokens_per_s: float = 4000.0
    decode_tokens_per_s: float = 40.0
    slots: int = 4                  # parallel decode slots (batch size)
    arch: Optional[str] = None      # repro.configs arch id, if any

    @classmethod
    def from_arch(cls, arch_id: str, *, capability: Optional[float] = None,
                  cost_per_1k_tokens: Optional[float] = None,
                  slots: int = 4, smoke: bool = False,
                  flops_per_s: float = 1e15) -> "ReplicaSpec":
        """Derive a spec from an architecture's parameter count.

        Token rates follow the 2·N-FLOPs-per-token rule against a
        nominal accelerator budget; capability and cost default to
        log-param scalings (bigger ⇒ more capable, pricier, slower) —
        crude, but heterogeneous in the right direction, and every
        number can be overridden."""
        from ..configs import get_arch
        cfg = get_arch(arch_id, smoke=smoke)
        n = float(cfg.n_params())
        # 0.5 at ~1e9 params -> ~1.0 at 1e12, floor 0.1.
        cap = capability if capability is not None else min(
            1.0, max(0.1, 0.5 + 0.167 * math.log10(max(n, 1.0) / 1e9)))
        cost = (cost_per_1k_tokens if cost_per_1k_tokens is not None
                else n / 1e9)      # ~$1 per 1k tokens per B params
        tok_s = flops_per_s / (2.0 * max(n, 1.0))
        return cls(name=arch_id, capability=cap,
                   cost_per_1k_tokens=cost,
                   prefill_tokens_per_s=tok_s * 8.0,  # prefill batches well
                   decode_tokens_per_s=tok_s,
                   slots=slots, arch=arch_id)


class Replica:
    """One serving instance: ``spec.slots`` parallel decode slots in
    simulated time (an M/G/c-style free-time heap)."""

    def __init__(self, spec: ReplicaSpec) -> None:
        self.spec = spec
        # Earliest-free simulated time per slot.
        self._free: List[float] = [0.0] * spec.slots
        heapq.heapify(self._free)
        self.served = 0
        self.busy_s = 0.0

    # -- load signals ---------------------------------------------------
    def backlog_s(self, now: float) -> float:
        """Total queued work: seconds until each slot frees, summed."""
        return sum(max(0.0, f - now) for f in self._free)

    def busy_slots(self, now: float) -> int:
        return sum(1 for f in self._free if f > now)

    # -- timing model ---------------------------------------------------
    def service_times(self, req: ServeRequest, now: float
                      ) -> Dict[str, float]:
        """Predicted (wait, ttft, latency, service) for ``req`` admitted
        at ``now`` — the router's feasibility oracle and the commit
        path share this arithmetic."""
        wait = max(0.0, self._free[0] - now)
        prefill = req.prompt_tokens / self.spec.prefill_tokens_per_s
        decode = req.output_tokens / self.spec.decode_tokens_per_s
        return {"wait": wait, "ttft": wait + prefill,
                "latency": wait + prefill + decode,
                "service": prefill + decode}

    def estimate_latency(self, req: ServeRequest, now: float) -> float:
        return self.service_times(req, now)["latency"]

    def admit(self, req: ServeRequest, now: float, index: int
              ) -> RequestOutcome:
        """Commit ``req`` to this replica's earliest-free slot."""
        t = self.service_times(req, now)
        start = heapq.heappop(self._free)
        start = max(start, now)
        heapq.heappush(self._free, start + t["service"])
        self.served += 1
        self.busy_s += t["service"]
        cost = self.spec.cost_per_1k_tokens * req.total_tokens / 1000.0
        return RequestOutcome(
            uid=req.uid, qclass=req.qclass.name, replica=index,
            rejected=False,
            ttft_s=t["ttft"],
            tpot_s=1.0 / self.spec.decode_tokens_per_s,
            latency_s=t["latency"],
            slo_s=req.qclass.latency_slo_s,
            quality_ok=self.spec.capability >= req.qclass.quality_floor,
            cost=cost, tokens=req.total_tokens)

    # -- token-level fidelity ------------------------------------------
    def build_engine(self, params, *, max_seq: int = 256,
                     smoke: bool = False, per_slot_prefill: bool = True):
        """Materialise the real :class:`~repro.serve.engine.ServeEngine`
        for this replica's architecture (requires ``spec.arch``)."""
        if self.spec.arch is None:
            raise ValueError(f"replica {self.spec.name!r} has no arch id")
        from ..configs import get_arch
        from .engine import ServeEngine
        cfg = get_arch(self.spec.arch, smoke=smoke)
        return ServeEngine(cfg, params, batch_size=self.spec.slots,
                           max_seq=max_seq,
                           per_slot_prefill=per_slot_prefill)


class ReplicaPool:
    """Heterogeneous replicas + a pluggable router policy."""

    def __init__(self, specs: Sequence[ReplicaSpec],
                 policy: RouterPolicyPlugin,
                 demand_bucket_s: float = 300.0) -> None:
        if not specs:
            raise ValueError("a pool needs at least one replica")
        self.replicas = [Replica(s) for s in specs]
        self.policy = policy
        self.metrics = ServingMetrics()
        # Observed arrival counts per time bucket (the demand signal
        # exported to the tidal autoscaler).
        self.demand_bucket_s = float(demand_bucket_s)
        self._arrivals: Dict[int, int] = {}
        self._service_s_sum = 0.0
        self._service_n = 0

    # -- routing --------------------------------------------------------
    def route(self, req: ServeRequest, now: Optional[float] = None
              ) -> RequestOutcome:
        now = req.arrival_s if now is None else now
        self._arrivals[int(now // self.demand_bucket_s)] = \
            self._arrivals.get(int(now // self.demand_bucket_s), 0) + 1
        idx = self.policy.select(req, self.replicas, now)
        if idx is None:
            out = RequestOutcome(uid=req.uid, qclass=req.qclass.name,
                                 replica=None, rejected=True,
                                 slo_s=req.qclass.latency_slo_s)
        else:
            rep = self.replicas[idx]
            out = rep.admit(req, now, idx)
            self._service_s_sum += out.latency_s - out.ttft_s \
                + req.prompt_tokens / rep.spec.prefill_tokens_per_s
            self._service_n += 1
        self.metrics.record(out)
        self.policy.observe(out)
        return out

    def route_trace(self, trace: Sequence[ServeRequest]) -> ServingMetrics:
        for req in sorted(trace, key=lambda r: r.arrival_s):
            self.route(req)
        return self.metrics

    # -- telemetry ------------------------------------------------------
    def bind_registry(self, registry, name: str = "pool") -> None:
        """Register a pull collector on a telemetry registry: on every
        ``collect()`` the pool publishes its headline serving metrics
        plus the observed-load signal (requests routed, Little's-law
        replica demand at the latest bucket)."""

        def collect(reg) -> None:
            self.metrics.publish(reg, pool=name)
            last_bucket = max(self._arrivals) if self._arrivals else 0
            t = last_bucket * self.demand_bucket_s
            reg.gauge("serving_observed_rps",
                      "observed arrival rate, latest bucket").set(
                self.observed_rps(t), pool=name)
            reg.gauge("serving_replica_demand",
                      "Little's-law replicas needed, latest bucket").set(
                self.replica_demand(t), pool=name)
            reg.gauge("serving_replicas", "replicas in the pool").set(
                len(self.replicas), pool=name)

        registry.add_collector(collect)

    # -- demand export --------------------------------------------------
    def observed_rps(self, t: float) -> float:
        """Observed arrival rate (requests/s) in the bucket holding
        ``t`` — piecewise-constant, zero where nothing arrived."""
        return (self._arrivals.get(int(t // self.demand_bucket_s), 0)
                / self.demand_bucket_s)

    def mean_service_s(self) -> float:
        if not self._service_n:
            return 1.0
        return self._service_s_sum / self._service_n

    def replica_demand(self, t: float) -> float:
        """Replicas needed to serve the observed rate at ``t``: Little's
        law (rate × mean service time = busy slots) over slots/replica."""
        slots = max(1, self.replicas[0].spec.slots)
        return self.observed_rps(t) * self.mean_service_s() / slots


def demand_service(pool: ReplicaPool, *, name: str = "serving",
                   min_replicas: int = 1, max_replicas: int = 8,
                   gpus_per_replica: int = 1, tenant: str = "svc",
                   gpu_type: int = 0):
    """Build a :class:`~repro.core.dynamics.tidal.TidalService` whose
    demand curve is the pool's OBSERVED request load — the serving
    fabric's hand-off to the cluster simulator's TidalAutoscaler."""
    from ..core.dynamics.tidal import TidalService
    return TidalService(name=name, tenant=tenant, gpu_type=gpu_type,
                        gpus_per_replica=gpus_per_replica,
                        min_replicas=min_replicas,
                        max_replicas=max_replicas,
                        demand=pool.replica_demand)
