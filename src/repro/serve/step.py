"""Serving step factories.

``prefill_step`` runs the prompt and emits the ring-buffer KV (or SSM)
cache; ``decode_step`` advances one token against it.  The decode shapes
of the dry-run (decode_32k, long_500k) lower exactly these functions —
one new token against a ``seq_len`` (windowed) cache, never a
``train_step``.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..models.model import Model

PyTree = Any


def make_prefill_step(cfg: ArchConfig, seq_len: int
                      ) -> Callable[[PyTree, Dict[str, jnp.ndarray]],
                                    Tuple[jnp.ndarray, PyTree]]:
    """(params, batch) -> (last-token logits, cache sized for seq_len)."""
    model = Model(cfg)

    def prefill_step(params, batch):
        return model.prefill(params, batch, seq_len=seq_len)

    return prefill_step


def make_decode_step(cfg: ArchConfig
                     ) -> Callable[[PyTree, PyTree, jnp.ndarray],
                                   Tuple[jnp.ndarray, PyTree]]:
    """(params, cache, token (B,)) -> (logits (B, V), new cache)."""
    model = Model(cfg)

    def decode_step(params, cache, token):
        return model.decode_step(params, cache, token)

    return decode_step
